"""Application benchmarks (paper §6.3): Vacation-like OLTP and a
Memcached-like KV store under YCSB-A, both persisting their data
structures through the allocator under test."""

from __future__ import annotations

import random
import time

from repro.core import pptr as pp


class PersistentBST:
    """Durably-linearizable BST over an allocator (Vacation's 'database').

    Node: [key, value, left pptr, right pptr] — paper Fig. 4's type.
    """

    def __init__(self, alloc):
        self.a = alloc
        self.root = None

    def insert(self, key, value):
        a = self.a
        node = a.malloc(32)
        r = getattr(a, "r", a)             # raw word access via adapter
        mem = a.mem
        base = node
        mem.write(base, key)
        mem.write(base + 1, value)
        mem.write(base + 2, pp.PPTR_NULL)
        mem.write(base + 3, pp.PPTR_NULL)
        mem.flush(base)
        mem.fence()
        if self.root is None:
            self.root = node
            return
        cur = self.root
        while True:
            slot = 2 if key < mem.read(cur) else 3
            child = pp.decode(cur + slot, mem.read(cur + slot))
            if child is None:
                mem.write(cur + slot, pp.encode(cur + slot, node))
                mem.flush(cur + slot)
                mem.fence()
                return
            cur = child

    def lookup(self, key):
        mem = self.a.mem
        cur = self.root
        while cur is not None:
            k = mem.read(cur)
            if k == key:
                return mem.read(cur + 1)
            cur = pp.decode(cur + 2, mem.read(cur + 2)) if key < k else \
                pp.decode(cur + 3, mem.read(cur + 3))
        return None


def vacation(alloc, *, relations=512, transactions=2000, queries=3):
    """Reservation transactions over BST 'tables' (STAMP Vacation shape)."""
    tree = PersistentBST(alloc)
    for k in random.Random(0).sample(range(relations * 4), relations):
        tree.insert(k, k)
    rng = random.Random(1)
    t0 = time.perf_counter()
    for _ in range(transactions):
        for _ in range(queries):
            tree.lookup(rng.randrange(relations * 4))
        tree.insert(rng.randrange(relations * 4, relations * 8),
                    rng.randrange(1 << 30))
    dt = time.perf_counter() - t0
    return transactions / dt


class PersistentKV:
    """Chained-hash KV store (library-mode memcached stand-in).

    Bucket heads live in a root directory block; entries are
    [key, value, next pptr] blocks.
    """

    def __init__(self, alloc, buckets=1024):
        self.a = alloc
        self.nb = buckets
        self.dir = alloc.malloc(buckets * 8)
        mem = alloc.mem
        for i in range(buckets):
            mem.write(self.dir + i, pp.PPTR_NULL)
        mem.flush(self.dir)
        mem.fence()

    def _bucket(self, key):
        return self.dir + (hash(key) % self.nb)

    def set(self, key, value):
        mem = self.a.mem
        b = self._bucket(key)
        node = self.a.malloc(24)
        mem.write(node, key)
        mem.write(node + 1, value)
        head = pp.decode(b, mem.read(b))
        mem.write(node + 2, pp.PPTR_NULL if head is None
                  else pp.encode(node + 2, head))
        mem.flush(node)
        mem.fence()
        mem.write(b, pp.encode(b, node))
        mem.flush(b)
        mem.fence()

    def get(self, key):
        mem = self.a.mem
        cur = pp.decode(self._bucket(key), mem.read(self._bucket(key)))
        while cur is not None:
            if mem.read(cur) == key:
                return mem.read(cur + 1)
            cur = pp.decode(cur + 2, mem.read(cur + 2))
        return None


def ycsb_a(alloc, *, records=5000, operations=10000):
    """YCSB workload A: 50% reads, 50% updates (update = new version)."""
    kv = PersistentKV(alloc)
    for k in range(records):
        kv.set(k, k)
    rng = random.Random(2)
    t0 = time.perf_counter()
    for _ in range(operations):
        k = rng.randrange(records)
        if rng.random() < 0.5:
            kv.get(k)
        else:
            kv.set(k, rng.randrange(1 << 30))
    dt = time.perf_counter() - t0
    return operations / dt
