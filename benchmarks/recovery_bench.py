"""Paper Fig. 6: recovery time vs number of reachable blocks, for a
Treiber stack and a BST, with filter functions and conservatively."""

from __future__ import annotations

import time

from repro.core import pptr as pp
from repro.core.ralloc import Ralloc


def build_stack(r, n):
    head = None
    for k in range(n):
        node = r.malloc(16)
        r.write_word(node, pp.PPTR_NULL if head is None
                     else pp.encode(node, head))
        r.write_word(node + 1, k)
        head = node
    r.flush_range(head, 2)
    r.fence()
    return head


def build_tree(r, n):
    import random
    rng = random.Random(0)
    root = None
    for key in rng.sample(range(n * 4), n):
        node = r.malloc(32)
        r.write_word(node, key)
        r.write_word(node + 1, key)
        r.write_word(node + 2, pp.PPTR_NULL)
        r.write_word(node + 3, pp.PPTR_NULL)
        if root is None:
            root = node
            continue
        cur = root
        while True:
            slot = 2 if key < r.read_word(cur) else 3
            child = pp.decode(cur + slot, r.read_word(cur + slot))
            if child is None:
                r.write_word(cur + slot, pp.encode(cur + slot, node))
                break
            cur = child
    return root


def measure(structure: str, n: int, conservative: bool = False):
    size = max(64 << 20, n * 64 * 4)
    r = Ralloc(None, size)
    builder = build_stack if structure == "stack" else build_tree
    root = builder(r, n)
    typename = None if conservative else (
        "stack_node" if structure == "stack" else "tree_node")
    r.set_root(0, root, typename)
    r.drop_all_caches()
    t0 = time.perf_counter()
    stats = r.recover()
    dt = time.perf_counter() - t0
    assert stats["reachable_blocks"] >= n
    return dt, stats


def sweep(ns=(1000, 4000, 16000), structures=("stack", "tree")):
    rows = []
    for s in structures:
        for n in ns:
            dt, stats = measure(s, n)
            rows.append({"structure": s, "blocks": n, "seconds": dt,
                         "us_per_block": dt / n * 1e6,
                         "mark_s": stats["mark_seconds"],
                         "sweep_s": stats["sweep_seconds"]})
    return rows
