"""§Roofline report: aggregate the dry-run JSONs into the per-cell table.

For every (arch × shape × mesh) cell: the three roofline terms, the
dominant bottleneck, MODEL_FLOPS = 6·N(_active)·D vs compiled HLO FLOPs
(useful-compute ratio), and memory-fit evidence.
"""

from __future__ import annotations

import json
import pathlib


def load(outdir="results/dryrun"):
    rows = []
    for f in sorted(pathlib.Path(outdir).glob("*.json")):
        d = json.loads(f.read_text())
        r = d["roofline"]
        chips = r["chips"]
        hlo_flops_global = r["flops_per_device"] * chips
        useful = (d["model_flops_global"] / hlo_flops_global
                  if hlo_flops_global else 0.0)
        dom_t = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        # roofline fraction: ideal compute time / dominant-term time
        ideal = d["model_flops_global"] / chips / 197e12
        rows.append({
            "arch": d["arch"], "shape": d["shape"], "mesh": d["mesh"],
            "kind": d["kind"],
            "t_compute_s": r["t_compute_s"],
            "t_memory_s": r["t_memory_s"],
            "t_collective_s": r["t_collective_s"],
            "dominant": r["dominant"],
            "useful_flops_ratio": useful,
            "roofline_fraction": (ideal / dom_t) if dom_t else 0.0,
            "mem_gib_per_dev": d["memory"]["peak_bytes_estimate"] / 2**30,
            "collectives": r["collective_counts"],
            "compile_s": d["compile_s"],
        })
    return rows


def table(rows, mesh="16x16"):
    out = []
    hdr = (f"{'arch':24s} {'shape':12s} {'t_comp':>8s} {'t_mem':>8s} "
           f"{'t_coll':>8s} {'dom':>10s} {'useful':>7s} {'roofl%':>7s} "
           f"{'GiB/dev':>8s}")
    out.append(hdr)
    for r in rows:
        if r["mesh"] != mesh:
            continue
        out.append(
            f"{r['arch']:24s} {r['shape']:12s} {r['t_compute_s']:8.4f} "
            f"{r['t_memory_s']:8.4f} {r['t_collective_s']:8.4f} "
            f"{r['dominant']:>10s} {r['useful_flops_ratio']:7.2f} "
            f"{100*r['roofline_fraction']:6.1f}% "
            f"{r['mem_gib_per_dev']:8.1f}")
    return "\n".join(out)


def main():
    rows = load()
    for mesh in ("16x16", "2x16x16"):
        print(f"\n=== roofline, mesh {mesh} (v5e: 197 TF/s bf16, "
              f"819 GB/s HBM, 50 GB/s ICI) ===")
        print(table(rows, mesh))


if __name__ == "__main__":
    main()
