"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (derived = ops/sec or
blocks/sec).  Allocator benches run all four allocators (ralloc,
lrmalloc = transient ancestor, makalu_lite, pmdk_lite) with modeled
Optane flush/fence latency.  The roofline section summarizes the
dry-run artifacts if present (run ``python -m repro.launch.dryrun`` to
generate them).
"""

from __future__ import annotations

import sys

from . import apps, recovery_bench, workloads
from .workloads import KINDS, fresh


def _row(name: str, ops_per_sec: float) -> None:
    us = 1e6 / ops_per_sec if ops_per_sec else float("inf")
    print(f"{name},{us:.3f},{ops_per_sec:.0f}", flush=True)


def bench_threadtest(threads=(1, 2)):
    for kind in KINDS:
        for t in threads:
            a = fresh(kind)
            _row(f"threadtest[{kind},t={t}]",
                 workloads.threadtest(a, n_threads=t))
            a.close()


def bench_shbench(threads=(1, 2)):
    for kind in KINDS:
        for t in threads:
            a = fresh(kind)
            _row(f"shbench[{kind},t={t}]", workloads.shbench(a, n_threads=t))
            a.close()


def bench_larson(threads=(1, 2)):
    for kind in KINDS:
        for t in threads:
            a = fresh(kind)
            _row(f"larson[{kind},t={t}]", workloads.larson(a, n_threads=t))
            a.close()


def bench_largebench(threads=(1, 2)):
    for kind in KINDS:
        for t in threads:
            a = fresh(kind)
            _row(f"largebench[{kind},t={t}]",
                 workloads.largebench(a, n_threads=t))
            a.close()


def bench_fragbench():
    """Steady-state span churn: the extra ``fragbench_watermark`` rows are
    ``name,watermark_growth_sbs,reuse_rate`` (not us/ops)."""
    for kind in KINDS:
        a = fresh(kind)
        ops, growth, reuse = workloads.fragbench(a)
        _row(f"fragbench[{kind},t=1]", ops)
        print(f"fragbench_watermark[{kind}],{growth:.1f},{reuse:.2f}",
              flush=True)
        a.close()


def bench_sharedprompt():
    """Shared-prompt span churn: the ``sharedprompt_footprint`` rows are
    ``name,peak_watermark_sbs,spans_saved_per_hit`` (not us/ops)."""
    for kind in KINDS:
        a = fresh(kind)
        ops, saved, peak = workloads.sharedprompt(a)
        _row(f"sharedprompt[{kind}]", ops)
        print(f"sharedprompt_footprint[{kind}],{peak:.0f},{saved:.2f}",
              flush=True)
        a.close()


def bench_prodcon(pairs=(1,)):
    for kind in KINDS:
        for p in pairs:
            a = fresh(kind)
            _row(f"prodcon[{kind},pairs={p}]", workloads.prodcon(a, n_pairs=p))
            a.close()


def bench_vacation():
    for kind in ("ralloc", "makalu_lite", "pmdk_lite"):   # persistent only
        a = fresh(kind)
        _row(f"vacation[{kind}]", apps.vacation(a))
        a.close()


def bench_ycsb():
    for kind in ("ralloc", "makalu_lite", "pmdk_lite"):
        a = fresh(kind)
        _row(f"memcached_ycsb_a[{kind}]", apps.ycsb_a(a))
        a.close()
    # paper §6.3: Makalu returns only half an over-full cache, gaining
    # locality on large-footprint apps — Ralloc offers the same knob
    from repro.core.baselines import _RallocAdapter
    from repro.core.ralloc import Ralloc
    a = _RallocAdapter(Ralloc(None, 256 << 20, keep_half=True,
                              flush_ns=workloads.FLUSH_NS,
                              fence_ns=workloads.FENCE_NS))
    _row("memcached_ycsb_a[ralloc+keep_half]", apps.ycsb_a(a))
    a.close()


def bench_recovery():
    for row in recovery_bench.sweep():
        name = f"recovery[{row['structure']},n={row['blocks']}]"
        print(f"{name},{row['us_per_block']:.3f},"
              f"{row['blocks'] / row['seconds']:.0f}", flush=True)


def bench_roofline():
    try:
        from .roofline import load, table
        rows = load()
        if not rows:
            print("# roofline: no dry-run artifacts (run repro.launch.dryrun)")
            return
        print("# roofline table (see EXPERIMENTS.md for analysis)")
        print(table(rows, "16x16"))
    except Exception as e:                   # pragma: no cover
        print(f"# roofline unavailable: {e}")


def main() -> None:
    print("name,us_per_call,derived")
    bench_threadtest()
    bench_shbench()
    bench_larson()
    bench_largebench()
    bench_fragbench()
    bench_sharedprompt()
    bench_prodcon()
    bench_vacation()
    bench_ycsb()
    bench_recovery()
    bench_roofline()


if __name__ == "__main__":
    main()
