"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (derived = ops/sec or
blocks/sec).  Allocator benches run all four allocators (ralloc,
lrmalloc = transient ancestor, makalu_lite, pmdk_lite) with modeled
Optane flush/fence latency.  The roofline section summarizes the
dry-run artifacts if present (run ``python -m repro.launch.dryrun`` to
generate them).

One entry point serves both the full runs and CI's smoke pass — the
workload list lives only here:

    python -m benchmarks.run                         # everything, full
    python -m benchmarks.run --workloads fragbench,sharedprompt --seed 3
    python -m benchmarks.run --profile smoke         # == benchmarks.smoke
"""

from __future__ import annotations

import argparse
import sys
import time

from repro import obs

from . import apps, recovery_bench, workloads
from .workloads import KINDS, fresh


def _row(name: str, ops_per_sec: float) -> None:
    us = 1e6 / ops_per_sec if ops_per_sec else float("inf")
    print(f"{name},{us:.3f},{ops_per_sec:.0f}", flush=True)


def bench_threadtest(threads=(1, 2), seed=0):
    for kind in KINDS:
        for t in threads:
            a = fresh(kind)
            _row(f"threadtest[{kind},t={t}]",
                 workloads.threadtest(a, n_threads=t))
            a.close()


def bench_shbench(threads=(1, 2), seed=0):
    for kind in KINDS:
        for t in threads:
            a = fresh(kind)
            _row(f"shbench[{kind},t={t}]",
                 workloads.shbench(a, n_threads=t, seed=seed))
            a.close()


def bench_larson(threads=(1, 2), seed=0):
    for kind in KINDS:
        for t in threads:
            a = fresh(kind)
            _row(f"larson[{kind},t={t}]",
                 workloads.larson(a, n_threads=t, seed=seed))
            a.close()


def bench_largebench(threads=(1, 2), seed=0):
    for kind in KINDS:
        for t in threads:
            a = fresh(kind)
            _row(f"largebench[{kind},t={t}]",
                 workloads.largebench(a, n_threads=t, seed=seed))
            a.close()


def bench_fragbench(seed=0):
    """Steady-state span churn: the extra ``fragbench_watermark`` rows are
    ``name,watermark_growth_sbs,reuse_rate`` (not us/ops)."""
    for kind in KINDS:
        a = fresh(kind)
        ops, growth, reuse = workloads.fragbench(a, seed=seed)
        _row(f"fragbench[{kind},t=1]", ops)
        print(f"fragbench_watermark[{kind}],{growth:.1f},{reuse:.2f}",
              flush=True)
        a.close()


def bench_sharedprompt(seed=0):
    """Shared-prompt span churn: the ``sharedprompt_footprint`` rows are
    ``name,peak_watermark_sbs,spans_saved_per_hit`` (not us/ops), and the
    ``sharedprompt_tailtrim`` row compares ralloc's peak footprint with
    1-sb *prefix* leases (range-lease tail trim) against whole-span
    leases — ``name,peak_sbs_prefix_leases,peak_sbs_whole_span``."""
    for kind in KINDS:
        a = fresh(kind)
        ops, saved, peak = workloads.sharedprompt(a, seed=seed)
        _row(f"sharedprompt[{kind}]", ops)
        print(f"sharedprompt_footprint[{kind}],{peak:.0f},{saved:.2f}",
              flush=True)
        a.close()
    a = fresh("ralloc")
    _, _, peak_trim = workloads.sharedprompt(a, prefix_k=1, seed=seed)
    a.close()
    a = fresh("ralloc")
    _, _, peak_whole = workloads.sharedprompt(a, prefix_k=None, seed=seed)
    a.close()
    print(f"sharedprompt_tailtrim[ralloc],{peak_trim:.0f},{peak_whole:.0f}",
          flush=True)


def bench_sharedprompt_recover(seed=0):
    """Crash-and-recover over published prompts: the extra
    ``sharedprompt_recover`` rows are ``name,sbs_reprefilled,peak_sbs``
    (not us/ops) — with the durable prefix index (``+index``) recovery
    re-publishes every prompt (zero re-prefill) and re-trims its lease;
    without it every prompt re-prefills into a fresh span."""
    for label, durable in (("ralloc+index", True), ("ralloc", False)):
        a = fresh("ralloc")
        ops, reprefill, peak = workloads.sharedprompt_recover(
            a, seed=seed, durable_index=durable)
        _row(f"sharedprompt_recover[{label}]", ops)
        print(f"sharedprompt_recover_footprint[{label}],"
              f"{reprefill:.0f},{peak:.0f}", flush=True)
        a.close()


def bench_servingchurn(seed=0):
    """Larson-style serving churn over the durable prefix index: the
    extra ``servingchurn_fences`` rows are
    ``name,fences_per_request,requests_per_sec`` (not us/ops).  The
    group-commit variant batches a whole generation of publications
    behind one fence pair + one root swing (``publish_batch``) and
    evicts through ``remove_batch`` — fences/request drops toward the
    amortized floor instead of paying the strict protocol per record."""
    for label, gc in (("ralloc", 1), ("ralloc+groupcommit", 8)):
        a = fresh("ralloc")
        ops, fpr = workloads.servingchurn(a, lanes=8, rounds=6,
                                          group_commit=gc, seed=seed)
        _row(f"servingchurn[{label}]", ops)
        print(f"servingchurn_fences[{label}],{fpr:.3f},{ops:.0f}",
              flush=True)
        a.close()


def bench_hierprompt(seed=0):
    """Hierarchical prompts (shared system × per-tenant middle × unique
    suffix): the extra ``hierprompt_footprint`` rows are
    ``name,sbs_per_request,fences_per_request`` (not us/ops).  The trie
    variant longest-prefix-matches the shared pages and leases only
    their superblocks — per-request footprint ~O(suffix); the flat
    exact-match baseline misses on every unique suffix and re-prefills
    the whole prompt — ~O(prompt)."""
    for label, trie in (("ralloc+trie", True), ("ralloc+flat", False)):
        a = fresh("ralloc")
        ops, fpr, spr = workloads.hierprompt(a, seed=seed, use_trie=trie)
        _row(f"hierprompt[{label}]", ops)
        print(f"hierprompt_footprint[{label}],{spr:.2f},{fpr:.3f}",
              flush=True)
        a.close()


def bench_idxscale(seed=0):
    """Placement-index scaling (device run table + bucketed prefix
    chains): the ``idxscale_dev`` row is
    ``name,us_small,us_big,scale_ratio`` across a 16× ``num_sbs``
    growth (flat ratio = O(buckets) placement); the ``idxscale_walk``
    row is ``name,walk_steps_per_lookup,max_chain`` (bucketed walks
    stay ≤ records/buckets + 1; one chain would average records/2)."""
    a = fresh("ralloc")
    ops, m = workloads.idxscale(a, num_sbs=(64, 1024), rounds=120,
                                prompts=32, n_buckets=8, seed=seed)
    _row("idxscale[ralloc]", ops)
    print(f"idxscale_dev[ralloc],{m['dev_alloc_us_small']:.1f},"
          f"{m['dev_alloc_us_big']:.1f},{m['dev_scale_ratio']:.2f}",
          flush=True)
    print(f"idxscale_walk[ralloc],{m['walk_steps_per_lookup']:.2f},"
          f"{m['max_chain']}", flush=True)
    a.close()


def bench_prodcon(pairs=(1,), seed=0):
    for kind in KINDS:
        for p in pairs:
            a = fresh(kind)
            _row(f"prodcon[{kind},pairs={p}]", workloads.prodcon(a, n_pairs=p))
            a.close()


def bench_vacation(seed=0):
    for kind in ("ralloc", "makalu_lite", "pmdk_lite"):   # persistent only
        a = fresh(kind)
        _row(f"vacation[{kind}]", apps.vacation(a))
        a.close()


def bench_ycsb(seed=0):
    for kind in ("ralloc", "makalu_lite", "pmdk_lite"):
        a = fresh(kind)
        _row(f"memcached_ycsb_a[{kind}]", apps.ycsb_a(a))
        a.close()
    # paper §6.3: Makalu returns only half an over-full cache, gaining
    # locality on large-footprint apps — Ralloc offers the same knob
    from repro.core.baselines import _RallocAdapter
    from repro.core.ralloc import Ralloc
    a = _RallocAdapter(Ralloc(None, 256 << 20, keep_half=True,
                              flush_ns=workloads.FLUSH_NS,
                              fence_ns=workloads.FENCE_NS))
    _row("memcached_ycsb_a[ralloc+keep_half]", apps.ycsb_a(a))
    a.close()


def bench_recovery(seed=0):
    for row in recovery_bench.sweep():
        name = f"recovery[{row['structure']},n={row['blocks']}]"
        print(f"{name},{row['us_per_block']:.3f},"
              f"{row['blocks'] / row['seconds']:.0f}", flush=True)


def bench_roofline(seed=0):
    try:
        from .roofline import load, table
        rows = load()
        if not rows:
            print("# roofline: no dry-run artifacts (run repro.launch.dryrun)")
            return
        print("# roofline table (see EXPERIMENTS.md for analysis)")
        print(table(rows, "16x16"))
    except Exception as e:                   # pragma: no cover
        print(f"# roofline unavailable: {e}")


# The single source of truth for what a "workload" is.  Full runs and the
# CI smoke pass select from the same table, so a workload added here is
# automatically covered by both (no more duplicated lists drifting apart).
#   full:  callable(seed) printing CSV rows
#   smoke: [(kind, callable(alloc, seed))] — one tiny fail-fast round per
#          allocator worth exercising (None = full-only section)
BENCHES: dict[str, dict] = {
    "threadtest": {
        "full": bench_threadtest,
        "smoke": [("ralloc", lambda a, s: workloads.threadtest(
            a, n_threads=1, iters=2, objs=50))],
    },
    "shbench": {
        "full": bench_shbench,
        "smoke": [("ralloc", lambda a, s: workloads.shbench(
            a, n_threads=1, iters=120, seed=s))],
    },
    "larson": {
        "full": bench_larson,
        "smoke": [("ralloc", lambda a, s: workloads.larson(
            a, n_threads=1, rounds=1, objs=40, iters=120, seed=s))],
    },
    "largebench": {
        "full": bench_largebench,
        "smoke": [("ralloc", lambda a, s: workloads.largebench(
            a, n_threads=1, iters=10, seed=s))],
    },
    "fragbench": {
        "full": bench_fragbench,
        "smoke": [("ralloc", lambda a, s: workloads.fragbench(
            a, iters=8, pool=4, seed=s)[0])],
    },
    "sharedprompt": {
        "full": bench_sharedprompt,
        # ralloc leases; one non-refcounting baseline keeps the
        # fresh-span fallback exercised; the prefix_k run keeps the
        # range-lease tail-trim path on the smoke hot path too
        # (a "+variant" suffix labels the row; the allocator is the
        # part before the "+")
        "smoke": [("ralloc", lambda a, s: workloads.sharedprompt(
            a, iters=4, fanout=3, seed=s)),
            ("ralloc+tailtrim", lambda a, s: workloads.sharedprompt(
                a, iters=4, fanout=3, prefix_k=1, seed=s)),
            ("makalu_lite", lambda a, s: workloads.sharedprompt(
                a, iters=4, fanout=3, seed=s))],
    },
    "sharedprompt_recover": {
        "full": bench_sharedprompt_recover,
        # both variants on the smoke path: the index round exercises
        # publish→crash→re-publish→re-trim end to end, the no-index
        # round keeps the re-prefill fallback alive
        "smoke": [("ralloc+index",
                   lambda a, s: workloads.sharedprompt_recover(
                       a, iters=2, fanout=3, seed=s)),
                  ("ralloc",
                   lambda a, s: workloads.sharedprompt_recover(
                       a, iters=2, fanout=3, seed=s,
                       durable_index=False))],
    },
    "servingchurn": {
        "full": bench_servingchurn,
        # strict vs group-commit publish on the same churn: the pair is
        # what the baseline gate trends — a regression that reopens the
        # per-record fence pairs shows up as fences_per_request drift
        "smoke": [("ralloc", lambda a, s: workloads.servingchurn(
            a, lanes=4, rounds=3, hold_rounds=1, group_commit=1, seed=s)),
            ("ralloc+groupcommit", lambda a, s: workloads.servingchurn(
                a, lanes=4, rounds=3, hold_rounds=1, group_commit=4,
                seed=s))],
    },
    "hierprompt": {
        "full": bench_hierprompt,
        # partial-prefix hits vs the flat exact-match baseline on the
        # same hierarchical traffic: the pair is what the baseline gate
        # trends — a regression that loses partial hits shows up as the
        # trie round's fences/request and sbs/request drifting up to
        # the flat round's
        "smoke": [("ralloc+trie", lambda a, s: workloads.hierprompt(
            a, tenants=2, reqs=4, seed=s)),
            ("ralloc+flat", lambda a, s: workloads.hierprompt(
                a, tenants=2, reqs=4, seed=s, use_trie=False))],
    },
    "idxscale": {
        "full": bench_idxscale,
        # the smoke round is host-only (num_sbs=() skips the device
        # sweep — that runs once in the sanity gate below); its row
        # pins the bucketed publish/lookup path's fences_per_request
        "smoke": [("ralloc", lambda a, s: workloads.idxscale(
            a, num_sbs=(), prompts=24, n_buckets=8, seed=s)[0])],
    },
    "prodcon": {
        "full": bench_prodcon,
        "smoke": [("ralloc", lambda a, s: workloads.prodcon(
            a, n_pairs=1, items=200))],
    },
    "vacation": {"full": bench_vacation, "smoke": None},
    "ycsb": {"full": bench_ycsb, "smoke": None},
    "recovery": {"full": bench_recovery, "smoke": None},
    "roofline": {"full": bench_roofline, "smoke": None},
}


def _meter_requests(a) -> dict:
    """Count allocator *requests* (malloc/free/span_acquire/span_release)
    on ``a`` in place — instance-attribute wrappers, so identity and
    feature detection (``hasattr``) on the adapter stay intact."""
    meter = {"n": 0}
    for meth in ("malloc", "free", "span_acquire", "span_release"):
        fn = getattr(a, meth, None)
        if fn is None:
            continue

        def wrapped(*args, _fn=fn, **kw):
            meter["n"] += 1
            return _fn(*args, **kw)
        setattr(a, meth, wrapped)
    return meter


def run_smoke(names: list[str], seed: int,
              json_path: str | None = None,
              baseline_path: str | None = None) -> int:
    """One tiny round of every selected workload, fail-fast (CI tier-1).

    ``json_path`` additionally writes the per-round results as JSON —
    CI uploads it as a workflow artifact so the perf trajectory is
    inspectable per-run without scraping logs.  Each round also reports
    its persistence traffic (``n_flush``/``n_fence``) normalized per
    allocator request (``fences_per_request``) — the paper's headline
    cost metric, trended per CI run via the artifact.

    ``baseline_path`` points at a checked-in prior smoke artifact
    (``benchmarks/baselines/smoke.json``): every round present in both
    must reproduce its baseline ``fences_per_request`` within ±20% —
    the gate that catches a silently reopened fence pair (regression)
    or an unrecorded improvement (update the baseline to claim it).

    Each workload round additionally runs under a
    :class:`repro.obs.WasteMonitor` (live persist-lint waste diagnosis:
    ``redundant_flushes`` / ``empty_fences``, both gated at ~0) and
    embeds the full ``obs.snapshot()`` as its ``metrics`` field; with
    ``json_path`` the per-round snapshots + Chrome-trace span events
    also land in a ``<stem>-metrics.json`` sibling (the CI artifact
    ``tools/dump_metrics.py`` renders)."""
    failed = 0
    results: list[dict] = []
    metrics_rounds: list[dict] = []

    def record(name, kind, ok, seconds, error=None, **extra):
        nonlocal failed
        if not ok:
            failed += 1
        results.append({"workload": name, "kind": kind, "ok": ok,
                        "seconds": round(seconds, 3), "error": error,
                        **extra})

    for name in names:
        for kind, fn in (BENCHES[name]["smoke"] or []):
            # "alloc+variant" labels distinct rounds of one allocator so
            # the JSON rows stay distinguishable in the artifact
            a = fresh(kind.split("+", 1)[0], mb=64)
            meter = _meter_requests(a)
            # counter resets route through the registry: the heap
            # registered its n_flush/n_fence/... as named sources, and
            # obs.reset raises UnknownMetric on a name nothing owns —
            # the old blind a.mem.reset_counters() could silently reset
            # the wrong (or no) heap after a refactor
            obs.reset_all()
            obs.reset("heap.flush", "heap.fence", "heap.cas", "heap.drain")
            monitor = obs.attach_waste_monitor(a.mem)
            t0 = time.perf_counter()
            try:
                fn(a, seed)
            except Exception as e:
                record(name, kind, False, time.perf_counter() - t0,
                       error=repr(e))
                print(f"smoke[{name},{kind}] FAILED: {e!r}", flush=True)
            else:
                c = a.counters
                fpr = (c["fence"] / meter["n"]) if meter["n"] else 0.0
                diag = monitor.diag
                snap = obs.snapshot()
                metrics_rounds.append({
                    "workload": name, "kind": kind, "snapshot": snap,
                    "traceEvents":
                        obs.chrome_trace()["traceEvents"]})
                record(name, kind, True, time.perf_counter() - t0,
                       n_requests=meter["n"], n_flush=c["flush"],
                       n_fence=c["fence"],
                       fences_per_request=round(fpr, 3),
                       redundant_flushes=diag["redundant_flushes"],
                       empty_fences=diag["empty_fences"],
                       metrics=snap)
                print(f"smoke[{name},{kind}] ok "
                      f"({time.perf_counter() - t0:.2f}s, "
                      f"{fpr:.2f} fences/request, "
                      f"{diag['redundant_flushes']} redundant flushes, "
                      f"{diag['empty_fences']} empty fences)", flush=True)
            finally:
                a.mem.tracer = None
                a.close()
    if "sharedprompt" in names:
        # sanity: ralloc's sharedprompt really shares (lease plumbing alive)
        a = fresh("ralloc", mb=64)
        t0 = time.perf_counter()
        try:
            _, saved, _ = workloads.sharedprompt(a, iters=3, fanout=3,
                                                 seed=seed)
            ok = saved >= 1.0
            record("sharedprompt_sanity", "ralloc", ok,
                   time.perf_counter() - t0, spans_saved_per_hit=saved)
            if not ok:
                print(f"smoke[sharedprompt,ralloc] FAILED: "
                      f"spans_saved_per_hit {saved} < 1.0 "
                      f"(span_acquire path dead)", flush=True)
        finally:
            a.close()
    if "sharedprompt_recover" in names:
        # sanity: the durable index really eliminates re-prefill — a
        # regression to transient-only publishing fails the PR here
        a = fresh("ralloc", mb=64)
        t0 = time.perf_counter()
        try:
            _, reprefill, _ = workloads.sharedprompt_recover(
                a, iters=2, fanout=3, seed=seed)
            ok = reprefill == 0
            record("sharedprompt_recover_sanity", "ralloc", ok,
                   time.perf_counter() - t0, sbs_reprefilled=reprefill)
            if not ok:
                print(f"smoke[sharedprompt_recover,ralloc] FAILED: "
                      f"{reprefill} sbs re-prefilled with the durable "
                      f"index (publish→recover→re-publish path dead)",
                      flush=True)
        finally:
            a.close()
    if "servingchurn" in names:
        # acceptance gate: the group commit must at least HALVE
        # fences/request vs the strict per-record publish protocol on
        # the same churn — weaker amortization means the batch paths
        # quietly fell back to per-record fencing
        fprs = {}
        t0 = time.perf_counter()
        for label, gc_n in (("ralloc", 1), ("ralloc+groupcommit", 4)):
            a = fresh("ralloc", mb=64)
            try:
                _, fprs[label] = workloads.servingchurn(
                    a, lanes=4, rounds=3, hold_rounds=1,
                    group_commit=gc_n, seed=seed)
            finally:
                a.close()
        ok = fprs["ralloc+groupcommit"] * 2 <= fprs["ralloc"]
        record("servingchurn_sanity", "ralloc", ok,
               time.perf_counter() - t0,
               fences_strict=round(fprs["ralloc"], 3),
               fences_grouped=round(fprs["ralloc+groupcommit"], 3))
        if not ok:
            print(f"smoke[servingchurn,ralloc] FAILED: group commit "
                  f"{fprs['ralloc+groupcommit']:.3f} fences/request is "
                  f"not ≤ half of strict {fprs['ralloc']:.3f} "
                  f"(publish_batch/remove_batch amortization dead)",
                  flush=True)
    if "hierprompt" in names:
        # acceptance gate (ISSUE PR 8): on hierarchical traffic the trie
        # must at least HALVE per-request superblock footprint vs the
        # flat exact-match baseline — O(suffix), not O(prompt).  A
        # weaker ratio means partial-prefix hits quietly died and every
        # request is re-prefilling its whole prompt again.
        sbs = {}
        t0 = time.perf_counter()
        for label, trie in (("trie", True), ("flat", False)):
            a = fresh("ralloc", mb=64)
            try:
                _, _, sbs[label] = workloads.hierprompt(
                    a, tenants=2, reqs=4, seed=seed, use_trie=trie)
            finally:
                a.close()
        ok = sbs["trie"] * 2 <= sbs["flat"]
        record("hierprompt_sanity", "ralloc", ok,
               time.perf_counter() - t0,
               sbs_trie=round(sbs["trie"], 3),
               sbs_flat=round(sbs["flat"], 3))
        if not ok:
            print(f"smoke[hierprompt,ralloc] FAILED: trie footprint "
                  f"{sbs['trie']:.2f} sbs/request is not ≤ half of the "
                  f"flat baseline's {sbs['flat']:.2f} (partial-prefix "
                  f"hit path dead)", flush=True)
    if "idxscale" in names:
        # acceptance gate (ISSUE 9): device large-object placement cost
        # must stay ~flat across a 16× num_sbs growth (the O(buckets)
        # bucket table, not a per-call suffix-min scan — that scaled
        # with the arena), and a bucketed prefix lookup must walk at
        # most records/buckets + 1 records.  Timing metrics are
        # reported but deliberately absent from the checked-in baseline
        # row (CI timing noise is not the contract; the walk lengths
        # are deterministic and gated).
        a = fresh("ralloc", mb=64)
        t0 = time.perf_counter()
        try:
            _, m = workloads.idxscale(a, num_sbs=(64, 1024), rounds=40,
                                      prompts=24, n_buckets=8, seed=seed)
            ok = (m["dev_scale_ratio"] <= 4.0
                  and m["walk_steps_per_lookup"] <= m["chain_bound"])
            record("idxscale_sanity", "ralloc", ok,
                   time.perf_counter() - t0,
                   walk_steps_per_lookup=round(m["walk_steps_per_lookup"],
                                               3),
                   max_chain=m["max_chain"],
                   chain_bound=round(m["chain_bound"], 2),
                   dev_alloc_us_small=round(m["dev_alloc_us_small"], 2),
                   dev_alloc_us_big=round(m["dev_alloc_us_big"], 2),
                   dev_scale_ratio=round(m["dev_scale_ratio"], 2))
            if not ok:
                print(f"smoke[idxscale,ralloc] FAILED: "
                      f"dev_scale_ratio {m['dev_scale_ratio']:.2f} > 4 "
                      f"(placement cost grew with num_sbs) or walk "
                      f"{m['walk_steps_per_lookup']:.2f} > "
                      f"{m['chain_bound']:.2f} records/lookup (bucketed "
                      f"chains degenerated to one list)", flush=True)
        finally:
            a.close()
    if baseline_path:
        import json
        with open(baseline_path) as f:
            base = json.load(f)
        # gate every derived metric a round shares with its baseline
        # row (fences_per_request, sbs_*, fences_strict, ...).  Raw
        # counters and wall-clock are size/timing artifacts, not the
        # contract — skipped.  ALL out-of-band metrics of a round are
        # reported in ONE failure, so a multi-metric regression is
        # diagnosable from a single CI run instead of one gate per fix.
        ungated = {"workload", "kind", "ok", "error", "seconds",
                   "n_requests", "n_flush", "n_fence"}
        want = {(b["workload"], b["kind"]): b
                for b in base.get("results", [])}
        for row in list(results):
            key = (row["workload"], row["kind"])
            bad = []
            for metric, w in want.get(key, {}).items():
                g = row.get(metric)
                if (metric in ungated
                        or not isinstance(w, (int, float))
                        or not isinstance(g, (int, float))
                        or isinstance(w, bool) or isinstance(g, bool)):
                    continue
                if abs(g - w) > 0.2 * abs(w) + 0.05:
                    bad.append((metric, g, w))
            if not bad:
                continue
            record(f"baseline:{key[0]}", key[1], False, 0.0,
                   deviations={m: {"got": g, "baseline": w}
                               for m, g, w in bad})
            detail = "; ".join(f"{m} {g:.3f} vs checked-in {w:.3f}"
                               for m, g, w in bad)
            print(f"smoke[{key[0]},{key[1]}] FAILED baseline gate "
                  f"(±20%): {detail} — regression, or an intended "
                  f"improvement that needs "
                  f"benchmarks/baselines/smoke.json updated", flush=True)
    if json_path:
        import json
        import os
        with open(json_path, "w") as f:
            json.dump({"profile": "smoke", "seed": seed,
                       "failed": failed, "results": results}, f, indent=2)
        print(f"# smoke results written to {json_path}", flush=True)
        stem, ext = os.path.splitext(json_path)
        metrics_path = f"{stem}-metrics{ext or '.json'}"
        with open(metrics_path, "w") as f:
            json.dump({"profile": "smoke", "seed": seed,
                       "rounds": metrics_rounds}, f, indent=2)
        print(f"# per-round metrics written to {metrics_path}", flush=True)
    return 1 if failed else 0


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="benchmarks.run", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--workloads", default="all",
                    help="comma-separated subset of: "
                         + ",".join(BENCHES) + " (default: all)")
    ap.add_argument("--seed", type=int, default=0,
                    help="workload RNG seed (default 0)")
    ap.add_argument("--profile", choices=("full", "smoke"), default="full",
                    help="'smoke' = one tiny fail-fast round per workload "
                         "(what CI's tier-1 job runs)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="smoke only: also write per-round results as "
                         "JSON (CI uploads it as a workflow artifact)")
    ap.add_argument("--baseline", default=None, metavar="PATH",
                    help="smoke only: checked-in prior smoke artifact; "
                         "each round's fences_per_request must match it "
                         "within ±20%% (benchmarks/baselines/smoke.json)")
    args = ap.parse_args(argv)
    if args.workloads in ("all", ""):
        names = list(BENCHES)
    else:
        names = [n.strip() for n in args.workloads.split(",") if n.strip()]
        unknown = [n for n in names if n not in BENCHES]
        if unknown:
            ap.error(f"unknown workload(s): {', '.join(unknown)} "
                     f"(known: {', '.join(BENCHES)})")
    if args.profile == "smoke":
        return run_smoke(names, args.seed, json_path=args.json,
                         baseline_path=args.baseline)
    print("name,us_per_call,derived")
    for name in names:
        BENCHES[name]["full"](seed=args.seed)
    return 0


if __name__ == "__main__":
    sys.exit(main())
