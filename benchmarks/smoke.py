"""Benchmarks smoke: one tiny round of every workload, fail-fast.

CI's tier-1 job runs this so a workload regression (crash, assertion,
hang) fails the PR immediately instead of surfacing only in the
non-blocking slow job.  Parameters are minimized for wall-clock — this
measures nothing; it only proves every workload still *runs* end to end
on the real allocators.

Thin shim over the shared entry point (``benchmarks.run`` owns the
workload list for full and smoke runs alike):

    PYTHONPATH=src python -m benchmarks.smoke
    # equivalent: python -m benchmarks.run --profile smoke
"""

from __future__ import annotations

import sys

from .run import main


if __name__ == "__main__":
    sys.exit(main(["--profile", "smoke"] + sys.argv[1:]))
