"""Benchmarks smoke: one tiny round of every workload, fail-fast.

CI's tier-1 job runs this so a workload regression (crash, assertion,
hang) fails the PR immediately instead of surfacing only in the
non-blocking slow job.  Parameters are minimized for wall-clock — this
measures nothing; it only proves every workload still *runs* end to end
on the real allocators (ralloc everywhere, plus one non-refcounting
baseline on ``sharedprompt`` to keep the fresh-span fallback exercised).

    PYTHONPATH=src python -m benchmarks.smoke
"""

from __future__ import annotations

import sys
import time

from . import workloads
from .workloads import fresh


def main() -> int:
    runs = [
        ("threadtest", "ralloc",
         lambda a: workloads.threadtest(a, n_threads=1, iters=2, objs=50)),
        ("shbench", "ralloc",
         lambda a: workloads.shbench(a, n_threads=1, iters=120)),
        ("larson", "ralloc",
         lambda a: workloads.larson(a, n_threads=1, rounds=1, objs=40,
                                    iters=120)),
        ("largebench", "ralloc",
         lambda a: workloads.largebench(a, n_threads=1, iters=10)),
        ("fragbench", "ralloc",
         lambda a: workloads.fragbench(a, iters=8, pool=4)[0]),
        ("sharedprompt", "ralloc",
         lambda a: workloads.sharedprompt(a, iters=4, fanout=3)),
        ("sharedprompt", "makalu_lite",
         lambda a: workloads.sharedprompt(a, iters=4, fanout=3)),
        ("prodcon", "ralloc",
         lambda a: workloads.prodcon(a, n_pairs=1, items=200)),
    ]
    failed = 0
    for name, kind, fn in runs:
        a = fresh(kind, mb=64)
        t0 = time.perf_counter()
        try:
            fn(a)
        except Exception as e:
            failed += 1
            print(f"smoke[{name},{kind}] FAILED: {e!r}", flush=True)
        else:
            print(f"smoke[{name},{kind}] ok "
                  f"({time.perf_counter() - t0:.2f}s)", flush=True)
        finally:
            a.close()
    # sanity: ralloc's sharedprompt really shares (refcount plumbing alive)
    a = fresh("ralloc", mb=64)
    try:
        _, saved, _ = workloads.sharedprompt(a, iters=3, fanout=3)
        if saved < 1.0:
            failed += 1
            print(f"smoke[sharedprompt,ralloc] FAILED: spans_saved_per_hit "
                  f"{saved} < 1.0 (span_acquire path dead)", flush=True)
    finally:
        a.close()
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
