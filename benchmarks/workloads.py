"""Shared allocator-benchmark driver (paper §6.2 workloads).

Every workload runs against any ``AllocAPI`` implementation.  Modeled
Optane write-back latency (flush 150 ns, fence 100 ns — Izraelevitz et
al. [26]) is injected so persistence cost shows up in throughput, not
just in flush counts.  CPython threads serialize on the GIL, so
multi-thread numbers measure *relative* synchronization/persistence
overheads, not hardware scalability (documented in EXPERIMENTS.md).
"""

from __future__ import annotations

import random
import threading
import time

from repro import obs
from repro.core.baselines import make_allocator

FLUSH_NS, FENCE_NS = 150, 100
KINDS = ("ralloc", "lrmalloc", "makalu_lite", "pmdk_lite")

# Per-request latency distributions for the serving-shaped workloads
# (cached at import; see repro.obs conventions).  One observation per
# serve — the smoke snapshot and EXPERIMENTS.md report the percentiles.
_OBS_CHURN_REQ = obs.histogram("servingchurn.request_seconds")
_OBS_HIER_REQ = obs.histogram("hierprompt.request_seconds")


def fresh(kind: str, mb: int = 256):
    return make_allocator(kind, None, mb << 20,
                          flush_ns=FLUSH_NS, fence_ns=FENCE_NS)


def run_threads(n_threads: int, fn) -> float:
    """Run fn(tid) on n threads; returns wall seconds."""
    errs = []

    def wrap(t):
        try:
            fn(t)
        except Exception as e:              # pragma: no cover
            errs.append(repr(e))

    ts = [threading.Thread(target=wrap, args=(t,)) for t in range(n_threads)]
    t0 = time.perf_counter()
    [t.start() for t in ts]
    [t.join() for t in ts]
    dt = time.perf_counter() - t0
    if errs:
        raise RuntimeError(errs[0])
    return dt


# --------------------------------------------------------------- workloads
def threadtest(alloc, n_threads=2, iters=20, objs=1000, size=64):
    """Hoard threadtest: per-thread batch alloc then batch free."""
    def body(t):
        for _ in range(iters):
            ps = [alloc.malloc(size) for _ in range(objs)]
            for p in ps:
                alloc.free(p)
    dt = run_threads(n_threads, body)
    return n_threads * iters * objs * 2 / dt        # ops/sec


def shbench(alloc, n_threads=2, iters=3000, seed=0):
    """MicroQuill shbench: mixed sizes 64–400 B, small-biased."""
    sizes = [64, 80, 96, 112, 128, 160, 224, 288, 400]
    weights = [9, 8, 7, 6, 5, 4, 3, 2, 1]

    def body(t):
        rng = random.Random(seed * 997 + t)
        held = []
        for _ in range(iters):
            held.append(alloc.malloc(rng.choices(sizes, weights)[0]))
            if len(held) > 50:
                for p in held:
                    alloc.free(p)
                held.clear()
        for p in held:
            alloc.free(p)
    dt = run_threads(n_threads, body)
    return n_threads * iters * 2 / dt


def larson(alloc, n_threads=2, rounds=2, objs=400, iters=2000, seed=0):
    """Larson bleeding: objects allocated by one round are freed by the
    next 'generation' of the same lane (cross-thread lifetime)."""
    leftovers = [[] for _ in range(n_threads)]

    def body(t):
        rng = random.Random(seed * 997 + t)
        held = leftovers[t]
        for _ in range(iters):
            i = rng.randrange(max(len(held), 1))
            if i < len(held):
                alloc.free(held[i])
                held[i] = alloc.malloc(rng.randint(64, 400))
            else:
                held.append(alloc.malloc(rng.randint(64, 400)))
        leftovers[t] = held

    total = 0.0
    for _ in range(rounds):                 # each round = a new generation
        total += run_threads(n_threads, body)
    for held in leftovers:
        for p in held:
            alloc.free(p)
    return n_threads * rounds * iters / total


def largebench(alloc, n_threads=2, iters=150, small=256, large=200_000,
               seed=0):
    """Large-object path (paper §4.4 ``LARGE_CLASS``): interleave small
    allocations with multi-superblock objects so superblock (re)init,
    span expansion and span free all sit on the hot path."""
    def body(t):
        rng = random.Random(seed * 997 + t)
        bigs, smalls = [], []
        for _ in range(iters):
            if bigs and rng.random() < 0.4:
                alloc.free(bigs.pop(rng.randrange(len(bigs))))
            else:
                p = alloc.malloc(large + rng.randrange(4) * 65536)
                assert p is not None
                bigs.append(p)
            smalls.append(alloc.malloc(small))
            if len(smalls) > 64:
                for p in smalls:
                    alloc.free(p)
                smalls.clear()
        for p in bigs:
            alloc.free(p)
        for p in smalls:
            alloc.free(p)
    dt = run_threads(n_threads, body)
    return n_threads * iters * 2 / dt


def fragbench(alloc, iters=80, sizes=(1, 2, 3, 4), pool=10, seed=0):
    """Fragmentation churn: keep ``pool`` mixed-size multi-superblock spans
    live; every round frees one at random and allocates a same-size
    replacement.  Once warm, every request is satisfiable from freed
    contiguous runs, so a placement-searching allocator (best-fit over the
    free set) holds its watermark flat while a watermark-only allocator
    leaks address space on every round.

    Returns ``(ops_per_sec, watermark_growth_sbs, reuse_rate)``:
    watermark growth in superblocks across the steady-state phase, and
    the fraction of steady-state allocations served without advancing
    the watermark.
    """
    from repro.core.layout import SB_SIZE, SB_WORDS
    rng = random.Random(seed)

    def span_bytes(k):                    # strictly large, ceil() = k sbs
        return k * SB_SIZE - 512

    held = []
    for _ in range(pool):
        k = rng.choice(sizes)
        p = alloc.malloc(span_bytes(k))
        assert p is not None
        held.append((p, k))
    wm0 = alloc.watermark_words()
    reused = 0
    t0 = time.perf_counter()
    for _ in range(iters):
        p, k = held.pop(rng.randrange(len(held)))
        alloc.free(p)
        before = alloc.watermark_words()
        q = alloc.malloc(span_bytes(k))
        assert q is not None
        if alloc.watermark_words() == before:
            reused += 1
        held.append((q, k))
    dt = time.perf_counter() - t0
    for p, _ in held:
        alloc.free(p)
    growth_sbs = (alloc.watermark_words() - wm0) / SB_WORDS
    return iters * 2 / dt, growth_sbs, reused / iters


def sharedprompt(alloc, iters=30, span_k=3, fanout=4, prefix_k=None,
                 hold_rounds=2, seed=0):
    """Serving-style shared-prompt churn (span range leases, core.spans).

    Each round one "publisher" reserves a ``span_k``-superblock prompt
    span and ``fanout - 1`` followers request the same prompt.  An
    allocator with span leases (ralloc's ``span_acquire``) serves a
    follower by leasing the published span — no new span, no copy;
    allocators without leases reserve a fresh span per follower.  The
    publisher then finishes *short* (its decode-ahead tail was never
    read) and releases; followers keep holding for ``hold_rounds`` more
    rounds before releasing their leases.

    ``prefix_k`` switches followers from whole-span leases to
    ``prefix_k``-superblock *prefix* leases (requires ``span_release``):
    the publisher's exit then frees the unleased decode-ahead tail
    immediately, so held rounds pin only the shared prefix and every
    follower's decode pages (modeled as one-superblock spans, the pages
    it writes past the shared prefix) slot into the freed tails instead
    of extending the watermark — the tail-trim win the range-lease
    refactor buys.

    Returns ``(ops_per_sec, spans_saved_per_hit, peak_watermark_sbs)``:
    the fraction of follower requests served without placing a new span,
    and the high-water address-space footprint in superblocks.
    """
    import collections
    from repro.core.layout import SB_SIZE, SB_WORDS
    can_share = hasattr(alloc, "span_acquire")
    can_range = prefix_k is not None and hasattr(alloc, "span_release")
    size = span_k * SB_SIZE - 512
    page_size = SB_SIZE - 512           # a follower's own decode pages
    peak = saved = hits = 0
    pending = collections.deque()       # rounds whose followers still hold

    def release_round(round_):
        followers, decodes = round_
        for p, n in followers:
            if n is None:
                alloc.free(p)           # whole-span lease / own span
            else:
                alloc.span_release(p, n)
        for p in decodes:
            alloc.free(p)

    t0 = time.perf_counter()
    for _ in range(iters):
        head = alloc.malloc(size)
        assert head is not None
        followers, decodes = [], []
        for _ in range(fanout - 1):
            hits += 1
            if can_share and can_range:
                n = max(1, min(prefix_k, span_k))
                alloc.span_acquire(head, n)
                followers.append((head, n))
                saved += 1
            elif can_share:
                alloc.span_acquire(head)
                followers.append((head, None))
                saved += 1
            else:
                p = alloc.malloc(size)
                assert p is not None
                followers.append((p, None))
        # the publisher finishes short: nobody leases its decode-ahead
        # tail, so with range leases the tail frees right here …
        alloc.free(head)
        # … and the followers' decode-past-the-prefix pages reuse it
        # (without range leases they extend the watermark instead)
        for _ in range(fanout - 1):
            p = alloc.malloc(page_size)
            assert p is not None
            decodes.append(p)
        pending.append((followers, decodes))
        peak = max(peak, alloc.watermark_words() // SB_WORDS)
        if len(pending) > hold_rounds:
            release_round(pending.popleft())
    while pending:
        release_round(pending.popleft())
    dt = time.perf_counter() - t0
    return iters * fanout / dt, saved / max(hits, 1), peak


def sharedprompt_recover(alloc, iters=4, span_k=3, fanout=3, prefix_k=1,
                         seed=0, durable_index=True):
    """Crash-and-recover over published prompts (durable prefix index,
    ``core.prefix_index`` — ralloc only).

    Three phases:

      1. *serve* — each round a publisher reserves a ``span_k``-sb
         prompt span, prefills it (one stamped+flushed word per
         superblock models the prefill work), publishes its
         ``prefix_k``-sb prefix — a durable index record when
         ``durable_index``, a transient dict entry (plus the same
         transient lease) otherwise — and roots itself (its page table);
         the crash hits with every publisher still mid-decode.
      2. *crash* — all transient state is lost; ``recover()`` rebuilds
         the allocator from the durable image (with the index, recovery
         re-trims each record's lease to the published prefix).
      3. *re-serve* — ``fanout - 1`` requests arrive per prompt.  A
         prompt whose key survives in the index is served by leasing the
         published span: **zero re-prefill**.  A forgotten prompt
         re-reserves and re-prefills a fresh span.  Publishers then
         finish short (with the index the decode-ahead tail frees at
         that instant; without it the whole span frees — and the work
         was already re-done).

    Returns ``(ops_per_sec, sbs_reprefilled, peak_watermark_sbs)``:
    superblocks of prompt state recomputed after the crash, and the
    high-water address-space footprint.
    """
    from repro.core.layout import SB_SIZE, SB_WORDS
    from repro.core.prefix_index import (REC_BYTES, PrefixIndex,
                                         hash_tokens)
    r = alloc.r                         # ralloc-only (needs recover/roots)
    idx = PrefixIndex(r) if durable_index else None
    # symmetric warm-up: the record size class claims its superblock (and
    # expansion batch) in BOTH variants, so the peak metric compares
    # span traffic, not one-off class initialization
    r.malloc(REC_BYTES)
    size = span_k * SB_SIZE - 512
    n = max(1, min(prefix_k, span_k))

    def prefill(head, k):
        for j in range(k):
            r.write_word(head + j * SB_WORDS, 0x5EED + j)
            alloc_flush(head + j * SB_WORDS)
        r.fence()
        return k

    def alloc_flush(w):
        if hasattr(r, "flush_range"):
            r.flush_range(w, 1)

    cache: dict[int, tuple[int, int]] = {}       # transient (dies at crash)
    owners: list[tuple[int, int]] = []           # (root_idx, head)
    peak = reprefilled = 0
    t0 = time.perf_counter()
    for it in range(iters):                      # ---- phase 1: serve
        head = alloc.malloc(size)
        assert head is not None
        prefill(head, span_k)
        key = hash_tokens([seed, it])
        if idx is not None:
            idx.publish(key, head, n_pages=n, lease_sbs=n)
        else:
            alloc.span_acquire(head, n)          # transient cache lease
        cache[key] = (head, n)
        r.set_root(it, head)                     # the publisher's page table
        owners.append((it, head))
        peak = max(peak, alloc.watermark_words() // SB_WORDS)

    # ---- phase 2: crash (all transient state lost) + recovery
    cache = {}
    r.recover()                                  # re-trims index records
    if idx is not None:
        cache = {rec.key: (rec.span, rec.lease_sbs)
                 for rec in idx.records()}

    for it in range(iters):                      # ---- phase 3: re-serve
        key = hash_tokens([seed, it])
        hit = cache.get(key)
        held = []
        for _ in range(fanout - 1):
            if hit is not None:
                head, ls = hit
                alloc.span_acquire(head, ls)     # cache hit: no re-prefill
                held.append((head, ls))
            else:
                p = alloc.malloc(size)
                assert p is not None
                reprefilled += prefill(p, span_k)
                held.append((p, None))
        peak = max(peak, alloc.watermark_words() // SB_WORDS)
        for p, ls in held:
            if ls is None:
                alloc.free(p)
            else:
                alloc.span_release(p, ls)
    for root_i, head in owners:                  # publishers finish short
        r.set_root(root_i, None)
        alloc.free(head)
    peak = max(peak, alloc.watermark_words() // SB_WORDS)
    dt = time.perf_counter() - t0
    return iters * fanout / dt, reprefilled, peak


def servingchurn(alloc, lanes=8, rounds=6, group_commit=1, hold_rounds=2,
                 span_k=2, seed=0):
    """Larson-style cross-lane serving churn over the durable prefix
    index (ralloc only).  Each round a new *generation* of ``lanes``
    requests arrives: every lane reserves a prompt span, prefills it
    (flushed stamp per superblock models the prompt KV), and publishes
    its prefix into the durable index; the generation published
    ``hold_rounds`` rounds ago is evicted by *this* round — records
    unlinked, spans freed — Larson's bleeding pattern lifted from
    objects to published prompts.

    ``group_commit`` is how many publications ride one index commit:
    1 = the strict per-record protocol (a fence pair per stage, per
    record), ``lanes`` = the whole generation lands behind one shared
    fields fence, one shared seal fence and ONE root swing
    (``PrefixIndex.publish_batch``), with eviction through the matching
    ``remove_batch`` (one unlink fence per generation).

    Returns ``(requests_per_sec, fences_per_request)`` where a request
    is one serve (reserve+prefill+publish) or one eviction.
    """
    import collections
    from repro.core.layout import SB_SIZE, SB_WORDS
    from repro.core.prefix_index import REC_BYTES, PrefixIndex, hash_tokens
    r = alloc.r                         # ralloc-only (durable index)
    idx = PrefixIndex(r)
    # warm the record class so its one-off superblock claim doesn't
    # pollute the per-protocol fence comparison
    r.free(r.malloc(REC_BYTES))
    gc = max(1, min(int(group_commit), lanes))
    size = span_k * SB_SIZE - 512
    gens = collections.deque()          # generations still published
    requests = 0
    fence0 = r.mem.n_fence

    def evict(gen):
        nonlocal requests
        keys, heads = gen
        if gc > 1:
            idx.remove_batch(keys)
        else:
            for k in keys:
                idx.remove(k)
        for h in heads:
            alloc.free(h)               # owner hold drops: the span frees
        requests += len(heads)

    t0 = time.perf_counter()
    for it in range(rounds):
        keys, heads, items = [], [], []
        for lane in range(lanes):
            t_req = time.perf_counter()
            head = alloc.malloc(size)
            assert head is not None
            for j in range(span_k):
                r.write_word(head + j * SB_WORDS, 0x5EED + j)
                r.flush_range(head + j * SB_WORDS, 1)
            key = hash_tokens([seed, it, lane])
            keys.append(key)
            heads.append(head)
            items.append((key, head, span_k, span_k))
            requests += 1
            _OBS_CHURN_REQ.observe(time.perf_counter() - t_req)
        # publish the generation (the flushed prefill stamps become
        # durable under the publish protocol's own content fence)
        if gc > 1:
            for i in range(0, len(items), gc):
                idx.publish_batch(items[i:i + gc])
        else:
            for item in items:
                idx.publish(*item)
        gens.append((keys, heads))
        if len(gens) > hold_rounds:     # the bleeding edge: this round
            evict(gens.popleft())       # evicts an older generation
    while gens:
        evict(gens.popleft())
    dt = time.perf_counter() - t0
    fences = r.mem.n_fence - fence0
    return requests / dt, fences / max(requests, 1)


def hierprompt(alloc, tenants=3, reqs=4, sys_pages=4, mid_pages=2,
               uniq_pages=2, page=4, seed=0, use_trie=True):
    """Hierarchical prompts over the durable prefix trie (ralloc only):
    every request is *shared system prompt* × *per-tenant middle* ×
    *unique suffix*, the production shape where exact-whole-prompt
    caching shares nothing (the unique suffix makes every full-prompt
    key distinct).

    ``use_trie=True`` serves through ``core.prefix_trie``: the first
    request of a tenant prefills a full span and publishes its shared
    prefix (splitting existing edges at the system/middle boundary, so
    the system prompt itself lands in ONE node all tenants descend
    from); every later request longest-prefix-matches the shared pages,
    leases only those superblocks, and allocates just its
    ``uniq_pages``-page suffix — per-request footprint O(suffix).

    ``use_trie=False`` is the flat exact-match baseline
    (``core.prefix_index`` keyed by the whole prompt, the pre-trie
    engine behavior): the unique suffix defeats every lookup, so each
    request prefills its full span — per-request footprint O(prompt).

    Returns ``(requests_per_sec, fences_per_request,
    sbs_per_request)`` where ``sbs_per_request`` is the superblocks of
    *new* prompt state each request had to materialize (leased shared
    superblocks are free — that is the whole point).
    """
    from repro.core.layout import SB_SIZE, SB_WORDS
    r = alloc.r                         # ralloc-only (durable trie/index)
    rng = random.Random(seed)
    shared_pages = sys_pages + mid_pages
    total_pages = shared_pages + uniq_pages
    size = total_pages * SB_SIZE - 512  # one page per superblock
    sys_toks = [rng.randrange(1, 1 << 16) for _ in range(sys_pages * page)]
    if use_trie:
        from repro.core.prefix_trie import REC_BYTES, PrefixTrie
        trie = PrefixTrie(r, page=page, sb_pages=1)
        idx = None
    else:
        from repro.core.prefix_index import (REC_BYTES, PrefixIndex,
                                             hash_tokens)
        trie, idx = None, PrefixIndex(r)
    # warm the record class so its one-off superblock claim doesn't
    # pollute the fence/footprint comparison between the two variants
    r.free(r.malloc(REC_BYTES))

    def prefill(head, k):
        for j in range(k):
            r.write_word(head + j * SB_WORDS, 0x5EED + j)
            r.flush_range(head + j * SB_WORDS, 1)
        r.fence()

    flat_keys: list[int] = []
    requests = new_sbs = 0
    fence0 = r.mem.n_fence
    t0 = time.perf_counter()
    for t in range(tenants):
        mid_toks = [rng.randrange(1, 1 << 16)
                    for _ in range(mid_pages * page)]
        shared = sys_toks + mid_toks
        for _ in range(reqs):
            uniq = [rng.randrange(1, 1 << 16)
                    for _ in range(uniq_pages * page)]
            toks = shared + uniq
            requests += 1
            t_req = time.perf_counter()
            node, k = trie.match(shared) if trie is not None else (None, 0)
            if node is not None and k == shared_pages:
                # partial hit: lease ONLY the shared superblocks, decode
                # the suffix on freshly allocated pages of its own
                alloc.span_acquire(node.span, node.lease_sbs)
                suffix = alloc.malloc(uniq_pages * SB_SIZE - 512)
                assert suffix is not None
                prefill(suffix, uniq_pages)
                new_sbs += uniq_pages
                alloc.free(suffix)
                alloc.span_release(node.span, node.lease_sbs)
                _OBS_HIER_REQ.observe(time.perf_counter() - t_req)
                continue
            # miss (first request of a tenant, or the flat baseline's
            # every request): reserve + prefill the FULL prompt span
            head = alloc.malloc(size)
            assert head is not None
            prefill(head, total_pages)
            new_sbs += total_pages
            if trie is not None:
                trie.insert(shared, head)    # splits at sys boundary
            else:
                key = hash_tokens(toks)      # whole prompt: never hits
                idx.publish(key, head, n_pages=shared_pages,
                            lease_sbs=shared_pages)
                flat_keys.append(key)
            # the publisher finishes short: the published prefix lease
            # pins the shared superblocks, the decode tail frees here
            alloc.free(head)
            _OBS_HIER_REQ.observe(time.perf_counter() - t_req)
    dt = time.perf_counter() - t0
    fences = r.mem.n_fence - fence0
    # teardown outside the timed region (eviction cost is servingchurn's
    # story, not this workload's)
    if trie is not None:
        trie.clear()
    else:
        for key in flat_keys:
            idx.remove(key)
    return requests / dt, fences / max(requests, 1), new_sbs / requests


def idxscale(alloc, num_sbs=(64, 1024), spans_per_arena=12, rounds=60,
             prompts=32, n_buckets=8, seed=0):
    """Placement-index scaling microbench (device run table + bucketed
    prefix chains).

    Two sweeps, one per index:

    1. *device*: for each arena size in ``num_sbs``, pre-fragment the
       free set (claim spans, free alternating ones) so every placement
       reads the free-run index, then time a steady alloc_large /
       free_large cycle.  With the O(buckets) bucket table the us/op
       stays ~flat as ``num_sbs`` grows; the retired per-call suffix-min
       scan grew with the arena.
    2. *host*: publish ``prompts`` records into a ``n_buckets``-bucketed
       ``PrefixIndex`` and look every key up — the measured
       ``walk_steps / lookups`` must stay ≤ records/buckets + 1, where a
       single chain averages records/2.

    Returns ``(lookups_per_sec, metrics)`` — metrics carries
    ``dev_alloc_us_small`` / ``dev_alloc_us_big`` / ``dev_scale_ratio``
    (empty ``num_sbs`` skips the device sweep: ratio 1.0) and
    ``walk_steps_per_lookup`` / ``max_chain`` / ``chain_bound``.
    """
    import functools

    import jax
    import jax.numpy as jnp

    from repro.core import jax_alloc as ja
    from repro.core.layout import SB_SIZE
    from repro.core.prefix_index import PrefixIndex, hash_tokens, iter_records

    timings: dict[int, float] = {}
    for n in num_sbs:
        cfg = ja.ArenaConfig(num_sbs=n, sb_words=32, class_words=(8,),
                             cache_cap=16)
        al = jax.jit(functools.partial(ja.alloc_large, cfg=cfg))
        fl = jax.jit(functools.partial(ja.free_large, cfg=cfg))
        st = ja.init_state(cfg)
        offs = []
        for _ in range(spans_per_arena):
            st, off = al(state=st, nwords=jnp.int32(2 * cfg.sb_words))
            offs.append(int(off))
        for off in offs[::2]:
            st = fl(state=st, off=jnp.int32(off))
        # warm-up claims one freed run (and compiles both kernels)
        st, off = al(state=st, nwords=jnp.int32(2 * cfg.sb_words))
        st = fl(state=st, off=jnp.int32(off))
        jax.block_until_ready(st.run_len)
        t0 = time.perf_counter()
        for _ in range(rounds):
            st, off = al(state=st, nwords=jnp.int32(2 * cfg.sb_words))
            st = fl(state=st, off=jnp.int32(off))
        jax.block_until_ready(st.run_len)
        timings[n] = (time.perf_counter() - t0) / (2 * rounds) * 1e6

    r = alloc.r                         # ralloc-only (typed roots)
    idx = PrefixIndex(r, n_buckets=n_buckets)
    keys = [hash_tokens([seed, i]) for i in range(prompts)]
    for k in keys:
        # one span per published prompt, through the metered adapter so
        # fences/request normalizes per publish
        idx.publish(k, alloc.malloc(SB_SIZE), n_pages=1, lease_sbs=1)
    idx.lookups = idx.walk_steps = 0
    t0 = time.perf_counter()
    for k in keys:
        assert idx.lookup(k) is not None
    dt = max(time.perf_counter() - t0, 1e-9)
    walk = idx.walk_steps / idx.lookups
    max_chain = max(len(list(iter_records(r, s))) for s in idx.slots)
    small, big = (timings[num_sbs[0]], timings[num_sbs[-1]]) \
        if timings else (0.0, 0.0)
    metrics = {
        "dev_alloc_us_small": small,
        "dev_alloc_us_big": big,
        "dev_scale_ratio": (big / small) if small else 1.0,
        "walk_steps_per_lookup": walk,
        "max_chain": max_chain,
        "chain_bound": prompts / n_buckets + 1,
    }
    return prompts / dt, metrics


def prodcon(alloc, n_pairs=1, items=4000, size=64):
    """Producer/consumer via an M&S-style queue: producer allocates,
    consumer frees (paper's Prod-con)."""
    import collections
    queues = [collections.deque() for _ in range(n_pairs)]
    done = [False] * n_pairs

    def producer(i):
        for _ in range(items):
            queues[i].append(alloc.malloc(size))
        done[i] = True

    def consumer(i):
        freed = 0
        while freed < items:
            try:
                p = queues[i].popleft()
            except IndexError:
                continue
            alloc.free(p)
            freed += 1

    def body(t):
        (producer if t % 2 == 0 else consumer)(t // 2)

    dt = run_threads(2 * n_pairs, body)
    return n_pairs * items * 2 / dt
