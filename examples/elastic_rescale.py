"""Elastic rescale: restore a checkpoint onto a different mesh.

Checkpoints store arrays unsharded with position-independent references,
so the same heap file restores onto any mesh shape — here 1×1 → 1×1
(CPU container), with the mesh-construction path identical to the
256-chip production meshes in launch/mesh.py.

Run:  PYTHONPATH=src python examples/elastic_rescale.py
"""

import dataclasses
import os
import tempfile

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_smoke_config
from repro.core.ralloc import Ralloc
from repro.distributed.sharding import train_param_specs
from repro.models import transformer as T
from repro.runtime import make_host_mesh

cfg = dataclasses.replace(get_smoke_config("qwen2_5_32b"), num_layers=2)
path = os.path.join(tempfile.gettempdir(), "elastic.heap")
if os.path.exists(path):
    os.unlink(path)

# "big mesh" job writes the checkpoint
params = T.init_params(cfg, jax.random.PRNGKey(0))
heap = Ralloc(path, 256 << 20)
cm = CheckpointManager(heap)
cm.save({"p": params}, step=100)
heap.close()
print("checkpoint written under mesh A")

# "rescaled" job restores onto mesh B with fresh sharding rules
heap2 = Ralloc(path, 256 << 20)
cm2 = CheckpointManager(heap2)
restored, step = cm2.load_latest({"p": params})
mesh_b = make_host_mesh()
shapes = jax.eval_shape(lambda: params)
specs = train_param_specs(shapes, mesh_b)
resharded = jax.tree.map(
    lambda a, s: jax.device_put(a, NamedSharding(mesh_b, s)),
    restored["p"], specs)
n = sum(x.size for x in jax.tree.leaves(resharded))
print(f"restored step {step}: {n/1e6:.2f}M params resharded onto mesh B "
      f"{dict(zip(mesh_b.axis_names, mesh_b.devices.shape))}")
heap2.close()
print("OK — same path scales 1×1 ↔ 16×16 ↔ 2×16×16 (dry-run verified)")
