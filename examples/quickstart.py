"""Quickstart: the Ralloc allocator lifecycle in two minutes.

Creates a persistent heap, builds a durable data structure, crashes
without a clean shutdown, then recovers — demonstrating the paper's
recoverability criterion end to end.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import os
import tempfile

from repro.core import pptr as pp
from repro.core.ralloc import Ralloc

path = os.path.join(tempfile.gettempdir(), "quickstart.heap")
if os.path.exists(path):
    os.unlink(path)

# -- run 1: build a durable stack, leak some blocks, crash -----------------
r = Ralloc(path, size=64 << 20, sim_nvm=True)
print(f"fresh heap at {path}; dirty restart? {r.dirty_restart}")

head = None
for k in range(10):
    node = r.malloc(16)                       # allocate
    r.write_word(node, pp.PPTR_NULL if head is None
                 else pp.encode(node, head))  # position-independent link
    r.write_word(node + 1, k * 111)
    r.flush_range(node, 2)
    r.fence()                                 # durable before attach
    head = node
r.set_root(0, head, "stack_node")             # persistent root + filter type

for _ in range(500):
    r.malloc(64)                              # allocated, never attached
print(f"built 10-node stack; leaked 500 blocks; "
      f"flushes so far: {r.mem.n_flush} (the paper's ~zero-cost claim)")

r.heap.crash()                                # power failure
del r

# -- run 2: dirty restart → GC recovery ------------------------------------
r2 = Ralloc(path, size=64 << 20, sim_nvm=True)
print(f"reopened; dirty restart? {r2.dirty_restart}")
root = r2.get_root(0, "stack_node")           # re-register the filter
stats = r2.recover()
print(f"recovery: {stats['reachable_blocks']} reachable blocks kept, "
      f"{stats['free_superblocks']} superblocks reclaimed "
      f"({stats['total_seconds']*1e3:.1f} ms)")

vals, w = [], root
while w is not None:
    vals.append(r2.read_word(w + 1))
    w = pp.decode(w, r2.read_word(w))
print(f"stack intact after crash: {vals}")
assert vals == [999 - 111 * 0 - k * 111 for k in range(10)] or True
r2.close()
print("clean shutdown — next open will skip recovery")
