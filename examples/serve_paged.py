"""Serve a small model with batched requests over the paged KV arena,
crash the allocator mid-generation, recover, and keep going.

This is the paper's recoverability story applied to inference state
(DESIGN.md §2.1): KV pages are allocator blocks, session page tables are
the persistent roots, and recovery is the vectorized mark–sweep.

Run:  PYTHONPATH=src python examples/serve_paged.py
"""

import dataclasses

import jax

from repro.configs import get_smoke_config
from repro.core import jax_alloc as ja
from repro.models import transformer as T
from repro.runtime import make_host_mesh
from repro.serving.engine import ServingEngine

cfg = dataclasses.replace(get_smoke_config("qwen2_5_32b"), page_size=8)
mesh = make_host_mesh()
params = T.init_params(cfg, jax.random.PRNGKey(0))
engine = ServingEngine(cfg, mesh, params, lanes=4, max_seq=96)

lanes = [engine.add_request([1, 2, 3]),
         engine.add_request([4, 5]),
         engine.add_request([6])]
print("serving 3 concurrent sessions (continuous batching)…")
for step in range(24):
    engine.step()
for lane in lanes:
    toks = engine.sessions[lane].tokens
    print(f"  session {lane}: {len(toks)} tokens: {toks[:12]}…")
pages = ja.live_blocks(engine.astate, engine.acfg)[0]
print(f"live KV pages: {pages}")

print("\n=== simulated crash: all transient allocator metadata lost ===")
stats = engine.crash_and_recover()
print(f"vectorized GC recovery: marked={stats['marked']} pages "
      f"(live before={stats['live_before']}, after={stats['live_after']})")

before = {l: list(engine.sessions[l].tokens) for l in lanes}
for step in range(8):
    engine.step()
for lane in lanes:
    toks = engine.sessions[lane].tokens
    assert toks[:len(before[lane])] == before[lane], "history corrupted!"
    print(f"  session {lane} resumed: +{len(toks)-len(before[lane])} tokens")

engine.finish(lanes[0])
print(f"\nevicted session {lanes[0]}; its pages returned to the arena "
      f"(live now: {ja.live_blocks(engine.astate, engine.acfg)[0]})")
print("OK")
