"""End-to-end training with recoverable checkpointing.

Trains a tiny model, hard-crashes the process state mid-run (no clean
shutdown), then restarts: recovery GC reclaims any half-written
checkpoint shards and training resumes from the last committed manifest.

Run:  PYTHONPATH=src python examples/train_checkpoint_recovery.py
"""

import dataclasses
import os
import tempfile

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_smoke_config
from repro.core.ralloc import Ralloc
from repro.data.pipeline import TokenStream
from repro.train.loop import Trainer
from repro.train.optimizer import AdamWConfig

cfg = dataclasses.replace(get_smoke_config("starcoder2_3b"),
                          num_layers=2, vocab_size=64)
path = os.path.join(tempfile.gettempdir(), "train_ckpt.heap")
if os.path.exists(path):
    os.unlink(path)

heap = Ralloc(path, 256 << 20, sim_nvm=True)
ckpt = CheckpointManager(heap)
stream = TokenStream(cfg.vocab_size, batch=2, seq_len=32, seed=1)

print("=== phase 1: train 9 steps, checkpoint every 4 ===")
tr = Trainer(cfg, AdamWConfig(warmup_steps=2), ckpt=ckpt, ckpt_every=4)
tr.run(stream, steps=9, log_every=2)

print("\n=== power failure (no close(), unflushed lines dropped) ===")
heap.heap.crash()
del tr, ckpt, heap

heap2 = Ralloc(path, 256 << 20, sim_nvm=True)
print(f"dirty restart detected: {heap2.dirty_restart}")
ckpt2 = CheckpointManager(heap2)
heap2.get_root(0, "ckpt_manifest")
heap2.get_root(1, "ckpt_manifest")
stats = heap2.recover()
print(f"GC recovery: {stats['reachable_blocks']} checkpoint blocks kept, "
      f"orphaned shards reclaimed")

print("\n=== phase 2: resume from the last committed checkpoint ===")
tr2 = Trainer(cfg, AdamWConfig(warmup_steps=2), ckpt=ckpt2, ckpt_every=4)
print(f"resumed at step {tr2.start_step} (checkpointed before the crash)")
tr2.run(stream, steps=12, log_every=2)
heap2.close()
print("OK — deterministic data pipeline replayed steps exactly")
