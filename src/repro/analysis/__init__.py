"""Persist-order analysis layer (two-pronged, static + dynamic).

* ``trace`` / ``persist_lint`` — dynamic checking: an optional tracer on
  ``NVMArray`` records every write/flush/fence/cas/crash as an
  epoch-stamped event; ``persist_lint`` replays the event stream against
  a declarative ordering spec (record fields durable before the root
  swing, durable unlink before lease release, dirty flag before any
  superblock mutation, ...) and reports violations plus the perf
  diagnostics the paper cares about (redundant flushes, empty fences).
* ``static_checks`` — an AST pass enforcing the repo-wide invariants
  that used to be honor-system: no direct ``.nvm[...]`` stores outside
  ``core/atomics.py``, no ``jax.sharding.AxisType``/``shard_map``
  references outside ``src/repro/runtime/``, and every write to a
  persistent layout field paired with a flush in the same function (or
  carrying a ``# persist: deferred`` annotation).
* ``faults`` — named fault-injection sites guarding the seeded
  flush/fence pairs, so mutation tests can prove the dynamic checker
  actually fails when an ordering site is disabled.

This package must stay import-light at ``__init__`` time: ``core``
modules import ``analysis.faults``, so importing core submodules here
would create a cycle.  Import the submodules explicitly.
"""

from __future__ import annotations

__all__ = ["faults", "persist_lint", "static_checks", "trace"]


def __getattr__(name):
    if name in __all__:
        import importlib
        return importlib.import_module(f".{name}", __name__)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
