"""Named fault-injection sites for the seeded flush/fence pairs.

The persist-order checker (``analysis.persist_lint``) is only worth its
keep if disabling a real ordering site makes it fail.  Each site below
guards exactly one flush/fence pair in the production code; mutation
tests suppress a site and assert the checker reports a violation, while
the unsuppressed tree must report zero violations on every crash-harness
and differential-fuzz trace.

This module is deliberately dependency-free (the guarded ``core``
modules import it, so it must import nothing from ``core``).
"""

from __future__ import annotations

from contextlib import contextmanager

#: every seeded site, for discoverability (suppressing an unknown site
#: is an error — a typo would silently test nothing)
SITES = frozenset({
    "prefix_index.publish.fields_persist",   # record fields flush+fence
    "prefix_index.publish.record_persist",   # seal-word flush+fence (append)
    "prefix_index.publish_batch.fields_persist",   # group-commit: the ONE
    #                                          fence N records' field groups
    #                                          share before any seal is written
    "prefix_index.publish_batch.records_persist",  # group-commit: the ONE
    #                                          fence N sealed records share
    #                                          before the single root swing
    "prefix_index.remove.unlink_persist",    # mid-chain unlink flush+fence
    "prefix_index.remove_batch.unlink_persist",    # batched eviction: the ONE
    #                                          fence N unlinks share before
    #                                          any lease drops
    "heap.set_root.persist",                 # root swing flush+fence
    "ralloc.trim_tail.persist",              # trim's size-record shrink
    "ralloc.free_large.persist",             # span record clears before free
    "prefix_trie.commit.fields_persist",     # trie batch: the ONE fence all
    #                                          new node records' non-seal
    #                                          fields share before any seal
    "prefix_trie.commit.records_persist",    # trie batch: the ONE fence the
    #                                          sealed records share before the
    #                                          root swing / chain relink
    "prefix_trie.commit.relink_persist",     # split: predecessor next-pointer
    #                                          splice flush+fence
    "prefix_trie.split.reparent_persist",    # split: children's parent words
    #                                          flush+fence before the old
    #                                          node's block frees
    "prefix_trie.remove.unlink_persist",     # leaf unlink flush+fence before
    #                                          its lease drops
})

_suppressed: set[str] = set()


def is_suppressed(site: str) -> bool:
    """True iff a mutation test disabled this flush/fence site."""
    return site in _suppressed


@contextmanager
def suppress(*sites: str):
    """Disable the named flush/fence sites for the duration of the block."""
    unknown = set(sites) - SITES
    if unknown:
        raise ValueError(f"unknown fault site(s): {sorted(unknown)}")
    added = set(sites) - _suppressed
    _suppressed.update(added)
    try:
        yield
    finally:
        _suppressed.difference_update(added)
