"""Dynamic persist-order checker: replay NVM traces against ordering rules.

The paper's recoverability argument rests on a handful of precisely
ordered durable writes (record fields before the root swing, durable
unlink before lease release, trim's size shrink before the tail frees,
dirty flag before any superblock mutation).  This module turns that
prose into a machine-checked spec: a :class:`DurabilityShadow` replays a
:class:`~repro.analysis.trace.PersistTracer` event stream under the
*strict* durability model — a write is guaranteed durable only once a
flush of its line happened *after* the write and a fence happened after
that flush (real ``clwb`` captures the line at flush time; the
simulator's fence-time write-back is a superset, so the shadow is the
conservative lower bound) — and a set of :class:`Rule` triggers fire on
writes and semantic ``note`` events, checking durable state at exactly
the instant ordering matters.

The shadow deliberately ignores the simulator's random evictions: it
models *guarantees*, not luck, which also makes the mutation tests
deterministic (a suppressed flush site always violates, regardless of
the eviction RNG).

Perf diagnostics ride along: redundant flushes (line already scheduled
with nothing new dirty), empty fences (no effective flush since the
last fence), and fences per semantic operation.
"""

from __future__ import annotations

from collections import Counter
from dataclasses import dataclass, field

from ..core import layout
from ..core import pptr as pp
from ..core.atomics import CACHELINE_WORDS
from ..core.prefix_index import REC_WORDS, TYPENAME as PREFIX_TYPENAME
from ..core.prefix_trie import (REC_WORDS as TRIE_REC_WORDS,
                                TYPENAME as TRIE_TYPENAME)

__all__ = [
    "DurabilityShadow",
    "Rule",
    "Violation",
    "Report",
    "standard_rules",
    "check_trace",
    "check_allocator",
]

_NOFLUSH = object()      # sentinel: pending word has no post-write flush yet

#: note labels that count as one semantic operation for fences-per-op
OP_LABELS = frozenset({"publish_end", "lease_release", "tail_free",
                       "span_free"})


class DurabilityShadow:
    """Strict (guarantee-only) model of the persist state of every word.

    * ``base`` — durable image at trace start (words never written keep
      their base value durably).
    * ``committed`` — words whose durable value changed during the trace.
    * ``pending`` — words written but not yet guaranteed durable:
      ``addr -> [latest_value, flushed_value_or_sentinel]`` where the
      flushed value is the snapshot a post-write flush captured (real
      clwb semantics) and becomes durable at the next fence.
    """

    def __init__(self, base):
        self.base = base
        self.committed: dict[int, int] = {}
        self.pending: dict[int, list] = {}
        self._by_line: dict[int, set[int]] = {}
        self._fence_has_work = False
        self.diag = Counter(writes=0, flushes=0, fences=0,
                            redundant_flushes=0, empty_fences=0)

    # ------------------------------------------------------------- events
    def write(self, addr: int, value: int) -> None:
        self.diag["writes"] += 1
        ent = self.pending.get(addr)
        if ent is None:
            self.pending[addr] = [value, _NOFLUSH]
            self._by_line.setdefault(addr // CACHELINE_WORDS, set()).add(addr)
        else:
            ent[0] = value

    def flush(self, addr: int) -> None:
        self.diag["flushes"] += 1
        effective = False
        for w in self._by_line.get(addr // CACHELINE_WORDS, ()):
            ent = self.pending[w]
            if ent[1] is _NOFLUSH or ent[1] != ent[0]:
                ent[1] = ent[0]
                effective = True
        if effective:
            self._fence_has_work = True
        else:
            self.diag["redundant_flushes"] += 1

    def fence(self) -> None:
        self.diag["fences"] += 1
        if not self._fence_has_work:
            self.diag["empty_fences"] += 1
        self._fence_has_work = False
        done = []
        for w, ent in self.pending.items():
            if ent[1] is _NOFLUSH:
                continue
            self.committed[w] = ent[1]
            if ent[1] == ent[0]:
                done.append(w)
            else:                      # rewritten since the flush snapshot
                ent[1] = _NOFLUSH
        for w in done:
            del self.pending[w]
            line = self._by_line[w // CACHELINE_WORDS]
            line.discard(w)
            if not line:
                del self._by_line[w // CACHELINE_WORDS]

    def drain(self) -> None:
        for w, ent in self.pending.items():
            self.committed[w] = ent[0]
        self.pending.clear()
        self._by_line.clear()
        self._fence_has_work = False

    def crash(self) -> None:
        self.pending.clear()
        self._by_line.clear()
        self._fence_has_work = False

    # ------------------------------------------------------------ queries
    def is_durable(self, addr: int) -> bool:
        """True iff word ``addr``'s latest write is guaranteed durable."""
        return addr not in self.pending

    def durable_value(self, addr: int) -> int:
        """Guaranteed-durable content of ``addr`` (base image fallback)."""
        v = self.committed.get(addr)
        return int(self.base[addr]) if v is None else v


@dataclass(frozen=True)
class Violation:
    rule: str
    seq: int          # event sequence number at which the rule fired
    message: str

    def __str__(self):
        return f"[{self.rule}] @{self.seq}: {self.message}"


@dataclass(frozen=True)
class Rule:
    """One declarative ordering rule.

    ``trigger(event) -> bool`` selects the instants the rule cares
    about; ``check(shadow, event) -> list[str]`` inspects the durable
    state *just before the event applies* and returns violation
    messages.
    """

    name: str
    trigger: object
    check: object


@dataclass
class Report:
    violations: list = field(default_factory=list)
    diagnostics: dict = field(default_factory=dict)

    @property
    def ok(self) -> bool:
        return not self.violations

    def __str__(self):
        if self.ok:
            return "persist-lint: OK"
        return "persist-lint: {} violation(s)\n{}".format(
            len(self.violations),
            "\n".join(f"  {v}" for v in self.violations))


# ---------------------------------------------------------------------------
# The standard rule set: the repo's recoverability contract, rule by rule.
# ---------------------------------------------------------------------------
def standard_rules(r, *, group_commit: bool = True) -> list[Rule]:
    """Ordering spec for a :class:`~repro.core.ralloc.Ralloc` heap ``r``.

    Rules close over the heap geometry and the root-filter typing table,
    never over memory contents — all state questions go through the
    shadow at trigger time.

    ``group_commit`` appends the *relaxed* batch-publish variant of the
    record rules (``PrefixIndex.publish_batch``): N record field groups
    may share ONE fence — none of the intermediate records is reachable
    before the swing, so per-record fences buy nothing — but every
    record of the batch must still be fully durable before the single
    root swing, and the swing itself durable by batch end.  The batch
    rules trigger only on ``batch_*`` notes, so strict single-publish
    traces are unaffected; pass ``group_commit=False`` for the pure
    per-record spec.
    """
    cfg = r.config
    desc_base, sb_base = cfg.desc_base, cfg.sb_base
    total_words = cfg.total_words

    def sb_of(addr):
        if addr >= sb_base:
            return (addr - sb_base) // layout.SB_WORDS
        return (addr - desc_base) // layout.DESC_WORDS

    def desc(sb, fld):
        return desc_base + sb * layout.DESC_WORDS + fld

    def is_index_slot(slot):
        return r._root_filters.get(slot) == PREFIX_TYPENAME

    rules = []

    # (1) Dirty flag set before any superblock/descriptor mutation: a
    # write that needs recovery must be preceded by a durable dirty=1,
    # or the restart path would skip recovery over a torn heap.
    def dirty_check(sh, ev):
        if sh.durable_value(layout.M_DIRTY) != 1:
            return [f"write to word {ev.addr} (sb {sb_of(ev.addr)}) before "
                    f"the dirty flag is durably set"]
        return []
    rules.append(Rule(
        "dirty-before-sb-mutation",
        lambda ev: ev.kind == "write" and ev.addr >= desc_base,
        dirty_check))

    # (2) Watermark covers the superblock: recovery only sweeps
    # sb < durable(M_USED_SBS), so mutating a superblock the durable
    # watermark does not cover would leave it unswept after a crash.
    def watermark_check(sh, ev):
        sb = sb_of(ev.addr)
        if sb >= sh.durable_value(layout.M_USED_SBS):
            return [f"write to sb {sb} beyond the durable watermark "
                    f"({sh.durable_value(layout.M_USED_SBS)})"]
        return []
    rules.append(Rule(
        "watermark-covers-sb",
        lambda ev: ev.kind == "write" and ev.addr >= desc_base,
        watermark_check))

    # (3) All non-seal record fields durable before the seal word is
    # written (note "record_seal" fires between the field fence and the
    # seal write in PrefixIndex.publish).
    def seal_check(sh, ev):
        rec = ev.info["record"]
        bad = [w for w in (rec, rec + 1, rec + 3, rec + 4)
               if not sh.is_durable(w)]
        if bad:
            return [f"record {rec}: words {bad} not durable at seal time"]
        return []
    rules.append(Rule(
        "record-fields-durable-before-seal",
        lambda ev: ev.kind == "note" and ev.label == "record_seal",
        seal_check))

    # (4) Whole record durable before the root swing publishes it: a
    # non-null store to an index-typed root slot must name a record all
    # REC_WORDS of which are guaranteed durable.
    def swing_check(sh, ev):
        rec = sb_base + ev.value - 1
        bad = [w for w in range(rec, rec + REC_WORDS)
               if not sh.is_durable(w)]
        if bad:
            return [f"root swing to record {rec} with non-durable "
                    f"words {bad}"]
        return []
    rules.append(Rule(
        "record-durable-before-root-swing",
        lambda ev: (ev.kind == "write" and ev.value
                    and layout.M_ROOTS <= ev.addr < layout.M_ROOTS
                    + layout.MAX_ROOTS
                    and is_index_slot(ev.addr - layout.M_ROOTS)),
        swing_check))

    # (5) The root swing itself is durable by the time publish returns
    # (note "publish_end"): otherwise the caller believes the record is
    # published while a crash would silently drop it *and* its lease.
    def publish_end_check(sh, ev):
        slot, rec = ev.info["slot"], ev.info["record"]
        addr = layout.M_ROOTS + slot
        want = rec - sb_base + 1
        if not sh.is_durable(addr) or sh.durable_value(addr) != want:
            return [f"publish returned with root slot {slot} not durably "
                    f"pointing at record {rec}"]
        return []
    rules.append(Rule(
        "root-swing-durable-at-publish-end",
        lambda ev: ev.kind == "note" and ev.label == "publish_end",
        publish_end_check))

    # (6) Durable unlink strictly before lease release (note
    # "lease_release" fires in PrefixIndex.remove just before
    # span_release): if the durable chain still reaches the record, a
    # crash after the release would recover a record whose lease was
    # already dropped — a dangling index entry.
    def unlink_check(sh, ev):
        slot, rec = ev.info["slot"], ev.info["record"]
        off = sh.durable_value(layout.M_ROOTS + slot)
        cur = sb_base + off - 1 if off else None
        seen = set()
        while cur is not None and cur not in seen and len(seen) < 65536:
            if not (sb_base <= cur < total_words):
                break                      # garbage next: chain truncates
            if cur == rec:
                return [f"lease release for record {rec} while the "
                        f"durable chain from slot {slot} still reaches it"]
            seen.add(cur)
            cur = pp.decode(cur, sh.durable_value(cur))
        return []
    rules.append(Rule(
        "unlink-durable-before-lease-release",
        lambda ev: ev.kind == "note" and ev.label == "lease_release",
        unlink_check))

    # (7) Trim's size-record shrink durable before the tail frees (note
    # "tail_free" fires in _trim_tail between the persist and the free
    # pushes): the durable head size must already exclude the tail, and
    # the tail descriptors must be durably cleared, or recovery would
    # resurrect the span over reused superblocks.
    def trim_check(sh, ev):
        head, new_ext, old_ext = (ev.info["head"], ev.info["new_ext"],
                                  ev.info["old_ext"])
        msgs = []
        szw = desc(head, layout.D_BLOCK_SIZE)
        sz = sh.durable_value(szw)
        if not sh.is_durable(szw) or sz <= 0 or sz > new_ext * layout.SB_SIZE:
            msgs.append(f"tail free with head sb {head} durable size {sz} "
                        f"not shrunk to ≤ {new_ext} sb(s)")
        for sb in range(head + new_ext, head + old_ext):
            cw = desc(sb, layout.D_SIZE_CLASS)
            if not sh.is_durable(cw) or sh.durable_value(cw) != 0:
                msgs.append(f"tail free with sb {sb} continuation marker "
                            f"not durably cleared")
        return msgs
    rules.append(Rule(
        "trim-shrink-durable-before-tail-free",
        lambda ev: ev.kind == "note" and ev.label == "tail_free",
        trim_check))

    # (8) Large-span records durably cleared before the span re-enters
    # the free set (note "span_free" in _free_large): a crash after the
    # push with live records would double-place the superblocks.
    def span_free_check(sh, ev):
        head, nsb = ev.info["head"], ev.info["nsb"]
        msgs = []
        for sb in range(head, head + nsb):
            for fld in (layout.D_SIZE_CLASS, layout.D_BLOCK_SIZE):
                w = desc(sb, fld)
                if not sh.is_durable(w) or sh.durable_value(w) != 0:
                    msgs.append(f"span free with sb {sb} desc word {fld} "
                                f"not durably cleared")
        return msgs
    rules.append(Rule(
        "span-records-cleared-before-free",
        lambda ev: ev.kind == "note" and ev.label == "span_free",
        span_free_check))

    # --- prefix-trie structural rules (core.prefix_trie): the trie's
    # insert/split/remove protocol is inherently batched (one field
    # fence, one seal fence, one swing/relink), so its rules are part of
    # the base spec.  Rules 5 and 6 above already cover the trie's
    # "publish_end" and "lease_release" notes — the trie reuses both
    # labels with the same info shape and the same obligations.

    def is_trie_slot(slot):
        return r._root_filters.get(slot) == TRIE_TYPENAME

    def _trie_nonseal(rec):
        # every sealed word plus the chain/parent links — all but the
        # seal itself (word 2), which the protocol writes after them
        return (rec, rec + 1, rec + 3, rec + 4, rec + 5, rec + 6, rec + 7)

    # (T1) Every node record's non-seal fields durable before ANY seal
    # word of the batch is written (note "trie_seal" fires between the
    # shared field fence and the first seal write).
    def trie_seal_check(sh, ev):
        msgs = []
        for rec in ev.info["records"]:
            bad = [w for w in _trie_nonseal(rec) if not sh.is_durable(w)]
            if bad:
                msgs.append(f"trie record {rec}: words {bad} not durable "
                            f"at seal time")
        return msgs
    rules.append(Rule(
        "trie-fields-durable-before-seal",
        lambda ev: ev.kind == "note" and ev.label == "trie_seal",
        trie_seal_check))

    # (T2) Every new child record fully durable before the single root
    # swing attaches the segment (note "trie_attach" fires between the
    # shared seal fence and the swing) — the trie analogue of (4b).
    def trie_attach_check(sh, ev):
        msgs = []
        for rec in ev.info["records"]:
            bad = [w for w in range(rec, rec + TRIE_REC_WORDS)
                   if not sh.is_durable(w)]
            if bad:
                msgs.append(f"trie attach with record {rec} words {bad} "
                            f"not durable")
        return msgs
    rules.append(Rule(
        "trie-child-durable-before-parent-swing",
        lambda ev: ev.kind == "note" and ev.label == "trie_attach",
        trie_attach_check))

    # (T3) Non-null store to a trie-typed root slot must name a record
    # all TRIE_REC_WORDS of which are durable — the sized analogue of
    # (4) for the 8-word trie record.
    def trie_swing_check(sh, ev):
        rec = sb_base + ev.value - 1
        bad = [w for w in range(rec, rec + TRIE_REC_WORDS)
               if not sh.is_durable(w)]
        if bad:
            return [f"trie root swing to record {rec} with non-durable "
                    f"words {bad}"]
        return []
    rules.append(Rule(
        "trie-record-durable-before-root-swing",
        lambda ev: (ev.kind == "write" and ev.value
                    and layout.M_ROOTS <= ev.addr < layout.M_ROOTS
                    + layout.MAX_ROOTS
                    and is_trie_slot(ev.addr - layout.M_ROOTS)),
        trie_swing_check))

    # (T4) Split: BOTH halves fully durable before the single relink
    # write splices them into the old node's chain position (note
    # "trie_split_relink" fires between the seal fence and the splice).
    # A torn splice with a non-durable half would recover a chain whose
    # covering node is garbage — the child subtree becomes unservable.
    def trie_split_check(sh, ev):
        msgs = []
        for rec in ev.info["records"]:
            bad = [w for w in range(rec, rec + TRIE_REC_WORDS)
                   if not sh.is_durable(w)]
            if bad:
                msgs.append(f"trie split relink with half {rec} words "
                            f"{bad} not durable")
        return msgs
    rules.append(Rule(
        "trie-split-halves-durable-before-relink",
        lambda ev: ev.kind == "note" and ev.label == "trie_split_relink",
        trie_split_check))

    # (T5) Split: every child's parent word durably points at the new
    # upper half before the old node's block frees (note "trie_old_free"
    # fires just before the free).  A freed-and-reused block under a
    # stale durable parent pointer would mis-shape the recovered tree.
    def trie_reparent_check(sh, ev):
        new = ev.info["new"]
        msgs = []
        for cp in ev.info["children"]:
            w = cp + 1
            if (not sh.is_durable(w)
                    or pp.decode(w, sh.durable_value(w)) != new):
                msgs.append(f"old trie node freed with child {cp} parent "
                            f"word not durably re-pointed at {new}")
        return msgs
    rules.append(Rule(
        "trie-reparent-durable-before-old-free",
        lambda ev: ev.kind == "note" and ev.label == "trie_old_free",
        trie_reparent_check))

    if not group_commit:
        return rules

    # --- group-commit (publish_batch) relaxation: N field groups share
    # one fence, but the shared boundaries still order strictly against
    # the seals and the single root swing.

    # (3b) Every batch record's non-seal fields durable before ANY seal
    # word is written (note "batch_seal" fires between the shared field
    # fence and the first seal write).
    def batch_seal_check(sh, ev):
        msgs = []
        for rec in ev.info["records"]:
            bad = [w for w in (rec, rec + 1, rec + 3, rec + 4)
                   if not sh.is_durable(w)]
            if bad:
                msgs.append(f"batch record {rec}: words {bad} not durable "
                            f"at seal time")
        return msgs
    rules.append(Rule(
        "batch-fields-durable-before-seal",
        lambda ev: ev.kind == "note" and ev.label == "batch_seal",
        batch_seal_check))

    # (4b) Every batch record fully durable (fields AND seal) before the
    # single root swing publishes the whole segment (note "batch_root"
    # fires between the shared seal fence and the swing).
    def batch_root_check(sh, ev):
        msgs = []
        for rec in ev.info["records"]:
            bad = [w for w in range(rec, rec + REC_WORDS)
                   if not sh.is_durable(w)]
            if bad:
                msgs.append(f"batch root swing with record {rec} words "
                            f"{bad} not durable")
        return msgs
    rules.append(Rule(
        "batch-records-durable-before-root-swing",
        lambda ev: ev.kind == "note" and ev.label == "batch_root",
        batch_root_check))

    # (5b) The swing is durable by the time publish_batch returns and
    # the durable chain from the root reaches every batch record — the
    # relaxation never weakens what the caller may assume at return.
    def batch_end_check(sh, ev):
        slot, recs = ev.info["slot"], ev.info["records"]
        addr = layout.M_ROOTS + slot
        want = recs[0] - sb_base + 1
        if not sh.is_durable(addr) or sh.durable_value(addr) != want:
            return [f"publish_batch returned with root slot {slot} not "
                    f"durably pointing at record {recs[0]}"]
        reached = set()
        off = sh.durable_value(addr)
        cur = sb_base + off - 1 if off else None
        while cur is not None and cur not in reached and len(reached) < 65536:
            if not (sb_base <= cur < total_words):
                break
            reached.add(cur)
            cur = pp.decode(cur, sh.durable_value(cur))
        missing = [rec for rec in recs if rec not in reached]
        if missing:
            return [f"publish_batch returned with records {missing} not on "
                    f"the durable chain from slot {slot}"]
        return []
    rules.append(Rule(
        "root-swing-durable-at-batch-end",
        lambda ev: ev.kind == "note" and ev.label == "publish_batch_end",
        batch_end_check))

    return rules


# ---------------------------------------------------------------------------
# Replay
# ---------------------------------------------------------------------------
def check_trace(events, base, rules) -> Report:
    """Replay ``events`` over ``base``, firing ``rules`` before each event
    applies; returns violations plus perf diagnostics."""
    sh = DurabilityShadow(base)
    violations: list[Violation] = []
    notes = Counter()
    batch_ops = 0
    for ev in events:
        if ev.kind in ("write", "note"):
            for rule in rules:
                if rule.trigger(ev):
                    for msg in rule.check(sh, ev):
                        violations.append(Violation(rule.name, ev.seq, msg))
        if ev.kind == "write":
            sh.write(ev.addr, ev.value)
        elif ev.kind == "flush":
            sh.flush(ev.addr)
        elif ev.kind == "fence":
            sh.fence()
        elif ev.kind == "drain":
            sh.drain()
        elif ev.kind == "crash":
            sh.crash()
        elif ev.kind == "note":
            notes[ev.label] += 1
            if ev.label == "publish_batch_end":
                # one group commit = N semantic publishes for fences/op
                batch_ops += len(ev.info.get("records", ()))
        # cas events are bookkeeping only: the underlying store already
        # arrived as its own write event.
    diag = dict(sh.diag)
    diag["notes"] = dict(notes)
    ops = batch_ops + sum(n for lbl, n in notes.items() if lbl in OP_LABELS)
    diag["ops"] = ops
    diag["fences_per_op"] = (diag["fences"] / ops) if ops else None
    return Report(violations=violations, diagnostics=diag)


def check_allocator(r, tracer, rules=None) -> Report:
    """Check the trace an attached tracer captured against the standard
    ordering spec for heap ``r`` (or an explicit rule list)."""
    if tracer.base is None:
        raise ValueError("tracer has no base image; use attach_tracer()")
    return check_trace(tracer.events, tracer.base,
                       standard_rules(r) if rules is None else rules)
