"""Static repo-invariant lint (AST pass).

Four rules, each converting a documented-but-honor-system invariant of
this codebase into a machine check:

``NVM001`` — no direct ``.nvm[...]`` stores outside ``core/atomics.py``.
    The ``nvm`` buffer is the durable image; a store that bypasses
    ``NVMArray.write`` is invisible to the write-back simulation, the
    persistence counters and the persist-order tracer.

``SHD001`` — no ``jax.sharding.AxisType`` / ``jax.experimental.shard_map``
    references outside ``src/repro/runtime/`` (the PR-1 rule).  All mesh
    and sharding concerns live behind the runtime facade so the core
    stays host-only importable.

``PER001`` — every write call whose target expression names a persistent
    layout field (``M_ROOTS``, ``M_DIRTY``, ``M_USED_SBS``,
    ``D_SIZE_CLASS``, ``D_BLOCK_SIZE``) must share its function with a
    flush-like call (``flush``/``flush_range``/``fence``/``persist``/
    ``_persist``/``drain``/``set_root``) or carry a ``# persist:
    deferred`` annotation on its line or the line above.  The rule is
    deliberately function-local and name-based: it cannot prove
    ordering (that is the dynamic checker's job) but it catches the
    classic drive-by — a new durable-field write added without any
    persistence thought at all.

``TRN001`` — the free-run index arrays (``run_len`` / ``run_start`` /
    ``run_bucket_min``) must never be named in a flush-like call.  They
    are *transient* placement indexes — pure functions of the persistent
    class records, rebuilt from scratch by recovery's sweep — and the
    paper's "pay almost nothing for persistence" claim rests on exactly
    that: flushing one would silently promote it to durable state and
    reopen a write-back cost the design already eliminated.

Used by ``tools/lint_persist.py`` (CLI, wired into tier-1 CI) and the
unit tests.
"""

from __future__ import annotations

import ast
import dataclasses
import pathlib

PERSIST_FIELDS = frozenset({"M_ROOTS", "M_DIRTY", "M_USED_SBS",
                            "D_SIZE_CLASS", "D_BLOCK_SIZE"})
WRITE_METHODS = frozenset({"write", "write_word", "write_block"})
FLUSH_METHODS = frozenset({"flush", "flush_range", "fence", "persist",
                           "_persist", "drain", "set_root", "set_roots"})
TRANSIENT_INDEX_FIELDS = frozenset({"run_len", "run_start",
                                    "run_bucket_min"})
DEFER_ANNOTATION = "persist: deferred"


@dataclasses.dataclass(frozen=True)
class Finding:
    path: str
    line: int
    code: str
    message: str

    def __str__(self):
        return f"{self.path}:{self.line}: {self.code} {self.message}"


def _attr_names(node) -> set[str]:
    """Every identifier reachable in an expression (Name ids + Attribute
    attrs) — the currency of all three rules' matching."""
    out = set()
    for n in ast.walk(node):
        if isinstance(n, ast.Name):
            out.add(n.id)
        elif isinstance(n, ast.Attribute):
            out.add(n.attr)
    return out


def _is_nvm_subscript(node) -> bool:
    return (isinstance(node, ast.Subscript)
            and isinstance(node.value, ast.Attribute)
            and node.value.attr == "nvm")


def _called_method(call: ast.Call) -> str | None:
    f = call.func
    if isinstance(f, ast.Attribute):
        return f.attr
    if isinstance(f, ast.Name):
        return f.id
    return None


def _line_has_deferral(source_lines, lineno: int) -> bool:
    for ln in (lineno, lineno - 1):
        if 1 <= ln <= len(source_lines) \
                and DEFER_ANNOTATION in source_lines[ln - 1]:
            return True
    return False


class _Scope:
    """One function body (or the module top level): collects the flagged
    write calls and whether any flush-like call appears."""

    def __init__(self, name):
        self.name = name
        self.flagged_writes: list[ast.Call] = []
        self.has_flush = False


def check_source(path_label: str, text: str, *,
                 allow_nvm_store: bool = False,
                 allow_sharding: bool = False) -> list[Finding]:
    """Lint one file's source; ``path_label`` is used in findings only."""
    findings: list[Finding] = []
    try:
        tree = ast.parse(text)
    except SyntaxError as e:
        return [Finding(path_label, e.lineno or 0, "PARSE", str(e))]
    source_lines = text.splitlines()

    # ---------------------------------------------------------- NVM001
    if not allow_nvm_store:
        for node in ast.walk(tree):
            targets = []
            if isinstance(node, ast.Assign):
                targets = node.targets
            elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
                targets = [node.target]
            for t in targets:
                if _is_nvm_subscript(t):
                    findings.append(Finding(
                        path_label, node.lineno, "NVM001",
                        "direct .nvm[...] store outside core/atomics.py "
                        "bypasses the write-back simulation and the "
                        "persist tracer; use NVMArray.write"))

    # ---------------------------------------------------------- SHD001
    if not allow_sharding:
        def _sharding_hit(node) -> str | None:
            if isinstance(node, ast.Attribute):
                chain = _attr_names(node)
                if node.attr == "AxisType" and "sharding" in chain:
                    return "jax.sharding.AxisType"
                if node.attr == "shard_map" and ("jax" in chain
                                                 or "experimental" in chain):
                    return "shard_map"
            if isinstance(node, ast.ImportFrom) and node.module:
                mod = node.module
                if "shard_map" in mod:
                    return mod
                if mod.startswith("jax"):
                    for a in node.names:
                        if a.name in ("shard_map", "AxisType"):
                            return f"{mod}.{a.name}"
            if isinstance(node, ast.Import):
                for a in node.names:
                    if "shard_map" in a.name or a.name == "jax.sharding":
                        return a.name
            return None

        for node in ast.walk(tree):
            hit = _sharding_hit(node)
            if hit:
                findings.append(Finding(
                    path_label, node.lineno, "SHD001",
                    f"{hit} referenced outside src/repro/runtime/ — mesh "
                    "and sharding concerns live behind the runtime facade"))

    # ---------------------------------------------------------- PER001
    scopes: list[_Scope] = []

    def visit_body(scope: _Scope, nodes):
        for node in nodes:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                sub = _Scope(node.name)
                scopes.append(sub)
                visit_body(sub, node.body)
                continue
            if isinstance(node, ast.ClassDef):
                visit_body(scope, node.body)
                continue
            for call in [n for n in ast.walk(node)
                         if isinstance(n, ast.Call)]:
                meth = _called_method(call)
                if meth in FLUSH_METHODS:
                    scope.has_flush = True
                    # TRN001: transient index arrays named in a flush
                    named = set()
                    for a in list(call.args) + [k.value
                                                for k in call.keywords]:
                        named |= _attr_names(a)
                    hit = sorted(named & TRANSIENT_INDEX_FIELDS)
                    if hit:
                        findings.append(Finding(
                            path_label, call.lineno, "TRN001",
                            f"transient index field(s) {', '.join(hit)} "
                            f"named in {meth}() — the free-run index is "
                            "rebuilt by recovery, never flushed"))
                if meth in WRITE_METHODS and call.args:
                    # only the *target* expression (first arg) counts —
                    # a value that mentions a layout constant is not a
                    # store to that field
                    if _attr_names(call.args[0]) & PERSIST_FIELDS:
                        scope.flagged_writes.append(call)

    module_scope = _Scope("<module>")
    scopes.append(module_scope)
    visit_body(module_scope, tree.body)

    for scope in scopes:
        if scope.has_flush:
            continue
        for call in scope.flagged_writes:
            if _line_has_deferral(source_lines, call.lineno):
                continue
            fields = sorted(_attr_names(call.args[0]) & PERSIST_FIELDS)
            findings.append(Finding(
                path_label, call.lineno, "PER001",
                f"write to persistent field(s) {', '.join(fields)} in "
                f"{scope.name}() with no flush-like call in the same "
                f"function; flush it or annotate `# {DEFER_ANNOTATION}`"))

    findings.sort(key=lambda f: (f.line, f.code))
    return findings


def check_file(path) -> list[Finding]:
    p = pathlib.Path(path)
    parts = p.parts
    return check_source(
        str(p), p.read_text(),
        allow_nvm_store=(p.name == "atomics.py" and "core" in parts),
        allow_sharding="runtime" in parts)


def check_tree(root) -> list[Finding]:
    """Lint every ``*.py`` under ``root`` (or a single file)."""
    rootp = pathlib.Path(root)
    if rootp.is_file():
        return check_file(rootp)
    findings: list[Finding] = []
    for p in sorted(rootp.rglob("*.py")):
        findings.extend(check_file(p))
    return findings
