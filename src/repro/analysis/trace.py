"""Epoch-stamped persist-event tracing for the simulated NVM.

``NVMArray`` accepts an optional ``tracer``; when set, every ``write``,
``flush``, ``fence``, ``cas``, ``crash`` and ``drain`` is reported *at
entry* (before the memory mutates), so a tracer that raises models a
crash just before the event takes effect.  Allocators forward semantic
markers via ``NVMArray.note`` (``record_seal``, ``publish_end``,
``lease_release``, ``tail_free``, ``span_free``) which the ordering
rules in :mod:`repro.analysis.persist_lint` trigger on.

Events are epoch-stamped: the epoch is the number of fences observed so
far, i.e. all events in one epoch sit between the same pair of persist
barriers.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

__all__ = [
    "TraceEvent",
    "PersistTracer",
    "CrashAfter",
    "SimulatedCrash",
    "attach_tracer",
]


@dataclass(frozen=True)
class TraceEvent:
    """One memory-ordering event.

    ``addr``/``value`` are word-granular (``None`` when not applicable:
    fences, crashes, notes).  ``label``/``info`` carry the semantic
    payload of ``note`` events.
    """

    seq: int
    epoch: int
    kind: str                      # write|flush|fence|cas|crash|drain|note
    addr: int | None = None
    value: int | None = None
    label: str | None = None
    info: dict = field(default_factory=dict)


class PersistTracer:
    """Records the full event stream plus a snapshot of the base image.

    ``base`` is the durable image at attach time; the checker's shadow
    model needs it to answer "what is the durable value of word X" for
    words never rewritten during the trace.
    """

    __slots__ = ("events", "base", "epoch")

    def __init__(self, base=None):
        self.events: list[TraceEvent] = []
        self.base = base
        self.epoch = 0

    def record(self, kind, addr=None, value=None, label=None, info=None):
        self.events.append(TraceEvent(
            seq=len(self.events), epoch=self.epoch, kind=kind, addr=addr,
            value=None if value is None else int(value),
            label=label, info=info or {}))
        if kind in ("fence", "drain"):
            self.epoch += 1

    def clear(self):
        self.events.clear()


class SimulatedCrash(RuntimeError):
    """Raised by :class:`CrashAfter` when its event budget is exhausted."""


class CrashAfter(PersistTracer):
    """Tracer that lets exactly ``budget`` events through, then raises.

    Because ``NVMArray`` reports events before mutating, the raising
    event never takes effect: the memory is left exactly as if the
    machine lost power at that point (volatile cache intact — callers
    crash-test by reopening from ``mem.nvm``, which holds only durable
    state).
    """

    __slots__ = ("remaining",)

    def __init__(self, budget, base=None):
        super().__init__(base)
        self.remaining = budget

    def record(self, kind, addr=None, value=None, label=None, info=None):
        if self.remaining <= 0:
            raise SimulatedCrash(f"event budget exhausted at {kind}")
        self.remaining -= 1
        super().record(kind, addr, value, label, info)


def attach_tracer(obj, tracer=None):
    """Attach a tracer to an allocator (anything with ``.mem``) or a raw
    ``NVMArray``; snapshots the durable image as the shadow base."""
    mem = getattr(obj, "mem", obj)
    if tracer is None:
        tracer = PersistTracer()
    if tracer.base is None:
        tracer.base = np.array(mem.nvm, copy=True)
    mem.tracer = tracer
    return tracer
