"""Recoverable checkpoint store on a Ralloc persistent heap.

Every checkpoint shard (one array leaf) is a block malloc'd from the
heap; a *manifest* block lists the shard pptrs plus JSON metadata, and a
persistent root points at the manifest — the root update is the atomic
commit.  No write-ahead log, no ordering between shard writes: if a
crash lands mid-checkpoint, the half-written shards are simply
unreachable and recovery GC reclaims them (paper §3 — exactly the
allocate-then-attach leak the paper's recoverability criterion covers).

Two roots alternate so the previous checkpoint stays reachable until the
new one commits.  All references are pptrs ⇒ the heap file can be
remapped anywhere (and restored onto a *different mesh*: arrays are
stored unsharded and resharded on load — the elastic-rescale path).

Manifest block layout (words):
  [0] n_shards   [1..n] pptr to shard block   [n+1] json byte length
  [n+2..] JSON metadata (leaf paths, shapes, dtypes, step) packed LE.
"""

from __future__ import annotations

import json

import numpy as np

from ..core import pptr as pp
from ..core.layout import WORD
from ..core.ralloc import Ralloc

ROOT_A, ROOT_B = 0, 1
_META_ROOT = 2          # tiny block holding which root is live


def manifest_filter(reader, block_word, size_bytes):
    """Filter function (paper §4.5.1): enumerate shard pptrs precisely."""
    n = reader.read_word(block_word)
    for k in range(int(n)):
        w = block_word + 1 + k
        tgt = pp.decode(w, reader.read_word(w))
        if tgt is not None:
            yield tgt, None          # shard blocks contain raw data, no refs


def register_filters(heap: Ralloc) -> None:
    heap.filters.register("ckpt_manifest", manifest_filter)


class CheckpointManager:
    def __init__(self, heap: Ralloc):
        self.heap = heap
        register_filters(heap)
        self._flip = 0

    # ------------------------------------------------------------------ save
    def save(self, tree: dict, step: int) -> None:
        import jax
        leaves, treedef = jax.tree.flatten(tree)
        heap = self.heap
        meta, shard_ptrs = [], []
        for i, leaf in enumerate(leaves):
            arr = np.asarray(leaf)
            raw = arr.tobytes()
            nwords = max(1, -(-len(raw) // WORD))
            blk = heap.malloc(nwords * WORD)
            if blk is None:
                raise MemoryError("checkpoint heap exhausted")
            words = np.frombuffer(raw.ljust(nwords * WORD, b"\0"),
                                  dtype=np.int64)
            for k in range(nwords):          # application stores + flush
                heap.write_word(blk + k, int(words[k]))
            heap.flush_range(blk, nwords)
            shard_ptrs.append(blk)
            meta.append({"shape": list(arr.shape), "dtype": str(arr.dtype),
                         "words": nwords})
        heap.fence()                          # shards durable before manifest

        mjson = json.dumps({"step": step, "leaves": meta,
                            "treedef": str(treedef)}).encode()
        n = len(shard_ptrs)
        jwords = -(-len(mjson) // WORD)
        mblk = heap.malloc((2 + n + jwords) * WORD)
        heap.write_word(mblk, n)
        for k, sp in enumerate(shard_ptrs):
            heap.write_word(mblk + 1 + k, pp.encode(mblk + 1 + k, sp))
        heap.write_word(mblk + 1 + n, len(mjson))
        packed = np.frombuffer(mjson.ljust(jwords * WORD, b"\0"), np.int64)
        for k in range(jwords):
            heap.write_word(mblk + 2 + n + k, int(packed[k]))
        heap.flush_range(mblk, 2 + n + jwords)
        heap.fence()                          # manifest durable before root

        root = (ROOT_A, ROOT_B)[self._flip]
        heap.set_root(root, mblk, "ckpt_manifest")   # atomic commit point
        other = (ROOT_B, ROOT_A)[self._flip]
        old = heap.get_root(other)
        heap.set_root(other, None)            # retire the older checkpoint
        self._flip ^= 1
        # the old manifest + shards are now unreachable; free eagerly in
        # normal operation (GC would also reclaim them after a crash)
        if old is not None:
            self._free_manifest(old)

    def _free_manifest(self, mblk: int) -> None:
        heap = self.heap
        n = int(heap.read_word(mblk))
        for k in range(n):
            w = mblk + 1 + k
            tgt = pp.decode(w, heap.read_word(w))
            if tgt is not None:
                heap.free(tgt)
        heap.free(mblk)

    # --------------------------------------------------------------- restore
    def load_latest(self, tree_like=None):
        """Returns (leaves_state_dict, step) from the newest live root."""
        import jax
        best = None
        for root in (ROOT_A, ROOT_B):
            mblk = self.heap.get_root(root, "ckpt_manifest")
            if mblk is None:
                continue
            info = self._read_manifest(mblk)
            if best is None or info[2]["step"] > best[2]["step"]:
                best = info
                self._flip = 1 - root         # next save goes to the other
        if best is None:
            return None, -1
        mblk, shard_ptrs, meta = best
        leaves = []
        for sp, m in zip(shard_ptrs, meta["leaves"]):
            words = np.array([self.heap.read_word(sp + k)
                              for k in range(m["words"])], dtype=np.int64)
            raw = words.tobytes()
            arr = np.frombuffer(raw, dtype=np.dtype(m["dtype"]))
            n = int(np.prod(m["shape"])) if m["shape"] else 1
            leaves.append(arr[:n].reshape(m["shape"]))
        if tree_like is not None:
            flat, treedef = jax.tree.flatten(tree_like)
            leaves = [l.astype(np.asarray(f).dtype) if hasattr(f, "dtype")
                      else l for l, f in zip(leaves, flat)]
            return treedef.unflatten(leaves), meta["step"]
        return leaves, meta["step"]

    def _read_manifest(self, mblk: int):
        heap = self.heap
        n = int(heap.read_word(mblk))
        ptrs = []
        for k in range(n):
            w = mblk + 1 + k
            ptrs.append(pp.decode(w, heap.read_word(w)))
        jlen = int(heap.read_word(mblk + 1 + n))
        jwords = -(-jlen // WORD)
        raw = np.array([heap.read_word(mblk + 2 + n + k)
                        for k in range(jwords)], np.int64).tobytes()[:jlen]
        return mblk, ptrs, json.loads(raw.decode())
