"""Architecture registry: one module per assigned architecture.

``get_config(arch)`` returns the exact published configuration;
``get_smoke_config(arch)`` returns a reduced same-family configuration
for CPU smoke tests (small layers/width, few experts, tiny vocab).
"""

from __future__ import annotations

import dataclasses
import importlib

ARCHS = (
    "granite_20b",
    "nemotron_4_340b",
    "qwen2_5_32b",
    "starcoder2_3b",
    "internvl2_26b",
    "granite_moe_3b_a800m",
    "moonshot_v1_16b_a3b",
    "mamba2_370m",
    "hubert_xlarge",
    "recurrentgemma_9b",
)

# assigned input-shape sets (LM family): seq_len × global_batch
SHAPES = {
    "train_4k":    {"kind": "train",   "seq_len": 4096,   "global_batch": 256},
    "prefill_32k": {"kind": "prefill", "seq_len": 32768,  "global_batch": 32},
    "decode_32k":  {"kind": "decode",  "seq_len": 32768,  "global_batch": 128},
    "long_500k":   {"kind": "decode",  "seq_len": 524288, "global_batch": 1},
}


def canon(arch: str) -> str:
    return arch.replace("-", "_").replace(".", "_")


def get_config(arch: str):
    mod = importlib.import_module(f"repro.configs.{canon(arch)}")
    return mod.config()


def get_smoke_config(arch: str):
    mod = importlib.import_module(f"repro.configs.{canon(arch)}")
    return mod.smoke_config()


def applicable_shapes(arch: str) -> list[str]:
    """Which assigned shapes apply (DESIGN.md §5 documents the skips)."""
    cfg = get_config(arch)
    out = ["train_4k", "prefill_32k"]
    if cfg.causal:                       # encoder-only archs have no decode
        out.append("decode_32k")
        if cfg.family in ("ssm", "hybrid"):   # sub-quadratic only
            out.append("long_500k")
    return out


def all_cells():
    return [(a, s) for a in ARCHS for s in applicable_shapes(a)]
