"""granite-20b — dense code LM [arXiv:2405.04324; hf].

52L, d_model 6144, 48 heads (GQA kv=1 ⇒ MQA), d_ff 24576, vocab 49152.
GPT-BigCode lineage: non-gated GELU MLP, LayerNorm; assignment tags it
llama-arch so RoPE is enabled.
"""
import dataclasses
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-20b", family="dense",
        num_layers=52, d_model=6144, num_heads=48, num_kv_heads=1,
        head_dim=128, d_ff=24576, vocab_size=49152,
        mlp="gelu", norm="layernorm", use_rope=True,
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=2, d_model=64, num_heads=4, num_kv_heads=1,
        head_dim=16, d_ff=256, vocab_size=128)
