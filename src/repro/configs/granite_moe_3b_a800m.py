"""granite-moe-3b-a800m — MoE LM [hf:ibm-granite/granite-3.0-1b-a400m; hf].

32L, d_model 1536, 24 heads (GQA kv=8), per-expert d_ff 512,
vocab 49155, 40 experts top-8.  SwiGLU experts, RMSNorm, RoPE.
"""
import dataclasses
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m", family="moe",
        num_layers=32, d_model=1536, num_heads=24, num_kv_heads=8,
        head_dim=64, d_ff=512, vocab_size=49155,
        pattern=(("attn", "moe"),),
        num_experts=40, top_k=8, expert_pad=8,  # 48 = 3 x 16 for EP
        mlp="swiglu", norm="rmsnorm", use_rope=True, tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=64, vocab_size=128, num_experts=8, top_k=2)
