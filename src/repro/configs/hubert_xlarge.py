"""hubert-xlarge — audio encoder [arXiv:2106.07447; unverified].

48L encoder-only (bidirectional), d_model 1280, 16 heads (MHA),
d_ff 5120, vocab 504 (masked-prediction codebook targets).
The conv waveform frontend is a STUB: ``input_specs`` feeds precomputed
frame embeddings [B, S, 1280].  No decode shapes (encoder-only).
"""
import dataclasses
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge", family="audio",
        num_layers=48, d_model=1280, num_heads=16, num_kv_heads=16,
        head_dim=80, d_ff=5120, vocab_size=504,
        mlp="gelu", norm="layernorm", use_rope=False, causal=False,
        frontend="audio",
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        head_dim=16, d_ff=256, vocab_size=64)
