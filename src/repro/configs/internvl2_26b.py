"""internvl2-26b — VLM [arXiv:2404.16821; hf].

Backbone only (assignment): InternLM2-20B-style decoder — 48L, d_model
6144, 48 heads (GQA kv=8), d_ff 16384, vocab 92553, SwiGLU, RMSNorm.
The InternViT frontend is a STUB: ``input_specs`` feeds precomputed
patch embeddings [B, S, d_model] (vision tokens + projected text mix).
"""
import dataclasses
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-26b", family="vlm",
        num_layers=48, d_model=6144, num_heads=48, num_kv_heads=8,
        head_dim=128, d_ff=16384, vocab_size=92553,
        mlp="swiglu", norm="rmsnorm", use_rope=True,
        frontend="vision",
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=256, vocab_size=128)
