"""mamba2-370m — SSM (state-space duality) [arXiv:2405.21060; unverified].

48L, d_model 1024, attention-free, ssm_state 128, vocab 50280.
Pure Mamba-2 blocks (no MLP): expand 2 ⇒ d_inner 2048, 32 heads of 64.
Sub-quadratic ⇒ runs the long_500k shape.
"""
import dataclasses
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m", family="ssm",
        num_layers=48, d_model=1024, vocab_size=50280,
        pattern=(("mamba2", "none"),),
        ssm_state=128, ssm_head_dim=64, expand=2, conv_width=4,
        mlp="gelu", norm="rmsnorm", use_rope=False, tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=2, d_model=64, ssm_state=16, ssm_head_dim=16,
        vocab_size=128, ssm_chunk=8)
