"""moonshot-v1-16b-a3b — MoE LM [hf:moonshotai/Moonlight-16B-A3B; hf].

48L, d_model 2048, 16 heads (kv=16 ⇒ MHA), per-expert d_ff 1408,
vocab 163840, 64 experts top-6.  SwiGLU experts, RMSNorm, RoPE.
(Moonlight's shared expert is folded into the routed pool here; noted
in DESIGN.md §5.)
"""
import dataclasses
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="moonshot-v1-16b-a3b", family="moe",
        num_layers=48, d_model=2048, num_heads=16, num_kv_heads=16,
        head_dim=128, d_ff=1408, vocab_size=163840,
        pattern=(("attn", "moe"),),
        num_experts=64, top_k=6,
        mlp="swiglu", norm="rmsnorm", use_rope=True,
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        head_dim=16, d_ff=64, vocab_size=128, num_experts=8, top_k=2)
