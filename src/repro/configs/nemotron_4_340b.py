"""nemotron-4-340b — dense LM [arXiv:2402.16819; unverified].

96L, d_model 18432, 96 heads (GQA kv=8), d_ff 73728, vocab 256000.
Squared-ReLU (non-gated) MLP, RoPE, no bias.
"""
import dataclasses
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-340b", family="dense",
        num_layers=96, d_model=18432, num_heads=96, num_kv_heads=8,
        head_dim=192, d_ff=73728, vocab_size=256000,
        mlp="squared_relu", norm="layernorm", use_rope=True,
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=2, d_model=96, num_heads=6, num_kv_heads=2,
        head_dim=16, d_ff=384, vocab_size=128)
