"""qwen2.5-32b — dense LM [hf:Qwen/Qwen2.5-0.5B family; hf].

64L, d_model 5120, 40 heads (GQA kv=8), d_ff 27648, vocab 152064.
SwiGLU, RMSNorm, RoPE, QKV bias (the Qwen2 signature).
"""
import dataclasses
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-32b", family="dense",
        num_layers=64, d_model=5120, num_heads=40, num_kv_heads=8,
        head_dim=128, d_ff=27648, vocab_size=152064,
        mlp="swiglu", norm="rmsnorm", use_rope=True, qkv_bias=True,
        rope_theta=1e6,
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=256, vocab_size=128)
