"""recurrentgemma-9b — hybrid RG-LRU + local attention [arXiv:2402.19427].

38L, d_model 4096, 16 heads (GQA kv=1 ⇒ MQA) head_dim 256, d_ff 12288,
vocab 256000, window 2048, pattern 2×recurrent : 1×local-attn.
Bounded window + constant recurrent state ⇒ runs long_500k.
38 = 12 full (rec,rec,attn) units + a (rec,rec) tail (unrolled).
"""
import dataclasses
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b", family="hybrid",
        num_layers=38, d_model=4096, num_heads=16, num_kv_heads=1,
        head_dim=256, d_ff=12288, vocab_size=256000,
        pattern=(("rglru", "mlp"), ("rglru", "mlp"), ("local_attn", "mlp")),
        window=2048, lru_width=4096, conv_width=4,
        mlp="swiglu", norm="rmsnorm", use_rope=True, tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=4, d_model=64, num_heads=4, num_kv_heads=1,
        head_dim=16, d_ff=256, vocab_size=128, window=16, lru_width=64)
