"""starcoder2-3b — dense code LM [arXiv:2402.19173; hf].

30L, d_model 3072, 24 heads (GQA kv=2), d_ff 12288, vocab 49152.
Non-gated GELU MLP, LayerNorm, RoPE, tied embeddings.
"""
import dataclasses
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-3b", family="dense",
        num_layers=30, d_model=3072, num_heads=24, num_kv_heads=2,
        head_dim=128, d_ff=12288, vocab_size=49152,
        mlp="gelu", norm="layernorm", use_rope=True, tie_embeddings=True,
        qkv_bias=True,
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=256, vocab_size=128)
