"""Core: Ralloc — recoverable, nonblocking persistent memory allocation.

The paper's primary contribution (Cai et al., 2020), in two guises:

  * ``ralloc.Ralloc`` — faithful host-side port (mmap "NVM", CAS lists,
    thread caches, filter-function GC recovery);
  * ``jax_alloc`` / ``jax_recovery`` — the TPU-native adaptation: a
    jittable, vectorized allocator + mark/sweep used by the paged
    KV-cache and checkpoint subsystems.
"""

from .layout import HeapConfig, SIZE_CLASSES, SB_SIZE, size_to_class
from .ralloc import Ralloc, OutOfMemory
from .filters import FilterRegistry, register_stock_filters

__all__ = [
    "HeapConfig", "SIZE_CLASSES", "SB_SIZE", "size_to_class",
    "Ralloc", "OutOfMemory", "FilterRegistry", "register_stock_filters",
]
