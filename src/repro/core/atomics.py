"""Atomic primitives and the simulated-NVM write-back layer.

The paper's hardware model (§2.1): stores go through volatile caches; an
application controls durability with ``clwb`` (flush) + ``sfence`` (fence);
on a full-system crash every line that was not written back is lost, but
writes-back are never torn at cache-line granularity.

We reproduce that model in software so the recoverability protocol can be
*tested* rather than assumed:

  * ``NVMArray`` wraps a ``numpy.int64`` buffer ("the NVM image").
  * With ``sim=True`` all writes land in a per-line write-back cache;
    ``flush(addr)`` schedules the line, ``fence()`` makes scheduled lines
    durable.  ``crash()`` drops everything not yet durable.  A seeded RNG
    spontaneously evicts dirty lines (hardware may write back *any* dirty
    line at *any* time — correct protocols must tolerate both presence and
    absence of unflushed data).
  * With ``sim=False`` ("fast mode", used by the benchmarks) writes go
    straight to the buffer and flush/fence only bump counters, so the
    *cost model* of persistence (flush/fence counts per operation — the
    paper's key claim is that Ralloc needs almost none) is still measured.

CAS is emulated with a short critical section.  The Ralloc *algorithm*
remains nonblocking — the lock stands in for a single hardware CAS
instruction, never protects multi-word state, and is never held across
other operations.  (CPython cannot express a true lock-free CAS on shared
numpy memory; this is the standard emulation.)
"""

from __future__ import annotations

import threading

import numpy as np

CACHELINE_WORDS = 8


class NVMArray:
    """int64 word array with flush/fence semantics and crash injection."""

    def __init__(self, words: int, *, sim: bool = False, seed: int = 0,
                 evict_prob: float = 0.01, backing: np.ndarray | None = None,
                 flush_ns: int = 0, fence_ns: int = 0, tracer=None):
        if backing is not None:
            assert backing.dtype == np.int64 and backing.size >= words
            self.nvm = backing
        else:
            self.nvm = np.zeros(words, dtype=np.int64)
        self.sim = sim
        # Optional persist-event tracer (analysis.trace.PersistTracer):
        # every ordering-relevant call is reported *at entry*, before the
        # memory mutates, so a raising tracer models a crash just before
        # the event.  None (the default) costs one attribute test per op.
        self.tracer = tracer
        # Optional modeled Optane write-back latency (benchmarks only):
        # clwb issue + WPQ drain are ~100–300 ns on real hardware; a busy
        # wait injects that cost so persistence shows up in throughput.
        self.flush_ns = flush_ns
        self.fence_ns = fence_ns
        self._cas_lock = threading.Lock()
        # persistence cost counters (valid in both modes)
        self.n_flush = 0
        self.n_fence = 0
        self.n_cas = 0
        self.n_drain = 0
        # clwb issued since the last sfence/drain?  A fence with no
        # intervening flush commits nothing (nothing is scheduled), so
        # callers at persist boundaries may elide it when this is False.
        self._flushed_since_fence = False
        if sim:
            self._cache: dict[int, dict[int, int]] = {}   # line -> {word: value}
            self._scheduled: set[int] = set()             # flushed, await fence
            self._rng = np.random.default_rng(seed)
            self._evict_prob = evict_prob

    # -- addressing helpers --------------------------------------------------
    @staticmethod
    def _line(idx: int) -> int:
        return idx // CACHELINE_WORDS

    # -- reads / writes -------------------------------------------------------
    def read(self, idx: int) -> int:
        if self.sim:
            line = self._cache.get(self._line(idx))
            if line is not None and idx in line:
                return line[idx]
        return int(self.nvm[idx])

    def read_block(self, idx: int, n: int) -> np.ndarray:
        """Read ``n`` consecutive words (cache-coherent view)."""
        out = self.nvm[idx:idx + n].copy()
        if self.sim:
            for line_id in range(self._line(idx), self._line(idx + n - 1) + 1):
                line = self._cache.get(line_id)
                if line:
                    for w, v in line.items():
                        if idx <= w < idx + n:
                            out[w - idx] = v
        return out

    def write(self, idx: int, value: int) -> None:
        value = int(np.int64(np.uint64(value & ((1 << 64) - 1))))
        if self.tracer is not None:
            self.tracer.record("write", idx, value)
        if self.sim:
            self._cache.setdefault(self._line(idx), {})[idx] = value
            self._maybe_evict()
        else:
            self.nvm[idx] = value

    def write_block(self, idx: int, values) -> None:
        for k, v in enumerate(values):
            self.write(idx + k, int(v))

    # -- persistence ----------------------------------------------------------
    def flush(self, idx: int) -> None:
        """clwb: schedule the line containing ``idx`` for write-back."""
        if self.tracer is not None:
            self.tracer.record("flush", idx)
        self.n_flush += 1
        self._flushed_since_fence = True
        if self.sim:
            self._scheduled.add(self._line(idx))
        if self.flush_ns:
            self._spin(self.flush_ns)

    def fence(self) -> None:
        """sfence: all scheduled lines become durable."""
        if self.tracer is not None:
            self.tracer.record("fence")
        self.n_fence += 1
        self._flushed_since_fence = False
        if self.sim:
            for line_id in list(self._scheduled):
                self._writeback(line_id)
            self._scheduled.clear()
        if self.fence_ns:
            self._spin(self.fence_ns)

    @property
    def flush_pending(self) -> bool:
        """True iff a clwb was issued since the last sfence/drain/crash.
        When False, an sfence would commit nothing — the strict model
        has no scheduled lines — so a persist boundary may skip it."""
        return self._flushed_since_fence

    @staticmethod
    def _spin(ns: int) -> None:
        import time
        end = time.perf_counter_ns() + ns
        while time.perf_counter_ns() < end:
            pass

    def persist(self, idx: int, value: int) -> None:
        """write + flush + fence of one word (ordered durable store)."""
        self.write(idx, value)
        self.flush(idx)
        self.fence()

    def _writeback(self, line_id: int) -> None:
        line = self._cache.pop(line_id, None)
        if line:
            for w, v in line.items():
                self.nvm[w] = v

    def _maybe_evict(self) -> None:
        """Hardware may evict any dirty line at any time."""
        if self._cache and self._rng.random() < self._evict_prob:
            victim = list(self._cache.keys())[
                int(self._rng.integers(len(self._cache)))]
            self._writeback(victim)

    # -- crash ----------------------------------------------------------------
    def crash(self) -> None:
        """Full-system crash: every non-durable line is lost."""
        if self.tracer is not None:
            self.tracer.record("crash")
        self._flushed_since_fence = False
        if self.sim:
            self._cache.clear()
            self._scheduled.clear()

    def drain(self) -> None:
        """Clean shutdown: write back everything (implicit eventual WB)."""
        if self.tracer is not None:
            self.tracer.record("drain")
        self.n_drain += 1
        self._flushed_since_fence = False
        if self.sim:
            for line_id in list(self._cache.keys()):
                self._writeback(line_id)
            self._scheduled.clear()

    # -- atomics ---------------------------------------------------------------
    def cas(self, idx: int, expected: int, new: int) -> bool:
        """Single-word compare-and-swap (emulated hardware primitive)."""
        self.n_cas += 1
        with self._cas_lock:
            if self.read(idx) == int(np.int64(np.uint64(expected & ((1 << 64) - 1)))):
                self.write(idx, new)      # the store reaches the tracer here
                if self.tracer is not None:
                    self.tracer.record("cas", idx, new, info={"ok": True})
                return True
            if self.tracer is not None:
                self.tracer.record("cas", idx, info={"ok": False})
            return False

    def faa(self, idx: int, delta: int) -> int:
        """Fetch-and-add; returns the previous value."""
        with self._cas_lock:
            old = self.read(idx)
            self.write(idx, old + delta)
            return old

    def reset_counters(self) -> None:
        self.n_flush = self.n_fence = self.n_cas = self.n_drain = 0

    # -- semantic trace markers ------------------------------------------------
    def note(self, label: str, **info) -> None:
        """Forward a semantic marker (``record_seal``, ``lease_release``,
        ``tail_free``, ...) to the attached tracer; no-op untraced.  The
        ordering rules in ``analysis.persist_lint`` trigger on these."""
        if self.tracer is not None:
            self.tracer.record("note", label=label, info=info)
