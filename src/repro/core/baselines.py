"""Baseline allocators the paper compares against (§6.1).

  * ``MakaluLite`` — a lock-based persistent allocator in the style of
    Makalu [Bhandari et al., OOPSLA'16]: size-class free lists whose
    metadata is kept *persistently consistent online*, so every
    synchronized malloc/free logs and flushes multiple words (the paper
    attributes Makalu/PMDK's ~10× gap on Threadtest/Shbench to exactly
    this).  Like Makalu it keeps a thread cache, but returns only half
    of an over-full cache to the global pool (§6.3).
  * ``PMDKLite`` — a transactional malloc-to/free-from allocator in the
    style of PMDK's libpmemobj: every operation runs in a tiny undo-log
    transaction (log write + flush + fence, mutation + flush, commit +
    flush + fence) and atomically installs the block pointer at a
    caller-supplied persistent location.
  * ``LRMalloc`` mode — ``Ralloc(persist=False)``: the transient ancestor
    (no flush/fence at all), used as the transient upper bound together
    with the process allocator.

All baselines share the ``AllocAPI`` protocol so benchmarks and the
application tests can swap allocators freely.
"""

from __future__ import annotations

import math
import threading

from . import layout
from .heap import PersistentHeap
from .layout import (HeapConfig, LARGE_CLASS, SB_SIZE, SB_WORDS, WORD,
                     size_to_class, class_block_size)


class AllocAPI:
    """Minimal protocol: malloc/free/close + persistence counters."""
    name = "abstract"

    def malloc(self, size: int) -> int | None: ...
    def free(self, ptr: int) -> None: ...
    def close(self) -> None: ...

    def watermark_words(self) -> int:
        """Persistent bump/expansion watermark in heap words — the
        address space the allocator has consumed and can never reclaim
        without recovery.  Fragmentation benchmarks track its growth
        under steady-state churn."""
        raise NotImplementedError

    @property
    def counters(self) -> dict:
        m = self.mem
        return {"flush": m.n_flush, "fence": m.n_fence, "cas": m.n_cas}


# ---------------------------------------------------------------------------
# Makalu-like: lock-based, eagerly-persistent free-list metadata
# ---------------------------------------------------------------------------
class MakaluLite(AllocAPI):
    name = "makalu_lite"

    # metadata word offsets, relative to layout.M_END (we reuse the heap file
    # layout but manage our own persistent head table + log area)
    _HEADS = 0                       # NUM_CLASSES persistent list heads
    _USED = layout.NUM_CLASSES       # persistent bump watermark (words)
    _LOG = layout.NUM_CLASSES + 1    # 4-word persistent op log

    def __init__(self, path: str | None, size: int, *, tcache_cap: int = 64,
                 flush_ns: int = 0, fence_ns: int = 0, **_):
        cfg = HeapConfig(size=size, flush_ns=flush_ns, fence_ns=fence_ns)
        self.config = cfg
        self.heap = PersistentHeap(path, cfg)
        self.heap.init()
        self.mem = self.heap.mem
        self._lock = threading.Lock()
        self._meta = layout.M_ROOTS  # reuse root area for our heads/log
        self._tls = threading.local()
        self.tcache_cap = tcache_cap
        self._sizes: dict[int, int] = {}
        if self.mem.read(self._meta + self._USED) == 0:
            self.mem.persist(self._meta + self._USED, cfg.sb_base)

    def _cache(self) -> dict[int, list[int]]:
        c = getattr(self._tls, "c", None)
        if c is None:
            c = {}
            self._tls.c = c
        return c

    def _log(self, *words: int) -> None:
        """Write + flush + fence an op record (Makalu-style logging)."""
        base = self._meta + self._LOG
        for k, w in enumerate(words):
            self.mem.write(base + k, w)
            self.mem.flush(base + k)
        self.mem.fence()

    def malloc(self, size: int) -> int | None:
        cls = size_to_class(size)
        if cls == LARGE_CLASS:
            nwords = -(-size // WORD)
            with self._lock:
                return self._bump(nwords)
        cache = self._cache().setdefault(cls, [])
        if cache:
            p = cache.pop()
            self._sizes[p] = cls
            return p
        bw = class_block_size(cls) // WORD
        with self._lock:
            self._log(1, cls)                     # begin-alloc record
            refill = []
            head_w = self._meta + self._HEADS + cls
            for _ in range(max(1, self.tcache_cap // 2)):
                head = self.mem.read(head_w)
                if head == 0:
                    break
                nxt = self.mem.read(head)
                self.mem.write(head_w, nxt)
                self.mem.flush(head_w)            # persistent head update
                self.mem.fence()
                refill.append(head)
            while len(refill) < max(1, self.tcache_cap // 2):
                p = self._bump(bw)
                if p is None:
                    break
                refill.append(p)
            self._log(2, cls)                     # commit record
        if not refill:
            return None
        cache.extend(refill[:-1])
        self._sizes[refill[-1]] = cls
        return refill[-1]

    def _bump(self, nwords: int) -> int | None:
        uw = self._meta + self._USED
        used = self.mem.read(uw)
        if used + nwords > self.config.total_words:
            return None
        self.mem.write(uw, used + nwords)
        self.mem.flush(uw)
        self.mem.fence()
        return used

    def free(self, ptr: int) -> None:
        # size class is rediscovered from a per-block prefix in real Makalu;
        # we keep the caller-side convention of same-size pools per bench and
        # recover the class from the block's list linkage on reuse.  For the
        # benchmark API we accept (ptr) and look the class up from a side map
        # maintained at malloc time — cheaper and favourable to the baseline.
        cache = self._cache()
        cls = self._sizes.pop(ptr, 1)
        lst = cache.setdefault(cls, [])
        lst.append(ptr)
        if len(lst) > self.tcache_cap:
            give = lst[len(lst) // 2:]           # Makalu: return only half
            del lst[len(lst) // 2:]
            head_w = self._meta + self._HEADS + cls
            with self._lock:
                self._log(3, cls)
                for p in give:
                    head = self.mem.read(head_w)
                    self.mem.write(p, head)
                    self.mem.flush(p)             # persistent next pointer
                    self.mem.write(head_w, p)
                    self.mem.flush(head_w)
                    self.mem.fence()
                self._log(4, cls)

    def watermark_words(self) -> int:
        return int(self.mem.read(self._meta + self._USED)) - self.config.sb_base

    def close(self) -> None:
        self.heap.close()


# ---------------------------------------------------------------------------
# PMDK-like: transactional malloc-to / free-from
# ---------------------------------------------------------------------------
class PMDKLite(AllocAPI):
    name = "pmdk_lite"

    _HEADS = 0
    _USED = layout.NUM_CLASSES
    _LOG = layout.NUM_CLASSES + 1    # undo log: [state, dest, old, new]
    _SCRATCH = layout.NUM_CLASSES + 8  # dummy dests ("local variable" trick, §6.1)

    def __init__(self, path: str | None, size: int, *, flush_ns: int = 0,
                 fence_ns: int = 0, **_):
        cfg = HeapConfig(size=size, flush_ns=flush_ns, fence_ns=fence_ns)
        self.config = cfg
        self.heap = PersistentHeap(path, cfg)
        self.heap.init()
        self.mem = self.heap.mem
        self._lock = threading.Lock()
        self._meta = layout.M_ROOTS
        self._next_scratch = 0
        self._cls_of: dict[int, int] = {}
        if self.mem.read(self._meta + self._USED) == 0:
            self.mem.persist(self._meta + self._USED, cfg.sb_base)

    def _tx(self, dest: int, new: int) -> None:
        """Undo-log transaction installing ``new`` at ``dest``."""
        base = self._meta + self._LOG
        m = self.mem
        m.write(base + 1, dest)
        m.write(base + 2, m.read(dest))
        m.write(base + 3, new)
        for k in range(1, 4):
            m.flush(base + k)
        m.write(base, 1)                  # log valid
        m.flush(base)
        m.fence()
        m.write(dest, new)
        m.flush(dest)
        m.fence()
        m.write(base, 0)                  # commit
        m.flush(base)
        m.fence()

    def malloc_to(self, size: int, dest: int) -> int | None:
        cls = size_to_class(size)
        nwords = (-(-size // WORD) if cls == LARGE_CLASS
                  else class_block_size(cls) // WORD)
        with self._lock:
            head_w = self._meta + self._HEADS + cls
            ptr = self.mem.read(head_w) if cls != LARGE_CLASS else 0
            if ptr != 0:
                nxt = self.mem.read(ptr)
                self._tx(head_w, nxt)
            else:
                uw = self._meta + self._USED
                used = self.mem.read(uw)
                if used + nwords > self.config.total_words:
                    return None
                self._tx(uw, used + nwords)
                ptr = used
            self._tx(dest, ptr)
        return ptr

    def free_from(self, dest: int, cls_hint: int = 1) -> None:
        with self._lock:
            ptr = self.mem.read(dest)
            if ptr == 0:
                return
            head_w = self._meta + self._HEADS + cls_hint
            self._tx(ptr, self.mem.read(head_w))     # block.next = head
            self._tx(head_w, ptr)                    # head = block
            self._tx(dest, 0)                        # break the last pointer

    # malloc/free shims: paper §6.1 — "for PMDK's malloc-to/free-from
    # interface we had to create a local dummy variable to hold the pointer"
    def malloc(self, size: int) -> int | None:
        with self._lock:
            scratch = self._meta + self._SCRATCH + (self._next_scratch % 64)
            self._next_scratch += 1
        p = self.malloc_to(size, scratch)
        if p is not None:
            self._cls_of[p] = size_to_class(size)
        return p

    def free(self, ptr: int) -> None:
        cls = self._cls_of.pop(ptr, 1)
        with self._lock:
            scratch = self._meta + self._SCRATCH + (self._next_scratch % 64)
            self._next_scratch += 1
        self.mem.write(scratch, ptr)
        self.free_from(scratch, cls)

    def watermark_words(self) -> int:
        return int(self.mem.read(self._meta + self._USED)) - self.config.sb_base

    def close(self) -> None:
        self.heap.close()


# ---------------------------------------------------------------------------
# Factory used by benchmarks
# ---------------------------------------------------------------------------
def make_allocator(kind: str, path: str | None, size: int, **kw):
    from .ralloc import Ralloc

    if kind == "ralloc":
        return _RallocAdapter(Ralloc(path, size, persist=True, **kw))
    if kind == "lrmalloc":        # transient ancestor: no flush/fence
        return _RallocAdapter(Ralloc(path, size, persist=False, **kw),
                              name="lrmalloc")
    if kind == "makalu_lite":
        return MakaluLite(path, size, **kw)
    if kind == "pmdk_lite":
        return PMDKLite(path, size, **kw)
    raise ValueError(f"unknown allocator kind: {kind}")


class _RallocAdapter(AllocAPI):
    def __init__(self, r, name: str = "ralloc"):
        self.r = r
        self.name = name
        self.mem = r.mem

    def malloc(self, size: int) -> int | None:
        return self.r.malloc(size)

    def free(self, ptr: int) -> None:
        self.r.free(ptr)

    def span_acquire(self, ptr: int, n_sbs: int | None = None) -> int:
        """Span range leases (core.spans) — only ralloc/lrmalloc offer
        this; workloads feature-detect it and fall back to fresh spans.
        ``n_sbs`` leases just a prefix of the span (partial sharing)."""
        return self.r.span_acquire(ptr, n_sbs)

    def span_release(self, ptr: int, n_sbs: int | None = None) -> None:
        """Release a (prefix) lease; ranges nobody leases free."""
        self.r.span_release(ptr, n_sbs)

    def span_trim(self, ptr: int, n_keep: int,
                  n_held: int | None = None) -> int:
        """Shrink the caller's lease to ``n_keep`` superblocks; the
        unleased tail returns to the free set.  Re-trims must pass the
        currently-held length via ``n_held`` (see ``Ralloc.span_trim``)."""
        return self.r.span_trim(ptr, n_keep, n_held)

    def watermark_words(self) -> int:
        return int(self.r.mem.read(layout.M_USED_SBS)) * layout.SB_WORDS

    def close(self) -> None:
        self.r.close()
