"""Filter functions (paper §4.5.1).

A filter function enumerates the references contained in a block of a
given type, replacing conservative scanning during trace-based recovery.
The registry maps a *type name* to ``fn(heap_reader, block_word, size_bytes)
-> iterable[(target_word, child_typename | None)]`` where ``heap_reader``
exposes ``read_word``.  Child type names let typed tracing recurse
precisely (paper Fig. 3: ``visit<T>`` pushes ``filter<T>`` thunks).

Filter functions are re-registered on every execution (function pointers
are never persisted — paper: "reestablished in each execution, avoiding
any complications due to recompilation or ASLR").

The default ``conservative_filter`` implements Boehm–Weiser-style scanning
specialized by the pptr tag: every aligned word whose top bits match the
uncommon pattern is treated as a potential self-relative reference
(paper §4.6: the pattern "serves to reduce the likelihood that
frequently-occurring integer constants will be mistaken for off-holders").
"""

from __future__ import annotations

from typing import Callable, Iterable, Optional

from . import pptr as pp
from .layout import WORD

FilterFn = Callable[[object, int, int], Iterable[tuple[int, Optional[str]]]]


def conservative_filter(reader, block_word: int, size_bytes: int):
    """Scan every 64-bit-aligned word for tagged self-relative offsets."""
    nwords = max(1, size_bytes // WORD)
    for k in range(nwords):
        w = block_word + k
        v = reader.read_word(w)
        if pp.looks_like_pptr(v):
            tgt = pp.decode(w, v)
            if tgt is not None:
                yield tgt, None          # child type unknown → conservative


class FilterRegistry:
    def __init__(self):
        self._fns: dict[str, FilterFn] = {}

    def register(self, typename: str, fn: FilterFn) -> None:
        self._fns[typename] = fn

    def get(self, typename: str | None) -> FilterFn:
        if typename is None:
            return conservative_filter
        return self._fns.get(typename, conservative_filter)


# -- stock filters for the test/benchmark data structures --------------------

def stack_node_filter(reader, block_word, size_bytes):
    """Treiber-stack node: [next: pptr][value...]."""
    nxt = pp.decode(block_word, reader.read_word(block_word))
    if nxt is not None:
        yield nxt, "stack_node"


def tree_node_filter(reader, block_word, size_bytes):
    """BST node: [key][value][left: pptr][right: pptr] (paper Fig. 4)."""
    for slot in (2, 3):
        w = block_word + slot
        child = pp.decode(w, reader.read_word(w))
        if child is not None:
            yield child, "tree_node"


def prefix_index_filter(reader, block_word, size_bytes):
    """Durable prefix-index record (core.prefix_index):
    [next: pptr][span: pptr][seal: key48+checksum16][n_pages][lease_sbs].

    Word 0 chains to the next record (typed recursion); word 1 is the
    record's reference to the published span head — the mark pass counts
    it exactly like a root, which is how the prefix cache's lease
    survives a crash.  Words 2–4 are plain integers (the seal checksum
    is remapped away from the pptr tag), so the typed filter and a
    conservative scan mark the identical live set.

    A record whose seal checksum does not match its fields is torn: its
    span reference is *not* yielded (belt — ``prune_torn_records`` has
    already durably unlinked it before the mark pass, suspenders), so a
    torn record can never re-publish a span.  Its next pointer is still
    followed: valid records behind it must stay reachable.
    """
    from .prefix_index import record_seal_matches
    nxt = pp.decode(block_word, reader.read_word(block_word))
    if nxt is not None:
        yield nxt, "prefix_index"
    if not record_seal_matches(reader, block_word):
        return
    span = pp.decode(block_word + 1, reader.read_word(block_word + 1))
    if span is not None:
        yield span, None          # span head: traced conservatively


def prefix_trie_filter(reader, block_word, size_bytes):
    """Durable prefix-trie node record (core.prefix_trie):
    [next: pptr][parent: pptr][seal: key48+checksum16][span: pptr]
    [end_page][start_page][lease_sbs][fingerprint].

    Word 0 chains to the next record and word 1 to the parent node —
    both recurse typed (the parent is also on the chain; yielding it
    only keeps the mark precise, it adds nothing live).  Word 3 is the
    node's reference to its span head: the mark pass counts it like a
    root, which is how each node's prefix lease survives a crash —
    several records may reference the same span (split halves), and the
    reconstruction counts one full-extent lease per record, which
    ``prune_torn_nodes`` + ``retrim_after_recovery`` then shrink back.
    Words 4–7 are plain integers (the fingerprint keeps its top 16 bits
    zero), so the typed filter and a conservative scan mark the
    identical live set.

    Same belt-and-suspenders as the flat index: a torn record's span
    reference is not yielded, its next (and parent) still are.
    """
    from .prefix_trie import record_seal_matches
    nxt = pp.decode(block_word, reader.read_word(block_word))
    if nxt is not None:
        yield nxt, "prefix_trie"
    parent = pp.decode(block_word + 1, reader.read_word(block_word + 1))
    if parent is not None:
        yield parent, "prefix_trie"
    if not record_seal_matches(reader, block_word):
        return
    span = pp.decode(block_word + 3, reader.read_word(block_word + 3))
    if span is not None:
        yield span, None          # span head: traced conservatively


def register_stock_filters(reg: FilterRegistry) -> None:
    reg.register("stack_node", stack_node_filter)
    reg.register("tree_node", tree_node_filter)
    reg.register("prefix_index", prefix_index_filter)
    reg.register("prefix_trie", prefix_trie_filter)
