"""Persistent heap segments (paper §2.1, §4.1–4.3).

A heap is a single file (standing in for a DAX segment) laid out as
``[metadata][descriptor region][superblock region]`` and mapped via
``numpy.memmap`` — i.e. loads/stores, no read()/write() syscalls, exactly
the DAX programming model.  Physical pages are consumed on first touch
(sparse file), matching the paper's observation that a segment can be
sized generously without committing memory.

``init()`` implements the fresh / clean-restart / dirty-restart
tri-state of paper Fig. 1: it returns True iff recovery is needed.  The
dirty indicator is a persisted word (the paper uses a robust pthread
mutex; a flag word + ordered stores is the moral equivalent for a
single-manager segment and is what we can express portably).
"""

from __future__ import annotations

import os

import numpy as np

from . import layout
from .. import obs
from ..analysis.faults import is_suppressed
from .atomics import CACHELINE_WORDS, NVMArray
from .layout import HeapConfig, MAGIC


class PersistentHeap:
    """mmap-backed three-region heap with a dirty-flag recovery protocol."""

    def __init__(self, path: str | None, config: HeapConfig,
                 backing: np.ndarray | None = None):
        """``backing`` overrides the storage array — crash-injection tests
        reopen a captured durable image in place of a file/fresh buffer."""
        self.path = path
        self.config = config
        self.existed = path is not None and os.path.exists(path)
        if backing is not None:
            assert path is None, "backing replaces file storage, not both"
            assert backing.dtype == np.int64
            assert backing.size >= config.total_words
        elif path is None:
            backing = np.zeros(config.total_words, dtype=np.int64)
        else:
            mode = "r+" if self.existed else "w+"
            backing = np.memmap(path, dtype=np.int64, mode=mode,
                                shape=(config.total_words,))
        self.mem = NVMArray(config.total_words, sim=config.sim_nvm,
                            seed=config.seed, backing=backing,
                            flush_ns=config.flush_ns, fence_ns=config.fence_ns)
        # Unify the persistence-cost counters behind the obs registry:
        # the newest heap owns the ``heap.*`` names, reads come straight
        # off the live NVMArray at snapshot time, and resets route
        # through ``obs.reset`` (which raises on a name no heap
        # registered — no more silent ``a.mem.reset_counters()`` skews).
        for attr, name in (("n_flush", "heap.flush"),
                           ("n_fence", "heap.fence"),
                           ("n_cas", "heap.cas"),
                           ("n_drain", "heap.drain")):
            obs.register_source(
                name,
                read=(lambda m=self.mem, a=attr: getattr(m, a)),
                reset=(lambda m=self.mem, a=attr: setattr(m, a, 0)))

    # ------------------------------------------------------------------ init
    def init(self) -> bool:
        """Create or remap the heap; True iff a dirty restart (recovery needed)."""
        m = self.mem
        fresh = m.read(layout.M_MAGIC) != MAGIC
        dirty = (not fresh) and m.read(layout.M_DIRTY) != 0
        if fresh:
            m.write(layout.M_MAGIC, MAGIC)
            m.write(layout.M_SB_REGION_WORDS, self.config.sb_region_words)
            m.write(layout.M_USED_SBS, 0)
            for i in range(layout.MAX_ROOTS):
                m.write(layout.M_ROOTS + i, 0)
            seen_lines = set()
            for w in (layout.M_MAGIC, layout.M_SB_REGION_WORDS,
                      layout.M_USED_SBS, layout.M_ROOTS):
                if w // CACHELINE_WORDS not in seen_lines:
                    seen_lines.add(w // CACHELINE_WORDS)
                    m.flush(w)
            m.fence()
        if fresh:
            # Transient list heads start empty on a fresh heap.  On a *clean*
            # restart they were implicitly written back at close() and are
            # reused as-is (paper: "allowing quick restart after a clean
            # shutdown"); on a *dirty* restart recovery rebuilds them.
            m.write(layout.M_FREE_HEAD, layout.pack_head(-1, 0))
            for c in range(layout.NUM_CLASSES):
                m.write(layout.M_PARTIAL_HEADS + c, layout.pack_head(-1, 0))
        # mark dirty until close() (any crash from here on needs recovery)
        m.persist(layout.M_DIRTY, 1)
        return dirty

    def close(self) -> None:
        """Clean shutdown: write everything back, clear the dirty flag."""
        self.mem.drain()
        self.mem.persist(layout.M_DIRTY, 0)
        self.mem.drain()
        if isinstance(self.mem.nvm, np.memmap):
            self.mem.nvm.flush()

    def crash(self) -> None:
        """Simulated full-system crash (drops non-durable lines)."""
        self.mem.crash()

    # ------------------------------------------------------------- addressing
    def desc_word(self, sb_idx: int, field: int) -> int:
        return self.config.desc_base + sb_idx * layout.DESC_WORDS + field

    def sb_word(self, sb_idx: int) -> int:
        return self.config.sb_base + sb_idx * layout.SB_WORDS

    def sb_of(self, block_word: int) -> int:
        """Descriptor index for a block address — pure bit manipulation."""
        return (block_word - self.config.sb_base) // layout.SB_WORDS

    def in_sb_region(self, word: int) -> bool:
        used = self.mem.read(layout.M_USED_SBS)
        return (self.config.sb_base <= word
                < self.config.sb_base + used * layout.SB_WORDS)

    # ----------------------------------------------------------------- roots
    def set_root(self, i: int, block_word: int | None) -> None:
        """Persist root ``i`` (region-based offset into the superblock region)."""
        assert 0 <= i < layout.MAX_ROOTS
        off = 0 if block_word is None else (block_word - self.config.sb_base + 1)
        self.mem.write(layout.M_ROOTS + i, off)
        if not is_suppressed("heap.set_root.persist"):
            self.mem.flush(layout.M_ROOTS + i)
            self.mem.fence()

    def set_roots(self, pairs) -> None:
        """Batched root swing: write and flush every ``(i, block_word)``
        pair, then ONE fence — the group-commit form of ``set_root``
        (NVTraverse: only the destination writes need ordering, and they
        can share it).  Atomicity is per slot: a crash mid-batch lands a
        prefix of the swings, each individually consistent."""
        for i, block_word in pairs:
            assert 0 <= i < layout.MAX_ROOTS
            off = (0 if block_word is None
                   else block_word - self.config.sb_base + 1)
            self.mem.write(layout.M_ROOTS + i, off)
        if not is_suppressed("heap.set_root.persist"):
            # one clwb per dirty *line*, not per slot — adjacent root
            # slots share cache lines and a second flush of an already
            # scheduled line is pure waste (persist-lint: redundant)
            seen_lines = set()
            for i, _ in pairs:
                w = layout.M_ROOTS + i
                if w // CACHELINE_WORDS not in seen_lines:
                    seen_lines.add(w // CACHELINE_WORDS)
                    self.mem.flush(w)
            self.mem.fence()

    def get_root(self, i: int) -> int | None:
        off = self.mem.read(layout.M_ROOTS + i)
        return None if off == 0 else self.config.sb_base + off - 1
