"""Ralloc-JAX: the paper's allocator vectorized for TPU execution.

This is the TPU-native adaptation of Ralloc (DESIGN.md §2).  It manages a
*virtual arena* of blocks — consumers (the paged KV cache, checkpoint
shard store, page-table nodes) index their own device arrays with the
offsets this allocator hands out, so all references are position
independent by construction (pure offsets; the arena can be remapped or
resharded without rewriting a single reference).

Mapping from the paper:

  * superblocks with a single size class; descriptors become
    structure-of-arrays ``sb_class`` / ``sb_free_count`` / ``free_bitmap``
    (bitmaps replace the in-block linked free lists: pointer chasing is
    hostile to the VPU, popcount/cumsum sweeps are native);
  * thread-local caches become one *rank-indexed block cache* per size
    class: a whole vector of lanes (decode streams) pops from the cache
    at distinct ranks computed by a cumsum — mutual exclusion by rank
    instead of by CAS, still synchronization-free;
  * the Treiber free/partial stacks become index stacks updated inside
    ``jit``; the "retire on fetch" rule for PARTIAL→EMPTY superblocks is
    preserved verbatim;
  * the persistent/transient split is preserved exactly: only
    ``sb_class``/``sb_block_words``/``used_sbs``/``roots``/``dirty`` need
    durability; everything else is rebuilt by ``jax_recovery``.

All operations are pure functions ``(state, …) -> (state, …)`` and are
jit/vmap/scan-compatible; ``size_class`` arguments are static.

Large objects (paper §4.4's ``LARGE_CLASS`` path, ported to the device
arena): a request bigger than one superblock takes a *contiguous* run of
superblocks straight off the watermark — the head superblock is tagged
``LARGE_CLS`` in ``sb_class`` with the object's total word count in
``sb_block_words`` (both persistent, mirroring the host's
``D_SIZE_CLASS``/``D_BLOCK_SIZE``), and every continuation superblock is
tagged ``LARGE_CONT``.  Spans carry per-superblock *range leases*
(``span_refs``, transient): ``free_large``/``trim_large`` decrement a
range and reset the class records of exactly the superblocks nobody
leases any more — the whole remaining span at the head's last release,
or a zero-count tail suffix (with the head's size record shrunk to
match) — before returning them to the free stack, so recovery can never
observe an orphaned continuation marker.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

NULL = jnp.int32(-1)

# ``sb_class`` sentinels.  -1 = uninitialized/free (as before); the large
# markers sit below it so every small class keeps its index >= 0.
FREE_CLS = -1
LARGE_CLS = -2        # head superblock of a multi-superblock object
LARGE_CONT = -3       # continuation superblock of a large span

# Empty-bucket sentinel of the free-run index (``run_bucket_min``).
RUN_INF = 2**31 - 1


@dataclasses.dataclass(frozen=True)
class ArenaConfig:
    """Static geometry of one device arena."""
    num_sbs: int                       # superblocks in the arena
    sb_words: int                      # words per superblock
    class_words: tuple[int, ...]       # block size (words) per size class
    cache_cap: int = 1024              # rank-indexed block cache capacity
    expand_sbs: int = 8                # watermark expansion increment
    run_buckets: int = 16              # free-run index size buckets

    @property
    def num_classes(self) -> int:
        return len(self.class_words)

    def blocks_per_sb(self, cls: int) -> int:
        return self.sb_words // self.class_words[cls]

    @property
    def max_blocks(self) -> int:
        return max(self.blocks_per_sb(c) for c in range(self.num_classes))

    @property
    def total_words(self) -> int:
        return self.num_sbs * self.sb_words


class AllocState(NamedTuple):
    """Allocator state pytree.  P = persistent fields, T = transient."""
    sb_class: jax.Array        # P i32[num_sbs]  (-1 = uninitialized)
    sb_block_words: jax.Array  # P i32[num_sbs]
    used_sbs: jax.Array        # P i32[]         watermark
    roots: jax.Array           # P i32[max_roots] block offsets, -1 = null
    dirty: jax.Array           # P i32[]
    free_bitmap: jax.Array     # T bool[num_sbs, max_blocks] True = free
    sb_free_count: jax.Array   # T i32[num_sbs]
    free_stack: jax.Array      # T i32[num_sbs + 1] (+1 dump slot)
    free_top: jax.Array        # T i32[]
    partial_stack: jax.Array   # T i32[num_classes, num_sbs + 1]
    partial_top: jax.Array     # T i32[num_classes]
    block_cache: jax.Array     # T i32[num_classes, cache_cap + 1] (+dump slot)
    cache_top: jax.Array       # T i32[num_classes]
    alloc_count: jax.Array     # T i32[]  (statistics)
    free_count: jax.Array      # T i32[]
    span_refs: jax.Array       # T i32[num_sbs] per-superblock lease count
    #                            over every LARGE_CLS span (transient —
    #                            GC-reconstructed from the number of
    #                            root-reachable references to the head,
    #                            broadcast over the span's persisted
    #                            extent; mirror of core.spans
    #                            RangeLeaseTable)
    run_len: jax.Array         # T i32[num_sbs] free-run length at each
    #                            run *start*, 0 elsewhere
    run_start: jax.Array       # T i32[num_sbs] per free superblock, the
    #                            start of its maximal run; -1 if not free
    run_bucket_min: jax.Array  # T i32[run_buckets] leftmost run start
    #                            per length bucket (exact lengths
    #                            1..B-1, overflow bucket B-1 for >= B);
    #                            RUN_INF = empty.  The device mirror of
    #                            the host core.spans.FreeRunIndex — all
    #                            three arrays are transient, rebuilt by
    #                            jax_recovery.sweep, never persisted.


def init_state(cfg: ArenaConfig, max_roots: int = 64) -> AllocState:
    n, c = cfg.num_sbs, cfg.num_classes
    return AllocState(
        sb_class=jnp.full((n,), -1, jnp.int32),
        sb_block_words=jnp.zeros((n,), jnp.int32),
        used_sbs=jnp.int32(0),
        roots=jnp.full((max_roots,), -1, jnp.int32),
        dirty=jnp.int32(1),
        free_bitmap=jnp.zeros((n, cfg.max_blocks), bool),
        sb_free_count=jnp.zeros((n,), jnp.int32),
        free_stack=jnp.full((n + 1,), -1, jnp.int32),
        free_top=jnp.int32(0),
        partial_stack=jnp.full((c, n + 1), -1, jnp.int32),
        partial_top=jnp.zeros((c,), jnp.int32),
        block_cache=jnp.full((c, cfg.cache_cap + 1), -1, jnp.int32),
        cache_top=jnp.zeros((c,), jnp.int32),
        alloc_count=jnp.int32(0),
        free_count=jnp.int32(0),
        span_refs=jnp.zeros((n,), jnp.int32),
        run_len=jnp.zeros((n,), jnp.int32),
        run_start=jnp.full((n,), -1, jnp.int32),
        run_bucket_min=jnp.full((cfg.run_buckets,), RUN_INF, jnp.int32),
    )


# ---------------------------------------------------------------------------
# free-run index
# ---------------------------------------------------------------------------
# The device mirror of the host ``core.spans.FreeRunIndex``: best-fit
# large-object placement reads O(run_buckets) bucket heads instead of
# running an O(num_sbs)-lane suffix-min scan per call.  The index is a
# pure function of the persistent fields — free ⟺ ``sb_class == FREE_CLS``
# below the watermark — so it is transient by construction (NVTraverse:
# only the destination write needs durability) and ``jax_recovery.sweep``
# rebuilds it with ``free_run_table`` after a crash.  Normal operation
# maintains ``run_len``/``run_start`` incrementally (elementwise range
# updates, no scan); the bucket heads are re-derived from ``run_len`` in
# one fused scatter-min pass per free-set transition.


def free_run_table(free_mask, num_sbs: int):
    """Canonical run scan: ``(run_len, run_start)`` from a free mask.

    One suffix-min ``associative_scan`` finds the first non-free index at
    or after every lane; a cummax propagates run-start ids to members.
    This is the single source of truth for "maximal contiguous free
    runs" on the device — the from-scratch recompute that recovery uses
    and that the incremental index is property-tested against.
    """
    free_mask = free_mask.astype(bool)
    ids = jnp.arange(num_sbs, dtype=jnp.int32)
    nonfree_at = jnp.where(free_mask, jnp.int32(num_sbs), ids)
    next_nonfree = lax.associative_scan(jnp.minimum, nonfree_at,
                                        reverse=True)
    prev_free = jnp.concatenate([jnp.zeros((1,), bool), free_mask[:-1]])
    is_start = free_mask & ~prev_free
    start_at = lax.associative_scan(
        jnp.maximum, jnp.where(is_start, ids, jnp.int32(-1)))
    run_len = jnp.where(is_start, next_nonfree - ids, 0)
    run_start = jnp.where(free_mask, start_at, jnp.int32(-1))
    return run_len, run_start


def _bucket_mins(cfg: ArenaConfig, run_len):
    """Leftmost run start per length bucket, in one scatter-min pass."""
    ids = jnp.arange(cfg.num_sbs, dtype=jnp.int32)
    is_start = run_len > 0
    b = jnp.where(is_start, jnp.minimum(run_len, cfg.run_buckets) - 1,
                  jnp.int32(cfg.run_buckets))
    mins = jnp.full((cfg.run_buckets + 1,), RUN_INF, jnp.int32)
    mins = mins.at[b].min(jnp.where(is_start, ids, RUN_INF))
    return mins[:cfg.run_buckets]


def _runs_add_range(cfg: ArenaConfig, rl, rs, a, b, enable):
    """Run-table update: contiguous ``[a, b)`` joins the free set.

    Merges with the run ending at ``a-1`` and the run starting at ``b``
    (both optional).  ``enable`` false (or an empty range) is a no-op —
    callers pass their op's validity mask.
    """
    n = cfg.num_sbs
    ids = jnp.arange(n, dtype=jnp.int32)
    a = jnp.asarray(a, jnp.int32)
    b = jnp.asarray(b, jnp.int32)
    left = jnp.where(a > 0, rs[jnp.clip(a - 1, 0, n - 1)], jnp.int32(-1))
    start = jnp.where(left >= 0, left, a)
    right_len = jnp.where(b < n, rl[jnp.clip(b, 0, n - 1)], jnp.int32(0))
    end = b + right_len
    member = (ids >= start) & (ids < end)
    rl2 = jnp.where(ids == start, end - start, jnp.where(member, 0, rl))
    rs2 = jnp.where(member, start, rs)
    enable = enable & (b > a)
    return jnp.where(enable, rl2, rl), jnp.where(enable, rs2, rs)


def _runs_remove_range(cfg: ArenaConfig, rl, rs, a, b, enable):
    """Run-table update: ``[a, b)`` leaves the free set.

    Precondition: the range lies inside one maximal run (always true for
    the two callers — a best-fit claim starts at a run start, a stack
    pop is a single member).  The run splits into left ``[start, a)``
    and right ``[b, end)`` remainders, either possibly empty.
    """
    n = cfg.num_sbs
    ids = jnp.arange(n, dtype=jnp.int32)
    a = jnp.asarray(a, jnp.int32)
    b = jnp.asarray(b, jnp.int32)
    start = jnp.maximum(rs[jnp.clip(a, 0, n - 1)], 0)
    end = start + rl[jnp.clip(start, 0, n - 1)]
    member = (ids >= start) & (ids < end)
    rl2 = jnp.where(ids == start, a - start,
                    jnp.where(ids == b, end - b,
                              jnp.where(member, 0, rl)))
    rs2 = jnp.where((ids >= a) & (ids < b), -1,
                    jnp.where((ids >= b) & (ids < end), b, rs))
    enable = enable & (b > a)
    return jnp.where(enable, rl2, rl), jnp.where(enable, rs2, rs)


def _with_runs(st: "AllocState", cfg: ArenaConfig, rl, rs) -> "AllocState":
    """Install updated run tables and refresh the bucket heads."""
    return st._replace(run_len=rl, run_start=rs,
                       run_bucket_min=_bucket_mins(cfg, rl))


def rebuild_run_index(state: "AllocState", cfg: ArenaConfig) -> "AllocState":
    """From-scratch index rebuild off the persistent class records.

    Used by ``jax_recovery.sweep`` and by the rare bulk free-set
    transition (a cache spill retiring FULL→EMPTY superblocks), where
    incremental maintenance would have to splice an arbitrary scatter of
    singletons.
    """
    ids = jnp.arange(cfg.num_sbs, dtype=jnp.int32)
    free_sb = (state.sb_class == FREE_CLS) & (ids < state.used_sbs)
    rl, rs = free_run_table(free_sb, cfg.num_sbs)
    return _with_runs(state, cfg, rl, rs)


def scan_best_fit(state: "AllocState", cfg: ArenaConfig, nsb):
    """Test oracle: the original full-scan best-fit placement.

    Returns ``(has_run, best_len, best_first)`` — smallest free run that
    fits ``nsb`` superblocks, leftmost on ties.  ``alloc_large`` must
    place identically through the bucket index; the differential and
    property suites assert exactly that.
    """
    ids = jnp.arange(cfg.num_sbs, dtype=jnp.int32)
    free_sb = (state.sb_class == FREE_CLS) & (ids < state.used_sbs)
    run_len, _ = free_run_table(free_sb, cfg.num_sbs)
    cand = (run_len > 0) & (run_len >= nsb)
    has_run = cand.any()
    best_len = jnp.min(jnp.where(cand, run_len, jnp.int32(cfg.num_sbs + 1)))
    best_first = jnp.argmax(cand & (run_len == best_len)).astype(jnp.int32)
    return has_run, best_len, best_first


# ---------------------------------------------------------------------------
# internal helpers
# ---------------------------------------------------------------------------
def _push_many(stack, top, ids, mask):
    """Vectorized multi-push: stack[top + rank(i)] = ids[i] for masked i."""
    ranks = jnp.cumsum(mask.astype(jnp.int32)) - 1
    dump = stack.shape[-1] - 1                      # reserved dump slot
    idx = jnp.where(mask, top + ranks, dump)
    stack = stack.at[idx].set(jnp.where(mask, ids, stack[dump]))
    # restore the dump slot (may have been scribbled)
    stack = stack.at[dump].set(-1)
    return stack, top + mask.sum(dtype=jnp.int32)


def _expand(st: AllocState, cfg: ArenaConfig):
    """Advance the used watermark; push new superblocks onto the free stack.

    The watermark is a persistent field — in the paper it is CAS'd then
    flushed+fenced before any new block escapes; here the state update is
    atomic by construction (one program step) and the persistence boundary
    is the host mirror (see ``persist_snapshot``).
    """
    k = jnp.minimum(jnp.int32(cfg.expand_sbs), cfg.num_sbs - st.used_sbs)
    ids = st.used_sbs + jnp.arange(cfg.num_sbs, dtype=jnp.int32)
    mask = jnp.arange(cfg.num_sbs) < k
    fs, ft = _push_many(st.free_stack, st.free_top,
                        jnp.where(mask, ids, -1), mask)
    rl, rs = _runs_add_range(cfg, st.run_len, st.run_start,
                             st.used_sbs, st.used_sbs + k, k > 0)
    st = _with_runs(st, cfg, rl, rs)
    return st._replace(free_stack=fs, free_top=ft,
                       used_sbs=st.used_sbs + k), k > 0


def _harvest(st: AllocState, cfg: ArenaConfig, cls: int, sb):
    """Move up to (cache capacity − top) free blocks of ``sb`` into the cache.

    Mirrors LRMalloc's "reserve all available blocks with one anchor CAS";
    if the cache cannot hold the whole superblock, the remainder stays and
    the superblock returns to the partial stack.
    """
    bw = cfg.class_words[cls]
    total = cfg.blocks_per_sb(cls)
    room = jnp.int32(cfg.cache_cap) - st.cache_top[cls]
    bits = st.free_bitmap[sb] & (jnp.arange(cfg.max_blocks) < total)
    order = jnp.cumsum(bits.astype(jnp.int32))        # 1-based among set bits
    sel = bits & (order <= room)
    t = sel.sum(dtype=jnp.int32)
    # push selected block offsets into the cache at distinct ranks;
    # non-selected writes land in the dedicated dump slot (index cap)
    offs = sb * cfg.sb_words + jnp.arange(cfg.max_blocks, dtype=jnp.int32) * bw
    cache_row = st.block_cache[cls]
    idx = jnp.where(sel, st.cache_top[cls] + order - 1, cfg.cache_cap)
    cache_row = cache_row.at[idx].set(jnp.where(sel, offs, -1))
    bitmap = st.free_bitmap.at[sb].set(st.free_bitmap[sb] & ~sel)
    count = st.sb_free_count[sb] - t
    st = st._replace(
        block_cache=st.block_cache.at[cls].set(cache_row),
        cache_top=st.cache_top.at[cls].add(t),
        free_bitmap=bitmap,
        sb_free_count=st.sb_free_count.at[sb].set(count),
    )
    # leftover free blocks → superblock goes back to the partial stack
    def back_to_partial(s):
        ps, pt = _push_many(
            s.partial_stack[cls], s.partial_top[cls],
            jnp.full((cfg.num_sbs,), sb, jnp.int32),
            jnp.arange(cfg.num_sbs) < 1)
        return s._replace(partial_stack=s.partial_stack.at[cls].set(ps),
                          partial_top=s.partial_top.at[cls].set(pt))
    return lax.cond(count > 0, back_to_partial, lambda s: s, st)


def _refill_step(st: AllocState, cfg: ArenaConfig, cls: int):
    """One slow-path refill attempt: partial → free → expand (paper §4.4)."""
    total = cfg.blocks_per_sb(cls)

    def from_partial(st):
        top = st.partial_top[cls]
        sb = st.partial_stack[cls, top - 1]
        st = st._replace(partial_top=st.partial_top.at[cls].add(-1))
        count = st.sb_free_count[sb]
        # retire-on-fetch: a PARTIAL→EMPTY superblock goes to the free stack
        def retire(s):
            fs, ft = _push_many(s.free_stack, s.free_top,
                                jnp.full((cfg.num_sbs,), sb, jnp.int32),
                                jnp.arange(cfg.num_sbs) < 1)
            rl, rs = _runs_add_range(cfg, s.run_len, s.run_start,
                                     sb, sb + 1, jnp.bool_(True))
            s = _with_runs(s, cfg, rl, rs)
            return s._replace(free_stack=fs, free_top=ft,
                              sb_class=s.sb_class.at[sb].set(-1))
        return lax.cond(count >= total, retire,
                        lambda s: _harvest(s, cfg, cls, sb), st), True

    def from_free(st):
        sb = st.free_stack[st.free_top - 1]
        st = st._replace(free_top=st.free_top - 1)
        rl, rs = _runs_remove_range(cfg, st.run_len, st.run_start,
                                    sb, sb + 1, jnp.bool_(True))
        st = _with_runs(st, cfg, rl, rs)
        bw = cfg.class_words[cls]
        # (re)initialize the superblock for this class — the persistent
        # fields (class, block size) change here and only here
        st = st._replace(
            sb_class=st.sb_class.at[sb].set(cls),
            sb_block_words=st.sb_block_words.at[sb].set(bw),
            free_bitmap=st.free_bitmap.at[sb].set(
                jnp.arange(cfg.max_blocks) < total),
            sb_free_count=st.sb_free_count.at[sb].set(total),
        )
        return _harvest(st, cfg, cls, sb), True

    def from_expand(st):
        st, ok = _expand(st, cfg)
        return st, ok

    has_partial = st.partial_top[cls] > 0
    has_free = st.free_top > 0
    branch = jnp.where(has_partial, 0, jnp.where(has_free, 1, 2))
    return lax.switch(branch, [
        lambda s: from_partial(s),
        lambda s: from_free(s),
        lambda s: from_expand(s),
    ], st)


def alloc(state: AllocState, cfg: ArenaConfig, cls: int, need):
    """Vectorized allocation: one block per lane where ``need`` is set.

    Returns (state, offsets i32[L]) with -1 for unserved lanes (either
    ``need`` false or out of memory).  The fast path (cache hit for every
    lane) touches only the cache row and its top — the vector analogue of
    the paper's synchronization-free thread-cache hit.
    """
    need = need.astype(bool)
    m = need.sum(dtype=jnp.int32)

    def cond(carry):
        st, progress = carry
        return (st.cache_top[cls] < m) & progress

    def body(carry):
        st, _ = carry
        st, ok = _refill_step(st, cfg, cls)
        return st, ok

    state, _ = lax.while_loop(cond, body, (state, jnp.bool_(True)))
    top = state.cache_top[cls]
    avail = jnp.minimum(top, m)
    ranks = jnp.cumsum(need.astype(jnp.int32)) - 1
    served = need & (ranks < avail)
    pos = jnp.maximum(top - 1 - ranks, 0)
    offs = jnp.where(served, state.block_cache[cls, pos], -1)
    state = state._replace(
        cache_top=state.cache_top.at[cls].add(-avail),
        alloc_count=state.alloc_count + avail)
    return state, offs


def _spill(st: AllocState, cfg: ArenaConfig, cls: int):
    """Flush the whole class cache back to superblock bitmaps (paper §4.4:
    an over-full cache is transferred "in its entirety")."""
    bw = cfg.class_words[cls]
    total = cfg.blocks_per_sb(cls)
    cap = cfg.cache_cap + 1                        # row includes the dump slot
    row = st.block_cache[cls]
    live = jnp.arange(cap) < st.cache_top[cls]
    sb = jnp.where(live, row // cfg.sb_words, cfg.num_sbs)   # dump row
    blk = jnp.where(live, (row % cfg.sb_words) // bw, 0)
    old_count = st.sb_free_count
    bitmap = jnp.pad(st.free_bitmap, ((0, 1), (0, 0)))
    bitmap = bitmap.at[sb, blk].set(True)
    delta = jnp.zeros((cfg.num_sbs + 1,), jnp.int32).at[sb].add(1)
    new_count = old_count + delta[:-1]
    st = st._replace(free_bitmap=bitmap[:-1],
                     sb_free_count=new_count,
                     cache_top=st.cache_top.at[cls].set(0))
    touched = delta[:-1] > 0
    was_full = touched & (old_count == 0) & (st.sb_class == cls)
    to_free = was_full & (new_count >= total)
    to_partial = was_full & (new_count < total)
    ids = jnp.arange(cfg.num_sbs, dtype=jnp.int32)
    ps, pt = _push_many(st.partial_stack[cls], st.partial_top[cls],
                        ids, to_partial)
    fs, ft = _push_many(st.free_stack, st.free_top, ids, to_free)
    # FULL→EMPTY superblocks retire immediately (class reset)
    sb_class = jnp.where(to_free, -1, st.sb_class)
    st = st._replace(partial_stack=st.partial_stack.at[cls].set(ps),
                     partial_top=st.partial_top.at[cls].set(pt),
                     free_stack=fs, free_top=ft, sb_class=sb_class)
    # retired superblocks are an arbitrary scatter — rebuild the run index
    return lax.cond(to_free.any(),
                    lambda s: rebuild_run_index(s, cfg), lambda s: s, st)


def free(state: AllocState, cfg: ArenaConfig, cls: int, offs, mask):
    """Vectorized deallocation of one block per masked lane.

    Lanes whose superblock is not currently initialized for ``cls`` are
    rejected (masked out) rather than pushed into the class cache — the
    vector analogue of the host-side rule that ``free`` must never index
    a thread cache with a large-span sentinel (double-free of a large
    object, or a small free aimed into a large span, is a no-op here).
    """
    mask = mask.astype(bool) & (offs >= 0)
    sb = jnp.clip(offs // cfg.sb_words, 0, cfg.num_sbs - 1)
    mask = mask & (state.sb_class[sb] == cls)
    k = mask.sum(dtype=jnp.int32)
    state = lax.cond(state.cache_top[cls] + k > cfg.cache_cap,
                     lambda s: _spill(s, cfg, cls), lambda s: s, state)
    ranks = jnp.cumsum(mask.astype(jnp.int32)) - 1
    idx = jnp.where(mask, state.cache_top[cls] + ranks, cfg.cache_cap)
    row = state.block_cache[cls]
    row = row.at[idx].set(jnp.where(mask, offs, -1))
    return state._replace(
        block_cache=state.block_cache.at[cls].set(row),
        cache_top=state.cache_top.at[cls].add(k),
        free_count=state.free_count + k)


def span_sbs(cfg: ArenaConfig, nwords):
    """Superblocks needed for a large object of ``nwords`` words."""
    return (nwords + cfg.sb_words - 1) // cfg.sb_words


def alloc_large(state: AllocState, cfg: ArenaConfig, nwords):
    """Contiguous multi-superblock allocation (paper §4.4 large path).

    Placement is a *best-fit* search over freed contiguous runs: the
    smallest run ≥ the request wins, leftmost on ties — the identical
    rule the host allocator applies in ``Ralloc._claim_free_run``, so
    host and device place spans identically given identical free sets.
    The search reads the transient free-run index instead of scanning:
    exact length buckets resolve in O(run_buckets) (the smallest
    eligible non-empty bucket is the best fit — every overflow run is
    longer), and only an overflow-bucket hit or an oversized request
    falls back to one masked min-reduction over the maintained
    ``run_len`` table (a single fused pass; the old suffix-min
    ``associative_scan`` survives solely as the ``scan_best_fit`` test
    oracle).  Only when no run fits does the span fall back to
    expanding the watermark.  Without the free-run search, every span
    would consume fresh watermark forever and alloc/free cycles of
    large objects would deterministically exhaust the arena even when
    it is entirely free.  Returns (state, off) where ``off`` is the
    word offset of the span start, or -1 when neither placement fits.
    jit-compatible; ``nwords`` may be a traced scalar.
    """
    nwords = jnp.asarray(nwords, jnp.int32)
    nsb = span_sbs(cfg, nwords)
    ids = jnp.arange(cfg.num_sbs, dtype=jnp.int32)
    nfit = jnp.int32(cfg.num_sbs + 1)

    # O(buckets) placement: exact buckets hold lengths 1..B-1, so the
    # smallest eligible non-empty one *is* the best fit and its head the
    # leftmost such run.
    bidx = jnp.arange(cfg.run_buckets - 1, dtype=jnp.int32)
    exact = (bidx + 1 >= nsb) & \
        (state.run_bucket_min[:cfg.run_buckets - 1] < RUN_INF)
    best_exact_len = jnp.min(jnp.where(exact, bidx + 1, nfit))
    exact_hit = best_exact_len <= cfg.num_sbs

    def from_bucket(_):
        b = jnp.clip(best_exact_len - 1, 0, cfg.run_buckets - 1)
        return best_exact_len, state.run_bucket_min[b]

    def from_reduce(_):
        fit = state.run_len >= nsb
        ln = jnp.min(jnp.where(fit, state.run_len, nfit))
        first = jnp.min(jnp.where(fit & (state.run_len == ln), ids,
                                  jnp.int32(cfg.num_sbs)))
        return ln, first

    best_len, best_first = lax.cond(exact_hit, from_bucket, from_reduce,
                                    None)
    has_run = best_len <= cfg.num_sbs
    wm_ok = state.used_sbs + nsb <= cfg.num_sbs
    ok = (nwords > 0) & (has_run | wm_ok)
    first = jnp.where(has_run, best_first, state.used_sbs)
    span = ok & (ids >= first) & (ids < first + nsb)
    head = span & (ids == first)
    cont = span & ~head
    # persistent records: class sentinel on every span member, total size
    # on the head (the device mirror of D_SIZE_CLASS / D_BLOCK_SIZE)
    sb_class = jnp.where(head, LARGE_CLS,
                         jnp.where(cont, LARGE_CONT, state.sb_class))
    sb_block_words = jnp.where(head, nwords,
                               jnp.where(cont, 0, state.sb_block_words))
    # claimed superblocks leave the free stack (order-preserving compact)
    stack = state.free_stack
    live = jnp.arange(stack.shape[0]) < state.free_top
    claimed = ok & has_run & (stack >= first) & (stack < first + nsb)
    keep = live & ~claimed
    pos = jnp.cumsum(keep.astype(jnp.int32)) - 1
    dump = stack.shape[0] - 1
    new_stack = jnp.full_like(stack, -1).at[
        jnp.where(keep, pos, dump)].set(jnp.where(keep, stack, -1))
    new_stack = new_stack.at[dump].set(-1)
    # claimed range leaves the free-run index (split at the run start)
    rl, rs = _runs_remove_range(cfg, state.run_len, state.run_start,
                                first, first + nsb, ok & has_run)
    state = _with_runs(state, cfg, rl, rs)
    state = state._replace(
        sb_class=sb_class,
        sb_block_words=sb_block_words,
        # one full-extent owner lease: count 1 on every member superblock
        span_refs=jnp.where(span, 1, state.span_refs),
        free_stack=new_stack,
        free_top=keep.sum(dtype=jnp.int32),
        used_sbs=jnp.where(ok & ~has_run, state.used_sbs + nsb,
                           state.used_sbs),
        alloc_count=state.alloc_count + ok.astype(jnp.int32))
    return state, jnp.where(ok, first * cfg.sb_words, -1)


def acquire_span(state: AllocState, cfg: ArenaConfig, off, n_sbs=-1):
    """Lease the ``n_sbs``-superblock *prefix* of the live span headed at
    ``off`` (``n_sbs < 0`` = the whole remaining extent).

    Vectorized mirror of ``Ralloc.span_acquire``: one masked add over the
    per-superblock lease vector.  Returns ``(state, ok)``; an invalid /
    dead / non-head ``off`` (or an empty range) is a masked no-op (``ok``
    false) — the device analogue of the host's raising ``span_acquire``,
    with the same raise-vs-masked-no-op asymmetry the feature matrix
    documents for ``free_large``.  Nothing persists: after a crash each
    root-reachable reference to the head is rebuilt as one full-extent
    lease (``jax_recovery``).
    """
    off = jnp.asarray(off, jnp.int32)
    n_sbs = jnp.asarray(n_sbs, jnp.int32)
    sb = jnp.clip(off // cfg.sb_words, 0, cfg.num_sbs - 1)
    valid = (off >= 0) & (off % cfg.sb_words == 0) & \
        (state.sb_class[sb] == LARGE_CLS)
    ext = span_sbs(cfg, state.sb_block_words[sb])
    n = jnp.where(n_sbs < 0, ext, jnp.minimum(n_sbs, ext))
    valid = valid & (n >= 1)
    ids = jnp.arange(cfg.num_sbs, dtype=jnp.int32)
    rng = valid & (ids >= sb) & (ids < sb + n)
    return state._replace(
        span_refs=state.span_refs + rng.astype(jnp.int32)), valid


def _lease_release(state: AllocState, cfg: ArenaConfig, sb, a, b, valid):
    """Drop one lease on member superblocks ``[sb+a, sb+b)`` of the span
    headed at ``sb``; free whatever the decrement leaves unleased.

    The vectorized core both ``free_large`` and ``trim_large`` share:

      * a range that is not fully leased (any member count already zero)
        invalidates the whole op — the masked-no-op mirror of the host's
        ``LeaseUnderflow`` raise;
      * head count reaching zero frees the entire remaining span (every
        genuine lease is a prefix and includes the head, so interior
        counts left over from conservative reconstruction cannot keep it
        alive);
      * otherwise the zero-count tail *suffix* frees: class records
        clear, the superblocks join the free stack, and the head's
        ``sb_block_words`` shrinks to the kept prefix — the persistent
        mirror of the host's ``_trim_tail``, so host and device stay
        placement- and extent-equivalent.  Interior zero ranges (only
        reachable via post-crash phantoms) stay placed until the head's
        last release, exactly like the host.
    """
    ids = jnp.arange(cfg.num_sbs, dtype=jnp.int32)
    ext = span_sbs(cfg, state.sb_block_words[sb])
    member = (ids >= sb) & (ids < sb + ext)
    rng = member & (ids >= sb + a) & (ids < sb + b)
    valid = valid & (b > a)
    valid = valid & ~(rng & (state.span_refs <= 0)).any()
    dec = valid & rng
    refs = state.span_refs - dec.astype(jnp.int32)
    head_zero = valid & (refs[sb] <= 0)
    last_live = jnp.max(jnp.where(member & (refs > 0), ids, -1))
    new_ext = jnp.maximum(last_live + 1 - sb, 0)
    freed = valid & member & (head_zero | (ids >= sb + new_ext))
    fs, ft = _push_many(state.free_stack, state.free_top, ids, freed)
    trimmed = valid & ~head_zero & (new_ext < ext)
    sbw = jnp.where(freed, 0, state.sb_block_words)
    sbw = sbw.at[sb].set(jnp.where(
        trimmed, jnp.minimum(sbw[sb], new_ext * cfg.sb_words), sbw[sb]))
    # the freed range is contiguous (whole remainder or a tail suffix);
    # splice it into the free-run index
    fa = jnp.min(jnp.where(freed, ids, jnp.int32(cfg.num_sbs)))
    fb = jnp.max(jnp.where(freed, ids + 1, jnp.int32(0)))
    rl, rs = _runs_add_range(cfg, state.run_len, state.run_start,
                             fa, fb, freed.any())
    state = _with_runs(state, cfg, rl, rs)
    return state._replace(
        sb_class=jnp.where(freed, FREE_CLS, state.sb_class),
        sb_block_words=sbw,
        span_refs=jnp.where(freed, 0, refs),
        free_stack=fs, free_top=ft), valid


def free_large(state: AllocState, cfg: ArenaConfig, off, n_sbs=-1):
    """Release one lease on the ``n_sbs``-superblock prefix of a large
    span (``n_sbs < 0`` = the whole remaining extent, the plain-free /
    owner case); ranges nobody leases any more free.

    While other leases cover a range the release is a pure transient
    decrement — class records stay put, the free stack is untouched.  The
    head range's last release resets every remaining member's class
    record (head *and* continuations — recovery must never see orphaned
    ``LARGE_CONT`` markers) and pushes the superblocks onto the free
    stack; a zero-count tail suffix frees the same way while the shared
    prefix stays placed (``sb_block_words`` shrinks to match the host's
    durable trim).  A non-head / already-freed ``off`` — or a release of
    a range not fully leased, including one past the *last* lease — is
    rejected (masked no-op) where the host raises, which keeps
    double-free and over-release safe.
    """
    off = jnp.asarray(off, jnp.int32)
    n_sbs = jnp.asarray(n_sbs, jnp.int32)
    sb = jnp.clip(off // cfg.sb_words, 0, cfg.num_sbs - 1)
    valid = (off >= 0) & (state.sb_class[sb] == LARGE_CLS)
    ext = span_sbs(cfg, state.sb_block_words[sb])
    b = jnp.where(n_sbs < 0, ext, jnp.minimum(n_sbs, ext))
    state, valid = _lease_release(state, cfg, sb, jnp.int32(0), b, valid)
    return state._replace(
        free_count=state.free_count + valid.astype(jnp.int32))


def trim_large(state: AllocState, cfg: ArenaConfig, off, n_keep, n_held=-1):
    """Shrink the caller's lease on the span headed at ``off`` to the
    ``n_keep``-superblock prefix — the decode-ahead reserver's "sequence
    finished short" path.  ``n_held`` is the length of the lease being
    shrunk (``< 0`` = the whole current extent, i.e. a full-extent
    lease); a caller re-trimming an already-shrunk lease must pass its
    current ``n_held`` exactly like the host ``span_trim``, or the
    release range would eat other holders' tail leases.  The trimmed
    range loses one lease; whatever suffix nobody else leases returns to
    the free stack while the shared prefix stays placed.  Invalid
    targets (non-head, dead, ``n_keep`` outside ``[1, held)``, range not
    fully leased) are masked no-ops where the host raises or no-ops.
    """
    off = jnp.asarray(off, jnp.int32)
    n_keep = jnp.asarray(n_keep, jnp.int32)
    n_held = jnp.asarray(n_held, jnp.int32)
    sb = jnp.clip(off // cfg.sb_words, 0, cfg.num_sbs - 1)
    valid = (off >= 0) & (off % cfg.sb_words == 0) & \
        (state.sb_class[sb] == LARGE_CLS)
    ext = span_sbs(cfg, state.sb_block_words[sb])
    b = jnp.where(n_held < 0, ext, jnp.minimum(n_held, ext))
    valid = valid & (n_keep >= 1) & (n_keep < b)
    state, valid = _lease_release(state, cfg, sb, n_keep, b, valid)
    return state, valid


def set_root(state: AllocState, i: int, off) -> AllocState:
    return state._replace(roots=state.roots.at[i].set(off))


# ---------------------------------------------------------------------------
# persistence boundary
# ---------------------------------------------------------------------------
PERSISTENT_FIELDS = ("sb_class", "sb_block_words", "used_sbs", "roots", "dirty")


def persistent_snapshot(state: AllocState) -> dict:
    """The only fields that must reach durable storage (paper's bold set)."""
    return {f: getattr(state, f) for f in PERSISTENT_FIELDS}


def free_runs(state: AllocState, cfg: ArenaConfig) -> list[tuple[int, int]]:
    """Debug/test helper: maximal contiguous runs ``(start, length)`` of
    free superblocks below the watermark — the search space of the
    best-fit large-object placement.  The host analogue is
    ``core.recovery.free_superblock_runs``; differential tests compare
    the two to pin down placement equivalence.
    """
    import numpy as np
    ids = jnp.arange(cfg.num_sbs, dtype=jnp.int32)
    free_sb = (state.sb_class == FREE_CLS) & (ids < state.used_sbs)
    run_len = np.asarray(free_run_table(free_sb, cfg.num_sbs)[0])
    starts = np.nonzero(run_len > 0)[0]
    return [(int(s), int(run_len[s])) for s in starts]


def live_blocks(state: AllocState, cfg: ArenaConfig):
    """Debug/test helper: per-class count of blocks not free anywhere.

    The extra ``"large"`` key counts live multi-superblock objects (one
    per ``LARGE_CLS`` head below the watermark).
    """
    out = {}
    in_use = jnp.arange(cfg.num_sbs) < state.used_sbs
    for c in range(cfg.num_classes):
        total = cfg.blocks_per_sb(c)
        sbs = (state.sb_class == c) & in_use
        in_sb = jnp.where(sbs, total - state.sb_free_count, 0).sum()
        cached = state.cache_top[c]
        out[c] = int(in_sb - cached)
    out["large"] = int(((state.sb_class == LARGE_CLS) & in_use).sum())
    return out
