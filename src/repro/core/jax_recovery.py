"""Vectorized GC recovery for the device-side allocator.

Paper §4.5 recovers a heap by (5) tracing reachable blocks from the
persistent roots and (6–9) sweeping the superblock region to rebuild all
transient metadata.  The paper runs this sequentially and notes (§6.4)
that parallelizing the trace across roots and the sweep across
superblocks is future work — on TPU we do exactly that:

  * **mark** — a data-parallel fixed-point: one step gathers every marked
    block's outgoing references (from a *reference table* produced by the
    consumer's filter functions) and scatter-ORs them into the mark
    bitmap; iteration count = graph depth, each step O(blocks × refs) on
    the VPU.
  * **sweep** — pure segmented reductions: per-superblock free bitmaps
    come from the mark bitmap, counts from popcounts, free/partial stacks
    from mask compaction (sort by (¬predicate, id)).

Blocks are identified by *slots* — offset // min(class_words) — so one
mark bitmap covers all classes.  Filter functions here are exact
(consumers enumerate their reference arrays, e.g. page-table pages);
conservative word-scanning has no device analogue because consumers own
typed arrays rather than a raw byte heap (DESIGN.md §2).
"""

from __future__ import annotations

import math
from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

from .jax_alloc import (FREE_CLS, LARGE_CLS, LARGE_CONT, AllocState,
                        ArenaConfig, init_state, rebuild_run_index,
                        span_sbs)


def slot_of(cfg: ArenaConfig, off):
    return off // min(cfg.class_words)


def num_slots(cfg: ArenaConfig) -> int:
    return cfg.total_words // min(cfg.class_words)


def mark(cfg: ArenaConfig, roots, ref_table, max_iter: int = 64):
    """Fixed-point reachability over the block-reference graph.

    roots:     i32[max_roots] block offsets (-1 = null)
    ref_table: i32[num_slots, R] outgoing references (block offsets, -1 null)
               — produced by the consumer's (vectorized) filter functions.
    Returns bool[num_slots] reachability.
    """
    S = num_slots(cfg)
    root_slots = jnp.where(roots >= 0, slot_of(cfg, roots), S)
    marked = jnp.zeros((S + 1,), bool).at[root_slots].set(True)
    marked = marked.at[S].set(False)
    tgt = jnp.where(ref_table >= 0, slot_of(cfg, ref_table), S)

    def body(carry):
        marked, _ = carry
        contrib = marked[:S, None] & (tgt < S)
        new = jnp.zeros((S + 1,), bool).at[
            jnp.where(contrib, tgt, S)].max(True)
        new = new.at[S].set(False)
        merged = marked | new
        changed = (merged != marked).any()
        return merged, changed

    def cond(carry):
        return carry[1]

    marked, _ = lax.while_loop(cond, body, body((marked, jnp.bool_(True))))
    return marked[:S]


def span_ref_counts(cfg: ArenaConfig, roots, ref_table, marked):
    """Count root-reachable references per slot (vectorized).

    A slot's count = (# roots naming it) + (# reference-table entries of
    *marked* source blocks naming it).  For a live large-span head this
    is exactly its transient refcount (one per holder whose page table /
    root references the head) — the device analogue of the reference
    counting ``core.recovery.trace`` does on the host.  Refcounts are
    never persisted; this is how they come back after a crash.
    """
    S = num_slots(cfg)
    root_slots = jnp.where(roots >= 0, slot_of(cfg, roots), S)
    counts = jnp.zeros((S + 1,), jnp.int32).at[root_slots].add(1)
    counts = counts.at[S].set(0)
    tgt = jnp.where(ref_table >= 0, slot_of(cfg, ref_table), S)
    contrib = marked[:, None] & (tgt < S)
    counts = counts.at[jnp.where(contrib, tgt, S)].add(
        contrib.astype(jnp.int32))
    return counts[:S]


def _compact(pred, n_plus_1: int):
    """Mask compaction: ids where pred, in ascending order, padded with -1."""
    ids = jnp.arange(pred.shape[0], dtype=jnp.int32)
    key = jnp.where(pred, ids, jnp.iinfo(jnp.int32).max)
    order = jnp.sort(key)
    cnt = pred.sum(dtype=jnp.int32)
    vals = jnp.where(jnp.arange(pred.shape[0]) < cnt, order, -1)
    out = jnp.full((n_plus_1,), -1, jnp.int32)
    return out.at[:pred.shape[0]].set(vals), cnt


def sweep(cfg: ArenaConfig, persistent: dict, marked,
          ref_counts=None) -> AllocState:
    """Rebuild every transient structure from (persistent fields, marks).

    Dead/orphaned large spans are swept back to ``FREE_CLS`` (and onto
    the free stack), so they re-enter the best-fit contiguous-run search
    of ``jax_alloc.alloc_large`` immediately.  Because that search keys
    off ``sb_class`` alone — never off stack order — a recovered heap is
    placement-equivalent to the pre-crash heap: the next span lands on
    the same superblock either side of a crash (asserted by the
    differential fuzz suite).

    ``ref_counts`` (per-slot, from ``span_ref_counts``) reconstructs the
    transient span range leases: every root-reachable reference to a
    live head is one lease, and lease *lengths* are transient and
    unrecoverable, so each reference conservatively becomes a lease over
    the span's whole persisted extent — the head's ``max(count, 1)`` is
    broadcast across every member superblock (the vectorized mirror of
    ``RangeLeaseTable.reconstruct``).  The floor only guards a caller
    sweeping with a stale count table: a live head is marked, so at
    least one reference exists.  Without ``ref_counts`` every live span
    recovers with a single full-extent owner lease.
    """
    n = cfg.num_sbs
    sb_ids = jnp.arange(n, dtype=jnp.int32)
    used = persistent["used_sbs"]
    sb_class = persistent["sb_class"]
    in_use = sb_ids < used
    minw = min(cfg.class_words)

    free_bitmap = jnp.zeros((n, cfg.max_blocks), bool)
    counts = jnp.zeros((n,), jnp.int32)
    empty = in_use & (sb_class == FREE_CLS)      # never initialized → free
    partial_stacks = []
    partial_tops = []
    Spad = num_slots(cfg)
    marked_pad = jnp.concatenate([marked, jnp.zeros((1,), bool)])

    # ---- large spans: a span is live iff its *head* block is marked -------
    # Associate every superblock with the nearest head at-or-before it (a
    # cummax over head indices), then check it falls inside that head's
    # recorded span.  Orphaned LARGE_CONT markers (no owning head, or out
    # of the head's reach) and unmarked spans are swept to the free stack.
    is_head = in_use & (sb_class == LARGE_CLS)
    span_len = jnp.where(is_head, span_sbs(cfg, persistent["sb_block_words"]),
                         0)
    head_of = lax.associative_scan(
        jnp.maximum, jnp.where(is_head, sb_ids, -1))
    reach = jnp.where(head_of >= 0, head_of + span_len[jnp.maximum(head_of, 0)],
                      0)
    in_span = in_use & (head_of >= 0) & (sb_ids < reach)
    head_slot = jnp.where(in_span, (head_of * cfg.sb_words) // minw, Spad)
    head_marked = marked_pad[head_slot]
    is_large = in_use & ((sb_class == LARGE_CLS) | (sb_class == LARGE_CONT))
    live_large = is_large & in_span & head_marked
    empty = empty | (is_large & ~live_large)

    # span range leases: a live head's count = root-reachable references
    # to it, broadcast over every member superblock (each reference is a
    # full-extent lease — lease lengths were transient)
    if ref_counts is None:
        head_counts = jnp.ones((n,), jnp.int32)
    else:
        rc_pad = jnp.concatenate([jnp.asarray(ref_counts, jnp.int32),
                                  jnp.zeros((1,), jnp.int32)])
        head_counts = rc_pad[head_slot]          # per member, its head's count
    span_refs = jnp.where(live_large, jnp.maximum(head_counts, 1), 0)

    new_class = sb_class
    for c in range(cfg.num_classes):
        cw = cfg.class_words[c]
        total = cfg.blocks_per_sb(c)
        sel = in_use & (sb_class == c)
        offs = (sb_ids[:, None] * cfg.sb_words
                + jnp.arange(cfg.max_blocks, dtype=jnp.int32)[None, :] * cw)
        slots = jnp.where(jnp.arange(cfg.max_blocks)[None, :] < total,
                          offs // minw, Spad)
        m = marked_pad[slots]                     # [n, max_blocks]
        valid = jnp.arange(cfg.max_blocks)[None, :] < total
        bm_c = valid & ~m
        cnt_c = bm_c.sum(axis=1, dtype=jnp.int32)
        free_bitmap = jnp.where(sel[:, None], bm_c, free_bitmap)
        counts = jnp.where(sel, cnt_c, counts)
        now_empty = sel & (cnt_c >= total)
        empty = empty | now_empty
        new_class = jnp.where(now_empty, -1, new_class)
        part = sel & (cnt_c > 0) & (cnt_c < total)
        stack_c, top_c = _compact(part, n + 1)
        partial_stacks.append(stack_c)
        partial_tops.append(top_c)

    # empty superblocks (incl. dead/orphaned large spans): wipe their
    # bitmaps/counts, clear their class records, and stack them as free
    free_bitmap = jnp.where(empty[:, None], False, free_bitmap)
    counts = jnp.where(empty, 0, counts)
    new_class = jnp.where(empty, FREE_CLS, new_class)
    free_stack, free_top = _compact(empty, n + 1)

    st = init_state(cfg, max_roots=persistent["roots"].shape[0])
    st = st._replace(
        sb_class=new_class,
        sb_block_words=jnp.where(empty, 0, persistent["sb_block_words"]),
        used_sbs=used,
        roots=persistent["roots"],
        dirty=jnp.int32(1),
        free_bitmap=free_bitmap,
        sb_free_count=counts,
        free_stack=free_stack,
        free_top=free_top,
        partial_stack=jnp.stack(partial_stacks),
        partial_top=jnp.stack(partial_tops),
        span_refs=span_refs,
    )
    # the transient free-run index is a pure function of the recovered
    # class records — rebuild it with the canonical scan
    return rebuild_run_index(st, cfg)


def live_record_mask(cfg: ArenaConfig, marked, offs, seal_ok=None):
    """Which block offsets survived the sweep (their slots are marked).

    The serving prefix store (``serving.prefix_store``) filters its
    durable record chain through this after recovery: an index record
    whose root swing never became durable is unreachable, stays unmarked,
    and is dropped here — the vectorized mirror of the host GC freeing an
    unreachable ``core.prefix_index`` record.  ``offs`` may contain -1
    (null) entries; they come back False.

    ``seal_ok``, when given, is a bool vector aligned with ``offs``:
    record ``i`` additionally survives only if ``seal_ok[i]`` — the
    device mirror of the host's torn-seal prune
    (``prefix_trie.prune_torn_nodes``), fed from
    ``PrefixStore.seal_matches``.  A record whose sidecar row tore
    mid-write is dropped here even though its block is marked.
    """
    offs = jnp.asarray(offs, jnp.int32)
    S = num_slots(cfg)
    slots = jnp.where(offs >= 0, slot_of(cfg, offs), S)
    padded = jnp.concatenate([jnp.asarray(marked, bool),
                              jnp.zeros((1,), bool)])
    live = (offs >= 0) & padded[slots]
    if seal_ok is not None:
        live = live & jnp.asarray(seal_ok, bool)
    return live


def recover(cfg: ArenaConfig, persistent: dict, ref_table,
            max_iter: int = 64) -> tuple[AllocState, jax.Array]:
    """Full vectorized recovery (mark + sweep + span-refcount rebuild).
    jit-compatible."""
    marked = mark(cfg, persistent["roots"], ref_table, max_iter)
    ref_counts = span_ref_counts(cfg, persistent["roots"], ref_table, marked)
    return sweep(cfg, persistent, marked, ref_counts), marked
