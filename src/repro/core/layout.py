"""Heap layout: regions, size classes, descriptor packing.

Mirrors Ralloc (Cai et al., 2020) §4.2–4.3:

  * A heap comprises three contiguous regions — superblock, descriptor,
    metadata — all nominally resident in "NVM" (here: an mmap'd file that
    simulates a DAX segment, see ``core.heap``).
  * Superblocks are 64 KiB; every block in a superblock shares one size
    class.  Descriptors are 64 B, one per superblock, locatable from the
    block address by bit manipulation (and vice versa).
  * 39 small size classes spanning 8 B .. 14 KiB (LRMalloc geometry:
    8-byte steps up to 64 B, then four steps per power-of-two doubling),
    plus class 0 for large blocks.

Only the *persistent* fields (size_class, block_size, region ``used``,
roots, dirty flag) are ever flushed online; everything else is transient
and reconstructed by recovery GC.
"""

from __future__ import annotations

import dataclasses

WORD = 8                      # bytes per word; the heap is an int64 array
SB_SIZE = 64 * 1024           # superblock bytes (paper: 64 KB)
SB_WORDS = SB_SIZE // WORD
DESC_WORDS = 8                # descriptor = 64 B padded to a cache line
CACHELINE_WORDS = 8
MAX_ROOTS = 1024              # paper: metadata region contains 1024 roots
LARGE_CLASS = 0               # class 0 = blocks larger than any standard size
MAX_SMALL = 14336             # 14 KiB — largest small class (paper §4.2)


def _build_size_classes() -> tuple[int, ...]:
    """LRMalloc-style class geometry: 8..64 in 8 B steps, then 4 per doubling."""
    sizes = list(range(8, 64 + 1, 8))                      # 8..64   (8 classes)
    base = 64
    while sizes[-1] < MAX_SMALL:
        step = base // 4
        for k in range(1, 5):
            s = base + k * step
            if s > MAX_SMALL:
                break
            sizes.append(s)
        base *= 2
    return tuple(sizes)


SIZE_CLASSES = _build_size_classes()
NUM_CLASSES = len(SIZE_CLASSES) + 1   # +1 for the large class 0
assert len(SIZE_CLASSES) == 39, len(SIZE_CLASSES)   # paper: 39 standard classes


def size_to_class(size: int) -> int:
    """Map a request size to its class index (1-based; 0 = large)."""
    if size > MAX_SMALL:
        return LARGE_CLASS
    # binary search over the small-class table
    lo, hi = 0, len(SIZE_CLASSES) - 1
    while lo < hi:
        mid = (lo + hi) // 2
        if SIZE_CLASSES[mid] < size:
            lo = mid + 1
        else:
            hi = mid
    return lo + 1


def class_block_size(cls: int) -> int:
    assert cls != LARGE_CLASS
    return SIZE_CLASSES[cls - 1]


def blocks_per_sb(block_size: int) -> int:
    return SB_SIZE // block_size


def contiguous_runs(sorted_ids) -> list[tuple[int, int]]:
    """Group ascending, duplicate-free indices into maximal contiguous
    runs ``(start, length)``.

    Shared by the host run index (``spans.FreeRunIndex.rebuild`` — the
    structure behind ``ralloc._claim_free_run``'s best-fit placement),
    the host recovery introspection (``recovery.free_superblock_runs``)
    and the device debug helper (``jax_alloc.free_runs``) so they can
    never drift apart — the differential-fuzz suite asserts host/device
    placement equivalence over exactly these runs.
    """
    runs: list[tuple[int, int]] = []
    start = prev = None
    for i in sorted_ids:
        if start is None:
            start = prev = i
        elif i == prev + 1:
            prev = i
        else:
            runs.append((start, prev - start + 1))
            start = prev = i
    if start is not None:
        runs.append((start, prev - start + 1))
    return runs


# ---------------------------------------------------------------------------
# Anchor packing (descriptor word 0) — updated with a single CAS, paper §4.2.
#   state(2) | avail(20) | count(20) | tag(22)
# ``avail`` is the index of the first free block in the superblock free list,
# ``count`` the number of free blocks, ``tag`` an anti-ABA counter.
# ---------------------------------------------------------------------------
EMPTY, PARTIAL, FULL = 0, 1, 2

_AVAIL_SHIFT = 2
_COUNT_SHIFT = 22
_TAG_SHIFT = 42
_F20 = (1 << 20) - 1
_F22 = (1 << 22) - 1
ANCHOR_NIL_AVAIL = _F20       # sentinel: no free block


def pack_anchor(state: int, avail: int, count: int, tag: int) -> int:
    return (state
            | ((avail & _F20) << _AVAIL_SHIFT)
            | ((count & _F20) << _COUNT_SHIFT)
            | ((tag & _F22) << _TAG_SHIFT))


def unpack_anchor(a: int) -> tuple[int, int, int, int]:
    a = int(a) & ((1 << 64) - 1)
    return (a & 0b11,
            (a >> _AVAIL_SHIFT) & _F20,
            (a >> _COUNT_SHIFT) & _F20,
            (a >> _TAG_SHIFT) & _F22)


# ---------------------------------------------------------------------------
# List-head packing (free / partial Treiber stacks): descriptor index + ABA
# counter in one CAS-able word (paper §4.2: "34 bits devoted to a counter").
#   idx(30) | counter(34)       idx == _IDX_NIL means empty list
# ---------------------------------------------------------------------------
_IDX_BITS = 30
_IDX_NIL = (1 << _IDX_BITS) - 1
HEAD_NIL = _IDX_NIL           # empty list head with counter 0


def pack_head(idx: int, counter: int) -> int:
    if idx < 0:
        idx = _IDX_NIL
    return (idx & _IDX_NIL) | ((counter & ((1 << 34) - 1)) << _IDX_BITS)


def unpack_head(h: int) -> tuple[int, int]:
    h = int(h) & ((1 << 64) - 1)
    idx = h & _IDX_NIL
    return (-1 if idx == _IDX_NIL else idx), (h >> _IDX_BITS)


# ---------------------------------------------------------------------------
# Descriptor field offsets (in words, relative to the descriptor base).
# Persistent (bold in paper Fig. 2): SIZE_CLASS, BLOCK_SIZE.  The rest is
# transient — reconstructed by recovery.
# ---------------------------------------------------------------------------
D_ANCHOR = 0
D_SIZE_CLASS = 1      # persistent
D_BLOCK_SIZE = 2      # persistent (large blocks: total byte size; 0 = span cont.)
D_NEXT_FREE = 3       # transient: next node in superblock free list (desc idx)
D_NEXT_PARTIAL = 4    # transient: next node in a partial list (desc idx)

LARGE_CONT = -1       # size_class value marking a large-span continuation SB


# ---------------------------------------------------------------------------
# Metadata region layout (word offsets).
# ---------------------------------------------------------------------------
M_MAGIC = 0
M_DIRTY = 1           # persistent dirty indicator (paper: robust mutex)
M_SB_REGION_WORDS = 2  # max size of the superblock region (persistent, set at init)
M_USED_SBS = 3        # persistent watermark: number of superblocks in use
M_FREE_HEAD = 4       # transient: superblock free-list head (idx+counter)
M_PARTIAL_HEADS = 5   # transient: NUM_CLASSES partial-list heads
M_ROOTS = M_PARTIAL_HEADS + NUM_CLASSES      # persistent: MAX_ROOTS root words
M_END = M_ROOTS + MAX_ROOTS

MAGIC = 0x52414C4C4F43_01     # "RALLOC" v1


@dataclasses.dataclass(frozen=True)
class HeapConfig:
    """Static configuration for one persistent heap."""
    size: int                       # max superblock-region size in bytes
    initial_sbs: int = 16           # superblocks made available at init (paper: 1 GB)
    expand_sbs: int = 16            # expansion increment (paper: 1 GB)
    tcache_cap: int = 64            # thread-local cache capacity per class
    sim_nvm: bool = False           # write-back cache simulation (crash testing)
    seed: int = 0                   # RNG seed for simulated evictions
    flush_ns: int = 0               # modeled clwb latency (benchmarks)
    fence_ns: int = 0               # modeled sfence latency (benchmarks)

    @property
    def num_sbs(self) -> int:
        return self.size // SB_SIZE

    @property
    def desc_region_words(self) -> int:
        return self.num_sbs * DESC_WORDS

    @property
    def sb_region_words(self) -> int:
        return self.num_sbs * SB_WORDS

    # file layout: [metadata][descriptors][superblocks]
    @property
    def desc_base(self) -> int:
        return M_END

    @property
    def sb_base(self) -> int:
        return M_END + self.desc_region_words

    @property
    def total_words(self) -> int:
        return self.sb_base + self.sb_region_words
