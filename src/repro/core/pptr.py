"""Position-independent pointers (paper §4.6).

A ``pptr`` stores the 64-bit *self-relative* offset of its target: the
delta between the target's address and the address of the pointer word
itself ("off-holder", Chen et al. [8]).  Because the superblock region is
bounded (1 TiB in the paper; here: heap word count), the delta fits in 48
bits; the spare high bits hold an *uncommon tag pattern* that (a) lets
conservative GC reject most integer constants, and (b) provides counter
bits for the Treiber-stack heads (see ``layout.pack_head``).

All code in this repo — allocator metadata *and* the test/benchmark data
structures — stores heap references exclusively as pptrs or as region-based
offsets, so a heap image can be remapped at any address (ASLR-friendly) and,
in the JAX adaptation, resharded across a different mesh (offsets survive
relocation; raw addresses would not).

Addresses at this layer are *word indices* into the heap array; NULL is
encoded as delta == 0 (a pointer to itself is meaningless).
"""

from __future__ import annotations

import numpy as np

PPTR_TAG = 0xA5A5              # uncommon pattern, top 16 bits
_TAG_SHIFT = 48
_DELTA_MASK = (1 << _TAG_SHIFT) - 1
_SIGN_BIT = 1 << (_TAG_SHIFT - 1)
PPTR_NULL = PPTR_TAG << _TAG_SHIFT     # tag with zero delta == null


def encode(holder_idx: int, target_idx: int | None) -> int:
    """Encode a self-relative pptr stored at word ``holder_idx``."""
    if target_idx is None:
        delta = 0
    else:
        delta = int(target_idx) - int(holder_idx)
        assert delta != 0, "pptr cannot reference its own holder"
    raw = (PPTR_TAG << _TAG_SHIFT) | (delta & _DELTA_MASK)
    return int(np.int64(np.uint64(raw)))


def decode(holder_idx: int, stored: int) -> int | None:
    """Decode a pptr read from word ``holder_idx``; None if null/invalid."""
    raw = int(np.uint64(np.int64(stored)))
    if (raw >> _TAG_SHIFT) != PPTR_TAG:
        return None
    delta = raw & _DELTA_MASK
    if delta == 0:
        return None
    if delta & _SIGN_BIT:                      # sign-extend 48-bit delta
        delta -= 1 << _TAG_SHIFT
    return holder_idx + delta


def is_pptr(stored: int) -> bool:
    raw = int(np.uint64(np.int64(stored)))
    return (raw >> _TAG_SHIFT) == PPTR_TAG and (raw & _DELTA_MASK) != 0


def looks_like_pptr(stored: int) -> bool:
    """Conservative-GC test: tagged, regardless of whether delta is 0."""
    return (int(np.uint64(np.int64(stored))) >> _TAG_SHIFT) == PPTR_TAG
