"""Durable prefix index: crash-surviving prompt-cache keys (host side).

The prefix cache (serving engine / sharedprompt workloads) is the
footprint lever of this codebase, yet before this module it was entirely
transient: a crash forgot every published prompt, and recovery
conservatively rebuilt each surviving reference as a *full-extent* span
lease, resurrecting decode-ahead slack until the lanes re-finished.

This module applies the paper's thesis (§4.5: persist just enough for
offline GC to reconstruct the rest) to the cache itself.  Each published
prompt gets one small **index record** — an ordinary allocator block —
holding:

    word 0   next record        (self-relative pptr, PPTR_NULL ends)
    word 1   span head          (self-relative pptr to the published span)
    word 2   seal               (48-bit prompt hash — see ``hash_tokens`` —
                                 plus a 16-bit content checksum in the top
                                 bits; written *last*, after every other
                                 word is durable, so a torn record is
                                 detectable — see ``record_is_valid``)
    word 3   page count         (full prompt pages published)
    word 4   lease length       (page-derived superblock count of the
                                 cache's prefix lease)

Records are linked from a dedicated root (Makalu-style roots, §4.5) and
traced precisely by a registered filter function
(``filters.prefix_index_filter``, §4.5.1) instead of conservatively.
The record's span pptr *is* the cache's durable reference: the existing
mark pass counts it like any other reference, so a published span
survives a crash even when no lane roots it, and the cache's lease comes
back from reachability alone.

Persist-boundary discipline (the only new durable writes, identical in
spirit to ``Ralloc._trim_tail``):

  * ``publish``: transient ``span_acquire`` first, then a fence (prior
    application flushes of the published contents become durable before
    the index can claim the prefix exists), then the non-seal record
    words are written + flushed + fenced, then the seal word (key +
    content checksum) is written + flushed + fenced *last*, and only
    then does the root swing (its own flush + fence).  A crash anywhere
    in that window recovers to one of two consistent states:
    *unpublished-but-leased* (the record never became reachable — GC
    frees the block and the lease count falls back to the durable roots)
    or *published* (the record re-surfaces and the prefix is
    re-published).  A dangling or torn record is unreachable by
    construction, and — defense in depth against hardware tears the
    protocol cannot see — a record whose seal checksum does not match
    its fields is pruned at recovery (``prune_torn_records``), never
    re-published.
  * ``remove``: the record is durably unlinked *before* its transient
    lease is released and its block freed — a linked record always
    implies a live span.  (The checksum covers words 1, 3, 4 and the
    key, *not* word 0: unlinking a neighbour rewrites a live record's
    next pointer, which must not stale its seal.)

Group commit (``publish_batch`` / ``remove_batch``): N publications can
amortize the persist boundaries — the batch's records are chained among
themselves (the last points at the old head), all N field groups share
ONE flush+fence, all N seal words share ONE flush+fence, and a single
root swing publishes the whole chain segment atomically (NVTraverse's
observation: only the "destination" write must be individually ordered;
intermediate appends may ride one fence).  ≈3 fences per batch instead
of 4 per record.  A crash still lands in one of the two consistent
states above, now batch-wide: before the swing none of the N records is
reachable (GC frees the blocks, leases fall back to the roots); after it
all N are.  Eviction mirrors it: ``remove_batch`` durably unlinks every
victim behind one fence (head removals fold into one root swing) before
ANY of the batch's leases drops — the per-record invariant is unchanged,
only the fence is shared.

Recovery-time **re-trim**: references rebuild as full-extent leases
(lease lengths are transient), but an index record knows its page-derived
lease length — ``retrim_after_recovery`` shrinks each record's
reconstructed lease back to the recorded superblock count, freeing the
decode-ahead tail immediately after recovery instead of waiting for the
reserver to re-finish.  ``recovery.recover`` invokes this automatically
for every root registered with the ``"prefix_index"`` type.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

from ..analysis.faults import is_suppressed
from . import pptr as pp
from .layout import MAX_ROOTS, WORD

TYPENAME = "prefix_index"
REC_WORDS = 5
REC_BYTES = REC_WORDS * WORD
#: default root slot — the top of the root table, far from the low slots
#: tests and the crash harness hand out sequentially.  With bucketing
#: (``PrefixIndex(n_buckets=k)``) this is bucket 0's slot and buckets
#: 1..k-1 descend from it; the reserved range below (down to
#: ``PREFIX_INDEX_ROOT - PREFIX_INDEX_MAX_BUCKETS + 1``) keeps them
#: clear of the trie root and the low harness slots.
PREFIX_INDEX_ROOT = MAX_ROOTS - 1
#: ceiling on bucket fan-out — sizes the reserved root-slot range.
PREFIX_INDEX_MAX_BUCKETS = 16

_KEY_MASK = (1 << 48) - 1


def bucket_slots(slot: int, n_buckets: int) -> tuple[int, ...]:
    """Root slots of a bucketed chain set: bucket ``b`` lives at
    ``slot - b``.  Every slot registers under the same ``TYPENAME``, so
    recovery's typed-root discovery prunes and re-trims each bucket
    without knowing about bucketing at all."""
    if not 1 <= n_buckets <= PREFIX_INDEX_MAX_BUCKETS:
        raise ValueError(f"n_buckets {n_buckets} outside "
                         f"[1, {PREFIX_INDEX_MAX_BUCKETS}]")
    if slot - (n_buckets - 1) < 0:
        raise ValueError(f"bucket range underflows the root table "
                         f"(slot {slot}, {n_buckets} buckets)")
    return tuple(slot - b for b in range(n_buckets))


def hash_tokens(tokens) -> int:
    """Deterministic 48-bit FNV-1a over a token sequence.

    48 bits on purpose: the top 16 bits of the seal word carry the
    content checksum instead, and ``_record_checksum`` guarantees the
    checksum never equals the pptr tag pattern — so a conservative scan
    of a record marks exactly the same targets as the typed filter
    (pinned by test).  Python's builtin ``hash`` is salted per process
    and useless across a crash; this one is stable.
    """
    h = 0xCBF29CE484222325
    for t in tokens:
        h ^= int(t) & 0xFFFFFFFFFFFFFFFF
        h = (h * 0x100000001B3) & 0xFFFFFFFFFFFFFFFF
    return h & _KEY_MASK


def _record_checksum(span_word: int, n_pages: int, lease_sbs: int,
                     key48: int) -> int:
    """16-bit content checksum stored in the seal word's top bits.

    FNV-1a over the sealed fields, folded to 16 bits.  The nonzero seed
    makes the all-zero record invalid (a zeroed seal word never matches
    — pinned by test), and the pptr tag pattern is remapped so the seal
    word can never be mistaken for a self-relative reference by the
    conservative scan.
    """
    h = 0x9E3779B97F4A7C15
    for v in (span_word, n_pages, lease_sbs, key48):
        h ^= int(v) & 0xFFFFFFFFFFFFFFFF
        h = (h * 0x100000001B3) & 0xFFFFFFFFFFFFFFFF
    c = (h ^ (h >> 16) ^ (h >> 32) ^ (h >> 48)) & 0xFFFF
    if c == pp.PPTR_TAG:
        c ^= 0x5A5A
    return c


def record_seal_matches(reader, rec: int) -> bool:
    """Checksum-only validity: the seal word's top 16 bits match the
    checksum of the sealed fields and the span pptr decodes.  Callers
    must have bounds-checked ``rec`` (``record_is_valid`` does both)."""
    w1 = int(reader.read_word(rec + 1))
    w2 = int(reader.read_word(rec + 2)) & ((1 << 64) - 1)
    if pp.decode(rec + 1, w1) is None:
        return False
    return (w2 >> 48) == _record_checksum(
        w1, int(reader.read_word(rec + 3)),
        int(reader.read_word(rec + 4)), w2 & _KEY_MASK)


def record_is_valid(r, rec: int) -> bool:
    """True iff ``rec`` lies inside the used superblock region and its
    seal checksum matches — i.e. the record was completely written."""
    heap = r.heap
    if not (heap.in_sb_region(rec) and heap.in_sb_region(rec + REC_WORDS - 1)):
        return False
    return record_seal_matches(r, rec)


@dataclasses.dataclass(frozen=True)
class PrefixRecord:
    """One decoded index record."""
    ptr: int                 # record block word address
    key: int                 # 48-bit prompt hash
    span: int | None         # span head block address (valid records: set)
    n_pages: int             # published whole pages
    lease_sbs: int           # the cache lease's superblock count


def walk_chain(r, slot: int, rec_words: int = REC_WORDS,
               seal_fn=record_seal_matches):
    """The one low-level chain walk every traversal shares (cycle-safe).

    Yields ``(prev, rec, nxt, valid)`` per visited record: ``prev`` is
    the chain predecessor (last *visited* record, None at the head),
    ``nxt`` the decoded next pointer (None at an out-of-bounds record —
    its memory cannot be read, let alone trusted), and ``valid`` whether
    the record is in bounds with a matching seal.  ``lookup``, ``remove``,
    ``remove_batch``, ``iter_records``, the recovery prune and the trie's
    node iteration all drive this single generator — with bucketed roots
    each bucket chain is just another ``slot``.
    """
    heap = r.heap
    prev = None
    rec = heap.get_root(slot)
    seen: set[int] = set()
    while rec is not None and rec not in seen:
        seen.add(rec)
        in_bounds = (heap.in_sb_region(rec)
                     and heap.in_sb_region(rec + rec_words - 1))
        nxt = pp.decode(rec, r.read_word(rec)) if in_bounds else None
        yield prev, rec, nxt, in_bounds and seal_fn(r, rec)
        prev, rec = rec, nxt


def iter_records(r, slot: int = PREFIX_INDEX_ROOT) -> Iterator[PrefixRecord]:
    """Walk the record chain from root ``slot``.

    Torn/corrupt records are skipped, never yielded: traversal continues
    through an in-bounds invalid record's next pointer and truncates at
    an out-of-bounds one.
    """
    for _prev, rec, _nxt, valid in walk_chain(r, slot):
        if valid:
            yield PrefixRecord(
                ptr=rec,
                key=int(r.read_word(rec + 2)) & _KEY_MASK,
                span=pp.decode(rec + 1, r.read_word(rec + 1)),
                n_pages=int(r.read_word(rec + 3)),
                lease_sbs=int(r.read_word(rec + 4)),
            )


def prune_torn_records(r, slot: int = PREFIX_INDEX_ROOT) -> int:
    """Durably unlink every torn/corrupt record on the chain; returns the
    number pruned.

    Runs at recovery time *before* the mark pass (``recovery.recover``),
    so a torn record is never re-published: its span pptr never reaches
    the tracer and its block, unreachable once unlinked, is reclaimed by
    the ordinary sweep.  Each unlink is individually durable (the same
    unlink-before-anything-else discipline as ``PrefixIndex.remove``).
    """
    m = r.mem
    heap = r.heap
    pruned = 0
    kept = None                    # last valid record kept on the chain
    for _prev, rec, nxt, valid in walk_chain(r, slot):
        if valid:
            kept = rec
            continue
        pruned += 1
        # the unlink rewrites ``kept``'s next (or the root) only — the
        # walker's already-decoded ``nxt`` is unaffected
        if kept is None:
            heap.set_root(slot, nxt)              # durable flush + fence
        else:
            m.write(kept, pp.PPTR_NULL if nxt is None
                    else pp.encode(kept, nxt))
            m.flush(kept)
            m.fence()
    return pruned


def retrim_after_recovery(r, slot: int = PREFIX_INDEX_ROOT
                          ) -> tuple[int, int]:
    """Shrink each surviving record's reconstructed full-extent lease to
    its recorded superblock count; returns ``(records, spans_trimmed)``.

    Runs after ``RangeLeaseTable.reconstruct``: every durable reference
    (roots and index records alike) came back as a full-extent lease, so
    per record exactly one full-extent lease exists to re-trim.  Tail
    superblocks nobody else leases free right here — the post-crash
    mirror of the owner's finish-short trim.
    """
    n = trimmed = 0
    for rec in iter_records(r, slot):
        n += 1
        if rec.span is None or rec.lease_sbs < 1:
            continue
        try:
            ext = r.span_extent(rec.span)
        except ValueError:          # defensive: never reachable by design
            continue
        if rec.lease_sbs < ext:
            r.span_trim(rec.span, rec.lease_sbs)
            trimmed += 1
    return n, trimmed


class PrefixIndex:
    """Host-side durable prefix index over one ``Ralloc`` heap.

    ``n_buckets > 1`` hash-buckets the durable chains by the 48-bit key:
    bucket ``key % n_buckets`` owns root slot ``slot - bucket``
    (``bucket_slots``), so ``lookup``/``remove``/``remove_batch`` walk
    O(records / n_buckets) records instead of one long chain.  The
    record format, persist ordering and fence counts are unchanged —
    bucketing only splits *where* the chains hang, and every bucket root
    registers under the same ``TYPENAME`` so recovery prunes and
    re-trims them without modification.  Group commits still spend ≈3
    fences per batch: the root swing covers all touched buckets with one
    batched ``set_roots`` (crash atomicity of a multi-bucket batch is
    accordingly per-bucket — a crash mid-swing can land a prefix of the
    buckets, each of which is individually consistent).
    """

    def __init__(self, r, slot: int = PREFIX_INDEX_ROOT,
                 n_buckets: int = 1):
        self.r = r
        self.slot = slot
        self.n_buckets = int(n_buckets)
        self.slots = bucket_slots(slot, self.n_buckets)
        #: lookup instrumentation: records visited / lookups served —
        #: the idxscale workload reports ``walk_steps / lookups``.
        self.lookups = 0
        self.walk_steps = 0
        # (re)register the typed roots: filter functions are re-registered
        # every execution, never persisted (paper §4.5.1)
        for s in self.slots:
            r.get_root(s, TYPENAME)

    def _slot_of(self, key: int) -> int:
        return self.slots[(int(key) & _KEY_MASK) % self.n_buckets]

    # ----------------------------------------------------------------- reads
    def records(self) -> list[PrefixRecord]:
        return [rec for s in self.slots for rec in iter_records(self.r, s)]

    def lookup(self, key: int) -> PrefixRecord | None:
        key &= _KEY_MASK
        self.lookups += 1
        for rec in iter_records(self.r, self._slot_of(key)):
            self.walk_steps += 1
            if rec.key == key:
                return rec
        return None

    # ---------------------------------------------------------------- writes
    def publish(self, key: int, span_ptr: int, n_pages: int,
                lease_sbs: int) -> int | None:
        """Durably publish ``span_ptr``'s prefix under ``key``.

        Acquires the cache's transient prefix lease first (the durable
        record must never outnumber the transient counts it shadows),
        fences, then appends the record with the ordering documented in
        the module docstring.  Returns the record address, or None when
        the heap cannot place a record block (the publish then stays
        transient-only — a safe degradation, the span is simply forgotten
        at the next crash).
        """
        r = self.r
        if lease_sbs < 1:
            raise ValueError(f"publish with an empty lease ({lease_sbs} sbs)")
        slot = self._slot_of(key)
        r.span_acquire(span_ptr, lease_sbs)
        # persist boundary: published contents (the application flushed
        # them) become durable before the index can claim they exist.
        # Elided when nothing was flushed since the last fence — e.g.
        # the span allocation itself just fenced — because an sfence
        # with no scheduled lines commits nothing.
        r.fence_if_pending()
        rec = r.malloc(REC_BYTES)
        if rec is None:
            r.span_release(span_ptr, lease_sbs)
            return None
        head = r.heap.get_root(slot)
        r.write_word(rec, pp.PPTR_NULL if head is None
                     else pp.encode(rec, head))
        span_word = pp.encode(rec + 1, span_ptr)
        r.write_word(rec + 1, span_word)
        r.write_word(rec + 3, int(n_pages))
        r.write_word(rec + 4, int(lease_sbs))
        if not is_suppressed("prefix_index.publish.fields_persist"):
            r.flush_range(rec, REC_WORDS)
            r.fence()                # fields durable BEFORE the seal word:
        r.mem.note("record_seal", record=rec)     # …a torn record can only
        key48 = int(key) & _KEY_MASK              # be missing its seal
        cksum = _record_checksum(span_word, int(n_pages), int(lease_sbs),
                                 key48)
        r.write_word(rec + 2, key48 | (cksum << 48))
        if not is_suppressed("prefix_index.publish.record_persist"):
            r.flush_range(rec + 2, 1)
            r.fence()                # sealed record durable BEFORE reachable
        r.set_root(slot, rec, TYPENAME)          # atomic swing (flush+fence)
        r.mem.note("publish_end", record=rec, slot=slot)
        return rec

    def publish_batch(self, items) -> list:
        """Group-commit publish: durably publish N prefixes with ONE
        field fence, ONE seal fence and ONE root swing.

        ``items`` is an iterable of ``(key, span_ptr, n_pages,
        lease_sbs)`` tuples.  Returns the per-item record addresses
        (``None`` where the heap could not place a record block — that
        publish stays transient-only and its lease is released at once,
        exactly like the single-publish degradation).

        Ordering (module docstring, "Group commit"): leases for all N
        first, then every record's non-seal fields — the batch chained
        newest-first, the last new record pointing at the old head —
        then one flush+fence covering all field groups *and* the
        application's prior flushes of the published contents, then all
        seal words + one flush+fence, then the single root swing.  The
        intermediate records need no individual fences because none is
        reachable until the swing lands.
        """
        r = self.r
        items = [(int(k) & _KEY_MASK, sp, int(np_), int(ls))
                 for k, sp, np_, ls in items]
        if not items:
            return []
        if len(items) == 1:            # degenerate batch: the strict path
            return [self.publish(*items[0])]
        for _k, _sp, _np, lease_sbs in items:
            if lease_sbs < 1:
                raise ValueError(
                    f"publish with an empty lease ({lease_sbs} sbs)")
        for _k, span_ptr, _np, lease_sbs in items:
            r.span_acquire(span_ptr, lease_sbs)
        recs: list = []
        for _k, span_ptr, _np, lease_sbs in items:
            rec = r.malloc(REC_BYTES)
            if rec is None:            # degrade per item, keep the rest
                r.span_release(span_ptr, lease_sbs)
            recs.append(rec)
        batch = [(rec, it) for rec, it in zip(recs, items) if rec is not None]
        if not batch:
            return recs
        # partition by bucket: each bucket's new records chain among
        # themselves, the last pointing at that bucket's old head
        groups: dict[int, list[tuple[int, tuple]]] = {}
        for rec, it in batch:
            groups.setdefault(self._slot_of(it[0]), []).append((rec, it))
        seals = []
        for slot, grp in groups.items():
            head = r.heap.get_root(slot)
            for i, (rec, (key48, span_ptr, n_pages, lease_sbs)) in \
                    enumerate(grp):
                nxt = grp[i + 1][0] if i + 1 < len(grp) else head
                r.write_word(rec, pp.PPTR_NULL if nxt is None
                             else pp.encode(rec, nxt))
                span_word = pp.encode(rec + 1, span_ptr)
                r.write_word(rec + 1, span_word)
                r.write_word(rec + 3, n_pages)
                r.write_word(rec + 4, lease_sbs)
                cksum = _record_checksum(span_word, n_pages, lease_sbs,
                                         key48)
                seals.append((rec, key48 | (cksum << 48)))
        if not is_suppressed("prefix_index.publish_batch.fields_persist"):
            # adjacent 40-byte records share cache lines: one clwb per
            # dirty line across the whole batch, not one per record
            r.flush_ranges((rec, REC_WORDS) for rec, _ in batch)
            r.fence()                  # the ONE fence N field groups share
        r.mem.note("batch_seal", records=[rec for rec, _ in batch])
        for rec, seal in seals:
            r.write_word(rec + 2, seal)
        if not is_suppressed("prefix_index.publish_batch.records_persist"):
            r.flush_ranges((rec + 2, 1) for rec, _ in seals)
            r.fence()                  # the ONE fence N sealed records share
        for slot, grp in groups.items():
            r.mem.note("batch_root", records=[rec for rec, _ in grp],
                       slot=slot)
        # one batched swing covers every touched bucket: all root words
        # written + flushed behind a single fence (still 3 fences/batch)
        r.set_roots([(slot, grp[0][0]) for slot, grp in groups.items()],
                    TYPENAME)
        for slot, grp in groups.items():
            r.mem.note("publish_batch_end",
                       records=[rec for rec, _ in grp], slot=slot)
        return recs

    def remove_batch(self, keys) -> int:
        """Batched eviction: durably unlink every record matching
        ``keys`` behind ONE shared fence (plus at most one root swing
        when the head is among the victims), then release the leases and
        free the blocks.  Returns the number removed.

        The per-record invariant of ``remove`` holds batch-wide: every
        unlink is durable before ANY lease of the batch drops.
        """
        r = self.r
        want = {int(k) & _KEY_MASK for k in keys}
        if not want:
            return 0
        # only buckets owning a wanted key need their chain walked
        touched = sorted({self._slot_of(k) for k in want}, reverse=True)
        dirty: list[int] = []
        swings: list[tuple[int, int | None]] = []
        victims: list[tuple[int, int | None, int, int]] = []
        for slot in touched:
            chain: list[tuple[int, int | None]] = []   # (rec, next) kept
            for _prev, rec, nxt, valid in walk_chain(r, slot):
                if (valid and (int(r.read_word(rec + 2)) & _KEY_MASK)
                        in want):
                    victims.append(
                        (rec, pp.decode(rec + 1, r.read_word(rec + 1)),
                         int(r.read_word(rec + 4)), slot))
                else:
                    chain.append((rec, nxt))
            # rewire the survivors around the victims: every predecessor
            # whose successor changed gets one next-pointer write, and
            # all those writes (across buckets) share one flush+fence
            for i, (surv, old_nxt) in enumerate(chain):
                new_nxt = chain[i + 1][0] if i + 1 < len(chain) else None
                if new_nxt != old_nxt:
                    r.write_word(surv, pp.PPTR_NULL if new_nxt is None
                                 else pp.encode(surv, new_nxt))
                    dirty.append(surv)
            new_head = chain[0][0] if chain else None
            if new_head != r.heap.get_root(slot):
                swings.append((slot, new_head))    # head victims fold
        if not victims:
            return 0
        if dirty and not is_suppressed(
                "prefix_index.remove_batch.unlink_persist"):
            r.flush_ranges((w, 1) for w in dirty)
            r.fence()                  # the ONE fence N unlinks share
        if swings:
            r.set_roots(swings, TYPENAME)          # ≤ 1 swing fence total
        for rec, span, lease, slot in victims:
            r.mem.note("lease_release", record=rec, slot=slot)
            if span is not None and lease >= 1:
                r.span_release(span, lease)
            r.free(rec)
        return len(victims)

    def remove(self, key: int) -> bool:
        """Durably unlink the record for ``key``, release the cache's
        transient lease, and free the record block.  Returns False when
        no record carries the key."""
        r = self.r
        key &= _KEY_MASK
        slot = self._slot_of(key)
        for prev, rec, nxt, valid in walk_chain(r, slot):
            if not (valid
                    and (int(r.read_word(rec + 2)) & _KEY_MASK) == key):
                continue
            # unlink durable BEFORE the lease drops: a linked record
            # must always imply a live span
            if prev is None:
                r.set_root(slot, nxt, TYPENAME)
            else:
                r.write_word(prev, pp.PPTR_NULL if nxt is None
                             else pp.encode(prev, nxt))
                if not is_suppressed("prefix_index.remove.unlink_persist"):
                    r.flush_range(prev, 1)
                    r.fence()
            span = pp.decode(rec + 1, r.read_word(rec + 1))
            lease = int(r.read_word(rec + 4))
            r.mem.note("lease_release", record=rec, slot=slot)
            if span is not None and lease >= 1:
                r.span_release(span, lease)
            r.free(rec)
            return True
        return False

    def clear(self) -> int:
        """Remove every record (reverse of all publishes); returns the
        number removed."""
        n = 0
        while True:
            recs = self.records()
            if not recs:
                return n
            self.remove(recs[0].key)
            n += 1
