"""Durable prefix index: crash-surviving prompt-cache keys (host side).

The prefix cache (serving engine / sharedprompt workloads) is the
footprint lever of this codebase, yet before this module it was entirely
transient: a crash forgot every published prompt, and recovery
conservatively rebuilt each surviving reference as a *full-extent* span
lease, resurrecting decode-ahead slack until the lanes re-finished.

This module applies the paper's thesis (§4.5: persist just enough for
offline GC to reconstruct the rest) to the cache itself.  Each published
prompt gets one small **index record** — an ordinary allocator block —
holding:

    word 0   next record        (self-relative pptr, PPTR_NULL ends)
    word 1   span head          (self-relative pptr to the published span)
    word 2   key                (48-bit prompt hash — see ``hash_tokens``)
    word 3   page count         (full prompt pages published)
    word 4   lease length       (page-derived superblock count of the
                                 cache's prefix lease)

Records are linked from a dedicated root (Makalu-style roots, §4.5) and
traced precisely by a registered filter function
(``filters.prefix_index_filter``, §4.5.1) instead of conservatively.
The record's span pptr *is* the cache's durable reference: the existing
mark pass counts it like any other reference, so a published span
survives a crash even when no lane roots it, and the cache's lease comes
back from reachability alone.

Persist-boundary discipline (the only new durable writes, identical in
spirit to ``Ralloc._trim_tail``):

  * ``publish``: transient ``span_acquire`` first, then a fence (prior
    application flushes of the published contents become durable before
    the index can claim the prefix exists), then the record words are
    written + flushed + fenced, and only then does the root swing (its
    own flush + fence).  A crash anywhere in that window recovers to one
    of two consistent states: *unpublished-but-leased* (the record never
    became reachable — GC frees the block and the lease count falls back
    to the durable roots) or *published* (the record re-surfaces and the
    prefix is re-published).  A dangling or torn record is unreachable
    by construction.
  * ``remove``: the record is durably unlinked *before* its transient
    lease is released and its block freed — a linked record always
    implies a live span.

Recovery-time **re-trim**: references rebuild as full-extent leases
(lease lengths are transient), but an index record knows its page-derived
lease length — ``retrim_after_recovery`` shrinks each record's
reconstructed lease back to the recorded superblock count, freeing the
decode-ahead tail immediately after recovery instead of waiting for the
reserver to re-finish.  ``recovery.recover`` invokes this automatically
for every root registered with the ``"prefix_index"`` type.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

from . import pptr as pp
from .layout import MAX_ROOTS, WORD

TYPENAME = "prefix_index"
REC_WORDS = 5
REC_BYTES = REC_WORDS * WORD
#: default root slot — the top of the root table, far from the low slots
#: tests and the crash harness hand out sequentially.
PREFIX_INDEX_ROOT = MAX_ROOTS - 1

_KEY_MASK = (1 << 48) - 1


def hash_tokens(tokens) -> int:
    """Deterministic 48-bit FNV-1a over a token sequence.

    48 bits on purpose: the stored key word can never carry the pptr tag
    pattern in its top 16 bits, so a conservative scan of a record marks
    exactly the same targets as the typed filter (pinned by test).
    Python's builtin ``hash`` is salted per process and useless across a
    crash; this one is stable.
    """
    h = 0xCBF29CE484222325
    for t in tokens:
        h ^= int(t) & 0xFFFFFFFFFFFFFFFF
        h = (h * 0x100000001B3) & 0xFFFFFFFFFFFFFFFF
    return h & _KEY_MASK


@dataclasses.dataclass(frozen=True)
class PrefixRecord:
    """One decoded index record."""
    ptr: int                 # record block word address
    key: int                 # 48-bit prompt hash
    span: int | None         # span head block address (None = torn/corrupt)
    n_pages: int             # published whole pages
    lease_sbs: int           # the cache lease's superblock count


def iter_records(r, slot: int = PREFIX_INDEX_ROOT) -> Iterator[PrefixRecord]:
    """Walk the record chain from root ``slot`` (cycle-safe)."""
    rec = r.heap.get_root(slot)
    seen: set[int] = set()
    while rec is not None and rec not in seen:
        seen.add(rec)
        yield PrefixRecord(
            ptr=rec,
            key=int(r.read_word(rec + 2)) & _KEY_MASK,
            span=pp.decode(rec + 1, r.read_word(rec + 1)),
            n_pages=int(r.read_word(rec + 3)),
            lease_sbs=int(r.read_word(rec + 4)),
        )
        rec = pp.decode(rec, r.read_word(rec))


def retrim_after_recovery(r, slot: int = PREFIX_INDEX_ROOT
                          ) -> tuple[int, int]:
    """Shrink each surviving record's reconstructed full-extent lease to
    its recorded superblock count; returns ``(records, spans_trimmed)``.

    Runs after ``RangeLeaseTable.reconstruct``: every durable reference
    (roots and index records alike) came back as a full-extent lease, so
    per record exactly one full-extent lease exists to re-trim.  Tail
    superblocks nobody else leases free right here — the post-crash
    mirror of the owner's finish-short trim.
    """
    n = trimmed = 0
    for rec in iter_records(r, slot):
        n += 1
        if rec.span is None or rec.lease_sbs < 1:
            continue
        try:
            ext = r.span_extent(rec.span)
        except ValueError:          # defensive: never reachable by design
            continue
        if rec.lease_sbs < ext:
            r.span_trim(rec.span, rec.lease_sbs)
            trimmed += 1
    return n, trimmed


class PrefixIndex:
    """Host-side durable prefix index over one ``Ralloc`` heap."""

    def __init__(self, r, slot: int = PREFIX_INDEX_ROOT):
        self.r = r
        self.slot = slot
        # (re)register the typed root: filter functions are re-registered
        # every execution, never persisted (paper §4.5.1)
        r.get_root(slot, TYPENAME)

    # ----------------------------------------------------------------- reads
    def records(self) -> list[PrefixRecord]:
        return list(iter_records(self.r, self.slot))

    def lookup(self, key: int) -> PrefixRecord | None:
        key &= _KEY_MASK
        for rec in iter_records(self.r, self.slot):
            if rec.key == key:
                return rec
        return None

    # ---------------------------------------------------------------- writes
    def publish(self, key: int, span_ptr: int, n_pages: int,
                lease_sbs: int) -> int | None:
        """Durably publish ``span_ptr``'s prefix under ``key``.

        Acquires the cache's transient prefix lease first (the durable
        record must never outnumber the transient counts it shadows),
        fences, then appends the record with the ordering documented in
        the module docstring.  Returns the record address, or None when
        the heap cannot place a record block (the publish then stays
        transient-only — a safe degradation, the span is simply forgotten
        at the next crash).
        """
        r = self.r
        if lease_sbs < 1:
            raise ValueError(f"publish with an empty lease ({lease_sbs} sbs)")
        r.span_acquire(span_ptr, lease_sbs)
        # persist boundary: published contents (the application flushed
        # them) become durable before the index can claim they exist
        r.fence()
        rec = r.malloc(REC_BYTES)
        if rec is None:
            r.span_release(span_ptr, lease_sbs)
            return None
        head = r.heap.get_root(self.slot)
        r.write_word(rec, pp.PPTR_NULL if head is None
                     else pp.encode(rec, head))
        r.write_word(rec + 1, pp.encode(rec + 1, span_ptr))
        r.write_word(rec + 2, int(key) & _KEY_MASK)
        r.write_word(rec + 3, int(n_pages))
        r.write_word(rec + 4, int(lease_sbs))
        r.flush_range(rec, REC_WORDS)
        r.fence()                    # record durable BEFORE it is reachable
        r.set_root(self.slot, rec, TYPENAME)     # atomic swing (flush+fence)
        return rec

    def remove(self, key: int) -> bool:
        """Durably unlink the record for ``key``, release the cache's
        transient lease, and free the record block.  Returns False when
        no record carries the key."""
        r = self.r
        key &= _KEY_MASK
        prev = None
        rec = r.heap.get_root(self.slot)
        seen: set[int] = set()
        while rec is not None and rec not in seen:
            seen.add(rec)
            nxt = pp.decode(rec, r.read_word(rec))
            if (int(r.read_word(rec + 2)) & _KEY_MASK) == key:
                # unlink durable BEFORE the lease drops: a linked record
                # must always imply a live span
                if prev is None:
                    r.set_root(self.slot, nxt, TYPENAME)
                else:
                    r.write_word(prev, pp.PPTR_NULL if nxt is None
                                 else pp.encode(prev, nxt))
                    r.flush_range(prev, 1)
                    r.fence()
                span = pp.decode(rec + 1, r.read_word(rec + 1))
                lease = int(r.read_word(rec + 4))
                if span is not None and lease >= 1:
                    r.span_release(span, lease)
                r.free(rec)
                return True
            prev, rec = rec, nxt
        return False

    def clear(self) -> int:
        """Remove every record (reverse of all publishes); returns the
        number removed."""
        n = 0
        while True:
            recs = self.records()
            if not recs:
                return n
            self.remove(recs[0].key)
            n += 1
