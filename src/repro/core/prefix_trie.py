"""Durable token-radix prefix trie: partial-prefix hits that survive a crash.

``core.prefix_index`` made the prefix cache crash-durable, but kept it
exact-whole-prompt keyed: two prompts sharing a 2k-token system prompt
and differing in the last token share nothing.  This module generalizes
the index chain into a **radix trie over prompt pages**: each node owns
a page range ``[start_page, end_page)`` of some published prompt and a
``RangeLeaseTable`` *prefix lease* ``[0, lease_sbs)`` on its span — the
exact "lease ``[0, k)`` of a longer span" shape the PR-4 lease machinery
was built for — so a request matching only ``k`` pages of a longer
published prompt leases just those ``k`` pages' superblocks and decodes
its suffix on its own pages.

Node semantics (the invariant everything below leans on):

  * A node's **span** is its publisher's own reservation and backs the
    node's *entire prefix* ``[0, end_page)`` at identity page offsets —
    page ``j`` of the prefix is span page ``j``.  A deep node is
    therefore self-contained: serving any boundary ``end_page`` needs
    only that one span.
  * A node's **key** is the cumulative 48-bit hash
    (``prefix_index.hash_tokens``) of the whole prefix up to
    ``end_page`` — not of the edge alone — so matching a node verifies
    the full path implicitly, and a mis-parented record (possible only
    through recovery of a hostile image) can never serve a wrong prefix.
  * A node's **lease** covers span superblocks ``[0, lease_sbs)`` with
    ``lease_sbs = ceil(end_page / sb_pages)`` — exactly the
    superblocks the prefix occupies.  One durable record ⇔ one lease,
    which is what lets recovery rebuild the lease vector by counting
    references (nothing extra persisted, same as PR 4/5).

Record layout (``REC_WORDS`` = 8; one ordinary allocator block each,
linked from a typed root and traced by the registered precise filter
``filters.prefix_trie_filter``):

    word 0   next record      (chain pptr; rewritten by unlink — unsealed)
    word 1   parent pptr      (tree shape; rewritten by split re-parent —
                               unsealed; PPTR_NULL = child of the root)
    word 2   seal             (key48 | checksum16 << 48, written LAST)
    word 3   span head        (self-relative pptr)
    word 4   end_page
    word 5   start_page
    word 6   lease_sbs
    word 7   fingerprint      (edge-first token low32 | prefix-last token
                               low16 << 32; top 16 bits zero) — lets even a
                               *recovered* node (whose exact tokens died
                               with the crash) verify a cheap token
                               fingerprint before serving, closing the
                               PR-5 "recovered entries match by hash
                               alone" residual.

Persist protocol — the group-commit discipline of ``publish_batch``
(NVTraverse: only the destination write needs its own fence) applied to
every structural operation:

  * **insert / insert_batch**: leases acquired, one content fence, all N
    new records' non-seal fields + ONE flush+fence
    (``prefix_trie.commit.fields_persist``), all N seals + ONE
    flush+fence (``.records_persist``), ONE root swing attaches the
    chain segment.  Crash anywhere ⇒ either none of the batch is
    reachable (GC frees the blocks, leases fall back to the roots) or
    all of it is.
  * **split** of node X ``[s, e)`` at page ``m``: two new records — M
    ``[s, m)`` and X' ``[m, e)``, ``M.next = X'``,
    ``X'.next = X.next``, ``X'.parent = M`` — go through the same
    fields-fence / seal-fence pair, then ONE relink write splices the
    pair in X's chain position (predecessor next-pointer, or the root
    swing when X was the head; ``.relink_persist``), X's children
    re-parent to X' behind one fence (``.reparent_persist``), and only
    then does X's lease drop and its block free.  Either crash side is
    consistent: before the relink the new pair is unreachable; after
    it, X is — the GC frees whichever side lost.
  * **remove** (leaf only): durable unlink (``.unlink_persist``) strictly
    before the lease release — a linked record always implies a live
    span, same as the flat index.

Recovery: ``recovery.recover`` prunes torn-seal nodes durably *before*
the mark pass and applies the **recoverability criterion** to their
children — a node is recoverable iff valid records cover its whole
ancestry ``[0, start_page)``; a child whose boundary some surviving
record still covers is durably re-parented to it (safe: navigation is
by cumulative hash, the parent pointer is only shape), anything else is
durably dropped with its descendants.  Surviving nodes re-publish with
zero re-prefill and ``retrim_after_recovery`` shrinks each one's
reconstructed full-extent lease back to its recorded ``lease_sbs``.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

from ..analysis.faults import is_suppressed
from . import pptr as pp
from .layout import MAX_ROOTS, WORD
from .prefix_index import (_KEY_MASK, PREFIX_INDEX_MAX_BUCKETS, hash_tokens,
                           walk_chain)

TYPENAME = "prefix_trie"
REC_WORDS = 8
REC_BYTES = REC_WORDS * WORD
#: default root slot — directly below the flat index's reserved bucket
#: range (``PREFIX_INDEX_ROOT`` down to ``PREFIX_INDEX_ROOT -
#: PREFIX_INDEX_MAX_BUCKETS + 1``), still far above the low slots tests
#: and the crash harness hand out sequentially.
PREFIX_TRIE_ROOT = MAX_ROOTS - 1 - PREFIX_INDEX_MAX_BUCKETS

_M32 = 0xFFFFFFFF
_M64 = 0xFFFFFFFFFFFFFFFF


def page_hashes(tokens, page: int) -> list[int]:
    """Cumulative 48-bit prefix hash at every whole-page boundary:
    ``out[j] == hash_tokens(tokens[:(j + 1) * page])`` — one pass."""
    h = 0xCBF29CE484222325
    out: list[int] = []
    for j in range((len(tokens) // page) * page):
        h ^= int(tokens[j]) & _M64
        h = (h * 0x100000001B3) & _M64
        if (j + 1) % page == 0:
            out.append(h & _KEY_MASK)
    return out


def fingerprint(first_tok: int, last_tok: int) -> int:
    """Pack the edge's first token (low 32 bits) and the prefix's last
    token (low 16 bits) into one 48-bit word.  Keeping the top 16 bits
    zero means the word can never carry the pptr tag pattern — no
    remap, and the round-trip through a recovered record is exact."""
    return (int(first_tok) & _M32) | ((int(last_tok) & 0xFFFF) << 32)


def _record_checksum(span_word: int, end_page: int, start_page: int,
                     lease_sbs: int, fprint: int, key48: int) -> int:
    """16-bit content checksum over the sealed fields (words 3–7 + key).

    Words 0 (next) and 1 (parent) are excluded: a neighbour's unlink
    rewrites next in place, and a split re-parents children in place —
    neither must stale a live record's seal.  Same nonzero seed and
    tag-remap guarantees as ``prefix_index._record_checksum``.
    """
    h = 0x9E3779B97F4A7C15
    for v in (span_word, end_page, start_page, lease_sbs, fprint, key48):
        h ^= int(v) & _M64
        h = (h * 0x100000001B3) & _M64
    c = (h ^ (h >> 16) ^ (h >> 32) ^ (h >> 48)) & 0xFFFF
    if c == pp.PPTR_TAG:
        c ^= 0x5A5A
    return c


def record_seal_matches(reader, rec: int) -> bool:
    """Checksum-only validity (caller bounds-checks ``rec``)."""
    w3 = int(reader.read_word(rec + 3))
    w2 = int(reader.read_word(rec + 2)) & _M64
    if pp.decode(rec + 3, w3) is None:
        return False
    return (w2 >> 48) == _record_checksum(
        w3, int(reader.read_word(rec + 4)), int(reader.read_word(rec + 5)),
        int(reader.read_word(rec + 6)), int(reader.read_word(rec + 7)),
        w2 & _KEY_MASK)


def record_is_valid(r, rec: int) -> bool:
    heap = r.heap
    if not (heap.in_sb_region(rec) and heap.in_sb_region(rec + REC_WORDS - 1)):
        return False
    return record_seal_matches(r, rec)


@dataclasses.dataclass(frozen=True)
class TrieRecord:
    """One decoded durable trie-node record."""
    ptr: int                 # record block word address
    key: int                 # cumulative 48-bit hash of [0, end_page)
    parent: int | None       # parent record address (None = root child)
    span: int | None         # span head block address
    end_page: int
    start_page: int
    lease_sbs: int
    fprint: int


def iter_nodes(r, slot: int = PREFIX_TRIE_ROOT) -> Iterator[TrieRecord]:
    """Walk the node chain from root ``slot``; torn records are skipped,
    never yielded — the trie drives the same ``prefix_index.walk_chain``
    generator as the flat index, with its own record width and seal."""
    for _prev, rec, _nxt, valid in walk_chain(r, slot, REC_WORDS,
                                              record_seal_matches):
        if valid:
            yield TrieRecord(
                ptr=rec,
                key=int(r.read_word(rec + 2)) & _KEY_MASK,
                parent=pp.decode(rec + 1, r.read_word(rec + 1)),
                span=pp.decode(rec + 3, r.read_word(rec + 3)),
                end_page=int(r.read_word(rec + 4)),
                start_page=int(r.read_word(rec + 5)),
                lease_sbs=int(r.read_word(rec + 6)),
                fprint=int(r.read_word(rec + 7)) & _M64,
            )


def _unlink(r, slot: int, prev: int | None, nxt: int | None) -> None:
    """One durable chain unlink (root swing or predecessor rewrite)."""
    if prev is None:
        r.heap.set_root(slot, nxt)                    # durable flush+fence
    else:
        r.mem.write(prev, pp.PPTR_NULL if nxt is None
                    else pp.encode(prev, nxt))
        r.mem.flush(prev)
        r.mem.fence()


def prune_torn_nodes(r, slot: int = PREFIX_TRIE_ROOT) -> int:
    """Durably drop every node recovery must not trust; returns the
    number pruned.  Runs *before* the mark pass.

    Two passes:

    1. **Torn seals** — unlinked exactly like
       ``prefix_index.prune_torn_records`` (a torn record's span pptr
       never reaches the tracer; its block, unreachable, is swept).
    2. **Recoverability criterion** for everything that survived pass 1:
       a node is servable only if valid records cover its whole ancestry
       ``[0, start_page)`` — serving concatenates the ancestor page
       ranges up to the node's start.  Fixpoint from the root boundary:
       keep a node iff ``start_page == 0`` or some *kept* node's
       ``end_page`` equals its ``start_page``.  A kept node whose
       durable parent pointer dangles (its parent was pruned in pass 1,
       e.g. the mid-split torn half) is durably **re-parented** to a
       covering survivor — safe, because navigation matches cumulative
       hashes and the parent word is only shape — while uncovered nodes
       (and, transitively, their subtrees) are durably dropped: their
       prefix pages cannot be reassembled, so a lease on them would pin
       superblocks nobody can ever serve.
    """
    heap = r.heap
    pruned = 0
    # -- pass 1: torn seals --------------------------------------------------
    kept_prev = None               # last valid record kept on the chain
    for _prev, rec, nxt, valid in walk_chain(r, slot, REC_WORDS,
                                             record_seal_matches):
        if valid:
            kept_prev = rec
            continue
        pruned += 1
        _unlink(r, slot, kept_prev, nxt)
    # -- pass 2: coverage fixpoint ------------------------------------------
    recs = list(iter_nodes(r, slot))
    by_ptr = {n.ptr: n for n in recs}
    kept: set[int] = set()
    boundaries: set[int] = {0}
    changed = True
    while changed:
        changed = False
        for n in recs:
            if n.ptr in kept or n.start_page not in boundaries:
                continue
            kept.add(n.ptr)
            boundaries.add(n.end_page)
            changed = True
    # drop uncovered nodes durably (unlink before anything else — the
    # same remove discipline; the block and, if nothing else references
    # it, the span are reclaimed by the sweep that follows)
    if len(kept) != len(recs):
        prev = None
        rec = heap.get_root(slot)
        seen = set()
        while rec is not None and rec not in seen:
            seen.add(rec)
            nxt = pp.decode(rec, r.read_word(rec))
            if rec in kept or rec not in by_ptr:
                prev = rec
            else:
                pruned += 1
                _unlink(r, slot, prev, nxt)
            rec = nxt
    # re-parent kept nodes whose durable parent is gone or mismatched
    dirty: list[int] = []
    for ptr in kept:
        n = by_ptr[ptr]
        ok = (n.start_page == 0 and n.parent is None) or (
            n.parent in kept
            and by_ptr[n.parent].end_page == n.start_page)
        if ok:
            continue
        new_parent = None
        if n.start_page > 0:
            new_parent = next(
                (q for q in kept
                 if by_ptr[q].end_page == n.start_page and q != ptr), None)
        r.mem.write(ptr + 1, pp.PPTR_NULL if new_parent is None
                    else pp.encode(ptr + 1, new_parent))
        dirty.append(ptr + 1)
    if dirty:
        for line in sorted({w // 8 for w in dirty}):
            r.mem.flush(line * 8)  # once per dirty line, not per word
        r.mem.fence()
    return pruned


def retrim_after_recovery(r, slot: int = PREFIX_TRIE_ROOT
                          ) -> tuple[int, int]:
    """Shrink each surviving node's reconstructed full-extent lease back
    to its recorded superblock count; returns ``(records, trimmed)``.

    Several nodes may lease the same span (a split leaves both halves on
    it): each durable record produced one full-extent lease in the mark
    pass, and ``span_trim`` releases exactly one lease's tail — the
    per-record loop is order-independent.
    """
    n = trimmed = 0
    for rec in iter_nodes(r, slot):
        n += 1
        if rec.span is None or rec.lease_sbs < 1:
            continue
        try:
            ext = r.span_extent(rec.span)
        except ValueError:          # defensive: never reachable by design
            continue
        if rec.lease_sbs < ext:
            r.span_trim(rec.span, rec.lease_sbs)
            trimmed += 1
    return n, trimmed


# ---------------------------------------------------------------------------
# Transient tree + the write protocol
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class TrieNode:
    """Transient mirror of one durable node.

    ``tokens``/``page_keys`` exist only for nodes published this
    process: per-page cumulative hashes enable mid-edge partial matching
    and splits.  Recovered nodes carry neither (both died with the
    crash) and match all-or-nothing at node granularity — full-key plus
    token fingerprint — the documented residual of page-key transience.
    """
    ptr: int                     # durable record address
    key: int
    span: int
    start_page: int
    end_page: int
    lease_sbs: int
    first_tok: int
    last_tok: int
    parent: "TrieNode | None" = None
    children: list = dataclasses.field(default_factory=list)
    tokens: tuple | None = None          # full prefix tokens [0, end_page)
    page_keys: list | None = None        # cum. hash per page of the edge


class PrefixTrie:
    """Host-side durable token-radix prefix trie over one ``Ralloc``
    heap.  ``page`` is tokens per page, ``sb_pages`` pages per
    superblock (``lease_sbs = ceil(end_page / sb_pages)``)."""

    def __init__(self, r, slot: int = PREFIX_TRIE_ROOT, *, page: int = 4,
                 sb_pages: int = 1):
        self.r = r
        self.slot = slot
        self.page = int(page)
        self.sb_pages = int(sb_pages)
        self.roots: list[TrieNode] = []
        self._by_ptr: dict[int, TrieNode] = {}
        # (re)register the typed root — filter functions are
        # re-registered every execution, never persisted (paper §4.5.1)
        r.get_root(slot, TYPENAME)
        self._rebuild()

    # ----------------------------------------------------------------- reads
    def nodes(self) -> list[TrieNode]:
        out: list[TrieNode] = []
        stack = list(self.roots)
        while stack:
            n = stack.pop()
            out.append(n)
            stack.extend(n.children)
        return out

    def _lease_for(self, end_page: int) -> int:
        return -(-int(end_page) // self.sb_pages)

    def _fp_ok(self, node: TrieNode, tokens) -> bool:
        return (int(tokens[node.start_page * self.page]) & _M32
                == node.first_tok
                and int(tokens[node.end_page * self.page - 1]) & 0xFFFF
                == node.last_tok)

    def match(self, tokens) -> tuple[TrieNode | None, int]:
        """Longest-prefix match: ``(node, pages)`` where ``pages`` whole
        pages of ``tokens`` are covered and ``node`` contains the last
        matched page (``pages < node.end_page`` ⇒ the match ends
        mid-edge and a split would materialize the boundary).
        ``(None, 0)`` when nothing matches."""
        tokens = tuple(int(t) for t in tokens)
        n = len(tokens) // self.page
        if n == 0:
            return None, 0
        hs = page_hashes(tokens, self.page)
        best: TrieNode | None = None
        depth = 0
        children = self.roots
        while depth < n:
            stepped = False
            for c in children:
                if c.start_page != depth:
                    continue
                if c.page_keys is not None:
                    edge = c.end_page - c.start_page
                    i = 0
                    while (i < edge and depth + i < n
                           and c.page_keys[i] == hs[depth + i]):
                        i += 1
                    if i == 0:
                        continue
                    # exact-token guard: a 48-bit page-hash collision
                    # must read as a miss, never serve foreign KV
                    a = depth * self.page
                    b = (depth + i) * self.page
                    if tokens[a:b] != c.tokens[a:b]:
                        continue
                    if i < edge:
                        return c, depth + i          # mid-edge partial
                    best, depth, stepped = c, depth + i, True
                    break
                # recovered node: all-or-nothing — cumulative key plus
                # token fingerprint (satellite: even recovered entries
                # verify tokens cheaply before serving)
                if (n >= c.end_page and hs[c.end_page - 1] == c.key
                        and self._fp_ok(c, tokens)):
                    best, depth, stepped = c, c.end_page, True
                    break
            if not stepped:
                break
            children = best.children
        return best, depth

    def lookup(self, tokens) -> tuple[TrieNode | None, int]:
        """Serving-path alias for :meth:`match` (read-only: a mid-edge
        result is reported, not split)."""
        return self.match(tokens)

    # ---------------------------------------------------------------- writes
    def insert(self, tokens, span_ptr: int) -> TrieNode | None:
        """Publish ``tokens``' whole-page prefix backed by ``span_ptr``
        (the publisher's own span, holding the full prefix at identity
        offsets).  Splits the trie as needed, then commits ONE new node
        covering the unmatched page range.  Returns the deepest node
        covering the prompt (existing or new), or None when the heap
        cannot place the record (the publish then simply doesn't happen
        — nothing transient leaks)."""
        out = self.insert_batch([(tokens, span_ptr)])
        return out[0]

    def insert_batch(self, items) -> list[TrieNode | None]:
        """Group-commit insert: N publishes share ONE field fence, ONE
        seal fence and ONE root swing (splits they require commit first,
        each its own small batch).  Arena pressure degrades the whole
        batch (None per item) — record blocks either all place or the
        trie is left untouched."""
        results: list[TrieNode | None] = []
        news: list[TrieNode] = []
        for tokens, span_ptr in items:
            tokens = tuple(int(t) for t in tokens)
            n = len(tokens) // self.page
            if n == 0:
                results.append(None)
                continue
            node, k = self.match(tokens)
            if k == n:
                results.append(node)           # already fully covered
                continue
            if node is not None and k < node.end_page:
                mid = self.split(node, k)
                if mid is None:                # degrade to a boundary hit
                    while node is not None and node.end_page > k:
                        node = node.parent
                    k = node.end_page if node is not None else 0
                else:
                    node = mid
            hs = page_hashes(tokens, self.page)
            new = TrieNode(
                ptr=-1, key=hs[n - 1], span=int(span_ptr), start_page=k,
                end_page=n, lease_sbs=self._lease_for(n),
                first_tok=int(tokens[k * self.page]) & _M32,
                last_tok=int(tokens[n * self.page - 1]) & 0xFFFF,
                parent=node, tokens=tokens[:n * self.page],
                page_keys=hs[k:n])
            news.append(new)
            results.append(new)
        if news and not self._commit_new(news):
            results = [None if isinstance(x, TrieNode) and x.ptr < 0 else x
                       for x in results]
        return results

    def _commit_new(self, news: list[TrieNode]) -> bool:
        """The insert commit: attach ``news`` (parents before children)
        as one chain segment.  See the module docstring for the fence
        ordering."""
        r = self.r
        for nd in news:
            r.span_acquire(nd.span, nd.lease_sbs)
        # content fence: the published pages' application flushes become
        # durable before the trie can claim the prefix exists (elided
        # when no flush is pending — a bare sfence commits nothing)
        r.fence_if_pending()
        recs = [r.malloc(REC_BYTES) for _ in news]
        if any(rec is None for rec in recs):
            for rec in recs:
                if rec is not None:
                    r.free(rec)
            for nd in news:
                r.span_release(nd.span, nd.lease_sbs)
            return False
        head = r.heap.get_root(self.slot)
        for nd, rec in zip(news, recs):
            nd.ptr = rec
        seals = []
        for i, (nd, rec) in enumerate(zip(news, recs)):
            nxt = recs[i + 1] if i + 1 < len(recs) else head
            r.write_word(rec, pp.PPTR_NULL if nxt is None
                         else pp.encode(rec, nxt))
            # a batch-internal parent already has its ptr (parents
            # precede children in ``news``)
            par = nd.parent.ptr if nd.parent is not None else None
            r.write_word(rec + 1, pp.PPTR_NULL if par is None
                         else pp.encode(rec + 1, par))
            span_word = pp.encode(rec + 3, nd.span)
            r.write_word(rec + 3, span_word)
            r.write_word(rec + 4, nd.end_page)
            r.write_word(rec + 5, nd.start_page)
            r.write_word(rec + 6, nd.lease_sbs)
            fp = fingerprint(nd.first_tok, nd.last_tok)
            r.write_word(rec + 7, fp)
            cksum = _record_checksum(span_word, nd.end_page, nd.start_page,
                                     nd.lease_sbs, fp, nd.key)
            seals.append((rec, nd.key | (cksum << 48)))
        if not is_suppressed("prefix_trie.commit.fields_persist"):
            r.flush_ranges((rec, REC_WORDS) for rec in recs)
            r.fence()              # the ONE fence N field groups share
        r.mem.note("trie_seal", records=list(recs))
        for rec, seal in seals:
            r.write_word(rec + 2, seal)
        if not is_suppressed("prefix_trie.commit.records_persist"):
            r.flush_ranges((rec + 2, 1) for rec, _ in seals)
            r.fence()              # the ONE fence N sealed records share
        r.mem.note("trie_attach", records=list(recs), slot=self.slot)
        r.set_root(self.slot, recs[0], TYPENAME)   # single swing (f+f)
        r.mem.note("publish_end", record=recs[0], slot=self.slot)
        # transient attach
        for nd in news:
            self._by_ptr[nd.ptr] = nd
            if nd.parent is None:
                self.roots.append(nd)
            else:
                nd.parent.children.append(nd)
        return True

    def split(self, node: TrieNode, pages: int) -> TrieNode | None:
        """Materialize interior boundary ``pages`` of ``node`` as an
        explicit node: X ``[s, e)`` becomes M ``[s, pages)`` + X'
        ``[pages, e)`` on the same span, spliced into X's chain position
        with ONE relink write.  Returns M, or None when the heap cannot
        place the pair (no split happens — callers fall back to the
        deepest existing boundary).  Only in-process nodes split:
        recovered nodes have no page keys to split an edge by."""
        r = self.r
        if node.tokens is None or node.page_keys is None:
            raise ValueError("cannot split a recovered node (no page keys)")
        if not (node.start_page < pages < node.end_page):
            raise ValueError(
                f"split boundary {pages} outside ({node.start_page}, "
                f"{node.end_page})")
        m_lease = self._lease_for(pages)
        # record ⇔ lease stays 1:1: both new leases up front, the old
        # record's lease drops at the end (net: the span gains M's)
        r.span_acquire(node.span, m_lease)
        r.span_acquire(node.span, node.lease_sbs)
        r.fence_if_pending()           # content boundary, as in _commit_new
        m_rec = r.malloc(REC_BYTES)
        x_rec = r.malloc(REC_BYTES) if m_rec is not None else None
        if m_rec is None or x_rec is None:
            if m_rec is not None:
                r.free(m_rec)
            r.span_release(node.span, m_lease)
            r.span_release(node.span, node.lease_sbs)
            return None
        old = node.ptr
        old_next = pp.decode(old, r.read_word(old))
        par = node.parent.ptr if node.parent is not None else None
        tok = node.tokens
        pg = self.page
        cut = pages - node.start_page
        m_key = node.page_keys[cut - 1]
        m_fp = fingerprint(tok[node.start_page * pg], tok[pages * pg - 1])
        x_fp = fingerprint(tok[pages * pg], tok[node.end_page * pg - 1])
        # M fields
        r.write_word(m_rec, pp.encode(m_rec, x_rec))
        r.write_word(m_rec + 1, pp.PPTR_NULL if par is None
                     else pp.encode(m_rec + 1, par))
        m_span_word = pp.encode(m_rec + 3, node.span)
        r.write_word(m_rec + 3, m_span_word)
        r.write_word(m_rec + 4, pages)
        r.write_word(m_rec + 5, node.start_page)
        r.write_word(m_rec + 6, m_lease)
        r.write_word(m_rec + 7, m_fp)
        # X' fields
        r.write_word(x_rec, pp.PPTR_NULL if old_next is None
                     else pp.encode(x_rec, old_next))
        r.write_word(x_rec + 1, pp.encode(x_rec + 1, m_rec))
        x_span_word = pp.encode(x_rec + 3, node.span)
        r.write_word(x_rec + 3, x_span_word)
        r.write_word(x_rec + 4, node.end_page)
        r.write_word(x_rec + 5, pages)
        r.write_word(x_rec + 6, node.lease_sbs)
        r.write_word(x_rec + 7, x_fp)
        if not is_suppressed("prefix_trie.commit.fields_persist"):
            r.flush_ranges([(m_rec, REC_WORDS), (x_rec, REC_WORDS)])
            r.fence()              # both halves' fields: ONE fence
        r.mem.note("trie_seal", records=[m_rec, x_rec])
        m_ck = _record_checksum(m_span_word, pages, node.start_page,
                                m_lease, m_fp, m_key)
        x_ck = _record_checksum(x_span_word, node.end_page, pages,
                                node.lease_sbs, x_fp, node.key)
        r.write_word(m_rec + 2, m_key | (m_ck << 48))
        r.write_word(x_rec + 2, node.key | (x_ck << 48))
        if not is_suppressed("prefix_trie.commit.records_persist"):
            r.flush_ranges([(m_rec + 2, 1), (x_rec + 2, 1)])
            r.fence()              # both seals: ONE fence
        r.mem.note("trie_split_relink", records=[m_rec, x_rec], old=old,
                   slot=self.slot)
        # the ONE relink write replacing X with the pair
        prev = self._chain_pred(old)
        if prev is None:
            r.set_root(self.slot, m_rec, TYPENAME)
        else:
            r.write_word(prev, pp.encode(prev, m_rec))
            if not is_suppressed("prefix_trie.commit.relink_persist"):
                r.flush_range(prev, 1)
                r.fence()
        # X's children re-parent to X' — durable before X's block can be
        # freed and reused (a reused block under a stale parent pointer
        # would corrupt the recovered tree's shape)
        child_ptrs = [c.ptr for c in node.children if c.ptr >= 0]
        for cp in child_ptrs:
            r.write_word(cp + 1, pp.encode(cp + 1, x_rec))
        if child_ptrs and not is_suppressed(
                "prefix_trie.split.reparent_persist"):
            r.flush_ranges((cp + 1, 1) for cp in child_ptrs)
            r.fence()
        r.mem.note("trie_old_free", old=old, new=x_rec,
                   children=list(child_ptrs), slot=self.slot)
        r.mem.note("lease_release", record=old, slot=self.slot)
        r.span_release(node.span, node.lease_sbs)
        r.free(old)
        # transient restructure: node becomes X', M takes its place
        m = TrieNode(
            ptr=m_rec, key=m_key, span=node.span,
            start_page=node.start_page, end_page=pages, lease_sbs=m_lease,
            first_tok=int(tok[node.start_page * pg]) & _M32,
            last_tok=int(tok[pages * pg - 1]) & 0xFFFF,
            parent=node.parent, tokens=tok[:pages * pg],
            page_keys=node.page_keys[:cut])
        sibs = (self.roots if node.parent is None else node.parent.children)
        sibs[sibs.index(node)] = m
        del self._by_ptr[old]
        node.ptr = x_rec
        node.start_page = pages
        node.first_tok = int(tok[pages * pg]) & _M32
        node.page_keys = node.page_keys[cut:]
        node.parent = m
        m.children.append(node)
        self._by_ptr[m_rec] = m
        self._by_ptr[x_rec] = node
        return m

    def remove(self, node: TrieNode) -> bool:
        """Evict a leaf: durable unlink strictly before the lease drops,
        then the block frees.  Interior nodes refuse (their children's
        ancestry would become unservable)."""
        if node.children:
            raise ValueError("remove: node has children (leaves only)")
        r = self.r
        if node.ptr < 0 or node.ptr not in self._by_ptr:
            return False
        nxt = pp.decode(node.ptr, r.read_word(node.ptr))
        prev = self._chain_pred(node.ptr)
        if prev is None:
            r.set_root(self.slot, nxt, TYPENAME)
        else:
            r.write_word(prev, pp.PPTR_NULL if nxt is None
                         else pp.encode(prev, nxt))
            if not is_suppressed("prefix_trie.remove.unlink_persist"):
                r.flush_range(prev, 1)
                r.fence()
        r.mem.note("lease_release", record=node.ptr, slot=self.slot)
        r.span_release(node.span, node.lease_sbs)
        r.free(node.ptr)
        del self._by_ptr[node.ptr]
        sibs = (self.roots if node.parent is None else node.parent.children)
        sibs.remove(node)
        node.ptr = -1
        return True

    def clear(self) -> int:
        """Remove every node, leaves inward; returns the count."""
        n = 0
        while True:
            leaves = [nd for nd in self.nodes() if not nd.children]
            if not leaves:
                return n
            for leaf in leaves:
                self.remove(leaf)
                n += 1

    # -------------------------------------------------------------- plumbing
    def _chain_pred(self, target: int) -> int | None:
        """Durable-chain predecessor of record ``target`` (None = head)."""
        for prev, rec, _nxt, _valid in walk_chain(
                self.r, self.slot, REC_WORDS, record_seal_matches):
            if rec == target:
                return prev
        raise ValueError(f"record {target} not on the chain")

    def _rebuild(self) -> None:
        """Transient tree from the durable records (post-recovery or
        fresh attach).  ``prune_torn_nodes`` has already repaired parent
        pointers durably; the coverage fallback here is defense in
        depth.  Recovered nodes carry no tokens/page keys."""
        self.roots = []
        self._by_ptr = {}
        recs = list(iter_nodes(self.r, self.slot))
        nodes: dict[int, TrieNode] = {}
        for rec in recs:
            nodes[rec.ptr] = TrieNode(
                ptr=rec.ptr, key=rec.key, span=rec.span,
                start_page=rec.start_page, end_page=rec.end_page,
                lease_sbs=rec.lease_sbs,
                first_tok=int(rec.fprint) & _M32,
                last_tok=(int(rec.fprint) >> 32) & 0xFFFF)
        by_rec = {rec.ptr: rec for rec in recs}
        for rec in recs:
            nd = nodes[rec.ptr]
            par = rec.parent
            if (par is not None and par in nodes and par != rec.ptr
                    and by_rec[par].end_page == rec.start_page):
                nd.parent = nodes[par]
            elif rec.start_page > 0:
                cover = next((p for p, q in by_rec.items()
                              if q.end_page == rec.start_page
                              and p != rec.ptr), None)
                nd.parent = nodes[cover] if cover is not None else None
                if nd.parent is None:
                    continue           # unservable orphan: not attached
            if nd.parent is None:
                self.roots.append(nd)
            else:
                nd.parent.children.append(nd)
            self._by_ptr[rec.ptr] = nd
