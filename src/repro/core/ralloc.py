"""Ralloc — nonblocking recoverable persistent allocator (paper §4).

Faithful host-side port of the paper's algorithm:

  * size-class-segregated allocation with per-thread caches (the fast path
    touches no shared state at all);
  * global superblock free list and per-class partial lists as Treiber
    stacks of *descriptors* (single-word CAS heads with ABA counters);
  * per-superblock block free lists threaded through the first word of
    each free block as a self-relative pptr (transient — never flushed);
  * anchors (state | avail | count | tag) updated with one CAS;
  * the only *persistent* writes during normal operation: a superblock's
    ``size_class``/``block_size`` at superblock (re)initialization and the
    region ``used`` watermark at expansion — each a write-back + fence.
    Typical mallocs/frees persist **nothing** (the paper's headline
    property);
  * ``recover()`` (see ``core.recovery``) reconstructs every transient
    structure from the persisted minimum plus GC reachability.

Addresses are word indices into the heap array; the public API hands out
block addresses ("pointers") that test data structures store as pptrs.
"""

from __future__ import annotations

import math
import threading

from . import layout, recovery
from .filters import FilterRegistry, conservative_filter
from .heap import PersistentHeap
from .layout import (ANCHOR_NIL_AVAIL, D_ANCHOR, D_BLOCK_SIZE, D_NEXT_FREE,
                     D_NEXT_PARTIAL, D_SIZE_CLASS, EMPTY, FULL, HeapConfig,
                     LARGE_CLASS, LARGE_CONT, PARTIAL, SB_SIZE, SB_WORDS,
                     WORD, pack_anchor, pack_head, unpack_anchor, unpack_head)
from . import pptr as pp
from .spans import FreeRunIndex, SpanRegistry


class OutOfMemory(Exception):
    pass


class Ralloc:
    """One persistent heap + allocator instance (paper Fig. 1 API)."""

    def __init__(self, path: str | None, size: int, *, sim_nvm: bool = False,
                 seed: int = 0, tcache_cap: int = 64, persist: bool = True,
                 expand_sbs: int = 16, keep_half: bool = False,
                 flush_ns: int = 0, fence_ns: int = 0, backing=None):
        """``persist=False`` disables flush/fence → LRMalloc-equivalent mode.

        ``backing`` hands the heap a pre-existing durable image (an int64
        array) instead of a file — crash-injection tests use it to reopen
        snapshots captured at persist boundaries.
        """
        self.config = HeapConfig(size=size, sim_nvm=sim_nvm, seed=seed,
                                 tcache_cap=tcache_cap, expand_sbs=expand_sbs,
                                 flush_ns=flush_ns, fence_ns=fence_ns)
        self.keep_half = keep_half
        self.heap = PersistentHeap(path, self.config, backing=backing)
        self.persist_on = persist
        self.filters = FilterRegistry()
        from .filters import register_stock_filters
        register_stock_filters(self.filters)
        self._root_filters: dict[int, str | None] = {}
        self._tls = threading.local()
        self._all_caches: list[list[list[int]]] = []
        self._caches_lock = threading.Lock()
        self._large_lock = threading.Lock()   # serializes span placement
        # transient span metadata (never flushed; GC-reconstructed):
        # refcounts per live span head + the size-bucketed free-run index
        # that mirrors free-stack membership (always take _large_lock
        # before _free_lock when both are needed)
        self.spans = SpanRegistry()
        self._run_index = FreeRunIndex()
        self._free_lock = threading.Lock()
        self._closed = False
        self.dirty_restart = self.heap.init()

    # ------------------------------------------------------------------ misc
    @property
    def mem(self):
        return self.heap.mem

    def _persist(self, *words: int) -> None:
        """flush(+fence) persistent fields — the paper's bold writes."""
        if self.persist_on:
            for w in words:
                self.mem.flush(w)
            self.mem.fence()

    def _tcache(self) -> list[list[int]]:
        c = getattr(self._tls, "cache", None)
        if c is None:
            c = [[] for _ in range(layout.NUM_CLASSES)]
            self._tls.cache = c
            with self._caches_lock:
                self._all_caches.append(c)
        return c

    def drop_all_caches(self) -> None:
        """Stop-the-world discard of every thread cache (recovery step 2)."""
        with self._caches_lock:
            for c in self._all_caches:
                for cls in range(layout.NUM_CLASSES):
                    c[cls].clear()

    # ------------------------------------------------------------------- API
    def recover(self) -> dict:
        """Offline GC + metadata reconstruction; returns recovery stats."""
        return recovery.recover(self)

    def close(self) -> None:
        """Return cached blocks, write back the heap, clear the dirty flag."""
        if self._closed:
            return
        cache = self._tcache()
        for cls in range(1, layout.NUM_CLASSES):
            if cache[cls]:
                self._flush_cache(cls, keep=0)
        self.heap.close()
        self._closed = True

    def malloc(self, size: int) -> int | None:
        """Allocate ``size`` bytes; returns the block word address (or None)."""
        if size <= 0:
            return None
        cls = layout.size_to_class(size)
        if cls == LARGE_CLASS:
            return self._malloc_large(size)
        cache = self._tcache()[cls]
        if not cache and not self._refill(cls):
            return None
        return cache.pop()

    def free(self, ptr: int) -> None:
        sb = self.heap.sb_of(ptr)
        assert 0 <= sb < self.config.num_sbs, "free of non-heap pointer"
        cls = self.mem.read(self.desc(sb, D_SIZE_CLASS))
        if cls == LARGE_CONT:
            # interior pointer into a live large span: redirect to the
            # owning head superblock instead of indexing the thread cache
            # with the sentinel (which silently corrupted the last class)
            while cls == LARGE_CONT:
                sb -= 1
                cls = self.mem.read(self.desc(sb, D_SIZE_CLASS))
            if cls != LARGE_CLASS:
                raise ValueError(
                    f"free of pointer {ptr} inside an orphaned large-span "
                    f"continuation (no owning head superblock)")
        if cls == LARGE_CLASS:
            if self.mem.read(self.desc(sb, D_BLOCK_SIZE)) <= 0:
                raise ValueError(
                    f"double/invalid free of large block at superblock {sb}")
            # refcounted span (see core.spans): while other holders remain,
            # a free is a pure transient decrement — nothing persisted, the
            # span stays placed.  Only the last reference tears it down.
            if self.spans.release(sb) > 0:
                return
            self._free_large(sb)
            return
        cache = self._tcache()[cls]
        cache.append(ptr)
        if len(cache) > self._cache_cap(cls):
            # paper: transfer the cache "in its entirety"; keep_half is the
            # Makalu-style locality tweak (beyond-paper option, §6.3 discussion)
            keep = len(cache) // 2 if self.keep_half else 0
            self._flush_cache(cls, keep=keep)

    # -------------------------------------------------------- span refcounts
    def span_acquire(self, ptr: int) -> int:
        """Take one extra (transient) reference on a live large span.

        ``ptr`` must be the span head block address.  Returns the new
        refcount.  Raises on a dead / non-head pointer — the host-side
        strictness mirror of the device's masked no-op ``acquire_span``
        (same asymmetry the feature matrix documents for ``free_large``).
        Acquire persists nothing: after a crash the count is rebuilt by
        counting root-reachable references to the head during GC.
        """
        sb = self.heap.sb_of(ptr)
        cls = self.mem.read(self.desc(sb, D_SIZE_CLASS))
        bs = self.mem.read(self.desc(sb, D_BLOCK_SIZE))
        if cls != LARGE_CLASS or bs <= 0 or ptr != self.heap.sb_word(sb):
            raise ValueError(
                f"span_acquire of non-head/dead span pointer {ptr}")
        return self.spans.acquire(sb)

    def span_release(self, ptr: int) -> None:
        """Drop one reference (frees the span when the last one drops) —
        an alias of ``free`` named for symmetry with ``span_acquire``."""
        self.free(ptr)

    def span_refcount(self, ptr: int) -> int:
        """Current transient refcount of the span holding ``ptr``."""
        return self.spans.count(self.heap.sb_of(ptr))

    def _cache_cap(self, cls: int) -> int:
        """Cache capacity: one superblock's worth of blocks (LRMalloc)."""
        return max(self.config.tcache_cap,
                   layout.blocks_per_sb(layout.class_block_size(cls)))

    def set_root(self, i: int, ptr: int | None, typename: str | None = None) -> None:
        self._root_filters[i] = typename
        self.heap.set_root(i, ptr)

    def get_root(self, i: int, typename: str | None = None) -> int | None:
        """Retrieve root ``i`` and (re)register its filter type (paper §4.5.1)."""
        self._root_filters[i] = typename
        return self.heap.get_root(i)

    # ------------------------------------------------------- address helpers
    def desc(self, sb_idx: int, field: int) -> int:
        return self.heap.desc_word(sb_idx, field)

    def block_words(self, block_size: int) -> int:
        return block_size // WORD if block_size % WORD == 0 else max(1, math.ceil(block_size / WORD))

    # --------------------------------------------------------- Treiber lists
    def _push_list(self, head_word: int, next_field: int, sb_idx: int) -> None:
        m = self.mem
        nf = self.desc(sb_idx, next_field)
        while True:
            old = m.read(head_word)
            idx, ctr = unpack_head(old)
            m.write(nf, idx if idx >= 0 else -1)
            if m.cas(head_word, old, pack_head(sb_idx, ctr + 1)):
                return

    def _pop_list(self, head_word: int, next_field: int) -> int | None:
        m = self.mem
        while True:
            old = m.read(head_word)
            idx, ctr = unpack_head(old)
            if idx < 0:
                return None
            nxt = m.read(self.desc(idx, next_field))
            if m.cas(head_word, old, pack_head(int(nxt), ctr + 1)):
                return idx

    # ------------------------------------------------- free stack + run index
    # All superblock free-stack traffic goes through these wrappers so the
    # size-bucketed run index (core.spans.FreeRunIndex) stays an exact
    # mirror of stack membership — the index is what lets the large-object
    # placement answer best-fit queries without draining + sorting the
    # stack on every request.
    def _free_push(self, sb: int) -> None:
        with self._free_lock:
            self._push_list(layout.M_FREE_HEAD, D_NEXT_FREE, sb)
            self._run_index.add(sb)

    def _free_pop(self) -> int | None:
        with self._free_lock:
            sb = self._pop_list(layout.M_FREE_HEAD, D_NEXT_FREE)
            if sb is not None:
                self._run_index.discard(sb)
            return sb

    # ------------------------------------------------------------ expansion
    def _expand(self, nsb: int) -> int | None:
        """Advance the used watermark by ``nsb`` superblocks (CAS+flush+fence).

        Returns the first new superblock index, or None if out of space.
        The watermark is durable *before* any block in the new superblocks
        can be handed out — recovery must never see reachable blocks above
        a stale watermark.
        """
        m = self.mem
        while True:
            old = m.read(layout.M_USED_SBS)
            if old + nsb > self.config.num_sbs:
                return None
            if m.cas(layout.M_USED_SBS, old, old + nsb):
                self._persist(layout.M_USED_SBS)
                return old

    # --------------------------------------------------------------- refill
    def _refill(self, cls: int) -> bool:
        """Recharge the thread cache for ``cls`` (paper §4.4)."""
        cache = self._tcache()[cls]
        bs = layout.class_block_size(cls)
        bw = self.block_words(bs)
        total = layout.blocks_per_sb(bs)
        m = self.mem
        phead = layout.M_PARTIAL_HEADS + cls

        while True:
            # 1. partial superblock of this class
            sb = self._pop_list(phead, D_NEXT_PARTIAL)
            if sb is not None:
                status, taken = self._reserve_all(sb)
                if status == "empty":      # became EMPTY while listed → retire
                    self._init_free_sb(sb)
                    self._free_push(sb)
                    continue
                if status == "full":       # raced empty-handed; try the next
                    continue
                avail, count = taken
                base = self.heap.sb_word(sb)
                w = base + avail * bw
                for _ in range(count):
                    cache.append(w)
                    nxt = pp.decode(w, m.read(w))
                    if nxt is None:
                        break
                    w = nxt
                return True

            # 2. free superblock (any class) — (re)initialize it for cls
            sb = self._free_pop()
            if sb is None:
                # 3. expand the used prefix of the superblock region.  A
                # concurrent span placement may be holding the *entire*
                # drained free stack (_claim_free_run), so re-check under
                # the placement lock before consuming fresh watermark —
                # expanding here would durably leak the address space the
                # free-run search exists to reclaim.
                with self._large_lock:
                    sb = self._free_pop()
                    if sb is None:
                        first = self._expand(self.config.expand_sbs)
                        if first is None:
                            first = self._expand(1)   # partial final expansion
                            if first is None:
                                return False
                            sb = first
                        else:
                            sb = first
                            for extra in range(first + 1,
                                               first + self.config.expand_sbs):
                                self._init_free_sb(extra)
                                self._free_push(extra)
            # persist size class & block size BEFORE any block escapes —
            # recovery depends on them (paper: "has to be persisted before a
            # superblock is used for allocation")
            m.write(self.desc(sb, D_SIZE_CLASS), cls)
            m.write(self.desc(sb, D_BLOCK_SIZE), bs)
            self._persist(self.desc(sb, D_SIZE_CLASS), self.desc(sb, D_BLOCK_SIZE))
            _, _, _, tag = unpack_anchor(m.read(self.desc(sb, D_ANCHOR)))
            m.write(self.desc(sb, D_ANCHOR),
                    pack_anchor(FULL, ANCHOR_NIL_AVAIL, 0, tag + 1))
            base = self.heap.sb_word(sb)
            for b in range(total):
                cache.append(base + b * bw)
            return True

    def _reserve_all(self, sb: int) -> tuple[str, tuple[int, int] | None]:
        """CAS the anchor to (FULL, nil, 0), reserving every free block."""
        m = self.mem
        aw = self.desc(sb, D_ANCHOR)
        while True:
            old = m.read(aw)
            state, avail, count, tag = unpack_anchor(old)
            if state == EMPTY or count == total_blocks(self, sb):
                return "empty", None             # retire-on-fetch (paper §4.4)
            if count == 0:
                return "full", None              # nothing to take
            if m.cas(aw, old, pack_anchor(FULL, ANCHOR_NIL_AVAIL, 0, tag + 1)):
                return "ok", (avail, count)

    def _init_free_sb(self, sb: int) -> None:
        m = self.mem
        _, _, _, tag = unpack_anchor(m.read(self.desc(sb, D_ANCHOR)))
        m.write(self.desc(sb, D_ANCHOR),
                pack_anchor(EMPTY, ANCHOR_NIL_AVAIL, 0, tag + 1))

    # ---------------------------------------------------------- cache flush
    def _flush_cache(self, cls: int, keep: int = 0) -> None:
        """Push cached blocks back to their superblocks' free lists."""
        cache = self._tcache()[cls]
        give = cache[keep:]
        del cache[keep:]
        bs = layout.class_block_size(cls)
        bw = self.block_words(bs)
        total = layout.blocks_per_sb(bs)
        by_sb: dict[int, list[int]] = {}
        for w in give:
            by_sb.setdefault(self.heap.sb_of(w), []).append(w)
        m = self.mem
        for sb, blocks in by_sb.items():
            base = self.heap.sb_word(sb)
            aw = self.desc(sb, D_ANCHOR)
            k = len(blocks)
            while True:
                old = m.read(aw)
                state, avail, count, tag = unpack_anchor(old)
                # thread the chain through the blocks' first words (transient)
                for i, w in enumerate(blocks[:-1]):
                    m.write(w, pp.encode(w, blocks[i + 1]))
                lastw = blocks[-1]
                if avail == ANCHOR_NIL_AVAIL:
                    m.write(lastw, pp.PPTR_NULL)
                else:
                    m.write(lastw, pp.encode(lastw, base + avail * bw))
                new_count = count + k
                new_state = EMPTY if new_count == total else (
                    PARTIAL if state == FULL else state)
                new_avail = (blocks[0] - base) // bw
                if m.cas(aw, old, pack_anchor(new_state, new_avail,
                                              new_count, tag + 1)):
                    break
            if state == FULL and new_state == EMPTY:
                self._free_push(sb)
            elif state == FULL and new_state == PARTIAL:
                self._push_list(layout.M_PARTIAL_HEADS + cls, D_NEXT_PARTIAL, sb)
            # PARTIAL→EMPTY: stays in the partial list; retired when fetched.

    # ----------------------------------------------------------------- large
    def _claim_free_run(self, nsb: int) -> int | None:
        """Best-fit contiguous-run search, driven by the size-bucketed
        run index (``core.spans.FreeRunIndex``).

        The index mirrors free-stack *membership* (every push/pop goes
        through ``_free_push``/``_free_pop``), so the best-fit answer —
        smallest run >= ``nsb``, leftmost on ties — is identical to the
        old drain-the-stack-and-sort search, and identical to the device
        allocator's suffix-min scan over ``sb_class == FREE_CLS``: host
        and device still place spans identically given identical free
        sets, and placement still depends only on membership, never on
        stack order (the placement-equivalence invariant).  What changed
        is cost: a miss is O(log) with zero stack traffic, and a hit
        only pops the stack until the claimed run's members are
        collected instead of draining + sorting everything.

        Returns the head superblock index, or None when no run of
        ``nsb`` exists.  Callers must hold ``_large_lock``: two
        concurrent claims would split one run across two searchers,
        making both miss it (one would then expand the watermark a
        fitting run exists for — the exact leak this search removes).
        """
        with self._free_lock:
            first = self._run_index.best_fit(nsb)
            if first is None:
                return None
            want = set(range(first, first + nsb))
            popped: list[int] = []
            while want:
                sb = self._pop_list(layout.M_FREE_HEAD, D_NEXT_FREE)
                if sb is None:
                    break
                popped.append(sb)
                want.discard(sb)
            if want:
                # the index drifted from the stack (an offline/raw stack
                # edit): the stack is fully drained now, so resync the
                # index to the drained membership and redo the search —
                # this degenerate path is exactly the old algorithm
                self._run_index.rebuild(popped)
                first = self._run_index.best_fit(nsb)
                if first is None:
                    for sb in popped:
                        self._push_list(layout.M_FREE_HEAD, D_NEXT_FREE, sb)
                    return None
            self._run_index.claim(first, nsb)
            for sb in popped:
                if not first <= sb < first + nsb:
                    self._push_list(layout.M_FREE_HEAD, D_NEXT_FREE, sb)
            return first

    def _malloc_large(self, size: int) -> int | None:
        nsb = math.ceil(size / SB_SIZE)
        # placement: best-fit over freed contiguous runs first — only when
        # no run fits does the span consume fresh watermark (the paper's
        # watermark-only policy leaks address space under span churn).
        # The lock serializes large-span *placement* only: the small-class
        # fast path stays synchronization-free, and the device allocator
        # gets the same atomicity by construction (one program step).
        with self._large_lock:
            first = self._claim_free_run(nsb)
            if first is None:
                first = self._expand(nsb)
                if first is None:
                    return None
        m = self.mem
        m.write(self.desc(first, D_SIZE_CLASS), LARGE_CLASS)
        m.write(self.desc(first, D_BLOCK_SIZE), size)
        to_persist = [self.desc(first, D_SIZE_CLASS), self.desc(first, D_BLOCK_SIZE)]
        for sb in range(first + 1, first + nsb):
            m.write(self.desc(sb, D_SIZE_CLASS), LARGE_CONT)
            m.write(self.desc(sb, D_BLOCK_SIZE), 0)
            to_persist.append(self.desc(sb, D_SIZE_CLASS))
        self._persist(*to_persist)
        _, _, _, tag = unpack_anchor(m.read(self.desc(first, D_ANCHOR)))
        m.write(self.desc(first, D_ANCHOR),
                pack_anchor(FULL, ANCHOR_NIL_AVAIL, 0, tag + 1))
        self.spans.register(first)           # one (transient) owner reference
        return self.heap.sb_word(first)

    def _free_large(self, first: int) -> None:
        m = self.mem
        size = m.read(self.desc(first, D_BLOCK_SIZE))
        nsb = math.ceil(size / SB_SIZE)
        # clear the persistent span records (head size + LARGE_CONT
        # continuation markers) *before* the superblocks become reachable
        # from the free list: a crash between the push and a lazy reset
        # would otherwise leave recovery staring at orphaned continuation
        # markers / a stale head that could resurrect the whole span
        to_persist = []
        for sb in range(first, first + nsb):
            m.write(self.desc(sb, D_SIZE_CLASS), 0)
            m.write(self.desc(sb, D_BLOCK_SIZE), 0)
            to_persist += [self.desc(sb, D_SIZE_CLASS),
                           self.desc(sb, D_BLOCK_SIZE)]
        self._persist(*to_persist)
        # the span re-enters the free set as one atomic unit: a placement
        # drain interleaving between the pushes would observe a torn run
        # (a prefix of the span), claim it misaligned, and leave stranded
        # fragments no later request can use
        self.spans.forget(first)
        with self._large_lock:
            for sb in range(first, first + nsb):
                self._init_free_sb(sb)
                self._free_push(sb)

    # ------------------------------------------------------------ block I/O
    # Convenience accessors used by test data structures & benchmarks: they
    # model application loads/stores to heap blocks (word granularity).
    def read_word(self, w: int) -> int:
        return self.mem.read(w)

    def write_word(self, w: int, v: int) -> None:
        self.mem.write(w, v)

    def flush_range(self, w: int, nwords: int) -> None:
        """Application-side durability (durable linearizability is the app's job)."""
        if self.persist_on:
            for line in range(w // 8, (w + max(nwords, 1) - 1) // 8 + 1):
                self.mem.flush(line * 8)

    def fence(self) -> None:
        if self.persist_on:
            self.mem.fence()


def total_blocks(r: Ralloc, sb: int) -> int:
    bs = r.mem.read(r.desc(sb, D_BLOCK_SIZE))
    return layout.blocks_per_sb(int(bs)) if bs > 0 else 0
