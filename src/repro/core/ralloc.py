"""Ralloc — nonblocking recoverable persistent allocator (paper §4).

Faithful host-side port of the paper's algorithm:

  * size-class-segregated allocation with per-thread caches (the fast path
    touches no shared state at all);
  * global superblock free list and per-class partial lists as Treiber
    stacks of *descriptors* (single-word CAS heads with ABA counters);
  * per-superblock block free lists threaded through the first word of
    each free block as a self-relative pptr (transient — never flushed);
  * anchors (state | avail | count | tag) updated with one CAS;
  * the only *persistent* writes during normal operation: a superblock's
    ``size_class``/``block_size`` at superblock (re)initialization and the
    region ``used`` watermark at expansion — each a write-back + fence.
    Typical mallocs/frees persist **nothing** (the paper's headline
    property);
  * ``recover()`` (see ``core.recovery``) reconstructs every transient
    structure from the persisted minimum plus GC reachability.

Addresses are word indices into the heap array; the public API hands out
block addresses ("pointers") that test data structures store as pptrs.
"""

from __future__ import annotations

import math
import threading

from . import layout, recovery
from .. import obs
from ..analysis.faults import is_suppressed
from .atomics import CACHELINE_WORDS
from .filters import FilterRegistry, conservative_filter
from .heap import PersistentHeap
from .layout import (ANCHOR_NIL_AVAIL, D_ANCHOR, D_BLOCK_SIZE, D_NEXT_FREE,
                     D_NEXT_PARTIAL, D_SIZE_CLASS, EMPTY, FULL, HeapConfig,
                     LARGE_CLASS, LARGE_CONT, PARTIAL, SB_SIZE, SB_WORDS,
                     WORD, pack_anchor, pack_head, unpack_anchor, unpack_head)
from . import pptr as pp
from .spans import FreeRunIndex, RangeLeaseTable


class OutOfMemory(Exception):
    pass


# Allocator-path metrics (cached at import: the hot-path cost is one
# bound call + enabled-flag branch; see repro.obs conventions).
_OBS_SMALL = obs.counter("alloc.small")
_OBS_LARGE = obs.counter("alloc.large")
_OBS_TCACHE_HIT = obs.counter("alloc.tcache_hit")
_OBS_TCACHE_MISS = obs.counter("alloc.tcache_miss")
_OBS_REFILL_PARTIAL = obs.counter("alloc.refill_partial")
_OBS_REFILL_FREE_SB = obs.counter("alloc.refill_free_sb")
_OBS_REFILL_EXPAND = obs.counter("alloc.refill_expand")
_OBS_GROWTH_SBS = obs.counter("alloc.watermark_growth_sbs")
_OBS_PLACE_RESYNC = obs.counter("placement.resync")
_OBS_PLACE_WATERMARK = obs.counter("placement.watermark")
_OBS_SPAN_ACQUIRE = obs.counter("span.acquire")
_OBS_SPAN_RELEASE = obs.counter("span.release")
_OBS_SPAN_TRIM = obs.counter("span.trim")
_OBS_LEASE_RELEASE = obs.counter("span.lease_release")
_OBS_SPAN_FREE = obs.counter("span.free")
_OBS_TAIL_TRIM = obs.counter("span.tail_trim")


class Ralloc:
    """One persistent heap + allocator instance (paper Fig. 1 API)."""

    def __init__(self, path: str | None, size: int, *, sim_nvm: bool = False,
                 seed: int = 0, tcache_cap: int = 64, persist: bool = True,
                 expand_sbs: int = 16, keep_half: bool = False,
                 flush_ns: int = 0, fence_ns: int = 0, backing=None):
        """``persist=False`` disables flush/fence → LRMalloc-equivalent mode.

        ``backing`` hands the heap a pre-existing durable image (an int64
        array) instead of a file — crash-injection tests use it to reopen
        snapshots captured at persist boundaries.
        """
        self.config = HeapConfig(size=size, sim_nvm=sim_nvm, seed=seed,
                                 tcache_cap=tcache_cap, expand_sbs=expand_sbs,
                                 flush_ns=flush_ns, fence_ns=fence_ns)
        self.keep_half = keep_half
        self.heap = PersistentHeap(path, self.config, backing=backing)
        self.persist_on = persist
        self.filters = FilterRegistry()
        from .filters import register_stock_filters
        register_stock_filters(self.filters)
        self._root_filters: dict[int, str | None] = {}
        self._tls = threading.local()
        self._all_caches: list[list[list[int]]] = []
        self._caches_lock = threading.Lock()
        # serializes span placement AND the lease-release decision
        # (reentrant: _release_range holds it across the decrement and
        # the _free_large/_trim_tail it decides on, which re-acquire it
        # around their free-stack pushes — without one lock over the
        # whole read-extent → decrement → free sequence, two concurrent
        # releases of a shared span could both observe a stale extent
        # and double-push the same tail superblocks)
        self._large_lock = threading.RLock()
        # transient span metadata (never flushed; GC-reconstructed):
        # per-superblock-range lease counts per live span + the
        # size-bucketed free-run index that mirrors free-stack membership
        # (always take _large_lock before _free_lock when both are needed)
        self.leases = RangeLeaseTable()
        self._run_index = FreeRunIndex()
        self._free_lock = threading.Lock()
        self._closed = False
        self.dirty_restart = self.heap.init()

    # ------------------------------------------------------------------ misc
    @property
    def mem(self):
        return self.heap.mem

    def _persist(self, *words: int) -> None:
        """flush(+fence) persistent fields — the paper's bold writes.

        One clwb per dirty *line*: adjacent descriptor fields (and the
        descriptors of neighbouring superblocks in a span batch) share
        cache lines, and re-flushing a line already scheduled with
        nothing newly dirty is pure waste (persist-lint: redundant
        flush).  Fence count is unchanged — ordering is identical."""
        if self.persist_on:
            m = self.mem
            seen_lines = set()
            for w in words:
                line = w // CACHELINE_WORDS
                if line not in seen_lines:
                    seen_lines.add(line)
                    m.flush(w)
            m.fence()

    def _tcache(self) -> list[list[int]]:
        c = getattr(self._tls, "cache", None)
        if c is None:
            c = [[] for _ in range(layout.NUM_CLASSES)]
            self._tls.cache = c
            with self._caches_lock:
                self._all_caches.append(c)
        return c

    def drop_all_caches(self) -> None:
        """Stop-the-world discard of every thread cache (recovery step 2)."""
        with self._caches_lock:
            for c in self._all_caches:
                for cls in range(layout.NUM_CLASSES):
                    c[cls].clear()

    # ------------------------------------------------------------------- API
    def recover(self) -> dict:
        """Offline GC + metadata reconstruction; returns recovery stats."""
        return recovery.recover(self)

    def close(self) -> None:
        """Return cached blocks, write back the heap, clear the dirty flag."""
        if self._closed:
            return
        cache = self._tcache()
        for cls in range(1, layout.NUM_CLASSES):
            if cache[cls]:
                self._flush_cache(cls, keep=0)
        self.heap.close()
        self._closed = True

    def malloc(self, size: int) -> int | None:
        """Allocate ``size`` bytes; returns the block word address (or None)."""
        if size <= 0:
            return None
        cls = layout.size_to_class(size)
        if cls == LARGE_CLASS:
            _OBS_LARGE.inc()
            return self._malloc_large(size)
        _OBS_SMALL.inc()
        cache = self._tcache()[cls]
        if cache:
            _OBS_TCACHE_HIT.inc()
        else:
            _OBS_TCACHE_MISS.inc()
            if not self._refill(cls):
                return None
        return cache.pop()

    def free(self, ptr: int) -> None:
        sb = self.heap.sb_of(ptr)
        assert 0 <= sb < self.config.num_sbs, "free of non-heap pointer"
        cls = self.mem.read(self.desc(sb, D_SIZE_CLASS))
        if cls == LARGE_CONT:
            # interior pointer into a live large span: redirect to the
            # owning head superblock instead of indexing the thread cache
            # with the sentinel (which silently corrupted the last class)
            while cls == LARGE_CONT:
                sb -= 1
                cls = self.mem.read(self.desc(sb, D_SIZE_CLASS))
            if cls != LARGE_CLASS:
                raise ValueError(
                    f"free of pointer {ptr} inside an orphaned large-span "
                    f"continuation (no owning head superblock)")
        if cls == LARGE_CLASS:
            # range-leased span (see core.spans): a plain free releases one
            # full-extent lease — while other leases remain the decrement is
            # purely transient and the leased prefix stays placed; only a
            # superblock range nobody leases any more actually frees (the
            # unleased tail via _trim_tail, everything when the head range's
            # last lease drops).  Check-dead + release are one locked step:
            # a racing last release could free and re-place this head.
            with self._large_lock:
                if self.mem.read(self.desc(sb, D_BLOCK_SIZE)) <= 0:
                    raise ValueError(
                        f"double/invalid free of large block at "
                        f"superblock {sb}")
                self._release_range(sb, 0, None)
            return
        cache = self._tcache()[cls]
        cache.append(ptr)
        if len(cache) > self._cache_cap(cls):
            # paper: transfer the cache "in its entirety"; keep_half is the
            # Makalu-style locality tweak (beyond-paper option, §6.3 discussion)
            keep = len(cache) // 2 if self.keep_half else 0
            self._flush_cache(cls, keep=keep)

    # ----------------------------------------------------------- span leases
    def _span_head(self, ptr: int) -> tuple[int, int]:
        """Validate ``ptr`` as a live span head; returns (head_sb, extent)."""
        sb = self.heap.sb_of(ptr)
        cls = self.mem.read(self.desc(sb, D_SIZE_CLASS))
        bs = self.mem.read(self.desc(sb, D_BLOCK_SIZE))
        if cls != LARGE_CLASS or bs <= 0 or ptr != self.heap.sb_word(sb):
            raise ValueError(
                f"span lease op on non-head/dead span pointer {ptr}")
        return sb, -(-int(bs) // SB_SIZE)

    def span_acquire(self, ptr: int, n_sbs: int | None = None) -> int:
        """Lease the ``n_sbs``-superblock *prefix* of a live large span
        (default: the whole remaining extent).

        ``ptr`` must be the span head block address.  Returns the new
        head-range lease count.  Raises on a dead / non-head pointer or a
        non-positive range — the host-side strictness mirror of the
        device's masked no-op ``acquire_span`` (same asymmetry the
        feature matrix documents for ``free_large``).  Acquire persists
        nothing: after a crash each root-reachable reference to the head
        is rebuilt as one full-extent lease during GC.
        """
        with self._large_lock:      # vs a concurrent release freeing it
            sb, ext = self._span_head(ptr)
            n = ext if n_sbs is None else n_sbs
            if n < 1:
                raise ValueError(f"span_acquire of an empty range ({n} sbs)")
            _OBS_SPAN_ACQUIRE.inc()
            self.leases.ensure(sb, ext)
            return self.leases.acquire(sb, min(n, ext))

    def span_release(self, ptr: int, n_sbs: int | None = None) -> None:
        """Drop one lease on the ``n_sbs``-superblock prefix (default: the
        whole remaining extent — equivalent to ``free``).  A range whose
        count drops to zero frees: the head range's last release tears
        down whatever remains of the span, an unleased tail suffix
        returns to the free set while the shared prefix stays placed.

        ``n_sbs`` must match a lease the caller actually holds.  The
        table is identity-free (counts, not holder ids), so a mismatched
        length that other holders' counts happen to cover is not
        detectable: it leaves an interior zero-count range that stays
        placed — a safe leak (paper Thm 5.4 direction: leak, never
        corrupt) reclaimed at the head range's last release — while a
        mismatch the counts do NOT cover raises ``LeaseUnderflow``."""
        if n_sbs is None:
            self.free(ptr)
            return
        with self._large_lock:      # validation + release are one step:
            # a concurrent last release could free the span and a new
            # placement reuse its head between the check and the act
            sb, _ = self._span_head(ptr)
            if n_sbs < 1:
                raise ValueError(
                    f"span_release of an empty range ({n_sbs} sbs)")
            _OBS_SPAN_RELEASE.inc()
            self._release_range(sb, 0, n_sbs)

    def span_trim(self, ptr: int, n_keep: int,
                  n_held: int | None = None) -> int:
        """Shrink the caller's lease to the ``n_keep`` prefix, freeing
        whatever tail no other holder leases (the decode-ahead reserver's
        "sequence finished short" path).  Returns the span's remaining
        extent in superblocks.

        ``n_held`` is the length of the lease being shrunk — default: the
        span's whole current extent, i.e. a full-extent lease.  A caller
        re-trimming a lease it already shrank (while other holders pin
        the extent) MUST pass its current ``n_held``; defaulting would
        release ``[n_keep, extent)`` and silently consume the other
        holders' tail leases.  ``n_keep`` >= the held length is a no-op;
        ``n_keep`` < 1 raises (the head range cannot be trimmed away —
        that is ``free``'s job)."""
        with self._large_lock:
            sb, ext = self._span_head(ptr)
            if n_keep < 1:
                raise ValueError(f"span_trim cannot drop the head (keep="
                                 f"{n_keep})")
            b = ext if n_held is None else min(n_held, ext)
            if n_keep >= b:
                return ext
            _OBS_SPAN_TRIM.inc()
            self._release_range(sb, n_keep, b)
            _, ext = self._span_head(ptr)
            return ext

    def span_refcount(self, ptr: int) -> int:
        """Current transient lease count at the span's *head* range."""
        return self.leases.count(self.heap.sb_of(ptr))

    def span_lease_counts(self, ptr: int) -> list[int]:
        """Per-superblock lease counts over the span holding ``ptr`` —
        comparable with the device's ``span_refs`` vector slice."""
        return self.leases.counts(self.heap.sb_of(ptr))

    def span_extent(self, ptr: int) -> int:
        """Current persisted extent (superblocks) of the live span headed
        at ``ptr`` — the device analogue is ``span_sbs(sb_block_words)``.
        Raises on a dead / non-head pointer."""
        return self._span_head(ptr)[1]

    def _release_range(self, head: int, a_sbs: int, b_sbs: int | None
                       ) -> None:
        """Drop one lease on superblocks ``[head+a, head+b)`` and free
        whatever the decrement leaves unleased (tentpole mechanics):

          * head-range count hits zero → ``_free_large`` on the whole
            remaining extent (stray interior counts from conservative
            reconstruction cannot outlive the head — every genuine lease
            is a prefix and includes it);
          * a zero-count tail suffix → ``_trim_tail`` returns exactly
            those superblocks to the free set and durably shrinks the
            head's size record so recovery can never resurrect them.

        Raises ``LeaseUnderflow`` (a ``ValueError``) if the range is not
        fully leased — the host strictness the device mirrors as a
        masked no-op.  ``_large_lock`` (reentrant) covers the whole
        read-extent → decrement → free/trim sequence: concurrent
        releases of a shared span must not both act on a stale extent
        (double-pushing the same tail superblocks to the free set).
        """
        with self._large_lock:
            size = int(self.mem.read(self.desc(head, D_BLOCK_SIZE)))
            ext = -(-size // SB_SIZE)
            if ext < 1:      # lost a release race: the span already died
                raise ValueError(
                    f"double/invalid release of the dead span at "
                    f"superblock {head}")
            self.leases.ensure(head, ext)
            b = ext if b_sbs is None else min(b_sbs, ext)
            _OBS_LEASE_RELEASE.inc()
            head_count, new_ext = self.leases.release(head, head + a_sbs,
                                                      head + b)
            if head_count == 0:
                self._free_large(head)
            elif new_ext < ext:
                self._trim_tail(head, new_ext, ext)

    def _cache_cap(self, cls: int) -> int:
        """Cache capacity: one superblock's worth of blocks (LRMalloc)."""
        return max(self.config.tcache_cap,
                   layout.blocks_per_sb(layout.class_block_size(cls)))

    def set_root(self, i: int, ptr: int | None, typename: str | None = None) -> None:
        self._root_filters[i] = typename
        self.heap.set_root(i, ptr)

    def set_roots(self, pairs, typename: str | None = None) -> None:
        """Swing several typed roots behind one shared fence."""
        pairs = list(pairs)
        for i, _ in pairs:
            self._root_filters[i] = typename
        self.heap.set_roots(pairs)

    def get_root(self, i: int, typename: str | None = None) -> int | None:
        """Retrieve root ``i`` and (re)register its filter type (paper §4.5.1)."""
        self._root_filters[i] = typename
        return self.heap.get_root(i)

    # ------------------------------------------------------- address helpers
    def desc(self, sb_idx: int, field: int) -> int:
        return self.heap.desc_word(sb_idx, field)

    def block_words(self, block_size: int) -> int:
        return block_size // WORD if block_size % WORD == 0 else max(1, math.ceil(block_size / WORD))

    # --------------------------------------------------------- Treiber lists
    def _push_list(self, head_word: int, next_field: int, sb_idx: int) -> None:
        m = self.mem
        nf = self.desc(sb_idx, next_field)
        while True:
            old = m.read(head_word)
            idx, ctr = unpack_head(old)
            m.write(nf, idx if idx >= 0 else -1)
            if m.cas(head_word, old, pack_head(sb_idx, ctr + 1)):
                return

    def _pop_list(self, head_word: int, next_field: int) -> int | None:
        m = self.mem
        while True:
            old = m.read(head_word)
            idx, ctr = unpack_head(old)
            if idx < 0:
                return None
            nxt = m.read(self.desc(idx, next_field))
            if m.cas(head_word, old, pack_head(int(nxt), ctr + 1)):
                return idx

    # ------------------------------------------------- free stack + run index
    # All superblock free-stack traffic goes through these wrappers so the
    # size-bucketed run index (core.spans.FreeRunIndex) stays an exact
    # mirror of stack membership — the index is what lets the large-object
    # placement answer best-fit queries without draining + sorting the
    # stack on every request.
    def _free_push(self, sb: int) -> None:
        with self._free_lock:
            self._push_list(layout.M_FREE_HEAD, D_NEXT_FREE, sb)
            self._run_index.add(sb)

    def _free_pop(self) -> int | None:
        with self._free_lock:
            sb = self._pop_list(layout.M_FREE_HEAD, D_NEXT_FREE)
            if sb is not None:
                self._run_index.discard(sb)
            return sb

    # ------------------------------------------------------------ expansion
    def _expand(self, nsb: int) -> int | None:
        """Advance the used watermark by ``nsb`` superblocks (CAS+flush+fence).

        Returns the first new superblock index, or None if out of space.
        The watermark is durable *before* any block in the new superblocks
        can be handed out — recovery must never see reachable blocks above
        a stale watermark.
        """
        m = self.mem
        while True:
            old = m.read(layout.M_USED_SBS)
            if old + nsb > self.config.num_sbs:
                return None
            if m.cas(layout.M_USED_SBS, old, old + nsb):
                self._persist(layout.M_USED_SBS)
                _OBS_GROWTH_SBS.inc(nsb)
                return old

    # --------------------------------------------------------------- refill
    def _refill(self, cls: int) -> bool:
        """Recharge the thread cache for ``cls`` (paper §4.4)."""
        cache = self._tcache()[cls]
        bs = layout.class_block_size(cls)
        bw = self.block_words(bs)
        total = layout.blocks_per_sb(bs)
        m = self.mem
        phead = layout.M_PARTIAL_HEADS + cls

        while True:
            # 1. partial superblock of this class
            sb = self._pop_list(phead, D_NEXT_PARTIAL)
            if sb is not None:
                status, taken = self._reserve_all(sb)
                if status == "empty":      # became EMPTY while listed → retire
                    self._init_free_sb(sb)
                    self._free_push(sb)
                    continue
                if status == "full":       # raced empty-handed; try the next
                    continue
                avail, count = taken
                base = self.heap.sb_word(sb)
                w = base + avail * bw
                for _ in range(count):
                    cache.append(w)
                    nxt = pp.decode(w, m.read(w))
                    if nxt is None:
                        break
                    w = nxt
                _OBS_REFILL_PARTIAL.inc()
                return True

            # 2. free superblock (any class) — (re)initialize it for cls
            from_expand = False
            sb = self._free_pop()
            if sb is None:
                # 3. expand the used prefix of the superblock region.  A
                # concurrent span placement may be holding the *entire*
                # drained free stack (_claim_free_run), so re-check under
                # the placement lock before consuming fresh watermark —
                # expanding here would durably leak the address space the
                # free-run search exists to reclaim.
                with self._large_lock:
                    sb = self._free_pop()
                    if sb is None:
                        from_expand = True
                        first = self._expand(self.config.expand_sbs)
                        if first is None:
                            first = self._expand(1)   # partial final expansion
                            if first is None:
                                return False
                            sb = first
                        else:
                            sb = first
                            for extra in range(first + 1,
                                               first + self.config.expand_sbs):
                                self._init_free_sb(extra)
                                self._free_push(extra)
            # persist size class & block size BEFORE any block escapes —
            # recovery depends on them (paper: "has to be persisted before a
            # superblock is used for allocation")
            m.write(self.desc(sb, D_SIZE_CLASS), cls)
            m.write(self.desc(sb, D_BLOCK_SIZE), bs)
            self._persist(self.desc(sb, D_SIZE_CLASS), self.desc(sb, D_BLOCK_SIZE))
            _, _, _, tag = unpack_anchor(m.read(self.desc(sb, D_ANCHOR)))
            m.write(self.desc(sb, D_ANCHOR),
                    pack_anchor(FULL, ANCHOR_NIL_AVAIL, 0, tag + 1))
            base = self.heap.sb_word(sb)
            for b in range(total):
                cache.append(base + b * bw)
            (_OBS_REFILL_EXPAND if from_expand else _OBS_REFILL_FREE_SB).inc()
            return True

    def _reserve_all(self, sb: int) -> tuple[str, tuple[int, int] | None]:
        """CAS the anchor to (FULL, nil, 0), reserving every free block."""
        m = self.mem
        aw = self.desc(sb, D_ANCHOR)
        while True:
            old = m.read(aw)
            state, avail, count, tag = unpack_anchor(old)
            if state == EMPTY or count == total_blocks(self, sb):
                return "empty", None             # retire-on-fetch (paper §4.4)
            if count == 0:
                return "full", None              # nothing to take
            if m.cas(aw, old, pack_anchor(FULL, ANCHOR_NIL_AVAIL, 0, tag + 1)):
                return "ok", (avail, count)

    def _init_free_sb(self, sb: int) -> None:
        m = self.mem
        _, _, _, tag = unpack_anchor(m.read(self.desc(sb, D_ANCHOR)))
        m.write(self.desc(sb, D_ANCHOR),
                pack_anchor(EMPTY, ANCHOR_NIL_AVAIL, 0, tag + 1))

    # ---------------------------------------------------------- cache flush
    def _flush_cache(self, cls: int, keep: int = 0) -> None:
        """Push cached blocks back to their superblocks' free lists."""
        cache = self._tcache()[cls]
        give = cache[keep:]
        del cache[keep:]
        bs = layout.class_block_size(cls)
        bw = self.block_words(bs)
        total = layout.blocks_per_sb(bs)
        by_sb: dict[int, list[int]] = {}
        for w in give:
            by_sb.setdefault(self.heap.sb_of(w), []).append(w)
        m = self.mem
        for sb, blocks in by_sb.items():
            base = self.heap.sb_word(sb)
            aw = self.desc(sb, D_ANCHOR)
            k = len(blocks)
            while True:
                old = m.read(aw)
                state, avail, count, tag = unpack_anchor(old)
                # thread the chain through the blocks' first words (transient)
                for i, w in enumerate(blocks[:-1]):
                    m.write(w, pp.encode(w, blocks[i + 1]))
                lastw = blocks[-1]
                if avail == ANCHOR_NIL_AVAIL:
                    m.write(lastw, pp.PPTR_NULL)
                else:
                    m.write(lastw, pp.encode(lastw, base + avail * bw))
                new_count = count + k
                new_state = EMPTY if new_count == total else (
                    PARTIAL if state == FULL else state)
                new_avail = (blocks[0] - base) // bw
                if m.cas(aw, old, pack_anchor(new_state, new_avail,
                                              new_count, tag + 1)):
                    break
            if state == FULL and new_state == EMPTY:
                self._free_push(sb)
            elif state == FULL and new_state == PARTIAL:
                self._push_list(layout.M_PARTIAL_HEADS + cls, D_NEXT_PARTIAL, sb)
            # PARTIAL→EMPTY: stays in the partial list; retired when fetched.

    # ----------------------------------------------------------------- large
    def _claim_free_run(self, nsb: int) -> int | None:
        """Best-fit contiguous-run search, driven by the size-bucketed
        run index (``core.spans.FreeRunIndex``).

        The index mirrors free-stack *membership* (every push/pop goes
        through ``_free_push``/``_free_pop``), so the best-fit answer —
        smallest run >= ``nsb``, leftmost on ties — is identical to the
        old drain-the-stack-and-sort search, and identical to the device
        allocator's suffix-min scan over ``sb_class == FREE_CLS``: host
        and device still place spans identically given identical free
        sets, and placement still depends only on membership, never on
        stack order (the placement-equivalence invariant).  What changed
        is cost: a miss is O(log) with zero stack traffic, and a hit
        only pops the stack until the claimed run's members are
        collected instead of draining + sorting everything.

        Returns the head superblock index, or None when no run of
        ``nsb`` exists.  Callers must hold ``_large_lock``: two
        concurrent claims would split one run across two searchers,
        making both miss it (one would then expand the watermark a
        fitting run exists for — the exact leak this search removes).
        """
        with self._free_lock:
            first = self._run_index.best_fit(nsb)
            if first is None:
                return None
            want = set(range(first, first + nsb))
            popped: list[int] = []
            while want:
                sb = self._pop_list(layout.M_FREE_HEAD, D_NEXT_FREE)
                if sb is None:
                    break
                popped.append(sb)
                want.discard(sb)
            if want:
                # the index drifted from the stack (an offline/raw stack
                # edit): the stack is fully drained now, so resync the
                # index to the drained membership and redo the search —
                # this degenerate path is exactly the old algorithm
                _OBS_PLACE_RESYNC.inc()
                self._run_index.rebuild(popped)
                first = self._run_index.best_fit(nsb)
                if first is None:
                    for sb in popped:
                        self._push_list(layout.M_FREE_HEAD, D_NEXT_FREE, sb)
                    return None
            self._run_index.claim(first, nsb)
            for sb in popped:
                if not first <= sb < first + nsb:
                    self._push_list(layout.M_FREE_HEAD, D_NEXT_FREE, sb)
            return first

    def _malloc_large(self, size: int) -> int | None:
        nsb = math.ceil(size / SB_SIZE)
        # placement: best-fit over freed contiguous runs first — only when
        # no run fits does the span consume fresh watermark (the paper's
        # watermark-only policy leaks address space under span churn).
        # The lock serializes large-span *placement* only: the small-class
        # fast path stays synchronization-free, and the device allocator
        # gets the same atomicity by construction (one program step).
        with self._large_lock:
            first = self._claim_free_run(nsb)
            if first is None:
                first = self._expand(nsb)
                if first is None:
                    return None
                _OBS_PLACE_WATERMARK.inc()
        m = self.mem
        m.write(self.desc(first, D_SIZE_CLASS), LARGE_CLASS)
        m.write(self.desc(first, D_BLOCK_SIZE), size)
        to_persist = [self.desc(first, D_SIZE_CLASS), self.desc(first, D_BLOCK_SIZE)]
        for sb in range(first + 1, first + nsb):
            m.write(self.desc(sb, D_SIZE_CLASS), LARGE_CONT)
            m.write(self.desc(sb, D_BLOCK_SIZE), 0)
            to_persist.append(self.desc(sb, D_SIZE_CLASS))
        self._persist(*to_persist)
        _, _, _, tag = unpack_anchor(m.read(self.desc(first, D_ANCHOR)))
        m.write(self.desc(first, D_ANCHOR),
                pack_anchor(FULL, ANCHOR_NIL_AVAIL, 0, tag + 1))
        # one (transient) full-extent owner lease
        self.leases.register(first, nsb)
        return self.heap.sb_word(first)

    def _free_large(self, first: int) -> None:
        m = self.mem
        size = m.read(self.desc(first, D_BLOCK_SIZE))
        nsb = math.ceil(size / SB_SIZE)
        # clear the persistent span records (head size + LARGE_CONT
        # continuation markers) *before* the superblocks become reachable
        # from the free list: a crash between the push and a lazy reset
        # would otherwise leave recovery staring at orphaned continuation
        # markers / a stale head that could resurrect the whole span
        to_persist = []
        for sb in range(first, first + nsb):
            m.write(self.desc(sb, D_SIZE_CLASS), 0)
            m.write(self.desc(sb, D_BLOCK_SIZE), 0)
            to_persist += [self.desc(sb, D_SIZE_CLASS),
                           self.desc(sb, D_BLOCK_SIZE)]
        if not is_suppressed("ralloc.free_large.persist"):
            self._persist(*to_persist)
        self.mem.note("span_free", head=first, nsb=nsb)
        _OBS_SPAN_FREE.inc()
        # the span re-enters the free set as one atomic unit: a placement
        # drain interleaving between the pushes would observe a torn run
        # (a prefix of the span), claim it misaligned, and leave stranded
        # fragments no later request can use
        self.leases.forget(first)
        with self._large_lock:
            for sb in range(first, first + nsb):
                self._init_free_sb(sb)
                self._free_push(sb)

    def _trim_tail(self, head: int, new_ext: int, old_ext: int) -> None:
        """Return the unleased tail ``[head+new_ext, head+old_ext)`` of a
        still-live span to the free set.

        The persistent records change exactly like a free of the tail
        alone: the head's size record shrinks to the kept prefix and the
        tail's continuation markers clear, all durable *before* the
        superblocks become reachable from the free list.  Either side of
        a crash mid-trim is safe: head-shrink durable without some tail
        clears leaves orphaned ``LARGE_CONT`` markers recovery sweeps to
        the free set; tail clears durable without the head shrink leaves
        the span looking whole and recovery re-installs the continuation
        markers (a safe leak of the tail back into the span — the same
        conservative direction every GC false positive takes).
        """
        m = self.mem
        size = int(m.read(self.desc(head, D_BLOCK_SIZE)))
        m.write(self.desc(head, D_BLOCK_SIZE),
                min(size, new_ext * SB_SIZE))
        to_persist = [self.desc(head, D_BLOCK_SIZE)]
        for sb in range(head + new_ext, head + old_ext):
            m.write(self.desc(sb, D_SIZE_CLASS), 0)
            m.write(self.desc(sb, D_BLOCK_SIZE), 0)
            to_persist += [self.desc(sb, D_SIZE_CLASS),
                           self.desc(sb, D_BLOCK_SIZE)]
        if not is_suppressed("ralloc.trim_tail.persist"):
            self._persist(*to_persist)
        self.mem.note("tail_free", head=head, new_ext=new_ext,
                      old_ext=old_ext)
        _OBS_TAIL_TRIM.inc()
        # the tail re-enters the free set atomically (same torn-run
        # argument as _free_large)
        with self._large_lock:
            for sb in range(head + new_ext, head + old_ext):
                self._init_free_sb(sb)
                self._free_push(sb)

    # ------------------------------------------------------------ block I/O
    # Convenience accessors used by test data structures & benchmarks: they
    # model application loads/stores to heap blocks (word granularity).
    def read_word(self, w: int) -> int:
        return self.mem.read(w)

    def write_word(self, w: int, v: int) -> None:
        self.mem.write(w, v)

    def flush_range(self, w: int, nwords: int) -> None:
        """Application-side durability (durable linearizability is the app's job)."""
        if self.persist_on:
            for line in range(w // 8, (w + max(nwords, 1) - 1) // 8 + 1):
                self.mem.flush(line * 8)

    def fence(self) -> None:
        if self.persist_on:
            self.mem.fence()

    def fence_if_pending(self) -> None:
        """The persist-boundary idiom: sfence only when a clwb has been
        issued since the last fence.  An elided fence is free — nothing
        is scheduled, so it would commit nothing (persist-lint counts it
        as an ``empty fence``)."""
        if self.persist_on and self.mem.flush_pending:
            self.mem.fence()

    def flush_ranges(self, ranges) -> None:
        """Line-deduplicated batch flush: every cache line under any
        ``(word, nwords)`` range is flushed exactly once.  Group-commit
        paths flush many small records whose 40/64-byte blocks share
        lines; per-record ``flush_range`` calls would re-issue clwb for
        the shared lines (persist-lint: ``redundant flush``)."""
        if not self.persist_on:
            return
        lines: set[int] = set()
        for w, nwords in ranges:
            lines.update(range(w // 8, (w + max(nwords, 1) - 1) // 8 + 1))
        for line in sorted(lines):
            self.mem.flush(line * 8)


def total_blocks(r: Ralloc, sb: int) -> int:
    bs = r.mem.read(r.desc(sb, D_BLOCK_SIZE))
    return layout.blocks_per_sb(int(bs)) if bs > 0 else 0
