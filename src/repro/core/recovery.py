"""Post-crash recovery: trace-based GC + metadata reconstruction (paper §4.5).

Recovery steps (paper numbering):
  2.  thread-local caches start empty (fresh process)
  3.  superblock free list and partial lists reset to empty
  4.  filter functions were registered by ``get_root`` calls
  5.  trace all blocks reachable from persistent roots
  6–9. sweep the superblock region: keep only traced blocks, rebuild every
      descriptor, partial list, and the superblock free list
  10. write back the three regions and fence

"In use" after recovery = reachable, even if never malloc'd pre-crash
(conservative false positives leak, never corrupt — paper Thm 5.4).
"""

from __future__ import annotations

import time

import numpy as np

from . import layout
from . import pptr as pp
from .. import obs
from .layout import (ANCHOR_NIL_AVAIL, D_ANCHOR, D_BLOCK_SIZE, D_NEXT_FREE,
                     D_NEXT_PARTIAL, D_SIZE_CLASS, EMPTY, FULL, LARGE_CLASS,
                     LARGE_CONT, PARTIAL, SB_WORDS, WORD, pack_anchor,
                     pack_head, unpack_anchor)


def _valid_block_start(r, word: int, used_sbs: int) -> tuple[bool, int, int]:
    """Validate a traced target as a block start.

    Returns (valid, size_class, size_bytes).  Interior pointers are not
    supported (paper §4.5); stale size classes on free superblocks can
    admit false positives — a tolerated, safe leak.
    """
    base = r.config.sb_base
    if not (base <= word < base + used_sbs * SB_WORDS):
        return False, 0, 0
    sb = (word - base) // SB_WORDS
    cls = int(r.mem.read(r.desc(sb, D_SIZE_CLASS)))
    bs = int(r.mem.read(r.desc(sb, D_BLOCK_SIZE)))
    if cls == LARGE_CONT:
        return False, 0, 0
    if cls == LARGE_CLASS:
        if bs > 0 and word == r.heap.sb_word(sb):
            return True, LARGE_CLASS, bs
        return False, 0, 0
    if not (1 <= cls < layout.NUM_CLASSES) or bs <= 0:
        return False, 0, 0
    if bs != layout.class_block_size(cls):
        return False, 0, 0
    bw = bs // WORD if bs % WORD == 0 else max(1, -(-bs // WORD))
    off = word - r.heap.sb_word(sb)
    if off % bw != 0 or off + bw > SB_WORDS:
        return False, 0, 0
    return True, cls, bs


def _conservative_targets(r, block_word: int, size_bytes: int):
    """Vectorized conservative scan of one block (numpy fast path)."""
    nwords = max(1, size_bytes // WORD)
    vals = r.mem.read_block(block_word, nwords).astype(np.uint64)
    tags = (vals >> np.uint64(48)) == np.uint64(pp.PPTR_TAG)
    idxs = np.nonzero(tags)[0]
    out = []
    for k in idxs:
        tgt = pp.decode(block_word + int(k), int(np.int64(vals[int(k)])))
        if tgt is not None:
            out.append((tgt, None))
    return out


def trace(r, span_refs: dict[int, int] | None = None
          ) -> dict[int, tuple[int, int]]:
    """Mark phase: BFS from persistent roots (paper Fig. 3 ``collect``).

    Returns {block_word: (size_class, size_bytes)} for every reachable block.

    When ``span_refs`` is given, the trace additionally counts — at zero
    extra passes — how many root-reachable references target each live
    large-span *head* (``span_refs[head_sb] += 1`` per reference, roots
    included).  Each such reference IS one range lease: lease lengths are
    transient and unrecoverable, so recovery rebuilds every reference as
    a lease over the span's remaining *persisted* extent (trims shrink
    that extent durably, so a trimmed tail never comes back).  The
    transient ``RangeLeaseTable`` is reconstructed the same way the free
    lists are — from the persisted minimum plus GC reachability (see
    ``core.spans``).
    """
    used_sbs = int(r.mem.read(layout.M_USED_SBS))
    visited: dict[int, tuple[int, int]] = {}
    pending: list[tuple[int, str | None]] = []

    def visit(word: int, typename: str | None) -> None:
        ok, cls, bs = _valid_block_start(r, word, used_sbs)
        if not ok:
            return
        if span_refs is not None and cls == LARGE_CLASS:
            sb = r.heap.sb_of(word)
            span_refs[sb] = span_refs.get(sb, 0) + 1
        if word not in visited:
            visited[word] = (cls, bs)
            pending.append((word, typename))

    for i, typename in list(r._root_filters.items()):
        root = r.heap.get_root(i)
        if root is not None:
            visit(root, typename)
    # also trace any set roots without registered filters (conservative)
    for i in range(layout.MAX_ROOTS):
        root = r.heap.get_root(i)
        if root is not None and i not in r._root_filters:
            visit(root, None)

    while pending:
        word, typename = pending.pop()
        _, bs = visited[word]
        if typename is None:
            for tgt, child in _conservative_targets(r, word, bs):
                visit(tgt, child)
        else:
            fn = r.filters.get(typename)
            for tgt, child in fn(r, word, bs):
                visit(tgt, child)
    return visited


#: the named, timed phases every ``recover()`` run reports (in order) —
#: pinned by the recovery-stats test so a renamed/dropped phase fails
#: loudly instead of silently vanishing from dashboards.
PHASES = ("prune_index", "prune_trie", "mark", "sweep", "reconstruct",
          "retrim_index", "retrim_trie", "drain")


def recover(r) -> dict:
    """Full recovery: steps 3 + 5–10.  Returns stats for the caller.

    Every step runs inside a named ``obs`` span (``recovery.<phase>``,
    names in :data:`PHASES`); the returned stats carry the same timings
    under ``"phases"`` — ``{name: {"seconds": float, "items": int}}`` —
    so a single recovery's profile travels with its result while the
    registry accumulates across runs for the benchmark snapshot.
    """
    t0 = time.perf_counter()
    phases: dict[str, dict] = {}

    def _phase(span):
        phases[span.name.split(".", 1)[1]] = {"seconds": span.seconds,
                                              "items": span.items}

    m = r.mem
    # step 2: thread caches are empty in a fresh process; for in-process
    # recovery (tests, partial-failure GC) drop them stop-the-world.
    r.drop_all_caches()
    # step 3: empty global lists
    m.write(layout.M_FREE_HEAD, pack_head(-1, 0))
    for c in range(layout.NUM_CLASSES):
        m.write(layout.M_PARTIAL_HEADS + c, pack_head(-1, 0))

    # step 4½: prune torn prefix-index records *before* the mark pass —
    # a record whose seal checksum does not match its fields must never
    # be re-published, so it is durably unlinked here and its block left
    # for the sweep (unreachable ⇒ reclaimed).
    index_slots = sorted(i for i, t in r._root_filters.items()
                         if t == "prefix_index")
    index_pruned = 0
    with obs.span("recovery.prune_index") as sp:
        if index_slots:
            from .prefix_index import prune_torn_records
            for slot in index_slots:
                index_pruned += prune_torn_records(r, slot)
        sp.add(index_pruned)
    _phase(sp)

    # same step for prefix-trie roots, plus the recoverability criterion:
    # children of pruned nodes are durably re-parented to a surviving
    # covering node or dropped with their subtrees (core.prefix_trie).
    trie_slots = sorted(i for i, t in r._root_filters.items()
                        if t == "prefix_trie")
    trie_pruned = 0
    with obs.span("recovery.prune_trie") as sp:
        if trie_slots:
            from .prefix_trie import prune_torn_nodes
            for slot in trie_slots:
                trie_pruned += prune_torn_nodes(r, slot)
        sp.add(trie_pruned)
    _phase(sp)

    # step 5: mark (+ span-refcount reconstruction, same pass)
    span_refs: dict[int, int] = {}
    with obs.span("recovery.mark") as sp:
        visited = trace(r, span_refs)
        sp.add(len(visited))
    _phase(sp)
    t_mark = time.perf_counter()

    # steps 6–9: sweep & rebuild
    sweep_span = obs.span("recovery.sweep")
    sweep_span.__enter__()
    used_sbs = int(m.read(layout.M_USED_SBS))
    by_sb: dict[int, list[int]] = {}
    large_heads: dict[int, int] = {}       # sb -> span length
    for word, (cls, bs) in visited.items():
        sb = r.heap.sb_of(word)
        if cls == LARGE_CLASS:
            large_heads[sb] = -(-bs // layout.SB_SIZE)
        else:
            by_sb.setdefault(sb, []).append(word)

    in_large_span: set[int] = set()
    for sb, nsb in large_heads.items():
        in_large_span.update(range(sb, sb + nsb))

    n_free_sbs = n_partial = n_full = 0
    for sb in range(used_sbs):
        aw = r.desc(sb, D_ANCHOR)
        if sb in in_large_span:
            if sb in large_heads:
                m.write(aw, pack_anchor(FULL, ANCHOR_NIL_AVAIL, 0, 0))
                n_full += 1
            else:
                m.write(r.desc(sb, D_SIZE_CLASS), LARGE_CONT)
            continue
        marked = by_sb.get(sb)
        if not marked:
            # clear stale class records (mirrors the device sweep): a
            # crash mid-_free_large can leave a dead head / orphaned
            # LARGE_CONT here, and a free-listed superblock still tagged
            # as a live large head would let a stale pointer re-free the
            # span into duplicate free-list entries
            m.write(r.desc(sb, D_SIZE_CLASS), 0)
            m.write(r.desc(sb, D_BLOCK_SIZE), 0)
            m.write(aw, pack_anchor(EMPTY, ANCHOR_NIL_AVAIL, 0, 0))
            _push(r, layout.M_FREE_HEAD, D_NEXT_FREE, sb)
            n_free_sbs += 1
            continue
        cls = int(m.read(r.desc(sb, D_SIZE_CLASS)))
        bs = layout.class_block_size(cls)
        bw = bs // WORD
        total = layout.blocks_per_sb(bs)
        base = r.heap.sb_word(sb)
        marked_idx = {(w - base) // bw for w in marked}
        free_idx = [b for b in range(total) if b not in marked_idx]
        if free_idx:
            # rebuild the in-superblock free chain (transient words)
            for a, b in zip(free_idx, free_idx[1:]):
                wa = base + a * bw
                m.write(wa, pp.encode(wa, base + b * bw))
            last = base + free_idx[-1] * bw
            m.write(last, pp.PPTR_NULL)
            m.write(aw, pack_anchor(PARTIAL, free_idx[0], len(free_idx), 0))
            _push(r, layout.M_PARTIAL_HEADS + cls, D_NEXT_PARTIAL, sb)
            n_partial += 1
        else:
            m.write(aw, pack_anchor(FULL, ANCHOR_NIL_AVAIL, 0, 0))
            n_full += 1
    sweep_span.add(used_sbs)
    sweep_span.__exit__(None, None, None)
    _phase(sweep_span)

    # rebuild the transient range-lease table and free-run index exactly
    # like the paper rebuilds thread caches and Treiber stacks: each
    # root-reachable reference to a live head becomes one lease over the
    # span's persisted extent, the index comes from the swept free list.
    # Dead heads that the conservative scan touched are not registered —
    # only live spans carry leases.
    with obs.span("recovery.reconstruct") as sp:
        live_leases = {sb: (large_heads[sb], c)
                       for sb, c in span_refs.items() if sb in large_heads}
        r.leases.reconstruct(live_leases)
        r._run_index.rebuild(free_superblock_list(r))
        sp.add(len(live_leases))
    _phase(sp)

    # precise lease re-trim (core.prefix_index): every reference above
    # came back as a conservative full-extent lease, but a durable
    # prefix-index record knows the page-derived length of the lease it
    # shadows — shrink each record's lease back to it, freeing the
    # decode-ahead tail *now* instead of when the reserver re-finishes.
    # The trims write persistent records (_trim_tail) before the final
    # drain below, so the recovered image is already re-trimmed.
    index_records = index_retrims = 0
    with obs.span("recovery.retrim_index") as sp:
        if index_slots:
            from .prefix_index import retrim_after_recovery
            for slot in index_slots:
                n, k = retrim_after_recovery(r, slot)
                index_records += n
                index_retrims += k
        sp.add(index_retrims)
    _phase(sp)
    trie_records = trie_retrims = 0
    with obs.span("recovery.retrim_trie") as sp:
        if trie_slots:
            from .prefix_trie import retrim_after_recovery as trie_retrim
            for slot in trie_slots:
                n, k = trie_retrim(r, slot)
                trie_records += n
                trie_retrims += k
        sp.add(trie_retrims)
    _phase(sp)

    # step 10: write back all three regions.  drain() IS the write-back
    # (clean-shutdown semantics: every line durable on return); the
    # fence that used to follow it had nothing left to order — persist-
    # lint counts exactly that as an empty fence, and the waste gauges
    # now gate it to zero.
    with obs.span("recovery.drain") as sp:
        m.drain()
    _phase(sp)
    t_end = time.perf_counter()
    return {
        "reachable_blocks": len(visited),
        "free_superblocks": n_free_sbs,
        "free_runs": len(free_superblock_runs(r)),
        "index_records": index_records,
        "index_retrims": index_retrims,
        "index_pruned": index_pruned,
        "trie_records": trie_records,
        "trie_retrims": trie_retrims,
        "trie_pruned": trie_pruned,
        "partial_superblocks": n_partial,
        "full_superblocks": n_full,
        "large_blocks": len(large_heads),
        "shared_spans": sum(1 for sb, c in span_refs.items()
                            if sb in large_heads and c > 1),
        "mark_seconds": t_mark - t0,
        "sweep_seconds": t_end - t_mark,
        "total_seconds": t_end - t0,
        "phases": phases,
    }


def _push(r, head_word: int, next_field: int, sb: int) -> None:
    """Single-threaded (offline) list push — no CAS needed during recovery."""
    idx, ctr = layout.unpack_head(r.mem.read(head_word))
    r.mem.write(r.desc(sb, next_field), idx if idx >= 0 else -1)
    r.mem.write(head_word, pack_head(sb, ctr + 1))


def free_superblock_list(r) -> list[int]:
    """Walk the superblock free list; raises on a cycle (a cycle would
    double-count superblocks and hand the same span out twice)."""
    out: list[int] = []
    seen: set[int] = set()
    idx, _ = layout.unpack_head(r.mem.read(layout.M_FREE_HEAD))
    while idx >= 0:
        if idx in seen:
            raise AssertionError(f"free-list cycle at superblock {idx}")
        seen.add(idx)
        out.append(idx)
        nxt = int(r.mem.read(r.desc(idx, D_NEXT_FREE)))
        idx = nxt if nxt >= 0 else -1
    return out


def free_superblock_runs(r) -> list[tuple[int, int]]:
    """Maximal contiguous runs ``(start, length)`` of free-listed
    superblocks — the search space of ``Ralloc._claim_free_run``.

    Recovery pushes every swept superblock back onto the free list and
    the best-fit search sorts the drained set before scanning, so
    large-object placement after recovery depends only on free-set
    membership — never on stack order.  This is the placement-
    equivalence guarantee the crash-injection and differential suites
    assert; the device analogue is ``jax_alloc.free_runs``.
    """
    return layout.contiguous_runs(sorted(free_superblock_list(r)))
