"""Span registry: transient refcounts + size-bucketed free-run index.

Ralloc's thesis is that metadata which recovery-time GC can rebuild need
not be persisted on the hot path.  This module applies that philosophy to
two pieces of large-span bookkeeping, both held **only in transient
memory** — nothing here is ever flushed:

  * ``SpanRegistry`` — a refcount per live ``LARGE_CLASS`` span head.
    ``Ralloc.span_acquire`` increments it; ``free`` of a span whose count
    is above one *decrements instead of freeing*, so several holders (the
    serving engine's shared-prompt lanes, the prefix cache) can reference
    one reserved span.  After a crash the counts are reconstructed by the
    existing mark phase: the number of root-reachable references to a
    span head *is* its refcount (``recovery.trace`` counts them while
    marking; ``jax_recovery.span_ref_counts`` is the vectorized device
    analogue).  No acquire/release ever writes NVM — the paper's
    "pay almost nothing for persistence" property extends to sharing.

  * ``FreeRunIndex`` — maximal contiguous runs of free superblocks,
    bucketed by length.  ``Ralloc._claim_free_run`` previously drained
    and sorted the whole Treiber free stack per large allocation
    (O(num_sbs log num_sbs)); the index answers best-fit queries
    (smallest run >= request, leftmost on ties) in O(log) and answers
    *misses* in O(1) without touching the stack at all.  It is a mirror
    of free-stack membership, updated at every push/pop, so placement
    still depends only on free-set membership — the property the
    differential-fuzz suite pins host/device lock-step to.

Both structures are rebuilt from scratch by ``recovery.recover`` (the
index from the swept free list, the counts from the GC trace), exactly
like the paper's thread caches and Treiber stacks.
"""

from __future__ import annotations

import bisect
import threading


class SpanRegistry:
    """Transient per-span refcounts, keyed by head superblock index.

    Counts are *advisory until reconstructed*: a span never registered
    (e.g. a reopened heap before ``recover()`` runs) defaults to one
    reference, which preserves the pre-registry free semantics.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._refs: dict[int, int] = {}

    def register(self, head_sb: int) -> None:
        """A freshly placed span starts with one reference (its owner)."""
        with self._lock:
            self._refs[head_sb] = 1

    def acquire(self, head_sb: int) -> int:
        """Add one reference; returns the new count."""
        with self._lock:
            c = self._refs.get(head_sb, 1) + 1
            self._refs[head_sb] = c
            return c

    def release(self, head_sb: int) -> int:
        """Drop one reference; returns the remaining count (0 = free it)."""
        with self._lock:
            c = self._refs.get(head_sb, 1) - 1
            if c <= 0:
                self._refs.pop(head_sb, None)
                return 0
            self._refs[head_sb] = c
            return c

    def count(self, head_sb: int) -> int:
        with self._lock:
            return self._refs.get(head_sb, 1)

    def forget(self, head_sb: int) -> None:
        """Drop the record entirely (the span was freed)."""
        with self._lock:
            self._refs.pop(head_sb, None)

    def reconstruct(self, counts: dict[int, int]) -> None:
        """Replace every count with the GC-reconstructed map (recovery)."""
        with self._lock:
            self._refs = {sb: max(1, int(c)) for sb, c in counts.items()}

    def snapshot(self) -> dict[int, int]:
        with self._lock:
            return dict(self._refs)


class FreeRunIndex:
    """Size-bucketed maximal runs of free superblock indices.

    Mirrors the membership of the superblock free stack.  Maintained
    incrementally: ``add``/``discard`` are amortized O(run) on merges and
    splits, ``best_fit`` is O(log #lengths), and a miss (no run of the
    requested length) costs O(log) with no stack traffic at all.
    """

    def __init__(self) -> None:
        self._start_len: dict[int, int] = {}     # run start -> length
        self._end_start: dict[int, int] = {}     # run end (exclusive) -> start
        self._of_run: dict[int, int] = {}        # member sb -> run start
        self._by_len: dict[int, list[int]] = {}  # length -> sorted starts
        self._lens: list[int] = []               # sorted distinct lengths

    # ------------------------------------------------------------ internals
    def _link(self, start: int, length: int) -> None:
        self._start_len[start] = length
        self._end_start[start + length] = start
        bucket = self._by_len.get(length)
        if bucket is None:
            self._by_len[length] = [start]
            bisect.insort(self._lens, length)
        else:
            bisect.insort(bucket, start)
        for sb in range(start, start + length):
            self._of_run[sb] = start

    def _unlink(self, start: int) -> int:
        length = self._start_len.pop(start)
        del self._end_start[start + length]
        bucket = self._by_len[length]
        bucket.pop(bisect.bisect_left(bucket, start))
        if not bucket:
            del self._by_len[length]
            self._lens.pop(bisect.bisect_left(self._lens, length))
        return length

    # ------------------------------------------------------------------ API
    def __contains__(self, sb: int) -> bool:
        return sb in self._of_run

    def __len__(self) -> int:
        return len(self._of_run)

    def add(self, sb: int) -> None:
        """A superblock entered the free set; merge with its neighbours."""
        if sb in self._of_run:
            return
        start, length = sb, 1
        left = self._end_start.get(sb)           # run ending right at sb
        if left is not None:
            length += self._unlink(left)
            start = left
        right_len = self._start_len.get(sb + 1)  # run starting right after
        if right_len is not None:
            self._unlink(sb + 1)
            length += right_len
        self._link(start, length)

    def discard(self, sb: int) -> None:
        """A superblock left the free set (popped for a small-class refill);
        split its run.  Tolerates non-members (offline/raw stack edits)."""
        start = self._of_run.pop(sb, None)
        if start is None:
            return
        length = self._unlink(start)
        if sb > start:
            self._link(start, sb - start)
        if start + length > sb + 1:
            self._link(sb + 1, start + length - sb - 1)

    def best_fit(self, nsb: int) -> int | None:
        """Start of the smallest run >= ``nsb`` (leftmost on ties) — the
        identical rule ``min((length, start))`` applied over drained runs
        before this index existed, and the rule the device's suffix-min
        scan implements."""
        i = bisect.bisect_left(self._lens, nsb)
        if i == len(self._lens):
            return None
        return self._by_len[self._lens[i]][0]

    def claim(self, start: int, nsb: int) -> None:
        """Remove the first ``nsb`` members of the run starting at
        ``start``; the remainder re-enters the index as its own run."""
        length = self._unlink(start)
        assert length >= nsb, (start, length, nsb)
        for sb in range(start, start + nsb):
            del self._of_run[sb]
        if length > nsb:
            self._link(start + nsb, length - nsb)

    def runs(self) -> list[tuple[int, int]]:
        """All runs as sorted ``(start, length)`` — comparable with
        ``recovery.free_superblock_runs`` / ``jax_alloc.free_runs``."""
        return sorted(self._start_len.items())

    def clear(self) -> None:
        self.__init__()

    def rebuild(self, ids) -> None:
        """Reset to exactly the given free-set membership (recovery, or a
        drift resync from a fully drained stack)."""
        from .layout import contiguous_runs
        self.clear()
        for start, length in contiguous_runs(sorted(ids)):
            self._link(start, length)
