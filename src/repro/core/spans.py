"""Span range leases: transient per-range refcounts + free-run index.

Ralloc's thesis is that metadata which recovery-time GC can rebuild need
not be persisted on the hot path.  This module applies that philosophy to
two pieces of large-span bookkeeping, both held **only in transient
memory** — nothing here is ever flushed:

  * ``RangeLeaseTable`` — per live ``LARGE_CLASS`` span, a table of
    ``[start_sb, end_sb) -> refs`` intervals (its *leases*).  Every lease
    is a **prefix** of the span: the owner's reservation leases the whole
    extent, while a follower that only reads the first pages
    (``Ralloc.span_acquire(n_sbs=…)``) leases just that prefix.  A
    release decrements a range; a superblock *suffix* whose count drops
    to zero is no longer leased by anyone and returns to the free set
    (``Ralloc._trim_tail``) while the shared prefix stays placed — this
    is what unpins the decode-ahead tail of a published span.  The head
    range reaching zero frees whatever remains of the span.  After a
    crash the counts are reconstructed by the existing mark phase: each
    root-reachable reference to a span head is one lease over the span's
    remaining (persisted) extent — lease lengths are transient, so
    recovery conservatively rebuilds them at full extent
    (``recovery.trace`` counts references while marking;
    ``jax_recovery.span_ref_counts`` is the vectorized device analogue).
    No acquire/trim/release ever writes NVM beyond the records a real
    free already wrote — the paper's "pay almost nothing for
    persistence" property extends from sharing to *partial* sharing.

  * ``FreeRunIndex`` — maximal contiguous runs of free superblocks,
    bucketed by length.  ``Ralloc._claim_free_run`` previously drained
    and sorted the whole Treiber free stack per large allocation
    (O(num_sbs log num_sbs)); the index answers best-fit queries
    (smallest run >= request, leftmost on ties) in O(log) and answers
    *misses* in O(1) without touching the stack at all.  It is a mirror
    of free-stack membership, updated at every push/pop, so placement
    still depends only on free-set membership — the property the
    differential-fuzz suite pins host/device lock-step to.

Both structures are rebuilt from scratch by ``recovery.recover`` (the
index from the swept free list, the leases from the GC trace), exactly
like the paper's thread caches and Treiber stacks.
"""

from __future__ import annotations

import bisect
import threading

from .. import obs

# best-fit query outcomes (cached at import; see repro.obs conventions):
# an exact-length bucket hit, a larger-run overflow fallback, or a miss
_OBS_PLACE_EXACT = obs.counter("placement.exact_bucket")
_OBS_PLACE_OVERFLOW = obs.counter("placement.overflow_fallback")
_OBS_PLACE_MISS = obs.counter("placement.miss")


class LeaseUnderflow(ValueError):
    """A range release would drop some superblock's lease count below
    zero — the caller is releasing a range it never leased."""


class RangeLeaseTable:
    """Transient per-superblock-range lease counts, keyed by span head.

    Each live span is a sorted, coalesced interval list
    ``[[start_sb, end_sb, refs], …]`` covering ``[head, head + extent)``.
    Counts are *advisory until reconstructed*: a span never registered
    (e.g. a reopened heap before ``recover()`` runs) defaults to one
    full-extent lease, which preserves the pre-lease free semantics —
    callers ``ensure`` a span from its persistent size record before
    touching it.

    Invariants the operations maintain:
      * intervals are contiguous, ascending, and merged when adjacent
        counts are equal;
      * the last interval always has ``refs > 0`` (a zero-count suffix is
        reported to the caller via ``release`` and dropped — it is the
        caller's job to return those superblocks to the free set);
      * interior zero-count intervals can arise from conservative
        post-crash reconstruction followed by partial releases, or from
        a caller releasing a length it never leased that other holders'
        counts happen to cover (the table is identity-free, so such a
        mismatch is undetectable); either way they stay placed — a safe
        leak in the paper's leak-never-corrupt direction — until the
        head range's last release frees whatever remains.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._spans: dict[int, list[list[int]]] = {}

    # ------------------------------------------------------------ internals
    @staticmethod
    def _split(segs: list[list[int]], at: int) -> None:
        """Ensure ``at`` is an interval boundary (interval split)."""
        for i, (s, e, c) in enumerate(segs):
            if s < at < e:
                segs[i] = [s, at, c]
                segs.insert(i + 1, [at, e, c])
                return

    @staticmethod
    def _coalesce(segs: list[list[int]]) -> None:
        """Merge adjacent intervals with equal counts (interval merge)."""
        i = 0
        while i + 1 < len(segs):
            if segs[i][2] == segs[i + 1][2] and segs[i][1] == segs[i + 1][0]:
                segs[i][1] = segs[i + 1][1]
                del segs[i + 1]
            else:
                i += 1

    # ------------------------------------------------------------------ API
    def register(self, head_sb: int, nsb: int) -> None:
        """A freshly placed ``nsb``-superblock span: one full-extent lease
        (its owner)."""
        with self._lock:
            self._spans[head_sb] = [[head_sb, head_sb + nsb, 1]]

    def ensure(self, head_sb: int, nsb: int) -> None:
        """Register a span not yet tracked (reopened heap before
        ``recover()``) with the single-owner default; no-op if known."""
        with self._lock:
            if head_sb not in self._spans:
                self._spans[head_sb] = [[head_sb, head_sb + nsb, 1]]

    def extent(self, head_sb: int) -> int | None:
        """Tracked extent in superblocks (None if unknown)."""
        with self._lock:
            segs = self._spans.get(head_sb)
            return None if not segs else segs[-1][1] - head_sb

    def acquire(self, head_sb: int, n_sbs: int) -> int:
        """Lease the ``n_sbs``-superblock prefix ``[head, head + n)``
        (clamped to the extent); returns the new head-range count."""
        with self._lock:
            segs = self._spans[head_sb]
            end = min(head_sb + max(1, n_sbs), segs[-1][1])
            self._split(segs, end)
            for seg in segs:
                if seg[0] < end:
                    seg[2] += 1
            self._coalesce(segs)
            return segs[0][2]

    def release(self, head_sb: int, start: int, end: int
                ) -> tuple[int, int]:
        """Drop one lease on ``[start, end)`` (absolute superblocks).

        Returns ``(head_count, new_extent_sbs)`` after the decrement:
        ``head_count == 0`` means the whole remaining span is unleased
        (the caller frees it; the record is dropped here); otherwise a
        zero-count *suffix* was truncated and ``new_extent_sbs`` tells
        the caller how much of the span is still leased — superblocks
        past it must return to the free set.  Raises ``LeaseUnderflow``
        (without mutating) if any part of the range is not leased.
        """
        with self._lock:
            segs = self._spans[head_sb]
            end = min(end, segs[-1][1])
            if not head_sb <= start < end:
                raise LeaseUnderflow(
                    f"empty/invalid release range [{start}, {end}) on the "
                    f"span at superblock {head_sb}")
            if any(c < 1 for s, e, c in segs if s < end and e > start):
                raise LeaseUnderflow(
                    f"release of unleased range [{start}, {end}) on the "
                    f"span at superblock {head_sb}")
            self._split(segs, start)
            self._split(segs, end)
            for seg in segs:
                if start <= seg[0] < end:
                    seg[2] -= 1
            if segs[0][2] <= 0:            # head range unleased → span dies
                del self._spans[head_sb]
                return 0, 0
            while segs and segs[-1][2] == 0:
                segs.pop()                 # unleased tail → caller frees it
            self._coalesce(segs)
            return segs[0][2], segs[-1][1] - head_sb

    def count(self, head_sb: int, sb_off: int = 0) -> int:
        """Lease count at ``head + sb_off`` (unknown span = one owner)."""
        with self._lock:
            segs = self._spans.get(head_sb)
            if not segs:
                return 1 if sb_off == 0 else 0
            for s, e, c in segs:
                if s <= head_sb + sb_off < e:
                    return c
            return 0

    def counts(self, head_sb: int) -> list[int]:
        """Per-superblock lease counts over the tracked extent."""
        with self._lock:
            segs = self._spans.get(head_sb, [])
            return [c for s, e, c in segs for _ in range(s, e)]

    def intervals(self, head_sb: int) -> list[tuple[int, int, int]]:
        """The coalesced ``(start_sb, end_sb, refs)`` lease intervals."""
        with self._lock:
            return [tuple(seg) for seg in self._spans.get(head_sb, [])]

    def forget(self, head_sb: int) -> None:
        """Drop the record entirely (the span was freed)."""
        with self._lock:
            self._spans.pop(head_sb, None)

    def reconstruct(self, spans: dict[int, tuple[int, int]]) -> None:
        """Replace everything with the GC-reconstructed map
        ``{head: (extent_sbs, count)}`` (recovery).  Lease lengths are
        transient and unrecoverable, so every reference conservatively
        becomes a full-extent lease — the tail stays pinned until the
        surviving holders release their (range) leases."""
        with self._lock:
            self._spans = {
                sb: [[sb, sb + nsb, max(1, int(c))]]
                for sb, (nsb, c) in spans.items() if nsb > 0}

    def snapshot(self) -> dict[int, list[tuple[int, int, int]]]:
        with self._lock:
            return {sb: [tuple(s) for s in segs]
                    for sb, segs in self._spans.items()}


class FreeRunIndex:
    """Size-bucketed maximal runs of free superblock indices.

    Mirrors the membership of the superblock free stack.  Maintained
    incrementally: ``add``/``discard`` are amortized O(run) on merges and
    splits, ``best_fit`` is O(log #lengths), and a miss (no run of the
    requested length) costs O(log) with no stack traffic at all.
    """

    def __init__(self) -> None:
        self._start_len: dict[int, int] = {}     # run start -> length
        self._end_start: dict[int, int] = {}     # run end (exclusive) -> start
        self._of_run: dict[int, int] = {}        # member sb -> run start
        self._by_len: dict[int, list[int]] = {}  # length -> sorted starts
        self._lens: list[int] = []               # sorted distinct lengths

    # ------------------------------------------------------------ internals
    def _link(self, start: int, length: int) -> None:
        self._start_len[start] = length
        self._end_start[start + length] = start
        bucket = self._by_len.get(length)
        if bucket is None:
            self._by_len[length] = [start]
            bisect.insort(self._lens, length)
        else:
            bisect.insort(bucket, start)
        for sb in range(start, start + length):
            self._of_run[sb] = start

    def _unlink(self, start: int) -> int:
        length = self._start_len.pop(start)
        del self._end_start[start + length]
        bucket = self._by_len[length]
        bucket.pop(bisect.bisect_left(bucket, start))
        if not bucket:
            del self._by_len[length]
            self._lens.pop(bisect.bisect_left(self._lens, length))
        return length

    # ------------------------------------------------------------------ API
    def __contains__(self, sb: int) -> bool:
        return sb in self._of_run

    def __len__(self) -> int:
        return len(self._of_run)

    def add(self, sb: int) -> None:
        """A superblock entered the free set; merge with its neighbours."""
        if sb in self._of_run:
            return
        start, length = sb, 1
        left = self._end_start.get(sb)           # run ending right at sb
        if left is not None:
            length += self._unlink(left)
            start = left
        right_len = self._start_len.get(sb + 1)  # run starting right after
        if right_len is not None:
            self._unlink(sb + 1)
            length += right_len
        self._link(start, length)

    def discard(self, sb: int) -> None:
        """A superblock left the free set (popped for a small-class refill);
        split its run.  Tolerates non-members (offline/raw stack edits)."""
        start = self._of_run.pop(sb, None)
        if start is None:
            return
        length = self._unlink(start)
        if sb > start:
            self._link(start, sb - start)
        if start + length > sb + 1:
            self._link(sb + 1, start + length - sb - 1)

    def best_fit(self, nsb: int) -> int | None:
        """Start of the smallest run >= ``nsb`` (leftmost on ties) — the
        identical rule ``min((length, start))`` applied over drained runs
        before this index existed, and the rule the device's suffix-min
        scan implements."""
        i = bisect.bisect_left(self._lens, nsb)
        if i == len(self._lens):
            _OBS_PLACE_MISS.inc()
            return None
        (_OBS_PLACE_EXACT if self._lens[i] == nsb
         else _OBS_PLACE_OVERFLOW).inc()
        return self._by_len[self._lens[i]][0]

    def claim(self, start: int, nsb: int) -> None:
        """Remove the first ``nsb`` members of the run starting at
        ``start``; the remainder re-enters the index as its own run."""
        length = self._unlink(start)
        assert length >= nsb, (start, length, nsb)
        for sb in range(start, start + nsb):
            del self._of_run[sb]
        if length > nsb:
            self._link(start + nsb, length - nsb)

    def runs(self) -> list[tuple[int, int]]:
        """All runs as sorted ``(start, length)`` — comparable with
        ``recovery.free_superblock_runs`` / ``jax_alloc.free_runs``."""
        return sorted(self._start_len.items())

    def clear(self) -> None:
        self.__init__()

    def rebuild(self, ids) -> None:
        """Reset to exactly the given free-set membership (recovery, or a
        drift resync from a fully drained stack)."""
        from .layout import contiguous_runs
        self.clear()
        for start, length in contiguous_runs(sorted(ids)):
            self._link(start, length)
