"""Deterministic synthetic data pipeline (+ optional file-backed tokens).

Seeded per (step, host) so every data shard draws a disjoint,
reproducible stream — restart-safe: resuming from step k regenerates
exactly the batches k, k+1, … (no pipeline state to checkpoint).
"""

from __future__ import annotations

import numpy as np


class TokenStream:
    def __init__(self, vocab_size: int, batch: int, seq_len: int,
                 seed: int = 0, host: int = 0, frontend_dim: int = 0):
        self.vocab = vocab_size
        self.batch = batch
        self.seq = seq_len
        self.seed = seed
        self.host = host
        self.frontend_dim = frontend_dim

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + self.host) * 1_000_003 + step)
        if self.frontend_dim:
            emb = rng.standard_normal(
                (self.batch, self.seq, self.frontend_dim)).astype(np.float32)
            labels = rng.integers(0, self.vocab,
                                  (self.batch, self.seq)).astype(np.int32)
            return {"embeds": emb, "labels": labels}
        toks = rng.integers(0, self.vocab,
                            (self.batch, self.seq)).astype(np.int32)
        return {"tokens": toks, "labels": toks}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class FileTokenStream(TokenStream):
    """Tokens memmapped from a flat int32 file, sliced deterministically."""

    def __init__(self, path: str, vocab_size: int, batch: int, seq_len: int,
                 seed: int = 0, host: int = 0):
        super().__init__(vocab_size, batch, seq_len, seed, host)
        self.data = np.memmap(path, dtype=np.int32, mode="r")

    def batch_at(self, step: int) -> dict:
        n = self.batch * self.seq
        total = len(self.data) - n - 1
        off = ((self.seed + step * 16_777_619 + self.host) % max(total, 1))
        toks = np.asarray(self.data[off:off + n]).reshape(
            self.batch, self.seq) % self.vocab
        return {"tokens": toks.astype(np.int32),
                "labels": toks.astype(np.int32)}
