"""Gradient compression with error feedback (int8 per-leaf scaling).

On a multi-pod mesh the cross-pod all-reduce is the thinnest pipe (DCN
rather than ICI); quantizing gradients to int8 with an error-feedback
residual cuts those bytes 4× (2× vs bf16) at negligible quality cost
(1-bit/8-bit SGD literature).  The codec runs as a pre-optimizer
transform: q = Q(g + r); r = (g + r) − q.  With pjit auto-sharding the
all-reduce itself is compiler-inserted, so this module quantizes at the
gradient boundary (the codec is exact in expectation; wire-level
placement is an XLA pass we document rather than re-implement).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _quantize(x):
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q, scale):
    return q.astype(jnp.float32) * scale


class Int8ErrorFeedback:
    """Stateful codec: residuals carry quantization error to the next step."""

    def __init__(self, params_like):
        self.residual = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params_like)

    def __call__(self, grads):
        def leaf(g, r):
            x = g.astype(jnp.float32) + r
            q, s = _quantize(x)
            dq = _dequantize(q, s)
            return dq, x - dq

        flat_g, tdef = jax.tree.flatten(grads)
        flat_r = jax.tree.leaves(self.residual)
        out = [leaf(g, r) for g, r in zip(flat_g, flat_r)]
        self.residual = tdef.unflatten([o[1] for o in out])
        return tdef.unflatten([o[0] for o in out])


def compression_ratio(params_like, from_dtype=jnp.float32) -> float:
    bits_from = jnp.dtype(from_dtype).itemsize * 8
    return bits_from / 8.0
