"""Sharding rules for training (pjit auto-SPMD) and serving (shard_map).

Training layout: 2-D "FSDP × TP" —

  * batch over the data axes ("pod", "data");
  * weight matrices sharded TP over "model" on their head/ffn dim and
    FSDP over "data" on the other dim (ZeRO-3: optimizer state follows);
  * embeddings vocab-sharded over "model" where divisible.

Every rule is divisibility-checked against the actual dim: an axis that
does not divide a dim is dropped (e.g. internvl2's vocab 92553 stays
unsharded on "model").  This keeps one rule set valid across all ten
architectures and both meshes.
"""

from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

from ..runtime import named_sharding


def _fit(spec: P, shape, mesh) -> P:
    """Drop axis names that do not evenly divide their dim."""
    out = []
    for i, entry in enumerate(spec):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        if i < len(shape) and shape[i] % size == 0:
            out.append(entry)
        else:
            out.append(None)
    return P(*out)


def train_param_spec(path: str, shape, mesh, dp: str = "data",
                     tp: str = "model") -> P:
    last = path.split("/")[-1]
    lead = 1 if path.startswith("units/") or "/units/" in f"/{path}" else 0
    pre = (None,) * lead

    def mk(*s):
        full = pre + s + (None,) * (len(shape) - lead - len(s))
        return _fit(P(*full), shape, mesh)

    if "attn" in path:
        if last in ("wq", "wk", "wv"):
            return mk(dp, tp)
        if last == "wo":
            return mk(tp, dp)
        return mk()                                   # biases
    if "ffn" in path:
        if last == "router":
            return mk(dp, None)
        if len(shape) - lead == 3:                    # moe experts [E, ·, ·]
            import os
            if os.environ.get("REPRO_MOE_NO_FSDP"):
                # B2: fine-grained experts (d_ff 512) are tiny — replicate
                # over data, shard only EP over model: zero FSDP collectives
                return mk(tp, None, None)
            if os.environ.get("REPRO_MOE_FSDP_NONCONTRACT"):
                # perf fix: FSDP on the NON-contraction dim — sharding the
                # contraction (d_model for wi, d_ff for wo) forces an
                # all-reduce of the [E, cap, ·] dispatch buffer per layer
                if last in ("wi", "wg"):
                    return mk(tp, None, dp)
                return mk(tp, dp, None)
            if last in ("wi", "wg"):
                return mk(tp, dp, None)
            return mk(tp, None, dp)
        if last in ("wi", "wg"):
            return mk(dp, tp)
        return mk(tp, dp)                             # wo
    if "ssd" in path:
        if last in ("in_z", "in_x", "in_dt"):
            return mk(dp, tp)
        if last == "in_bc":
            return mk(dp, None)
        if last == "conv_x_w":
            return mk(None, tp)
        if last in ("conv_x_b", "norm_w", "A_log", "dt_bias", "D"):
            return mk(tp)
        if last == "out_proj":
            return mk(tp, dp)
        return mk()
    if "rglru" in path:
        if last in ("in_x", "in_g"):
            return mk(dp, tp)
        if last == "conv_w":
            return mk(None, tp)
        if last in ("conv_b", "lam"):
            return mk(tp)
        if last in ("wa", "wx"):
            return mk(dp, tp)
        if last == "out":
            return mk(tp, dp)
        return mk()
    if last in ("embed", "unembed"):
        return _fit(P(tp, dp), shape, mesh)
    return mk()                                       # norms etc.


def tree_path_map(fn, tree, path=""):
    if isinstance(tree, dict):
        return {k: tree_path_map(fn, v, f"{path}/{k}".lstrip("/"))
                for k, v in tree.items()}
    return fn(path, tree)


def train_param_specs(params_shape, mesh):
    return tree_path_map(
        lambda path, leaf: train_param_spec(path, leaf.shape, mesh),
        params_shape)


def train_param_shardings(params_shape, mesh):
    return jax.tree.map(lambda s: named_sharding(mesh, s),
                        train_param_specs(params_shape, mesh))


def batch_spec(mesh) -> P:
    dp = tuple(a for a in mesh.axis_names if a != "model")
    return P(dp)


def make_batch_constrainer(mesh):
    """Returns f(x) pinning dim 0 of activations to the data axes.

    XLA's auto-SPMD occasionally reshards attention intermediates from
    batch-parallel to head-parallel (observed: full-batch f32 score
    buffers).  An explicit constraint at every layer-unit boundary keeps
    activations batch-sharded throughout.
    """
    if mesh is None:
        return lambda x: x
    dp = tuple(a for a in mesh.axis_names if a != "model")
    size = 1
    for a in dp:
        size *= mesh.shape[a]

    def constrain(x):
        if x.ndim >= 1 and x.shape[0] % size == 0 and size > 1:
            spec = P(dp, *([None] * (x.ndim - 1)))
            return jax.lax.with_sharding_constraint(
                x, named_sharding(mesh, spec))
        return x

    return constrain


def opt_state_specs(params_specs):
    """AdamW moments shard exactly like their parameters (ZeRO-3)."""
    return {"m": params_specs, "v": params_specs}
