"""FlashAttention forward Pallas TPU kernel.

TPU-native tiling: the grid's innermost dimension iterates KV blocks
*sequentially per core*, so the running softmax state (m, l, acc) lives
in VMEM scratch across grid steps — the canonical TPU flash schedule
(contrast with the GPU warp-per-tile formulation; DESIGN.md §2).  GQA is
handled by flattening query heads as (kv_head, group) and deriving the
KV head index inside the BlockSpec index maps.

Block shapes are MXU-aligned (multiples of 128 on the sequence dims,
head_dim padded by the caller if needed).  Fully-masked causal blocks
are skipped with ``pl.when``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int,
                  bq: int, bk: int, nk: int):
    ik = pl.program_id(3)
    iq = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    run = True
    if causal:
        # skip blocks strictly above the diagonal
        run = (ik * bk) <= (iq * bq + bq - 1)
    if window:
        run = jnp.logical_and(run, (ik + 1) * bk - 1 >= 0)

    @pl.when(run if not isinstance(run, bool) else True)
    def _body():
        q = q_ref[0].astype(jnp.float32) * scale          # [bq, d]
        k = k_ref[0].astype(jnp.float32)                  # [bk, d]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        mask = jnp.ones((bq, bk), bool)
        if causal:
            mask = mask & (kpos <= qpos)
        if window:
            mask = mask & (kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        corr = jnp.exp(m_prev - m_new)
        e = jnp.exp(s - m_new[:, None])
        l_scr[...] = l_scr[...] * corr + e.sum(axis=1)
        v = v_ref[0].astype(jnp.float32)
        acc_scr[...] = acc_scr[...] * corr[:, None] + \
            jax.lax.dot_general(e, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _final():
        l = jnp.maximum(l_scr[...], 1e-20)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q: [B, H, S, dh]; k/v: [B, K, S, dh] (GQA).  Returns [B, H, S, dh]."""
    B, H, S, dh = q.shape
    K = k.shape[1]
    g = H // K
    bq = min(block_q, S)
    bk = min(block_k, S)
    assert S % bq == 0 and S % bk == 0
    nq, nk = S // bq, S // bk
    scale = dh ** -0.5

    qf = q.reshape(B * H, S, dh)
    kf = k.reshape(B * K, S, dh)
    vf = v.reshape(B * K, S, dh)

    grid = (B, H, nq, nk)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          window=window, bq=bq, bk=bk, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda b, h, iq, ik: (b * H + h, iq, 0)),
            pl.BlockSpec((1, bk, dh),
                         lambda b, h, iq, ik: (b * K + h // g, ik, 0)),
            pl.BlockSpec((1, bk, dh),
                         lambda b, h, iq, ik: (b * K + h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh),
                               lambda b, h, iq, ik: (b * H + h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),      # running max
            pltpu.VMEM((bq,), jnp.float32),      # running denominator
            pltpu.VMEM((bq, dh), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, dh)
