"""jit'd public wrapper for the flash-attention kernel.

``interpret=True`` (the default off-TPU) runs the kernel body through
the Pallas interpreter for correctness validation; on TPU hardware the
same call compiles to a Mosaic kernel with the BlockSpec VMEM tiling.
"""

from __future__ import annotations

import functools

import jax

from .kernel import flash_attention


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention_op(q, k, v, *, causal: bool = True, window: int = 0,
                       block_q: int = 128, block_k: int = 128,
                       interpret: bool | None = None):
    if interpret is None:
        interpret = not _on_tpu()
    return flash_attention(q, k, v, causal=causal, window=window,
                           block_q=block_q, block_k=block_k,
                           interpret=interpret)
