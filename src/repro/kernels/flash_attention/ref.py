"""Pure-jnp oracle for the flash-attention kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: [B, H, S, dh]; k/v: [B, K, S, dh].  fp32 softmax, exact."""
    B, H, S, dh = q.shape
    K = k.shape[1]
    g = H // K
    qg = q.reshape(B, K, g, S, dh).astype(jnp.float32) * (dh ** -0.5)
    s = jnp.einsum("bkgsd,bktd->bkgst", qg, k.astype(jnp.float32))
    ii = jnp.arange(S)
    mask = jnp.ones((S, S), bool)
    if causal:
        mask = mask & (ii[None, :] <= ii[:, None])
    if window:
        mask = mask & (ii[None, :] > ii[:, None] - window)
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,bktd->bkgsd", p, v.astype(jnp.float32))
    return o.reshape(B, H, S, dh).astype(q.dtype)
