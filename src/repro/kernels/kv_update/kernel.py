"""KV-append Pallas kernel: scatter one token's K/V into its page slot.

The write address comes from the allocator's block table (scalar
prefetch) — the storage face of the paged arena.  The arena aliases
input↔output so the update is in-place at whole-arena granularity; each
visited page block is copied through VMEM and its one slot row updated
(distinct sequences own distinct pages — engine contract — so grid
steps never collide).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kv_update_kernel(pid_ref, slot_ref, kn_ref, vn_ref, ki_ref, vi_ref,
                      ko_ref, vo_ref):
    b = pl.program_id(0)
    slot = slot_ref[b]
    ko_ref[...] = ki_ref[...]
    vo_ref[...] = vi_ref[...]

    @pl.when(pid_ref[b] >= 0)
    def _write():
        ko_ref[0, slot] = kn_ref[0].astype(ko_ref.dtype)
        vo_ref[0, slot] = vn_ref[0].astype(vo_ref.dtype)


def kv_update(arena_k, arena_v, k_new, v_new, page_ids, slots, *,
              interpret: bool = False):
    """arena_k/v: [pages, page, K, dh]; k/v_new: [B, K, dh];
    page_ids/slots: [B] (−1 page id ⇒ skip; the last page is the reserved
    dump target and must not hold live data).  Aliased in-place update."""
    B = k_new.shape[0]
    npages, page, K, dh = arena_k.shape
    # invalid lanes (pid −1) are routed to the RESERVED dump page (the
    # last page): a block copy of page 0 here could clobber another
    # lane's earlier in-place write (grid steps share the aliased buffer)
    dump = npages - 1
    page_spec = pl.BlockSpec(
        (1, page, K, dh),
        lambda b, pid, sl: (jnp.where(pid[b] < 0, dump, pid[b]), 0, 0, 0))
    tok_spec = pl.BlockSpec((1, K, dh), lambda b, pid, sl: (b, 0, 0))
    out = pl.pallas_call(
        _kv_update_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B,),
            in_specs=[tok_spec, tok_spec, page_spec, page_spec],
            out_specs=[page_spec, page_spec],
        ),
        out_shape=[jax.ShapeDtypeStruct(arena_k.shape, arena_k.dtype),
                   jax.ShapeDtypeStruct(arena_v.shape, arena_v.dtype)],
        input_output_aliases={4: 0, 5: 1},   # indices count scalar-prefetch args
        interpret=interpret,
    )(page_ids, slots, k_new, v_new, arena_k, arena_v)
    return out


def kv_update_ref(arena_k, arena_v, k_new, v_new, page_ids, slots):
    """Pure-jnp oracle (dump-row trick for invalid ids)."""
    dump = arena_k.shape[0]
    pid = jnp.where(page_ids >= 0, page_ids, dump)
    ak = jnp.concatenate([arena_k, jnp.zeros_like(arena_k[:1])])
    av = jnp.concatenate([arena_v, jnp.zeros_like(arena_v[:1])])
    ak = ak.at[pid, slots].set(k_new.astype(ak.dtype))
    av = av.at[pid, slots].set(v_new.astype(av.dtype))
    return ak[:-1], av[:-1]
