"""Paged decode-attention Pallas TPU kernel.

One query token per sequence attends over K/V pages resolved through a
block table — the compute face of the Ralloc page allocator: block-table
entries are the *position-independent offsets* the allocator hands out
(DESIGN.md §2.1).

TPU schedule: grid = (batch, kv_head, pages); the page dimension runs
sequentially per core, carrying the online-softmax state in VMEM
scratch.  The block table and sequence lengths ride in scalar-prefetch
SMEM so the page→HBM address indirection happens in the BlockSpec index
map (pages stream HBM→VMEM double-buffered by the Pallas pipeline).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, page: int, npages: int,
                  scale: float, window: int):
    b = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[b]
    pid = bt_ref[b, p]
    pos = p * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)[0]
    valid = (pos < length) & (pid >= 0)
    if window:
        valid = valid & (pos > length - 1 - window)

    @pl.when(jnp.any(valid))
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # [G, dh]
        k = k_ref[0, :, 0].astype(jnp.float32)            # [page, dh]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = jnp.where(valid[None, :], s, NEG_INF)         # [G, page]
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        corr = jnp.exp(m_prev - m_new)
        e = jnp.exp(s - m_new[:, None])
        l_scr[...] = l_scr[...] * corr + e.sum(axis=1)
        v = v_ref[0, :, 0].astype(jnp.float32)            # [page, dh]
        acc_scr[...] = acc_scr[...] * corr[:, None] + \
            jax.lax.dot_general(e, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(p == npages - 1)
    def _final():
        l = jnp.maximum(l_scr[...], 1e-20)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def paged_attention(q, arena_k, arena_v, block_table, lengths, *,
                    window: int = 0, interpret: bool = False):
    """q: [B, H, dh]; arena_k/v: [pages, page, K, dh];
    block_table: [B, P] page ids (-1 unused); lengths: [B] tokens held.

    Pages are filled contiguously (engine contract); returns [B, H, dh].
    """
    B, H, dh = q.shape
    npages_tot, page, K, _ = arena_k.shape
    P = block_table.shape[1]
    g = H // K
    scale = dh ** -0.5
    qg = q.reshape(B, K, g, dh)

    grid = (B, K, P)
    kernel = functools.partial(_paged_kernel, page=page, npages=P,
                               scale=scale, window=window)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, g, dh),
                             lambda b, k, p, bt, ln: (b, k, 0, 0)),
                pl.BlockSpec((1, page, 1, dh),
                             lambda b, k, p, bt, ln:
                             (jnp.maximum(bt[b, p], 0), 0, k, 0)),
                pl.BlockSpec((1, page, 1, dh),
                             lambda b, k, p, bt, ln:
                             (jnp.maximum(bt[b, p], 0), 0, k, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, g, dh),
                                   lambda b, k, p, bt, ln: (b, k, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((g,), jnp.float32),
                pltpu.VMEM((g,), jnp.float32),
                pltpu.VMEM((g, dh), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, K, g, dh), q.dtype),
        interpret=interpret,
    )(block_table, lengths, qg, arena_k, arena_v)
    return out.reshape(B, H, dh)
