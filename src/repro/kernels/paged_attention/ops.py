"""jit'd public wrapper for the paged-attention decode kernel."""

from __future__ import annotations

import functools

import jax

from .kernel import paged_attention


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_attention_op(q, arena_k, arena_v, block_table, lengths, *,
                       window: int = 0, interpret: bool | None = None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return paged_attention(q, arena_k, arena_v, block_table, lengths,
                           window=window, interpret=interpret)
