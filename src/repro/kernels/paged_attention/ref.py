"""Pure-jnp oracle for paged decode attention."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def paged_attention_ref(q, arena_k, arena_v, block_table, lengths, *,
                        window: int = 0):
    B, H, dh = q.shape
    npages, page, K, _ = arena_k.shape
    P = block_table.shape[1]
    g = H // K
    bt = jnp.clip(block_table, 0)
    k = arena_k[bt].reshape(B, P * page, K, dh).astype(jnp.float32)
    v = arena_v[bt].reshape(B, P * page, K, dh).astype(jnp.float32)
    qg = q.reshape(B, K, g, dh).astype(jnp.float32) * (dh ** -0.5)
    s = jnp.einsum("bkgd,btkd->bkgt", qg, k)
    pos = jnp.arange(P * page)[None]
    valid = (pos < lengths[:, None]) & \
        jnp.repeat(block_table >= 0, page, axis=1)
    if window:
        valid = valid & (pos > (lengths[:, None] - 1 - window))
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgt,btkd->bkgd", p, v)
    return o.reshape(B, H, dh).astype(q.dtype)
