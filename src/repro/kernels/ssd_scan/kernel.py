"""Mamba-2 SSD chunked-scan Pallas TPU kernel (forward).

One (batch, head) pair per grid row; the chunk dimension runs
sequentially per core carrying the [P, N] inter-chunk SSM state in VMEM
scratch — the same carry-in-scratch schedule as the flash kernel.  Per
chunk the kernel computes the quadratic dual form on the MXU:

  y_intra = (C Bᵀ ⊙ L) · (x·dt)          L = causal decay mask
  y_inter = (C · h_in) ⊙ exp(cumsum log a)
  h_out   = h_in · exp(Σ log a) + Σ decay_out · B ⊗ (x·dt)

Inputs are pre-discretized (x·dt and log-decay per step), matching
``layers.ssd.ssd_chunked`` — which is the pure-jnp oracle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(xdt_ref, loga_ref, b_ref, c_ref, y_ref, h_scr, *,
                nchunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    xdt = xdt_ref[0].astype(jnp.float32)        # [Q, P]
    loga = loga_ref[0].astype(jnp.float32)      # [Q]
    B = b_ref[0].astype(jnp.float32)            # [Q, N]
    C = c_ref[0].astype(jnp.float32)            # [Q, N]
    Q = xdt.shape[0]

    cums = jnp.cumsum(loga)                     # [Q]
    # intra-chunk quadratic form
    G = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [Q, Q]
    rel = cums[:, None] - cums[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(ii >= jj, jnp.exp(rel), 0.0)
    y_intra = jax.lax.dot_general(G * L, xdt, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    # inter-chunk contribution from the carried state  h: [P, N]
    h = h_scr[...]
    y_inter = jax.lax.dot_general(C, h, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    y_inter = y_inter * jnp.exp(cums)[:, None]
    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update
    decay_out = jnp.exp(cums[-1] - cums)                    # [Q]
    xb = xdt * decay_out[:, None]                           # [Q, P]
    dh = jax.lax.dot_general(xb, B, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [P, N]
    h_scr[...] = h * jnp.exp(cums[-1]) + dh


def ssd_scan(xdt, loga, B, C, *, interpret: bool = False):
    """xdt: [Bz, H, S, P]; loga: [Bz, H, S]; B/C: [Bz, S, N] (shared
    across heads).  Chunk = 128 steps.  Returns y [Bz, H, S, P] fp32."""
    Bz, H, S, P = xdt.shape
    N = B.shape[-1]
    Q = min(128, S)
    assert S % Q == 0
    nc = S // Q

    xf = xdt.reshape(Bz * H, S, P)
    lf = loga.reshape(Bz * H, S)
    # broadcast B/C across heads to keep the index maps affine
    bf = jnp.repeat(B, H, axis=0).reshape(Bz * H, S, N)
    cf = jnp.repeat(C, H, axis=0).reshape(Bz * H, S, N)

    grid = (Bz * H, nc)
    out = pl.pallas_call(
        functools.partial(_ssd_kernel, nchunks=nc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Q, P), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, Q), lambda bh, c: (bh, c)),
            pl.BlockSpec((1, Q, N), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, Q, N), lambda bh, c: (bh, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, Q, P), lambda bh, c: (bh, c, 0)),
        out_shape=jax.ShapeDtypeStruct((Bz * H, S, P), jnp.float32),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xf, lf, bf, cf)
    return out.reshape(Bz, H, S, P)
