"""Pure-jnp oracle for the SSD scan kernel — delegates to the model's
chunked implementation (layers.ssd.ssd_chunked)."""

from __future__ import annotations

import jax.numpy as jnp

from ...layers.ssd import ssd_chunked


def ssd_scan_ref(xdt, loga, B, C, *, chunk: int = 128):
    Bz, H, S, P = xdt.shape
    N = B.shape[-1]
    Q = min(chunk, S)
    nc = S // Q
    xdt_c = xdt.transpose(0, 2, 1, 3).reshape(Bz, nc, Q, H, P)
    loga_c = loga.transpose(0, 2, 1).reshape(Bz, nc, Q, H)
    Bc = B.reshape(Bz, nc, Q, N)
    Cc = C.reshape(Bz, nc, Q, N)
    y, _ = ssd_chunked(None, xdt_c.astype(jnp.float32),
                       loga_c.astype(jnp.float32),
                       Bc.astype(jnp.float32), Cc.astype(jnp.float32))
    return y.reshape(Bz, S, H, P).transpose(0, 2, 1, 3)
