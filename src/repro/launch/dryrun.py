import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import/initialization (device count locks on init).

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this produces:
  * proof of compilation on the production mesh (256-chip single pod and
    512-chip two-pod);
  * ``memory_analysis()`` (fits-per-device evidence);
  * ``cost_analysis()`` raw numbers plus loop-corrected FLOPs/bytes and
    per-collective bytes from ``hlo_analysis`` (the §Roofline inputs).

Usage:
  python -m repro.launch.dryrun --arch granite-20b --shape train_4k
  python -m repro.launch.dryrun --arch all                 # every cell
  python -m repro.launch.dryrun ... --multi-pod            # 2×16×16 mesh
"""

import argparse
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp

from ..configs import ARCHS, SHAPES, applicable_shapes, canon, get_config
from ..launch import hlo_analysis, specs
from ..launch.mesh import make_production_mesh
from ..models import transformer as T
from ..serving import decode as dec
from ..train.optimizer import AdamWConfig
from ..train.step import make_train_step


def _analysis(lowered, compiled, mesh, extra):
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):    # jax 0.4.x: one dict per program
        ca = ca[0] if ca else {}
    ma = compiled.memory_analysis()
    chips = mesh.devices.size
    roof = hlo_analysis.analyze(compiled.as_text(), chips)
    out = {
        "cost_analysis_flops": float(ca.get("flops", 0.0)),
        "cost_analysis_bytes": float(ca.get("bytes accessed", 0.0)),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "peak_bytes_estimate": ma.argument_size_in_bytes
            + ma.temp_size_in_bytes,
        },
        "roofline": roof,
    }
    out.update(extra)
    return out


def _apply_overrides(cfg, overrides: str):
    import dataclasses
    if not overrides:
        return cfg
    kw = {}
    for item in overrides.split(","):
        k, v = item.split("=")
        cur = getattr(cfg, k)
        kw[k] = type(cur)(v) if not isinstance(cur, bool) else v == "True"
    return dataclasses.replace(cfg, **kw)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             overrides: str = "") -> dict:
    cfg = _apply_overrides(get_config(arch), overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    kind = SHAPES[shape_name]["kind"]
    info = dict(SHAPES[shape_name])
    scale = int(os.environ.get("REPRO_BATCH_SCALE", "1"))
    if scale != 1:
        info["global_batch"] *= scale
        SHAPES[shape_name] = info          # seen by specs builders
    t0 = time.time()

    if kind == "train":
        params = specs.abstract_params(cfg, mesh, "train")
        opt = specs.abstract_opt_state(params, mesh)
        batch = specs.train_batch_specs(cfg, shape_name, mesh)
        step = make_train_step(cfg, AdamWConfig(), mesh=mesh)
        shardings = jax.tree.map(lambda s: s.sharding, (params, opt, batch))
        jitted = jax.jit(step, in_shardings=shardings,
                         out_shardings=(shardings[0], shardings[1], None),
                         donate_argnums=(0, 1))
        lowered = jitted.lower(params, opt, batch)
    elif kind == "prefill":
        params = specs.abstract_params(cfg, mesh, "serve")
        batch = specs.prefill_batch_specs(cfg, shape_name, mesh)

        from ..distributed.sharding import make_batch_constrainer
        constrain = make_batch_constrainer(mesh)

        def prefill(params, batch):
            logits, aux, kv = T.forward(cfg, params, batch, collect_kv=True,
                                        constrain=constrain)
            return logits[:, -1], kv

        shardings = jax.tree.map(lambda s: s.sharding, (params, batch))
        jitted = jax.jit(prefill, in_shardings=shardings)
        lowered = jitted.lower(params, batch)
    else:  # decode
        params = specs.abstract_params(cfg, mesh, "serve")
        dstate, tokens, batch_sharded = specs.decode_state_specs(
            cfg, shape_name, mesh)
        pshape = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), params)
        step, _, _ = dec.make_decode_step(cfg, mesh, pshape,
                                          batch_sharded=batch_sharded)
        lowered = step.lower(params, dstate, tokens)

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    if os.environ.get("REPRO_SAVE_HLO"):
        import gzip
        hdir = pathlib.Path(os.environ["REPRO_SAVE_HLO"])
        hdir.mkdir(parents=True, exist_ok=True)
        name = (f"{canon(arch)}__{shape_name}__"
                f"{'2x16x16' if multi_pod else '16x16'}.hlo.gz")
        with gzip.open(hdir / name, "wt") as fh:
            fh.write(compiled.as_text())

    ntok = info["global_batch"] * (info["seq_len"] if kind != "decode" else 1)
    model_flops = 6 * cfg.active_param_count() * ntok
    if kind == "train":
        pass                               # 6ND already counts fwd+bwd
    else:
        model_flops = model_flops // 3     # 2ND forward-only
    extra = {
        "arch": arch, "shape": shape_name, "kind": kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "model_flops_global": float(model_flops),
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    return _analysis(lowered, compiled, mesh, extra)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--override", default="", help="cfg overrides k=v,...")
    ap.add_argument("--tag", default="", help="suffix for perf variants")
    args = ap.parse_args()

    archs = list(ARCHS) if args.arch == "all" else [canon(args.arch)]
    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = 0
    for arch in archs:
        shapes = (applicable_shapes(arch) if args.shape == "all"
                  else [args.shape])
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}"
                if args.tag:
                    tag += f"__{args.tag}"
                if args.skip_existing and (outdir / f"{tag}.json").exists():
                    print(f"SKIP {tag}", flush=True)
                    continue
                try:
                    res = run_cell(arch, shape, mp, args.override)
                    (outdir / f"{tag}.json").write_text(
                        json.dumps(res, indent=1, default=float))
                    r = res["roofline"]
                    print(f"OK   {tag}: compile={res['compile_s']}s "
                          f"dom={r['dominant']} "
                          f"t=({r['t_compute_s']:.4f},"
                          f"{r['t_memory_s']:.4f},"
                          f"{r['t_collective_s']:.4f})s "
                          f"mem={res['memory']['peak_bytes_estimate']/2**30:.1f}GiB/dev",
                          flush=True)
                except Exception as e:
                    failures += 1
                    print(f"FAIL {tag}: {e}", flush=True)
                    traceback.print_exc()
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
