"""Roofline-term extraction from compiled (post-SPMD) HLO text.

``compiled.cost_analysis()`` counts each ``while`` body **once**, so a
scan-over-layers model under-reports FLOPs/bytes by ~num_layers (verified
in this container: scan of 8 matmuls reports 1×).  This analyzer walks
the optimized HLO, multiplies loop bodies by their trip counts, and also
accumulates per-collective byte counts (absent from cost_analysis
altogether).

Costs per op (per device — post-SPMD shapes):
  dot/convolution   2 · numel(out) · contraction-size FLOPs
  fusion            bytes = operands + outputs (the fused-traffic model);
                    FLOPs from any dots inside its computation
  elementwise/other bytes = operands + outputs, FLOPs ≈ numel(out)
  all-gather        bytes ≈ numel(out)           (receives (n−1)/n ≈ 1)
  reduce-scatter    bytes ≈ numel(in)
  all-reduce        bytes ≈ 2 · numel(in)        (ring: RS + AG)
  all-to-all        bytes ≈ numel(in)
  collective-permute bytes ≈ numel(in)

Trip counts come from integer constants in the loop condition
computation (lax.scan lowers to ``lt(counter, L)``).
"""

from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s*([\w\-]+)\((.*)\)")
_CALL_RE = re.compile(r"(?:calls|to_apply|body|condition|branch_computations)="
                      r"[{]?%?([\w.\-]+)")


def _shape_bytes(stype: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(stype):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _dtype_size_of(stype: str) -> int:
    m = _SHAPE_RE.search(stype)
    return _DTYPE_BYTES.get(m.group(1), 4) if m else 4


def _shape_numel(stype: str) -> int:
    m = _SHAPE_RE.search(stype)
    if not m:
        return 0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


@dataclasses.dataclass
class Op:
    name: str
    out_type: str
    opcode: str
    args: str
    line: str


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_counts: dict = dataclasses.field(default_factory=dict)

    def add(self, other: "Costs", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.coll_bytes += other.coll_bytes * mult
        for k, v in other.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0) + v * mult


COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


_ARG_NAME_RE = re.compile(r"%([\w.\-]+)")


class HloModule:
    def __init__(self, text: str):
        self.computations: dict[str, list[Op]] = {}
        self.types: dict[str, str] = {}        # op name -> output type
        cur = None
        for line in text.splitlines():
            s = re.sub(r"/\*.*?\*/", "", line).strip()
            if ("{" in s and ("->" in s or s.startswith("ENTRY"))
                    and "=" not in s.split("{")[0]):
                m2 = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)", s)
                if m2:
                    cur = m2.group(1)
                    self.computations[cur] = []
                continue
            if s == "}" or s.startswith("}"):
                continue
            om = _OP_RE.match(s)
            if om and cur is not None:
                op = Op(om.group(1), om.group(2), om.group(3), om.group(4), s)
                self.computations[cur].append(op)
                self.types[op.name] = op.out_type
        self.entry = self._find_entry(text)

    def _arg_bytes(self, op: Op) -> int:
        """Operand bytes resolved through the name→type map."""
        total = 0
        for name in _ARG_NAME_RE.findall(op.args):
            total += _shape_bytes(self.types.get(name, ""))
        return total

    def _arg_shapes(self, op: Op) -> list[list[int]]:
        out = []
        for name in _ARG_NAME_RE.findall(op.args):
            t = self.types.get(name, "")
            m = _SHAPE_RE.search(t)
            if m:
                out.append([int(d) for d in m.group(2).split(",") if d])
        return out

    def _find_entry(self, text: str) -> str:
        m = re.search(r"ENTRY\s+%?([\w.\-]+)", text)
        if m:
            return m.group(1)
        return next(iter(self.computations))

    # ------------------------------------------------------------------
    def trip_count(self, cond_name: str) -> int:
        ops = self.computations.get(cond_name, [])
        best = 1
        for op in ops:
            if op.opcode == "constant":
                m = re.search(r"constant\((\d+)\)", op.line)
                if m:
                    best = max(best, int(m.group(1)))
        return best

    def _dot_flops(self, op: Op) -> float:
        out_n = _shape_numel(op.out_type)
        # contraction size: lhs shape numel / product of lhs free dims —
        # approximate via lhs numel / (out numel / rhs free) is fiddly;
        # use lhs_contracting_dims against the lhs operand shape instead.
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
        shapes = self._arg_shapes(op)
        if not shapes:
            return 0.0
        lhs_dims = shapes[0]
        contr = 1
        if m:
            for ix in m.group(1).split(","):
                if ix and int(ix) < len(lhs_dims):
                    contr *= lhs_dims[int(ix)]
        return 2.0 * out_n * max(contr, 1)

    def comp_costs(self, name: str, _memo=None) -> Costs:
        if _memo is None:
            _memo = {}
        if name in _memo:
            return _memo[name]
        total = Costs()
        _memo[name] = total                 # break recursion cycles
        for op in self.computations.get(name, []):
            oc = op.opcode
            if oc == "while":
                calls = dict(re.findall(r"(body|condition)=%?([\w.\-]+)",
                                        op.line))
                trips = self.trip_count(calls.get("condition", ""))
                body = self.comp_costs(calls.get("body", ""), _memo)
                total.add(body, trips)
            elif oc in ("call", "fusion", "conditional", "map",
                        "async-start"):
                for sub in _CALL_RE.findall(op.line):
                    sc = self.comp_costs(sub, _memo)
                    if oc == "fusion":
                        # fused interior traffic stays in registers/VMEM:
                        # count only FLOPs + any collectives, plus the
                        # fusion's boundary bytes below
                        total.flops += sc.flops
                        total.coll_bytes += sc.coll_bytes
                        for kk, vv in sc.coll_counts.items():
                            total.coll_counts[kk] = \
                                total.coll_counts.get(kk, 0) + vv
                    else:
                        total.add(sc)
                if oc == "fusion":
                    handled = False
                    out_n = _shape_numel(op.out_type)
                    for sub in _CALL_RE.findall(op.line):
                        ops = self.computations.get(sub, [])
                        dus = [o for o in ops
                               if o.opcode == "dynamic-update-slice"]
                        if dus and _shape_numel(dus[-1].out_type) == out_n:
                            # in-place (aliased) stacked update on TPU:
                            # traffic = the update slice, not the stack
                            handled = True
                            shapes = self._arg_shapes(dus[-1])
                            upd = 1
                            if len(shapes) >= 2:
                                for d in shapes[1]:
                                    upd *= d
                            total.bytes += 2 * upd * _dtype_size_of(
                                dus[-1].out_type)
                        elif ops and all(o.opcode in (
                                "convert", "bitcast", "copy", "reshape",
                                "transpose", "parameter", "constant",
                                "broadcast") for o in ops):
                            # pure dtype/layout fusion: XLA-CPU emulates
                            # bf16 arithmetic via f32 round-trips; on TPU
                            # (native bf16) this traffic does not exist
                            handled = True
                    if not handled:
                        # operands consumed through an interior
                        # dynamic-slice count as the slice, not the whole
                        # (possibly unit-stacked) array
                        in_bytes = 0
                        interior_ds = []
                        for sub in _CALL_RE.findall(op.line):
                            interior_ds += [
                                o for o in self.computations.get(sub, [])
                                if o.opcode == "dynamic-slice"]
                        if interior_ds:
                            in_bytes = sum(_shape_bytes(o.out_type)
                                           for o in interior_ds)
                        else:
                            in_bytes = self._arg_bytes(op)
                        total.bytes += in_bytes + _shape_bytes(op.out_type)
            elif oc in ("dot", "convolution"):
                total.flops += self._dot_flops(op)
                total.bytes += self._arg_bytes(op) + \
                    _shape_bytes(op.out_type)
            elif any(oc.startswith(c) for c in COLLECTIVES):
                kind = next(c for c in COLLECTIVES if oc.startswith(c))
                if kind == "all-gather":
                    nb = _shape_bytes(op.out_type)
                elif kind == "all-reduce":
                    nb = 2 * self._arg_bytes(op)
                else:
                    nb = self._arg_bytes(op)
                total.coll_bytes += nb
                total.coll_counts[kind] = total.coll_counts.get(kind, 0) + 1
                total.bytes += self._arg_bytes(op) + \
                    _shape_bytes(op.out_type)
            elif oc in ("parameter", "constant", "get-tuple-element",
                        "tuple", "bitcast", "after-all", "copy-start",
                        "copy-done"):
                continue
            elif oc == "dynamic-update-slice":
                # aliased in-place: traffic = the update slice (read+write),
                # NOT the full destination array (the scan-carry stacks are
                # multi-GiB; counting them per step inflates bytes ~50×)
                shapes = self._arg_shapes(op)
                upd = 1
                if len(shapes) >= 2:
                    for d in shapes[1]:
                        upd *= d
                total.bytes += 2 * upd * _dtype_size_of(op.out_type)
            elif oc == "dynamic-slice":
                total.bytes += 2 * _shape_bytes(op.out_type)
            elif oc == "gather":
                total.bytes += 2 * _shape_bytes(op.out_type)
            elif oc == "scatter":
                shapes = self._arg_shapes(op)
                upd = 1
                if len(shapes) >= 3:
                    for d in shapes[2]:
                        upd *= d
                total.bytes += 2 * upd * _dtype_size_of(op.out_type)
            else:
                ob = _shape_bytes(op.out_type)
                total.flops += _shape_numel(op.out_type)
                total.bytes += self._arg_bytes(op) + ob
        # fusions inside: their internal dots were added above; internal
        # elementwise double-counts a little — acceptable at roofline scale
        return total

    def entry_costs(self) -> Costs:
        return self.comp_costs(self.entry)


# hardware constants: TPU v5e
PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link (~per chip, simplistic)


def roofline(costs: Costs, chips: int) -> dict:
    """Three roofline terms (seconds, per step) from per-device costs."""
    t_compute = costs.flops / PEAK_FLOPS
    t_memory = costs.bytes / HBM_BW
    t_coll = costs.coll_bytes / ICI_BW
    dominant = max(
        (("compute", t_compute), ("memory", t_memory),
         ("collective", t_coll)), key=lambda kv: kv[1])[0]
    return {
        "flops_per_device": costs.flops,
        "bytes_per_device": costs.bytes,
        "collective_bytes_per_device": costs.coll_bytes,
        "collective_counts": costs.coll_counts,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "chips": chips,
    }


def analyze(compiled_text: str, chips: int) -> dict:
    mod = HloModule(compiled_text)
    return roofline(mod.entry_costs(), chips)
