"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any
jax initialization.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh for CPU tests/examples."""
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
