"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any
jax initialization.  Mesh construction itself goes through the
version-agnostic ``repro.runtime`` layer.
"""

from __future__ import annotations

from ..runtime import make_host_mesh, make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


__all__ = ["make_production_mesh", "make_host_mesh"]
