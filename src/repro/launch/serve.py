"""Serving launcher: paged continuous-batching generation.

CPU-scale example:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-32b --smoke \
      --requests 4 --steps 32 [--crash-at 16]

``--crash-at N`` drops all transient allocator state at step N and
recovers via the vectorized GC before continuing (the paper's
recoverability criterion, live).
"""

import argparse

import jax

from ..configs import get_config, get_smoke_config
from ..models import transformer as T
from ..runtime import make_host_mesh
from ..serving.engine import ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--crash-at", type=int, default=-1)
    args = ap.parse_args()

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    mesh = make_host_mesh()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, mesh, params, lanes=max(args.requests, 2),
                           max_seq=args.max_seq)
    lanes = [engine.add_request([1 + i, 2 + i]) for i in range(args.requests)]
    for step in range(args.steps):
        if step == args.crash_at:
            stats = engine.crash_and_recover()
            print(f"[serve] crash at step {step}; recovery: {stats}")
        engine.step()
    for lane in lanes:
        s = engine.sessions.get(lane)
        if s:
            print(f"lane {lane}: {len(s.tokens)} tokens: {s.tokens[:16]}")


if __name__ == "__main__":
    main()
