"""ShapeDtypeStruct input specs for every (arch × shape) dry-run cell.

Never allocates device memory: abstract params via ``jax.eval_shape``,
abstract batches/state via ShapeDtypeStruct — the shannon/kernels
pattern.  Frontend-stub archs ([audio]/[vlm]) receive precomputed
frame/patch embeddings instead of tokens.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs import SHAPES
from ..distributed import sharding as shrules
from ..models import transformer as T
from ..models.config import ModelConfig
from ..runtime import named_sharding
from ..serving import decode as dec
from ..train.optimizer import init_opt_state


def sds(shape, dtype, mesh=None, spec=None):
    sharding = named_sharding(mesh, spec) if mesh is not None else None
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def abstract_params(cfg: ModelConfig, mesh=None, layout: str = "train"):
    shapes = jax.eval_shape(
        lambda: T.init_params(cfg, jax.random.PRNGKey(0)))
    if mesh is None:
        return shapes
    if layout == "train":
        specs = shrules.train_param_specs(shapes, mesh)
    else:
        specs = dec.serve_param_specs(cfg, shapes, mesh.shape["model"])
    return jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=named_sharding(mesh, sp)),
        shapes, specs)


def abstract_opt_state(params_abs, mesh):
    shapes = jax.eval_shape(init_opt_state, params_abs)

    def shard_like(s, ref):
        if not s.shape:
            return jax.ShapeDtypeStruct(s.shape, s.dtype,
                                        sharding=named_sharding(mesh, P()))
        return jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=ref.sharding)

    return {
        "m": jax.tree.map(shard_like, shapes["m"], params_abs),
        "v": jax.tree.map(shard_like, shapes["v"], params_abs),
        "step": jax.ShapeDtypeStruct((), jnp.int32,
                                     sharding=named_sharding(mesh, P())),
    }


def train_batch_specs(cfg: ModelConfig, shape_name: str, mesh):
    info = SHAPES[shape_name]
    B, S = info["global_batch"], info["seq_len"]
    dp = shrules.batch_spec(mesh)
    if cfg.frontend:
        return {
            "embeds": sds((B, S, cfg.d_model), jnp.bfloat16, mesh, dp),
            "labels": sds((B, S), jnp.int32, mesh, dp),
        }
    return {
        "tokens": sds((B, S), jnp.int32, mesh, dp),
        "labels": sds((B, S), jnp.int32, mesh, dp),
    }


def prefill_batch_specs(cfg: ModelConfig, shape_name: str, mesh):
    info = SHAPES[shape_name]
    B, S = info["global_batch"], info["seq_len"]
    dp = shrules.batch_spec(mesh)
    if cfg.frontend:
        return {"embeds": sds((B, S, cfg.d_model), jnp.bfloat16, mesh, dp)}
    return {"tokens": sds((B, S), jnp.int32, mesh, dp)}


def decode_state_specs(cfg: ModelConfig, shape_name: str, mesh):
    """Abstract decode state + token batch for a decode-shape cell."""
    info = SHAPES[shape_name]
    B, S = info["global_batch"], info["seq_len"]
    dp_axes = tuple(a for a in mesh.axis_names if a != "model")
    dp_total = 1
    for a in dp_axes:
        dp_total *= mesh.shape[a]
    batch_sharded = B % dp_total == 0 and B >= dp_total
    dstate_shapes = jax.eval_shape(
        lambda: dec.make_dstate(cfg, batch=B, max_seq=S,
                                dp_shards=dp_total))
    sspecs = dec.dstate_specs(cfg, mesh, batch_sharded)
    dstate_abs = jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=named_sharding(mesh, sp)),
        dstate_shapes, sspecs, is_leaf=lambda x: isinstance(
            x, jax.ShapeDtypeStruct))
    tok_spec = P(dp_axes) if batch_sharded else P()
    tokens = sds((B,), jnp.int32, mesh, tok_spec)
    return dstate_abs, tokens, batch_sharded
