"""Training launcher.

CPU-scale example (runs in this container):
  PYTHONPATH=src python -m repro.launch.train --arch starcoder2-3b \
      --smoke --steps 50 --ckpt /tmp/train.heap

On a TPU fleet the same driver runs the full config with the production
mesh (remove --smoke); per-host data sharding comes from the
deterministic pipeline's host index.
"""

import argparse

import jax

from ..checkpoint.manager import CheckpointManager
from ..configs import get_config, get_smoke_config
from ..core.ralloc import Ralloc
from ..data.pipeline import TokenStream
from ..distributed.compression import Int8ErrorFeedback
from ..train.loop import Trainer
from ..train.optimizer import AdamWConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    ckpt = None
    if args.ckpt:
        heap = Ralloc(args.ckpt, 1 << 30)
        ckpt = CheckpointManager(heap)
    stream = TokenStream(cfg.vocab_size, args.batch, args.seq, seed=0,
                         frontend_dim=cfg.d_model if cfg.frontend else 0)
    trainer = Trainer(cfg, AdamWConfig(lr=args.lr),
                      ckpt=ckpt, ckpt_every=args.ckpt_every,
                      microbatches=args.microbatches)
    if args.compress_grads:
        trainer.step_fn = jax.jit(
            __import__("repro.train.step", fromlist=["make_train_step"])
            .make_train_step(cfg, AdamWConfig(lr=args.lr),
                             microbatches=args.microbatches,
                             compressor=Int8ErrorFeedback(trainer.params)))
    hist = trainer.run(stream, steps=args.steps)
    print(f"final loss {hist[-1]:.4f}; straggler events: "
          f"{trainer.straggler_events}")
    if ckpt:
        heap.close()


if __name__ == "__main__":
    main()
