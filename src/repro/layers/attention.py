"""Grouped-query attention: full (train/prefill) and paged-decode paths.

The decode path reads K/V through a *page-table indirection* into a KV
arena whose pages are allocated by ``core.jax_alloc`` — this is the
paper's allocator serving as the memory manager for inference state
(DESIGN.md §2.1).  The pure-jnp implementation here is the oracle; the
Pallas kernels in ``repro.kernels`` implement the same contracts with
VMEM tiling and are validated against these functions.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import param
from .rope import apply_rope

NEG_INF = -1e30


def init_attention(cfg, key):
    kq, kk, kv, ko, kb = jax.random.split(key, 5)
    d, h, k, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    p = {
        "wq": param(kq, (d, h * dh), cfg.dtype),
        "wk": param(kk, (d, k * dh), cfg.dtype),
        "wv": param(kv, (d, k * dh), cfg.dtype),
        "wo": param(ko, (h * dh, d), cfg.dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * dh,), cfg.dtype)
        p["bk"] = jnp.zeros((k * dh,), cfg.dtype)
        p["bv"] = jnp.zeros((k * dh,), cfg.dtype)
    return p


def _qkv(cfg, p, x, positions):
    B, S, _ = x.shape
    h, k, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,de->bse", x, p["wq"])
    kk = jnp.einsum("bsd,de->bse", x, p["wk"])
    v = jnp.einsum("bsd,de->bse", x, p["wv"])
    if cfg.qkv_bias:
        q, kk, v = q + p["bq"], kk + p["bk"], v + p["bv"]
    q = q.reshape(B, S, h, dh)
    kk = kk.reshape(B, S, k, dh)
    v = v.reshape(B, S, k, dh)
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        kk = apply_rope(kk, positions, cfg.rope_theta)
    return q, kk, v


def full_attention(cfg, p, x, positions, *, causal: bool = True,
                   window: int = 0):
    """Training / prefill attention.  Returns (out [B,S,D], (k, v))."""
    B, S, _ = x.shape
    h, kvh, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    g = h // kvh
    q, k, v = _qkv(cfg, p, x, positions)
    qg = q.reshape(B, S, kvh, g, dh)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k,
                        preferred_element_type=jnp.float32)
    scores = scores * (dh ** -0.5)
    ii = positions[:, :, None] if positions.ndim == 2 else positions[None, :, None]
    jj = positions[:, None, :] if positions.ndim == 2 else positions[None, None, :]
    mask = jnp.ones((1, S, S), bool)
    if causal:
        mask = mask & (jj <= ii)
    if window:
        mask = mask & (jj > ii - window)
    scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    out = out.reshape(B, S, h * dh)
    return jnp.einsum("bse,ed->bsd", out, p["wo"]), (k, v)


def chunked_attention(cfg, p, x, positions, *, causal: bool = True,
                      window: int = 0, kv_chunk: int = 256):
    """Flash-style online-softmax attention over KV chunks.

    Never materializes the S×T score matrix: a ``lax.scan`` over KV
    chunks carries running (max, denominator, accumulator).  This is the
    XLA-level equivalent of FlashAttention and the pure-jnp oracle for
    ``kernels/flash_attention``.  ~2× the FLOPs of an ideal causal kernel
    (masked blocks are still computed — the Pallas kernel skips them).
    """
    B, S, _ = x.shape
    h, kvh, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    g = h // kvh
    q, k, v = _qkv(cfg, p, x, positions)
    C = min(kv_chunk, S)
    while S % C:
        C -= 1
    nc = S // C
    qg = (q.reshape(B, S, kvh, g, dh) * (dh ** -0.5)).astype(jnp.float32)
    kc = k.reshape(B, nc, C, kvh, dh).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nc, C, kvh, dh).transpose(1, 0, 2, 3, 4)
    qpos = positions if positions.ndim == 2 else positions[None]
    kpos = qpos.reshape(B, nc, C).transpose(1, 0, 2)

    def body(carry, inp):
        m, l, acc = carry
        kb, vb, kp = inp
        s = jnp.einsum("bskgd,bckd->bskgc", qg, kb.astype(jnp.float32))
        valid = jnp.ones((B, S, C), bool)
        if causal:
            valid = valid & (kp[:, None, :] <= qpos[:, :, None])
        if window:
            valid = valid & (kp[:, None, :] > qpos[:, :, None] - window)
        s = jnp.where(valid[:, :, None, None, :], s, NEG_INF)
        m2 = jnp.maximum(m, s.max(axis=-1))
        corr = jnp.exp(m - m2)
        e = jnp.exp(s - m2[..., None])
        l2 = l * corr + e.sum(axis=-1)
        acc2 = acc * corr[..., None] + jnp.einsum(
            "bskgc,bckd->bskgd", e, vb.astype(jnp.float32))
        return (m2, l2, acc2), None

    m0 = jnp.full((B, S, kvh, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, S, kvh, g), jnp.float32)
    a0 = jnp.zeros((B, S, kvh, g, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kc, vc, kpos))
    out = (acc / jnp.maximum(l, 1e-20)[..., None]).astype(x.dtype)
    out = out.reshape(B, S, h * dh)
    return jnp.einsum("bse,ed->bsd", out, p["wo"]), (k, v)


def pallas_attention(cfg, p, x, positions, *, causal: bool = True,
                     window: int = 0):
    """Forward attention through the Pallas flash kernel (VMEM-tiled).

    On TPU this compiles to a Mosaic kernel; in the CPU dry-run the
    interpret-mode lowering produces the same *traffic shape* (per-tile
    loads inside the grid loop instead of S×T score materialization),
    which is what the roofline memory term measures.  Forward-only:
    training wraps it in jax.checkpoint so the backward recomputes via
    the chunked path.
    """
    from ..kernels.flash_attention.kernel import flash_attention
    import jax as _jax
    B, S, _ = x.shape
    h, kvh, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q, k, v = _qkv(cfg, p, x, positions)
    interpret = _jax.default_backend() != "tpu"
    out = flash_attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                          v.transpose(0, 2, 1, 3), causal=causal,
                          window=window, interpret=interpret)
    out = out.transpose(0, 2, 1, 3).reshape(B, S, h * dh)
    return jnp.einsum("bse,ed->bsd", out, p["wo"]), (k, v)


def attention_fwd(cfg, p, x, positions, *, causal: bool = True,
                  window: int = 0):
    """Dispatch on cfg.attn_impl: 'chunked' (default), 'naive', 'pallas'."""
    impl = getattr(cfg, "attn_impl", "chunked")
    if impl == "naive":
        return full_attention(cfg, p, x, positions, causal=causal,
                              window=window)
    if impl == "pallas":
        return pallas_attention(cfg, p, x, positions, causal=causal,
                                window=window)
    return chunked_attention(cfg, p, x, positions, causal=causal,
                             window=window)


def paged_decode_attention(cfg, p, x, pos, arena_k, arena_v, block_table,
                           kv_positions, *, window: int = 0):
    """One-token decode reading K/V through the page-table indirection.

    x:            [B, D]       current-token activations
    pos:          [B]          current position of each sequence
    arena_k/v:    [num_pages+1, page, K, Dh]   (last page = dump)
    block_table:  [B, P]       page ids (-1 → dump page)
    kv_positions: [B, P*page]  token position held by each slot (-1 invalid)

    Returns (out [B, D], (k_new, v_new)) — the caller is responsible for
    having scattered k_new/v_new into the arena *before* calling (see
    ``kvcache.append_kv``); kv_positions already reflects the new token.
    """
    B, D = x.shape
    h, kvh, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    g = h // kvh
    page = arena_k.shape[1]
    P = block_table.shape[1]
    q = jnp.einsum("bd,de->be", x, p["wq"])
    if cfg.qkv_bias:
        q = q + p["bq"]
    q = q.reshape(B, h, dh)
    if cfg.use_rope:
        q = apply_rope(q[:, None], pos[:, None], cfg.rope_theta)[:, 0]

    dump = arena_k.shape[0] - 1
    bt = jnp.where(block_table < 0, dump, block_table)
    k = arena_k[bt].reshape(B, P * page, kvh, dh)     # gather via page table
    v = arena_v[bt].reshape(B, P * page, kvh, dh)
    qg = q.reshape(B, kvh, g, dh)
    scores = jnp.einsum("bkgd,btkd->bkgt", qg, k,
                        preferred_element_type=jnp.float32) * (dh ** -0.5)
    valid = (kv_positions >= 0) & (kv_positions <= pos[:, None])
    if window:
        valid = valid & (kv_positions > (pos[:, None] - window))
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkgt,btkd->bkgd", probs, v).reshape(B, h * dh)
    return jnp.einsum("be,ed->bd", out, p["wo"])


def decode_kv(cfg, p, x, pos):
    """Current token's k/v (for the caller to scatter into the arena)."""
    kk = jnp.einsum("bd,de->be", x, p["wk"])
    v = jnp.einsum("bd,de->be", x, p["wv"])
    if cfg.qkv_bias:
        kk, v = kk + p["bk"], v + p["bv"]
    kvh, dh = cfg.num_kv_heads, cfg.head_dim
    kk = kk.reshape(x.shape[0], kvh, dh)
    v = v.reshape(x.shape[0], kvh, dh)
    if cfg.use_rope:
        kk = apply_rope(kk[:, None], pos[:, None], cfg.rope_theta)[:, 0]
    return kk, v
