"""Shared layer primitives: norms, embeddings, initializers.

Parameters are plain nested dicts of ``jnp`` arrays.  Every leaf is
created through ``param()`` so initialization is deterministic per path
and abstract-initializable via ``jax.eval_shape`` (the dry-run never
allocates real weights).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def param(key, shape, dtype=jnp.bfloat16, scale: float | None = None,
          init: str = "normal"):
    if init == "zeros":
        return jnp.zeros(shape, dtype)
    if init == "ones":
        return jnp.ones(shape, dtype)
    fan_in = shape[0] if len(shape) >= 2 else max(shape[-1], 1)
    s = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * s).astype(dtype)


# Norms carry custom VJPs that keep every [B,S,D]-shaped backward tensor
# in the residual dtype (bf16).  Without this, the einsum-f32 VJP converts
# x wholesale and XLA pushes that convert into the *saved scan-carry
# stack* — observed +78 GiB/device (f32[52,16,4096,6144]) on granite-20b.
# fp32 is kept only for per-position scalars (mean / inv-std).

def _f32_rowsum(a, b):
    return jnp.einsum("...d,...d->...", a, b,
                      preferred_element_type=jnp.float32)


@jax.custom_vjp
def rmsnorm(x, w, eps: float = 1e-6):
    d = x.shape[-1]
    inv = jax.lax.rsqrt(_f32_rowsum(x, x) / d + eps)
    return x * inv[..., None].astype(x.dtype) * w.astype(x.dtype)


def _rms_fwd(x, w, eps):
    d = x.shape[-1]
    inv = jax.lax.rsqrt(_f32_rowsum(x, x) / d + eps)
    y = x * inv[..., None].astype(x.dtype) * w.astype(x.dtype)
    return y, (x, w, inv)


def _rms_bwd(res, ct):
    x, w, inv = res
    d = x.shape[-1]
    t = ct * w.astype(x.dtype)                          # bf16 [B,S,D]
    dot = _f32_rowsum(t, x)                             # f32  [B,S]
    coef = (inv ** 3 * dot / d)[..., None].astype(x.dtype)
    dx = t * inv[..., None].astype(x.dtype) - x * coef
    xhat = x * inv[..., None].astype(x.dtype)
    dw = jnp.einsum("...d,...d->d", ct, xhat,
                    preferred_element_type=jnp.float32).astype(w.dtype)
    return dx, dw, None


rmsnorm.defvjp(_rms_fwd, _rms_bwd)


@jax.custom_vjp
def layernorm(x, w, b, eps: float = 1e-5):
    y, _ = _ln_fwd_impl(x, eps)
    return y * w.astype(x.dtype) + b.astype(x.dtype)


def _ln_fwd_impl(x, eps):
    d = x.shape[-1]
    mu = jnp.einsum("...d->...", x,
                    preferred_element_type=jnp.float32) / d
    ssq = _f32_rowsum(x, x) / d
    inv = jax.lax.rsqrt(ssq - mu * mu + eps)
    xhat = (x - mu[..., None].astype(x.dtype)) * inv[..., None].astype(x.dtype)
    return xhat, (mu, inv)


def _ln_fwd(x, w, b, eps):
    xhat, (mu, inv) = _ln_fwd_impl(x, eps)
    return xhat * w.astype(x.dtype) + b.astype(x.dtype), (x, w, mu, inv)


def _ln_bwd(res, ct):
    x, w, mu, inv = res
    d = x.shape[-1]
    xhat = (x - mu[..., None].astype(x.dtype)) * inv[..., None].astype(x.dtype)
    t = ct * w.astype(x.dtype)
    m1 = (jnp.einsum("...d->...", t,
                     preferred_element_type=jnp.float32) / d)[..., None]
    m2 = (_f32_rowsum(t, xhat) / d)[..., None]
    dx = (t - m1.astype(x.dtype) - xhat * m2.astype(x.dtype)) \
        * inv[..., None].astype(x.dtype)
    dw = jnp.einsum("...d,...d->d", ct, xhat,
                    preferred_element_type=jnp.float32).astype(w.dtype)
    db = jnp.einsum("...d->d", ct,
                    preferred_element_type=jnp.float32).astype(w.dtype)
    return dx, dw, db, None


layernorm.defvjp(_ln_fwd, _ln_bwd)


def norm_params(kind: str, d: int, key):
    if kind == "rmsnorm":
        return {"w": jnp.ones((d,), jnp.float32)}
    return {"w": jnp.ones((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)}


def apply_norm(kind: str, p, x):
    if kind == "rmsnorm":
        return rmsnorm(x, p["w"])
    return layernorm(x, p["w"], p["b"])


def embed(tokens, table):
    return jnp.take(table, tokens, axis=0)


def unembed(x, table):
    """Logits against a (possibly tied) [V, D] table, f32 accumulation."""
    return jnp.einsum("...d,vd->...v", x, table,
                      preferred_element_type=jnp.float32)
