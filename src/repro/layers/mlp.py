"""Feed-forward variants used by the assigned architectures.

  * ``swiglu``        — gated SiLU (LLaMA / Qwen2.5 / Moonlight)
  * ``squared_relu``  — non-gated ReLU² (Nemotron-4, Primer)
  * ``gelu``          — non-gated GELU (StarCoder2 / granite GPT-BigCode
                        lineage, HuBERT, ViT stubs)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import param


def init_mlp(cfg, key):
    k1, k2, k3 = jax.random.split(key, 3)
    d, f = cfg.d_model, cfg.d_ff
    if cfg.mlp == "swiglu":
        return {"wi": param(k1, (d, f), cfg.dtype),
                "wg": param(k2, (d, f), cfg.dtype),
                "wo": param(k3, (f, d), cfg.dtype)}
    return {"wi": param(k1, (d, f), cfg.dtype),
            "wo": param(k3, (f, d), cfg.dtype)}


def apply_mlp(cfg, p, x):
    if cfg.mlp == "swiglu":
        h = jnp.einsum("...d,df->...f", x, p["wi"])
        g = jnp.einsum("...d,df->...f", x, p["wg"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * h
    else:
        h = jnp.einsum("...d,df->...f", x, p["wi"])
        if cfg.mlp == "squared_relu":
            h = jnp.square(jax.nn.relu(h))
        else:
            h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("...f,fd->...d", h, p["wo"])
