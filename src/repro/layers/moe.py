"""Mixture-of-experts FFN with top-k routing (granite-MoE, Moonlight).

Dispatch is argsort-based with a static per-expert capacity (GShard-style
token dropping) — ragged grouping is expressed as sort + segment
positions so every shape stays static for XLA.  Experts are sharded over
the ``model`` mesh axis when divisible (expert parallelism; the
resharding materializes as all-to-alls in the lowered HLO), otherwise
over ``d_ff`` (tensor parallelism) — see ``distributed.sharding``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import param


def init_moe(cfg, key):
    kr, k1, k2, k3 = jax.random.split(key, 4)
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ep = e + cfg.expert_pad          # zero-padded so E divides the TP axis
    p = {
        "router": param(kr, (d, e), jnp.float32),
        "wi": param(k1, (ep, d, f), cfg.dtype),
        "wo": param(k3, (ep, f, d), cfg.dtype),
    }
    if cfg.mlp == "swiglu":
        p["wg"] = param(k2, (ep, d, f), cfg.dtype)
    return p


def apply_moe(cfg, p, x, *, capacity_factor: float = 1.25,
              constrain=lambda a: a):
    """Dispatch router: 'local' (per-batch-row, shard-friendly) or the
    original 'global' argsort dispatch."""
    if getattr(cfg, "moe_dispatch", "global") == "local":
        return apply_moe_local(cfg, p, x, capacity_factor=capacity_factor,
                               constrain=constrain)
    return apply_moe_global(cfg, p, x, capacity_factor=capacity_factor)


def apply_moe_local(cfg, p, x, *, capacity_factor: float = 1.25,
                    constrain=lambda a: a):
    """Per-batch-row dispatch: every token's (sort, scatter, gather) stays
    within its own batch row, so with batch sharded over the data axes the
    dispatch generates **zero cross-data-shard collectives** — only the
    expert contraction communicates (over the model/EP axis).

    §Perf B3: the global-argsort dispatch below sorts all B·S·K
    assignments jointly, which XLA partitions with all-to-alls and
    all-reduces across data; measured 92 s collective term on
    granite-moe train_4k.  Capacity here is per row (1.25·S·K/E), so
    drop behaviour differs slightly from the global router at row-level
    load imbalance — same expectation, tested for parity at high
    capacity_factor.
    """
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.top_k
    N = S * K

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert = jax.lax.top_k(probs, K)                  # [B, S, K]
    gate = gate / jnp.clip(gate.sum(-1, keepdims=True), 1e-9)

    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.zeros((E,)).at[expert.reshape(-1)].add(1.0) / (B * N)
    aux = E * jnp.sum(me * ce)

    cap = int(max(1, round(capacity_factor * N / E)))
    flat_e = expert.reshape(B, N)                           # [B, S*K]
    order = jnp.argsort(flat_e, axis=-1, stable=True)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    start = jax.vmap(lambda row: jnp.searchsorted(
        row, jnp.arange(E, dtype=jnp.int32)))(sorted_e)     # [B, E]
    pos = jnp.arange(N, dtype=jnp.int32)[None] - \
        jnp.take_along_axis(start, sorted_e, axis=-1)
    b_ix = jnp.arange(B, dtype=jnp.int32)[:, None]
    ranks = jnp.zeros((B, N), jnp.int32).at[b_ix, order].set(pos)
    keep = ranks < cap

    slot = jnp.where(keep, flat_e * cap + ranks, E * cap)   # per-row dump
    tok = jnp.repeat(jnp.arange(S, dtype=jnp.int32)[None], B, 0)
    tok = jnp.repeat(tok, K, axis=-1).reshape(B, N)
    buf = jnp.zeros((B, E * cap + 1, D), x.dtype)
    buf = buf.at[b_ix, slot].set(
        jnp.take_along_axis(x, tok[..., None], axis=1))
    # keep the dispatch buffer batch-sharded: without the constraint XLA
    # replicates its batch dim and all-reduces it across data (§Perf B3)
    buf = constrain(buf)
    buf = buf[:, :-1].reshape(B, E, cap, D)

    h = jnp.einsum("becd,edf->becf", buf, p["wi"][:E])
    if cfg.mlp == "swiglu":
        g = jnp.einsum("becd,edf->becf", buf, p["wg"][:E])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * h
    elif cfg.mlp == "squared_relu":
        h = jnp.square(jax.nn.relu(h))
    else:
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    out_e = constrain(jnp.einsum("becf,efd->becd", h, p["wo"][:E]))
    out_flat = out_e.reshape(B, E * cap, D)

    gathered = jnp.take_along_axis(
        out_flat, jnp.clip(slot, 0, E * cap - 1)[..., None], axis=1)
    gathered = jnp.where(keep[..., None], gathered, 0).astype(jnp.float32)
    y = jnp.zeros((B, S, D), jnp.float32).at[b_ix, tok].add(
        gathered * gate.reshape(B, N)[..., None])
    return y.astype(x.dtype), aux


def apply_moe_global(cfg, p, x, *, capacity_factor: float = 1.25):
    """x: [B, S, D] → [B, S, D] plus aux load-balancing loss."""
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.top_k
    N = B * S
    xf = x.reshape(N, D)

    logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert = jax.lax.top_k(probs, K)                    # [N, K]
    gate = gate / jnp.clip(gate.sum(-1, keepdims=True), 1e-9)

    # load-balancing auxiliary loss (Switch): E * Σ_e f_e · P_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.zeros((E,)).at[expert.reshape(-1)].add(1.0) / (N * K)
    aux = E * jnp.sum(me * ce)

    cap = int(max(1, round(capacity_factor * N * K / E)))
    flat_e = expert.reshape(-1)                               # [N*K]
    # position of each (token, k) within its expert queue
    order = jnp.argsort(flat_e, stable=True)
    ranks = jnp.zeros((N * K,), jnp.int32)
    seq = jnp.arange(N * K, dtype=jnp.int32)
    sorted_e = flat_e[order]
    start = jnp.searchsorted(sorted_e, jnp.arange(E, dtype=jnp.int32))
    pos_in_e = seq - start[sorted_e]
    ranks = ranks.at[order].set(pos_in_e)
    keep = ranks < cap                                        # dropped beyond cap

    # scatter tokens into the [E, cap, D] buffer
    slot = jnp.where(keep, flat_e * cap + ranks, E * cap)     # dump slot
    buf = jnp.zeros((E * cap + 1, D), x.dtype)
    tok_idx = jnp.repeat(jnp.arange(N, dtype=jnp.int32), K)
    buf = buf.at[slot].set(xf[tok_idx])
    buf = buf[:-1].reshape(E, cap, D)

    h = jnp.einsum("ecd,edf->ecf", buf, p["wi"][:E])
    if cfg.mlp == "swiglu":
        g = jnp.einsum("ecd,edf->ecf", buf, p["wg"][:E])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * h
    elif cfg.mlp == "squared_relu":
        h = jnp.square(jax.nn.relu(h))
    else:
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    out_e = jnp.einsum("ecf,efd->ecd", h, p["wo"][:E]).reshape(E * cap, D)

    # gather back and combine with gates
    gathered = jnp.where(keep[:, None], out_e[jnp.clip(slot, 0, E * cap - 1)],
                         0).astype(jnp.float32)
    y = jnp.zeros((N, D), jnp.float32).at[tok_idx].add(
        gathered * gate.reshape(-1)[:, None])
    return y.astype(x.dtype).reshape(B, S, D), aux
