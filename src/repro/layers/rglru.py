"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Full-sequence mode uses ``lax.associative_scan`` over the elementwise
linear recurrence h_t = a_t ⊙ h_{t-1} + b_t — O(log S) depth on TPU.
Decode keeps per-sequence state pages in the Ralloc arena (constant
memory; together with the bounded local-attention window this is why
recurrentgemma runs the ``long_500k`` shape).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import param

_C = 8.0  # Griffin's fixed exponent scale


def init_rglru(cfg, key):
    ks = jax.random.split(key, 7)
    D, W = cfg.d_model, cfg.lru_width
    return {
        "in_x": param(ks[0], (D, W), cfg.dtype),      # recurrent branch
        "in_g": param(ks[1], (D, W), cfg.dtype),      # gelu gate branch
        "conv_w": param(ks[2], (cfg.conv_width, W), cfg.dtype,
                        scale=cfg.conv_width ** -0.5),
        "conv_b": jnp.zeros((W,), cfg.dtype),
        "wa": param(ks[3], (W, W), cfg.dtype),        # recurrence gate r_t
        "wx": param(ks[4], (W, W), cfg.dtype),        # input gate i_t
        "lam": jnp.full((W,), 2.0, jnp.float32),      # Λ (a = σ(Λ) ≈ 0.88)
        "out": param(ks[5], (W, D), cfg.dtype),
    }


def _conv(cfg, p, u):
    W = cfg.conv_width
    pad = jnp.pad(u, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(pad[:, k:k + u.shape[1], :] * p["conv_w"][k] for k in range(W))
    return (out + p["conv_b"]).astype(u.dtype)


def _gates(p, x):
    r = jax.nn.sigmoid(jnp.einsum("...w,wv->...v", x, p["wa"])
                       .astype(jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum("...w,wv->...v", x, p["wx"])
                       .astype(jnp.float32))
    log_a = -_C * r * jax.nn.softplus(p["lam"])       # log a_t  (a_t ∈ (0,1))
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.clip(1.0 - a * a, 1e-12)) * (i * x.astype(jnp.float32))
    return a, b


def rglru_forward(cfg, p, x):
    """x: [B, S, D] → [B, S, D] via associative scan over the recurrence."""
    xr = jnp.einsum("bsd,dw->bsw", x, p["in_x"])
    xg = jnp.einsum("bsd,dw->bsw", x, p["in_g"])
    xr = _conv(cfg, p, xr)
    a, b = _gates(p, xr)

    def combine(u, v):
        au, bu = u
        av, bv = v
        return au * av, bu * av + bv

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = h * jax.nn.gelu(xg.astype(jnp.float32))
    return jnp.einsum("bsw,wd->bsd", y.astype(x.dtype), p["out"])


def rglru_init_state(cfg, batch):
    return {
        "h": jnp.zeros((batch, cfg.lru_width), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.lru_width),
                          jnp.float32),
    }


def rglru_decode(cfg, p, x, state):
    """Single-token update.  x: [B, D] → ([B, D], state')."""
    xr = jnp.einsum("bd,dw->bw", x, p["in_x"]).astype(jnp.float32)
    xg = jnp.einsum("bd,dw->bw", x, p["in_g"])
    hist = jnp.concatenate([state["conv"], xr[:, None, :]], axis=1)
    conv = jnp.einsum("bwc,wc->bc", hist, p["conv_w"].astype(jnp.float32))
    conv = (conv + p["conv_b"].astype(jnp.float32)).astype(x.dtype)
    a, b = _gates(p, conv)
    h = a * state["h"] + b
    y = h * jax.nn.gelu(xg.astype(jnp.float32))
    out = jnp.einsum("bw,wd->bd", y.astype(x.dtype), p["out"])
    return out, {"h": h, "conv": hist[:, 1:, :]}
