"""Rotary position embeddings (RoPE), applied in fp32 for stability."""

from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float = 1e4):
    half = head_dim // 2
    return theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)


def apply_rope(x, positions, theta: float = 1e4):
    """x: [..., S, H, Dh]; positions: broadcastable to [..., S]."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                      # [dh/2]
    ang = positions[..., None].astype(jnp.float32) * freqs   # [..., S, dh/2]
    cos = jnp.cos(ang)[..., None, :]                   # [..., S, 1, dh/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)
