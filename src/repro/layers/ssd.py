"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060).

Chunked implementation: within-chunk interactions use the quadratic
"attention-like" dual form (MXU-friendly Q×Q matmuls), while the O(S)
inter-chunk state is carried by a ``lax.scan``.  Decode is a single
recurrent update over persistent per-sequence state pages — which the
paged-state manager allocates from the Ralloc arena exactly like KV
pages (constant memory per sequence: the reason this arch runs the
``long_500k`` shape).

Projections are kept *split* (z | x | BC | dt) rather than fused so that
tensor parallelism can shard z/x/dt by SSM head and replicate the small
B/C/state projections (see ``serving.tp_layers``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import param


def d_inner(cfg):
    return cfg.expand * cfg.d_model


def n_heads(cfg):
    return d_inner(cfg) // cfg.ssm_head_dim


def init_mamba2(cfg, key):
    ks = jax.random.split(key, 8)
    D, Di, N, H = cfg.d_model, d_inner(cfg), cfg.ssm_state, n_heads(cfg)
    return {
        "in_z": param(ks[0], (D, Di), cfg.dtype),
        "in_x": param(ks[1], (D, Di), cfg.dtype),
        "in_bc": param(ks[2], (D, 2 * N), cfg.dtype),
        "in_dt": param(ks[3], (D, H), cfg.dtype),
        "conv_x_w": param(ks[4], (cfg.conv_width, Di), cfg.dtype,
                          scale=cfg.conv_width ** -0.5),
        "conv_x_b": jnp.zeros((Di,), cfg.dtype),
        "conv_bc_w": param(ks[5], (cfg.conv_width, 2 * N), cfg.dtype,
                           scale=cfg.conv_width ** -0.5),
        "conv_bc_b": jnp.zeros((2 * N,), cfg.dtype),
        "A_log": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm_w": jnp.ones((Di,), jnp.float32),
        "out_proj": param(ks[6], (Di, D), cfg.dtype),
    }


def _causal_conv(u, w, b):
    """Depthwise causal conv1d over [B, S, C]."""
    W = w.shape[0]
    pad = jnp.pad(u, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(pad[:, k:k + u.shape[1], :] * w[k] for k in range(W))
    return jax.nn.silu((out + b).astype(jnp.float32)).astype(u.dtype)


def _gated_norm(y, z, w, eps=1e-6):
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = y * jax.lax.rsqrt(jnp.mean(y * y, axis=-1, keepdims=True) + eps)
    return y * w


def ssd_chunked(cfg, xdt, loga, Bc, Cc, h0=None):
    """Core chunked SSD over pre-discretized inputs.

    xdt:  [B, nc, Q, H, P] (x ⊙ dt, fp32)
    loga: [B, nc, Q, H]    (dt · A, fp32 log-decay)
    Bc/Cc:[B, nc, Q, N]
    Returns (y [B, nc, Q, H, P], h_final [B, H, P, N]).
    """
    Bsz, nc, Q, H, P = xdt.shape
    N = Bc.shape[-1]
    cums = jnp.cumsum(loga, axis=2)
    G = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)
    rel = cums[:, :, :, None, :] - cums[:, :, None, :, :]
    ii = jnp.arange(Q)
    causal = ii[:, None] >= ii[None, :]
    L = jnp.where(causal[None, None, :, :, None], jnp.exp(rel), 0.0)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", G[..., None] * L, xdt)

    decay_out = jnp.exp(cums[:, :, -1:, :] - cums)
    chunk_state = jnp.einsum("bcjn,bcjh,bcjhp->bchpn", Bc, decay_out, xdt)
    chunk_decay = jnp.exp(cums[:, :, -1, :])

    def step(h, inp):
        st, dec = inp
        return h * dec[:, :, None, None] + st, h

    if h0 is None:
        h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    h_fin, h_ins = jax.lax.scan(
        step, h0, (chunk_state.transpose(1, 0, 2, 3, 4),
                   chunk_decay.transpose(1, 0, 2)))
    h_ins = h_ins.transpose(1, 0, 2, 3, 4)
    y_inter = jnp.einsum("bcin,bchpn,bcih->bcihp", Cc, h_ins, jnp.exp(cums))
    return y_intra + y_inter, h_fin


def mamba2_forward(cfg, p, x):
    """Full-sequence SSD.  x: [B, S, D] → [B, S, D]."""
    Bsz, S, D = x.shape
    Di, N, H, P = d_inner(cfg), cfg.ssm_state, n_heads(cfg), cfg.ssm_head_dim
    Q = cfg.ssm_chunk
    assert S % Q == 0, f"seq {S} not divisible by chunk {Q}"
    nc = S // Q

    z = jnp.einsum("bsd,de->bse", x, p["in_z"])
    xs = jnp.einsum("bsd,de->bse", x, p["in_x"])
    bc = jnp.einsum("bsd,de->bse", x, p["in_bc"])
    dt = jnp.einsum("bsd,de->bse", x, p["in_dt"])
    xs = _causal_conv(xs, p["conv_x_w"], p["conv_x_b"])
    bc = _causal_conv(bc, p["conv_bc_w"], p["conv_bc_b"])
    Bm, Cm = bc[..., :N], bc[..., N:]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    loga = (dt * A).reshape(Bsz, nc, Q, H)
    xh = xs.reshape(Bsz, nc, Q, H, P).astype(jnp.float32)
    xdt = xh * dt.reshape(Bsz, nc, Q, H)[..., None]
    Bc = Bm.reshape(Bsz, nc, Q, N).astype(jnp.float32)
    Cc = Cm.reshape(Bsz, nc, Q, N).astype(jnp.float32)

    y, _ = ssd_chunked(cfg, xdt, loga, Bc, Cc)
    y = y.reshape(Bsz, S, H, P) + p["D"][None, None, :, None] * \
        xh.reshape(Bsz, S, H, P)
    y = _gated_norm(y.reshape(Bsz, S, Di), z.astype(jnp.float32), p["norm_w"])
    return jnp.einsum("bse,ed->bsd", y.astype(x.dtype), p["out_proj"])


def mamba2_init_state(cfg, batch):
    Di, N, H, P = d_inner(cfg), cfg.ssm_state, n_heads(cfg), cfg.ssm_head_dim
    return {
        "h": jnp.zeros((batch, H, P, N), jnp.float32),
        "conv_x": jnp.zeros((batch, cfg.conv_width - 1, Di), jnp.float32),
        "conv_bc": jnp.zeros((batch, cfg.conv_width - 1, 2 * N), jnp.float32),
    }


def mamba2_decode(cfg, p, x, state):
    """Single-token recurrent update.  x: [B, D] → ([B, D], state')."""
    Bsz, D = x.shape
    Di, N, H, P = d_inner(cfg), cfg.ssm_state, n_heads(cfg), cfg.ssm_head_dim
    z = jnp.einsum("bd,de->be", x, p["in_z"])
    xs = jnp.einsum("bd,de->be", x, p["in_x"]).astype(jnp.float32)
    bc = jnp.einsum("bd,de->be", x, p["in_bc"]).astype(jnp.float32)
    dt = jnp.einsum("bd,de->be", x, p["in_dt"])

    hist_x = jnp.concatenate([state["conv_x"], xs[:, None, :]], axis=1)
    hist_bc = jnp.concatenate([state["conv_bc"], bc[:, None, :]], axis=1)
    cx = jnp.einsum("bwc,wc->bc", hist_x, p["conv_x_w"].astype(jnp.float32))
    cx = jax.nn.silu(cx + p["conv_x_b"].astype(jnp.float32))
    cbc = jnp.einsum("bwc,wc->bc", hist_bc, p["conv_bc_w"].astype(jnp.float32))
    cbc = jax.nn.silu(cbc + p["conv_bc_b"].astype(jnp.float32))
    Bm, Cm = cbc[:, :N], cbc[:, N:]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = jnp.exp(dt * -jnp.exp(p["A_log"]))
    xh = cx.reshape(Bsz, H, P)
    h = (state["h"] * a[:, :, None, None]
         + jnp.einsum("bn,bhp,bh->bhpn", Bm, xh, dt))
    y = jnp.einsum("bn,bhpn->bhp", Cm, h) + p["D"][None, :, None] * xh
    y = _gated_norm(y.reshape(Bsz, Di), z.astype(jnp.float32), p["norm_w"])
    out = jnp.einsum("be,ed->bd", y.astype(x.dtype), p["out_proj"])
    return out, {"h": h, "conv_x": hist_x[:, 1:, :], "conv_bc": hist_bc[:, 1:, :]}
