"""Model configuration covering all ten assigned architectures.

A model is a stack of (mixer, ffn) layer specs cycled from ``pattern``:

  mixer ∈ {"attn", "local_attn", "mamba2", "rglru"}
  ffn   ∈ {"mlp", "moe", "none"}

Uniform stacks (pattern length 1) scan over layers; hybrid stacks
(RecurrentGemma's 2×RG-LRU : 1×local-attn) scan over *pattern units*
with any remainder layers unrolled.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    # layer pattern: tuple of (mixer, ffn) cycled over layers
    pattern: tuple[tuple[str, str], ...] = (("attn", "mlp"),)
    # attention options
    qkv_bias: bool = False
    use_rope: bool = True
    rope_theta: float = 1e4
    causal: bool = True
    window: int = 0                # local-attention window (0 = full)
    # ffn options
    mlp: str = "swiglu"            # swiglu | squared_relu | gelu
    norm: str = "rmsnorm"
    tie_embeddings: bool = False
    # moe
    num_experts: int = 0
    top_k: int = 0
    expert_pad: int = 0            # zero experts padding E to a TP multiple
    capacity_factor: float = 1.25
    moe_dispatch: str = "local"    # local (per batch row; §Perf B5) | global
    # ssm (mamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_chunk: int = 64
    conv_width: int = 4
    expand: int = 2
    # hybrid (rg-lru)
    lru_width: int = 0
    # modality frontend stub: None | "audio" | "vision"
    frontend: str | None = None
    # numerics / memory
    dtype: Any = jnp.bfloat16
    remat: str = "unit"            # none | unit (checkpoint each pattern unit)
    attn_impl: str = "chunked"     # chunked (flash-style) | naive
    # serving
    page_size: int = 128           # KV-arena tokens per page
    kv_dtype: str = "bf16"         # bf16 | int8 (per-slot-per-head scales)

    # ------------------------------------------------------------------
    @property
    def layer_specs(self) -> tuple[tuple[str, str], ...]:
        m = len(self.pattern)
        return tuple(self.pattern[i % m] for i in range(self.num_layers))

    @property
    def full_units(self) -> int:
        return self.num_layers // len(self.pattern)

    @property
    def tail_specs(self) -> tuple[tuple[str, str], ...]:
        r = self.num_layers % len(self.pattern)
        return self.pattern[:r]

    @property
    def attn_layers(self) -> int:
        return sum(1 for mx, _ in self.layer_specs
                   if mx in ("attn", "local_attn"))

    @property
    def is_encoder(self) -> bool:
        return not self.causal

    def param_count(self) -> int:
        """Analytic parameter count (embeddings included once)."""
        n = self.vocab_size * self.d_model
        if not self.tie_embeddings and self.vocab_size:
            n += self.vocab_size * self.d_model
        for mixer, ffn in self.layer_specs:
            if mixer in ("attn", "local_attn"):
                n += self.d_model * (self.num_heads + 2 * self.num_kv_heads) \
                     * self.head_dim
                n += self.num_heads * self.head_dim * self.d_model
            elif mixer == "mamba2":
                di = self.expand * self.d_model
                h = di // self.ssm_head_dim
                n += self.d_model * (2 * di + 2 * self.ssm_state + h)
                n += di * self.d_model
            elif mixer == "rglru":
                w = self.lru_width
                n += 2 * self.d_model * w + 2 * w * w + w * self.d_model
            if ffn == "mlp":
                k = 3 if self.mlp == "swiglu" else 2
                n += k * self.d_model * self.d_ff
            elif ffn == "moe":
                k = 3 if self.mlp == "swiglu" else 2
                n += self.num_experts * k * self.d_model * self.d_ff
                n += self.d_model * self.num_experts
        return n

    def active_param_count(self) -> int:
        """MoE: params touched per token (router top-k)."""
        if self.family != "moe":
            return self.param_count()
        dense = self.param_count()
        k = 3 if self.mlp == "swiglu" else 2
        per_expert = k * self.d_model * self.d_ff
        n_moe = sum(1 for _, f in self.layer_specs if f == "moe")
        return dense - n_moe * (self.num_experts - self.top_k) * per_expert
