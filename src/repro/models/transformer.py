"""Unified model stack: init + full-sequence forward for all families.

Layers are grouped into *pattern units* and scanned (``lax.scan`` over
stacked unit parameters) with optional per-unit rematerialization — the
combination that keeps both HLO size and activation memory bounded at
the assigned model scales.  The decode path (single token, paged KV /
recurrent state) lives in ``repro.serving``; this module is the
training/prefill oracle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..layers import attention, mlp as mlp_lib, moe as moe_lib, rglru, ssd
from ..layers.common import apply_norm, embed, norm_params, param, unembed
from .config import ModelConfig


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _init_layer(cfg: ModelConfig, spec, key):
    mixer, ffn = spec
    kmix, kffn, kn1, kn2 = jax.random.split(key, 4)
    p = {"norm1": norm_params(cfg.norm, cfg.d_model, kn1)}
    if mixer in ("attn", "local_attn"):
        p["attn"] = attention.init_attention(cfg, kmix)
    elif mixer == "mamba2":
        p["ssd"] = ssd.init_mamba2(cfg, kmix)
    elif mixer == "rglru":
        p["rglru"] = rglru.init_rglru(cfg, kmix)
    else:
        raise ValueError(mixer)
    if ffn != "none":
        p["norm2"] = norm_params(cfg.norm, cfg.d_model, kn2)
        p["ffn"] = (moe_lib.init_moe(cfg, kffn) if ffn == "moe"
                    else mlp_lib.init_mlp(cfg, kffn))
    return p


def _init_unit(cfg: ModelConfig, key):
    keys = jax.random.split(key, len(cfg.pattern))
    return {f"l{i}": _init_layer(cfg, spec, keys[i])
            for i, spec in enumerate(cfg.pattern)}


def init_params(cfg: ModelConfig, key):
    ku, kt, ke, kh, kf = jax.random.split(key, 5)
    units = jax.vmap(lambda k: _init_unit(cfg, k))(
        jax.random.split(ku, cfg.full_units))
    params = {
        "units": units,
        "final_norm": norm_params(cfg.norm, cfg.d_model, kh),
        "embed": param(ke, (cfg.vocab_size, cfg.d_model), cfg.dtype,
                       scale=1.0 / (cfg.d_model ** 0.5)),
    }
    if cfg.tail_specs:
        tkeys = jax.random.split(kt, len(cfg.tail_specs))
        params["tail"] = {f"t{i}": _init_layer(cfg, spec, tkeys[i])
                          for i, spec in enumerate(cfg.tail_specs)}
    if not cfg.tie_embeddings:
        params["unembed"] = param(kf, (cfg.vocab_size, cfg.d_model),
                                  cfg.dtype, scale=1.0 / (cfg.d_model ** 0.5))
    return params


# ---------------------------------------------------------------------------
# forward (full sequence)
# ---------------------------------------------------------------------------
def _apply_layer(cfg: ModelConfig, spec, p, x, positions,
                 constrain=lambda a: a):
    mixer, ffn = spec
    aux = jnp.float32(0.0)
    kv = None
    h = apply_norm(cfg.norm, p["norm1"], x)
    if mixer == "attn":
        h, kv = attention.attention_fwd(cfg, p["attn"], h, positions,
                                        causal=cfg.causal, window=0)
    elif mixer == "local_attn":
        h, kv = attention.attention_fwd(cfg, p["attn"], h, positions,
                                        causal=cfg.causal, window=cfg.window)
    elif mixer == "mamba2":
        h = ssd.mamba2_forward(cfg, p["ssd"], h)
    elif mixer == "rglru":
        h = rglru.rglru_forward(cfg, p["rglru"], h)
    x = x + h
    if ffn != "none":
        h = apply_norm(cfg.norm, p["norm2"], x)
        if ffn == "moe":
            h, aux = moe_lib.apply_moe(cfg, p["ffn"], h,
                                       capacity_factor=cfg.capacity_factor,
                                       constrain=constrain)
        else:
            h = mlp_lib.apply_mlp(cfg, p["ffn"], h)
        x = x + h
    return x, aux, kv


def forward(cfg: ModelConfig, params, batch, *, collect_kv: bool = False,
            constrain=lambda x: x):
    """Full-sequence forward.

    batch: {"tokens": i32[B,S]} or {"embeds": [B,S,D]} for stub frontends.
    Returns (logits f32[B,S,V], aux_loss[, kv]) — with ``collect_kv`` the
    per-attention-layer (k, v) tensors are stacked across scan units
    (prefill writes them into the paged arena; see ``serving.engine``).
    """
    if cfg.frontend is not None and "embeds" in batch:
        x = batch["embeds"].astype(cfg.dtype)
    else:
        x = embed(batch["tokens"], params["embed"])
    x = constrain(x)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def unit_fn(x, unit_p):
        aux = jnp.float32(0.0)
        kvs = {}
        for i, spec in enumerate(cfg.pattern):
            x, a, kv = _apply_layer(cfg, spec, unit_p[f"l{i}"], x, positions,
                                    constrain)
            x = constrain(x)
            aux = aux + a
            if collect_kv and kv is not None:
                kvs[f"l{i}"] = kv
        return x, (aux, kvs)

    body = unit_fn
    if cfg.remat == "unit":
        body = jax.checkpoint(unit_fn, prevent_cse=False)

    x, (auxs, kv_units) = jax.lax.scan(lambda c, p: body(c, p),
                                       x, params["units"])
    aux = auxs.sum()
    kv_tail = {}
    for i, spec in enumerate(cfg.tail_specs):
        x, a, kv = _apply_layer(cfg, spec, params["tail"][f"t{i}"], x,
                                positions)
        aux = aux + a
        if collect_kv and kv is not None:
            kv_tail[f"t{i}"] = kv
    x = apply_norm(cfg.norm, params["final_norm"], x)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = unembed(x, table)
    if collect_kv:
        return logits, aux, {"units": kv_units, "tail": kv_tail}
    return logits, aux


def hidden_states(cfg: ModelConfig, params, batch, constrain=lambda x: x):
    """Final-norm hidden states [B, S, D] (the pre-unembed activations)."""
    if cfg.frontend is not None and "embeds" in batch:
        x = batch["embeds"].astype(cfg.dtype)
    else:
        x = embed(batch["tokens"], params["embed"])
    x = constrain(x)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def unit_fn(x, unit_p):
        aux = jnp.float32(0.0)
        for i, spec in enumerate(cfg.pattern):
            x, a, _ = _apply_layer(cfg, spec, unit_p[f"l{i}"], x, positions,
                                   constrain)
            x = constrain(x)
            aux = aux + a
        return x, aux

    body = unit_fn
    if cfg.remat == "unit":
        body = jax.checkpoint(unit_fn, prevent_cse=False)
    x, auxs = jax.lax.scan(lambda c, p: body(c, p), x, params["units"])
    aux = auxs.sum()
    for i, spec in enumerate(cfg.tail_specs):
        x, a, _ = _apply_layer(cfg, spec, params["tail"][f"t{i}"], x,
                               positions)
        aux = aux + a
    return apply_norm(cfg.norm, params["final_norm"], x), aux


def chunked_ce(cfg: ModelConfig, x, table, labels, *, chunk: int = 256):
    """CE over the vocabulary computed in remat'd sequence chunks.

    Avoids ever materializing [B, S, V] fp32 logits — the unembed matmul
    and the logsumexp are recomputed per chunk in the backward pass.  The
    single biggest activation-memory lever for the large-vocab archs.
    """
    B, S, D = x.shape
    chunk = min(chunk, S)
    while S % chunk:
        chunk -= 1
    nc = S // chunk
    xc = x.reshape(B, nc, chunk, D).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, nc, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def one(xs, ls):
        logits = jnp.einsum("bsd,vd->bsv", xs, table,
                            preferred_element_type=jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.clip(ls, 0)[..., None], axis=-1)[..., 0]
        mask = ls >= 0
        return (jnp.where(mask, lse - gold, 0.0).sum(),
                mask.sum().astype(jnp.float32))

    def body(acc, inp):
        s, n = one(*inp)
        return (acc[0] + s, acc[1] + n), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)),
                                 (xc, lc))
    return tot / jnp.maximum(cnt, 1.0)


def loss_fn(cfg: ModelConfig, params, batch, *, aux_weight: float = 0.01,
            loss_chunk: int = 256, constrain=lambda x: x):
    """Next-token (causal) or frame-classification (encoder) CE loss."""
    x, aux = hidden_states(cfg, params, batch, constrain)
    labels = batch["labels"]
    if cfg.causal:
        x = x[:, :-1]
        labels = labels[:, 1:]
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    ce = chunked_ce(cfg, x, table, labels, chunk=loss_chunk)
    return ce + aux_weight * aux, {"ce": ce, "aux": aux}
