"""``repro.obs`` — unified observability: metrics registry, phase spans,
Chrome-trace export, live persist-waste gauges.

One process-wide default :class:`~repro.obs.registry.Registry` backs the
module-level helpers; instrumented modules cache metric objects at
import time (``_HIT = obs.counter("alloc.tcache_hit")``) so the hot-path
cost is one bound-method call with an enabled-flag branch — near zero
when disabled, tiny when enabled.

Metric naming conventions (see ROADMAP "Observability"):

  ``heap.*``      flush/fence/cas/drain counts of the live host heap
                  (registered as *sources* by ``PersistentHeap``)
  ``alloc.*``     host allocator paths (tcache, refill source, watermark)
  ``placement.*`` free-run index: exact-bucket vs overflow vs miss
  ``span.*``      large-span lease traffic (acquire/release/trim/free)
  ``device.*``    engine-side device-allocator call sites
  ``engine.*``    publish queue depth / flush batches
  ``sched.*``     admission (rejects, park-retries, queue depth)
  ``serve.*``     request latency (TTFT, total) histograms
  ``trie.*``      prefix-cache hit depth distribution
  ``recovery.*``  named recovery phases (span timings)
  ``persist.*``   waste gauges from an attached :class:`WasteMonitor`
"""

from __future__ import annotations

from .registry import Counter, Gauge, Histogram, Registry, UnknownMetric
from .waste import WasteMonitor

__all__ = [
    "Counter", "Gauge", "Histogram", "Registry", "UnknownMetric",
    "WasteMonitor", "get_registry", "counter", "gauge", "gauge_fn",
    "histogram", "register_source", "span", "snapshot", "chrome_trace",
    "reset", "reset_all", "enable", "disable", "is_enabled",
    "attach_waste_monitor",
]

_default = Registry(enabled=True)


def get_registry() -> Registry:
    return _default


def counter(name: str) -> Counter:
    return _default.counter(name)


def gauge(name: str) -> Gauge:
    return _default.gauge(name)


def gauge_fn(name: str, fn) -> Gauge:
    return _default.gauge_fn(name, fn)


def histogram(name: str) -> Histogram:
    return _default.histogram(name)


def register_source(name: str, read, reset=None) -> None:
    _default.register_source(name, read, reset)


def span(name: str, **args):
    return _default.span(name, **args)


def snapshot() -> dict:
    return _default.snapshot()


def chrome_trace() -> dict:
    return _default.chrome_trace()


def reset(*names: str) -> None:
    _default.reset(*names)


def reset_all() -> None:
    _default.reset_all()


def enable() -> None:
    _default.enable()


def disable() -> None:
    _default.disable()


def is_enabled() -> bool:
    return _default.enabled


def attach_waste_monitor(mem, registry: Registry | None = None
                         ) -> WasteMonitor:
    """Attach a :class:`WasteMonitor` to ``mem``'s tracer slot and bind
    its waste gauges (``persist.redundant_flushes`` / ``.empty_fences``)
    into the registry.  Returns the monitor; detach with
    ``mem.tracer = None``."""
    mon = WasteMonitor(registry or _default)
    mem.tracer = mon
    return mon
