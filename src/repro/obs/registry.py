"""Dependency-free metrics registry: counters, gauges, histograms, spans.

Design constraints (ISSUE 10):

  * **Near-zero cost when disabled.**  Every hot-path mutator
    (``Counter.inc``, ``Gauge.set``, ``Histogram.observe``) is one
    attribute test + branch when the registry is disabled — the same
    budget as ``NVMArray``'s ``if self.tracer is not None`` pattern.
    Instrumented modules cache the metric object at import time so the
    per-event cost is a bound-method call, never a registry lookup.
  * **No dependencies.**  Stdlib only; ``core``/``serving`` import us,
    never the reverse (the waste monitor in :mod:`repro.obs.waste`
    re-implements the persist-lint diag algorithm for the same reason —
    the unit parity test keeps the two implementations lock-step).
  * **Snapshot is plain data.**  :meth:`Registry.snapshot` returns a
    JSON-serializable dict; the benchmark harness embeds it per round
    and ``tools/dump_metrics.py`` renders it.
  * **Resets are named and checked.**  External counter *sources* (the
    heap's ``n_flush``/``n_fence``/... pair) register read/reset
    callbacks; :meth:`Registry.reset` raises :class:`UnknownMetric` on a
    name nothing registered, so a harness reset can never silently miss
    a heap (the ``benchmarks/run.py`` hazard this replaces).

Spans (:meth:`Registry.span`) always *time* — recovery stats carry their
phase durations whether or not metrics are on — but only *record* (trace
event + accumulated phase row) while the registry is enabled.  Exported
trace events follow the Chrome ``traceEvents`` format (``ph: "X"``,
microsecond ``ts``/``dur``), loadable in ``chrome://tracing`` and
Perfetto.

Counters tolerate racy ``+=`` under the GIL (a lost increment is an
observability blip, not corruption); structural mutation of the registry
itself is lock-protected.
"""

from __future__ import annotations

import threading
import time

__all__ = ["Counter", "Gauge", "Histogram", "Registry", "UnknownMetric"]


class UnknownMetric(KeyError):
    """A reset named a metric nothing registered (or one that cannot be
    reset) — raised instead of silently skipping, so a benchmark round
    can never run with stale counters."""


class Counter:
    """Monotonic event count (reset only via the registry)."""

    __slots__ = ("name", "value", "_reg")

    def __init__(self, name: str, reg: "Registry"):
        self.name = name
        self.value = 0
        self._reg = reg

    def inc(self, n: int = 1) -> None:
        if self._reg.enabled:
            self.value += n

    def reset(self) -> None:
        self.value = 0


class Gauge:
    """Point-in-time value: either explicitly ``set`` or backed by a
    read callback (``fn``) sampled at snapshot time — callback gauges
    cost nothing between snapshots."""

    __slots__ = ("name", "value", "fn", "_reg")

    def __init__(self, name: str, reg: "Registry"):
        self.name = name
        self.value = 0
        self.fn = None
        self._reg = reg

    def set(self, value) -> None:
        if self._reg.enabled:
            self.value = value

    def read(self):
        return self.value if self.fn is None else self.fn()

    def reset(self) -> None:
        self.value = 0


class Histogram:
    """Distribution summary with exact percentiles up to ``cap`` stored
    observations (benchmark rounds stay far below it); beyond the cap
    only count/sum/min/max keep updating and the summary says so."""

    __slots__ = ("name", "count", "total", "vmin", "vmax", "_values",
                 "_cap", "_reg")

    def __init__(self, name: str, reg: "Registry", cap: int = 16384):
        self.name = name
        self._cap = cap
        self._reg = reg
        self.reset()

    def observe(self, value) -> None:
        if not self._reg.enabled:
            return
        self.count += 1
        self.total += value
        if self.vmin is None or value < self.vmin:
            self.vmin = value
        if self.vmax is None or value > self.vmax:
            self.vmax = value
        if len(self._values) < self._cap:
            self._values.append(value)

    def reset(self) -> None:
        self.count = 0
        self.total = 0.0
        self.vmin = None
        self.vmax = None
        self._values = []

    def summary(self) -> dict:
        out = {"count": self.count, "sum": self.total,
               "min": self.vmin, "max": self.vmax,
               "mean": (self.total / self.count) if self.count else None}
        vals = sorted(self._values)
        for q, key in ((0.50, "p50"), (0.90, "p90"), (0.99, "p99")):
            out[key] = (vals[min(len(vals) - 1, int(q * len(vals)))]
                        if vals else None)
        if self.count > len(self._values):
            out["sampled"] = len(self._values)
        return out


class _Span:
    """Context manager timing one named phase.  Always times (callers
    read ``.seconds`` for their own stats); records a Chrome-trace event
    and accumulates into the registry's phase table only while enabled.
    ``add(n)`` attributes an item count to the phase (blocks swept,
    records pruned, ...)."""

    __slots__ = ("name", "args", "seconds", "items", "_reg", "_t0")

    def __init__(self, reg: "Registry", name: str, args: dict):
        self._reg = reg
        self.name = name
        self.args = args
        self.seconds = 0.0
        self.items = 0

    def add(self, n: int = 1) -> None:
        self.items += n

    def __enter__(self) -> "_Span":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.seconds = time.perf_counter() - self._t0
        reg = self._reg
        if reg.enabled:
            reg._record_span(self)


class Registry:
    """Named metrics + phase spans + Chrome-trace buffer.

    ``counter``/``gauge``/``histogram`` are get-or-create (stable
    identity per name, so modules can cache the object at import time).
    """

    TRACE_CAP = 20000

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._lock = threading.Lock()
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._histograms: dict[str, Histogram] = {}
        self._sources: dict[str, tuple] = {}     # name -> (read, reset|None)
        self._phases: dict[str, dict] = {}
        self._trace: list[dict] = []
        self._trace_epoch = time.perf_counter()
        self._trace_dropped = 0

    # ------------------------------------------------------------ lifecycle
    def enable(self) -> None:
        self.enabled = True

    def disable(self) -> None:
        self.enabled = False

    # ------------------------------------------------------ metric creation
    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            with self._lock:
                c = self._counters.setdefault(name, Counter(name, self))
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            with self._lock:
                g = self._gauges.setdefault(name, Gauge(name, self))
        return g

    def gauge_fn(self, name: str, fn) -> Gauge:
        """Bind (or rebind) a read callback to a gauge — last binding
        wins, matching the one-live-owner convention of sources."""
        g = self.gauge(name)
        g.fn = fn
        return g

    def histogram(self, name: str) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            with self._lock:
                h = self._histograms.setdefault(name, Histogram(name, self))
        return h

    def register_source(self, name: str, read, reset=None) -> None:
        """Register an externally-owned counter (e.g. the heap's
        ``n_flush``).  Re-registering a name replaces the previous
        binding: the newest owner (the live heap) wins."""
        with self._lock:
            self._sources[name] = (read, reset)

    # ------------------------------------------------------------ span/phase
    def span(self, name: str, **args) -> _Span:
        return _Span(self, name, args)

    def _record_span(self, span: _Span) -> None:
        row = self._phases.get(span.name)
        if row is None:
            row = self._phases[span.name] = {
                "seconds": 0.0, "items": 0, "calls": 0}
        row["seconds"] += span.seconds
        row["items"] += span.items
        row["calls"] += 1
        if len(self._trace) >= self.TRACE_CAP:
            self._trace_dropped += 1
            return
        ev = {"name": span.name, "ph": "X", "pid": 0,
              "tid": threading.get_ident() & 0xFFFF,
              "ts": round((span._t0 - self._trace_epoch) * 1e6, 3),
              "dur": round(span.seconds * 1e6, 3)}
        if span.args or span.items:
            ev["args"] = dict(span.args, items=span.items) \
                if span.items else dict(span.args)
        self._trace.append(ev)

    # -------------------------------------------------------------- queries
    def snapshot(self) -> dict:
        counters = {n: c.value for n, c in self._counters.items()}
        for name, (read, _reset) in self._sources.items():
            counters[name] = read()
        return {
            "enabled": self.enabled,
            "counters": counters,
            "gauges": {n: g.read() for n, g in self._gauges.items()},
            "histograms": {n: h.summary()
                           for n, h in self._histograms.items() if h.count},
            "phases": {n: dict(row) for n, row in self._phases.items()},
        }

    def chrome_trace(self) -> dict:
        """The span buffer in Chrome ``traceEvents`` format (loadable in
        chrome://tracing / Perfetto)."""
        return {"traceEvents": list(self._trace),
                "displayTimeUnit": "ms",
                "otherData": {"dropped": self._trace_dropped}}

    # --------------------------------------------------------------- resets
    def reset(self, *names: str) -> None:
        """Reset each named metric; unknown or unresettable names raise
        :class:`UnknownMetric` (never silently skipped)."""
        for name in names:
            if name in self._counters:
                self._counters[name].reset()
            elif name in self._histograms:
                self._histograms[name].reset()
            elif name in self._gauges and self._gauges[name].fn is None:
                self._gauges[name].reset()
            elif name in self._sources:
                reset = self._sources[name][1]
                if reset is None:
                    raise UnknownMetric(
                        f"metric source {name!r} has no reset callback")
                reset()
            else:
                raise UnknownMetric(
                    f"no resettable metric named {name!r} is registered")

    def reset_all(self) -> None:
        """Zero every registry-owned metric and clear spans/trace.
        External sources keep their owners' counts — reset those by
        name, so a missing registration is an error, not a skew."""
        for c in self._counters.values():
            c.reset()
        for g in self._gauges.values():
            if g.fn is None:
                g.reset()
        for h in self._histograms.values():
            h.reset()
        self._phases.clear()
        self._trace.clear()
        self._trace_dropped = 0
        self._trace_epoch = time.perf_counter()
