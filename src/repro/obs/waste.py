"""Streaming persist-waste monitor: live redundant-flush / empty-fence
gauges.

``analysis.persist_lint.DurabilityShadow`` computes two perf
diagnostics during test-only trace replay: **redundant flushes** (the
line was already scheduled and nothing on it is newly dirty — a wasted
``clwb``) and **empty fences** (no effective flush since the last fence
— a wasted ``sfence``).  This module promotes them to live metrics: a
:class:`WasteMonitor` plugs into the ``NVMArray.tracer`` slot and runs
the *identical* per-line algorithm incrementally, publishing the counts
as registry gauges, so a benchmark round and a crash-harness replay
report the same waste numbers (asserted by the parity unit test, which
replays one trace through both implementations).

The algorithm is deliberately re-implemented rather than imported:
``repro.obs`` stays dependency-free (``analysis`` imports ``core``;
``core`` imports us), and two independent implementations make the
parity test a real check instead of a tautology.  Semantics mirror the
shadow exactly:

  * a write makes its word *pending* with no flush snapshot;
  * a flush of a line is *effective* iff some pending word on the line
    has no snapshot or was rewritten since its snapshot (real ``clwb``
    captures line contents at flush time); otherwise it is redundant;
  * a fence with no effective flush since the previous fence is empty;
    it then commits snapshots — words whose snapshot equals their
    latest value stop being pending;
  * drain/crash clear all pending state without counting a fence.

``cas`` events are bookkeeping only (the underlying store already
arrived as its own ``write``), and ``note`` markers don't touch the
persist state — both ignored, exactly as ``check_trace`` does.

Cost: a few dict operations per traced memory event, only while a
monitor is attached; ``record`` early-outs on a disabled registry so
the tracer slot can stay occupied at one branch per event.
"""

from __future__ import annotations

CACHELINE_WORDS = 8                  # == core.atomics.CACHELINE_WORDS

_NOFLUSH = object()                  # pending word has no flush snapshot yet

__all__ = ["WasteMonitor", "CACHELINE_WORDS"]


class WasteMonitor:
    """Tracer-protocol object (``record(kind, addr, value, label,
    info)``) maintaining live persist-waste diagnostics."""

    __slots__ = ("writes", "flushes", "fences", "redundant_flushes",
                 "empty_fences", "_pending", "_by_line",
                 "_fence_has_work", "_reg")

    def __init__(self, registry=None, prefix: str = "persist"):
        self.writes = 0
        self.flushes = 0
        self.fences = 0
        self.redundant_flushes = 0
        self.empty_fences = 0
        self._pending: dict[int, list] = {}   # addr -> [latest, snapshot]
        self._by_line: dict[int, set[int]] = {}
        self._fence_has_work = False
        self._reg = registry
        if registry is not None:
            registry.gauge_fn(f"{prefix}.redundant_flushes",
                              lambda: self.redundant_flushes)
            registry.gauge_fn(f"{prefix}.empty_fences",
                              lambda: self.empty_fences)
            registry.gauge_fn(f"{prefix}.writes", lambda: self.writes)
            registry.gauge_fn(f"{prefix}.flushes", lambda: self.flushes)
            registry.gauge_fn(f"{prefix}.fences", lambda: self.fences)

    # ------------------------------------------------------ tracer protocol
    def record(self, kind, addr=None, value=None, label=None,
               info=None) -> None:
        if self._reg is not None and not self._reg.enabled:
            return
        if kind == "write":
            self.writes += 1
            ent = self._pending.get(addr)
            if ent is None:
                self._pending[addr] = [value, _NOFLUSH]
                self._by_line.setdefault(
                    addr // CACHELINE_WORDS, set()).add(addr)
            else:
                ent[0] = value
        elif kind == "flush":
            self.flushes += 1
            effective = False
            for w in self._by_line.get(addr // CACHELINE_WORDS, ()):
                ent = self._pending[w]
                if ent[1] is _NOFLUSH or ent[1] != ent[0]:
                    ent[1] = ent[0]
                    effective = True
            if effective:
                self._fence_has_work = True
            else:
                self.redundant_flushes += 1
        elif kind == "fence":
            self.fences += 1
            if not self._fence_has_work:
                self.empty_fences += 1
            self._fence_has_work = False
            done = []
            for w, ent in self._pending.items():
                if ent[1] is _NOFLUSH:
                    continue
                if ent[1] == ent[0]:
                    done.append(w)
                else:                  # rewritten since the flush snapshot
                    ent[1] = _NOFLUSH
            for w in done:
                del self._pending[w]
                line = self._by_line[w // CACHELINE_WORDS]
                line.discard(w)
                if not line:
                    del self._by_line[w // CACHELINE_WORDS]
        elif kind in ("drain", "crash"):
            self._pending.clear()
            self._by_line.clear()
            self._fence_has_work = False
        # "cas" / "note": no persist-state effect (matches check_trace)

    # -------------------------------------------------------------- queries
    @property
    def diag(self) -> dict:
        """The counts under ``DurabilityShadow.diag``'s key names."""
        return {"writes": self.writes, "flushes": self.flushes,
                "fences": self.fences,
                "redundant_flushes": self.redundant_flushes,
                "empty_fences": self.empty_fences}
