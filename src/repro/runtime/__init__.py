"""Runtime layer: version-agnostic device/mesh/sharding construction.

No module outside this package may touch ``jax.sharding.AxisType``,
``jax.make_mesh``'s ``axis_types=``, or the moving ``shard_map`` entry
point directly — import from here instead.
"""

from .compat import (AXIS_TYPE_AUTO, axis_size, axis_types_kwargs,
                     make_host_mesh, make_mesh, named_sharding, shard_map)

__all__ = [
    "AXIS_TYPE_AUTO",
    "axis_size",
    "axis_types_kwargs",
    "make_host_mesh",
    "make_mesh",
    "named_sharding",
    "shard_map",
]
