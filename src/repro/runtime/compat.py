"""Version-agnostic jax device/mesh/sharding layer.

Every other module builds meshes and shard_maps through *this* file, so
one place absorbs the churn in jax's public surface instead of every
call site pinning a version:

  * ``jax.sharding.AxisType`` + the ``axis_types=`` kwarg of
    ``jax.make_mesh`` exist only in newer jax; 0.4.x meshes have no axis
    types at all (everything behaves like ``Auto``).
  * ``jax.shard_map`` (with ``check_vma=``) is the new spelling of
    ``jax.experimental.shard_map.shard_map`` (with ``check_rep=``).
  * very old jax lacks ``jax.make_mesh`` entirely; we fall back to
    reshaping ``jax.devices()`` into a ``jax.sharding.Mesh`` by hand.

All detection is import-time ``hasattr``/try-import — no version string
parsing, so prerelease/vendored builds resolve to whatever they actually
provide.  The application-facing API is deliberately tiny (the Puddles
argument: recovery/runtime layers should be application independent):

  ``make_mesh``, ``make_host_mesh``, ``axis_types_kwargs``,
  ``shard_map``, ``named_sharding``, ``AXIS_TYPE_AUTO``.
"""

from __future__ import annotations

import inspect
import math
from typing import Any, Sequence

import numpy as np

import jax
from jax.sharding import Mesh, NamedSharding

# --------------------------------------------------------------- detection
# jax.sharding.AxisType arrives via a module __getattr__ that *raises* on
# old versions, so getattr with a default is the whole feature probe.
AXIS_TYPE_AUTO: Any = None
_axis_type_cls = getattr(jax.sharding, "AxisType", None)
if _axis_type_cls is not None:
    AXIS_TYPE_AUTO = _axis_type_cls.Auto

_HAS_AXIS_TYPES = AXIS_TYPE_AUTO is not None

_make_mesh = getattr(jax, "make_mesh", None)
_MAKE_MESH_TAKES_AXIS_TYPES = (
    _make_mesh is not None
    and "axis_types" in inspect.signature(_make_mesh).parameters)

try:                                        # new spelling (jax >= 0.6)
    _shard_map = jax.shard_map
    _SHARD_MAP_REP_KWARG = "check_vma"
except AttributeError:                      # 0.4.x/0.5.x spelling
    from jax.experimental.shard_map import shard_map as _shard_map
    _SHARD_MAP_REP_KWARG = "check_rep"


# ------------------------------------------------------------------- mesh
def axis_types_kwargs(n_axes: int) -> dict:
    """``{"axis_types": (Auto,) * n}`` where supported, else ``{}``.

    On jax 0.4.x meshes carry no axis types and the auto-SPMD partitioner
    treats every axis as ``Auto`` — dropping the kwarg is semantically
    the identity, not an approximation.
    """
    if _HAS_AXIS_TYPES and _MAKE_MESH_TAKES_AXIS_TYPES:
        return {"axis_types": (AXIS_TYPE_AUTO,) * n_axes}
    return {}


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str],
              *, devices=None) -> Mesh:
    """Build a ``Mesh`` with Auto axis types on any jax version."""
    axis_shapes = tuple(axis_shapes)
    axis_names = tuple(axis_names)
    if _make_mesh is not None:
        kw = axis_types_kwargs(len(axis_names))
        if devices is not None:
            kw["devices"] = devices
        return _make_mesh(axis_shapes, axis_names, **kw)
    # pre-make_mesh fallback: reshape the flat device list ourselves
    devs = list(jax.devices()) if devices is None else list(devices)
    need = math.prod(axis_shapes)
    if len(devs) < need:
        raise ValueError(
            f"mesh {axis_shapes} needs {need} devices, have {len(devs)}")
    grid = np.array(devs[:need]).reshape(axis_shapes)
    return Mesh(grid, axis_names)


def make_host_mesh(data: int = 1, model: int = 1) -> Mesh:
    """The (data, model) mesh every CPU test/example uses."""
    return make_mesh((data, model), ("data", "model"))


# -------------------------------------------------------------- shard_map
if hasattr(jax.lax, "axis_size"):
    axis_size = jax.lax.axis_size
else:
    def axis_size(axis_name):
        """Static mapped-axis size; ``psum`` of a scalar literal is
        constant-folded to a Python int, the pre-``lax.axis_size`` idiom."""
        return jax.lax.psum(1, axis_name)


def shard_map(f, *, mesh, in_specs, out_specs, check_replication: bool = False):
    """Portable ``shard_map``: one boolean replication-check knob mapped to
    whichever of ``check_vma``/``check_rep`` this jax spells it as."""
    kw = {_SHARD_MAP_REP_KWARG: check_replication}
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **kw)


# ------------------------------------------------------------- shardings
def named_sharding(mesh: Mesh, spec) -> NamedSharding:
    """``NamedSharding`` constructor (single choke point should the class
    move again, as ``MeshPspecSharding`` → ``NamedSharding`` once did)."""
    return NamedSharding(mesh, spec)
