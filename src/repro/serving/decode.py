"""serve_step (decode): shard_map assembly over (pod, data, model).

One decode step = embed → scan over pattern units (each unit applies its
mixers/ffns via ``tp_layers``) → final norm → vocab-parallel logits →
greedy sample.  Batch and KV pages are sharded over the data axes
(shard-local page ids — one arena per data shard); ``model`` carries
Megatron-style TP plus slot-sharded paged attention.

The decode state is a pytree:

  {"pos": i32[B], "block_table": i32[B, P], "kv_pos": i32[B, P, page],
   "units": {"l<i>": mixer-state stacked over units}, "tail": {...}}
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from ..layers.common import apply_norm
from ..models.config import ModelConfig
from ..runtime import axis_size, shard_map
from . import tp_layers as tpl

MODEL_AXIS = "model"


def data_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a != MODEL_AXIS)


# ---------------------------------------------------------------------------
# sharding specs
# ---------------------------------------------------------------------------
def serve_param_specs(cfg: ModelConfig, params_shape, tp: int = 16) -> dict:
    """PartitionSpecs for the serving weight layout (model-axis TP only).

    Vocab tables whose row count does not divide the TP axis are
    replicated (internvl2: 92553, granite-moe: 49155, hubert: 504).
    """
    M = MODEL_AXIS
    vocab_ok = cfg.vocab_size % tp == 0

    def spec_for(path: str, ndim: int, lead: int):
        pre = (None,) * lead

        def p(*s):
            return P(*(pre + s + (None,) * (ndim - lead - len(s))))

        last = path.split("/")[-1]
        if "attn" in path:
            if last in ("wq", "wk", "wv", "wo"):
                return p(M, None)
            return p()                        # biases replicated
        if "ffn" in path:
            if last == "router":
                return p()
            if last in ("wi", "wg"):
                return p(M) if ndim - lead == 3 else p(None, M)
            if last == "wo":
                return p(M) if ndim - lead == 3 else p(M, None)
        if "ssd" in path:
            if last in ("in_z", "in_x", "in_dt", "conv_x_w"):
                return p(None, M)
            if last in ("conv_x_b", "A_log", "dt_bias", "D", "norm_w"):
                return p(M)
            if last == "out_proj":
                return p(M, None)
            return p()                        # in_bc / conv_bc_* replicated
        if "rglru" in path:
            if last in ("in_x", "in_g", "conv_w"):
                return p(None, M)
            if last in ("conv_b", "lam"):
                return p(M)
            if last in ("wa", "wx", "out"):
                return p(M, None)
            return p()
        if last in ("embed", "unembed"):
            return P(M, None) if vocab_ok else P(None, None)
        return p()                            # norms etc. replicated

    def walk(tree, path="", lead=0):
        if isinstance(tree, dict):
            out = {}
            for k, v in tree.items():
                l2 = lead + 1 if k == "units" and path == "" else lead
                out[k] = walk(v, f"{path}/{k}", l2)
            return out
        return spec_for(path, len(tree.shape), lead)

    return walk(params_shape)


def mixer_state_specs(cfg: ModelConfig, mesh, stacked: bool,
                      batch_sharded: bool):
    """Specs for one pattern position's state (optionally unit-stacked).

    With ``batch_sharded`` the batch dim is split over the data axes and
    each data shard keeps its own sequences' pages.  Otherwise (batch <
    dp, e.g. long_500k) the *pages* are split over the data axes —
    sequence parallelism — and recurrent states are replicated over dp.
    """
    dp = data_axes(mesh)
    pre = (None,) if stacked else ()
    M = MODEL_AXIS
    bdp = dp if batch_sharded else None

    def mk(*s):
        return P(*(pre + s))

    out = {}
    for i, (mixer, _) in enumerate(cfg.pattern):
        key = f"l{i}"
        if mixer in ("attn", "local_attn"):
            out[key] = {"k": mk(dp, M, None, None),
                        "v": mk(dp, M, None, None)}
            if cfg.kv_dtype == "int8":
                out[key]["ks"] = mk(dp, M, None)
                out[key]["vs"] = mk(dp, M, None)
        elif mixer == "mamba2":
            out[key] = {"h": mk(bdp, M, None, None),
                        "conv_x": mk(bdp, None, M),
                        "conv_bc": mk(bdp, None, None)}
        elif mixer == "rglru":
            out[key] = {"h": mk(bdp, M), "conv": mk(bdp, None, M)}
    return out


def dstate_specs(cfg: ModelConfig, mesh, batch_sharded: bool = True):
    dp = data_axes(mesh)
    if batch_sharded:
        pos_s, bt_s, kvp_s = P(dp), P(dp, None), P(dp, None, MODEL_AXIS)
    else:  # sequence parallelism: pages over dp, batch replicated
        pos_s, bt_s, kvp_s = P(), P(None, dp), P(None, dp, MODEL_AXIS)
    specs = {
        "pos": pos_s,
        "block_table": bt_s,
        "kv_pos": kvp_s,
        "units": mixer_state_specs(cfg, mesh, True, batch_sharded),
    }
    tail = {}
    for i, (mixer, _) in enumerate(cfg.tail_specs):
        sub = mixer_state_specs(cfg, mesh, False, batch_sharded)
        if f"l{i}" in sub:
            tail[f"t{i}"] = sub[f"l{i}"]
    specs["tail"] = tail
    return specs


# ---------------------------------------------------------------------------
# decode state construction
# ---------------------------------------------------------------------------
def make_dstate(cfg: ModelConfig, *, batch: int, max_seq: int,
                pages_per_shard: int | None = None, dp_shards: int = 1,
                dtype=None):
    """Zero-initialized decode state (host-side; engine fills block tables)."""
    from ..layers import rglru, ssd
    dtype = dtype or cfg.dtype
    page = cfg.page_size
    if cfg.attn_layers == 0:
        Pn = dp_shards                    # attention-free: vestigial table
    else:
        Pn = max(1, max_seq // page)
        if cfg.window:                    # ring buffer of window pages
            Pn = min(Pn, (cfg.window + page - 1) // page + 1)
        Pn = -(-Pn // dp_shards) * dp_shards   # divisible for seq-parallel
    pages = pages_per_shard or max(batch // dp_shards, 1) * (Pn // dp_shards
                                   if batch < dp_shards else Pn) + 1
    pages_g = pages * dp_shards

    def attn_state(n_units):
        K, dh = cfg.num_kv_heads, cfg.head_dim
        shape = (pages_g, page, K, dh)
        sshape = (pages_g, page, K)
        if n_units:
            shape = (n_units,) + shape
            sshape = (n_units,) + sshape
        if cfg.kv_dtype == "int8":
            return {"k": jnp.zeros(shape, jnp.int8),
                    "v": jnp.zeros(shape, jnp.int8),
                    "ks": jnp.zeros(sshape, jnp.float32),
                    "vs": jnp.zeros(sshape, jnp.float32)}
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}

    units, tail = {}, {}
    U = cfg.full_units
    for i, (mixer, _) in enumerate(cfg.pattern):
        if mixer in ("attn", "local_attn"):
            units[f"l{i}"] = attn_state(U)
        elif mixer == "mamba2":
            s = ssd.mamba2_init_state(cfg, batch)
            units[f"l{i}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (U,) + a.shape), s)
        elif mixer == "rglru":
            s = rglru.rglru_init_state(cfg, batch)
            units[f"l{i}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (U,) + a.shape), s)
    for i, (mixer, _) in enumerate(cfg.tail_specs):
        if mixer in ("attn", "local_attn"):
            tail[f"t{i}"] = attn_state(0)
        elif mixer == "mamba2":
            tail[f"t{i}"] = ssd.mamba2_init_state(cfg, batch)
        elif mixer == "rglru":
            tail[f"t{i}"] = rglru.rglru_init_state(cfg, batch)
    return {
        "pos": jnp.zeros((batch,), jnp.int32),
        "block_table": jnp.full((batch, Pn), -1, jnp.int32),
        "kv_pos": jnp.full((batch, Pn, page), -1, jnp.int32),
        "units": units,
        "tail": tail,
    }


# ---------------------------------------------------------------------------
# the step itself
# ---------------------------------------------------------------------------
def _apply_layer_tp(cfg, spec, p, x, pos, block_table, kv_pos, state,
                    seq_dp_axes=()):
    mixer, ffn = spec
    M = MODEL_AXIS
    h = apply_norm(cfg.norm, p["norm1"], x)
    new_state = state
    if mixer in ("attn", "local_attn"):
        win = cfg.window if mixer == "local_attn" else 0
        scales = ((state["ks"], state["vs"])
                  if cfg.kv_dtype == "int8" else None)
        y, ak, av, kv_pos2, nsc = tpl.attn_decode_tp(
            cfg, p["attn"], h, pos, state["k"], state["v"], block_table,
            kv_pos, window=win, axis=M, seq_dp_axes=seq_dp_axes,
            scales=scales)
        new_state = {"k": ak, "v": av}
        if nsc is not None:
            new_state["ks"], new_state["vs"] = nsc
    elif mixer == "mamba2":
        y, new_state = tpl.mamba2_decode_tp(cfg, p["ssd"], h, state, M)
    elif mixer == "rglru":
        y, new_state = tpl.rglru_decode_tp(cfg, p["rglru"], h, state, M)
    x = x + y
    if ffn != "none":
        h = apply_norm(cfg.norm, p["norm2"], x)
        if ffn == "moe":
            x = x + tpl.moe_decode_tp(cfg, p["ffn"], h, M)
        else:
            x = x + tpl.mlp_decode_tp(cfg, p["ffn"], h, M)
    return x, new_state


def _decode_local(cfg: ModelConfig, seq_dp_axes, params, dstate, tokens,
                  return_logits: bool = False, vocab_sharded: bool = True):
    """Runs per-device inside shard_map."""
    M = MODEL_AXIS
    pos = dstate["pos"]
    block_table = dstate["block_table"]
    kv_pos = dstate["kv_pos"]
    x = tpl.embed_tp(params["embed"], tokens, M, sharded=vocab_sharded)

    def body(x, inp):
        unit_p, unit_s = inp
        new_s = {}
        for i, spec in enumerate(cfg.pattern):
            st = unit_s.get(f"l{i}")
            x, ns = _apply_layer_tp(cfg, spec, unit_p[f"l{i}"], x, pos,
                                    block_table, kv_pos, st, seq_dp_axes)
            if ns is not None:
                new_s[f"l{i}"] = ns
        return x, new_s

    x, new_units = lax.scan(body, x, (params["units"], dstate["units"]))
    new_tail = {}
    for i, spec in enumerate(cfg.tail_specs):
        st = dstate["tail"].get(f"t{i}")
        x, ns = _apply_layer_tp(cfg, spec, params["tail"][f"t{i}"], x, pos,
                                block_table, kv_pos, st, seq_dp_axes)
        if ns is not None:
            new_tail[f"t{i}"] = ns
    x = apply_norm(cfg.norm, params["final_norm"], x)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits_loc = tpl.logits_tp(table, x, M)
    next_tok = tpl.greedy_sample_tp(logits_loc, M, sharded=vocab_sharded)

    # one new token is now resident at position pos for every sequence:
    # advance position; mark its slot in kv_pos (idempotent w.r.t. layers)
    page_loc = kv_pos.shape[-1]
    page = page_loc * axis_size(M)
    P_loc = kv_pos.shape[1]
    slot = pos % page
    mine = (slot // page_loc) == lax.axis_index(M)
    gpage = pos // page
    if seq_dp_axes:
        mine = mine & ((gpage // P_loc) == tpl.dp_linear_index(seq_dp_axes))
        lpage = gpage % P_loc
    else:
        lpage = gpage
    b_ix = jnp.arange(pos.shape[0])
    lslot = jnp.where(mine, slot % page_loc, 0)
    kv_pos = kv_pos.at[b_ix, lpage, lslot].set(
        jnp.where(mine, pos, kv_pos[b_ix, lpage, lslot]))
    out_state = dict(dstate, pos=pos + 1, kv_pos=kv_pos,
                     units=new_units, tail=new_tail)
    if return_logits:
        full = (lax.all_gather(logits_loc, MODEL_AXIS, axis=1, tiled=True)
                if vocab_sharded else logits_loc)
        return out_state, next_tok, full
    return out_state, next_tok


def make_decode_step(cfg: ModelConfig, mesh, params_shape, *,
                     batch_sharded: bool = True, return_logits: bool = False):
    """Build the jitted serve_step: (params, dstate, tokens) → (dstate', tok).

    ``batch_sharded=False`` switches to sequence-parallel mode for
    global_batch < #data-shards (the long_500k shape): pages are spread
    over the data axes and the attention merge spans (data + model).
    """
    dp = data_axes(mesh)
    tp = mesh.shape[MODEL_AXIS]
    vocab_sharded = cfg.vocab_size % tp == 0
    pspecs = serve_param_specs(cfg, params_shape, tp)
    sspecs = dstate_specs(cfg, mesh, batch_sharded)
    tok_spec = P(dp) if batch_sharded else P()
    seq_dp_axes = () if batch_sharded else dp

    out_specs = (sspecs, tok_spec)
    if return_logits:
        out_specs = out_specs + ((P(dp, None) if batch_sharded
                                  else P(None, None)),)
    fn = shard_map(
        functools.partial(_decode_local, cfg, seq_dp_axes,
                          return_logits=return_logits,
                          vocab_sharded=vocab_sharded),
        mesh=mesh,
        in_specs=(pspecs, sspecs, tok_spec),
        out_specs=out_specs,
    )
    return jax.jit(fn, donate_argnums=(1,)), pspecs, sspecs
