"""Continuous-batching serving engine over the Ralloc paged arena.

The engine owns the *mechanism*:
  * an ``AllocState`` whose blocks are KV pages (1 block = 1 page, so the
    position-independent offsets the allocator returns *are* page ids);
  * the decode step built by ``serving.decode`` (shard_map TP);
  * per-lane transient state (``serving.lane_state``) and the shared
    prefix cache (``serving.prefix_cache``).

Policy lives in ``serving.scheduler``: admission with a bounded wait
queue, arrivals/finishes interleaved with batched decode, and the
group-commit cadence for the publish queue below.

Page allocation happens lazily: a lane that crosses a page boundary gets
a fresh page from the allocator (vectorized ``alloc`` over all lanes —
the rank-indexed cache makes the common step allocation-free).  Evicted
sessions free their pages in one vectorized ``free``.

Group-commit publish: span-path publications split into a transient half
(``queue_publish`` — cache entry + prefix lease, effective immediately)
and a durable half parked in ``_publish_queue``; ``flush_publishes``
lands N queued records with ONE vectorized block allocation, one chained
``PrefixStore.append_batch`` and ONE root swing — the device mirror of
``core.prefix_index.publish_batch``'s single-fence-pair group commit.

Recoverability (paper §4.5 transplanted to inference): the persistent
fields of the allocator plus each session's block-table row (the "page
table", reachable from the session root) survive a crash; ``recover()``
rebuilds every transient allocator structure with the vectorized
mark–sweep and the engine resumes mid-generation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .. import obs
from ..core import jax_alloc as ja
from ..core import jax_recovery as jr
from ..core.prefix_index import hash_tokens
from ..core.prefix_trie import fingerprint, page_hashes
from ..models.config import ModelConfig
from . import decode as dec
from .lane_state import LaneStates, Session, reset_lane
from .prefix_store import PrefixStore
from .prefix_trie_cache import CacheNode, PrefixTrieCache
from .scheduler import EngineBusy, PendingPublish

__all__ = ["ServingEngine", "Session", "EngineBusy", "PAGE_CLS"]

PAGE_CLS = 0

# Engine metrics (cached at import; see repro.obs conventions).
# ``device.*`` counts invocations of the jit-compiled allocator wrappers
# (the device-side fast path is inside the trace and unobservable from
# the host — the host FreeRunIndex carries the per-bucket placement
# metrics); ``engine.publish_*`` tracks the group-commit queue.
_OBS_DEV_ALLOC = obs.counter("device.alloc_calls")
_OBS_DEV_ALLOC_LARGE = obs.counter("device.alloc_large_calls")
_OBS_DEV_TRIM = obs.counter("device.trim_calls")
_OBS_SPAN_RESERVE_FAIL = obs.counter("device.span_reserve_failed")
_OBS_PUB_QUEUED = obs.counter("engine.publish_queued")
_OBS_PUB_FLUSHES = obs.counter("engine.publish_flushes")
_OBS_PUB_DEPTH = obs.gauge("engine.publish_queue_depth")
_OBS_PUB_BATCH = obs.histogram("engine.publish_batch_size")


class ServingEngine:
    def __init__(self, cfg: ModelConfig, mesh, params, *, lanes: int = 8,
                 max_seq: int = 512, pages_per_sb: int = 16,
                 prefix_buckets: int = 4):
        self.cfg = cfg
        self.mesh = mesh
        self.params = params
        self.lanes = lanes
        self.max_seq = max_seq
        # arena sizing: a whole number of superblocks per lane, so that a
        # decode-ahead span (max_seq pages rounded UP to superblocks by
        # alloc_large) always fits for every lane at once — per-page slack
        # alone would under-provision the superblock rounding
        per_lane_sbs = -(-(max_seq // cfg.page_size + 2) // pages_per_sb)
        num_sbs = lanes * per_lane_sbs + 1
        self.acfg = ja.ArenaConfig(num_sbs=num_sbs, sb_words=pages_per_sb,
                                   class_words=(1,),
                                   cache_cap=max(64, 2 * lanes))
        # root slots: one per lane (page tables) + one per hash bucket of
        # the durable prefix index's record chains (serving.prefix_store) —
        # bucket b's chain head mirrors into roots[lanes + b]
        self._index_root = lanes
        self.prefix_buckets = prefix_buckets
        self.astate = ja.init_state(self.acfg,
                                    max_roots=lanes + prefix_buckets)
        self._alloc = jax.jit(functools.partial(ja.alloc, cfg=self.acfg,
                                                cls=PAGE_CLS))
        self._free = jax.jit(functools.partial(ja.free, cfg=self.acfg,
                                               cls=PAGE_CLS))
        self._alloc_large = jax.jit(functools.partial(ja.alloc_large,
                                                      cfg=self.acfg))
        self._free_large = jax.jit(functools.partial(ja.free_large,
                                                     cfg=self.acfg))
        self._acquire_span = jax.jit(functools.partial(ja.acquire_span,
                                                       cfg=self.acfg))
        self._trim_large = jax.jit(functools.partial(ja.trim_large,
                                                     cfg=self.acfg))
        self.lane_states = LaneStates(lanes)
        pshape = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params)
        self.step_fn, _, _ = dec.make_decode_step(cfg, mesh, pshape)
        self.dstate = dec.make_dstate(cfg, batch=lanes, max_seq=max_seq,
                                      pages_per_shard=int(num_sbs
                                                          * pages_per_sb) + 1)
        # prefix sharing (RadixAttention-style) — the trie cache keeps
        # the flat exact-match dict API (entries / tokens / page_refs /
        # lookup) and adds longest-prefix-match over published prompts:
        # a request matching k pages of a longer prompt leases only
        # those k pages' superblocks (serving.prefix_trie_cache)
        self.prefix_cache = PrefixTrieCache(page=cfg.page_size)
        # durable prefix index: span-path entries additionally own one
        # record block reachable from roots[_index_root], which is what
        # lets crash_and_recover re-publish them instead of re-prefilling
        self.prefix_store = PrefixStore(jr.num_slots(self.acfg),
                                        n_buckets=prefix_buckets)
        # group-commit queue: transiently-published span entries whose
        # durable record append waits for the next flush_publishes
        self._publish_queue: list[PendingPublish] = []
        self.publish_capacity = max(4, lanes)    # records per group commit

    def _mirror_index_roots(self) -> None:
        """Mirror every prefix-chain bucket head into its root slot
        (bucket b -> roots[lanes + b]); pure state update, no fence."""
        for b, head in enumerate(self.prefix_store.heads):
            self.astate = ja.set_root(self.astate, self._index_root + b,
                                      jnp.int32(head))

    # ------------------------------------------- component-state delegation
    @property
    def sessions(self) -> dict[int, Session]:
        return self.lane_states.sessions

    @property
    def free_lanes(self) -> list[int]:
        return self.lane_states.free_lanes

    @property
    def large_spans(self) -> dict[int, tuple[int, int]]:
        return self.lane_states.large_spans

    @property
    def shared_spans(self) -> dict[int, tuple[int, int, int]]:
        return self.lane_states.shared_spans

    @property
    def cur_tokens(self) -> np.ndarray:
        return self.lane_states.cur_tokens

    @property
    def _prefix_cache(self) -> dict[int, tuple]:
        return self.prefix_cache.entries

    @property
    def _prefix_tokens(self) -> dict[int, tuple]:
        return self.prefix_cache.tokens

    @property
    def page_refs(self) -> dict[int, int]:
        return self.prefix_cache.page_refs

    @page_refs.setter
    def page_refs(self, refs: dict[int, int]) -> None:
        self.prefix_cache.page_refs = refs

    # ------------------------------------------------------------- requests
    def add_request(self, prompt: list[int],
                    share_prefix: bool = False) -> int:
        lane = self.lane_states.acquire()
        if lane is None:
            raise EngineBusy(
                f"all {self.lanes} lanes are busy — queue admission through "
                f"serving.scheduler.Scheduler.submit")
        self.sessions[lane] = Session(lane=lane, tokens=list(prompt))
        # reset lane state (pos=0) and feed the prompt token by token
        self.dstate = reset_lane(self.dstate, lane)
        self.cur_tokens[lane] = prompt[0]
        # oversized prompt: its page table will not fit the per-step lazy
        # path gracefully — reserve one contiguous multi-superblock span up
        # front (device large-object path) sized *decode-ahead*: the span
        # covers every page the sequence can ever touch (max_seq, not just
        # the prompt), so generation never needs a mid-decode lazy page or
        # a span migration.  Clamped to the page-table width: generation
        # stops at max_seq, so pages past it would never be touched.
        # A shared-prefix *hit* on a published span skips the reservation
        # entirely: the lane acquires the published span instead.
        table_width = int(self.dstate["block_table"].shape[1])
        n_prompt_pages = min(-(-len(prompt) // self.cfg.page_size),
                             table_width)
        hit = self.prefix_cache.lookup(prompt) if share_prefix else None
        # longest-prefix match when the exact entry misses: a request
        # matching k whole pages of a published prompt leases only those
        # k pages' superblocks and decodes its suffix on its own lazily-
        # allocated pages.  A mid-edge match first materializes the
        # boundary as a trie split (durable when the node has a record).
        pnode, pk = None, 0
        if share_prefix and hit is None and self.cfg.attn_layers > 0:
            pnode, pk = self.prefix_cache.match_partial(prompt)
            if pnode is not None and pk * self.cfg.page_size >= len(prompt):
                pnode, pk = None, 0    # no suffix left: only the exact
                #                        entry may serve the whole prompt
            elif pnode is not None and pk < pnode.end_page:
                m = self._split_node(pnode, pk)
                if m is None:          # no record blocks: fall back to
                    #                    the deepest existing boundary
                    pnode, pk = self.prefix_cache.deepest_boundary(pnode, pk)
                else:
                    pnode = m
            if pnode is None:
                pk = 0
        if (self.cfg.attn_layers > 0 and hit is None and pnode is None
                and n_prompt_pages > self.acfg.sb_words):
            n_ahead = min(-(-self.max_seq // self.cfg.page_size), table_width)
            try:
                self._reserve_span(lane, max(n_prompt_pages, n_ahead))
            except MemoryError:
                # back out the admission completely: session gone, lane
                # decode state neutral, lane in the pool exactly once —
                # the lane must be indistinguishable from never-admitted
                # (the old path handed the lane back with this request's
                # pos/block-table/cur-token still written into it)
                del self.sessions[lane]
                self.dstate = reset_lane(self.dstate, lane)
                self.cur_tokens[lane] = 0
                self.lane_states.release(lane)
                raise
        if hit is not None:
            if hit[0] == "span":
                # lease the published span's *prefix*: the prompt's KV
                # pages are exactly the prefix superblocks this lane will
                # read — no copy, no fresh reservation, and no claim on
                # the publisher's decode-ahead tail (which frees for
                # reuse the moment its own leases drop)
                _, off, n_span, full, plen, kvp, next_tok, lease_sbs = hit
                self.astate, _ = self._acquire_span(
                    state=self.astate, off=jnp.int32(off),
                    n_sbs=jnp.int32(lease_sbs))
                self.shared_spans[lane] = (off, full, lease_sbs)
                pages = off + np.arange(full, dtype=np.int32)
            else:
                _, pages, plen, kvp, next_tok = hit
                pages = np.asarray(pages, np.int32)
                for p in pages.tolist():
                    self.prefix_cache.add_page_ref(p)
            bt = np.asarray(self.dstate["block_table"]).copy()
            bt[lane, :len(pages)] = pages
            self.dstate["block_table"] = jnp.asarray(bt)
            kv = np.asarray(self.dstate["kv_pos"]).copy()
            kv[lane, :len(pages)] = kvp
            self.dstate["kv_pos"] = jnp.asarray(kv)
            self.dstate["pos"] = self.dstate["pos"].at[lane].set(plen)
            # the model's continuation at the prompt boundary was
            # sampled by the publisher — it is part of the prefix
            self.sessions[lane].tokens = list(prompt) + [next_tok]
            self.cur_tokens[lane] = next_tok
        elif pnode is not None and pk > 0:
            # partial hit at a trie-node boundary: the node's span backs
            # the whole prefix [0, pk) at identity offsets, so ONE
            # acquire_span of the node's lease (= exactly the matched
            # pages' superblocks) makes this an ordinary shared-span
            # lane; the un-matched prompt suffix replays teacher-forced
            # on the lane's own lazily-allocated pages
            off, lease_sbs = pnode.span, pnode.lease_sbs
            self.astate, _ = self._acquire_span(
                state=self.astate, off=jnp.int32(off),
                n_sbs=jnp.int32(lease_sbs))
            self.shared_spans[lane] = (off, pk, lease_sbs)
            self.lane_states.partial_hits[lane] = pk
            pages = off + np.arange(pk, dtype=np.int32)
            bt = np.asarray(self.dstate["block_table"]).copy()
            bt[lane, :pk] = pages
            self.dstate["block_table"] = jnp.asarray(bt)
            kv = np.asarray(self.dstate["kv_pos"]).copy()
            page = self.cfg.page_size
            kv[lane, :pk] = np.arange(pk * page,
                                      dtype=np.int32).reshape(pk, page)
            self.dstate["kv_pos"] = jnp.asarray(kv)
            self.dstate["pos"] = self.dstate["pos"].at[lane].set(pk * page)
            self.cur_tokens[lane] = prompt[pk * page]
        # the allocator root for this lane points at its page table
        self.astate = ja.set_root(self.astate, lane, jnp.int32(lane))
        return lane

    def _reserve_span(self, lane: int, n_pages: int) -> None:
        """Back ``n_pages`` page-table slots of ``lane`` with one
        contiguous large-object span (page ids = span offsets).  Raises
        ``MemoryError`` with the lane untouched; ``add_request`` owns
        backing the admission out."""
        _OBS_DEV_ALLOC_LARGE.inc()
        self.astate, off = self._alloc_large(state=self.astate,
                                             nwords=jnp.int32(n_pages))
        off = int(off)
        if off < 0:
            _OBS_SPAN_RESERVE_FAIL.inc()
            raise MemoryError(
                f"KV arena cannot reserve a contiguous {n_pages}-page span")
        self.large_spans[lane] = (off, n_pages)
        bt = np.asarray(self.dstate["block_table"]).copy()
        bt[lane, :n_pages] = off + np.arange(n_pages, dtype=np.int32)
        self.dstate["block_table"] = jnp.asarray(bt)

    def _alloc_blocks(self, n: int) -> list[int]:
        """``n`` arena blocks (prefix-index record slots) in ONE
        vectorized alloc; -1 entries when the arena is full.

        Record slots occupy dedicated ranks *past* the lane range — the
        old single-record path requested rank 0, lane 0's slot in the
        rank-indexed cache, so fusing a record grab into a step's lane
        allocation could pop one cache entry for both a KV page and a
        record.  The tail ranks can never collide with any lane's, and
        the fixed ``lanes + publish_capacity`` width keeps this a single
        jit trace across batch sizes."""
        assert 0 < n <= self.publish_capacity
        need = np.zeros((self.lanes + self.publish_capacity,), bool)
        need[self.lanes:self.lanes + n] = True
        _OBS_DEV_ALLOC.inc()
        self.astate, offs = self._alloc(state=self.astate,
                                        need=jnp.asarray(need))
        return [int(o) for o in
                np.asarray(offs)[self.lanes:self.lanes + n]]

    def _split_node(self, node: CacheNode, k: int) -> CacheNode | None:
        """Materialize page boundary ``k`` inside in-process trie node
        ``node`` (X ``[s, e)`` → M ``[s, k)`` + X' ``[k, e)``, same
        span).  Returns M, or None when the arena cannot place the two
        record blocks a durable split needs (nothing changes then — the
        caller serves the deepest existing boundary instead).

        Device mirror of ``core.prefix_trie.PrefixTrie.split``, ordering
        included: both new records land (``PrefixStore.split`` splices
        them into X's chain position), children re-parent, and only then
        does the old record's lease drop and its block free.  Leases
        stay record ⇔ lease 1:1: M's new lease and X''s replacement are
        acquired up front, X's old lease releases at the end.  A node
        still parked in the publish queue has no record yet: its queue
        entry is replaced by two pending publishes and the split stays
        transient until the next flush."""
        if node.page_keys is None or node.tokens is None:
            return None                # recovered node: no page keys
        m_rec = x_rec = -1
        if node.rec_off >= 0:
            m_rec, x_rec = self._alloc_blocks(2)
            if m_rec < 0 or x_rec < 0:
                live = np.full((self.acfg.cache_cap,), -1, np.int32)
                live[:2] = (m_rec, x_rec)
                if (live >= 0).any():
                    self.astate = self._free(state=self.astate,
                                             offs=jnp.asarray(live),
                                             mask=jnp.asarray(live >= 0))
                return None
        m_lease = -(-k // self.acfg.sb_words)
        old_key, old_lease = node.key, node.lease_sbs
        old_rec = node.rec_off
        self.astate, _ = self._acquire_span(
            state=self.astate, off=jnp.int32(node.span),
            n_sbs=jnp.int32(m_lease))
        self.astate, _ = self._acquire_span(
            state=self.astate, off=jnp.int32(node.span),
            n_sbs=jnp.int32(node.lease_sbs))
        old_entry = self._prefix_cache.get(old_key)
        span_pages = old_entry[2] if old_entry is not None else node.end_page
        m = self.prefix_cache.split_transient(node, k)
        m.lease_sbs = m_lease
        m.rec_off = m_rec
        page = self.cfg.page_size
        kvp = np.arange(k * page, dtype=np.int32).reshape(k, page)
        self.prefix_cache.insert(
            m.key,
            ("span", node.span, span_pages, k, k * page, kvp, m.next_tok,
             m_lease),
            tokens=m.tokens)
        if old_rec >= 0:
            par = (self.prefix_cache.nodes[m.parent].rec_off
                   if m.parent >= 0 and m.parent in self.prefix_cache.nodes
                   else -1)
            self.prefix_store.split(
                old_rec,
                dict(rec_off=m_rec, key=m.key, span=node.span, n_pages=k,
                     span_pages=span_pages, next_tok=m.next_tok,
                     lease_sbs=m_lease, parent=par, start_page=m.start_page,
                     fprint=m.fprint),
                dict(rec_off=x_rec, key=node.key, span=node.span,
                     n_pages=node.end_page, span_pages=span_pages,
                     next_tok=node.next_tok, lease_sbs=node.lease_sbs,
                     parent=m_rec, start_page=k, fprint=node.fprint))
            node.rec_off = x_rec
            for ck in node.children:
                child = self.prefix_cache.nodes.get(ck)
                if child is not None and child.rec_off >= 0:
                    self.prefix_store.reparent(child.rec_off, x_rec)
            self._mirror_index_roots()
        else:
            # queued-only node: swap its parked publish for the pair (M
            # first — flush resolves X''s parent_key through it)
            for i, p in enumerate(self._publish_queue):
                if p.key == old_key:
                    self._publish_queue[i:i + 1] = [
                        PendingPublish(
                            key=m.key, span=node.span, n_pages=k,
                            span_pages=span_pages, next_tok=m.next_tok,
                            lease_sbs=m_lease, start_page=m.start_page,
                            parent_key=m.parent, fprint=m.fprint),
                        PendingPublish(
                            key=node.key, span=node.span,
                            n_pages=node.end_page, span_pages=span_pages,
                            next_tok=node.next_tok,
                            lease_sbs=node.lease_sbs, start_page=k,
                            parent_key=m.key, fprint=node.fprint)]
                    break
        # old record's lease drops last (a linked record always implied
        # a live span); its block frees after the relink, never before
        self.astate = self._free_large(state=self.astate,
                                       off=jnp.int32(node.span),
                                       n_sbs=jnp.int32(old_lease))
        if old_rec >= 0:
            offs = np.full((self.acfg.cache_cap,), -1, np.int32)
            offs[0] = old_rec
            self.astate = self._free(state=self.astate,
                                     offs=jnp.asarray(offs),
                                     mask=jnp.asarray(offs >= 0))
        return m

    # -------------------------------------------------------------- publish
    def queue_publish(self, lane: int) -> bool:
        """Register this lane's fully-processed prompt as a shared prefix.

        Only whole pages are shared (a partially-filled page would be
        written by the owner — violating block disjointness).  A lane
        holding a reserved span publishes the *span itself*: later
        matching requests acquire the span (one refcount each, see
        ``core.spans``) instead of copying pages into a fresh
        reservation; the span frees when the last holder exits.

        The transient half is immediate — cache entry + prefix lease, so
        sharers can hit before any flush — but the durable record append
        parks in the group-commit queue until ``flush_publishes``.
        Page-path entries are transient-only and complete here.  Returns
        True when a new entry was created."""
        s = self.sessions[lane]
        pos = int(np.asarray(self.dstate["pos"][lane]))
        page = self.cfg.page_size
        full = pos // page
        if full == 0:
            return False
        kv = np.asarray(self.dstate["kv_pos"][lane])
        span = self.large_spans.get(lane)
        if span is None:
            shared = self.shared_spans.get(lane)  # sharers may re-publish
            if shared is not None:
                span = shared[:2]                 # (off, backed prefix pages)
        if span is not None:
            off, n_span = span
            # only span-backed pages can be published under the span
            # entry: clamp to the leading block-table slots the span
            # actually backs (a sharer's post-prefix pages are its own
            # lazy allocations and hold *its* KV, not the span's)
            bt_lane = np.asarray(self.dstate["block_table"][lane])
            cover = 0
            while (cover < min(full, n_span, bt_lane.size)
                   and int(bt_lane[cover]) == off + cover):
                cover += 1
            full = min(full, cover)
            if full == 0:
                return False
            key = hash_tokens(s.tokens[:full * page])
            if self._prefix_cache.get(key) is not None:
                # already published (the cache holds exactly one reference
                # per entry): acquiring again would leak a span reference
                # when this entry is overwritten
                return False
            # the prefix cache itself holds one *prefix* lease — just the
            # superblocks the shared prompt pages occupy — so the prefix
            # survives the publishing session's eviction while the
            # decode-ahead tail stays free to be reclaimed
            lease_sbs = -(-full // self.acfg.sb_words)
            self.astate, _ = self._acquire_span(
                state=self.astate, off=jnp.int32(off),
                n_sbs=jnp.int32(lease_sbs))
            # the prefix boundary token, NOT the lane's current token:
            # mid-page publishes clamp the entry to full*page positions,
            # and a sharer's first decode input must be the token that
            # followed the *published* prefix, not whatever this lane is
            # decoding several positions later
            next_tok = int(s.tokens[full * page])
            self.prefix_cache.insert(
                key,
                ("span", off, n_span, full, full * page, kv[:full].copy(),
                 next_tok, lease_sbs),
                tokens=s.tokens[:full * page])
            # attach the prefix into the trie: the deepest existing
            # boundary becomes the parent (a mid-edge match materializes
            # it as a split first); the new node's edge covers [k, full)
            # but its span still backs the whole [0, full) prefix.
            # k < full always: a boundary AT full would mean this exact
            # prefix is already published, caught by the dedupe above.
            toks = tuple(int(t) for t in s.tokens[:full * page])
            parent, k = self.prefix_cache.match_partial(toks)
            if parent is not None and k < parent.end_page:
                m = self._split_node(parent, k)
                if m is None:
                    parent, k = self.prefix_cache.deepest_boundary(parent, k)
                else:
                    parent = m
            if parent is None:
                k = 0
            node = CacheNode(
                key=key, span=off, start_page=k, end_page=full,
                lease_sbs=lease_sbs, next_tok=next_tok,
                fprint=fingerprint(toks[k * page], toks[full * page - 1]),
                parent=(parent.key if parent is not None else -1),
                tokens=toks, page_keys=page_hashes(toks, page)[k:])
            self.prefix_cache.insert_node(node)
            # the durable index record (one ordinary arena block) parks in
            # the group-commit queue: flush_publishes appends the whole
            # batch behind a single root swing, mirroring the host
            # PrefixIndex.publish_batch fence amortization.  After a crash
            # the record re-publishes this entry and re-trims the lease,
            # so the prefix is hittable without re-prefill.
            self._publish_queue.append(PendingPublish(
                key=key, span=off, n_pages=full, span_pages=n_span,
                next_tok=next_tok, lease_sbs=lease_sbs,
                start_page=k, parent_key=node.parent, fprint=node.fprint))
            _OBS_PUB_QUEUED.inc()
            _OBS_PUB_DEPTH.set(len(self._publish_queue))
            return True
        bt = np.asarray(self.dstate["block_table"][lane])
        if pos != full * page:
            # share only a fully-processed, page-aligned prompt: a
            # mid-page publish would hand sharers a boundary token whose
            # preceding positions are NOT all inside the shared pages
            return False
        pages = tuple(int(p) for p in bt[:full])
        for p in pages:
            # +1: the prefix cache itself holds a reference, so the pages
            # survive the publishing session's eviction
            self.prefix_cache.add_page_ref(p)
        # page-path entries stay transient-only: their sharing is per-page
        # refcounts, not a span lease, and the durable index records only
        # span-backed prefixes (a crash forgets these — they re-prefill)
        pkey = hash_tokens(s.tokens[:full * page])
        self.prefix_cache.insert(
            pkey,
            ("pages", pages, full * page, kv[:full].copy(),
             int(self.cur_tokens[lane])),
            tokens=s.tokens[:full * page])
        return True

    def flush_publishes(self) -> int:
        """Land every parked publication durably: per batch of up to
        ``publish_capacity``, ONE vectorized record-block allocation, one
        chained ``append_batch`` and ONE root swing — the group commit.
        A full arena degrades safely: those publishes stay
        transient-only.  Returns the number of records appended."""
        appended = 0
        while self._publish_queue:
            batch = self._publish_queue[:self.publish_capacity]
            del self._publish_queue[:len(batch)]
            _OBS_PUB_FLUSHES.inc()
            _OBS_PUB_BATCH.observe(len(batch))
            recs = self._alloc_blocks(len(batch))
            rec_of: dict[int, int] = {}     # key -> record landed this batch
            payloads = []
            for rec, p in zip(recs, batch):
                if rec < 0:
                    continue
                # parent record offset resolves NOW: the parent either
                # landed earlier in this very batch (queued splits put M
                # before X') or already owns a record from a prior flush;
                # a parent that missed its block degrades to -1 and the
                # recovery coverage pass re-links by page boundary
                par = -1
                if p.parent_key >= 0:
                    par = rec_of.get(p.parent_key, -1)
                    if par < 0:
                        pn = self.prefix_cache.nodes.get(p.parent_key)
                        par = pn.rec_off if pn is not None else -1
                payloads.append(dict(
                    rec_off=rec, key=p.key, span=p.span,
                    n_pages=p.n_pages, span_pages=p.span_pages,
                    next_tok=p.next_tok, lease_sbs=p.lease_sbs,
                    parent=par, start_page=p.start_page, fprint=p.fprint))
                rec_of[p.key] = rec
            if payloads:
                self.prefix_store.append_batch(payloads)
                self._mirror_index_roots()
                for q in payloads:
                    self.prefix_cache.set_rec(q["key"], q["rec_off"])
                appended += len(payloads)
        _OBS_PUB_DEPTH.set(0)
        return appended

    @property
    def pending_publishes(self) -> int:
        return len(self._publish_queue)

    def publish_prefix(self, lane: int) -> None:
        """Immediate (ungrouped) publish: queue + flush in one call.
        Batched serving amortizes instead via ``queue_publish`` +
        ``flush_publishes`` on the scheduler's cadence."""
        self.queue_publish(lane)
        self.flush_publishes()

    def drop_prefix_cache(self) -> None:
        """Release the cache's references; fully-unreferenced pages (and
        spans whose last holder was the cache) free."""
        for key, entry in list(self._prefix_cache.items()):
            if entry[0] == "span":
                # durable unlink FIRST (a linked record must always imply
                # a live span — core.prefix_index ordering), then the
                # lease release, then the record block frees.  An entry
                # still parked in the publish queue has no record yet
                # (remove returns None) — dropping its queue slot below
                # is its whole un-publication.
                rec = self.prefix_store.remove(key)
                if rec is not None:
                    self._mirror_index_roots()
                # free_large releases the cache's prefix lease: a
                # transient decrement while holders remain, the actual
                # free of whatever range the cache was last to lease
                self.astate = self._free_large(state=self.astate,
                                               off=jnp.int32(entry[1]),
                                               n_sbs=jnp.int32(entry[7]))
                if rec is not None:
                    offs = np.full((self.acfg.cache_cap,), -1, np.int32)
                    offs[0] = rec.off
                    self.astate = self._free(state=self.astate,
                                             offs=jnp.asarray(offs),
                                             mask=jnp.asarray(offs >= 0))
                continue
            pages = entry[1]
            stale = []
            for p in pages:
                if p in self.page_refs:
                    self.page_refs[p] -= 1
                    if self.page_refs[p] <= 0:
                        stale.append(p)
                        del self.page_refs[p]
            if stale:
                offs = np.full((self.acfg.cache_cap,), -1, np.int32)
                offs[:len(stale)] = stale
                self.astate = self._free(state=self.astate,
                                         offs=jnp.asarray(offs),
                                         mask=jnp.asarray(offs >= 0))
        self.prefix_cache.clear()
        # parked appends for the just-dropped entries must never land
        self._publish_queue.clear()

    # ------------------------------------------------------------------ step
    def step(self) -> dict[int, int]:
        """One decode step for every active lane; returns emitted tokens."""
        active = self.lane_states.active()
        if not active.any():
            return {}
        # page-boundary lanes need a fresh page before the step — unless
        # the slot is already backed (prefix hit or a reserved large span)
        pos = np.asarray(self.dstate["pos"])
        page = self.cfg.page_size
        need = active & (pos % page == 0) & (self.cfg.attn_layers > 0)
        if need.any():
            # only boundary steps pay the block-table device→host sync
            bt_now = np.asarray(self.dstate["block_table"])
            slot = np.clip(pos // page, 0, bt_now.shape[1] - 1)
            need &= bt_now[np.arange(self.lanes), slot] < 0
        if need.any():
            _OBS_DEV_ALLOC.inc()
            self.astate, offs = self._alloc(state=self.astate,
                                            need=jnp.asarray(need))
            offs = np.asarray(offs)
            bt = np.asarray(self.dstate["block_table"]).copy()
            for lane in np.nonzero(need)[0]:
                if offs[lane] < 0:
                    raise MemoryError("KV arena exhausted")
                bt[lane, pos[lane] // page] = offs[lane]
            self.dstate["block_table"] = jnp.asarray(bt)

        self.dstate, toks = self.step_fn(self.params, self.dstate,
                                         jnp.asarray(self.cur_tokens))
        toks = np.asarray(toks)
        out = {}
        for lane, s in list(self.sessions.items()):
            if s.done:
                continue
            t = int(pos[lane]) + 1
            if t < len(s.tokens):
                self.cur_tokens[lane] = s.tokens[t]       # teacher-forced
            else:
                s.tokens.append(int(toks[lane]))
                self.cur_tokens[lane] = int(toks[lane])
                out[lane] = int(toks[lane])
            if len(s.tokens) >= self.max_seq - 1:
                self.finish(lane)
        return out

    def finish(self, lane: int) -> None:
        """Evict a session: free its pages (shared pages only at ref 0,
        leased span ranges only when their last lease releases).

        The lane's span records are *poisoned* here — popped before any
        release — so a dead lane can never free a span reallocated at
        the same offset: a second ``finish`` of the lane raises
        (``KeyError``), it cannot silently release someone else's span.
        """
        s = self.sessions.pop(lane)
        s.done = True
        bt = np.asarray(self.dstate["block_table"][lane])
        pages = bt[bt >= 0].astype(np.int32)
        span = self.large_spans.pop(lane, None)
        shared = self.shared_spans.pop(lane, None)
        self.lane_states.partial_hits.pop(lane, None)
        if span is not None:
            # the prompt's page table is one large span: free_large drops
            # the owner's full-extent lease — superblocks nobody else
            # leases free *now* (in particular the decode-ahead tail past
            # the published prefix, which only prefix leases cover);
            # pages decoded past the span were lazily allocated and go
            # through the per-page free below
            off, n_span = span
            self.astate = self._free_large(state=self.astate,
                                           off=jnp.int32(off),
                                           n_sbs=jnp.int32(-1))
            pages = pages[(pages < off) | (pages >= off + n_span)]
        elif shared is not None:
            # a sharer releases exactly the prefix range it leased; its
            # own decode pages (which may legitimately reuse freed tail
            # superblocks of this very span) free per-page below
            off, n_backed, lease_sbs = shared
            self.astate = self._free_large(state=self.astate,
                                           off=jnp.int32(off),
                                           n_sbs=jnp.int32(lease_sbs))
            pages = pages[(pages < off) | (pages >= off + n_backed)]
        keep = []
        for p in pages.tolist():
            if p in self.page_refs:
                self.page_refs[p] -= 1
                if self.page_refs[p] > 0:
                    keep.append(p)          # still referenced elsewhere
                else:
                    del self.page_refs[p]
        if keep:
            pages = np.asarray([p for p in pages.tolist() if p not in keep],
                               np.int32)
        if pages.size:
            offs = np.full((self.acfg.cache_cap,), -1, np.int32)
            offs[:pages.size] = pages
            self.astate = self._free(state=self.astate,
                                     offs=jnp.asarray(offs),
                                     mask=jnp.asarray(offs >= 0))
        self.dstate["block_table"] = \
            self.dstate["block_table"].at[lane].set(-1)
        self.astate = ja.set_root(self.astate, lane, jnp.int32(-1))
        self.lane_states.release(lane)

    # ------------------------------------------------------------- recovery
    def ref_table(self) -> np.ndarray:
        """Filter function output: each live session's root block (its
        first page) references the session's remaining pages.

        Lanes sharing a span root at the same head page, so their
        reference lists *accumulate* into that slot's row (the row is
        widened as needed) — losing one lane's refs would sweep its
        lazily-allocated decode pages out from under it.

        Prefix-index records contribute their own rows (the record
        type's filter function): ``[next record, span head]`` — the mark
        pass traces the chain precisely and counts the record→span
        reference like a lane root, which is what keeps a published span
        alive across a crash with no lane rooted on it."""
        S = jr.num_slots(self.acfg)
        R = int(self.dstate["block_table"].shape[1])
        bt = np.asarray(self.dstate["block_table"])
        rows: dict[int, list[int]] = {}
        for lane, s in self.sessions.items():
            if s.done:
                continue
            pages = bt[lane][bt[lane] >= 0]
            if pages.size == 0:
                continue
            rows.setdefault(int(pages[0]), []).extend(pages[1:].tolist())
        for rec_off, tgts in self.prefix_store.ref_rows().items():
            rows.setdefault(rec_off, []).extend(tgts)
        width = max([R] + [len(v) for v in rows.values()])
        refs = np.full((S, width), -1, np.int32)
        for root, tgts in rows.items():
            refs[root, :len(tgts)] = tgts
        return refs

    def crash_and_recover(self) -> dict:
        """Simulate losing all transient allocator state, then rebuild it
        from (persistent fields + session page tables + the durable
        prefix index) via vectorized GC.

        Engine-side sharing metadata is transient and comes back from
        what the roots can see: per-page refcounts are recounted from
        live block tables and span leases are reconstructed inside
        ``jr.recover`` as the number of root-reachable references to each
        span head — conservatively *full-extent*, because lease lengths
        are transient.  The durable prefix index is the exception the
        tentpole adds: surviving records re-publish their entries into
        the rebuilt cache (hittable without re-prefill) and every lease
        whose true length IS recorded — the cache's record lease and each
        live sharer's prefix lease — is re-trimmed to its page-derived
        superblock count, so the decode-ahead tail frees immediately
        after recovery instead of waiting for the reserver to
        re-finish."""
        # Named engine-recovery phases (repro.obs spans): timings + item
        # counts surface in the returned stats and the metrics snapshot,
        # mirroring core.recovery's host-side phase profile.
        phases: dict[str, dict] = {}

        def _phase(span):
            phases[span.name.split(".", 1)[1]] = {
                "seconds": span.seconds, "items": span.items}

        # torn / unrecoverable-orphan pre-prune, BEFORE the mark pass
        # (host ordering: prune_torn_nodes runs before recover's trace).
        # A torn record's span reference would otherwise phantom-lease
        # the span, and its marked block would leak as owned-by-nobody.
        prune_span = obs.span("engine_recovery.prune_records")
        prune_span.__enter__()
        recs0 = self.prefix_store.walk()
        trie_pruned = 0
        if recs0:
            by_off = {r.off: r for r in recs0}
            keep = {r.off for r in recs0
                    if self.prefix_store.seal_matches(r.off)}
            # recoverability: a node is servable iff kept records cover
            # [0, start_page) contiguously — fixpoint from boundary 0
            bounds, grew = {0}, True
            while grew:
                grew = False
                for off in keep:
                    r = by_off[off]
                    if r.start_page in bounds and r.n_pages not in bounds:
                        bounds.add(r.n_pages)
                        grew = True
            keep = {off for off in keep
                    if by_off[off].start_page in bounds}
            if len(keep) < len(recs0):
                self.prefix_store.prune(
                    np.asarray([r.off in keep for r in recs0], bool))
                trie_pruned = len(recs0) - len(keep)
            # survivors with dangling parents re-parent to ANY kept
            # record ending at their start page (navigation is by
            # cumulative hash — the parent field is only trie shape)
            for r in self.prefix_store.walk():
                if r.start_page == 0:
                    if r.parent != -1:
                        self.prefix_store.reparent(r.off, -1)
                    continue
                if (r.parent in keep and r.parent != r.off
                        and by_off[r.parent].n_pages == r.start_page):
                    continue
                cover = next((o for o in keep if o != r.off
                              and by_off[o].n_pages == r.start_page), None)
                self.prefix_store.reparent(
                    r.off, cover if cover is not None else -1)
        prune_span.add(trie_pruned)
        prune_span.__exit__(None, None, None)
        _phase(prune_span)
        with obs.span("engine_recovery.snapshot") as sp:
            persistent = ja.persistent_snapshot(self.astate)
            roots = np.full((self.lanes + self.prefix_buckets,), -1,
                            np.int32)
            bt = np.asarray(self.dstate["block_table"])
            for lane, s in self.sessions.items():
                pages = bt[lane][bt[lane] >= 0]
                if pages.size:
                    roots[lane] = int(pages[0])
            for b, head in enumerate(self.prefix_store.heads):
                roots[self._index_root + b] = head
            persistent["roots"] = jnp.asarray(roots)
            sp.add(int((roots >= 0).sum()))
        _phase(sp)
        with obs.span("engine_recovery.mark_sweep") as sp:
            new_state, marked = jr.recover(self.acfg, persistent,
                                           jnp.asarray(self.ref_table()))
            live_before = ja.live_blocks(self.astate, self.acfg)[PAGE_CLS]
            self.astate = new_state
            live_after = ja.live_blocks(new_state, self.acfg)[PAGE_CLS]
            sp.add(int(np.asarray(marked).sum()))
        _phase(sp)
        # drop + recount the engine's transient sharing records (recovery
        # step 2: caches start empty in a fresh process).  Span-backed
        # pages are excluded: their sharing is the *span's* refcount
        # (reconstructed inside jr.recover) and finish() never routes them
        # through the per-page free, so a per-page count would go stale
        # and poison the offset after the span frees and is reallocated.
        # (Exact token sequences die with the cache: re-published entries
        # are named by the record's hash alone.)
        with obs.span("engine_recovery.recount_refs") as sp:
            self.prefix_cache.clear()
            # queued-but-unflushed appends die with the process too: they
            # never became durable, no lease reconstruction references
            # them, and their cache entries were just cleared — dropping
            # the queue IS the crash semantics for an un-flushed group
            # commit
            self._publish_queue.clear()
            spans = list(self.large_spans.values()) + \
                [(off, n_backed) for off, n_backed, _ in
                 self.shared_spans.values()]
            counts: dict[int, int] = {}
            for lane, s in self.sessions.items():
                if s.done:
                    continue
                for p in bt[lane][bt[lane] >= 0].tolist():
                    if any(off <= p < off + n for off, n in spans):
                        continue
                    counts[p] = counts.get(p, 0) + 1
            self.page_refs = {p: c for p, c in counts.items() if c > 1}
            sp.add(len(self.page_refs))
        _phase(sp)
        # re-publish surviving index records into the rebuilt cache and
        # re-trim each record's reconstructed full-extent lease to its
        # recorded superblock count (a record whose root swing never
        # became durable is unmarked — pruned, exactly like the host GC
        # frees an unreachable core.prefix_index record)
        with obs.span("engine_recovery.republish") as sp:
            recs = self.prefix_store.walk()
            seal_ok = np.asarray([self.prefix_store.seal_matches(r.off)
                                  for r in recs] + [True], bool)
            live = jr.live_record_mask(self.acfg, marked,
                                       np.asarray([r.off for r in recs]
                                                  + [-1], np.int32),
                                       seal_ok=jnp.asarray(seal_ok))
            survivors = self.prefix_store.prune(
                np.asarray(live)[:len(recs)])
            page = self.cfg.page_size
            for rec in survivors:
                # a fully-processed prompt page p holds positions
                # p*page .. p*page+page-1 — kv_pos rebuilds
                # deterministically
                kvp = np.arange(rec.n_pages * page,
                                dtype=np.int32).reshape(rec.n_pages, page)
                self._prefix_cache[rec.key] = (
                    "span", rec.span, rec.span_pages, rec.n_pages,
                    rec.n_pages * page, kvp, rec.next_tok, rec.lease_sbs)
                _OBS_DEV_TRIM.inc()
                self.astate, _ = self._trim_large(
                    state=self.astate, off=jnp.int32(rec.span),
                    n_keep=jnp.int32(rec.lease_sbs), n_held=jnp.int32(-1))
            self._mirror_index_roots()
            # rebuild the trie shape from the surviving records
            # (token-less nodes: they match all-or-nothing, key +
            # fingerprint) so longest-prefix partial hits work
            # immediately after recovery
            self.prefix_cache.rebuild_from_records(survivors)
            sp.add(len(survivors))
        _phase(sp)
        # live sharers' prefix leases were also rebuilt full-extent;
        # their true lengths survive in shared_spans — re-trim them too,
        # so the post-recovery lease vector equals the pre-crash one
        with obs.span("engine_recovery.retrim_shared") as sp:
            for lane, (off, _n_backed,
                       lease_sbs) in self.shared_spans.items():
                if lane in self.sessions and not self.sessions[lane].done:
                    _OBS_DEV_TRIM.inc()
                    self.astate, _ = self._trim_large(
                        state=self.astate, off=jnp.int32(off),
                        n_keep=jnp.int32(lease_sbs), n_held=jnp.int32(-1))
                    sp.add(1)
        _phase(sp)
        return {"marked": int(np.asarray(marked).sum()),
                "live_before": live_before, "live_after": live_after,
                "index_records": len(survivors),
                "trie_pruned": trie_pruned,
                "phases": phases}
