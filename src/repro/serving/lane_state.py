"""Per-lane transient state for the serving engine.

A *lane* is one decode stream; the engine batches every active lane
through a single decode step.  Everything here is transient — sessions,
the lane pool, span bookkeeping and current tokens die with a crash and
are rebuilt by ``ServingEngine.crash_and_recover`` from the durable
image.  Split out of the engine so admission policy
(``serving.scheduler``) and publish bookkeeping
(``serving.prefix_cache``) can reason about lane lifetime without the
decode plumbing.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class Session:
    lane: int
    tokens: list
    done: bool = False


class LaneStates:
    """Lane pool + per-lane session/span records.

    ``large_spans``: lanes holding a contiguous multi-superblock page
    span (oversized prompts): lane -> (span head offset, n_pages); the
    owner holds a full-extent lease released via ``free_large`` —
    unleased tail superblocks (decode-ahead slack nobody's prefix lease
    covers) free right then, not at the last holder's exit.

    ``shared_spans``: lanes that *acquired* a prefix lease on another
    lane's published span (shared-prefix hits — exact whole-prompt hits
    AND longest-prefix partial hits alike; a partial hit leases the
    matched trie node's span prefix and decodes its suffix on its own
    lazily-allocated pages): lane -> (off, n_backed_pages, lease_sbs);
    finish releases exactly that prefix range.

    ``partial_hits``: the subset of shared-span lanes admitted through a
    *partial* (longest-prefix) trie match: lane -> matched whole pages.
    Pure observability — the span bookkeeping above is authoritative for
    every release path — but it is what the hierprompt benchmark and the
    trie serving tests read to assert O(suffix) footprints.
    """

    def __init__(self, lanes: int):
        self.lanes = lanes
        self.sessions: dict[int, Session] = {}
        self.free_lanes: list[int] = list(range(lanes))
        self.large_spans: dict[int, tuple[int, int]] = {}
        self.shared_spans: dict[int, tuple[int, int, int]] = {}
        self.partial_hits: dict[int, int] = {}
        self.cur_tokens = np.zeros((lanes,), np.int32)

    def acquire(self) -> int | None:
        """Claim a free lane — ``None`` when every lane is busy.  The
        caller turns that into admission control (a typed ``EngineBusy``
        or a wait-queue park), never a bare pop failure."""
        return self.free_lanes.pop() if self.free_lanes else None

    def release(self, lane: int) -> None:
        self.free_lanes.append(lane)

    def active(self) -> np.ndarray:
        """Boolean mask of lanes with a live, unfinished session."""
        act = np.zeros((self.lanes,), bool)
        for lane, s in self.sessions.items():
            if not s.done:
                act[lane] = True
        return act


def reset_lane(dstate: dict, lane: int) -> dict:
    """Neutralize one lane's decode state — fresh admission, or backing
    out a failed reservation: pos 0, no backing pages, no prefix KV."""
    dstate["pos"] = dstate["pos"].at[lane].set(0)
    dstate["block_table"] = dstate["block_table"].at[lane].set(-1)
    dstate["kv_pos"] = dstate["kv_pos"].at[lane].set(-1)
    return dstate
