"""Transient prefix cache: hash-keyed shared-prompt entries + page refs.

Split out of the engine: this is the RadixAttention-style sharing state.
Keys are 48-bit prompt hashes (``core.prefix_index.hash_tokens``) so a
durable index record can name its entry across a crash; the cache itself
is transient and rebuilt by recovery from surviving records.

``entries`` holds two entry shapes:

  ("span",  off, n_span, full, plen, kv_pos, next_tok, lease_sbs) —
      span-backed prefixes; the entry owns one *prefix* span lease and
      (once the group-commit queue flushes) one durable index record;
  ("pages", pages, plen, kv_pos, next_tok) —
      page-path prefixes shared via per-page refcounts, transient-only
      (a crash forgets them — they re-prefill).

``tokens`` maps each hash to the exact published token sequence: a hit
must never serve another prompt's KV on a 48-bit collision, so hits on
entries published THIS process verify token equality.  The durable
record stores only the hash, so entries re-published by recovery match
by hash alone — the documented residual.
"""

from __future__ import annotations

from ..core.prefix_index import hash_tokens


class PrefixCache:
    def __init__(self):
        self.entries: dict[int, tuple] = {}     # hash -> cache entry
        self.tokens: dict[int, tuple] = {}      # hash -> exact tokens
        # pages holding a shared prompt prefix are referenced by several
        # block tables; refcounts enforce the paper's "no block used for
        # two purposes" discipline — a shared page returns to the
        # allocator only at refcount zero
        self.page_refs: dict[int, int] = {}

    def lookup(self, prompt) -> tuple | None:
        """Collision-safe hit for ``prompt`` (or ``None`` on a miss —
        including the hash-collision-treated-as-miss case)."""
        khash = hash_tokens(prompt)
        hit = self.entries.get(khash)
        if hit is not None:
            known = self.tokens.get(khash)
            if known is not None and known != tuple(prompt):
                return None              # hash collision: treat as a miss
        return hit

    def insert(self, key: int, entry: tuple, tokens=None) -> None:
        self.entries[key] = entry
        if tokens is not None:
            self.tokens[key] = tuple(tokens)

    def add_page_ref(self, p: int) -> None:
        # +1 baseline: the owner's block table is the implicit first ref
        self.page_refs[p] = self.page_refs.get(p, 1) + 1

    def clear(self) -> None:
        self.entries.clear()
        self.tokens.clear()
