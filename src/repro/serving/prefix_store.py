"""Durable prefix store: the serving engine's device-side prefix index.

Device mirror of ``core.prefix_index``.  The engine's prefix cache keyed
transient host objects by prompt tuple; everything in it died with a
crash, so recovery could only rebuild conservative full-extent span
leases and every published prompt had to be re-prefilled.  The store
persists the minimum that lets ``crash_and_recover`` rebuild the rest:

  * each published prompt owns one **record block** — an ordinary arena
    block (``PAGE_CLS``), so the record is reachable/traceable/sweepable
    exactly like a KV page;
  * the record *fields* live in a durable sidecar array (device
    consumers own typed arrays rather than a raw byte heap — see
    ``core.jax_recovery``'s module docstring), indexed by the record's
    block offset:

        F_NEXT        next record block offset (-1 ends the chain)
        F_SPAN        published span head offset
        F_KEY         48-bit prompt hash (``core.prefix_index.hash_tokens``)
        F_PAGES       full prompt pages published
        F_SPAN_PAGES  pages the span backed at publish time
        F_TOK         the sampled continuation token at the prompt
                      boundary (part of the published prefix)
        F_LEASE       the cache lease's superblock count

  * the chain head lives in a dedicated allocator root
    (``ServingEngine._index_root``), and the engine's ``ref_table`` adds
    one row per record — ``[next record, span head]`` — which is the
    record type's *filter function* in the vectorized recovery model:
    the mark pass traces records precisely, and ``span_ref_counts``
    counts the record→span reference exactly like a lane root, so a
    published span survives a crash even when no lane roots it.

Durability ordering mirrors the host (``core.prefix_index``): fields are
written before the chain head swings, and removal unlinks before the
lease is released — a linked record always implies a live span.  After
recovery the engine walks the chain (filtered through
``jax_recovery.live_record_mask``), re-publishes each record into the
rebuilt cache, and re-trims the record's reconstructed full-extent lease
to ``F_LEASE`` superblocks (``trim_large``), freeing the decode-ahead
tail immediately.
"""

from __future__ import annotations

import dataclasses

import numpy as np

F_NEXT, F_SPAN, F_KEY, F_PAGES, F_SPAN_PAGES, F_TOK, F_LEASE = range(7)
REC_FIELDS = 7


@dataclasses.dataclass(frozen=True)
class StoreRecord:
    """One decoded store record."""
    off: int                 # record block offset (the record id)
    key: int                 # 48-bit prompt hash
    span: int                # span head offset
    n_pages: int             # published whole pages
    span_pages: int          # pages the span backed at publish time
    next_tok: int            # sampled continuation at the prompt boundary
    lease_sbs: int           # the cache lease's superblock count


class PrefixStore:
    """Durable record table + chain head for one device arena.

    ``words`` and ``head`` are the durable state (they survive a crash
    like the decode state's block tables do); the engine mirrors
    ``head`` into its dedicated allocator root so the mark pass starts
    from it.
    """

    def __init__(self, num_slots: int):
        self.words = np.full((num_slots, REC_FIELDS), -1, np.int64)
        self.head = -1

    # ---------------------------------------------------------------- reads
    def walk(self) -> list[StoreRecord]:
        """Decode the chain from ``head`` (cycle-safe)."""
        out: list[StoreRecord] = []
        rec, seen = self.head, set()
        while rec >= 0 and rec not in seen:
            seen.add(rec)
            w = self.words[rec]
            out.append(StoreRecord(
                off=rec, key=int(w[F_KEY]), span=int(w[F_SPAN]),
                n_pages=int(w[F_PAGES]), span_pages=int(w[F_SPAN_PAGES]),
                next_tok=int(w[F_TOK]), lease_sbs=int(w[F_LEASE])))
            rec = int(w[F_NEXT])
        return out

    def ref_rows(self) -> dict[int, list[int]]:
        """Per-record reference lists for the engine's ``ref_table`` —
        the record type's filter-function output: next record + span."""
        rows: dict[int, list[int]] = {}
        for rec in self.walk():
            tgts = [t for t in (int(self.words[rec.off][F_NEXT]), rec.span)
                    if t >= 0]
            rows[rec.off] = tgts
        return rows

    # --------------------------------------------------------------- writes
    def append(self, rec_off: int, *, key: int, span: int, n_pages: int,
               span_pages: int, next_tok: int, lease_sbs: int) -> None:
        """Link a freshly allocated record block at the chain head.

        Fields first, head swing last — the durability ordering the host
        index fences around; a crash between the two leaves the record
        unreachable and the sweep frees its block.
        """
        self.append_batch([dict(rec_off=rec_off, key=key, span=span,
                                n_pages=n_pages, span_pages=span_pages,
                                next_tok=next_tok, lease_sbs=lease_sbs)])

    def append_batch(self, payloads: list[dict]) -> None:
        """Group-commit append: link N freshly allocated record blocks as
        one chain segment with a single head swing.

        Device mirror of ``PrefixIndex.publish_batch``: every record's
        fields are written first — the batch chained among itself, the
        last record pointing at the old head — and only then does
        ``head`` swing once to the first record.  A crash before the
        swing leaves the whole segment unreachable (the sweep frees all
        N blocks and their leases fall back to the roots); after it all
        N records are published.  Each payload dict carries the same
        keyword fields ``append`` takes.
        """
        if not payloads:
            return
        offs = [int(p["rec_off"]) for p in payloads]
        for i, p in enumerate(payloads):
            nxt = offs[i + 1] if i + 1 < len(offs) else self.head
            self.words[offs[i]] = (nxt, int(p["span"]), int(p["key"]),
                                   int(p["n_pages"]), int(p["span_pages"]),
                                   int(p["next_tok"]), int(p["lease_sbs"]))
        self.head = offs[0]

    def remove(self, key: int) -> StoreRecord | None:
        """Unlink the record for ``key``; returns it (the caller releases
        the span lease and frees the record block *after* the unlink)."""
        prev, rec, seen = -1, self.head, set()
        while rec >= 0 and rec not in seen:
            seen.add(rec)
            w = self.words[rec]
            nxt = int(w[F_NEXT])
            if int(w[F_KEY]) == int(key):
                out = StoreRecord(
                    off=rec, key=int(w[F_KEY]), span=int(w[F_SPAN]),
                    n_pages=int(w[F_PAGES]),
                    span_pages=int(w[F_SPAN_PAGES]),
                    next_tok=int(w[F_TOK]), lease_sbs=int(w[F_LEASE]))
                if prev < 0:
                    self.head = nxt
                else:
                    self.words[prev][F_NEXT] = nxt
                self.words[rec] = -1
                return out
            prev, rec = rec, nxt
        return None

    def prune(self, live_mask) -> list[StoreRecord]:
        """Drop records whose blocks the sweep did not mark (their root
        swing never became durable); returns the surviving records.

        ``live_mask`` is ``jax_recovery.live_record_mask(cfg, marked,
        [r.off for r in walk()])`` — by construction an unreachable
        record can only sit at the chain head, but pruning the whole walk
        keeps a corrupt image from resurrecting stale entries.
        """
        recs = self.walk()
        live = np.asarray(live_mask, bool)
        keep = [r for r, ok in zip(recs, live) if ok]
        for r, ok in zip(recs, live):
            if not ok:
                self.words[r.off] = -1
        self.head = keep[0].off if keep else -1
        for a, b in zip(keep, keep[1:]):
            self.words[a.off][F_NEXT] = b.off
        if keep:
            self.words[keep[-1].off][F_NEXT] = -1
        return keep
