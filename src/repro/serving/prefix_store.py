"""Durable prefix store: the serving engine's device-side prefix index.

Device mirror of ``core.prefix_trie``.  The engine's prefix cache keyed
transient host objects by prompt tuple; everything in it died with a
crash, so recovery could only rebuild conservative full-extent span
leases and every published prompt had to be re-prefilled.  The store
persists the minimum that lets ``crash_and_recover`` rebuild the rest:

  * each published prefix-trie node owns one **record block** — an
    ordinary arena block (``PAGE_CLS``), so the record is
    reachable/traceable/sweepable exactly like a KV page;
  * the record *fields* live in a durable sidecar array (device
    consumers own typed arrays rather than a raw byte heap — see
    ``core.jax_recovery``'s module docstring), indexed by the record's
    block offset:

        F_NEXT        next record block offset (-1 ends the chain)
        F_SPAN        published span head offset
        F_KEY         48-bit cumulative prefix hash up to F_PAGES
                      (``core.prefix_index.hash_tokens``)
        F_PAGES       the node's end page — full prefix pages published
        F_SPAN_PAGES  pages the span backed at publish time
        F_TOK         the sampled continuation token at the prefix
                      boundary (part of the published prefix)
        F_LEASE       the cache lease's superblock count
        F_PARENT      parent node's record block offset (-1 = root
                      child) — the trie shape; excluded from the seal
                      because a split re-parents children in place
        F_START       the node's start page (the edge covers
                      [F_START, F_PAGES) of the prefix)
        F_FPRINT      token fingerprint (edge-first token low32 |
                      prefix-last token low16 << 32) — lets a recovered
                      record verify tokens cheaply before serving
        F_SEAL        16-bit checksum over the content fields (all but
                      F_NEXT / F_PARENT / F_SEAL), the device mirror of
                      the host record's word-2 seal: a record whose
                      fields tore mid-write fails the seal and
                      ``jax_recovery.live_record_mask`` drops it

  * the chain head lives in a dedicated allocator root
    (``ServingEngine._index_root``), and the engine's ``ref_table`` adds
    one row per record — ``[next record, parent record, span head]`` —
    which is the record type's *filter function* in the vectorized
    recovery model: the mark pass traces records precisely, and
    ``span_ref_counts`` counts the record→span reference exactly like a
    lane root, so a published span survives a crash even when no lane
    roots it.

Durability ordering mirrors the host (``core.prefix_trie``): fields are
written (seal last) before the chain head swings, a split splices both
new halves before the old record clears, and removal unlinks before the
lease is released — a linked record always implies a live span.  After
recovery the engine prunes seal-mismatched and unrecoverable-orphan
records, walks the survivors (filtered through
``jax_recovery.live_record_mask``), re-publishes each into the rebuilt
trie cache with zero re-prefill, and re-trims the record's
reconstructed full-extent lease to ``F_LEASE`` superblocks
(``trim_large``), freeing the decode-ahead tail immediately.
"""

from __future__ import annotations

import dataclasses

import numpy as np

(F_NEXT, F_SPAN, F_KEY, F_PAGES, F_SPAN_PAGES, F_TOK, F_LEASE, F_PARENT,
 F_START, F_FPRINT, F_SEAL) = range(11)
REC_FIELDS = 11

#: the seal covers exactly these fields, in this order (chain/shape
#: fields are rewritten in place by unlink/re-parent and must not stale
#: a live record's seal — same exclusion as host words 0 and 1)
_SEALED = (F_SPAN, F_KEY, F_PAGES, F_SPAN_PAGES, F_TOK, F_LEASE, F_START,
           F_FPRINT)

_M64 = 0xFFFFFFFFFFFFFFFF


def record_checksum(fields) -> int:
    """16-bit FNV fold over the sealed field values (host
    ``prefix_trie._record_checksum`` discipline: nonzero seed so an
    all-zero record never passes; -1 is never a valid seal, so the
    sidecar's fill value reads as torn)."""
    h = 0x9E3779B97F4A7C15
    for v in fields:
        h ^= int(v) & _M64
        h = (h * 0x100000001B3) & _M64
    return (h ^ (h >> 16) ^ (h >> 32) ^ (h >> 48)) & 0xFFFF


@dataclasses.dataclass(frozen=True)
class StoreRecord:
    """One decoded store record."""
    off: int                 # record block offset (the record id)
    key: int                 # 48-bit cumulative prefix hash
    span: int                # span head offset
    n_pages: int             # the node's end page (full prefix pages)
    span_pages: int          # pages the span backed at publish time
    next_tok: int            # sampled continuation at the prefix boundary
    lease_sbs: int           # the cache lease's superblock count
    parent: int = -1         # parent record offset (-1 = root child)
    start_page: int = 0      # edge covers [start_page, n_pages)
    fprint: int = 0          # token fingerprint (first low32 | last low16)


class PrefixStore:
    """Durable record table + chain heads for one device arena.

    ``words`` and ``heads`` are the durable state (they survive a crash
    like the decode state's block tables do); the engine mirrors every
    head into its own dedicated allocator root so the mark pass starts
    from all of them.  ``n_buckets > 1`` hash-buckets the chains by the
    48-bit key (device mirror of the host ``PrefixIndex`` bucketing):
    ``lookup``-style walks — ``remove``, the split predecessor search —
    touch O(records / n_buckets) rows, and each bucket's head swings
    independently.  The single-bucket default is the historical one-chain
    layout, bit-for-bit.
    """

    def __init__(self, num_slots: int, n_buckets: int = 1):
        if n_buckets < 1:
            raise ValueError(f"n_buckets {n_buckets} < 1")
        self.words = np.full((num_slots, REC_FIELDS), -1, np.int64)
        self.n_buckets = int(n_buckets)
        self.heads = [-1] * self.n_buckets

    def _bucket(self, key: int) -> int:
        return int(key) % self.n_buckets

    @property
    def head(self) -> int:
        """Bucket 0's chain head (the whole chain when unbucketed)."""
        return self.heads[0]

    @head.setter
    def head(self, rec: int) -> None:
        self.heads[0] = int(rec)

    # ---------------------------------------------------------------- reads
    def _decode(self, rec: int) -> StoreRecord:
        w = self.words[rec]
        return StoreRecord(
            off=rec, key=int(w[F_KEY]), span=int(w[F_SPAN]),
            n_pages=int(w[F_PAGES]), span_pages=int(w[F_SPAN_PAGES]),
            next_tok=int(w[F_TOK]), lease_sbs=int(w[F_LEASE]),
            parent=int(w[F_PARENT]), start_page=int(w[F_START]),
            fprint=int(w[F_FPRINT]))

    def _walk_bucket(self, b: int) -> list[StoreRecord]:
        out: list[StoreRecord] = []
        rec, seen = self.heads[b], set()
        while rec >= 0 and rec not in seen:
            seen.add(rec)
            out.append(self._decode(rec))
            rec = int(self.words[rec][F_NEXT])
        return out

    def walk(self) -> list[StoreRecord]:
        """Decode every chain, bucket 0 first (cycle-safe); torn records
        are still yielded — recovery prunes them by ``seal_ok`` mask."""
        return [r for b in range(self.n_buckets)
                for r in self._walk_bucket(b)]

    def seal_matches(self, rec_off: int) -> bool:
        """True iff the record's seal checksum matches its fields."""
        w = self.words[int(rec_off)]
        return int(w[F_SEAL]) == record_checksum(w[f] for f in _SEALED)

    def ref_rows(self) -> dict[int, list[int]]:
        """Per-record reference lists for the engine's ``ref_table`` —
        the record type's filter-function output: next record, parent
        record, and (only when the seal matches — a torn record must
        never re-lease its span) the span head."""
        rows: dict[int, list[int]] = {}
        for rec in self.walk():
            w = self.words[rec.off]
            tgts = [t for t in (int(w[F_NEXT]), int(w[F_PARENT])) if t >= 0]
            if rec.span >= 0 and self.seal_matches(rec.off):
                tgts.append(rec.span)
            rows[rec.off] = tgts
        return rows

    # --------------------------------------------------------------- writes
    def _fill(self, rec_off: int, nxt: int, p: dict) -> None:
        row = np.full(REC_FIELDS, -1, np.int64)
        row[F_NEXT] = nxt
        row[F_SPAN] = int(p["span"])
        row[F_KEY] = int(p["key"])
        row[F_PAGES] = int(p["n_pages"])
        row[F_SPAN_PAGES] = int(p["span_pages"])
        row[F_TOK] = int(p["next_tok"])
        row[F_LEASE] = int(p["lease_sbs"])
        row[F_PARENT] = int(p.get("parent", -1))
        row[F_START] = int(p.get("start_page", 0))
        row[F_FPRINT] = int(p.get("fprint", 0))
        row[F_SEAL] = record_checksum(row[f] for f in _SEALED)
        self.words[rec_off] = row

    def append(self, rec_off: int, *, key: int, span: int, n_pages: int,
               span_pages: int, next_tok: int, lease_sbs: int,
               parent: int = -1, start_page: int = 0,
               fprint: int = 0) -> None:
        """Link a freshly allocated record block at the chain head.

        Fields first (seal last within the row), head swing last — the
        durability ordering the host trie fences around; a crash between
        the two leaves the record unreachable and the sweep frees its
        block.
        """
        self.append_batch([dict(rec_off=rec_off, key=key, span=span,
                                n_pages=n_pages, span_pages=span_pages,
                                next_tok=next_tok, lease_sbs=lease_sbs,
                                parent=parent, start_page=start_page,
                                fprint=fprint)])

    def append_batch(self, payloads: list[dict]) -> None:
        """Group-commit append: link N freshly allocated record blocks as
        one chain segment with a single head swing.

        Device mirror of ``PrefixTrie._commit_new``: every record's
        fields are written first — the batch chained among itself, the
        last record pointing at the old head — and only then does
        ``head`` swing once to the first record.  A crash before the
        swing leaves the whole segment unreachable (the sweep frees all
        N blocks and their leases fall back to the roots); after it all
        N records are published.  Each payload dict carries the same
        keyword fields ``append`` takes.
        """
        if not payloads:
            return
        # partition by bucket; every record's fields land before any
        # head swings (the device analogue of the host's batched
        # ``set_roots`` swing after the shared seal fence)
        groups: dict[int, list[dict]] = {}
        for p in payloads:
            groups.setdefault(self._bucket(p["key"]), []).append(p)
        for b, grp in groups.items():
            offs = [int(p["rec_off"]) for p in grp]
            for i, p in enumerate(grp):
                nxt = offs[i + 1] if i + 1 < len(offs) else self.heads[b]
                self._fill(offs[i], nxt, p)
        for b, grp in groups.items():
            self.heads[b] = int(grp[0]["rec_off"])

    def split(self, old_off: int, m_payload: dict, x_payload: dict) -> None:
        """Replace record ``old_off`` with the pair M + X' in its chain
        position (device mirror of ``PrefixTrie.split``): M links to X',
        X' inherits the old record's next pointer, and ONE splice write
        (predecessor next-pointer or the head) swaps the pair in.  The
        old row clears only after the splice — the caller then releases
        the old record's lease and frees its block, mirroring the host's
        relink-before-free fence ordering.  Children of the old record
        re-parent via :meth:`reparent`.
        """
        old_off = int(old_off)
        m_off = int(m_payload["rec_off"])
        x_off = int(x_payload["rec_off"])
        ob = self._bucket(self.words[old_off][F_KEY])
        mb = self._bucket(m_payload["key"])
        xb = self._bucket(x_payload["key"])
        if ob == mb == xb:
            # all three share one chain (always true unbucketed): the
            # historical single-splice replacement in place
            old_next = int(self.words[old_off][F_NEXT])
            self._fill(x_off, old_next, x_payload)
            self._fill(m_off, x_off, m_payload)
            prev = self._pred_in_bucket(ob, old_off)
            if prev < 0:
                self.heads[ob] = m_off
            else:
                self.words[prev][F_NEXT] = m_off
            self.words[old_off] = -1
            return
        # the halves hash to other buckets: publish both at their own
        # bucket heads (fields before swing, X' before M so M fronts a
        # shared chain), then unlink the old record from its chain —
        # the predecessor search runs after the inserts, so a new head
        # in the old record's bucket is accounted for
        self._fill(x_off, self.heads[xb], x_payload)
        self.heads[xb] = x_off
        self._fill(m_off, self.heads[mb], m_payload)
        self.heads[mb] = m_off
        prev = self._pred_in_bucket(ob, old_off)
        old_next = int(self.words[old_off][F_NEXT])
        if prev < 0:
            self.heads[ob] = old_next
        else:
            self.words[prev][F_NEXT] = old_next
        self.words[old_off] = -1

    def _pred_in_bucket(self, b: int, target: int) -> int:
        """Chain predecessor of ``target`` in bucket ``b`` (-1 = head)."""
        prev, rec, seen = -1, self.heads[b], set()
        while rec >= 0 and rec not in seen and rec != target:
            seen.add(rec)
            prev, rec = rec, int(self.words[rec][F_NEXT])
        if rec != target:
            raise ValueError(f"split: record {target} not on the chain")
        return prev

    def reparent(self, child_off: int, new_parent: int) -> None:
        """Re-point a child record's parent field (unsealed, like host
        word 1) — used by split before the old record's block frees."""
        self.words[int(child_off)][F_PARENT] = int(new_parent)

    def remove(self, key: int) -> StoreRecord | None:
        """Unlink the record for ``key``; returns it (the caller releases
        the span lease and frees the record block *after* the unlink).
        Only the key's bucket chain is walked."""
        b = self._bucket(key)
        prev, rec, seen = -1, self.heads[b], set()
        while rec >= 0 and rec not in seen:
            seen.add(rec)
            w = self.words[rec]
            nxt = int(w[F_NEXT])
            if int(w[F_KEY]) == int(key):
                out = self._decode(rec)
                if prev < 0:
                    self.heads[b] = nxt
                else:
                    self.words[prev][F_NEXT] = nxt
                self.words[rec] = -1
                return out
            prev, rec = rec, nxt
        return None

    def prune(self, live_mask) -> list[StoreRecord]:
        """Drop records whose blocks the sweep did not mark (their root
        swing never became durable) or whose seal failed; returns the
        surviving records.

        ``live_mask`` is ``jax_recovery.live_record_mask(cfg, marked,
        [r.off for r in walk()], seal_ok=...)`` — aligned with ``walk``
        order, i.e. bucket by bucket.  By construction an unreachable
        record can only sit at a chain head, but pruning the whole walk
        keeps a corrupt image from resurrecting stale entries.
        Surviving records whose parent was pruned keep their (now
        dangling) parent field; the engine's recoverability pass
        re-parents or drops them.
        """
        live = np.asarray(live_mask, bool)
        keep_all: list[StoreRecord] = []
        i = 0
        for b in range(self.n_buckets):
            recs = self._walk_bucket(b)
            flags = live[i:i + len(recs)]
            i += len(recs)
            keep = [r for r, ok in zip(recs, flags) if ok]
            for r, ok in zip(recs, flags):
                if not ok:
                    self.words[r.off] = -1
            self.heads[b] = keep[0].off if keep else -1
            for a, c in zip(keep, keep[1:]):
                self.words[a.off][F_NEXT] = c.off
            if keep:
                self.words[keep[-1].off][F_NEXT] = -1
            keep_all.extend(keep)
        return keep_all
