"""Serving-side prefix-trie cache: longest-prefix-match over published
prompts, replacing the flat exact-match ``PrefixCache`` in the engine
path.

Device mirror of the host trie (``core.prefix_trie``) with the same
node semantics: a node covers pages ``[start_page, end_page)`` of some
published prompt, its ``span`` backs the *entire* prefix ``[0,
end_page)`` at identity page offsets (the publisher's own reservation),
and its lease covers ``ceil(end_page * page / sb_words)`` superblocks —
so any hit at any node boundary leases exactly ONE span, and
``LaneStates.shared_spans`` keeps its single-span tuple shape.

The flat dict API (``entries`` / ``tokens`` / ``page_refs`` / ``lookup``
/ ``insert`` / ``add_page_ref`` / ``clear``) is preserved verbatim — an
exact whole-prompt hit is just the trie hit whose boundary equals the
prompt — and the trie adds:

  * :meth:`match_partial` — longest-prefix match at page granularity: a
    request matching ``k`` pages of a longer published prompt leases
    only those ``k`` pages' superblocks and decodes its suffix on its
    own lazily-allocated pages;
  * transient :class:`CacheNode` shape mirroring the durable
    ``PrefixStore`` records (``parent`` / ``start_page`` / ``rec_off``),
    rebuilt from the surviving records after ``crash_and_recover``.

Nodes published this process carry per-page cumulative hashes
(``page_keys``) and the exact prefix tokens, enabling mid-edge partial
matches and splits.  Recovered nodes carry neither — they match
all-or-nothing at node granularity, by full cumulative key *plus* the
durable token fingerprint (``F_FPRINT``), so even a recovered entry
verifies tokens cheaply before serving (the fix for the PR-5
"recovered entries match by hash alone" collision residual).
"""

from __future__ import annotations

import dataclasses

from .. import obs
from ..core.prefix_index import hash_tokens
from ..core.prefix_trie import fingerprint, page_hashes

_M32 = 0xFFFFFFFF

# Prefix-cache metrics (cached at import; see repro.obs conventions).
# ``trie.hit_depth_pages`` is the distribution of matched whole pages on
# partial hits — the depth a request actually leases.
_OBS_EXACT_HIT = obs.counter("trie.exact_hit")
_OBS_EXACT_MISS = obs.counter("trie.exact_miss")
_OBS_PARTIAL_HIT = obs.counter("trie.partial_hit")
_OBS_PARTIAL_MISS = obs.counter("trie.partial_miss")
_OBS_HIT_DEPTH = obs.histogram("trie.hit_depth_pages")


@dataclasses.dataclass
class CacheNode:
    """Transient mirror of one durable prefix-store record."""
    key: int                     # cumulative 48-bit hash of [0, end_page)
    span: int                    # span head offset (backs [0, end_page))
    start_page: int
    end_page: int
    lease_sbs: int
    next_tok: int                # sampled continuation at the boundary
    fprint: int                  # durable token fingerprint
    rec_off: int = -1            # durable record block (-1: queued only)
    parent: int = -1             # parent node's key (-1 = root child)
    children: list = dataclasses.field(default_factory=list)  # child keys
    page_keys: list | None = None    # cum. hash per edge page (in-process)
    tokens: tuple | None = None      # full prefix tokens (in-process)


class PrefixTrieCache:
    """Transient trie + flat-compat dicts for one serving engine."""

    def __init__(self, page: int):
        self.page = int(page)
        self.entries: dict[int, tuple] = {}      # key -> flat cache entry
        self.tokens: dict[int, tuple] = {}       # key -> exact prompt tokens
        self.page_refs: dict[int, int] = {}      # page -> sharer count
        self.nodes: dict[int, CacheNode] = {}    # key -> trie node
        self.roots: list[int] = []               # keys with start_page == 0

    # ------------------------------------------------------------ flat API
    def lookup(self, prompt):
        """Exact whole-prompt hit (flat semantics).  In-process entries
        verify the exact token tuple; recovered span entries verify the
        durable token fingerprint — hash alone never serves."""
        key = hash_tokens(prompt)
        hit = self.entries.get(key)
        if hit is None:
            _OBS_EXACT_MISS.inc()
            return None
        known = self.tokens.get(key)
        if known is not None:
            if known != tuple(prompt):
                _OBS_EXACT_MISS.inc()
                return None
            _OBS_EXACT_HIT.inc()
            return hit
        node = self.nodes.get(key)
        if node is not None and not self._fp_ok(node, prompt):
            _OBS_EXACT_MISS.inc()
            return None
        _OBS_EXACT_HIT.inc()
        return hit

    def insert(self, key: int, entry: tuple, tokens=None) -> None:
        self.entries[key] = entry
        if tokens is not None:
            self.tokens[key] = tuple(tokens)

    def add_page_ref(self, p: int) -> None:
        self.page_refs[p] = self.page_refs.get(p, 1) + 1

    def clear(self) -> None:
        """Forget entries, tokens and trie shape; ``page_refs`` is decode
        state, not cache state — untouched (same as the flat cache)."""
        self.entries.clear()
        self.tokens.clear()
        self.nodes.clear()
        self.roots.clear()

    # ------------------------------------------------------------ trie API
    def _fp_ok(self, node: CacheNode, tokens) -> bool:
        pg = self.page
        return node.fprint == fingerprint(tokens[node.start_page * pg],
                                          tokens[node.end_page * pg - 1])

    def match_partial(self, prompt) -> tuple[CacheNode | None, int]:
        """Longest-prefix match: ``(node, pages)`` where ``pages`` whole
        pages of ``prompt`` are covered and ``node`` contains the last
        matched page.  ``pages < node.end_page`` means the match ends
        mid-edge of an in-process node (the engine splits there);
        recovered nodes only ever match at their full boundary."""
        prompt = tuple(int(t) for t in prompt)
        n = len(prompt) // self.page
        if n == 0:
            _OBS_PARTIAL_MISS.inc()
            return None, 0
        hs = page_hashes(prompt, self.page)
        best: CacheNode | None = None
        depth = 0
        child_keys = self.roots
        while depth < n:
            stepped = False
            for ck in child_keys:
                c = self.nodes.get(ck)
                if c is None or c.start_page != depth:
                    continue
                if c.page_keys is not None:
                    edge = c.end_page - c.start_page
                    i = 0
                    while (i < edge and depth + i < n
                           and c.page_keys[i] == hs[depth + i]):
                        i += 1
                    if i == 0:
                        continue
                    a, b = depth * self.page, (depth + i) * self.page
                    if prompt[a:b] != c.tokens[a:b]:
                        continue          # page-hash collision reads as miss
                    if i < edge:
                        _OBS_PARTIAL_HIT.inc()
                        _OBS_HIT_DEPTH.observe(depth + i)
                        return c, depth + i
                    best, depth, stepped = c, depth + i, True
                    break
                if (n >= c.end_page and hs[c.end_page - 1] == c.key
                        and self._fp_ok(c, prompt)):
                    best, depth, stepped = c, c.end_page, True
                    break
            if not stepped:
                break
            child_keys = best.children
        if best is None:
            _OBS_PARTIAL_MISS.inc()
        else:
            _OBS_PARTIAL_HIT.inc()
            _OBS_HIT_DEPTH.observe(depth)
        return best, depth

    def deepest_boundary(self, node: CacheNode | None, k: int
                         ) -> tuple[CacheNode | None, int]:
        """Clamp a mid-edge match to the deepest full-node boundary ≤ k
        (used when a split cannot happen — e.g. no record blocks)."""
        while node is not None and node.end_page > k:
            node = self.nodes.get(node.parent) if node.parent >= 0 else None
        return node, (node.end_page if node is not None else 0)

    def insert_node(self, node: CacheNode) -> None:
        self.nodes[node.key] = node
        if node.parent >= 0 and node.parent in self.nodes:
            sibs = self.nodes[node.parent].children
            if node.key not in sibs:
                sibs.append(node.key)
        else:
            node.parent = -1
            if node.key not in self.roots:
                self.roots.append(node.key)

    def set_rec(self, key: int, rec_off: int) -> None:
        node = self.nodes.get(key)
        if node is not None:
            node.rec_off = int(rec_off)

    def split_transient(self, node: CacheNode, k: int) -> CacheNode:
        """Transient half of a split: node X ``[s, e)`` becomes M
        ``[s, k)`` (returned) with X' ``[k, e)`` as its only initial
        child; X's children re-parent to X'.  The caller mirrors the
        durable half (``PrefixStore.split``) and the lease churn."""
        assert node.page_keys is not None and node.tokens is not None
        pg = self.page
        cut = k - node.start_page
        m = CacheNode(
            key=node.page_keys[cut - 1], span=node.span,
            start_page=node.start_page, end_page=k,
            lease_sbs=0,                    # caller fills in
            next_tok=int(node.tokens[k * pg]),
            fprint=fingerprint(node.tokens[node.start_page * pg],
                               node.tokens[k * pg - 1]),
            parent=node.parent,
            tokens=node.tokens[:k * pg],
            page_keys=node.page_keys[:cut])
        # X' keeps its key (same full prefix) and durable lease length
        old_key = node.key
        node.start_page = k
        node.fprint = fingerprint(node.tokens[k * pg],
                                  node.tokens[node.end_page * pg - 1])
        node.page_keys = node.page_keys[cut:]
        node.parent = m.key
        m.children = [old_key]
        if m.parent >= 0 and m.parent in self.nodes:
            sibs = self.nodes[m.parent].children
            sibs[sibs.index(old_key)] = m.key
        else:
            self.roots[self.roots.index(old_key)] = m.key
        self.nodes[m.key] = m
        return m

    def rebuild_from_records(self, records) -> None:
        """Two-pass transient rebuild from surviving ``StoreRecord``s
        (post-crash): create every node token-less (all-or-nothing
        matching), then link parents by record offset with the same
        coverage fallback as the host ``PrefixTrie._rebuild``."""
        self.nodes.clear()
        self.roots.clear()
        by_off = {int(r.off): r for r in records}
        key_of = {off: int(r.key) for off, r in by_off.items()}
        for off, r in by_off.items():
            self.nodes[int(r.key)] = CacheNode(
                key=int(r.key), span=int(r.span), start_page=int(r.start_page),
                end_page=int(r.n_pages), lease_sbs=int(r.lease_sbs),
                next_tok=int(r.next_tok), fprint=int(r.fprint),
                rec_off=off)
        for off, r in by_off.items():
            nd = self.nodes[int(r.key)]
            par = int(r.parent)
            if (par in by_off and par != off
                    and by_off[par].n_pages == r.start_page):
                nd.parent = key_of[par]
            elif int(r.start_page) > 0:
                cover = next((o for o, q in by_off.items()
                              if q.n_pages == r.start_page and o != off),
                             None)
                nd.parent = key_of[cover] if cover is not None else -1
                if nd.parent < 0:
                    continue              # unservable orphan: unattached
            if nd.parent >= 0:
                self.nodes[nd.parent].children.append(nd.key)
            else:
                self.roots.append(nd.key)
