"""Admission control, continuous batching, and the group-commit queue.

The engine (``serving.engine``) is the mechanism: lanes, pages, spans,
one batched decode step.  This module is the policy that turns it into a
serving loop:

  * **admission** — ``submit`` claims a free lane immediately or parks
    the request in a bounded wait queue; a full queue raises the typed
    :class:`EngineBusy` instead of the old bare ``IndexError`` from
    ``free_lanes.pop()``;
  * **continuous batching** — every ``step`` first admits waiting
    arrivals onto lanes freed by finished requests, then runs one
    batched decode step for all active lanes, then collects finishes
    (publishing their prefixes when requested) — arrivals and exits
    interleave with decode instead of draining the whole batch;
  * **group commit** — span-path publications park their durable record
    append (``ServingEngine.queue_publish``) and the scheduler flushes
    them in batches (``ServingEngine.flush_publishes``): N records land
    behind ONE chained append and ONE root swing, the device mirror of
    ``PrefixIndex.publish_batch``, so publish persistence amortizes
    across requests instead of costing one fence pair each.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque

from .. import obs


class EngineBusy(RuntimeError):
    """Admission failed: every lane is busy (and, from ``submit``, the
    wait queue is full).  Typed so callers can shed load or retry
    instead of pattern-matching a bare ``IndexError``."""


# Serving-path metrics (cached at import; see repro.obs conventions).
_OBS_REJECTS = obs.counter("sched.rejects")
_OBS_PARK_RETRY = obs.counter("sched.park_retries")
_OBS_QUEUE_DEPTH = obs.gauge("sched.queue_depth")
_OBS_QUEUE_DEPTH_H = obs.histogram("sched.queue_depth_at_submit")
_OBS_TTFT = obs.histogram("serve.ttft_seconds")
_OBS_LATENCY = obs.histogram("serve.latency_seconds")


@dataclasses.dataclass
class PendingPublish:
    """One span-path publication parked in the group-commit queue.

    The transient half already happened at queue time — cache entry
    inserted, trie node attached, prefix lease acquired — so sharers can
    hit immediately; only the durable record append waits for the batch
    flush, exactly like ``PrefixTrie._commit_new`` chains records behind
    one fence and one root swing.

    The trie fields (``start_page`` / ``parent_key`` / ``fprint``)
    default to the flat depth-1 shape: the node covers ``[0, n_pages)``
    under the root.  ``parent_key`` is the parent *node key* — the
    record offset is resolved at flush time (the parent may itself still
    be parked earlier in the queue)."""
    key: int
    span: int
    n_pages: int
    span_pages: int
    next_tok: int
    lease_sbs: int
    start_page: int = 0
    parent_key: int = -1
    fprint: int = 0


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list
    share_prefix: bool
    max_new_tokens: int | None
    publish: bool
    lane: int | None = None
    session: object = None
    t_submit: float = 0.0        # perf_counter at submit (latency metrics)
    t_first: float | None = None  # perf_counter at first emitted token


class Scheduler:
    """Continuous-batching driver over one :class:`ServingEngine`.

    ``max_waiting`` bounds the wait queue (admission control);
    ``publish_every`` is the group-commit cadence — parked publishes
    flush every that-many steps, or sooner when a full batch
    (``engine.publish_capacity``) accumulates.
    """

    def __init__(self, engine, *, max_waiting: int = 64,
                 publish_every: int = 4):
        self.engine = engine
        self.max_waiting = max_waiting
        self.publish_every = max(1, publish_every)
        self.waiting: deque[Request] = deque()
        self.active: dict[int, Request] = {}     # lane -> request
        self.results: dict[int, list] = {}       # rid -> final tokens
        self._next_rid = 0
        self._steps = 0

    # ------------------------------------------------------------ admission
    def submit(self, prompt, *, share_prefix: bool = False,
               max_new_tokens: int | None = None,
               publish: bool = False) -> int:
        """Admit now if a lane is free, else enqueue; raises
        :class:`EngineBusy` when the wait queue is full too."""
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid, list(prompt), share_prefix, max_new_tokens,
                      publish, t_submit=time.perf_counter())
        if not self._admit(req):
            if len(self.waiting) >= self.max_waiting:
                _OBS_REJECTS.inc()
                raise EngineBusy(
                    f"all {self.engine.lanes} lanes busy and the wait "
                    f"queue is full ({self.max_waiting})")
            self.waiting.append(req)
        _OBS_QUEUE_DEPTH.set(len(self.waiting))
        _OBS_QUEUE_DEPTH_H.observe(len(self.waiting))
        return rid

    def _admit(self, req: Request) -> bool:
        eng = self.engine
        if not eng.free_lanes:
            return False
        try:
            req.lane = eng.add_request(req.prompt,
                                       share_prefix=req.share_prefix)
        except EngineBusy:
            return False
        except MemoryError:
            # span reservation failed; the engine neutralized the lane.
            # Spans free as other requests finish, so park and retry —
            # unless nothing is running, in which case it can never fit.
            if not self.active:
                raise
            _OBS_PARK_RETRY.inc()
            return False
        req.session = eng.sessions[req.lane]
        self.active[req.lane] = req
        return True

    def _admit_waiting(self) -> None:
        while self.waiting and self.engine.free_lanes:
            if not self._admit(self.waiting[0]):
                break
            self.waiting.popleft()

    # ----------------------------------------------------------------- loop
    def step(self) -> dict[int, int]:
        """One continuous-batching tick: admit → decode → collect
        finishes → maybe flush the publish queue.  Returns
        ``rid -> emitted token`` for lanes that sampled this step."""
        eng = self.engine
        self._admit_waiting()
        emitted = eng.step()
        self._steps += 1
        out: dict[int, int] = {}
        for lane, req in list(self.active.items()):
            if lane in emitted:
                out[req.rid] = emitted[lane]
                if req.t_first is None:
                    req.t_first = time.perf_counter()
                    _OBS_TTFT.observe(req.t_first - req.t_submit)
            sess = eng.sessions.get(lane)
            if sess is None or sess.done:
                self._complete(lane, req)        # engine auto-finished it
            elif (req.max_new_tokens is not None
                    and len(sess.tokens)
                    >= len(req.prompt) + req.max_new_tokens):
                if req.publish:
                    eng.queue_publish(lane)
                eng.finish(lane)
                self._complete(lane, req)
        if (eng.pending_publishes >= eng.publish_capacity
                or (eng.pending_publishes
                    and self._steps % self.publish_every == 0)):
            eng.flush_publishes()
        return out

    def _complete(self, lane: int, req: Request) -> None:
        del self.active[lane]
        self.results[req.rid] = list(req.session.tokens)
        _OBS_LATENCY.observe(time.perf_counter() - req.t_submit)
        _OBS_QUEUE_DEPTH.set(len(self.waiting))

    def drain(self, max_steps: int = 100_000) -> dict[int, list]:
        """Step until every submitted request completes, then flush any
        parked publishes; returns ``rid -> final tokens``."""
        steps = 0
        while (self.active or self.waiting) and steps < max_steps:
            self.step()
            steps += 1
        if self.active or self.waiting:
            raise RuntimeError("scheduler drain did not converge")
        self.engine.flush_publishes()
        return self.results
