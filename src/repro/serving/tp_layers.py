"""Tensor-parallel decode layers (run inside ``jax.shard_map``).

Decode reads K/V through the paged arena, so the natural distribution is

  * batch over the ``data`` (+``pod``) axes — every sequence, its block
    table and its pages live on exactly one data shard (page ids are
    shard-local: one allocator instance per data shard, mirroring the
    paper's multi-heap/process model);
  * within a data shard, the ``model`` axis shards *page slots*: each of
    the tp chips holds page_size/tp slots of every page.  Attention
    computes per-shard partial softmax statistics and merges them with a
    pmax + psum — distributed FlashDecoding.  This works for any number
    of KV heads (GQA kv=1 included), which head-sharding cannot do;
  * weights are row/column-parallel over ``model`` (Megatron-style), so
    each layer costs a handful of tiny [B, ·] psums.

All functions here take *local* shards; ``axis`` is the model axis name.
They are exercised at tp=1 by the CPU tests and at tp=16 by the dry-run.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from ..layers.rope import apply_rope
from ..runtime import axis_size

NEG_INF = -1e30


def _tp(axis):
    return axis_size(axis)


def _idx(axis):
    return lax.axis_index(axis)


def _xslice(x, axis):
    """Local slice of a model-replicated activation along its last dim."""
    tp = _tp(axis)
    d = x.shape[-1] // tp
    return lax.dynamic_slice_in_dim(x, _idx(axis) * d, d, x.ndim - 1)


# ---------------------------------------------------------------------------
# embedding / logits (vocab-parallel)
# ---------------------------------------------------------------------------
def embed_tp(table_loc, tokens, axis, sharded: bool = True):
    """Vocab-sharded embedding gather + psum (plain gather if replicated)."""
    if not sharded:
        return table_loc[tokens]
    v_loc = table_loc.shape[0]
    off = _idx(axis) * v_loc
    local = tokens - off
    ok = (local >= 0) & (local < v_loc)
    rows = table_loc[jnp.clip(local, 0, v_loc - 1)]
    return lax.psum(jnp.where(ok[:, None], rows, 0), axis)


def logits_tp(table_loc, x, axis):
    """Vocab-sharded logits [B, V_loc] (caller merges/samples)."""
    return jnp.einsum("bd,vd->bv", x, table_loc,
                      preferred_element_type=jnp.float32)


def greedy_sample_tp(logits_loc, axis, sharded: bool = True):
    """Greedy token from vocab-sharded logits via local argmax + gather."""
    if not sharded:
        return jnp.argmax(logits_loc, axis=1).astype(jnp.int32)
    v_loc = logits_loc.shape[1]
    loc_max = jnp.max(logits_loc, axis=1)
    loc_arg = jnp.argmax(logits_loc, axis=1) + _idx(axis) * v_loc
    allm = lax.all_gather(loc_max, axis)              # [tp, B]
    alla = lax.all_gather(loc_arg, axis)
    winner = jnp.argmax(allm, axis=0)                 # [B]
    return jnp.take_along_axis(alla, winner[None], axis=0)[0].astype(jnp.int32)


# ---------------------------------------------------------------------------
# attention decode: slot-sharded paged KV + distributed softmax merge
# ---------------------------------------------------------------------------
def dp_linear_index(dp_axes) -> jax.Array:
    """Flattened index over (possibly several) data axes."""
    out = jnp.int32(0)
    for a in dp_axes:
        out = out * axis_size(a) + lax.axis_index(a)
    return out


def attn_decode_tp(cfg, p, x, pos, arena_k, arena_v, block_table, kv_pos,
                   *, window: int = 0, axis: str = "model",
                   seq_dp_axes: tuple = (), scales=None):
    """One-token paged attention.

    x:           [B, D] replicated over ``axis``
    arena_k/v:   [pages_loc, page_loc, K, dh] local slot shard (+1 dump page)
    block_table: [B, P_loc] shard-local page ids (-1 unused)
    kv_pos:      [B, P_loc, page_loc] position per local slot (-1 invalid)

    When ``seq_dp_axes`` is non-empty, one sequence's *pages* are sharded
    across those data axes (sequence parallelism for batch < dp, e.g. the
    long_500k shape) and the softmax merge spans (dp_axes + model).

    Returns (y [B, D], arena_k', arena_v', kv_pos').
    """
    B, D = x.shape
    h, kvh, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    g = h // kvh
    tp = _tp(axis)
    page_loc = arena_k.shape[1]
    page = page_loc * tp
    P = block_table.shape[1]
    dump = arena_k.shape[0] - 1
    merge_axes = tuple(seq_dp_axes) + (axis,)

    # fused row-parallel qkv: one psum
    xs = _xslice(x, axis)
    qp = jnp.einsum("bd,de->be", xs, p["wq"])
    kp = jnp.einsum("bd,de->be", xs, p["wk"])
    vp = jnp.einsum("bd,de->be", xs, p["wv"])
    qkv = lax.psum(jnp.concatenate([qp, kp, vp], axis=-1), axis)
    q, k_new, v_new = jnp.split(qkv, [h * dh, h * dh + kvh * dh], axis=-1)
    if cfg.qkv_bias:
        q, k_new, v_new = q + p["bq"], k_new + p["bk"], v_new + p["bv"]
    q = q.reshape(B, h, dh)
    k_new = k_new.reshape(B, kvh, dh)
    v_new = v_new.reshape(B, kvh, dh)
    if cfg.use_rope:
        q = apply_rope(q[:, None], pos[:, None], cfg.rope_theta)[:, 0]
        k_new = apply_rope(k_new[:, None], pos[:, None], cfg.rope_theta)[:, 0]

    # scatter the new token's k/v into the (dp, slot) shard that owns it
    slot = pos % page
    mine = (slot // page_loc) == _idx(axis)
    gpage = pos // page                       # global page index of the token
    if seq_dp_axes:
        dpi = dp_linear_index(seq_dp_axes)
        mine = mine & ((gpage // P) == dpi)
        lpage = gpage % P
    else:
        lpage = gpage
    pid = jnp.take_along_axis(block_table, lpage[:, None], axis=1)[:, 0]
    pid_w = jnp.where(mine & (pid >= 0), pid, dump)
    lslot = jnp.where(mine, slot % page_loc, 0)
    b_ix = jnp.arange(B)
    if scales is not None:
        # int8 KV (KIVI-style per-slot-per-head scales): quantize the new
        # token's k/v, store int8 + fp32 scale; dequantize on gather
        ks, vs = scales
        k_s = jnp.max(jnp.abs(k_new.astype(jnp.float32)), -1) / 127.0 + 1e-9
        v_s = jnp.max(jnp.abs(v_new.astype(jnp.float32)), -1) / 127.0 + 1e-9
        kq = jnp.clip(jnp.round(k_new.astype(jnp.float32)
                                / k_s[..., None]), -127, 127).astype(jnp.int8)
        vq = jnp.clip(jnp.round(v_new.astype(jnp.float32)
                                / v_s[..., None]), -127, 127).astype(jnp.int8)
        arena_k = arena_k.at[pid_w, lslot].set(kq)
        arena_v = arena_v.at[pid_w, lslot].set(vq)
        ks = ks.at[pid_w, lslot].set(k_s)
        vs = vs.at[pid_w, lslot].set(v_s)
    else:
        arena_k = arena_k.at[pid_w, lslot].set(k_new.astype(arena_k.dtype))
        arena_v = arena_v.at[pid_w, lslot].set(v_new.astype(arena_v.dtype))
    kv_pos = kv_pos.at[b_ix, lpage, lslot].set(
        jnp.where(mine & (pid >= 0), pos, kv_pos[b_ix, lpage, lslot]))

    # local paged gather + partial softmax
    bt = jnp.where(block_table < 0, dump, block_table)
    kloc = arena_k[bt].reshape(B, P * page_loc, kvh, dh)
    vloc = arena_v[bt].reshape(B, P * page_loc, kvh, dh)
    if scales is not None:
        ksl = ks[bt].reshape(B, P * page_loc, kvh)[..., None]
        vsl = vs[bt].reshape(B, P * page_loc, kvh)[..., None]
        kloc = (kloc.astype(jnp.float32) * ksl).astype(x.dtype)
        vloc = (vloc.astype(jnp.float32) * vsl).astype(x.dtype)
    qg = q.reshape(B, kvh, g, dh)
    s = jnp.einsum("bkgd,btkd->bkgt", qg, kloc,
                   preferred_element_type=jnp.float32) * (dh ** -0.5)
    kvp = kv_pos.reshape(B, P * page_loc)
    valid = (kvp >= 0) & (kvp <= pos[:, None])
    if window:
        valid = valid & (kvp > (pos[:, None] - window))
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1)                                  # [B,K,G]
    M = lax.pmax(m, merge_axes)
    e = jnp.exp(s - M[..., None])
    l = jnp.sum(e, axis=-1)
    acc = jnp.einsum("bkgt,btkd->bkgd", e.astype(vloc.dtype), vloc)
    # merge partial (l, acc) across the KV shards in one psum
    merged = lax.psum(
        jnp.concatenate([acc.astype(jnp.float32),
                         l[..., None]], axis=-1), merge_axes)
    out = merged[..., :dh] / jnp.maximum(merged[..., dh:], 1e-20)
    out = out.reshape(B, h * dh).astype(x.dtype)

    # row-parallel output projection
    os = _xslice(out, axis)
    wo_loc = p["wo"]
    y = lax.psum(jnp.einsum("be,ed->bd", os, wo_loc), axis)
    new_scales = (ks, vs) if scales is not None else None
    return y, arena_k, arena_v, kv_pos, new_scales


# ---------------------------------------------------------------------------
# ffn
# ---------------------------------------------------------------------------
def mlp_decode_tp(cfg, p, x, axis):
    h = jnp.einsum("bd,df->bf", x, p["wi"])
    if cfg.mlp == "swiglu":
        gg = jnp.einsum("bd,df->bf", x, p["wg"])
        h = jax.nn.silu(gg.astype(jnp.float32)).astype(x.dtype) * h
    elif cfg.mlp == "squared_relu":
        h = jnp.square(jax.nn.relu(h))
    else:
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return lax.psum(jnp.einsum("bf,fd->bd", h, p["wo"]), axis)


def moe_decode_tp(cfg, p, x, axis):
    """Expert-parallel decode: every local expert runs densely over the
    (small) token batch; gates mask the combine; one psum merges shards."""
    B = x.shape[0]
    e_loc = p["wi"].shape[0]
    e_real = p["router"].shape[1]
    logits = jnp.einsum("bd,de->be", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert = lax.top_k(probs, cfg.top_k)
    gate = gate / jnp.clip(gate.sum(-1, keepdims=True), 1e-9)
    gate_full = jnp.zeros((B, e_real), jnp.float32)
    gate_full = gate_full.at[jnp.arange(B)[:, None], expert].add(gate)
    # pad gates out to the padded expert count, slice this shard's experts
    gate_pad = jnp.pad(gate_full, ((0, 0), (0, e_loc * _tp(axis) - e_real)))
    gl = lax.dynamic_slice_in_dim(gate_pad, _idx(axis) * e_loc, e_loc, 1)
    h = jnp.einsum("bd,edf->ebf", x, p["wi"])
    if cfg.mlp == "swiglu":
        gg = jnp.einsum("bd,edf->ebf", x, p["wg"])
        h = jax.nn.silu(gg.astype(jnp.float32)).astype(x.dtype) * h
    else:
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    y = jnp.einsum("ebf,efd->ebd", h, p["wo"])
    y = jnp.einsum("ebd,be->bd", y.astype(jnp.float32), gl)
    return lax.psum(y, axis).astype(x.dtype)


# ---------------------------------------------------------------------------
# recurrent mixers
# ---------------------------------------------------------------------------
def mamba2_decode_tp(cfg, p, x, state, axis):
    """Head-sharded single-token SSD update (B/C replicated)."""
    from ..layers import ssd as ssd_lib
    B, D = x.shape
    N = cfg.ssm_state
    P = cfg.ssm_head_dim
    z = jnp.einsum("bd,de->be", x, p["in_z"])            # [B, Di_loc]
    xs = jnp.einsum("bd,de->be", x, p["in_x"]).astype(jnp.float32)
    bc = jnp.einsum("bd,de->be", x, p["in_bc"]).astype(jnp.float32)
    dt = jnp.einsum("bd,de->be", x, p["in_dt"])          # [B, H_loc]
    hist_x = jnp.concatenate([state["conv_x"], xs[:, None, :]], axis=1)
    hist_bc = jnp.concatenate([state["conv_bc"], bc[:, None, :]], axis=1)
    cx = jnp.einsum("bwc,wc->bc", hist_x, p["conv_x_w"].astype(jnp.float32))
    cx = jax.nn.silu(cx + p["conv_x_b"].astype(jnp.float32))
    cbc = jnp.einsum("bwc,wc->bc", hist_bc, p["conv_bc_w"].astype(jnp.float32))
    cbc = jax.nn.silu(cbc + p["conv_bc_b"].astype(jnp.float32))
    Bm, Cm = cbc[:, :N], cbc[:, N:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = jnp.exp(dt * -jnp.exp(p["A_log"]))
    h_loc = cx.shape[1] // P
    xh = cx.reshape(B, h_loc, P)
    hidden = (state["h"] * a[:, :, None, None]
              + jnp.einsum("bn,bhp,bh->bhpn", Bm, xh, dt))
    y = jnp.einsum("bn,bhpn->bhp", Cm, hidden) + p["D"][None, :, None] * xh
    y = y.reshape(B, -1) * jax.nn.silu(z.astype(jnp.float32))
    # distributed gated RMSNorm: global mean of squares over d_inner
    di = y.shape[1] * _tp(axis)
    ssq = lax.psum(jnp.sum(y * y, axis=-1, keepdims=True), axis) / di
    y = y * lax.rsqrt(ssq + 1e-6) * p["norm_w"]
    out = lax.psum(jnp.einsum("be,ed->bd", y.astype(x.dtype), p["out_proj"]),
                   axis)
    return out, {"h": hidden, "conv_x": hist_x[:, 1:], "conv_bc": hist_bc[:, 1:]}


def rglru_decode_tp(cfg, p, x, state, axis):
    """Width-sharded single-token RG-LRU update."""
    _C = 8.0
    xr = jnp.einsum("bd,dw->bw", x, p["in_x"]).astype(jnp.float32)  # [B,W_loc]
    xg = jnp.einsum("bd,dw->bw", x, p["in_g"])
    hist = jnp.concatenate([state["conv"], xr[:, None, :]], axis=1)
    conv = jnp.einsum("bwc,wc->bc", hist, p["conv_w"].astype(jnp.float32))
    conv = conv + p["conv_b"].astype(jnp.float32)
    # row-parallel gate projections: psum yields the full pre-activation,
    # then each shard keeps its local width slice
    ga = jnp.einsum("bw,wv->bv", conv.astype(x.dtype), p["wa"])
    gi = jnp.einsum("bw,wv->bv", conv.astype(x.dtype), p["wx"])
    gfull = lax.psum(jnp.concatenate([ga, gi], axis=-1), axis)
    W = gfull.shape[-1] // 2
    w_loc = p["in_x"].shape[1]
    off = _idx(axis) * w_loc
    r = jax.nn.sigmoid(lax.dynamic_slice_in_dim(
        gfull[:, :W], off, w_loc, 1).astype(jnp.float32))
    i = jax.nn.sigmoid(lax.dynamic_slice_in_dim(
        gfull[:, W:], off, w_loc, 1).astype(jnp.float32))
    a = jnp.exp(-_C * r * jax.nn.softplus(p["lam"]))
    b = jnp.sqrt(jnp.clip(1.0 - a * a, 1e-12)) * (i * conv)
    h = a * state["h"] + b
    y = h * jax.nn.gelu(xg.astype(jnp.float32))
    out = lax.psum(jnp.einsum("bw,wd->bd", y.astype(x.dtype), p["out"]), axis)
    return out, {"h": h, "conv": hist[:, 1:]}
