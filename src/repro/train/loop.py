"""Fault-tolerant training driver.

Wraps ``make_train_step`` with the production concerns:
  * recoverable checkpointing on a Ralloc persistent heap (crash at any
    point ⇒ restart resumes from the last *committed* manifest root;
    half-written checkpoints are GC'd, never read);
  * automatic restart-from-checkpoint on step failure;
  * straggler watchdog: a step exceeding ``straggler_factor`` × the
    rolling median is logged and counted (on a real multi-host fleet the
    same hook triggers scale-down / hot-spare swap — here single-host);
  * elastic rescale: ``restore_onto`` re-shards a checkpoint onto a new
    mesh (arrays are stored unsharded + position-independent, so any
    mesh works — see examples/elastic_rescale.py).
"""

from __future__ import annotations

import statistics
import time

import jax
import numpy as np

from ..checkpoint.manager import CheckpointManager
from ..models import transformer as T
from ..models.config import ModelConfig
from .optimizer import AdamWConfig, init_opt_state
from .step import make_train_step


class Trainer:
    def __init__(self, cfg: ModelConfig, opt_cfg: AdamWConfig, *,
                 mesh=None, ckpt: CheckpointManager | None = None,
                 ckpt_every: int = 50, microbatches: int = 1,
                 compressor=None, straggler_factor: float = 3.0,
                 seed: int = 0):
        self.cfg = cfg
        self.opt_cfg = opt_cfg
        self.mesh = mesh
        self.ckpt = ckpt
        self.ckpt_every = ckpt_every
        self.straggler_factor = straggler_factor
        self.step_fn = jax.jit(make_train_step(
            cfg, opt_cfg, microbatches=microbatches, compressor=compressor,
            mesh=mesh))
        self.params = T.init_params(cfg, jax.random.PRNGKey(seed))
        self.opt = init_opt_state(self.params)
        self.start_step = 0
        self.step_times: list[float] = []
        self.straggler_events = 0
        if ckpt is not None:
            restored, step = ckpt.load_latest({"p": self.params,
                                               "o_m": self.opt["m"],
                                               "o_v": self.opt["v"]})
            if restored is not None:
                self.params = jax.tree.map(jax.numpy.asarray, restored["p"])
                self.opt["m"] = jax.tree.map(jax.numpy.asarray,
                                             restored["o_m"])
                self.opt["v"] = jax.tree.map(jax.numpy.asarray,
                                             restored["o_v"])
                self.opt["step"] = jax.numpy.int32(step)
                self.start_step = step

    def _maybe_checkpoint(self, step: int) -> None:
        if self.ckpt is not None and step % self.ckpt_every == 0 and step:
            self.ckpt.save({"p": self.params, "o_m": self.opt["m"],
                            "o_v": self.opt["v"]}, step=step)

    def run(self, batches, steps: int, log_every: int = 10):
        history = []
        step = self.start_step
        while step < steps:
            batch = {k: jax.numpy.asarray(v)
                     for k, v in batches.batch_at(step).items()}
            t0 = time.perf_counter()
            try:
                self.params, self.opt, metrics = self.step_fn(
                    self.params, self.opt, batch)
                loss = float(metrics["loss"])
            except Exception as e:                      # fault tolerance
                if self.ckpt is None:
                    raise
                print(f"[trainer] step {step} failed ({e!r}); "
                      f"restoring last checkpoint")
                self.__init__(self.cfg, self.opt_cfg, mesh=self.mesh,
                              ckpt=self.ckpt, ckpt_every=self.ckpt_every)
                step = self.start_step
                continue
            dt = time.perf_counter() - t0
            if len(self.step_times) >= 5:
                med = statistics.median(self.step_times[-20:])
                if dt > self.straggler_factor * med:
                    self.straggler_events += 1
                    print(f"[trainer] straggler: step {step} took "
                          f"{dt:.2f}s (median {med:.2f}s)")
            self.step_times.append(dt)
            history.append(loss)
            if step % log_every == 0:
                print(f"[trainer] step {step} loss {loss:.4f} "
                      f"({dt*1e3:.0f} ms)")
            step += 1
            self._maybe_checkpoint(step)
        return history
