"""AdamW with fp32 moments, sharded like the parameters (ZeRO-3)."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100


def init_opt_state(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.int32(0)}


def _schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    return cfg.lr * warm


def apply_updates(cfg: AdamWConfig, params, opt, grads):
    step = opt["step"] + 1
    lr = _schedule(cfg, step.astype(jnp.float32))
    # global-norm clip
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m2 / (1 - cfg.b1 ** step)
        vh = v2 / (1 - cfg.b2 ** step)
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + \
            cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt["m"])
    flat_v = jax.tree.leaves(opt["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, gnorm
