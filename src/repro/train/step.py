"""train_step: loss + grads + AdamW update, with optional microbatching
(gradient accumulation) and a gradient-compression hook."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..models import transformer as T
from ..models.config import ModelConfig
from .optimizer import AdamWConfig, apply_updates


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    *, microbatches: int = 1, compressor=None, mesh=None):
    """Returns train_step(params, opt, batch) → (params', opt', metrics)."""
    from ..distributed.sharding import make_batch_constrainer
    constrain = make_batch_constrainer(mesh)

    def loss(params, batch):
        return T.loss_fn(cfg, params, batch, constrain=constrain)

    def train_step(params, opt, batch):
        if microbatches > 1:
            def micro(batch_slice):
                return jax.value_and_grad(loss, has_aux=True)(
                    params, batch_slice)

            def split(x):
                b = x.shape[0]
                return x.reshape(microbatches, b // microbatches,
                                 *x.shape[1:])

            mb = jax.tree.map(split, batch)

            def body(acc, sl):
                (l, parts), g = micro(sl)
                acc_g, acc_l = acc
                return (jax.tree.map(jnp.add, acc_g, g), acc_l + l), None

            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, lsum), _ = jax.lax.scan(body, (zero_g, jnp.float32(0)),
                                            mb)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            lval = lsum / microbatches
        else:
            (lval, parts), grads = jax.value_and_grad(loss, has_aux=True)(
                params, batch)
        if compressor is not None:
            grads = compressor(grads)
        params2, opt2, gnorm = apply_updates(opt_cfg, params, opt, grads)
        metrics = {"loss": lval, "grad_norm": gnorm}
        return params2, opt2, metrics

    return train_step
