"""Minimal stand-in for ``hypothesis`` when it is not installed.

The property tests in this repo use a small slice of hypothesis's API:
``@given`` over ``integers`` / ``booleans`` / ``tuples`` / ``lists``
strategies, plus ``@settings(max_examples=…, deadline=…)``.  This shim
re-implements exactly that slice as deterministic seeded random sampling
so the suite still *runs* the properties (rather than skipping whole
modules) in environments where dependencies cannot be installed.

It is NOT a replacement for hypothesis — no shrinking, no example
database, no sophisticated search.  ``requirements-dev.txt`` pins the
real thing; test modules import it first and fall back to this shim:

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_fallback import given, settings, strategies as st
"""

from __future__ import annotations

import functools
import inspect
import random
import zlib

DEFAULT_MAX_EXAMPLES = 25


class _Strategy:
    def __init__(self, sample):
        self._sample = sample

    def sample(self, rng: random.Random):
        return self._sample(rng)


class strategies:  # noqa: N801 — mirrors ``hypothesis.strategies`` module
    @staticmethod
    def integers(min_value=None, max_value=None):
        lo = -(2 ** 63) if min_value is None else min_value
        hi = 2 ** 63 - 1 if max_value is None else max_value

        def sample(rng):
            # bias toward boundaries — the cheapest bug-finding trick
            r = rng.random()
            if r < 0.1:
                return lo
            if r < 0.2:
                return hi
            return rng.randint(lo, hi)
        return _Strategy(sample)

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)

    @staticmethod
    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda rng: rng.choice(elements))

    @staticmethod
    def tuples(*strats):
        return _Strategy(lambda rng: tuple(s.sample(rng) for s in strats))

    @staticmethod
    def lists(elem, min_size=0, max_size=10):
        def sample(rng):
            n = rng.randint(min_size, max_size)
            return [elem.sample(rng) for _ in range(n)]
        return _Strategy(sample)


def given(*strats):
    def decorate(fn):
        @functools.wraps(fn)
        def runner(*args, **kwargs):
            n = getattr(runner, "_max_examples", DEFAULT_MAX_EXAMPLES)
            # deterministic per-test seed so failures reproduce
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = random.Random(seed)
            for _ in range(n):
                drawn = [s.sample(rng) for s in strats]
                fn(*args, *drawn, **kwargs)
        if not hasattr(runner, "_max_examples"):  # wraps() copies a stashed
            runner._max_examples = DEFAULT_MAX_EXAMPLES  # below-given value
        runner.hypothesis_fallback = True
        # hide the strategy-filled parameters from pytest's fixture
        # resolution (wraps() exposes them via __wrapped__ otherwise)
        del runner.__wrapped__
        runner.__signature__ = inspect.Signature()
        return runner
    return decorate


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None,
             **_ignored):
    def decorate(fn):
        if hasattr(fn, "_max_examples"):      # applied above @given
            fn._max_examples = max_examples
            return fn
        # applied below @given: stash for given() to pick up via wraps
        fn._max_examples = max_examples
        return fn
    return decorate
