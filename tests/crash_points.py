"""Crash-injection harness: persist-boundary snapshots → recovery checks.

NVTraverse-style persistence-ordering bugs hide in interleavings that
ordinary unit tests never exercise: the state that is durable *between*
two fences, not the state the program sees.  This harness makes those
states first-class test inputs:

  * ``record_persist_boundaries`` hooks an allocator's ``fence`` so that
    every persist boundary captures the durable NVM image twice — once
    *before* the fence (a crash here loses every scheduled-but-unfenced
    line) and once *after* (the lines just became durable).  Random
    cache eviction in the simulated-NVM layer varies what else happens
    to be durable, so repeated runs explore different interleavings.
  * ``run_crash_points`` drives a host large-span alloc/free trace under
    the hook, then reopens **every** captured snapshot as a fresh heap,
    runs ``recover()``, and asserts the recovered heap is consistent:

      - every rooted span survives with its size record and flushed
        contents intact (no lost spans);
      - every ``LARGE_CONT`` marker belongs to a live span head (no
        orphaned continuations);
      - the free list holds each superblock at most once, never one
        inside a live span (no double-counted blocks);
      - a fresh span allocated post-recovery lands outside every live
        span (the free set is really free);
      - GC-reconstructed range-lease counts equal the durable holder
        count on every superblock of each span (one root per holder):
        acquire/trim/release persist nothing beyond the records a real
        free writes, so the counts must come back from reachability
        alone — no range freed while referenced, none retained with
        zero reconstructed leases.

The trace follows the application durability protocol the paper assumes:
span contents are flushed+fenced *before* the root is set, and the root
is cleared *before* the span is freed — so at any boundary, a durable
root implies a durable, recoverable span.
"""

from __future__ import annotations

import numpy as np

from repro.analysis.persist_lint import check_allocator
from repro.analysis.trace import attach_tracer
from repro.core import layout, recovery
from repro.core.layout import (D_BLOCK_SIZE, D_SIZE_CLASS, LARGE_CLASS,
                               LARGE_CONT, SB_SIZE)
from repro.core.prefix_index import PrefixIndex
from repro.core.ralloc import Ralloc

MB = 1 << 20
SENTINEL = 0xC0DE0000
KEY0 = 0x51A5E0000


def assert_persist_order(r: Ralloc, tracer, where: str) -> None:
    """Every harness run doubles as a persist-order check: replay the
    traced events against the standard ordering spec and fail on any
    violation (see ``repro.analysis.persist_lint``)."""
    rep = check_allocator(r, tracer)
    assert rep.ok, f"persist-order violations during {where}:\n{rep}"


def record_persist_boundaries(r: Ralloc) -> list[np.ndarray]:
    """Hook ``r``'s fence; returns the (growing) list of durable images."""
    snaps: list[np.ndarray] = []
    mem = r.mem
    orig = mem.fence

    def fence():
        snaps.append(mem.nvm.copy())       # crash just before the fence
        orig()
        snaps.append(mem.nvm.copy())       # crash just after
    mem.fence = fence
    return snaps


def dedup_images(snaps: list[np.ndarray]) -> list[np.ndarray]:
    seen: set[int] = set()
    out: list[np.ndarray] = []
    for s in snaps:
        h = hash(s.tobytes())
        if h not in seen:
            seen.add(h)
            out.append(s)
    return out


def run_host_trace(r: Ralloc, ops, idx: PrefixIndex | None = None
                   ) -> list[tuple[int, int, int, int]]:
    """Replay a span alloc/acquire/trim/release/publish interleaving.

    ``ops`` entries are ``(kind, k)`` with kind in {"alloc", "acquire",
    "acquire_prefix", "trim", "free", "publish", "unpublish"} — legacy
    ``(is_free, k)`` bool tuples are accepted and mean free/alloc.  One
    *holder* = one (transient) range lease + one durable root:

      * ``alloc`` places a ``k``-superblock span, stamps + flushes a
        sentinel, and roots it (the owner's full-extent lease);
      * ``acquire`` / ``acquire_prefix`` lease the oldest live span
        (full extent / a ``k``-clamped prefix — ``span_acquire`` persists
        nothing) and then root it at a fresh index, so at every persist
        boundary the durable roots pointing at a head ARE its
        reconstructible lease count;
      * ``trim`` shrinks the oldest span to a ``k``-clamped prefix
        (``span_trim`` — the unleased tail durably leaves the span), then
        re-stamps the recorded length *after* the trim completes;
      * ``free`` drops the oldest holder's lease (unroot BEFORE
        releasing — a shared release is a pure transient decrement);
      * ``publish`` durably publishes a ``k``-clamped prefix of the
        oldest span into the prefix index (``PrefixIndex.publish``:
        transient acquire → fence → record append → root swing — the
        fence IS the satellite's ``publish_durable`` boundary, so every
        run snapshots the acquired-but-unpublished window);
      * ``unpublish`` durably removes the oldest published record
        (unlink before the lease drops).

    Returns the final holder list ``[(root_idx, ptr, k, lease_sbs)]``.
    """
    holders: list[tuple[int, int, int, int]] = []  # (root, ptr, k, lease)
    published: list[int] = []                      # keys, oldest first
    next_root = 0
    next_key = KEY0
    for kind, k in ops:
        if isinstance(kind, bool):
            kind = "free" if kind else "alloc"
        if kind == "free" and holders:
            i, ptr, _, lease = holders.pop(0)
            r.set_root(i, None)                 # unroot BEFORE releasing
            r.span_release(ptr, lease)
        elif kind in ("acquire", "acquire_prefix") and holders:
            _, ptr, k0, _ = holders[0]          # oldest live span
            ext = _span_ext(r, ptr)
            n = ext if kind == "acquire" else max(1, min(k, ext))
            r.span_acquire(ptr, n)              # transient lease only …
            i = next_root
            next_root += 1
            r.set_root(i, ptr)                  # … the root is the durable ref
            holders.append((i, ptr, k0, n))
        elif kind == "publish" and holders and idx is not None:
            _, ptr, _, _ = holders[0]
            ext = _span_ext(r, ptr)
            n = max(1, min(k, ext))
            key = next_key
            next_key += 1
            if idx.publish(key, ptr, n_pages=n, lease_sbs=n) is not None:
                published.append(key)
        elif kind == "unpublish" and published:
            idx.remove(published.pop(0))
        elif kind == "trim" and holders:
            _, ptr, _, _ = holders[0]
            ext = _span_ext(r, ptr)
            if ext > 1:
                n_keep = max(1, min(k, ext - 1))
                new_ext = r.span_trim(ptr, n_keep)
                # exactly one full-extent lease shrank to n_keep (trim's
                # contract); a zero-count suffix may have freed, clamping
                # every other lease to the surviving extent
                shrunk, upd = False, []
                for i, p, kk, l in holders:
                    if p == ptr:
                        if not shrunk and min(l, ext) == ext:
                            l, shrunk = n_keep, True
                        l = min(l, new_ext)
                    upd.append((i, p, kk, l))
                holders = upd
                # re-stamp the recorded length once the trim is durable —
                # a crash in between leaves the old (larger) record, so
                # recovery checks only require extent <= recorded length
                r.write_word(ptr + 1, new_ext)
                r.flush_range(ptr + 1, 1)
                r.fence()
        elif kind not in ("free", "trim", "unpublish") or not holders:
            ptr = r.malloc(k * SB_SIZE - 256)
            if ptr is None:
                continue
            i = next_root
            next_root += 1
            # sentinel keyed by the head superblock (stable across holders)
            r.write_word(ptr, SENTINEL + r.heap.sb_of(ptr))
            r.write_word(ptr + 1, k)
            r.flush_range(ptr, 2)
            r.fence()                           # contents durable BEFORE root
            r.set_root(i, ptr)
            holders.append((i, ptr, k, k))
    return holders


def _span_ext(r: Ralloc, ptr: int) -> int:
    """Current persisted extent (superblocks) of the span at ``ptr``."""
    return r.span_extent(ptr)


def check_recovered_heap(r: Ralloc, n_roots: int,
                         index: PrefixIndex | None = None
                         ) -> dict[int, int]:
    """Assert span/free-list consistency after ``recover()``; returns the
    recovered ``{head_sb: span_sbs}`` map.

    With ``index``, the recovered prefix-index records join the expected
    lease model: each durable root is one full-extent lease, each record
    one lease *re-trimmed* to its recorded superblock count (recovery
    runs ``retrim_after_recovery`` for typed index roots) — and a record
    may be a span's only reference.  A record naming a dead span — the
    "dangling index record" the publish ordering forbids — fails here."""
    m = r.mem
    used = int(m.read(layout.M_USED_SBS))
    cls_of = [int(m.read(r.desc(sb, D_SIZE_CLASS))) for sb in range(used)]
    bs_of = [int(m.read(r.desc(sb, D_BLOCK_SIZE))) for sb in range(used)]

    spans: dict[int, int] = {}
    covered: set[int] = set()
    for sb in range(used):
        if cls_of[sb] == LARGE_CLASS and bs_of[sb] > 0:
            nsb = -(-bs_of[sb] // SB_SIZE)
            assert sb + nsb <= used, f"span at {sb} exceeds the watermark"
            assert not covered & set(range(sb, sb + nsb)), \
                f"span at {sb} overlaps another live span"
            for j in range(sb + 1, sb + nsb):
                assert cls_of[j] == LARGE_CONT, \
                    f"span at {sb} torn: sb {j} is not a continuation"
            covered |= set(range(sb, sb + nsb))
            spans[sb] = nsb
    for sb in range(used):
        if cls_of[sb] == LARGE_CONT:
            assert sb in covered, f"orphaned LARGE_CONT at superblock {sb}"

    free = recovery.free_superblock_list(r)     # raises on a cycle
    assert len(free) == len(set(free)), "double-counted free superblock"
    for sb in free:
        assert 0 <= sb < used, f"free-listed sb {sb} above the watermark"
        assert sb not in covered, f"free-listed sb {sb} inside a live span"

    # every durable root must name a live, content-intact span
    root_refs: dict[int, int] = {}
    for i in range(n_roots):
        w = r.heap.get_root(i)
        if w is None:
            continue
        sb = r.heap.sb_of(w)
        root_refs[sb] = root_refs.get(sb, 0) + 1
        assert sb in spans, f"root {i} points at a lost span (sb {sb})"
        assert int(r.read_word(w)) == SENTINEL + sb, \
            f"root {i}: span contents lost"
        # a trim durably shrinks the extent before the harness re-stamps
        # the length word, so a crash in the window leaves record >=
        # extent; an extent *above* the record would be a resurrected tail
        assert 1 <= spans[sb] <= int(r.read_word(w + 1)), \
            f"root {i}: span length record corrupted / tail resurrected"

    # never a dangling index record: every recovered record names a live
    # span with a sane lease length (the publish/unpublish durability
    # ordering guarantees a linked record always implies a live span)
    rec_refs: dict[int, list[int]] = {}
    if index is not None:
        for rec in index.records():
            assert rec.span is not None, "torn index record survived"
            sb = r.heap.sb_of(rec.span)
            assert sb in spans, \
                f"dangling index record: names a dead span (sb {sb})"
            assert rec.lease_sbs >= 1 and rec.n_pages >= 1, \
                f"index record at {rec.ptr} carries a corrupt length"
            rec_refs.setdefault(sb, []).append(rec.lease_sbs)

    # GC-reconstructed lease counts == the durable reference model, on
    # EVERY superblock of the span: acquire/trim/release persist nothing
    # beyond the records a real free writes, so at every boundary the
    # per-range counts recovery rebuilds must equal the durable roots
    # referencing the head (each a full-extent lease — lengths are
    # transient) plus the durable index records (each re-trimmed to its
    # recorded length) — no range freed while referenced, none retained
    # with zero reconstructed leases
    for sb, nsb in spans.items():
        assert sb in root_refs or sb in rec_refs, \
            f"zero-ref span at sb {sb} survived recovery"
        base = root_refs.get(sb, 0)
        want = [base + sum(1 for ls in rec_refs.get(sb, []) if ls > i)
                for i in range(nsb)]
        assert r.leases.counts(sb) == want, \
            f"span at sb {sb}: reconstructed lease counts " \
            f"{r.leases.counts(sb)} != durable model {want} " \
            f"(roots {base}, records {rec_refs.get(sb, [])})"

    # the free set is genuinely free: a fresh span never lands in a live one
    p = r.malloc(2 * SB_SIZE - 256)
    if p is not None:
        psb = r.heap.sb_of(p)
        assert not covered & {psb, psb + 1}, \
            "fresh span allocated inside a live span"
    return spans


def run_crash_points(ops: list[tuple[bool, int]], *, size: int = 2 * MB,
                     seed: int = 0) -> int:
    """The harness entry point: trace → snapshot at every persist boundary
    → recover each snapshot → consistency checks.  Returns the number of
    distinct durable images exercised.

    ``expand_sbs=1`` keeps the watermark honest now that publish events
    allocate small record blocks (a 16-superblock batch expansion per
    record refill would dwarf the span traffic under test)."""
    r = Ralloc(None, size, sim_nvm=True, seed=seed, expand_sbs=1)
    idx = PrefixIndex(r)
    tracer = attach_tracer(r)
    snaps = record_persist_boundaries(r)
    run_host_trace(r, ops, idx)
    assert_persist_order(r, tracer, "the host trace")
    # every op allocates at most one root — a (True, k) op with nothing
    # live falls through to an allocation too, so bound by len(ops), not
    # by the is_free=False count (which would leave roots unchecked)
    n_roots = len(ops) + 1
    images = dedup_images(snaps)
    for img in images:
        r2 = Ralloc(None, size, sim_nvm=True, seed=seed + 1,
                    backing=img.copy(), expand_sbs=1)
        # registering the typed index root BEFORE recover() is what makes
        # the trace visit records precisely and re-trim their leases
        idx2 = PrefixIndex(r2)
        tracer2 = attach_tracer(r2)
        assert r2.dirty_restart, "persist-boundary image must be dirty"
        r2.recover()
        check_recovered_heap(r2, n_roots, index=idx2)
        assert_persist_order(r2, tracer2, "recovery of a boundary image")
    return len(images)
