"""Baseline allocators + sharding-rule unit tests."""

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.core.baselines import make_allocator
from repro.distributed import sharding as sh

KINDS = ("ralloc", "lrmalloc", "makalu_lite", "pmdk_lite")


@pytest.mark.parametrize("kind", KINDS)
def test_allocator_kinds_basic(kind):
    a = make_allocator(kind, None, 8 << 20)
    ps = [a.malloc(64) for _ in range(300)]
    assert None not in ps and len(set(ps)) == 300
    for p in ps[::2]:
        a.free(p)
    ps2 = [a.malloc(64) for _ in range(150)]
    assert None not in ps2
    live = set(ps[1::2]) | set(ps2)
    assert len(live) == len(set(live))
    a.close()


def test_persistence_cost_hierarchy():
    """Paper §6.2: Ralloc flushes ~nothing during batch churn; Makalu and
    PMDK flush persistent metadata in every synchronized operation."""
    counts = {}
    for kind in KINDS:
        a = make_allocator(kind, None, 16 << 20)
        a.malloc(64)
        a.mem.reset_counters()
        for _ in range(3):                 # churn defeats the 1-slot cache
            ps = [a.malloc(64) for _ in range(500)]
            for p in ps:
                a.free(p)
        counts[kind] = a.counters["flush"]
        a.close()
    assert counts["ralloc"] <= 12
    assert counts["makalu_lite"] > 20 * max(counts["ralloc"], 1)
    assert counts["pmdk_lite"] > counts["makalu_lite"]


class _FakeMesh:
    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}


def test_train_specs_divisibility_fallback():
    mesh = _FakeMesh()
    # vocab 92553 (internvl2) not divisible by 16 ⇒ axis dropped
    spec = sh.train_param_spec("embed", (92553, 6144), mesh)
    assert spec == P(None, "data")
    spec = sh.train_param_spec("embed", (49152, 6144), mesh)
    assert spec == P("model", "data")
    # attention weights shard FSDP × TP
    spec = sh.train_param_spec("units/l0/attn/wq", (52, 6144, 6144), mesh)
    assert spec == P(None, "data", "model")
    # kv=1 projection: 128 cols still divisible by 16
    spec = sh.train_param_spec("units/l0/attn/wk", (52, 6144, 128), mesh)
    assert spec == P(None, "data", "model")
    # moe experts: E=48 divisible
    spec = sh.train_param_spec("units/l0/ffn/wi", (32, 48, 1536, 512), mesh)
    assert spec == P(None, "model", "data", None)


def test_serve_specs_vocab_fallback():
    from repro.configs import get_config
    from repro.launch import specs
    from repro.serving.decode import serve_param_specs
    cfg = get_config("internvl2_26b")
    shapes = specs.abstract_params(cfg)
    sp = serve_param_specs(cfg, shapes, tp=16)
    assert sp["embed"] == P(None, None)          # 92553 % 16 != 0
    cfg2 = get_config("qwen2_5_32b")
    shapes2 = specs.abstract_params(cfg2)
    sp2 = serve_param_specs(cfg2, shapes2, tp=16)
    assert sp2["embed"] == P("model", None)
