"""Recoverable checkpointing, trainer fault tolerance, compression codec."""

import dataclasses
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_smoke_config
from repro.core.ralloc import Ralloc
from repro.data.pipeline import TokenStream
from repro.distributed.compression import Int8ErrorFeedback
from repro.runtime import make_host_mesh
from repro.train.loop import Trainer
from repro.train.optimizer import AdamWConfig

MB = 1 << 20


def test_checkpoint_roundtrip_and_crash():
    path = tempfile.mktemp()
    heap = Ralloc(path, 64 * MB, sim_nvm=True, seed=3)
    cm = CheckpointManager(heap)
    tree = {"w": np.arange(1000, dtype=np.float32).reshape(10, 100),
            "b": np.ones((7,), np.int64)}
    cm.save(tree, step=10)
    tree2 = {k: np.asarray(v) * 2 for k, v in tree.items()}
    cm.save(tree2, step=20)
    # crash mid-"checkpoint": leaked shard allocations, no commit
    for _ in range(5):
        heap.malloc(8000)
    heap.heap.crash()
    del heap, cm

    heap2 = Ralloc(path, 64 * MB, sim_nvm=True, seed=4)
    assert heap2.dirty_restart
    cm2 = CheckpointManager(heap2)
    heap2.get_root(0, "ckpt_manifest")
    heap2.get_root(1, "ckpt_manifest")
    heap2.recover()
    restored, step = cm2.load_latest(tree)
    assert step == 20
    np.testing.assert_array_equal(restored["w"], tree2["w"])
    # heap remains serviceable
    cm2.save({k: np.asarray(v) * 3 for k, v in tree.items()}, step=30)
    r3, s3 = cm2.load_latest(tree)
    assert s3 == 30 and np.allclose(r3["w"], tree["w"] * 3)
    heap2.close()
    os.unlink(path)


def test_trainer_resumes_from_checkpoint():
    cfg = dataclasses.replace(get_smoke_config("starcoder2_3b"),
                              num_layers=2, vocab_size=64)
    path = tempfile.mktemp()
    heap = Ralloc(path, 256 * MB)
    cm = CheckpointManager(heap)
    stream = TokenStream(cfg.vocab_size, 2, 32, seed=1)
    tr = Trainer(cfg, AdamWConfig(warmup_steps=2), ckpt=cm, ckpt_every=5)
    tr.run(stream, steps=7, log_every=1000)
    w_after7 = np.asarray(jax.tree.leaves(tr.params)[0], np.float32)

    # "crash": new trainer over the same heap resumes at the ckpt step
    tr2 = Trainer(cfg, AdamWConfig(warmup_steps=2), ckpt=cm, ckpt_every=5)
    assert tr2.start_step == 5
    w_restored = np.asarray(jax.tree.leaves(tr2.params)[0], np.float32)
    assert w_restored.shape == w_after7.shape
    # deterministic data ⇒ re-running steps 5..7 reproduces the state
    tr2.run(stream, steps=7, log_every=1000)
    w_replay = np.asarray(jax.tree.leaves(tr2.params)[0], np.float32)
    np.testing.assert_allclose(w_replay, w_after7, atol=2e-2)
    heap.close()
    os.unlink(path)


def test_int8_error_feedback_unbiased():
    params = {"w": jnp.zeros((64, 64))}
    codec = Int8ErrorFeedback(params)
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)}
    # accumulated dequantized grads converge to accumulated true grads
    acc_q = np.zeros((64, 64))
    for _ in range(50):
        dq = codec(g)
        acc_q += np.asarray(dq["w"])
    err = np.abs(acc_q / 50 - np.asarray(g["w"])).max()
    assert err < 2e-2, err             # error feedback keeps it unbiased


def test_elastic_restore_across_meshes():
    """Checkpoint written under one mesh restores onto another (1×1 here;
    the arrays are stored unsharded + position-independent)."""
    cfg = dataclasses.replace(get_smoke_config("qwen2_5_32b"), num_layers=2)
    path = tempfile.mktemp()
    heap = Ralloc(path, 256 * MB)
    cm = CheckpointManager(heap)
    from repro.models import transformer as T
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    cm.save({"p": params}, step=1)
    mesh = make_host_mesh()
    restored, step = cm.load_latest({"p": params})
    from jax.sharding import NamedSharding, PartitionSpec as P
    resharded = jax.tree.map(
        lambda a: jax.device_put(a, NamedSharding(mesh, P())),
        restored["p"])
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(resharded)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    heap.close()
    os.unlink(path)
