"""Host-side Ralloc: unit + property tests (paper §5 invariants)."""

import threading

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # container without dev deps
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import layout
from repro.core import pptr as pp
from repro.core.ralloc import Ralloc

MB = 1 << 20


# ---------------------------------------------------------------- layout
def test_size_classes_paper_geometry():
    assert len(layout.SIZE_CLASSES) == 39          # paper §4.2
    assert layout.SIZE_CLASSES[0] == 8
    assert layout.SIZE_CLASSES[-1] == 14336
    for s in layout.SIZE_CLASSES:
        assert s % 8 == 0


@given(st.integers(1, 14336))
def test_size_to_class_covers(sz):
    cls = layout.size_to_class(sz)
    assert 1 <= cls < layout.NUM_CLASSES
    assert layout.class_block_size(cls) >= sz
    if cls > 1:
        assert layout.class_block_size(cls - 1) < sz


@given(st.integers(0, 2), st.integers(0, (1 << 20) - 1),
       st.integers(0, (1 << 20) - 1), st.integers(0, (1 << 22) - 1))
def test_anchor_roundtrip(state, avail, count, tag):
    a = layout.pack_anchor(state, avail, count, tag)
    assert layout.unpack_anchor(a) == (state, avail, count, tag)


@given(st.integers(-1, (1 << 30) - 2), st.integers(0, (1 << 34) - 1))
def test_head_roundtrip(idx, ctr):
    h = layout.pack_head(idx, ctr)
    assert layout.unpack_head(h) == (idx, ctr)


@given(st.integers(0, 1 << 40), st.integers(0, 1 << 40))
def test_pptr_roundtrip(holder, target):
    if holder == target:
        target += 1
    enc = pp.encode(holder, target)
    assert pp.is_pptr(enc)
    assert pp.decode(holder, enc) == target


@given(st.integers(min_value=-(2**63), max_value=2**63 - 1))
def test_pptr_tag_rejects_most_integers(v):
    # only values carrying the 0xA5A5 tag pattern decode as references
    if (v >> 48) & 0xFFFF != pp.PPTR_TAG:
        assert not pp.looks_like_pptr(v)


# ------------------------------------------------------------- allocation
def test_malloc_free_no_overlap():
    r = Ralloc(None, 16 * MB)
    ptrs = [r.malloc(sz) for sz in (8, 64, 400, 4096, 14336) for _ in range(50)]
    assert None not in ptrs
    spans = sorted((p, p + -(-sz // 8)) for p, sz in
                   zip(ptrs, [8, 64, 400, 4096, 14336] * 50))
    # no two live blocks overlap
    ptrs_sorted = sorted(ptrs)
    assert len(set(ptrs)) == len(ptrs)


def test_persistence_cost_near_zero():
    """The paper's headline: typical ops persist nothing."""
    r = Ralloc(None, 16 * MB)
    r.malloc(64)
    r.mem.reset_counters()
    for _ in range(1000):
        r.free(r.malloc(64))
    assert r.mem.n_flush <= 4          # only superblock (re)init persists
    assert r.mem.n_fence <= 2


def test_large_blocks_span_superblocks():
    r = Ralloc(None, 32 * MB)
    big = r.malloc(200_000)            # > 64 KiB ⇒ multi-superblock
    assert big is not None
    sb = r.heap.sb_of(big)
    assert r.mem.read(r.desc(sb, layout.D_BLOCK_SIZE)) == 200_000
    assert r.mem.read(r.desc(sb + 1, layout.D_SIZE_CLASS)) == layout.LARGE_CONT
    r.free(big)
    # superblocks are reusable afterwards
    again = [r.malloc(60_000) for _ in range(4)]
    assert None not in again


def test_free_large_resets_continuation_metadata():
    """Regression: ``_free_large`` must clear D_SIZE_CLASS/D_BLOCK_SIZE on
    every span superblock (head + LARGE_CONT continuations) before they
    reach the free list — stale markers poisoned later frees/recovery."""
    r = Ralloc(None, 32 * MB)
    big = r.malloc(300_000)                # 5-superblock span
    sb = r.heap.sb_of(big)
    n_cont = sum(
        1 for s in range(sb + 1, r.config.num_sbs)
        if r.mem.read(r.desc(s, layout.D_SIZE_CLASS)) == layout.LARGE_CONT)
    assert n_cont == 4
    r.free(big)
    for s in range(sb, sb + 5):
        assert r.mem.read(r.desc(s, layout.D_SIZE_CLASS)) == 0
        assert r.mem.read(r.desc(s, layout.D_BLOCK_SIZE)) == 0


def test_free_of_continuation_pointer_redirects_to_head():
    """Regression: freeing a pointer that lands in a LARGE_CONT superblock
    used to index the thread cache with the -1 sentinel (corrupting the
    last size class); it must free the owning large object instead."""
    r = Ralloc(None, 4 * MB)
    big = r.malloc(200_000)
    interior = big + layout.SB_WORDS + 7   # inside the 2nd span superblock
    r.free(interior)
    sb = r.heap.sb_of(big)
    assert r.mem.read(r.desc(sb, layout.D_BLOCK_SIZE)) == 0   # span freed
    # the span's superblocks really return: exhaust the small heap and
    # check allocations landed inside the freed span's superblock range
    got_sbs = set()
    while (p := r.malloc(14336)) is not None:
        got_sbs.add(r.heap.sb_of(p))
    assert {sb, sb + 1, sb + 2, sb + 3} <= got_sbs


def test_double_free_of_large_block_rejected():
    r = Ralloc(None, 32 * MB)
    big = r.malloc(200_000)
    r.free(big)
    with pytest.raises(ValueError):
        r.free(big)


def test_block_reuse_after_free():
    r = Ralloc(None, 8 * MB)
    a = r.malloc(128)
    r.free(a)
    b = r.malloc(128)
    assert b == a                      # LIFO thread cache reuses immediately


def test_out_of_memory_returns_none():
    r = Ralloc(None, 2 * MB)
    got = [r.malloc(14336) for _ in range(500)]
    assert None in got                 # bounded heap must eventually fail
    assert got[0] is not None


def test_multithreaded_no_overlap():
    r = Ralloc(None, 64 * MB)
    live, errs = [[] for _ in range(6)], []

    def worker(t):
        try:
            import random
            rng = random.Random(t)
            mine = []
            for _ in range(1500):
                if mine and rng.random() < 0.45:
                    r.free(mine.pop(rng.randrange(len(mine))))
                else:
                    p = r.malloc(rng.choice([16, 64, 256, 400]))
                    assert p is not None
                    mine.append(p)
            live[t] = mine
        except Exception as e:         # pragma: no cover
            errs.append(repr(e))

    ts = [threading.Thread(target=worker, args=(t,)) for t in range(6)]
    [t.start() for t in ts]
    [t.join() for t in ts]
    assert not errs
    flat = [p for lst in live for p in lst]
    assert len(flat) == len(set(flat)), "cross-thread overlap"


# ------------------------------------------------ property: random workload
@settings(max_examples=20, deadline=None)
@given(st.lists(st.tuples(st.booleans(), st.integers(8, 2048)),
                min_size=1, max_size=300))
def test_property_alloc_free_invariants(ops):
    r = Ralloc(None, 16 * MB)
    live = {}
    for is_free, sz in ops:
        if is_free and live:
            p = next(iter(live))
            r.free(p)
            del live[p]
        else:
            p = r.malloc(sz)
            if p is not None:
                assert p not in live
                live[p] = sz
    # all live blocks disjoint
    spans = sorted((p, p + -(-s // 8)) for p, s in live.items())
    for (a0, a1), (b0, b1) in zip(spans, spans[1:]):
        assert a1 <= b0
