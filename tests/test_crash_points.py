"""Crash injection at every persist boundary (harness: ``crash_points``).

Every fence during a host large-span alloc/free interleaving yields two
durable images (before/after); each must recover to a consistent heap —
no lost spans, no orphaned ``LARGE_CONT`` markers, no double-counted
superblocks.  See ``crash_points`` for the invariant definitions.
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # container without dev deps
    from _hypothesis_fallback import given, settings, strategies as st

from crash_points import run_crash_points


def test_crash_injection_alloc_free_interleaving():
    """Deterministic smoke: alloc/free churn with span reuse (the best-fit
    path re-places spans into freed runs, so snapshots cover reused
    superblocks with stale prior-life records too)."""
    ops = [(False, 2), (False, 1), (False, 3),   # three spans
           (True, 0), (False, 2),                # free oldest, reuse its run
           (True, 0), (True, 0), (False, 1)]     # drain, then re-place
    n = run_crash_points(ops, seed=7)
    assert n >= 10                               # many distinct durable states


def test_crash_injection_free_then_crash_rejoins_free_set():
    """A span freed immediately before the crash must re-enter the
    searchable free set (not linger as a half-freed orphan)."""
    n = run_crash_points([(False, 3), (True, 0)], seed=3)
    assert n >= 4


@settings(max_examples=5, deadline=None)
@given(st.lists(st.tuples(st.booleans(), st.integers(1, 3)),
                min_size=2, max_size=8))
def test_property_crash_at_any_persist_boundary_recovers(ops):
    run_crash_points(ops, seed=11)


@pytest.mark.slow
@settings(max_examples=15, deadline=None)
@given(st.lists(st.tuples(st.booleans(), st.integers(1, 4)),
                min_size=4, max_size=14))
def test_property_crash_points_deep(ops):
    """Deeper sweep for the non-blocking slow CI job: longer traces,
    bigger spans, more examples."""
    run_crash_points(ops, size=4 * (1 << 20), seed=23)
