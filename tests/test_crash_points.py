"""Crash injection at every persist boundary (harness: ``crash_points``).

Every fence during a host large-span alloc/free interleaving yields two
durable images (before/after); each must recover to a consistent heap —
no lost spans, no orphaned ``LARGE_CONT`` markers, no double-counted
superblocks.  See ``crash_points`` for the invariant definitions.
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # container without dev deps
    from _hypothesis_fallback import given, settings, strategies as st

from crash_points import run_crash_points


def test_crash_injection_alloc_free_interleaving():
    """Deterministic smoke: alloc/free churn with span reuse (the best-fit
    path re-places spans into freed runs, so snapshots cover reused
    superblocks with stale prior-life records too)."""
    ops = [(False, 2), (False, 1), (False, 3),   # three spans
           (True, 0), (False, 2),                # free oldest, reuse its run
           (True, 0), (True, 0), (False, 1)]     # drain, then re-place
    n = run_crash_points(ops, seed=7)
    assert n >= 10                               # many distinct durable states


def test_crash_injection_free_then_crash_rejoins_free_set():
    """A span freed immediately before the crash must re-enter the
    searchable free set (not linger as a half-freed orphan)."""
    n = run_crash_points([(False, 3), (True, 0)], seed=3)
    assert n >= 4


@settings(max_examples=5, deadline=None)
@given(st.lists(st.tuples(st.booleans(), st.integers(1, 3)),
                min_size=2, max_size=8))
def test_property_crash_at_any_persist_boundary_recovers(ops):
    run_crash_points(ops, seed=11)


def test_crash_injection_shared_span_holders():
    """Shared-span churn: a twice-acquired span must survive every
    boundary with its GC-reconstructed refcount equal to the durable
    holder count, and tear down only when the last holder leaves."""
    ops = [("alloc", 2), ("acquire", 0), ("alloc", 1), ("acquire", 0),
           ("free", 0), ("free", 0), ("free", 0), ("alloc", 2)]
    n = run_crash_points(ops, seed=5)
    assert n >= 8


@settings(max_examples=5, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["alloc", "acquire", "free"]),
                          st.integers(1, 3)),
                min_size=2, max_size=9))
def test_property_refcounts_reconstructed_at_any_boundary(ops):
    """Satellite property: at every persist boundary of a trace with
    acquire/release events, recovery reconstructs span refcounts exactly
    (checked inside ``check_recovered_heap``)."""
    run_crash_points(ops, seed=13)


def test_crash_between_acquire_and_publish_is_safe():
    """A crash after ``span_acquire`` but before the new holder's root is
    durable must neither leak the span nor enable a double free: the
    acquire touched nothing durable, so recovery rebuilds the count the
    durable roots imply (1), one free really frees, a second raises."""
    import numpy as np
    from repro.core import layout, recovery as rec
    from repro.core.layout import SB_SIZE
    from repro.core.ralloc import Ralloc

    r = Ralloc(None, 2 * (1 << 20), sim_nvm=True, seed=1)
    ptr = r.malloc(2 * SB_SIZE - 256)
    r.write_word(ptr, 0xBEEF)
    r.flush_range(ptr, 1)
    r.fence()
    r.set_root(0, ptr)
    r.mem.drain(); r.fence()                  # root durable
    assert r.span_acquire(ptr) == 2           # transient only — no flush
    img = r.mem.nvm.copy()                    # crash here: count still 2 live

    r2 = Ralloc(None, 2 * (1 << 20), sim_nvm=True, seed=2, backing=img)
    r2.recover()
    sb = r2.heap.sb_of(ptr)
    assert r2.leases.count(sb) == 1           # one durable holder ⇒ one ref
    r2.free(ptr)                              # …so one free tears it down
    assert (sb, 2) in rec.free_superblock_runs(r2) or \
        any(s <= sb < s + ln for s, ln in rec.free_superblock_runs(r2))
    with pytest.raises(ValueError):
        r2.free(ptr)                          # and a second free is caught


def test_crash_injection_trimmed_tail_stays_freed():
    """A trim durably shrinks the span: at every boundary after the trim
    the tail superblocks must either still belong to the span (crash
    before the shrink was durable — a safe leak) or be genuinely free,
    and the surviving prefix keeps its contents and lease counts."""
    ops = [("alloc", 3), ("acquire_prefix", 1),   # owner + 1-sb prefix lease
           ("trim", 1),                           # owner keeps 1 sb → tail
           ("alloc", 2),                          # reuses the freed tail
           ("free", 0), ("free", 0)]              # owner, then prefix holder
    n = run_crash_points(ops, seed=17)
    assert n >= 8


def test_crash_injection_partial_release_frees_tail():
    """The owner's full-extent release while a prefix lease remains must
    free exactly the unleased tail — every boundary in that window
    recovers with the prefix alive (its holder's root) and the tail
    reusable."""
    ops = [("alloc", 3), ("acquire_prefix", 2),
           ("free", 0),                           # owner exits → tail frees
           ("alloc", 1),                          # lands in the freed tail
           ("free", 0)]                           # prefix holder exits
    n = run_crash_points(ops, seed=19)
    assert n >= 6


@settings(max_examples=5, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["alloc", "acquire",
                                           "acquire_prefix", "trim",
                                           "free"]),
                          st.integers(1, 3)),
                min_size=2, max_size=9))
def test_property_range_leases_reconstructed_at_any_boundary(ops):
    """Tentpole property: traces mixing prefix acquires, trims, and
    partial releases recover per-range lease counts equal to the durable
    holder count at every persist boundary (checked inside
    ``check_recovered_heap``)."""
    run_crash_points(ops, seed=29)


def test_crash_injection_publish_durable_boundary():
    """Satellite (``publish_durable``): ``PrefixIndex.publish`` fences
    between the transient span-lease acquisition and the durable
    index-record append, so the harness snapshots exactly that window.
    A crash there must recover to either consistent state —
    unpublished-but-leased (no record: counts fall back to the durable
    roots, the span frees when they release) or published (the record
    re-surfaces, its lease re-trimmed to the recorded length) — and
    never to a dangling index record (asserted in
    ``check_recovered_heap``)."""
    ops = [("alloc", 3), ("publish", 1),         # publish a 1-sb prefix
           ("free", 0),                          # owner exits: tail frees,
                                                 # the record alone pins it
           ("alloc", 2), ("publish", 2),
           ("unpublish", 0),                     # durable unlink boundary
           ("free", 0)]
    n = run_crash_points(ops, seed=37)
    assert n >= 12


def test_crash_injection_record_is_spans_only_reference():
    """A span whose every holder exited survives on the index record
    alone, re-trimmed to the published prefix; unpublishing it at last
    frees the prefix too."""
    ops = [("alloc", 3), ("publish", 1), ("free", 0), ("alloc", 1),
           ("unpublish", 0)]
    n = run_crash_points(ops, seed=43)
    assert n >= 8


@settings(max_examples=5, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["alloc", "acquire_prefix",
                                           "publish", "unpublish",
                                           "trim", "free"]),
                          st.integers(1, 3)),
                min_size=2, max_size=9))
def test_property_publish_crash_at_any_boundary_recovers(ops):
    """Satellite property: traces mixing publishes, unpublishes, trims
    and releases recover — at every persist boundary — lease counts
    equal to durable roots (full extent) + durable records (recorded,
    re-trimmed length), with no dangling records."""
    run_crash_points(ops, seed=41)


def test_torn_record_pruned_at_recovery_never_republished():
    """Satellite (torn-record hardening): a record whose sealed words
    tore across the crash must be durably unlinked by recovery — its key
    unresolvable, its lease never reconstructed, its block reclaimed —
    while an intact neighbour record survives untouched.  A second
    recovery over the pruned image is a no-op (``index_pruned == 0``)."""
    from repro.core.layout import SB_SIZE
    from repro.core.prefix_index import PrefixIndex, hash_tokens
    from repro.core.ralloc import Ralloc

    r = Ralloc(None, 2 * (1 << 20), sim_nvm=True, seed=51, expand_sbs=1)
    idx = PrefixIndex(r)
    key_a, key_b = hash_tokens([1, 2]), hash_tokens([3, 4])
    a = r.malloc(2 * SB_SIZE - 256)
    r.write_word(a, 0xAAAA); r.flush_range(a, 1); r.fence()
    r.set_root(0, a)
    rec_a = idx.publish(key_a, a, n_pages=1, lease_sbs=1)
    b = r.malloc(2 * SB_SIZE - 256)
    r.write_word(b, 0xBBBB); r.flush_range(b, 1); r.fence()
    rec_b = idx.publish(key_b, b, n_pages=2, lease_sbs=2)
    assert rec_a is not None and rec_b is not None
    # b's only durable reference is its record; a is also rooted
    r.mem.drain(); r.fence()
    img = r.mem.nvm.copy()
    img[rec_b + 4] ^= 0x2000                  # tear a sealed word of b's rec

    r2 = Ralloc(None, 2 * (1 << 20), sim_nvm=True, seed=52,
                backing=img, expand_sbs=1)
    idx2 = PrefixIndex(r2)
    stats = r2.recover()
    assert stats["index_pruned"] == 1, stats
    # the torn record is gone and never re-publishes its span
    assert idx2.lookup(key_b) is None
    surv = idx2.lookup(key_a)
    assert surv is not None and surv.ptr == rec_a and surv.n_pages == 1
    assert int(r2.read_word(surv.span)) == 0xAAAA
    assert [rec.ptr for rec in idx2.records()] == [rec_a]
    # leases reflect survivors only: span a = root + record on sb 0 of 2;
    # span b lost its sole reference and was swept into the free set
    sb_a, sb_b = r2.heap.sb_of(a), r2.heap.sb_of(b)
    assert r2.leases.counts(sb_a)[0] == 2
    # counts() == [] means span b is not tracked at all (count() would
    # report the advisory single-owner default for unknown spans)
    assert r2.leases.counts(sb_b) == []
    assert sb_b not in r2.leases.snapshot()
    from repro.core import recovery as rec_mod
    assert any(s <= sb_b < s + ln
               for s, ln in rec_mod.free_superblock_runs(r2))
    # pruning is idempotent: a second recovery finds nothing torn
    img2 = r2.mem.nvm.copy()
    r3 = Ralloc(None, 2 * (1 << 20), sim_nvm=True, seed=53,
                backing=img2, expand_sbs=1)
    idx3 = PrefixIndex(r3)
    stats3 = r3.recover()
    assert stats3["index_pruned"] == 0
    assert [rec.ptr for rec in idx3.records()] == [rec_a]


@pytest.mark.slow
@settings(max_examples=15, deadline=None)
@given(st.lists(st.tuples(st.booleans(), st.integers(1, 4)),
                min_size=4, max_size=14))
def test_property_crash_points_deep(ops):
    """Deeper sweep for the non-blocking slow CI job: longer traces,
    bigger spans, more examples."""
    run_crash_points(ops, size=4 * (1 << 20), seed=23)


@pytest.mark.slow
@settings(max_examples=15, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["alloc", "acquire",
                                           "acquire_prefix", "trim",
                                           "free"]),
                          st.integers(1, 4)),
                min_size=4, max_size=14))
def test_property_range_lease_crash_points_deep(ops):
    """Deep range-lease sweep for the non-blocking slow CI job."""
    run_crash_points(ops, size=4 * (1 << 20), seed=31)
