"""Differential fuzz: host Ralloc vs. device jax_alloc on the same trace.

Both allocators implement the identical large-object placement rule
(best-fit over maximal free runs, leftmost on ties, watermark fallback),
so replaying one randomized alloc/free/size trace through both must keep
them in lock-step: same span placement (in superblock units), same
occupancy map, same free-run structure, and the same state after
recovery.  The one *documented* divergence in the ROADMAP feature matrix
— host ``free`` of an invalid/double large pointer raises, device
``free_large`` is a masked no-op — is asserted explicitly so silent
drift on either side fails the suite.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # container without dev deps
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import jax_alloc as ja
from repro.core import jax_recovery as jr
from repro.core import layout, recovery
from repro.core.layout import SB_SIZE
from repro.core.ralloc import Ralloc

N_SBS = 24
DEV_SB_WORDS = 64
DEV_CFG = ja.ArenaConfig(num_sbs=N_SBS, sb_words=DEV_SB_WORDS,
                         class_words=(8,), cache_cap=16, expand_sbs=1)

_alloc_large = jax.jit(functools.partial(ja.alloc_large, cfg=DEV_CFG))
_free_large = jax.jit(functools.partial(ja.free_large, cfg=DEV_CFG))


def host_occupancy(r: Ralloc) -> tuple[int, list[str]]:
    """(watermark, per-sb state): H = span head, C = continuation, F = free."""
    used = int(r.mem.read(layout.M_USED_SBS))
    out = []
    for sb in range(used):
        cls = int(r.mem.read(r.desc(sb, layout.D_SIZE_CLASS)))
        bs = int(r.mem.read(r.desc(sb, layout.D_BLOCK_SIZE)))
        if cls == layout.LARGE_CLASS and bs > 0:
            out.append("H")
        elif cls == layout.LARGE_CONT:
            out.append("C")
        else:
            out.append("F")
    return used, out


def dev_occupancy(st_: ja.AllocState) -> tuple[int, list[str]]:
    used = int(st_.used_sbs)
    cls = np.asarray(st_.sb_class)[:used]
    out = []
    for c in cls.tolist():
        out.append("H" if c == ja.LARGE_CLS else
                   "C" if c == ja.LARGE_CONT else "F")
    return used, out


def replay(ops):
    """Drive both allocators through one trace; assert lock-step at every
    op.  Returns (host, device state, live list of (host ptr, dev off, k))."""
    r = Ralloc(None, N_SBS * SB_SIZE)
    dst = ja.init_state(DEV_CFG, max_roots=64)
    live = []
    for is_free, k in ops:
        if is_free and live:
            ptr, off, _ = live.pop(0)
            r.free(ptr)
            dst = _free_large(state=dst, off=jnp.int32(off))
        else:
            ptr = r.malloc(k * SB_SIZE - 256)
            dst, off = _alloc_large(state=dst,
                                    nwords=jnp.int32(k * DEV_SB_WORDS - 4))
            off = int(off)
            assert (ptr is None) == (off < 0), \
                f"serveability drift on a {k}-sb request"
            if ptr is None:
                continue
            assert r.heap.sb_of(ptr) == off // DEV_SB_WORDS, \
                f"placement drift: host sb {r.heap.sb_of(ptr)} vs " \
                f"device sb {off // DEV_SB_WORDS}"
            live.append((ptr, off, k))
        assert host_occupancy(r) == dev_occupancy(dst), "occupancy drift"
    return r, dst, live


def assert_free_runs_agree(r, dst):
    host_runs = recovery.free_superblock_runs(r)
    assert host_runs == ja.free_runs(dst, DEV_CFG), "free-run drift"


@settings(max_examples=12, deadline=None)
@given(st.lists(st.tuples(st.booleans(), st.integers(1, 4)),
                min_size=1, max_size=30))
def test_differential_trace_lockstep(ops):
    r, dst, live = replay(ops)
    assert_free_runs_agree(r, dst)

    # documented asymmetry (ROADMAP feature matrix): double-free of a
    # large span — host raises, device is a masked no-op
    if live:
        ptr, off, _ = live.pop(0)
        r.free(ptr)
        dst = _free_large(state=dst, off=jnp.int32(off))
        with pytest.raises(ValueError):
            r.free(ptr)
        before = dev_occupancy(dst)
        dst2 = _free_large(state=dst, off=jnp.int32(off))
        assert dev_occupancy(dst2) == before
        assert int(dst2.free_top) == int(dst.free_top)
        dst = dst2
        assert host_occupancy(r) == dev_occupancy(dst)

    # recovery: root every live span on both sides, recover, and demand
    # identical occupancy AND identical placement of the next span
    for i, (ptr, _, _) in enumerate(live):
        r.set_root(i, ptr)
    r.recover()
    roots = np.full((64,), -1, np.int32)
    for i, (_, off, _) in enumerate(live):
        roots[i] = off
    pers = ja.persistent_snapshot(dst)
    pers["roots"] = jnp.asarray(roots)
    refs = jnp.full((jr.num_slots(DEV_CFG), 1), -1, jnp.int32)
    dst, _ = jr.recover(DEV_CFG, pers, refs)
    assert host_occupancy(r) == dev_occupancy(dst), "post-recovery drift"
    assert_free_runs_agree(r, dst)

    ptr = r.malloc(2 * SB_SIZE - 256)
    dst, off = _alloc_large(state=dst, nwords=jnp.int32(2 * DEV_SB_WORDS - 4))
    assert (ptr is None) == (int(off) < 0)
    if ptr is not None:
        assert r.heap.sb_of(ptr) == int(off) // DEV_SB_WORDS, \
            "post-recovery placement drift"


def test_differential_best_fit_prefers_smallest_run():
    """Constructed fragmentation: [2-run][live][3-run][live][2-run] free
    pattern — a 2-sb request must take a 2-run (best fit), never split
    the 3-run; both sides must agree on which one."""
    ops = [(False, 2), (False, 1), (False, 3), (False, 1), (False, 2),
           (True, 0)]                 # frees the first 2-span → run at 0
    r, dst, live = replay(ops)
    # free the 3-span (index 1 after the pop in replay: live holds
    # [1-span@2, 3-span@3, 1-span@6, 2-span@7]) → runs: (0,2) and (3,3)
    ptr, off, _ = live.pop(1)
    r.free(ptr)
    dst = _free_large(state=dst, off=jnp.int32(off))
    assert recovery.free_superblock_runs(r) == [(0, 2), (3, 3)]
    assert_free_runs_agree(r, dst)
    # a 2-sb request: best fit takes (0, 2) exactly, leaving (3, 3) whole
    p2 = r.malloc(2 * SB_SIZE - 256)
    dst, o2 = _alloc_large(state=dst, nwords=jnp.int32(2 * DEV_SB_WORDS - 4))
    assert r.heap.sb_of(p2) == int(o2) // DEV_SB_WORDS == 0
    assert recovery.free_superblock_runs(r) == [(3, 3)]
    # a 3-sb request then lands exactly on the preserved 3-run
    p3 = r.malloc(3 * SB_SIZE - 256)
    dst, o3 = _alloc_large(state=dst, nwords=jnp.int32(3 * DEV_SB_WORDS - 4))
    assert r.heap.sb_of(p3) == int(o3) // DEV_SB_WORDS == 3
    assert host_occupancy(r) == dev_occupancy(dst)


@pytest.mark.slow
@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.booleans(), st.integers(1, 5)),
                min_size=5, max_size=60))
def test_differential_trace_lockstep_deep(ops):
    """Longer traces for the non-blocking slow CI job."""
    r, dst, _ = replay(ops)
    assert_free_runs_agree(r, dst)
