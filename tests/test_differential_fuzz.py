"""Differential fuzz: host Ralloc vs. device jax_alloc on the same trace.

Both allocators implement the identical large-object placement rule
(best-fit over maximal free runs, leftmost on ties, watermark fallback),
so replaying one randomized alloc/free/size trace through both must keep
them in lock-step: same span placement (in superblock units), same
occupancy map, same free-run structure, and the same state after
recovery.  The one *documented* divergence in the ROADMAP feature matrix
— host ``free`` of an invalid/double large pointer raises, device
``free_large`` is a masked no-op — is asserted explicitly so silent
drift on either side fails the suite.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # container without dev deps
    from _hypothesis_fallback import given, settings, strategies as st

from repro.analysis.persist_lint import check_allocator
from repro.analysis.trace import attach_tracer
from repro.core import jax_alloc as ja
from repro.core import jax_recovery as jr
from repro.core import layout, recovery
from repro.core.layout import SB_SIZE
from repro.core.ralloc import Ralloc

N_SBS = 24
DEV_SB_WORDS = 64
DEV_CFG = ja.ArenaConfig(num_sbs=N_SBS, sb_words=DEV_SB_WORDS,
                         class_words=(8,), cache_cap=16, expand_sbs=1)

_alloc_large = jax.jit(functools.partial(ja.alloc_large, cfg=DEV_CFG))
_free_large = jax.jit(functools.partial(ja.free_large, cfg=DEV_CFG))
_scan_fit = jax.jit(functools.partial(ja.scan_best_fit, cfg=DEV_CFG))


def host_occupancy(r: Ralloc) -> tuple[int, list[str]]:
    """(watermark, per-sb state): H = span head, C = continuation, F = free."""
    used = int(r.mem.read(layout.M_USED_SBS))
    out = []
    for sb in range(used):
        cls = int(r.mem.read(r.desc(sb, layout.D_SIZE_CLASS)))
        bs = int(r.mem.read(r.desc(sb, layout.D_BLOCK_SIZE)))
        if cls == layout.LARGE_CLASS and bs > 0:
            out.append("H")
        elif cls == layout.LARGE_CONT:
            out.append("C")
        else:
            out.append("F")
    return used, out


def dev_occupancy(st_: ja.AllocState) -> tuple[int, list[str]]:
    used = int(st_.used_sbs)
    cls = np.asarray(st_.sb_class)[:used]
    out = []
    for c in cls.tolist():
        out.append("H" if c == ja.LARGE_CLS else
                   "C" if c == ja.LARGE_CONT else "F")
    return used, out


def assert_persist_clean(r):
    """Every fuzz run doubles as a persist-order check: the host heap
    carries a tracer from birth (see the replay functions), and the full
    event stream — trace, recovery, post-recovery ops — must satisfy the
    standard ordering spec (``repro.analysis.persist_lint``).  The fast
    (non-sim) mode changes nothing: the shadow models *guarantees*, not
    the cache."""
    rep = check_allocator(r, r._persist_tracer)
    assert rep.ok, f"persist-order violations:\n{rep}"


def replay(ops):
    """Drive both allocators through one trace; assert lock-step at every
    op.  Returns (host, device state, live list of (host ptr, dev off, k))."""
    r = Ralloc(None, N_SBS * SB_SIZE)
    r._persist_tracer = attach_tracer(r)
    dst = ja.init_state(DEV_CFG, max_roots=64)
    live = []
    for is_free, k in ops:
        if is_free and live:
            ptr, off, _ = live.pop(0)
            r.free(ptr)
            dst = _free_large(state=dst, off=jnp.int32(off))
        else:
            ptr = r.malloc(k * SB_SIZE - 256)
            has, _, first = _scan_fit(state=dst, nsb=jnp.int32(k))
            dst, off = _alloc_large(state=dst,
                                    nwords=jnp.int32(k * DEV_SB_WORDS - 4))
            off = int(off)
            assert (ptr is None) == (off < 0), \
                f"serveability drift on a {k}-sb request"
            if bool(has):        # bucket index == retired suffix-min scan
                assert off == int(first) * DEV_SB_WORDS, "index/scan drift"
            if ptr is None:
                continue
            assert r.heap.sb_of(ptr) == off // DEV_SB_WORDS, \
                f"placement drift: host sb {r.heap.sb_of(ptr)} vs " \
                f"device sb {off // DEV_SB_WORDS}"
            live.append((ptr, off, k))
        assert host_occupancy(r) == dev_occupancy(dst), "occupancy drift"
    assert_persist_clean(r)
    return r, dst, live


def assert_free_runs_agree(r, dst):
    host_runs = recovery.free_superblock_runs(r)
    assert host_runs == ja.free_runs(dst, DEV_CFG), "free-run drift"
    # indexed path: the incrementally-maintained run table the device
    # places through must equal a from-scratch recompute of the free set
    # it mirrors — at every lock-step checkpoint, including post-recovery
    ids = jnp.arange(DEV_CFG.num_sbs, dtype=jnp.int32)
    free = (dst.sb_class == ja.FREE_CLS) & (ids < dst.used_sbs)
    rl, rs = ja.free_run_table(free, DEV_CFG.num_sbs)
    np.testing.assert_array_equal(np.asarray(dst.run_len), np.asarray(rl),
                                  "run_len drift")
    np.testing.assert_array_equal(np.asarray(dst.run_start), np.asarray(rs),
                                  "run_start drift")
    np.testing.assert_array_equal(np.asarray(dst.run_bucket_min),
                                  np.asarray(ja._bucket_mins(DEV_CFG, rl)),
                                  "bucket-min drift")


@settings(max_examples=12, deadline=None)
@given(st.lists(st.tuples(st.booleans(), st.integers(1, 4)),
                min_size=1, max_size=30))
def test_differential_trace_lockstep(ops):
    r, dst, live = replay(ops)
    assert_free_runs_agree(r, dst)

    # documented asymmetry (ROADMAP feature matrix): double-free of a
    # large span — host raises, device is a masked no-op
    if live:
        ptr, off, _ = live.pop(0)
        r.free(ptr)
        dst = _free_large(state=dst, off=jnp.int32(off))
        with pytest.raises(ValueError):
            r.free(ptr)
        before = dev_occupancy(dst)
        dst2 = _free_large(state=dst, off=jnp.int32(off))
        assert dev_occupancy(dst2) == before
        assert int(dst2.free_top) == int(dst.free_top)
        dst = dst2
        assert host_occupancy(r) == dev_occupancy(dst)

    # recovery: root every live span on both sides, recover, and demand
    # identical occupancy AND identical placement of the next span
    for i, (ptr, _, _) in enumerate(live):
        r.set_root(i, ptr)
    r.recover()
    roots = np.full((64,), -1, np.int32)
    for i, (_, off, _) in enumerate(live):
        roots[i] = off
    pers = ja.persistent_snapshot(dst)
    pers["roots"] = jnp.asarray(roots)
    refs = jnp.full((jr.num_slots(DEV_CFG), 1), -1, jnp.int32)
    dst, _ = jr.recover(DEV_CFG, pers, refs)
    assert host_occupancy(r) == dev_occupancy(dst), "post-recovery drift"
    assert_free_runs_agree(r, dst)

    ptr = r.malloc(2 * SB_SIZE - 256)
    dst, off = _alloc_large(state=dst, nwords=jnp.int32(2 * DEV_SB_WORDS - 4))
    assert (ptr is None) == (int(off) < 0)
    if ptr is not None:
        assert r.heap.sb_of(ptr) == int(off) // DEV_SB_WORDS, \
            "post-recovery placement drift"
    assert_persist_clean(r)      # trace + recovery + post-recovery ops


def test_differential_best_fit_prefers_smallest_run():
    """Constructed fragmentation: [2-run][live][3-run][live][2-run] free
    pattern — a 2-sb request must take a 2-run (best fit), never split
    the 3-run; both sides must agree on which one."""
    ops = [(False, 2), (False, 1), (False, 3), (False, 1), (False, 2),
           (True, 0)]                 # frees the first 2-span → run at 0
    r, dst, live = replay(ops)
    # free the 3-span (index 1 after the pop in replay: live holds
    # [1-span@2, 3-span@3, 1-span@6, 2-span@7]) → runs: (0,2) and (3,3)
    ptr, off, _ = live.pop(1)
    r.free(ptr)
    dst = _free_large(state=dst, off=jnp.int32(off))
    assert recovery.free_superblock_runs(r) == [(0, 2), (3, 3)]
    assert_free_runs_agree(r, dst)
    # a 2-sb request: best fit takes (0, 2) exactly, leaving (3, 3) whole
    p2 = r.malloc(2 * SB_SIZE - 256)
    dst, o2 = _alloc_large(state=dst, nwords=jnp.int32(2 * DEV_SB_WORDS - 4))
    assert r.heap.sb_of(p2) == int(o2) // DEV_SB_WORDS == 0
    assert recovery.free_superblock_runs(r) == [(3, 3)]
    # a 3-sb request then lands exactly on the preserved 3-run
    p3 = r.malloc(3 * SB_SIZE - 256)
    dst, o3 = _alloc_large(state=dst, nwords=jnp.int32(3 * DEV_SB_WORDS - 4))
    assert r.heap.sb_of(p3) == int(o3) // DEV_SB_WORDS == 3
    assert host_occupancy(r) == dev_occupancy(dst)


_acquire_span = jax.jit(functools.partial(ja.acquire_span, cfg=DEV_CFG))
_trim_large = jax.jit(functools.partial(ja.trim_large, cfg=DEV_CFG))


def _host_ext(r, ptr):
    """Current persisted extent (sbs) of the host span at ``ptr``."""
    return r.span_extent(ptr)


def assert_lease_lockstep(r, dst, live):
    """Per-superblock lease counts must agree three ways: host interval
    table == device ``span_refs`` vector == the naive count model implied
    by the outstanding leases (``sum(lease > i)``), and the device vector
    must be zero outside live spans."""
    expect = np.zeros((N_SBS,), np.int32)
    for ptr, off, _, leases in live:
        sb = off // DEV_SB_WORDS
        hext = _host_ext(r, ptr)
        dext = int(ja.span_sbs(DEV_CFG, int(dst.sb_block_words[sb])))
        assert hext == dext, f"extent drift on span at sb {sb}"
        model = [sum(1 for l in leases if min(l, hext) > i)
                 for i in range(hext)]
        assert r.span_lease_counts(ptr) == model, \
            f"host lease drift at sb {sb}"
        expect[sb:sb + hext] = model
    assert np.asarray(dst.span_refs)[:N_SBS].tolist() == expect.tolist(), \
        "device lease-vector drift"


def replay_events(events):
    """Drive both allocators through an acquire/trim/partial-release
    trace in lock-step.

    Beyond ``replay``: spans carry range leases.  ``acquire`` leases the
    oldest live span's full extent on both sides; ``acquire_prefix``
    leases only a ``k``-clamped prefix; ``trim`` shrinks one full-extent
    lease of the oldest span to a ``k``-clamped prefix (the unleased
    tail frees on both sides); ``free`` releases the oldest span's
    oldest outstanding lease — a release that leaves every range leased
    must be a pure transient decrement on both sides (occupancy
    unchanged), while an unleased tail (or the head range's last
    release) must free identically.  Per-superblock lease counts are
    asserted in lock-step against a naive count model at every event.
    Returns (host, device state, live [[ptr, off, k, leases]]).
    """
    r = Ralloc(None, N_SBS * SB_SIZE)
    r._persist_tracer = attach_tracer(r)
    dst = ja.init_state(DEV_CFG, max_roots=64)
    live = []       # [ptr, off, k, [lease_sbs, ...]]
    for op, k in events:
        if op in ("acquire", "acquire_prefix") and live:
            ent = live[0]
            ext = _host_ext(r, ent[0])
            n = ext if op == "acquire" else max(1, min(k, ext))
            r.span_acquire(ent[0], n)
            dst, ok = _acquire_span(state=dst, off=jnp.int32(ent[1]),
                                    n_sbs=jnp.int32(n))
            assert bool(ok)
            ent[3].append(n)
        elif op == "trim" and live:
            ent = live[0]
            ext = _host_ext(r, ent[0])
            if ext <= 1:
                continue
            n_keep = max(1, min(k, ext - 1))
            before = dev_occupancy(dst)
            r.span_trim(ent[0], n_keep)
            dst, ok = _trim_large(state=dst, off=jnp.int32(ent[1]),
                                  n_keep=jnp.int32(n_keep))
            assert bool(ok)
            # exactly one full-extent lease shrank (trim's contract)…
            full = [i for i, l in enumerate(ent[3]) if min(l, ext) == ext]
            ent[3][full[0]] = n_keep
            # …so with another full lease outstanding nothing may move
            if len(full) > 1:
                assert dev_occupancy(dst) == before, \
                    "covered trim disturbed device occupancy"
        elif op == "free" and live:
            ent = live[0]
            ext = _host_ext(r, ent[0])
            lease = min(ent[3].pop(0), ext)
            before = dev_occupancy(dst)
            r.span_release(ent[0], lease)
            dst = _free_large(state=dst, off=jnp.int32(ent[1]),
                              n_sbs=jnp.int32(lease))
            still = [min(l, ext) for l in ent[3]]
            if still and max(still) == ext:
                # every range still leased: pure transient decrement
                assert dev_occupancy(dst) == before, \
                    "covered release disturbed device occupancy"
            if not ent[3]:
                live.pop(0)
        elif op == "alloc" or not live:
            ptr = r.malloc(k * SB_SIZE - 256)
            dst, off = _alloc_large(state=dst,
                                    nwords=jnp.int32(k * DEV_SB_WORDS - 4))
            off = int(off)
            assert (ptr is None) == (off < 0), "serveability drift"
            if ptr is None:
                continue
            assert r.heap.sb_of(ptr) == off // DEV_SB_WORDS, "placement drift"
            live.append([ptr, off, k, [k]])
        assert host_occupancy(r) == dev_occupancy(dst), "occupancy drift"
        assert_lease_lockstep(r, dst, live)
    assert_persist_clean(r)
    return r, dst, live


EVENT = st.tuples(st.sampled_from(["alloc", "acquire", "acquire_prefix",
                                   "trim", "free"]),
                  st.integers(1, 4))


@settings(max_examples=12, deadline=None)
@given(st.lists(EVENT, min_size=2, max_size=30))
def test_differential_refcounted_trace_lockstep(events):
    """Acquire/prefix-acquire/trim/partial-release events stay in
    lock-step, and recovery of a heap with range-leased spans
    reconstructs every per-range count exactly: no range freed while
    leased, none retained with zero leases."""
    r, dst, live = replay_events(events)
    assert_free_runs_agree(r, dst)

    # root every live span once per outstanding lease — the durable image
    # a crash would leave (each holder's root is its reference); recovery
    # must rebuild, on EVERY member superblock, count = root-reachable
    # references to the head (lease lengths are transient, so each
    # reference conservatively becomes a full-extent lease)
    roots = np.full((64,), -1, np.int32)
    i = 0
    for ptr, off, _, leases in live:
        for _ in leases:
            r.set_root(i, ptr)
            roots[i] = off
            i += 1
    r.recover()
    pers = ja.persistent_snapshot(dst)
    pers["roots"] = jnp.asarray(roots)
    refs_tab = jnp.full((jr.num_slots(DEV_CFG), 1), -1, jnp.int32)
    dst, _ = jr.recover(DEV_CFG, pers, refs_tab)
    assert host_occupancy(r) == dev_occupancy(dst), "post-recovery drift"
    assert_free_runs_agree(r, dst)
    for ptr, off, _, leases in live:
        sb = off // DEV_SB_WORDS
        ext = _host_ext(r, ptr)
        want = [len(leases)] * ext
        assert r.span_lease_counts(ptr) == want, \
            "host reconstructed per-range lease drift"
        assert np.asarray(dst.span_refs)[sb:sb + ext].tolist() == want, \
            "device reconstructed per-range lease drift"
    # no zero-lease span survived: every live device member carries >= 1
    dev_heads = np.nonzero(np.asarray(dst.sb_class) == ja.LARGE_CLS)[0]
    assert all(int(dst.span_refs[h]) >= 1 for h in dev_heads)
    assert len(dev_heads) == len(live)

    # the released-to-zero spans really freed: both sides place the next
    # span identically (free sets agree all the way down)
    ptr = r.malloc(2 * SB_SIZE - 256)
    dst, off = _alloc_large(state=dst, nwords=jnp.int32(2 * DEV_SB_WORDS - 4))
    assert (ptr is None) == (int(off) < 0)
    if ptr is not None:
        assert r.heap.sb_of(ptr) == int(off) // DEV_SB_WORDS
    assert_persist_clean(r)      # trace + recovery + post-recovery ops


def test_differential_shared_free_keeps_span_placed():
    """Deterministic: a twice-acquired span pinned between two live spans
    survives two releases in place, then frees on the third — and the
    freed run is found again by both placement searches."""
    r, dst, live = replay_events([
        ("alloc", 1), ("alloc", 2), ("alloc", 1),
        ("free", 0),                       # span@0 released → freed
        ("acquire", 0), ("acquire", 0),    # span@1 (now oldest): 3 leases
    ])
    assert [len(e[3]) for e in live] == [3, 1]
    r2, dst2, live2 = replay_events([
        ("alloc", 1), ("alloc", 2), ("alloc", 1),
        ("free", 0), ("acquire", 0), ("acquire", 0),
        ("free", 0), ("free", 0),          # two shared frees: still placed
    ])
    assert [len(e[3]) for e in live2] == [1, 1]
    assert recovery.free_superblock_runs(r2) == [(0, 1)]
    r2.free(live2[0][0])                   # last release → the 2-run frees
    dst2 = _free_large(state=dst2, off=jnp.int32(live2[0][1]))
    assert recovery.free_superblock_runs(r2) == [(0, 3)]
    assert_free_runs_agree(r2, dst2)
    # host raise vs device masked no-op carries over to the *last* free
    with pytest.raises(ValueError):
        r2.free(live2[0][0])
    before = dev_occupancy(dst2)
    dst2 = _free_large(state=dst2, off=jnp.int32(live2[0][1]))
    assert dev_occupancy(dst2) == before


def test_differential_prefix_lease_tail_trim():
    """Deterministic tentpole scenario: a follower leases only the 1-sb
    prefix of a 3-sb span; the owner's release frees exactly the 2-sb
    decode-ahead tail on BOTH sides, the freed tail is re-placed
    identically, and the prefix frees only at the follower's release."""
    r, dst, live = replay_events([
        ("alloc", 3),
        ("acquire_prefix", 1),             # follower: [head] only
        ("free", 0),                       # owner (lease 3) exits
    ])
    assert [len(e[3]) for e in live] == [1]
    assert recovery.free_superblock_runs(r) == [(1, 2)]
    assert_free_runs_agree(r, dst)
    # both sides re-place a 2-sb span into the freed tail
    p = r.malloc(2 * SB_SIZE - 256)
    dst, o = _alloc_large(state=dst, nwords=jnp.int32(2 * DEV_SB_WORDS - 4))
    assert r.heap.sb_of(p) == int(o) // DEV_SB_WORDS == 1
    # follower exits → the prefix frees; over-release past the last
    # lease keeps the documented asymmetry (host raises, device no-ops)
    r.span_release(live[0][0], 1)
    dst = _free_large(state=dst, off=jnp.int32(live[0][1]),
                      n_sbs=jnp.int32(1))
    assert recovery.free_superblock_runs(r) == [(0, 1)]
    assert_free_runs_agree(r, dst)
    with pytest.raises(ValueError):
        r.span_release(live[0][0], 1)
    before = dev_occupancy(dst)
    dst = _free_large(state=dst, off=jnp.int32(live[0][1]),
                      n_sbs=jnp.int32(1))
    assert dev_occupancy(dst) == before


def test_differential_trim_lockstep():
    """Deterministic: trims free the same tail superblocks on both sides
    mid-trace, and the trimmed extent survives recovery identically."""
    r, dst, live = replay_events([
        ("alloc", 4), ("alloc", 1),
        ("trim", 2),                       # span@0 keeps [0, 2)
        ("alloc", 2),                      # best-fit lands on the tail
    ])
    assert recovery.free_superblock_runs(r) == []
    assert live[2][1] // DEV_SB_WORDS == 2   # re-placed into trimmed tail
    assert_free_runs_agree(r, dst)


# ---------------------------------------------------------------------------
# durable prefix-index traces (PR 5): publish / crash / re-publish lockstep
# ---------------------------------------------------------------------------
from repro.core.prefix_index import REC_BYTES, PrefixIndex  # noqa: E402

_alloc_small = jax.jit(functools.partial(ja.alloc, cfg=DEV_CFG, cls=0))


def _pin_record_sb(r, dst):
    """Pin superblock 0 on both sides as the record superblock.

    Host prefix-index records are small allocator blocks; the first one
    would claim a superblock for the record size class — an event the
    device (whose records are sidecar rows, not blocks) never mirrors.
    Claiming sb 0 up front on BOTH sides (one permanently-rooted block
    each) keeps every later occupancy/free-run/placement comparison
    symmetric: all span traffic sits above sb 0, and host record churn
    stays inside sb 0's block cache with zero superblock traffic.
    """
    warm = r.malloc(REC_BYTES)
    assert r.heap.sb_of(warm) == 0
    r.set_root(62, warm)
    dst, offs = _alloc_small(state=dst, need=jnp.ones((1,), bool))
    warm_dev = int(np.asarray(offs)[0])
    assert warm_dev // DEV_SB_WORDS == 0
    return warm, warm_dev, dst


def replay_publish_events(events):
    """Drive both allocators through an acquire/release/publish trace in
    lock-step, with the host running a real durable ``PrefixIndex``.

    Device records are modeled by their recovery-visible effect: one
    durable root naming the span head per record (the identical
    reference-count contribution) plus the recorded lease length
    replayed as ``trim_large`` after recovery — the exact sequence the
    serving engine performs from its ``PrefixStore``.

    Returns ``(host, idx, device state, spans, published, warm_dev)``
    with ``spans`` entries ``[ptr, off, k, holder_leases,
    publish_leases]`` and ``published`` entries ``(key, ptr, off,
    lease_sbs)`` (oldest first).
    """
    r = Ralloc(None, N_SBS * SB_SIZE, expand_sbs=1)
    r._persist_tracer = attach_tracer(r)
    idx = PrefixIndex(r)
    dst = ja.init_state(DEV_CFG, max_roots=64)
    warm, warm_dev, dst = _pin_record_sb(r, dst)
    spans = []          # [ptr, off, k, holder_leases, publish_leases]
    published = []      # (key, ptr, off, lease_sbs)
    next_key = 0x10
    for op, k in events:
        if op in ("acquire", "acquire_prefix") and spans:
            ent = spans[0]
            ext = _host_ext(r, ent[0])
            n = ext if op == "acquire" else max(1, min(k, ext))
            r.span_acquire(ent[0], n)
            dst, ok = _acquire_span(state=dst, off=jnp.int32(ent[1]),
                                    n_sbs=jnp.int32(n))
            assert bool(ok)
            ent[3].append(n)
        elif op == "publish" and spans:
            ent = spans[0]
            ext = _host_ext(r, ent[0])
            n = max(1, min(k, ext))
            key = next_key
            next_key += 1
            # host: transient lease + durable record; device: the cache's
            # transient lease (its durable shadow is modeled at recovery)
            assert idx.publish(key, ent[0], n_pages=n,
                               lease_sbs=n) is not None
            dst, ok = _acquire_span(state=dst, off=jnp.int32(ent[1]),
                                    n_sbs=jnp.int32(n))
            assert bool(ok)
            ent[4].append(n)
            published.append((key, ent[0], ent[1], n))
        elif op == "unpublish" and published:
            key, ptr, off, n = published.pop(0)
            ent = next(e for e in spans if e[0] == ptr)
            before = dev_occupancy(dst)
            assert idx.remove(key)          # unlink → release → block free
            dst = _free_large(state=dst, off=jnp.int32(off),
                              n_sbs=jnp.int32(n))
            ent[4].remove(n)
            if ent[3] or ent[4]:
                ext = _host_ext(r, ptr)
                still = [min(l, ext) for l in ent[3] + ent[4]]
                if still and max(still) == ext:
                    assert dev_occupancy(dst) == before, \
                        "covered unpublish disturbed device occupancy"
            else:
                spans.pop(spans.index(ent))
        elif op == "free" and spans and spans[0][3]:
            ent = spans[0]
            ext = _host_ext(r, ent[0])
            lease = min(ent[3].pop(0), ext)
            r.span_release(ent[0], lease)
            dst = _free_large(state=dst, off=jnp.int32(ent[1]),
                              n_sbs=jnp.int32(lease))
            if not ent[3] and not ent[4]:
                spans.pop(0)
        elif op == "alloc" or not spans:
            ptr = r.malloc(k * SB_SIZE - 256)
            dst, off = _alloc_large(state=dst,
                                    nwords=jnp.int32(k * DEV_SB_WORDS - 4))
            off = int(off)
            assert (ptr is None) == (off < 0), "serveability drift"
            if ptr is None:
                continue
            assert r.heap.sb_of(ptr) == off // DEV_SB_WORDS, "placement drift"
            spans.append([ptr, off, k, [k], []])
        assert host_occupancy(r) == dev_occupancy(dst), "occupancy drift"
        # naive per-sb count model over ALL outstanding leases (holders
        # AND publishes — the cache lease counts like any other)
        assert_lease_lockstep(r, dst,
                              [[p, o, kk, h + pub]
                               for p, o, kk, h, pub in spans])
    assert_persist_clean(r)
    return r, idx, dst, spans, published, warm_dev


def recover_both_with_index(r, dst, spans, published, warm_dev):
    """Crash both sides and recover.  Host: durable roots (one per
    holder lease) + the real index records; ``recover()`` re-trims
    record leases from their recorded lengths.  Device: the same durable
    reference set (records stand in as roots) + explicit ``trim_large``
    per record — the engine's recovery sequence."""
    roots = np.full((64,), -1, np.int32)
    i = 0
    for ptr, off, _, holders, _pubs in spans:
        for _ in holders:
            r.set_root(i, ptr)
            roots[i] = off
            i += 1
    for _key, _ptr, off, _n in published:
        roots[i] = off                      # the record's device stand-in
        i += 1
    assert i <= 62
    roots[62] = warm_dev                    # the pinned record superblock
    r.recover()                             # auto re-trim (typed root)
    pers = ja.persistent_snapshot(dst)
    pers["roots"] = jnp.asarray(roots)
    refs_tab = jnp.full((jr.num_slots(DEV_CFG), 1), -1, jnp.int32)
    dst, _ = jr.recover(DEV_CFG, pers, refs_tab)
    for _key, _ptr, off, n in published:
        dst, _ok = _trim_large(state=dst, off=jnp.int32(off),
                               n_keep=jnp.int32(n), n_held=jnp.int32(-1))
    return dst


def assert_post_recovery_index_model(r, dst, spans, published):
    """Post-recovery lease vectors must equal the index-derived model:
    holder roots rebuild full-extent, records rebuild re-trimmed to
    their recorded lengths (clamped to the durable extent)."""
    for ptr, off, _, holders, _pubs in spans:
        sb = off // DEV_SB_WORDS
        ext = _host_ext(r, ptr)
        dext = int(ja.span_sbs(DEV_CFG, int(dst.sb_block_words[sb])))
        assert ext == dext, f"post-recovery extent drift at sb {sb}"
        recs = [n for _k, p, _o, n in published if p == ptr]
        want = [len(holders) + sum(1 for n in recs if n > i)
                for i in range(ext)]
        assert r.span_lease_counts(ptr) == want, \
            f"host post-recovery lease drift at sb {sb}"
        assert np.asarray(dst.span_refs)[sb:sb + ext].tolist() == want, \
            f"device post-recovery lease drift at sb {sb}"


EVENT_PUB = st.tuples(st.sampled_from(["alloc", "acquire",
                                       "acquire_prefix", "free",
                                       "publish", "unpublish"]),
                      st.integers(1, 4))


@settings(max_examples=12, deadline=None)
@given(st.lists(EVENT_PUB, min_size=2, max_size=30))
def test_differential_publish_crash_republish_lockstep(events):
    """Satellite: publish/crash/re-publish traces through both
    allocators — post-recovery, the re-trimmed lease vectors match the
    naive per-sb count model with index-derived lengths, and a fresh
    publish on the recovered heap stays in lock-step."""
    r, idx, dst, spans, published, warm_dev = replay_publish_events(events)
    assert_free_runs_agree(r, dst)

    dst = recover_both_with_index(r, dst, spans, published, warm_dev)
    assert host_occupancy(r) == dev_occupancy(dst), "post-recovery drift"
    assert_free_runs_agree(r, dst)
    # host records really survived (count them against the model)
    assert len(idx.records()) == len(published)
    assert_post_recovery_index_model(r, dst, spans, published)

    # re-publish on a surviving span: lock-step continues on the
    # recovered heap (no placement or lease drift)
    if spans:
        ptr, off = spans[0][0], spans[0][1]
        ext = _host_ext(r, ptr)
        assert idx.publish(0xFFFF, ptr, n_pages=1, lease_sbs=1) is not None
        dst, ok = _acquire_span(state=dst, off=jnp.int32(off),
                                n_sbs=jnp.int32(1))
        assert bool(ok)
        assert r.span_lease_counts(ptr)[0] == \
            int(np.asarray(dst.span_refs)[off // DEV_SB_WORDS])
        assert host_occupancy(r) == dev_occupancy(dst)
    # both sides place the next span identically (free sets agree)
    p = r.malloc(2 * SB_SIZE - 256)
    dst, o = _alloc_large(state=dst, nwords=jnp.int32(2 * DEV_SB_WORDS - 4))
    assert (p is None) == (int(o) < 0)
    if p is not None:
        assert r.heap.sb_of(p) == int(o) // DEV_SB_WORDS
    assert_persist_clean(r)      # trace + recovery + re-publish


def test_differential_record_only_span_retrims_after_crash():
    """Deterministic tentpole scenario: every holder of a published span
    exits, the record alone keeps it alive across a crash, and recovery
    re-trims the record's full-extent reconstruction down to the
    published prefix on BOTH sides — the decode-ahead tail frees at
    recovery, not when some lane re-finishes."""
    r, idx, dst, spans, published, warm_dev = replay_publish_events([
        ("alloc", 3),
        ("publish", 1),                    # 1-sb published prefix
        ("free", 0),                       # owner exits: tail frees NOW
    ])
    assert [e[3] for e in spans] == [[]] and [e[4] for e in spans] == [[1]]
    assert recovery.free_superblock_runs(r) == [(2, 2)]
    assert_free_runs_agree(r, dst)

    dst = recover_both_with_index(r, dst, spans, published, warm_dev)
    # the record is the span's only durable reference; its lease came
    # back at the trimmed 1-sb extent (durably shrunk pre-crash)
    ptr, off = spans[0][0], spans[0][1]
    assert _host_ext(r, ptr) == 1
    assert r.span_lease_counts(ptr) == [1]
    assert np.asarray(dst.span_refs)[off // DEV_SB_WORDS] == 1
    assert_free_runs_agree(r, dst)
    # unpublish on the recovered heap frees the prefix on both sides
    assert idx.remove(published[0][0])
    dst = _free_large(state=dst, off=jnp.int32(off), n_sbs=jnp.int32(1))
    assert recovery.free_superblock_runs(r) == [(1, 3)]
    assert_free_runs_agree(r, dst)
    assert_persist_clean(r)      # trace + recovery + unpublish


@pytest.mark.slow
@settings(max_examples=40, deadline=None)
@given(st.lists(EVENT_PUB, min_size=5, max_size=60))
def test_differential_publish_trace_deep(events):
    """Deep publish-event sweep for the non-blocking slow CI job."""
    r, idx, dst, spans, published, warm_dev = replay_publish_events(events)
    assert_free_runs_agree(r, dst)
    dst = recover_both_with_index(r, dst, spans, published, warm_dev)
    assert host_occupancy(r) == dev_occupancy(dst)
    assert_post_recovery_index_model(r, dst, spans, published)
    assert_persist_clean(r)


@pytest.mark.slow
@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.booleans(), st.integers(1, 5)),
                min_size=5, max_size=60))
def test_differential_trace_lockstep_deep(ops):
    """Longer traces for the non-blocking slow CI job."""
    r, dst, _ = replay(ops)
    assert_free_runs_agree(r, dst)


@pytest.mark.slow
@settings(max_examples=40, deadline=None)
@given(st.lists(EVENT, min_size=5, max_size=60))
def test_differential_refcounted_trace_deep(events):
    """Deep refcounted-event sweep for the non-blocking slow CI job."""
    r, dst, _ = replay_events(events)
    assert_free_runs_agree(r, dst)
