"""Fragmentation regression: steady-state span churn must not grow the
watermark once the free set can satisfy requests.

This is the tentpole property the best-fit contiguous-run search buys:
the seed's watermark-only placement leaked address space on every
large-object cycle, so span-heavy serving churn deterministically
exhausted the arena even when it was almost entirely free.  Both
allocators (host ``ralloc`` and device ``jax_alloc``) are held to the
same bound here; the benchmark twin is ``benchmarks.workloads.fragbench``.
"""

import functools
import random
import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import jax_alloc as ja
from repro.core import layout
from repro.core.layout import SB_SIZE
from repro.core.ralloc import Ralloc

MB = 1 << 20
SIZES = (1, 2, 3, 4)
POOL = 10
ROUNDS = 120


def test_host_watermark_stable_under_span_churn():
    r = Ralloc(None, 64 * MB)
    rng = random.Random(0)
    held = []
    for _ in range(POOL):                      # warmup: populate the pool
        k = rng.choice(SIZES)
        p = r.malloc(k * SB_SIZE - 256)
        assert p is not None
        held.append((p, k))
    wm0 = int(r.mem.read(layout.M_USED_SBS))
    for i in range(ROUNDS):
        p, k = held.pop(rng.randrange(len(held)))
        r.free(p)                              # a k-run is now free
        q = r.malloc(k * SB_SIZE - 256)        # ⇒ a k-request must reuse it
        assert q is not None
        held.append((q, k))
        assert int(r.mem.read(layout.M_USED_SBS)) == wm0, \
            f"round {i}: watermark grew under satisfiable churn"
    # live spans stay disjoint through all that reuse
    spans = sorted((r.heap.sb_of(p), k) for p, k in held)
    for (a, ka), (b, _) in zip(spans, spans[1:]):
        assert a + ka <= b, "span overlap after churn"


def test_host_mixed_small_and_span_churn_watermark_stable():
    """Small-class pressure interleaved with span churn: freed spans must
    still be found (small allocations also consume the free list)."""
    r = Ralloc(None, 64 * MB)
    rng = random.Random(1)
    held, smalls = [], []
    for _ in range(POOL):
        k = rng.choice(SIZES)
        held.append((r.malloc(k * SB_SIZE - 256), k))
    for _ in range(200):
        smalls.append(r.malloc(4096))
    wm0 = int(r.mem.read(layout.M_USED_SBS))
    for i in range(60):
        p, k = held.pop(rng.randrange(len(held)))
        r.free(p)
        q = r.malloc(k * SB_SIZE - 256)
        assert q is not None
        held.append((q, k))
        smalls.append(r.malloc(4096))
        r.free(smalls.pop(0))
        assert int(r.mem.read(layout.M_USED_SBS)) == wm0, \
            f"round {i}: watermark grew"


def test_host_concurrent_span_churn_watermark_stable():
    """Placement is serialized (``_large_lock``): two racing span
    allocations must never both drain the free stack, miss the split run,
    and expand the watermark.  Same-size churn keeps every free run usable
    under any interleaving, so the watermark must stay exactly flat."""
    r = Ralloc(None, 64 * MB)
    T = 4
    held = [r.malloc(2 * SB_SIZE - 256) for _ in range(T)]
    assert None not in held
    wm0 = int(r.mem.read(layout.M_USED_SBS))
    errs = []

    def worker(t):
        try:
            p = held[t]
            for _ in range(60):
                r.free(p)
                p = r.malloc(2 * SB_SIZE - 256)
                assert p is not None
            held[t] = p
        except Exception as e:             # pragma: no cover
            errs.append(repr(e))

    ts = [threading.Thread(target=worker, args=(t,)) for t in range(T)]
    [t.start() for t in ts]
    [t.join() for t in ts]
    assert not errs, errs
    assert int(r.mem.read(layout.M_USED_SBS)) == wm0, \
        "concurrent churn grew the watermark (placement race)"
    assert len(set(held)) == T             # no double-placed spans


def test_small_refill_rechecks_free_list_under_placement_lock():
    """White-box regression: while a span placement holds the drained
    free stack (``_large_lock`` + empty list), a small-class refill must
    wait and re-check rather than expand the watermark — otherwise every
    such window durably leaks ``expand_sbs`` superblocks."""
    r = Ralloc(None, 64 * MB)
    p = r.malloc(2 * SB_SIZE - 256)
    r.free(p)                                  # free list now holds a 2-run
    wm0 = int(r.mem.read(layout.M_USED_SBS))
    # simulate a mid-placement claimer: hold the lock with the stack drained
    r._large_lock.acquire()
    drained = []
    while (sb := r._pop_list(layout.M_FREE_HEAD,
                             layout.D_NEXT_FREE)) is not None:
        drained.append(sb)
    assert drained
    got = []
    th = threading.Thread(target=lambda: got.append(r.malloc(256)))
    th.start()
    th.join(0.3)
    assert th.is_alive(), "refill expanded instead of waiting for placement"
    assert int(r.mem.read(layout.M_USED_SBS)) == wm0
    for sb in drained:                         # placement finishes: push back
        r._push_list(layout.M_FREE_HEAD, layout.D_NEXT_FREE, sb)
    r._large_lock.release()
    th.join()
    assert got and got[0] is not None
    assert int(r.mem.read(layout.M_USED_SBS)) == wm0, \
        "refill consumed fresh watermark despite a free superblock"


def test_device_watermark_stable_under_span_churn():
    cfg = ja.ArenaConfig(num_sbs=48, sb_words=64, class_words=(8,),
                         cache_cap=16, expand_sbs=1)
    alloc = jax.jit(functools.partial(ja.alloc_large, cfg=cfg))
    free = jax.jit(functools.partial(ja.free_large, cfg=cfg))
    st = ja.init_state(cfg)
    rng = random.Random(0)
    held = []
    for _ in range(POOL):
        k = rng.choice(SIZES)
        st, off = alloc(state=st, nwords=jnp.int32(k * 64 - 4))
        assert int(off) >= 0
        held.append((int(off), k))
    wm0 = int(st.used_sbs)
    for i in range(ROUNDS):
        off, k = held.pop(rng.randrange(len(held)))
        st = free(state=st, off=jnp.int32(off))
        st, off2 = alloc(state=st, nwords=jnp.int32(k * 64 - 4))
        assert int(off2) >= 0
        held.append((int(off2), k))
        assert int(st.used_sbs) == wm0, \
            f"round {i}: device watermark grew under satisfiable churn"
    assert ja.live_blocks(st, cfg)["large"] == POOL
    # spans disjoint
    spans = sorted((o // 64, k) for o, k in held)
    for (a, ka), (b, _) in zip(spans, spans[1:]):
        assert a + ka <= b


def test_device_best_fit_leaves_large_runs_intact():
    """Shrinking requests into a fragmented arena: best-fit keeps the big
    run available for the big request that arrives last (first-fit would
    have split it and failed)."""
    cfg = ja.ArenaConfig(num_sbs=12, sb_words=64, class_words=(8,),
                         cache_cap=16, expand_sbs=1)
    st = ja.init_state(cfg)
    offs = []
    for k in (2, 1, 4, 1, 2, 1):               # fill 11 of 12 sbs
        st, o = ja.alloc_large(st, cfg, jnp.int32(k * 64 - 4))
        offs.append((int(o), k))
    st = ja.free_large(st, cfg, jnp.int32(offs[0][0]))   # free the 2-run @0
    st = ja.free_large(st, cfg, jnp.int32(offs[2][0]))   # free the 4-run @3
    assert ja.free_runs(st, cfg) == [(0, 2), (3, 4)]
    st, o = ja.alloc_large(st, cfg, jnp.int32(2 * 64 - 4))
    assert int(o) // 64 == 0                   # best fit: the 2-run, not 4
    st, o = ja.alloc_large(st, cfg, jnp.int32(4 * 64 - 4))
    assert int(o) // 64 == 3                   # the 4-run survived whole
