"""Property tests for the transient placement indexes (device free-run
table + host hash-bucketed prefix chains).

Both indexes are *transient* — pure functions of persistent state that
recovery rebuilds — so each has a from-scratch oracle the incremental
maintenance must match exactly:

* device: after ANY op sequence, ``(run_len, run_start, run_bucket_min)``
  equals a recompute via ``free_run_table`` from ``(sb_class, used_sbs)``,
  and ``alloc_large`` places exactly where the retired suffix-min scan
  (``scan_best_fit``) would;
* host: bucketed ``PrefixIndex`` lookup agrees with a naive walk over
  every record and with a model dict, under publish/remove/dup-key mixes.

Deep variants (longer sequences, more examples) run under
``pytest -m slow``.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # container without dev deps
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import jax_alloc as ja
from repro.core import jax_recovery as jr
from repro.core.layout import SB_SIZE
from repro.core.prefix_index import PrefixIndex, hash_tokens, iter_records
from repro.core.ralloc import Ralloc

MB = 1 << 20

# run_buckets=4 with num_sbs=24: runs of length >= 4 land in the overflow
# bucket, so the masked-reduce fallback path gets constant exercise
CFG = ja.ArenaConfig(num_sbs=24, sb_words=64, class_words=(8,),
                     cache_cap=16, expand_sbs=2, run_buckets=4)

ALLOC = jax.jit(functools.partial(ja.alloc, cfg=CFG, cls=0))
FREE = jax.jit(functools.partial(ja.free, cfg=CFG, cls=0))
ALLOC_LARGE = jax.jit(functools.partial(ja.alloc_large, cfg=CFG))
FREE_LARGE = jax.jit(functools.partial(ja.free_large, cfg=CFG))
TRIM_LARGE = jax.jit(functools.partial(ja.trim_large, cfg=CFG))
SCAN = jax.jit(functools.partial(ja.scan_best_fit, cfg=CFG))


# ------------------------------------------------------------------ oracles
def assert_index_matches(stt, cfg=CFG):
    """Incremental run index == from-scratch recompute off persistent
    fields (the free-set invariant: free <=> FREE_CLS below used_sbs)."""
    ids = jnp.arange(cfg.num_sbs, dtype=jnp.int32)
    free = (stt.sb_class == ja.FREE_CLS) & (ids < stt.used_sbs)
    rl, rs = ja.free_run_table(free, cfg.num_sbs)
    np.testing.assert_array_equal(np.asarray(stt.run_len), np.asarray(rl))
    np.testing.assert_array_equal(np.asarray(stt.run_start), np.asarray(rs))
    np.testing.assert_array_equal(np.asarray(stt.run_bucket_min),
                                  np.asarray(ja._bucket_mins(cfg, rl)))


def run_device_ops(ops, check_every=True):
    """Interpret (kind, a, b) tuples as allocator ops, asserting the
    index oracle and the ``scan_best_fit`` placement oracle throughout."""
    stt = ja.init_state(CFG)
    small: list[int] = []
    spans: dict[int, int] = {}             # head word off -> held sbs
    for kind, a, b in ops:
        kind %= 5
        if kind == 0:                                       # small alloc
            need = jnp.asarray([(a >> i) & 1 for i in range(8)], bool)
            stt, offs = ALLOC(state=stt, need=need)
            small += [int(o) for o in np.asarray(offs) if o >= 0]
        elif kind == 1 and small:                           # small free
            k = min(len(small), 1 + a % 8)
            sel = [small.pop(b % len(small)) for _ in range(k)]
            offs = np.full(8, -1, np.int64)
            offs[:k] = sel
            stt = FREE(state=stt, offs=jnp.asarray(offs, jnp.int32),
                       mask=jnp.asarray(offs >= 0))
        elif kind == 2:                                     # large alloc
            nsb = 1 + a % 6
            nwords = nsb * CFG.sb_words - (b % CFG.sb_words)
            has, _, first = (bool(v) if i == 0 else int(v)
                             for i, v in enumerate(SCAN(state=stt, nsb=nsb)))
            wm_ok = int(stt.used_sbs) + nsb <= CFG.num_sbs
            stt, off = ALLOC_LARGE(state=stt, nwords=jnp.int32(nwords))
            off = int(off)
            if has:                  # indexed placement == scan placement
                assert off == first * CFG.sb_words
            elif wm_ok:
                assert off == int(np.asarray(stt.used_sbs) - nsb) \
                    * CFG.sb_words
            else:
                assert off == -1
            if off >= 0:
                spans[off] = nsb
        elif kind == 3 and spans:                           # large free
            off = sorted(spans)[a % len(spans)]
            spans.pop(off)
            stt = FREE_LARGE(state=stt, off=jnp.int32(off),
                             n_sbs=jnp.int32(-1))
        elif kind == 4 and spans:                           # trim
            cand = [o for o in sorted(spans) if spans[o] > 1]
            if cand:
                off = cand[a % len(cand)]
                n_keep = 1 + b % (spans[off] - 1)
                stt, ok = TRIM_LARGE(state=stt, off=jnp.int32(off),
                                     n_keep=jnp.int32(n_keep),
                                     n_held=jnp.int32(spans[off]))
                if bool(ok):
                    spans[off] = n_keep
        if check_every:
            assert_index_matches(stt)
    return stt, spans


def recover_and_check(stt, spans):
    """Crash-recover keeping every live span rooted; the swept state's
    rebuilt index must satisfy the same oracle, and the next placement
    must still match the scan."""
    pers = ja.persistent_snapshot(stt)
    roots = np.full((int(stt.roots.shape[0]),), -1, np.int32)
    for i, off in enumerate(sorted(spans)[:roots.shape[0]]):
        roots[i] = off
    pers["roots"] = jnp.asarray(roots)
    refs = np.full((jr.num_slots(CFG), 1), -1, np.int32)
    st2, _ = jr.recover(CFG, pers, jnp.asarray(refs))
    assert_index_matches(st2)
    has, _, first = SCAN(state=st2, nsb=1)
    st3, off = ALLOC_LARGE(state=st2, nwords=jnp.int32(CFG.sb_words))
    if bool(has):
        assert int(off) == int(first) * CFG.sb_words
    assert_index_matches(st3)


# --------------------------------------------- device run-index properties
@settings(max_examples=20, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 2 ** 16), st.integers(0, 2 ** 16),
                          st.integers(0, 2 ** 16)),
                max_size=30))
def test_run_index_matches_recompute(ops):
    stt, spans = run_device_ops(ops, check_every=True)
    recover_and_check(stt, spans)


@pytest.mark.slow
@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 2 ** 16), st.integers(0, 2 ** 16),
                          st.integers(0, 2 ** 16)),
                min_size=20, max_size=120))
def test_run_index_matches_recompute_deep(ops):
    stt, spans = run_device_ops(ops, check_every=False)
    assert_index_matches(stt)
    recover_and_check(stt, spans)


# ------------------------------------------- host bucketed-chain properties
def run_prefix_ops(ops, n_buckets):
    r = Ralloc(None, 8 * MB, expand_sbs=1)
    idx = PrefixIndex(r, n_buckets=n_buckets)
    spans = [r.malloc(SB_SIZE // 2) for _ in range(4)]
    model: dict[int, list[int]] = {}       # key -> span stack, newest last
    for kind, a in ops:
        key = hash_tokens([a % 12])        # tiny key space: collisions +
        kind %= 3                          # duplicate keys across buckets
        if kind == 0:
            span = spans[a % len(spans)]
            rec = idx.publish(key, span, n_pages=1 + a % 7, lease_sbs=1)
            if rec is not None:
                model.setdefault(key, []).append(span)
        elif kind == 1:
            removed = idx.remove(key)
            assert removed == bool(model.get(key))
            if removed:
                model[key].pop()           # remove unlinks newest first
        else:
            before = idx.walk_steps
            rec = idx.lookup(key)
            if model.get(key):
                assert rec is not None and rec.span == model[key][-1]
            else:
                assert rec is None
            # bucketed walk never visits more than its own chain
            chain = len(list(iter_records(r, idx._slot_of(key))))
            assert idx.walk_steps - before <= chain
    # every record hangs off the root its key hashes to
    for s in idx.slots:
        for rec in iter_records(r, s):
            assert idx._slot_of(rec.key) == s
    # final sweep: bucketed lookup == naive walk over ALL records
    naive: dict[int, object] = {}
    for rec in idx.records():              # bucket-major, newest first
        naive.setdefault(rec.key, rec)
    for k in set(naive) | set(model):
        got = idx.lookup(k)
        want = naive.get(k)
        assert (got is None) == (want is None)
        if got is not None:
            assert got.ptr == want.ptr and got.span == want.span
    assert sum(len(v) for v in model.values()) == len(idx.records())


@settings(max_examples=20, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 2 ** 16), st.integers(0, 2 ** 16)),
                max_size=40),
       st.sampled_from([1, 3, 4]))
def test_bucketed_lookup_matches_naive_walk(ops, n_buckets):
    run_prefix_ops(ops, n_buckets)


@pytest.mark.slow
@settings(max_examples=80, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 2 ** 16), st.integers(0, 2 ** 16)),
                min_size=30, max_size=150),
       st.sampled_from([2, 5, 8, 16]))
def test_bucketed_lookup_matches_naive_walk_deep(ops, n_buckets):
    run_prefix_ops(ops, n_buckets)
