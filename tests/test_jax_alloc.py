"""Device-side vectorized allocator + vectorized GC recovery."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import jax_alloc as ja
from repro.core import jax_recovery as jr

CFG = ja.ArenaConfig(num_sbs=32, sb_words=256, class_words=(8, 32),
                     cache_cap=128)


@pytest.fixture(scope="module")
def fns():
    return {
        (c, "alloc"): jax.jit(functools.partial(ja.alloc, cfg=CFG, cls=c))
        for c in (0, 1)
    } | {
        (c, "free"): jax.jit(functools.partial(ja.free, cfg=CFG, cls=c))
        for c in (0, 1)
    }


def test_randomized_invariants(fns):
    st = ja.init_state(CFG)
    L = 16
    rng = np.random.default_rng(0)
    live = {0: set(), 1: set()}
    for _ in range(150):
        cls = int(rng.integers(2))
        if rng.random() < 0.55:
            need = jnp.asarray(rng.random(L) < 0.7)
            st, offs = fns[(cls, "alloc")](state=st, need=need)
            got = np.asarray(offs)
            got = got[got >= 0]
            assert not (set(got.tolist()) & live[cls]), "double alloc"
            live[cls] |= set(got.tolist())
        else:
            pool = list(live[cls])
            k = min(len(pool), L)
            sel = rng.choice(pool, size=k, replace=False) if k else []
            offs = np.full(L, -1, np.int64)
            offs[:k] = sel
            st = fns[(cls, "free")](state=st, offs=jnp.asarray(offs, jnp.int32),
                                    mask=jnp.asarray(offs >= 0))
            live[cls] -= set(int(x) for x in sel)
    # cross-class word-range disjointness
    spans = sorted((o, o + CFG.class_words[c])
                   for c, s in live.items() for o in s)
    for (a0, a1), (b0, b1) in zip(spans, spans[1:]):
        assert a1 <= b0
    lb = ja.live_blocks(st, CFG)
    assert lb[0] == len(live[0]) and lb[1] == len(live[1])


def test_oom_partial_service(fns):
    tiny = ja.ArenaConfig(num_sbs=2, sb_words=64, class_words=(32,),
                          cache_cap=16, expand_sbs=1)
    alloc = jax.jit(functools.partial(ja.alloc, cfg=tiny, cls=0))
    st = ja.init_state(tiny)
    st, o1 = alloc(state=st, need=jnp.ones(4, bool))
    assert int((np.asarray(o1) >= 0).sum()) == 4   # 2 sbs × 2 blocks
    st, o2 = alloc(state=st, need=jnp.ones(4, bool))
    assert int((np.asarray(o2) >= 0).sum()) == 0   # exhausted → all -1


def test_vectorized_recovery(fns):
    st = ja.init_state(CFG)
    alloc0 = fns[(0, "alloc")]
    alloc1 = fns[(1, "alloc")]
    st, data = alloc0(state=st, need=jnp.ones(16, bool))
    st, tables = alloc1(state=st, need=jnp.asarray([True] * 4 + [False] * 12))
    data = np.asarray(data)
    tables = np.asarray(tables)[:4]

    S = jr.num_slots(CFG)
    refs = np.full((S, 4), -1, np.int32)
    minw = min(CFG.class_words)
    for i, t in enumerate(tables):
        refs[t // minw] = data[i * 4:(i + 1) * 4]
    roots = np.full((64,), -1, np.int32)
    roots[:4] = tables
    pers = ja.persistent_snapshot(st)
    pers["roots"] = jnp.asarray(roots)

    st2, marked = jax.jit(functools.partial(jr.recover, cfg=CFG))(
        persistent=pers, ref_table=jnp.asarray(refs))
    reach = set(tables.tolist()) | set(data.tolist())
    marked_offs = {int(s) * minw for s in np.nonzero(np.asarray(marked))[0]}
    assert marked_offs == reach
    lb = ja.live_blocks(st2, CFG)
    assert lb[0] == 16 and lb[1] == 4
    # fresh allocations never overlap recovered-live blocks
    got = set()
    for _ in range(20):
        st2, offs = alloc0(state=st2, need=jnp.ones(16, bool))
        offs = np.asarray(offs)
        got |= set(offs[offs >= 0].tolist())
    assert not (got & reach)


def test_large_alloc_crash_recovery_roundtrip():
    """alloc_large → write → crash → vectorized recover → read parity."""
    cfg = ja.ArenaConfig(num_sbs=16, sb_words=64, class_words=(8,),
                         cache_cap=32, expand_sbs=2)
    st = ja.init_state(cfg)
    st, off = jax.jit(functools.partial(ja.alloc_large, cfg=cfg))(
        state=st, nwords=jnp.int32(200))           # 4-superblock span
    off = int(off)
    assert off == 0
    assert np.asarray(st.sb_class)[:4].tolist() == \
        [ja.LARGE_CLS] + [ja.LARGE_CONT] * 3
    # the consumer's data array: write through the span's word offsets
    data = np.zeros((cfg.total_words,), np.int64)
    data[off:off + 200] = np.arange(200) + 7
    # some small blocks too — one rooted, the rest leaked
    st, smalls = jax.jit(functools.partial(ja.alloc, cfg=cfg, cls=0))(
        state=st, need=jnp.ones(8, bool))
    smalls = np.asarray(smalls)

    pers = ja.persistent_snapshot(st)
    roots = np.full((64,), -1, np.int32)
    roots[0] = off                                  # span head is a root
    roots[1] = int(smalls[0])
    pers["roots"] = jnp.asarray(roots)
    S = jr.num_slots(cfg)
    refs = jnp.full((S, 1), -1, jnp.int32)
    st2, marked = jax.jit(functools.partial(jr.recover, cfg=cfg))(
        persistent=pers, ref_table=refs)

    lb = ja.live_blocks(st2, cfg)
    assert lb["large"] == 1 and lb[0] == 1          # span + rooted small
    assert np.asarray(st2.sb_class)[:4].tolist() == \
        [ja.LARGE_CLS] + [ja.LARGE_CONT] * 3
    assert int(st2.sb_block_words[0]) == 200        # size record intact
    assert data[off:off + 200].tolist() == (np.arange(200) + 7).tolist()
    # fresh allocations (small or large) never overlap the live span
    alloc = jax.jit(functools.partial(ja.alloc, cfg=cfg, cls=0))
    got = []
    for _ in range(30):
        st2, o = alloc(state=st2, need=jnp.ones(8, bool))
        got += np.asarray(o)[np.asarray(o) >= 0].tolist()
    assert got and all(not (off <= g < off + 4 * cfg.sb_words) for g in got)
    # free the span: every superblock returns for reuse, markers cleared
    st2 = jax.jit(functools.partial(ja.free_large, cfg=cfg))(
        state=st2, off=jnp.int32(off))
    assert ja.live_blocks(st2, cfg)["large"] == 0
    assert np.asarray(st2.sb_class)[:4].tolist() == [-1] * 4


def test_large_alloc_watermark_exhaustion():
    """A contiguous request the watermark cannot satisfy returns -1 and
    leaves the state untouched (partial spans must never leak out)."""
    cfg = ja.ArenaConfig(num_sbs=4, sb_words=64, class_words=(8,),
                         cache_cap=16, expand_sbs=1)
    st = ja.init_state(cfg)
    st, ok_off = ja.alloc_large(st, cfg, jnp.int32(2 * 64))   # 2 of 4 sbs
    assert int(ok_off) == 0
    st, bad = ja.alloc_large(st, cfg, jnp.int32(3 * 64))      # needs 3 > 2
    assert int(bad) == -1
    assert int(st.used_sbs) == 2                              # unchanged
    assert np.asarray(st.sb_class)[2:].tolist() == [-1, -1]
    st, fit = ja.alloc_large(st, cfg, jnp.int32(2 * 64))      # exact fit
    assert int(fit) == 2 * 64
    assert ja.live_blocks(st, cfg)["large"] == 2


def test_large_alloc_reuses_freed_spans():
    """Regression: alloc/free cycles of large spans must not exhaust the
    arena — freed spans are found again by the contiguous-run search
    (watermark alone would leak every cycle and fail permanently)."""
    cfg = ja.ArenaConfig(num_sbs=6, sb_words=64, class_words=(8,),
                         cache_cap=16, expand_sbs=1)
    allocL = jax.jit(functools.partial(ja.alloc_large, cfg=cfg))
    freeL = jax.jit(functools.partial(ja.free_large, cfg=cfg))
    st = ja.init_state(cfg)
    for i in range(10):                       # 10 cycles ≫ 3 sbs of slack
        st, off = allocL(state=st, nwords=jnp.int32(2 * 64))
        assert int(off) >= 0, f"cycle {i} exhausted the arena"
        st = freeL(state=st, off=off)
    # two live spans + one freed-and-reallocated span still coexist
    st, a = allocL(state=st, nwords=jnp.int32(2 * 64))
    st, b = allocL(state=st, nwords=jnp.int32(2 * 64))
    st = freeL(state=st, off=a)
    st, c = allocL(state=st, nwords=jnp.int32(2 * 64))
    assert int(b) >= 0 and int(c) >= 0 and int(c) != int(b)
    assert ja.live_blocks(st, cfg)["large"] == 2
    # small allocations still work off the remaining superblocks
    st, offs = ja.alloc(st, cfg, 0, jnp.ones(4, bool))
    assert int((np.asarray(offs) >= 0).sum()) == 4


def test_span_refcounts_share_and_reconstruct():
    """Device span refcounts: ``acquire_span`` increments, a shared
    ``free_large`` decrements without moving anything, the last release
    frees, invalid acquires are masked no-ops, and vectorized recovery
    reconstructs the count from root-reachable references alone."""
    cfg = ja.ArenaConfig(num_sbs=8, sb_words=64, class_words=(8,),
                         cache_cap=16, expand_sbs=1)
    st = ja.init_state(cfg)
    st, off = ja.alloc_large(st, cfg, jnp.int32(2 * 64))
    off = int(off)
    assert int(st.span_refs[0]) == 1
    st, ok = ja.acquire_span(st, cfg, jnp.int32(off))
    assert bool(ok) and int(st.span_refs[0]) == 2
    # masked no-ops: interior-of-head, continuation, free superblock,
    # negative — the host raises on all of these; the device must no-op,
    # never silently succeed (refcount drift between the two sides)
    for bad in (off + 3, off + 64, 5 * 64, -1):
        st, ok = ja.acquire_span(st, cfg, jnp.int32(bad))
        assert not bool(ok)
    assert int(st.span_refs[0]) == 2

    st = ja.free_large(st, cfg, jnp.int32(off))      # shared → decrement
    assert int(st.span_refs[0]) == 1
    assert np.asarray(st.sb_class)[:2].tolist() == \
        [ja.LARGE_CLS, ja.LARGE_CONT]                # still placed

    # crash with two holders: two roots reference the head; the count
    # must come back as exactly 2 (nothing about it was ever persisted)
    st2, _ = ja.acquire_span(st, cfg, jnp.int32(off))
    pers = ja.persistent_snapshot(st2)
    roots = np.full((64,), -1, np.int32)
    roots[0] = roots[1] = off
    pers["roots"] = jnp.asarray(roots)
    refs = jnp.full((jr.num_slots(cfg), 1), -1, jnp.int32)
    rec, _ = jr.recover(cfg, pers, refs)
    assert int(rec.span_refs[0]) == 2
    rec = ja.free_large(rec, cfg, jnp.int32(off))    # holder 1 leaves
    assert ja.live_blocks(rec, cfg)["large"] == 1
    rec = ja.free_large(rec, cfg, jnp.int32(off))    # last holder frees
    assert ja.live_blocks(rec, cfg)["large"] == 0
    assert int(rec.span_refs[0]) == 0
    assert np.asarray(rec.sb_class)[:2].tolist() == [-1, -1]


def test_free_large_over_release_asymmetry_at_last_lease():
    """Satellite: the documented ``free_large`` raise-vs-masked-no-op
    asymmetry at the *last* lease, pinned directly (not via the fuzz
    trace): releasing past the holder count raises on the host but is a
    state-preserving no-op on the device — for a plain double free, for
    an over-release after a shared holder left, and for a range release
    on the already-freed span."""
    from repro.core.layout import SB_SIZE
    from repro.core.ralloc import Ralloc

    cfg = ja.ArenaConfig(num_sbs=8, sb_words=64, class_words=(8,),
                         cache_cap=16, expand_sbs=1)
    st = ja.init_state(cfg)
    r = Ralloc(None, 8 * SB_SIZE)
    ptr = r.malloc(2 * SB_SIZE - 256)
    st, off = ja.alloc_large(st, cfg, jnp.int32(2 * 64))
    # one shared holder joins and leaves again: the *last* lease is the
    # owner's, so the very next release frees — and one more past it is
    # the over-release both sides must handle per the feature matrix
    r.span_acquire(ptr)
    st, _ = ja.acquire_span(st, cfg, off)
    r.free(ptr)
    st = ja.free_large(st, cfg, off)
    r.free(ptr)                                      # last lease → frees
    st = ja.free_large(st, cfg, off)
    assert np.asarray(st.sb_class)[:2].tolist() == [-1, -1]
    import pytest
    with pytest.raises(ValueError):
        r.free(ptr)                                  # host: raises
    before = jax.tree.map(lambda a: np.asarray(a).copy(), st)
    st = ja.free_large(st, cfg, off)                 # device: masked no-op
    for a, b in zip(jax.tree.leaves(before), jax.tree.leaves(st)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    # a *range* over-release on the dead span: same asymmetry
    with pytest.raises(ValueError):
        r.span_release(ptr, n_sbs=1)
    st = ja.free_large(st, cfg, off, jnp.int32(1))
    for a, b in zip(jax.tree.leaves(before), jax.tree.leaves(st)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_device_prefix_lease_and_trim():
    """Per-superblock lease vector semantics: a prefix ``acquire_span``
    bumps only its range, the owner's release frees the unleased tail
    (shrinking the head's size record like the host's durable trim), and
    ``trim_large`` invalid targets are masked no-ops."""
    cfg = ja.ArenaConfig(num_sbs=10, sb_words=64, class_words=(8,),
                         cache_cap=16, expand_sbs=1)
    st = ja.init_state(cfg)
    st, off = ja.alloc_large(st, cfg, jnp.int32(4 * 64))
    assert np.asarray(st.span_refs)[:4].tolist() == [1, 1, 1, 1]
    st, ok = ja.acquire_span(st, cfg, off, jnp.int32(2))
    assert bool(ok)
    assert np.asarray(st.span_refs)[:4].tolist() == [2, 2, 1, 1]
    st = ja.free_large(st, cfg, off)                 # owner: full release
    assert np.asarray(st.span_refs)[:4].tolist() == [1, 1, 0, 0]
    assert np.asarray(st.sb_class)[:4].tolist() == \
        [ja.LARGE_CLS, ja.LARGE_CONT, ja.FREE_CLS, ja.FREE_CLS]
    assert int(st.sb_block_words[0]) == 2 * 64       # extent shrank
    assert ja.free_runs(st, cfg) == [(2, 2)]
    st = ja.free_large(st, cfg, off, jnp.int32(2))   # follower leaves
    assert np.asarray(st.sb_class)[:4].tolist() == [-1] * 4

    # trim: keep 1 of 3, tail returns; invalid trims are masked no-ops
    st, off = ja.alloc_large(st, cfg, jnp.int32(3 * 64))
    st, ok = ja.trim_large(st, cfg, off, jnp.int32(1))
    assert bool(ok)
    assert int(st.sb_block_words[int(off) // 64]) == 64
    for bad_off, bad_keep in ((off, 0), (off, 9), (off + 3, 1),
                              (9 * 64, 1)):
        st2, ok = ja.trim_large(st, cfg, jnp.int32(bad_off),
                                jnp.int32(bad_keep))
        assert not bool(ok)
        for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(st2)):
            assert np.array_equal(np.asarray(a), np.asarray(b))
    st = ja.free_large(st, cfg, off, jnp.int32(1))

    # a re-trim while another holder pins the extent passes n_held: only
    # the caller's own [n_keep, n_held) range releases (host mirror of
    # Ralloc.span_trim(n_held=…))
    st, off = ja.alloc_large(st, cfg, jnp.int32(4 * 64))
    sb0 = int(off) // 64
    st, _ = ja.acquire_span(st, cfg, off)            # follower: full extent
    st, ok = ja.trim_large(st, cfg, off, jnp.int32(3))
    assert bool(ok)
    assert np.asarray(st.span_refs)[sb0:sb0 + 4].tolist() == [2, 2, 2, 1]
    st, ok = ja.trim_large(st, cfg, off, jnp.int32(1), jnp.int32(3))
    assert bool(ok)
    assert np.asarray(st.span_refs)[sb0:sb0 + 4].tolist() == [2, 1, 1, 1]
    st, ok = ja.trim_large(st, cfg, off, jnp.int32(1), jnp.int32(1))
    assert not bool(ok)                              # nothing held past 1
    st = ja.free_large(st, cfg, off)                 # follower's release
    assert np.asarray(st.span_refs)[sb0:sb0 + 4].tolist() == [1, 0, 0, 0]
    assert int(st.sb_block_words[sb0]) == 64         # tail freed, 1 sb kept
    st = ja.free_large(st, cfg, off, jnp.int32(1))
    assert np.asarray(st.sb_class)[sb0:sb0 + 4].tolist() == [-1] * 4


def test_small_free_into_large_span_rejected():
    """The vector analogue of the host rule: ``free`` lanes aimed at a
    superblock not initialized for their class are masked out."""
    cfg = ja.ArenaConfig(num_sbs=8, sb_words=64, class_words=(8,),
                         cache_cap=16, expand_sbs=1)
    st = ja.init_state(cfg)
    st, off = ja.alloc_large(st, cfg, jnp.int32(100))
    before = ja.live_blocks(st, cfg)
    st = ja.free(st, cfg, 0, jnp.asarray([int(off) + 8], jnp.int32),
                 jnp.ones(1, bool))
    assert ja.live_blocks(st, cfg) == before
    assert int(st.cache_top[0]) == 0                # nothing entered a cache


def test_retire_on_fetch_preserved():
    """PARTIAL→EMPTY superblocks retire when fetched (paper §4.4)."""
    cfg = ja.ArenaConfig(num_sbs=4, sb_words=64, class_words=(8,),
                         cache_cap=16, expand_sbs=1)
    alloc = jax.jit(functools.partial(ja.alloc, cfg=cfg, cls=0))
    free = jax.jit(functools.partial(ja.free, cfg=cfg, cls=0))
    st = ja.init_state(cfg)
    st, offs = alloc(state=st, need=jnp.ones(8, bool))
    st = free(state=st, offs=offs, mask=jnp.ones(8, bool))
    # spill everything back
    for _ in range(4):
        st, o = alloc(state=st, need=jnp.ones(8, bool))
        st = free(state=st, offs=o, mask=jnp.ones(8, bool))
    lb = ja.live_blocks(st, cfg)
    assert lb[0] == 0
