"""Device-side vectorized allocator + vectorized GC recovery."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import jax_alloc as ja
from repro.core import jax_recovery as jr

CFG = ja.ArenaConfig(num_sbs=32, sb_words=256, class_words=(8, 32),
                     cache_cap=128)


@pytest.fixture(scope="module")
def fns():
    return {
        (c, "alloc"): jax.jit(functools.partial(ja.alloc, cfg=CFG, cls=c))
        for c in (0, 1)
    } | {
        (c, "free"): jax.jit(functools.partial(ja.free, cfg=CFG, cls=c))
        for c in (0, 1)
    }


def test_randomized_invariants(fns):
    st = ja.init_state(CFG)
    L = 16
    rng = np.random.default_rng(0)
    live = {0: set(), 1: set()}
    for _ in range(150):
        cls = int(rng.integers(2))
        if rng.random() < 0.55:
            need = jnp.asarray(rng.random(L) < 0.7)
            st, offs = fns[(cls, "alloc")](state=st, need=need)
            got = np.asarray(offs)
            got = got[got >= 0]
            assert not (set(got.tolist()) & live[cls]), "double alloc"
            live[cls] |= set(got.tolist())
        else:
            pool = list(live[cls])
            k = min(len(pool), L)
            sel = rng.choice(pool, size=k, replace=False) if k else []
            offs = np.full(L, -1, np.int64)
            offs[:k] = sel
            st = fns[(cls, "free")](state=st, offs=jnp.asarray(offs, jnp.int32),
                                    mask=jnp.asarray(offs >= 0))
            live[cls] -= set(int(x) for x in sel)
    # cross-class word-range disjointness
    spans = sorted((o, o + CFG.class_words[c])
                   for c, s in live.items() for o in s)
    for (a0, a1), (b0, b1) in zip(spans, spans[1:]):
        assert a1 <= b0
    lb = ja.live_blocks(st, CFG)
    assert lb[0] == len(live[0]) and lb[1] == len(live[1])


def test_oom_partial_service(fns):
    tiny = ja.ArenaConfig(num_sbs=2, sb_words=64, class_words=(32,),
                          cache_cap=16, expand_sbs=1)
    alloc = jax.jit(functools.partial(ja.alloc, cfg=tiny, cls=0))
    st = ja.init_state(tiny)
    st, o1 = alloc(state=st, need=jnp.ones(4, bool))
    assert int((np.asarray(o1) >= 0).sum()) == 4   # 2 sbs × 2 blocks
    st, o2 = alloc(state=st, need=jnp.ones(4, bool))
    assert int((np.asarray(o2) >= 0).sum()) == 0   # exhausted → all -1


def test_vectorized_recovery(fns):
    st = ja.init_state(CFG)
    alloc0 = fns[(0, "alloc")]
    alloc1 = fns[(1, "alloc")]
    st, data = alloc0(state=st, need=jnp.ones(16, bool))
    st, tables = alloc1(state=st, need=jnp.asarray([True] * 4 + [False] * 12))
    data = np.asarray(data)
    tables = np.asarray(tables)[:4]

    S = jr.num_slots(CFG)
    refs = np.full((S, 4), -1, np.int32)
    minw = min(CFG.class_words)
    for i, t in enumerate(tables):
        refs[t // minw] = data[i * 4:(i + 1) * 4]
    roots = np.full((64,), -1, np.int32)
    roots[:4] = tables
    pers = ja.persistent_snapshot(st)
    pers["roots"] = jnp.asarray(roots)

    st2, marked = jax.jit(functools.partial(jr.recover, cfg=CFG))(
        persistent=pers, ref_table=jnp.asarray(refs))
    reach = set(tables.tolist()) | set(data.tolist())
    marked_offs = {int(s) * minw for s in np.nonzero(np.asarray(marked))[0]}
    assert marked_offs == reach
    lb = ja.live_blocks(st2, CFG)
    assert lb[0] == 16 and lb[1] == 4
    # fresh allocations never overlap recovered-live blocks
    got = set()
    for _ in range(20):
        st2, offs = alloc0(state=st2, need=jnp.ones(16, bool))
        offs = np.asarray(offs)
        got |= set(offs[offs >= 0].tolist())
    assert not (got & reach)


def test_large_alloc_crash_recovery_roundtrip():
    """alloc_large → write → crash → vectorized recover → read parity."""
    cfg = ja.ArenaConfig(num_sbs=16, sb_words=64, class_words=(8,),
                         cache_cap=32, expand_sbs=2)
    st = ja.init_state(cfg)
    st, off = jax.jit(functools.partial(ja.alloc_large, cfg=cfg))(
        state=st, nwords=jnp.int32(200))           # 4-superblock span
    off = int(off)
    assert off == 0
    assert np.asarray(st.sb_class)[:4].tolist() == \
        [ja.LARGE_CLS] + [ja.LARGE_CONT] * 3
    # the consumer's data array: write through the span's word offsets
    data = np.zeros((cfg.total_words,), np.int64)
    data[off:off + 200] = np.arange(200) + 7
    # some small blocks too — one rooted, the rest leaked
    st, smalls = jax.jit(functools.partial(ja.alloc, cfg=cfg, cls=0))(
        state=st, need=jnp.ones(8, bool))
    smalls = np.asarray(smalls)

    pers = ja.persistent_snapshot(st)
    roots = np.full((64,), -1, np.int32)
    roots[0] = off                                  # span head is a root
    roots[1] = int(smalls[0])
    pers["roots"] = jnp.asarray(roots)
    S = jr.num_slots(cfg)
    refs = jnp.full((S, 1), -1, jnp.int32)
    st2, marked = jax.jit(functools.partial(jr.recover, cfg=cfg))(
        persistent=pers, ref_table=refs)

    lb = ja.live_blocks(st2, cfg)
    assert lb["large"] == 1 and lb[0] == 1          # span + rooted small
    assert np.asarray(st2.sb_class)[:4].tolist() == \
        [ja.LARGE_CLS] + [ja.LARGE_CONT] * 3
    assert int(st2.sb_block_words[0]) == 200        # size record intact
    assert data[off:off + 200].tolist() == (np.arange(200) + 7).tolist()
    # fresh allocations (small or large) never overlap the live span
    alloc = jax.jit(functools.partial(ja.alloc, cfg=cfg, cls=0))
    got = []
    for _ in range(30):
        st2, o = alloc(state=st2, need=jnp.ones(8, bool))
        got += np.asarray(o)[np.asarray(o) >= 0].tolist()
    assert got and all(not (off <= g < off + 4 * cfg.sb_words) for g in got)
    # free the span: every superblock returns for reuse, markers cleared
    st2 = jax.jit(functools.partial(ja.free_large, cfg=cfg))(
        state=st2, off=jnp.int32(off))
    assert ja.live_blocks(st2, cfg)["large"] == 0
    assert np.asarray(st2.sb_class)[:4].tolist() == [-1] * 4


def test_large_alloc_watermark_exhaustion():
    """A contiguous request the watermark cannot satisfy returns -1 and
    leaves the state untouched (partial spans must never leak out)."""
    cfg = ja.ArenaConfig(num_sbs=4, sb_words=64, class_words=(8,),
                         cache_cap=16, expand_sbs=1)
    st = ja.init_state(cfg)
    st, ok_off = ja.alloc_large(st, cfg, jnp.int32(2 * 64))   # 2 of 4 sbs
    assert int(ok_off) == 0
    st, bad = ja.alloc_large(st, cfg, jnp.int32(3 * 64))      # needs 3 > 2
    assert int(bad) == -1
    assert int(st.used_sbs) == 2                              # unchanged
    assert np.asarray(st.sb_class)[2:].tolist() == [-1, -1]
    st, fit = ja.alloc_large(st, cfg, jnp.int32(2 * 64))      # exact fit
    assert int(fit) == 2 * 64
    assert ja.live_blocks(st, cfg)["large"] == 2


def test_large_alloc_reuses_freed_spans():
    """Regression: alloc/free cycles of large spans must not exhaust the
    arena — freed spans are found again by the contiguous-run search
    (watermark alone would leak every cycle and fail permanently)."""
    cfg = ja.ArenaConfig(num_sbs=6, sb_words=64, class_words=(8,),
                         cache_cap=16, expand_sbs=1)
    allocL = jax.jit(functools.partial(ja.alloc_large, cfg=cfg))
    freeL = jax.jit(functools.partial(ja.free_large, cfg=cfg))
    st = ja.init_state(cfg)
    for i in range(10):                       # 10 cycles ≫ 3 sbs of slack
        st, off = allocL(state=st, nwords=jnp.int32(2 * 64))
        assert int(off) >= 0, f"cycle {i} exhausted the arena"
        st = freeL(state=st, off=off)
    # two live spans + one freed-and-reallocated span still coexist
    st, a = allocL(state=st, nwords=jnp.int32(2 * 64))
    st, b = allocL(state=st, nwords=jnp.int32(2 * 64))
    st = freeL(state=st, off=a)
    st, c = allocL(state=st, nwords=jnp.int32(2 * 64))
    assert int(b) >= 0 and int(c) >= 0 and int(c) != int(b)
    assert ja.live_blocks(st, cfg)["large"] == 2
    # small allocations still work off the remaining superblocks
    st, offs = ja.alloc(st, cfg, 0, jnp.ones(4, bool))
    assert int((np.asarray(offs) >= 0).sum()) == 4


def test_span_refcounts_share_and_reconstruct():
    """Device span refcounts: ``acquire_span`` increments, a shared
    ``free_large`` decrements without moving anything, the last release
    frees, invalid acquires are masked no-ops, and vectorized recovery
    reconstructs the count from root-reachable references alone."""
    cfg = ja.ArenaConfig(num_sbs=8, sb_words=64, class_words=(8,),
                         cache_cap=16, expand_sbs=1)
    st = ja.init_state(cfg)
    st, off = ja.alloc_large(st, cfg, jnp.int32(2 * 64))
    off = int(off)
    assert int(st.span_refs[0]) == 1
    st, ok = ja.acquire_span(st, cfg, jnp.int32(off))
    assert bool(ok) and int(st.span_refs[0]) == 2
    # masked no-ops: interior-of-head, continuation, free superblock,
    # negative — the host raises on all of these; the device must no-op,
    # never silently succeed (refcount drift between the two sides)
    for bad in (off + 3, off + 64, 5 * 64, -1):
        st, ok = ja.acquire_span(st, cfg, jnp.int32(bad))
        assert not bool(ok)
    assert int(st.span_refs[0]) == 2

    st = ja.free_large(st, cfg, jnp.int32(off))      # shared → decrement
    assert int(st.span_refs[0]) == 1
    assert np.asarray(st.sb_class)[:2].tolist() == \
        [ja.LARGE_CLS, ja.LARGE_CONT]                # still placed

    # crash with two holders: two roots reference the head; the count
    # must come back as exactly 2 (nothing about it was ever persisted)
    st2, _ = ja.acquire_span(st, cfg, jnp.int32(off))
    pers = ja.persistent_snapshot(st2)
    roots = np.full((64,), -1, np.int32)
    roots[0] = roots[1] = off
    pers["roots"] = jnp.asarray(roots)
    refs = jnp.full((jr.num_slots(cfg), 1), -1, jnp.int32)
    rec, _ = jr.recover(cfg, pers, refs)
    assert int(rec.span_refs[0]) == 2
    rec = ja.free_large(rec, cfg, jnp.int32(off))    # holder 1 leaves
    assert ja.live_blocks(rec, cfg)["large"] == 1
    rec = ja.free_large(rec, cfg, jnp.int32(off))    # last holder frees
    assert ja.live_blocks(rec, cfg)["large"] == 0
    assert int(rec.span_refs[0]) == 0
    assert np.asarray(rec.sb_class)[:2].tolist() == [-1, -1]


def test_small_free_into_large_span_rejected():
    """The vector analogue of the host rule: ``free`` lanes aimed at a
    superblock not initialized for their class are masked out."""
    cfg = ja.ArenaConfig(num_sbs=8, sb_words=64, class_words=(8,),
                         cache_cap=16, expand_sbs=1)
    st = ja.init_state(cfg)
    st, off = ja.alloc_large(st, cfg, jnp.int32(100))
    before = ja.live_blocks(st, cfg)
    st = ja.free(st, cfg, 0, jnp.asarray([int(off) + 8], jnp.int32),
                 jnp.ones(1, bool))
    assert ja.live_blocks(st, cfg) == before
    assert int(st.cache_top[0]) == 0                # nothing entered a cache


def test_retire_on_fetch_preserved():
    """PARTIAL→EMPTY superblocks retire when fetched (paper §4.4)."""
    cfg = ja.ArenaConfig(num_sbs=4, sb_words=64, class_words=(8,),
                         cache_cap=16, expand_sbs=1)
    alloc = jax.jit(functools.partial(ja.alloc, cfg=cfg, cls=0))
    free = jax.jit(functools.partial(ja.free, cfg=cfg, cls=0))
    st = ja.init_state(cfg)
    st, offs = alloc(state=st, need=jnp.ones(8, bool))
    st = free(state=st, offs=offs, mask=jnp.ones(8, bool))
    # spill everything back
    for _ in range(4):
        st, o = alloc(state=st, need=jnp.ones(8, bool))
        st = free(state=st, offs=o, mask=jnp.ones(8, bool))
    lb = ja.live_blocks(st, cfg)
    assert lb[0] == 0
