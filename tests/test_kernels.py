"""Pallas kernels vs pure-jnp oracles (interpret mode, shape/dtype sweeps)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.kv_update.kernel import kv_update, kv_update_ref
from repro.kernels.paged_attention.kernel import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref


@pytest.mark.parametrize("B,H,K,S,dh,causal,win,dt", [
    (1, 4, 2, 256, 64, True, 0, jnp.float32),
    (2, 4, 1, 256, 128, True, 0, jnp.bfloat16),     # MQA (granite/rg)
    (1, 8, 8, 128, 64, False, 0, jnp.float32),      # encoder (hubert)
    (1, 4, 2, 512, 64, True, 128, jnp.float32),     # local window (rg)
    (1, 16, 16, 128, 80, False, 0, jnp.bfloat16),   # MHA, non-pow2 dh
])
def test_flash_attention_vs_ref(B, H, K, S, dh, causal, win, dt):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, S, dh), dt)
    k = jax.random.normal(ks[1], (B, K, S, dh), dt)
    v = jax.random.normal(ks[2], (B, K, S, dh), dt)
    out = flash_attention(q, k, v, causal=causal, window=win,
                          interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=win)
    tol = 3e-2 if dt == jnp.bfloat16 else 2e-5
    err = float(jnp.abs(out.astype(jnp.float32)
                        - ref.astype(jnp.float32)).max())
    assert err < tol, err


@pytest.mark.parametrize("B,H,K,pages,page,P,dh,dt,win", [
    (2, 4, 2, 16, 16, 4, 64, jnp.float32, 0),
    (2, 8, 1, 16, 32, 3, 128, jnp.bfloat16, 0),     # MQA decode
    (1, 4, 4, 8, 16, 2, 64, jnp.float32, 24),       # windowed decode
    (3, 8, 2, 24, 8, 6, 128, jnp.float32, 0),       # small pages
])
def test_paged_attention_vs_ref(B, H, K, pages, page, P, dh, dt, win):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, H, dh), dt)
    ak = jax.random.normal(ks[1], (pages, page, K, dh), dt)
    av = jax.random.normal(ks[2], (pages, page, K, dh), dt)
    rng = np.random.default_rng(0)
    bt = np.full((B, P), -1, np.int32)
    lens = np.zeros((B,), np.int32)
    for b in range(B):
        n = int(rng.integers(1, P * page))
        lens[b] = n
        need = -(-n // page)
        bt[b, :need] = rng.choice(pages, size=need, replace=False)
    out = paged_attention(q, ak, av, jnp.asarray(bt), jnp.asarray(lens),
                          window=win, interpret=True)
    ref = paged_attention_ref(q, ak, av, jnp.asarray(bt), jnp.asarray(lens),
                              window=win)
    tol = 3e-2 if dt == jnp.bfloat16 else 1e-5
    err = float(jnp.abs(out.astype(jnp.float32)
                        - ref.astype(jnp.float32)).max())
    assert err < tol, err


def test_kv_update_visited_pages():
    """Interpret-mode aliasing zeroes unvisited blocks (TPU donation keeps
    them); compare only the pages the kernel touches."""
    key = jax.random.PRNGKey(0)
    B, K, dh, pages, page = 4, 2, 64, 8, 16
    kn = jax.random.normal(key, (B, K, dh), jnp.float32)
    vn = jax.random.normal(jax.random.PRNGKey(1), (B, K, dh), jnp.float32)
    ak = jax.random.normal(jax.random.PRNGKey(2), (pages, page, K, dh))
    av = jax.random.normal(jax.random.PRNGKey(3), (pages, page, K, dh))
    pids = jnp.asarray([0, 3, -1, 5], jnp.int32)
    slots = jnp.asarray([1, 15, 0, 7], jnp.int32)
    ak2, av2 = kv_update(ak, av, kn, vn, pids, slots, interpret=True)
    rk, rv = kv_update_ref(ak, av, kn, vn, pids, slots)
    visited = [0, 3, 5]          # page 7 is the reserved dump page
    for p in visited:
        np.testing.assert_allclose(np.asarray(ak2[p]), np.asarray(rk[p]),
                                   atol=1e-6)
        np.testing.assert_allclose(np.asarray(av2[p]), np.asarray(rv[p]),
                                   atol=1e-6)


def test_flash_attention_block_shape_sweep():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (1, 2, 256, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, 256, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 2, 256, 64), jnp.float32)
    ref = attention_ref(q, k, v, causal=True)
    for bq, bk in [(64, 64), (128, 64), (64, 128), (256, 256)]:
        out = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk,
                              interpret=True)
        assert float(jnp.abs(out - ref).max()) < 2e-5, (bq, bk)


@pytest.mark.parametrize("Bz,H,S,P,N", [
    (2, 2, 256, 64, 32),
    (1, 4, 128, 32, 64),
    (2, 1, 512, 64, 128),     # full mamba2-370m state width
])
def test_ssd_scan_vs_ref(Bz, H, S, P, N):
    from repro.kernels.ssd_scan.kernel import ssd_scan
    from repro.kernels.ssd_scan.ref import ssd_scan_ref
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    xdt = jax.random.normal(ks[0], (Bz, H, S, P), jnp.float32) * 0.1
    loga = -jnp.abs(jax.random.normal(ks[1], (Bz, H, S), jnp.float32)) * 0.1
    B = jax.random.normal(ks[2], (Bz, S, N), jnp.float32) * 0.3
    C = jax.random.normal(ks[3], (Bz, S, N), jnp.float32) * 0.3
    out = ssd_scan(xdt, loga, B, C, interpret=True)
    ref = ssd_scan_ref(xdt, loga, B, C)
    rel = float(jnp.abs(out - ref).max()) / (float(jnp.abs(ref).max()) + 1e-9)
    assert rel < 1e-4, rel
