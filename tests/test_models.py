"""Per-architecture smoke tests (reduced configs) + model-level checks."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, applicable_shapes, get_config, \
    get_smoke_config
from repro.models import transformer as T


def _batch(cfg, key, B=2, S=32):
    if cfg.frontend:
        return {"embeds": jax.random.normal(key, (B, S, cfg.d_model),
                                            jnp.float32),
                "labels": jnp.zeros((B, S), jnp.int32)}
    return {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
            "labels": jnp.zeros((B, S), jnp.int32)}


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_forward_and_loss(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    batch = _batch(cfg, key)
    logits, aux = T.forward(cfg, params, batch)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    loss, parts = T.loss_fn(cfg, params, batch)
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_train_step(arch):
    from repro.train.optimizer import AdamWConfig, init_opt_state
    from repro.train.step import make_train_step
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(cfg, AdamWConfig(warmup_steps=1)))
    batch = _batch(cfg, key)
    p2, o2, m = step(params, opt, batch)
    assert np.isfinite(float(m["loss"]))
    assert np.isfinite(float(m["grad_norm"]))
    # parameters actually moved
    moved = any(
        float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max()) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2))
        if a.dtype != jnp.int32)
    assert moved


def test_full_configs_match_assignment():
    expect = {
        "granite_20b": (52, 6144, 48, 1, 24576, 49152),
        "nemotron_4_340b": (96, 18432, 96, 8, 73728, 256000),
        "qwen2_5_32b": (64, 5120, 40, 8, 27648, 152064),
        "starcoder2_3b": (30, 3072, 24, 2, 12288, 49152),
        "internvl2_26b": (48, 6144, 48, 8, 16384, 92553),
        "granite_moe_3b_a800m": (32, 1536, 24, 8, 512, 49155),
        "moonshot_v1_16b_a3b": (48, 2048, 16, 16, 1408, 163840),
        "mamba2_370m": (48, 1024, 0, 0, 0, 50280),
        "hubert_xlarge": (48, 1280, 16, 16, 5120, 504),
        "recurrentgemma_9b": (38, 4096, 16, 1, 12288, 256000),
    }
    for arch, (L, d, h, kv, ff, v) in expect.items():
        cfg = get_config(arch)
        assert cfg.num_layers == L and cfg.d_model == d, arch
        assert cfg.num_heads == h and cfg.num_kv_heads == kv, arch
        assert cfg.d_ff == ff and cfg.vocab_size == v, arch
    moe = get_config("granite_moe_3b_a800m")
    assert moe.num_experts == 40 and moe.top_k == 8
    moon = get_config("moonshot_v1_16b_a3b")
    assert moon.num_experts == 64 and moon.top_k == 6
    mamba = get_config("mamba2_370m")
    assert mamba.ssm_state == 128


def test_applicable_shapes_rules():
    # encoder-only: no decode; sub-quadratic only run long_500k
    assert "decode_32k" not in applicable_shapes("hubert_xlarge")
    assert "long_500k" in applicable_shapes("mamba2_370m")
    assert "long_500k" in applicable_shapes("recurrentgemma_9b")
    assert "long_500k" not in applicable_shapes("granite_20b")
    assert len([c for a in ARCHS for c in applicable_shapes(a)]) == 31


def test_chunked_vs_naive_attention():
    cfg = dataclasses.replace(get_smoke_config("qwen2_5_32b"),
                              dtype=jnp.float32)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    ln, _ = T.forward(dataclasses.replace(cfg, attn_impl="naive"),
                      params, batch)
    lc, _ = T.forward(dataclasses.replace(cfg, attn_impl="chunked"),
                      params, batch)
    assert float(jnp.abs(ln - lc).max()) < 1e-4


def test_tiny_training_reduces_loss():
    from repro.data.pipeline import TokenStream
    from repro.train.loop import Trainer
    from repro.train.optimizer import AdamWConfig
    cfg = dataclasses.replace(get_smoke_config("starcoder2_3b"),
                              num_layers=2, vocab_size=64)
    tr = Trainer(cfg, AdamWConfig(lr=3e-3, warmup_steps=5))
    # learnable: repeated pattern tokens
    class Fixed:
        def batch_at(self, step):
            t = (np.arange(2 * 32).reshape(2, 32) % 7).astype(np.int32)
            return {"tokens": t, "labels": t}
    hist = tr.run(Fixed(), steps=30, log_every=1000)
    assert hist[-1] < hist[0] * 0.7, (hist[0], hist[-1])
