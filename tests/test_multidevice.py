"""Numeric parity of the sharded paths on a real multi-device mesh.

Runs in a subprocess with ``--xla_force_host_platform_device_count=4``
(the flag must precede jax init, so it cannot run in the main pytest
process): a (data=2, model=2) mesh exercises

  * shard_map decode: row/col-parallel TP, slot-sharded paged KV,
    distributed-softmax merge, vocab-parallel sampling — vs the
    single-device oracle;
  * pjit train_step with the FSDP×TP sharding rules — vs 1-device.

This is the strongest correctness evidence for the distribution layer:
the 512-device dry-run proves it compiles; this proves it computes the
same numbers.
"""

import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    import sys
    sys.path.insert(0, "src")
    from repro.configs import get_smoke_config
    from repro.models import transformer as T
    from repro.serving import decode as dec
    from repro.distributed import sharding as shrules
    from repro.runtime import make_mesh, named_sharding
    from repro.train.optimizer import AdamWConfig, init_opt_state
    from repro.train.step import make_train_step
    from jax.sharding import PartitionSpec as P

    mesh1 = make_mesh((1, 1), ("data", "model"),
                      devices=jax.devices()[:1])
    mesh4 = make_mesh((2, 2), ("data", "model"))

    # smoke config with dims divisible by tp=2 everywhere
    cfg = dataclasses.replace(get_smoke_config("qwen2_5_32b"),
                              dtype=jnp.float32, vocab_size=128)
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    B, S = 4, 12
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)

    # ---- decode parity: mesh (1,1) vs (2,2) --------------------------------
    def run_decode(mesh):
        pshape = jax.eval_shape(lambda: params)
        step, pspecs, sspecs = dec.make_decode_step(cfg, mesh, pshape,
                                                    return_logits=True)
        ds = dec.make_dstate(cfg, batch=B, max_seq=32,
                             dp_shards=mesh.shape["data"])
        Pn = ds["block_table"].shape[1]
        pages_per_shard = ds["units"]["l0"]["k"].shape[1] // \
            mesh.shape["data"]
        # shard-local page ids: each data shard's sequences use its pool
        bt = np.zeros((B, Pn), np.int32)
        per_shard = B // mesh.shape["data"]
        for b in range(B):
            lane_in_shard = b % per_shard
            bt[b] = lane_in_shard * Pn + np.arange(Pn)
        ds["block_table"] = jnp.asarray(bt)
        outs = []
        for t in range(S):
            ds, tok, lg = step(params, ds, toks[:, t])
            outs.append(np.asarray(lg))
        return np.stack(outs, 1)

    l1 = run_decode(mesh1)
    l4 = run_decode(mesh4)
    err = np.abs(l1 - l4).max() / (np.abs(l1).max() + 1e-9)
    assert err < 1e-4, f"decode mesh parity: rel={err:.3e}"
    print(f"DECODE-PARITY-OK rel={err:.2e}")

    # ---- sequence-parallel decode (batch < dp — the long_500k path) --------
    # hybrid smoke arch: RG-LRU state + windowed attention, batch 1
    cfgh = dataclasses.replace(get_smoke_config("recurrentgemma_9b"),
                               dtype=jnp.float32, vocab_size=128,
                               page_size=4, window=8)
    paramsh = T.init_params(cfgh, jax.random.PRNGKey(2))
    tok1 = jax.random.randint(jax.random.PRNGKey(3), (1, 10), 0, 128)
    lfull, _ = T.forward(cfgh, paramsh, {"tokens": tok1})

    def run_seqpar(mesh):
        dp = mesh.shape["data"]
        pshape = jax.eval_shape(lambda: paramsh)
        step, _, _ = dec.make_decode_step(cfgh, mesh, pshape,
                                          batch_sharded=False,
                                          return_logits=True)
        ds = dec.make_dstate(cfgh, batch=1, max_seq=16, dp_shards=dp)
        Pn = ds["block_table"].shape[1]
        # page slot j lives on data shard j // (Pn/dp); ids are shard-local
        bt = (np.arange(Pn, dtype=np.int32) % (Pn // dp))[None, :]
        ds["block_table"] = jnp.asarray(bt)
        outs = []
        for t in range(10):
            ds, tok, lg = step(paramsh, ds, tok1[:, t])
            outs.append(np.asarray(lg))
        return np.stack(outs, 1)

    s1 = run_seqpar(mesh1)
    s4 = run_seqpar(mesh4)
    err_sp = np.abs(s1 - s4).max() / (np.abs(s1).max() + 1e-9)
    assert err_sp < 1e-4, f"seq-parallel mesh parity: rel={err_sp:.3e}"
    err_or = np.abs(s4 - np.asarray(lfull)).max() / \
        (np.abs(np.asarray(lfull)).max() + 1e-9)
    assert err_or < 1e-3, f"seq-parallel vs oracle: rel={err_or:.3e}"
    print(f"SEQPAR-PARITY-OK rel={err_sp:.2e} oracle={err_or:.2e}")

    # ---- train-step parity: pjit on (2,2) vs single device -----------------
    step_fn = make_train_step(cfg, AdamWConfig(warmup_steps=1))
    batch = {"tokens": toks, "labels": toks}
    opt = init_opt_state(params)
    p1, o1, m1 = jax.jit(step_fn)(params, opt, batch)

    pspecs = shrules.train_param_specs(jax.eval_shape(lambda: params), mesh4)
    psh = jax.tree.map(lambda s: named_sharding(mesh4, s), pspecs)
    params4 = jax.tree.map(lambda a, s: jax.device_put(a, s), params, psh)
    osh = {"m": psh, "v": psh, "step": named_sharding(mesh4, P())}
    opt4 = {"m": jax.tree.map(lambda a, s: jax.device_put(a, s),
                              opt["m"], psh),
            "v": jax.tree.map(lambda a, s: jax.device_put(a, s),
                              opt["v"], psh),
            "step": opt["step"]}
    bsh = named_sharding(mesh4, P(("data",)))
    batch4 = jax.tree.map(lambda a: jax.device_put(a, bsh), batch)
    step4 = make_train_step(cfg, AdamWConfig(warmup_steps=1), mesh=mesh4)
    p4, o4, m4 = jax.jit(step4)(params4, opt4, batch4)
    dl = abs(float(m1["loss"]) - float(m4["loss"]))
    assert dl < 1e-4, f"loss mismatch {dl}"
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        d = np.abs(np.asarray(a, np.float32) - np.asarray(b, np.float32)).max()
        assert d < 5e-4, f"param divergence {d}"
    print(f"TRAIN-PARITY-OK dloss={dl:.2e}")
""")


@pytest.mark.slow
def test_sharded_paths_match_single_device():
    res = subprocess.run([sys.executable, "-c", _SCRIPT],
                         capture_output=True, text=True, timeout=900,
                         cwd=".")
    assert "DECODE-PARITY-OK" in res.stdout, res.stdout + res.stderr
    assert "SEQPAR-PARITY-OK" in res.stdout, res.stdout + res.stderr
    assert "TRAIN-PARITY-OK" in res.stdout, res.stdout + res.stderr
