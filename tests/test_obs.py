"""Units for the unified observability layer (``repro.obs``).

Covers the registry primitives (near-zero disabled path, get-or-create
identity, named resets that raise on unknown metrics — the
``benchmarks/run.py`` reset hazard), span/phase recording with
Chrome-trace export, the live :class:`WasteMonitor`'s parity with the
persist-lint ``DurabilityShadow`` on one and the same trace, and the
exact recovery-stats contract (phase names + stat keys pinned, so a
rename fails loudly instead of silently vanishing from dashboards).
"""

import json
import os
import tempfile

import numpy as np
import pytest

from repro import obs
from repro.analysis.persist_lint import DurabilityShadow
from repro.analysis.trace import attach_tracer
from repro.core import recovery
from repro.core.ralloc import Ralloc
from repro.obs.registry import Registry, UnknownMetric

MB = 1 << 20


# ---------------------------------------------------------------------------
# registry primitives
# ---------------------------------------------------------------------------
def test_counter_gauge_histogram_roundtrip():
    reg = Registry()
    c = reg.counter("t.hits")
    assert c is reg.counter("t.hits")        # stable identity (cacheable)
    c.inc()
    c.inc(3)
    reg.gauge("t.depth").set(7)
    h = reg.histogram("t.lat")
    for v in (1.0, 2.0, 3.0, 4.0):
        h.observe(v)
    snap = reg.snapshot()
    assert snap["counters"]["t.hits"] == 4
    assert snap["gauges"]["t.depth"] == 7
    hs = snap["histograms"]["t.lat"]
    assert hs["count"] == 4 and hs["min"] == 1.0 and hs["max"] == 4.0
    assert hs["mean"] == 2.5 and hs["p50"] == 3.0


def test_disabled_registry_records_nothing():
    reg = Registry(enabled=False)
    reg.counter("t.c").inc()
    reg.gauge("t.g").set(5)
    reg.histogram("t.h").observe(1.0)
    with reg.span("t.phase") as sp:
        sp.add(3)
    assert sp.seconds >= 0.0                 # spans still time when disabled
    snap = reg.snapshot()
    assert snap["counters"]["t.c"] == 0
    assert snap["gauges"]["t.g"] == 0
    assert snap["histograms"] == {} and snap["phases"] == {}
    assert reg.chrome_trace()["traceEvents"] == []


def test_reset_unknown_metric_raises():
    reg = Registry()
    reg.counter("t.known")
    with pytest.raises(UnknownMetric):
        reg.reset("t.known", "t.never_registered")
    reg.gauge_fn("t.fn_gauge", lambda: 42)
    with pytest.raises(UnknownMetric):
        reg.reset("t.fn_gauge")              # callback gauges can't reset
    reg.register_source("t.src_no_reset", read=lambda: 1)
    with pytest.raises(UnknownMetric):
        reg.reset("t.src_no_reset")


def test_source_reset_routes_to_owner():
    reg = Registry()
    box = {"n": 9}
    reg.register_source("t.src", read=lambda: box["n"],
                        reset=lambda: box.update(n=0))
    assert reg.snapshot()["counters"]["t.src"] == 9
    reg.reset("t.src")
    assert box["n"] == 0
    # reset_all leaves sources alone (the owner resets by name)
    box["n"] = 5
    reg.reset_all()
    assert box["n"] == 5


def test_heap_registers_resettable_sources():
    """The live heap's n_flush/n_fence/... are registry sources: the
    benchmark harness resets them BY NAME through the registry (typo →
    UnknownMetric) instead of the old blind reset_counters() call."""
    r = Ralloc(None, 8 * MB)
    p = r.malloc(64)
    r.write_word(p, 1)
    r.flush_range(p, 1)
    r.fence()
    assert r.mem.n_flush > 0 and r.mem.n_fence > 0
    obs.reset("heap.flush", "heap.fence", "heap.cas", "heap.drain")
    assert r.mem.n_flush == 0 and r.mem.n_fence == 0
    assert r.mem.n_cas == 0 and r.mem.n_drain == 0
    snap = obs.snapshot()
    assert snap["counters"]["heap.flush"] == 0
    with pytest.raises(UnknownMetric):
        obs.reset("heap.flushh")
    r.close()


# ---------------------------------------------------------------------------
# spans, phases and Chrome-trace export
# ---------------------------------------------------------------------------
def test_span_phases_accumulate_and_trace_exports():
    reg = Registry()
    for _ in range(3):
        with reg.span("phase.one", tag="x") as sp:
            sp.add(2)
    snap = reg.snapshot()
    row = snap["phases"]["phase.one"]
    assert row["calls"] == 3 and row["items"] == 6
    assert row["seconds"] >= 0.0
    trace = reg.chrome_trace()
    assert len(trace["traceEvents"]) == 3
    ev = trace["traceEvents"][0]
    assert ev["name"] == "phase.one" and ev["ph"] == "X"
    assert ev["dur"] >= 0 and ev["args"]["items"] == 2
    # loadable: a JSON round-trip preserves the Chrome trace shape
    loaded = json.loads(json.dumps(trace))
    assert {e["name"] for e in loaded["traceEvents"]} == {"phase.one"}
    reg.reset_all()
    assert reg.chrome_trace()["traceEvents"] == []
    assert reg.snapshot()["phases"] == {}


# ---------------------------------------------------------------------------
# WasteMonitor ≡ DurabilityShadow (two implementations, one trace)
# ---------------------------------------------------------------------------
def test_waste_monitor_parity_with_shadow_diag():
    """Replay one real allocator trace through BOTH waste analyses: the
    streaming monitor (repro.obs.waste) and the batch shadow
    (analysis.persist_lint).  Their diagnostics must agree exactly."""
    r = Ralloc(None, 8 * MB)
    tr = attach_tracer(r)
    ptrs = [r.malloc(64) for _ in range(20)]
    for i, p in enumerate(ptrs):
        r.write_word(p, i)
        r.flush_range(p, 1)
    r.fence()
    r.fence()                          # deliberate: one empty fence
    r.flush_range(ptrs[0], 1)          # deliberate: one redundant flush
    for p in ptrs[::2]:
        r.free(p)
    r.set_root(0, ptrs[1])
    r.mem.tracer = None
    events = tr.events
    assert any(e.kind == "write" for e in events)

    sh = DurabilityShadow(tr.base)
    mon = obs.WasteMonitor()           # standalone (no registry binding)
    for ev in events:
        mon.record(ev.kind, ev.addr, ev.value, ev.label, ev.info)
        if ev.kind == "write":
            sh.write(ev.addr, ev.value)
        elif ev.kind == "flush":
            sh.flush(ev.addr)
        elif ev.kind == "fence":
            sh.fence()
        elif ev.kind == "drain":
            sh.drain()
        elif ev.kind == "crash":
            sh.crash()
    assert mon.diag == dict(sh.diag)
    assert mon.diag["empty_fences"] >= 1
    assert mon.diag["redundant_flushes"] >= 1
    r.close()


def test_waste_monitor_gauges_live_in_snapshot():
    reg = Registry()
    r = Ralloc(None, 8 * MB)
    mon = obs.attach_waste_monitor(r.mem, registry=reg)
    p = r.malloc(64)
    r.write_word(p, 7)
    r.flush_range(p, 1)
    r.fence()
    r.mem.tracer = None
    snap = reg.snapshot()
    assert snap["gauges"]["persist.writes"] == mon.writes > 0
    assert snap["gauges"]["persist.flushes"] == mon.flushes > 0
    assert snap["gauges"]["persist.redundant_flushes"] == 0
    assert snap["gauges"]["persist.empty_fences"] == 0
    r.close()


# ---------------------------------------------------------------------------
# recovery stats contract: phase names and stat keys are pinned
# ---------------------------------------------------------------------------
def test_recovery_stats_keys_and_phase_names_pinned():
    """Exact-set pin: a renamed or dropped recovery stat/phase breaks
    this test instead of silently disappearing from the snapshot."""
    path = tempfile.mktemp()
    r = Ralloc(path, 8 * MB, sim_nvm=True, seed=7)
    p = r.malloc(64)
    r.write_word(p, 123)
    r.flush_range(p, 1)
    r.fence()
    r.set_root(0, p)
    r.heap.crash()
    del r
    r2 = Ralloc(path, 8 * MB, sim_nvm=True, seed=8)
    assert r2.dirty_restart
    r2.get_root(0)
    obs.reset_all()
    stats = r2.recover()
    assert set(stats) == {
        "reachable_blocks", "free_superblocks", "free_runs",
        "index_records", "index_retrims", "index_pruned",
        "trie_records", "trie_retrims", "trie_pruned",
        "partial_superblocks", "full_superblocks", "large_blocks",
        "shared_spans", "mark_seconds", "sweep_seconds", "total_seconds",
        "phases",
    }
    assert recovery.PHASES == (
        "prune_index", "prune_trie", "mark", "sweep", "reconstruct",
        "retrim_index", "retrim_trie", "drain")
    assert set(stats["phases"]) == set(recovery.PHASES)
    for name, row in stats["phases"].items():
        assert set(row) == {"seconds", "items"}
        assert row["seconds"] >= 0.0
    # the same phases accumulated into the registry under recovery.*
    reg_phases = obs.snapshot()["phases"]
    assert {f"recovery.{n}" for n in recovery.PHASES} <= set(reg_phases)
    r2.close()
    os.unlink(path)


# ---------------------------------------------------------------------------
# allocator counters flow end to end
# ---------------------------------------------------------------------------
def test_allocator_counters_populate_snapshot():
    obs.reset_all()
    r = Ralloc(None, 8 * MB)
    ptrs = [r.malloc(64) for _ in range(10)]
    for p in ptrs:
        r.free(p)
    big = r.malloc(3 * MB)
    r.free(big)
    c = obs.snapshot()["counters"]
    assert c["alloc.small"] == 10 and c["alloc.large"] == 1
    assert c["alloc.tcache_hit"] + c["alloc.tcache_miss"] == 10
    assert c["alloc.watermark_growth_sbs"] > 0
    assert c["heap.flush"] > 0 and c["heap.fence"] > 0
    r.close()
