"""Beyond-paper perf features: int8 paged KV, local MoE dispatch."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.layers import moe as moe_lib
from repro.models import transformer as T
from repro.runtime import make_host_mesh
from repro.serving import decode as dec


@pytest.fixture(scope="module")
def mesh():
    return make_host_mesh()


def test_int8_kv_decode_parity(mesh):
    """KIVI-style int8 paged KV: ≤ a few % logit error vs fp32 cache."""
    key = jax.random.PRNGKey(1)
    cfg = dataclasses.replace(get_smoke_config("qwen2_5_32b"),
                              dtype=jnp.float32)
    params = T.init_params(cfg, key)
    B, S = 2, 24
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    lf, _ = T.forward(cfg, params, {"tokens": toks})
    cfg8 = dataclasses.replace(cfg, kv_dtype="int8")
    pshape = jax.eval_shape(lambda: params)
    step, _, _ = dec.make_decode_step(cfg8, mesh, pshape, return_logits=True)
    ds = dec.make_dstate(cfg8, batch=B, max_seq=64, dp_shards=1)
    Pn = ds["block_table"].shape[1]
    ds["block_table"] = jnp.asarray(
        np.arange(B * Pn, dtype=np.int32).reshape(B, Pn))
    assert ds["units"]["l0"]["k"].dtype == jnp.int8
    errs = []
    for t in range(S):
        ds, tok, lg = step(params, ds, toks[:, t])
        errs.append(float(jnp.abs(lg - lf[:, t]).max()))
    rel = max(errs) / (float(jnp.abs(lf).max()) + 1e-9)
    assert rel < 5e-2, rel


def test_moe_local_dispatch_matches_global():
    """§Perf B5: per-row dispatch is numerically identical to the global
    argsort dispatch when no tokens are dropped."""
    cfg = dataclasses.replace(get_smoke_config("granite_moe_3b_a800m"),
                              dtype=jnp.float32)
    p = moe_lib.init_moe(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 16, cfg.d_model),
                          jnp.float32)
    yg, ag = moe_lib.apply_moe_global(cfg, p, x, capacity_factor=100.0)
    yl, al = moe_lib.apply_moe_local(cfg, p, x, capacity_factor=100.0)
    assert float(jnp.abs(yg - yl).max()) < 1e-5
    assert abs(float(ag - al)) < 1e-6


def test_moe_local_dispatch_drops_per_row():
    """Capacity in the local router is per row: an overloaded row drops
    tokens while other rows are unaffected."""
    cfg = dataclasses.replace(get_smoke_config("granite_moe_3b_a800m"),
                              dtype=jnp.float32, num_experts=4, top_k=1,
                              expert_pad=0)
    p = moe_lib.init_moe(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 8, cfg.d_model),
                          jnp.float32)
    y, aux = moe_lib.apply_moe_local(cfg, p, x, capacity_factor=0.3)
    assert np.isfinite(np.asarray(y)).all()


def test_full_model_with_local_dispatch_trains():
    from repro.train.optimizer import AdamWConfig, init_opt_state
    from repro.train.step import make_train_step
    cfg = dataclasses.replace(get_smoke_config("moonshot_v1_16b_a3b"),
                              moe_dispatch="local")
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(cfg, AdamWConfig(warmup_steps=1)))
    batch = {"tokens": jnp.zeros((2, 16), jnp.int32),
             "labels": jnp.zeros((2, 16), jnp.int32)}
    p2, o2, m = step(params, opt, batch)
    assert np.isfinite(float(m["loss"]))
