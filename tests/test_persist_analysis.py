"""Units for the persist-order analysis layer (``repro.analysis``).

Covers the strict durability shadow (the clwb-captures-at-flush model),
the tracer plumbing, the perf diagnostics, the torn-record seal
checksum, and the static AST lint — including the requirement that the
current tree is lint-clean (the same gate CI enforces via
``tools/lint_persist.py``).
"""

import pathlib
import subprocess
import sys

import numpy as np
import pytest

from repro.analysis.persist_lint import (DurabilityShadow, check_allocator,
                                         check_trace, standard_rules)
from repro.analysis.static_checks import (DEFER_ANNOTATION, check_source,
                                          check_tree)
from repro.analysis.trace import CrashAfter, SimulatedCrash, attach_tracer
from repro.analysis import faults
from repro.core import pptr as pp
from repro.core.atomics import NVMArray
from repro.core.layout import SB_SIZE
from repro.core.prefix_index import (PrefixIndex, _record_checksum,
                                     hash_tokens, record_is_valid)
from repro.core.ralloc import Ralloc

REPO = pathlib.Path(__file__).resolve().parents[1]


# ---------------------------------------------------------------------------
# DurabilityShadow: the strict (guarantee-only) model
# ---------------------------------------------------------------------------
def _shadow(n=64):
    return DurabilityShadow(np.zeros(n, dtype=np.int64))


def test_shadow_write_flush_fence_lifecycle():
    sh = _shadow()
    sh.write(3, 7)
    assert not sh.is_durable(3)
    assert sh.durable_value(3) == 0          # base image until committed
    sh.flush(3)
    assert not sh.is_durable(3)              # clwb alone guarantees nothing
    sh.fence()
    assert sh.is_durable(3)
    assert sh.durable_value(3) == 7


def test_shadow_fence_without_flush_commits_nothing():
    sh = _shadow()
    sh.write(3, 7)
    sh.fence()
    assert not sh.is_durable(3)
    assert sh.durable_value(3) == 0


def test_shadow_rewrite_after_flush_keeps_flushed_snapshot():
    """Hardware clwb captures the line at flush time: a later write is
    NOT covered by the earlier flush, but the flushed snapshot still
    commits at the fence."""
    sh = _shadow()
    sh.write(3, 7)
    sh.flush(3)
    sh.write(3, 9)                           # after the flush
    sh.fence()
    assert not sh.is_durable(3)              # latest value not guaranteed
    assert sh.durable_value(3) == 7          # the snapshot committed
    sh.flush(3)
    sh.fence()
    assert sh.is_durable(3)
    assert sh.durable_value(3) == 9


def test_shadow_flush_covers_whole_line():
    sh = _shadow()
    sh.write(8, 1)
    sh.write(9, 2)
    sh.flush(8)                              # same cache line as 9
    sh.fence()
    assert sh.is_durable(8) and sh.is_durable(9)


def test_shadow_crash_drops_pending_drain_commits_all():
    sh = _shadow()
    sh.write(3, 7)
    sh.crash()
    assert sh.is_durable(3) and sh.durable_value(3) == 0
    sh.write(4, 9)
    sh.drain()
    assert sh.is_durable(4) and sh.durable_value(4) == 9


def test_shadow_perf_diagnostics():
    sh = _shadow()
    sh.write(3, 7)
    sh.flush(3)
    sh.flush(3)                              # nothing new dirty → redundant
    sh.fence()
    sh.fence()                               # nothing flushed since → empty
    assert sh.diag["redundant_flushes"] == 1
    assert sh.diag["empty_fences"] == 1
    assert sh.diag["flushes"] == 2 and sh.diag["fences"] == 2


# ---------------------------------------------------------------------------
# Tracer plumbing
# ---------------------------------------------------------------------------
def test_tracer_records_epoch_stamped_events():
    mem = NVMArray(64, sim=True)
    tr = attach_tracer(mem)
    mem.write(3, 7)
    mem.flush(3)
    mem.fence()
    mem.write(4, 1)
    kinds = [(e.kind, e.epoch) for e in tr.events]
    assert kinds == [("write", 0), ("flush", 0), ("fence", 0), ("write", 1)]
    assert tr.events[0].addr == 3 and tr.events[0].value == 7


def test_tracer_cas_emits_write_then_cas():
    mem = NVMArray(64)
    tr = attach_tracer(mem)
    assert mem.cas(0, 0, 5)
    assert [e.kind for e in tr.events] == ["write", "cas"]
    assert tr.events[1].info == {"ok": True}
    assert not mem.cas(0, 0, 6)              # expected stale
    assert tr.events[-1].kind == "cas" and not tr.events[-1].info["ok"]


def test_tracer_note_passthrough_and_untraced_noop():
    mem = NVMArray(64)
    mem.note("whatever", a=1)                # no tracer: must not raise
    tr = attach_tracer(mem)
    mem.note("record_seal", record=12)
    ev = tr.events[-1]
    assert ev.kind == "note" and ev.label == "record_seal"
    assert ev.info == {"record": 12}


def test_crash_after_blocks_the_budgeted_event():
    mem = NVMArray(64, sim=True)
    attach_tracer(mem, CrashAfter(2))
    mem.write(3, 7)                          # event 1
    mem.flush(3)                             # event 2
    with pytest.raises(SimulatedCrash):
        mem.fence()                          # event 3: blocked BEFORE effect
    mem.tracer = None
    assert int(mem.nvm[3]) == 0              # the fence never wrote back


# ---------------------------------------------------------------------------
# check_trace end-to-end on a live allocator
# ---------------------------------------------------------------------------
def test_clean_publish_remove_trace_has_zero_violations():
    r = Ralloc(None, 2 * (1 << 20), sim_nvm=True, seed=3, expand_sbs=1)
    tr = attach_tracer(r)
    idx = PrefixIndex(r)
    p = r.malloc(2 * SB_SIZE - 256)
    r.write_word(p, 0xBEEF)
    r.flush_range(p, 1)
    r.fence()
    r.set_root(0, p)
    key = hash_tokens([1, 2])
    assert idx.publish(key, p, n_pages=2, lease_sbs=1) is not None
    assert idx.remove(key)
    rep = check_allocator(r, tr)
    assert rep.ok, rep
    d = rep.diagnostics
    assert d["notes"]["publish_end"] == 1
    assert d["notes"]["lease_release"] == 1
    assert d["ops"] == 2 and d["fences_per_op"] > 0


def test_check_trace_flags_unflushed_root_swing():
    """Synthetic violation: hand-built event stream where a root swing
    publishes a record none of whose words are durable."""
    from repro.analysis.trace import TraceEvent
    from repro.core import layout
    r = Ralloc(None, 2 * (1 << 20), sim_nvm=True, seed=4, expand_sbs=1)
    PrefixIndex(r, slot=9)
    base = r.mem.nvm.copy()
    rec = r.config.sb_base + 100
    events = [
        TraceEvent(0, 0, "write", rec, 1),
        TraceEvent(1, 0, "write", layout.M_ROOTS + 9,
                   rec - r.config.sb_base + 1),
    ]
    rep = check_trace(events, base, standard_rules(r))
    assert any(v.rule == "record-durable-before-root-swing"
               for v in rep.violations), rep


# ---------------------------------------------------------------------------
# faults registry
# ---------------------------------------------------------------------------
def test_faults_suppress_scoped_and_typo_rejected():
    site = "heap.set_root.persist"
    assert not faults.is_suppressed(site)
    with faults.suppress(site):
        assert faults.is_suppressed(site)
    assert not faults.is_suppressed(site)
    with pytest.raises(ValueError):
        with faults.suppress("no.such.site"):
            pass


# ---------------------------------------------------------------------------
# seal checksum (torn-record hardening)
# ---------------------------------------------------------------------------
def test_checksum_zero_fields_nonzero_and_never_pptr_tag():
    assert _record_checksum(0, 0, 0, 0) != 0     # zeroed seal word invalid
    rng = np.random.default_rng(7)
    for _ in range(500):
        vals = [int(x) for x in rng.integers(0, 1 << 62, size=4)]
        c = _record_checksum(*vals)
        assert 0 <= c < (1 << 16)
        assert c != pp.PPTR_TAG              # conservative-scan equivalence


def test_record_is_valid_detects_each_torn_field():
    r = Ralloc(None, 2 * (1 << 20), sim_nvm=True, seed=5, expand_sbs=1)
    idx = PrefixIndex(r)
    p = r.malloc(2 * SB_SIZE - 256)
    r.set_root(0, p)
    rec = idx.publish(hash_tokens([3]), p, n_pages=2, lease_sbs=1)
    assert record_is_valid(r, rec)
    for off in (1, 2, 3, 4):                 # every sealed word
        saved = r.read_word(rec + off)
        r.write_word(rec + off, saved ^ 0x10000)
        assert not record_is_valid(r, rec), f"tear in word {off} missed"
        r.write_word(rec + off, saved)
    assert record_is_valid(r, rec)
    # …but a next-pointer rewrite (neighbour unlink) must NOT invalidate
    r.write_word(rec, pp.PPTR_NULL)
    assert record_is_valid(r, rec)
    # out-of-bounds addresses are invalid, not crashes
    assert not record_is_valid(r, r.config.total_words + 5)


# ---------------------------------------------------------------------------
# static checks
# ---------------------------------------------------------------------------
def test_static_nvm001_store_flagged_and_allowed_in_atomics():
    src = "def f(mem):\n    mem.nvm[3] = 7\n"
    assert [f.code for f in check_source("x.py", src)] == ["NVM001"]
    assert check_source("x.py", src, allow_nvm_store=True) == []
    # reads don't count
    assert check_source("x.py", "def f(mem):\n    return mem.nvm[3]\n") == []


def test_static_shd001_sharding_refs_flagged_outside_runtime():
    for src in ("from jax.experimental.shard_map import shard_map\n",
                "import jax.experimental.shard_map as sm\n",
                "from jax.sharding import AxisType\n",
                "def f():\n    import jax\n    return jax.sharding.AxisType\n"):
        codes = [f.code for f in check_source("x.py", src)]
        assert codes and set(codes) == {"SHD001"}, src
        assert check_source("x.py", src, allow_sharding=True) == []
    # the runtime facade re-export is the sanctioned path
    assert check_source("x.py", "from repro.runtime import shard_map\n") == []


def test_static_per001_unflushed_persistent_write():
    bad = "def g(mem, layout):\n    mem.write(layout.M_ROOTS + 1, 5)\n"
    assert [f.code for f in check_source("x.py", bad)] == ["PER001"]
    ok = ("def g(mem, layout):\n"
          "    mem.write(layout.M_ROOTS + 1, 5)\n"
          "    mem.flush(layout.M_ROOTS + 1)\n"
          "    mem.fence()\n")
    assert check_source("x.py", ok) == []
    deferred = ("def g(mem, layout):\n"
                f"    # {DEFER_ANNOTATION}: drained at close\n"
                "    mem.write(layout.D_SIZE_CLASS, 0)\n")
    assert check_source("x.py", deferred) == []
    # a layout constant used as a *value* is not a persistent-field write
    val = "def g(mem, layout):\n    mem.write(10, layout.M_ROOTS)\n"
    assert check_source("x.py", val) == []


def test_static_trn001_transient_index_never_flushed():
    # naming a free-run index array in any flush-like call is the bug:
    # the index is transient, rebuilt by recovery's sweep
    bad = "def g(mem, st):\n    mem.flush(st.run_bucket_min)\n"
    assert [f.code for f in check_source("x.py", bad)] == ["TRN001"]
    bad_kw = "def g(mem, st):\n    mem.flush_range(base, n=st.run_len)\n"
    assert [f.code for f in check_source("x.py", bad_kw)] == ["TRN001"]
    # reading/maintaining the arrays outside persistence calls is fine
    ok = ("def g(st):\n"
          "    rl = st.run_len + 1\n"
          "    return rl, st.run_start, st.run_bucket_min\n")
    assert check_source("x.py", ok) == []


def test_static_lint_current_tree_is_clean():
    findings = check_tree(REPO / "src" / "repro")
    assert findings == [], "\n".join(map(str, findings))


def test_lint_cli_exits_zero_on_tree():
    proc = subprocess.run(
        [sys.executable, str(REPO / "tools" / "lint_persist.py"),
         str(REPO / "src" / "repro")],
        capture_output=True, text=True, cwd=REPO)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "clean" in proc.stdout