"""Mutation tests: the persist-order checker has teeth.

Each seeded flush/fence site in the allocator can be suppressed via
``repro.analysis.faults.suppress``.  For every site we run a scenario
that exercises it and assert the trace checker reports a violation of
the *expected* rule; the identical unmutated scenario must report zero
violations.  This is the ISSUE's acceptance bar: the checker is only
evidence if deleting a barrier actually trips it.

The shadow model is strict (flush-after-write + fence required), so
these results are deterministic — no dependence on the simulator's
random eviction, and identical in sim and fast modes.
"""

import pytest

from repro.analysis import faults
from repro.analysis.persist_lint import check_allocator
from repro.analysis.trace import attach_tracer
from repro.core.layout import SB_SIZE
from repro.core.prefix_index import PrefixIndex, hash_tokens
from repro.core.ralloc import Ralloc

HEAP_BYTES = 4 * (1 << 20)


def _heap(seed):
    r = Ralloc(None, HEAP_BYTES, sim_nvm=True, seed=seed, expand_sbs=1)
    tr = attach_tracer(r)
    return r, tr


def _publish_scenario(seed=11):
    """Allocate a 2-sb span, root it, publish a prefix record."""
    r, tr = _heap(seed)
    idx = PrefixIndex(r)
    p = r.malloc(2 * SB_SIZE - 256)
    r.write_word(p, 0x1111)
    r.flush_range(p, 1)
    r.fence()
    r.set_root(0, p)
    idx.publish(hash_tokens([1]), p, n_pages=2, lease_sbs=2)
    return r, tr, idx


def _rules_fired(r, tr):
    rep = check_allocator(r, tr)
    return rep, {v.rule for v in rep.violations}


# ---------------------------------------------------------------------------
# baseline: every scenario below, unmutated, is clean
# ---------------------------------------------------------------------------
def test_unmutated_combined_scenario_is_clean():
    r, tr, idx = _publish_scenario()
    # second record → later mid-chain removal path
    q = r.malloc(3 * SB_SIZE - 256)
    r.set_root(1, q)
    idx.publish(hash_tokens([2]), q, n_pages=1, lease_sbs=3)
    assert idx.remove(hash_tokens([1]))          # mid-chain unlink
    r.span_trim(q, 1)                            # tail trim
    # free an unpublished span end-to-end
    s = r.malloc(SB_SIZE)
    r.set_root(2, s)
    r.set_root(2, None)
    r.free(s)
    rep, fired = _rules_fired(r, tr)
    assert rep.ok, rep
    assert fired == set()


# ---------------------------------------------------------------------------
# one test per fault site
# ---------------------------------------------------------------------------
def test_mutation_publish_fields_persist():
    r, tr = _heap(21)
    idx = PrefixIndex(r)
    p = r.malloc(2 * SB_SIZE - 256)
    r.set_root(0, p)
    with faults.suppress("prefix_index.publish.fields_persist"):
        idx.publish(hash_tokens([1]), p, n_pages=2, lease_sbs=2)
    rep, fired = _rules_fired(r, tr)
    assert not rep.ok
    assert "record-fields-durable-before-seal" in fired, rep


def test_mutation_publish_record_persist():
    r, tr = _heap(22)
    idx = PrefixIndex(r)
    p = r.malloc(2 * SB_SIZE - 256)
    r.set_root(0, p)
    with faults.suppress("prefix_index.publish.record_persist"):
        idx.publish(hash_tokens([1]), p, n_pages=2, lease_sbs=2)
    rep, fired = _rules_fired(r, tr)
    assert not rep.ok
    assert "record-durable-before-root-swing" in fired, rep


def test_mutation_remove_unlink_persist():
    r, tr = _heap(23)
    idx = PrefixIndex(r)
    p = r.malloc(2 * SB_SIZE - 256)
    r.set_root(0, p)
    q = r.malloc(SB_SIZE)
    r.set_root(1, q)
    idx.publish(hash_tokens([1]), p, n_pages=2, lease_sbs=2)
    idx.publish(hash_tokens([2]), q, n_pages=1, lease_sbs=1)
    with faults.suppress("prefix_index.remove.unlink_persist"):
        assert idx.remove(hash_tokens([1]))      # NOT the head → mid-chain
    rep, fired = _rules_fired(r, tr)
    assert not rep.ok
    assert "unlink-durable-before-lease-release" in fired, rep


def test_mutation_set_root_persist():
    r, tr = _heap(24)
    idx = PrefixIndex(r)
    p = r.malloc(2 * SB_SIZE - 256)
    with faults.suppress("heap.set_root.persist"):
        r.set_root(0, p)
        idx.publish(hash_tokens([1]), p, n_pages=2, lease_sbs=2)
    rep, fired = _rules_fired(r, tr)
    assert not rep.ok
    assert "root-swing-durable-at-publish-end" in fired, rep


def test_mutation_trim_tail_persist():
    r, tr = _heap(25)
    p = r.malloc(3 * SB_SIZE - 256)
    r.set_root(0, p)
    with faults.suppress("ralloc.trim_tail.persist"):
        r.span_trim(p, 1)
    rep, fired = _rules_fired(r, tr)
    assert not rep.ok
    assert "trim-shrink-durable-before-tail-free" in fired, rep


def test_mutation_free_large_persist():
    # The span must have no other lease holders (no published record):
    # freeing a leased span only decrements the lease and never reaches
    # _free_large's persist at all.
    r, tr = _heap(26)
    p = r.malloc(SB_SIZE)
    r.set_root(1, p)
    r.set_root(1, None)
    with faults.suppress("ralloc.free_large.persist"):
        r.free(p)
    rep, fired = _rules_fired(r, tr)
    assert not rep.ok
    assert "span-records-cleared-before-free" in fired, rep


# ---------------------------------------------------------------------------
# the wiring has teeth too: a suppressed site makes the crash harness fail
# ---------------------------------------------------------------------------
def test_crash_harness_detects_suppressed_site():
    from crash_points import run_crash_points
    ops = [("alloc", 2), ("publish", 1)]
    with faults.suppress("prefix_index.publish.record_persist"):
        with pytest.raises(AssertionError):
            run_crash_points(ops, seed=90)
