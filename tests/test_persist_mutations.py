"""Mutation tests: the persist-order checker has teeth.

Each seeded flush/fence site in the allocator can be suppressed via
``repro.analysis.faults.suppress``.  For every site we run a scenario
that exercises it and assert the trace checker reports a violation of
the *expected* rule; the identical unmutated scenario must report zero
violations.  This is the ISSUE's acceptance bar: the checker is only
evidence if deleting a barrier actually trips it.

The shadow model is strict (flush-after-write + fence required), so
these results are deterministic — no dependence on the simulator's
random eviction, and identical in sim and fast modes.
"""

import pytest

from repro.analysis import faults
from repro.analysis.persist_lint import check_allocator
from repro.analysis.trace import attach_tracer
from repro.core.layout import SB_SIZE
from repro.core.prefix_index import PrefixIndex, hash_tokens
from repro.core.ralloc import Ralloc

HEAP_BYTES = 4 * (1 << 20)


def _heap(seed):
    r = Ralloc(None, HEAP_BYTES, sim_nvm=True, seed=seed, expand_sbs=1)
    tr = attach_tracer(r)
    return r, tr


def _publish_scenario(seed=11):
    """Allocate a 2-sb span, root it, publish a prefix record."""
    r, tr = _heap(seed)
    idx = PrefixIndex(r)
    p = r.malloc(2 * SB_SIZE - 256)
    r.write_word(p, 0x1111)
    r.flush_range(p, 1)
    r.fence()
    r.set_root(0, p)
    idx.publish(hash_tokens([1]), p, n_pages=2, lease_sbs=2)
    return r, tr, idx


def _rules_fired(r, tr):
    rep = check_allocator(r, tr)
    return rep, {v.rule for v in rep.violations}


# ---------------------------------------------------------------------------
# baseline: every scenario below, unmutated, is clean
# ---------------------------------------------------------------------------
def test_unmutated_combined_scenario_is_clean():
    r, tr, idx = _publish_scenario()
    # second record → later mid-chain removal path
    q = r.malloc(3 * SB_SIZE - 256)
    r.set_root(1, q)
    idx.publish(hash_tokens([2]), q, n_pages=1, lease_sbs=3)
    assert idx.remove(hash_tokens([1]))          # mid-chain unlink
    r.span_trim(q, 1)                            # tail trim
    # free an unpublished span end-to-end
    s = r.malloc(SB_SIZE)
    r.set_root(2, s)
    r.set_root(2, None)
    r.free(s)
    rep, fired = _rules_fired(r, tr)
    assert rep.ok, rep
    assert fired == set()


# ---------------------------------------------------------------------------
# one test per fault site
# ---------------------------------------------------------------------------
def test_mutation_publish_fields_persist():
    r, tr = _heap(21)
    idx = PrefixIndex(r)
    p = r.malloc(2 * SB_SIZE - 256)
    r.set_root(0, p)
    with faults.suppress("prefix_index.publish.fields_persist"):
        idx.publish(hash_tokens([1]), p, n_pages=2, lease_sbs=2)
    rep, fired = _rules_fired(r, tr)
    assert not rep.ok
    assert "record-fields-durable-before-seal" in fired, rep


def test_mutation_publish_record_persist():
    r, tr = _heap(22)
    idx = PrefixIndex(r)
    p = r.malloc(2 * SB_SIZE - 256)
    r.set_root(0, p)
    with faults.suppress("prefix_index.publish.record_persist"):
        idx.publish(hash_tokens([1]), p, n_pages=2, lease_sbs=2)
    rep, fired = _rules_fired(r, tr)
    assert not rep.ok
    assert "record-durable-before-root-swing" in fired, rep


def test_mutation_remove_unlink_persist():
    r, tr = _heap(23)
    idx = PrefixIndex(r)
    p = r.malloc(2 * SB_SIZE - 256)
    r.set_root(0, p)
    q = r.malloc(SB_SIZE)
    r.set_root(1, q)
    idx.publish(hash_tokens([1]), p, n_pages=2, lease_sbs=2)
    idx.publish(hash_tokens([2]), q, n_pages=1, lease_sbs=1)
    with faults.suppress("prefix_index.remove.unlink_persist"):
        assert idx.remove(hash_tokens([1]))      # NOT the head → mid-chain
    rep, fired = _rules_fired(r, tr)
    assert not rep.ok
    assert "unlink-durable-before-lease-release" in fired, rep


def test_mutation_set_root_persist():
    r, tr = _heap(24)
    idx = PrefixIndex(r)
    p = r.malloc(2 * SB_SIZE - 256)
    with faults.suppress("heap.set_root.persist"):
        r.set_root(0, p)
        idx.publish(hash_tokens([1]), p, n_pages=2, lease_sbs=2)
    rep, fired = _rules_fired(r, tr)
    assert not rep.ok
    assert "root-swing-durable-at-publish-end" in fired, rep


def test_mutation_trim_tail_persist():
    r, tr = _heap(25)
    p = r.malloc(3 * SB_SIZE - 256)
    r.set_root(0, p)
    with faults.suppress("ralloc.trim_tail.persist"):
        r.span_trim(p, 1)
    rep, fired = _rules_fired(r, tr)
    assert not rep.ok
    assert "trim-shrink-durable-before-tail-free" in fired, rep


def test_mutation_free_large_persist():
    # The span must have no other lease holders (no published record):
    # freeing a leased span only decrements the lease and never reaches
    # _free_large's persist at all.
    r, tr = _heap(26)
    p = r.malloc(SB_SIZE)
    r.set_root(1, p)
    r.set_root(1, None)
    with faults.suppress("ralloc.free_large.persist"):
        r.free(p)
    rep, fired = _rules_fired(r, tr)
    assert not rep.ok
    assert "span-records-cleared-before-free" in fired, rep


# ---------------------------------------------------------------------------
# group commit (publish_batch / remove_batch): the relaxed rules have teeth
# ---------------------------------------------------------------------------
def _batch_scenario(seed, n=3):
    """n spans rooted, published in ONE group commit."""
    r, tr = _heap(seed)
    idx = PrefixIndex(r)
    spans = []
    for i in range(n):
        p = r.malloc(2 * SB_SIZE - 256)
        r.set_root(i, p)
        spans.append(p)
    items = [(hash_tokens([i + 1]), p, 2, 2) for i, p in enumerate(spans)]
    return r, tr, idx, items


def test_unmutated_batch_scenario_is_clean():
    r, tr, idx, items = _batch_scenario(31)
    recs = idx.publish_batch(items)
    assert all(rec is not None for rec in recs)
    # the whole batch is on the chain, newest item first
    assert [rec.key for rec in idx.records()] == [k for k, *_ in items]
    # batched eviction of a generation: mid-chain + head victims in one call
    assert idx.remove_batch([items[0][0], items[1][0]]) == 2
    assert [rec.key for rec in idx.records()] == [items[2][0]]
    assert idx.remove_batch([items[2][0]]) == 1
    rep, fired = _rules_fired(r, tr)
    assert rep.ok, rep
    assert fired == set()
    # fences/op reflects the amortization: 3 publishes rode one commit
    assert rep.diagnostics["notes"]["publish_batch_end"] == 1
    assert rep.diagnostics["ops"] >= 6        # 3 publishes + 3 removals


def test_batch_publish_fences_amortized():
    """The group commit's whole point: N publishes cost ~3 fences, not 4N."""
    def publish_fences(batched):
        from repro.core.prefix_index import REC_BYTES
        r, tr, idx, items = _batch_scenario(32)
        r.free(r.malloc(REC_BYTES))   # warm the record class: measure the
        before = r.mem.n_fence        # protocol, not one-off sb claims
        if batched:
            idx.publish_batch(items)
        else:
            for it in items:
                idx.publish(*it)
        return r.mem.n_fence - before
    single, batch = publish_fences(False), publish_fences(True)
    # ≥3 fences per strict publish (fields, seal, swing; the content
    # boundary fence elides here — nothing was flushed since the span
    # allocs fenced, so it would commit nothing)
    assert single >= 3 * 3
    assert batch <= 3 + 1                     # shared fences + root swing
    assert batch * 2 < single


def test_mutation_publish_batch_fields_persist():
    r, tr, idx, items = _batch_scenario(33)
    with faults.suppress("prefix_index.publish_batch.fields_persist"):
        idx.publish_batch(items)
    rep, fired = _rules_fired(r, tr)
    assert not rep.ok
    assert "batch-fields-durable-before-seal" in fired, rep


def test_mutation_publish_batch_records_persist():
    r, tr, idx, items = _batch_scenario(34)
    with faults.suppress("prefix_index.publish_batch.records_persist"):
        idx.publish_batch(items)
    rep, fired = _rules_fired(r, tr)
    assert not rep.ok
    assert "batch-records-durable-before-root-swing" in fired, rep


def test_mutation_set_root_persist_batch():
    r, tr, idx, items = _batch_scenario(35)
    with faults.suppress("heap.set_root.persist"):
        idx.publish_batch(items)
    rep, fired = _rules_fired(r, tr)
    assert not rep.ok
    assert "root-swing-durable-at-batch-end" in fired, rep


def test_mutation_remove_batch_unlink_persist():
    r, tr, idx, items = _batch_scenario(36)
    idx.publish_batch(items)
    # victim is mid-chain: its unlink is a predecessor next-word rewrite,
    # exactly the write the shared fence must cover
    with faults.suppress("prefix_index.remove_batch.unlink_persist"):
        assert idx.remove_batch([items[1][0]]) == 1
    rep, fired = _rules_fired(r, tr)
    assert not rep.ok
    assert "unlink-durable-before-lease-release" in fired, rep


# ---------------------------------------------------------------------------
# prefix trie (core.prefix_trie): every structural fence has teeth
# ---------------------------------------------------------------------------
def _trie_heap(seed):
    from repro.core.prefix_trie import PrefixTrie
    r, tr = _heap(seed)
    return r, tr, PrefixTrie(r, page=4, sb_pages=1)


def _pages(n, start=0):
    return list(range(start * 1000, start * 1000 + n * 4))


def test_unmutated_trie_scenario_is_clean():
    r, tr, trie = _trie_heap(41)
    a = _pages(6)
    trie.insert(a, r.malloc(6 * SB_SIZE - 256))          # insert commit
    b = a[:16] + _pages(3, start=7)                      # shares 4 pages
    trie.insert(b, r.malloc(7 * SB_SIZE - 256))          # split + insert
    leaf = next(n for n in trie.nodes()
                if not n.children and n.ptr != r.heap.get_root(trie.slot))
    trie.remove(leaf)                                    # mid-chain unlink
    rep, fired = _rules_fired(r, tr)
    assert rep.ok, rep
    assert fired == set()


def test_mutation_trie_fields_persist():
    r, tr, trie = _trie_heap(42)
    with faults.suppress("prefix_trie.commit.fields_persist"):
        trie.insert(_pages(3), r.malloc(3 * SB_SIZE - 256))
    rep, fired = _rules_fired(r, tr)
    assert not rep.ok
    assert "trie-fields-durable-before-seal" in fired, rep


def test_mutation_trie_records_persist():
    r, tr, trie = _trie_heap(43)
    with faults.suppress("prefix_trie.commit.records_persist"):
        trie.insert(_pages(3), r.malloc(3 * SB_SIZE - 256))
    rep, fired = _rules_fired(r, tr)
    assert not rep.ok
    assert "trie-record-durable-before-root-swing" in fired, rep


def test_mutation_trie_relink_persist():
    # the split victim must NOT be the chain head: a head split relinks
    # through set_root (its own internal durable site) and the
    # predecessor-rewrite fence under test is never reached
    r, tr, trie = _trie_heap(44)
    a = _pages(6)
    trie.insert(a, r.malloc(6 * SB_SIZE - 256))
    trie.insert(_pages(3, start=5), r.malloc(3 * SB_SIZE - 256))
    c = a[:16] + _pages(3, start=9)              # mid-edge: splits A at 4
    with faults.suppress("prefix_trie.commit.relink_persist"):
        trie.insert(c, r.malloc(7 * SB_SIZE - 256))
    rep, fired = _rules_fired(r, tr)
    assert not rep.ok
    assert "unlink-durable-before-lease-release" in fired, rep


def test_mutation_trie_reparent_persist():
    r, tr, trie = _trie_heap(45)
    a = _pages(6)
    trie.insert(a, r.malloc(6 * SB_SIZE - 256))
    d = a + _pages(2, start=5)                   # child of A at page 6
    trie.insert(d, r.malloc(8 * SB_SIZE - 256))
    c = a[:16] + _pages(3, start=9)              # splits A at 4 → D reparents
    with faults.suppress("prefix_trie.split.reparent_persist"):
        trie.insert(c, r.malloc(7 * SB_SIZE - 256))
    rep, fired = _rules_fired(r, tr)
    assert not rep.ok
    assert "trie-reparent-durable-before-old-free" in fired, rep


def test_mutation_trie_remove_unlink_persist():
    r, tr, trie = _trie_heap(46)
    trie.insert(_pages(3), r.malloc(3 * SB_SIZE - 256))
    trie.insert(_pages(3, start=5), r.malloc(3 * SB_SIZE - 256))
    # a mid-chain leaf: the head's unlink would go through set_root
    leaf = next(n for n in trie.nodes()
                if not n.children and n.ptr != r.heap.get_root(trie.slot))
    with faults.suppress("prefix_trie.remove.unlink_persist"):
        trie.remove(leaf)
    rep, fired = _rules_fired(r, tr)
    assert not rep.ok
    assert "unlink-durable-before-lease-release" in fired, rep


# ---------------------------------------------------------------------------
# the wiring has teeth too: a suppressed site makes the crash harness fail
# ---------------------------------------------------------------------------
def test_crash_harness_detects_suppressed_site():
    from crash_points import run_crash_points
    ops = [("alloc", 2), ("publish", 1)]
    with faults.suppress("prefix_index.publish.record_persist"):
        with pytest.raises(AssertionError):
            run_crash_points(ops, seed=90)
