"""Durable prefix index unit + property tests (core.prefix_index).

The index's contract: publishing appends one durable record (the only
new persistent writes) whose span reference reconstructs the prefix
cache's lease across a crash; the registered filter function traces
records *precisely* yet marks exactly the live set a conservative scan
would; recovery re-trims each record's conservatively-rebuilt
full-extent lease down to the recorded superblock count.
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # container without dev deps
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import pptr as pp
from repro.core import recovery
from repro.core.filters import conservative_filter, prefix_index_filter
from repro.core.layout import SB_SIZE
from repro.core.prefix_index import (PREFIX_INDEX_ROOT, REC_BYTES,
                                     PrefixIndex, hash_tokens, iter_records)
from repro.core.ralloc import Ralloc

MB = 1 << 20


def fresh(size_mb: int = 8, **kw):
    r = Ralloc(None, size_mb * MB, expand_sbs=1, **kw)
    return r, PrefixIndex(r)


# ----------------------------------------------------------------- hashing
def test_hash_tokens_deterministic_and_untagged():
    a = hash_tokens([1, 2, 3])
    assert a == hash_tokens((1, 2, 3))
    assert a != hash_tokens([3, 2, 1])           # order-sensitive
    for toks in ([], [0], [7] * 100, range(500)):
        h = hash_tokens(toks)
        assert 0 <= h < (1 << 48)                # storable, never pptr-tagged
        assert not pp.looks_like_pptr(h)


# ---------------------------------------------------- publish / remove CRUD
def test_publish_appends_and_remove_unlinks():
    r, idx = fresh()
    spans = [r.malloc(2 * SB_SIZE - 256) for _ in range(3)]
    keys = [hash_tokens([k]) for k in range(3)]
    for k, s in zip(keys, spans):
        assert idx.publish(k, s, n_pages=4, lease_sbs=1) is not None
    got = idx.records()
    assert [rec.key for rec in got] == keys[::-1]        # newest first
    assert [rec.span for rec in got] == spans[::-1]
    assert all(rec.n_pages == 4 and rec.lease_sbs == 1 for rec in got)
    assert idx.lookup(keys[1]).span == spans[1]
    # each publish holds one transient prefix lease
    for s in spans:
        assert r.span_lease_counts(s)[0] == 2

    assert idx.remove(keys[1])                   # middle of the chain
    assert [rec.key for rec in idx.records()] == [keys[2], keys[0]]
    assert r.span_lease_counts(spans[1]) == [1, 1]   # its lease released
    assert not idx.remove(keys[1])               # already gone
    assert idx.remove(keys[2])                   # head of the chain
    assert [rec.key for rec in idx.records()] == [keys[0]]
    assert idx.clear() == 1
    assert idx.records() == []
    for s in spans:                              # cache leases all released
        assert r.span_lease_counts(s) == [1, 1]


def test_publish_rejects_bad_args():
    r, idx = fresh()
    s = r.malloc(2 * SB_SIZE - 256)
    with pytest.raises(ValueError):
        idx.publish(1, s, n_pages=1, lease_sbs=0)        # empty lease
    small = r.malloc(64)
    with pytest.raises(ValueError):
        idx.publish(1, small, n_pages=1, lease_sbs=1)    # not a span
    r.free(s)
    with pytest.raises(ValueError):
        idx.publish(1, s, n_pages=1, lease_sbs=1)        # dead span


def test_record_blocks_recycle_through_the_allocator():
    """Records are ordinary blocks: removal frees them for reuse."""
    r, idx = fresh()
    s = r.malloc(2 * SB_SIZE - 256)
    rec = idx.publish(5, s, n_pages=2, lease_sbs=1)
    idx.remove(5)
    assert r.malloc(REC_BYTES) == rec            # LIFO thread cache


# ------------------------------------------------- filter round-trip (sat.)
@settings(max_examples=20, deadline=None)
@given(st.integers(1, 6), st.integers(0, 2 ** 48 - 1))
def test_filter_round_trip_matches_conservative_scan(n_recs, key0):
    """Satellite: for index records, the typed filter and a conservative
    Boehm-style scan must mark the SAME live set — same record-word
    targets per record, and an identical reachable set whether the trace
    runs typed or untyped."""
    r, idx = fresh()
    spans = []
    for i in range(n_recs):
        s = r.malloc((1 + i % 3) * SB_SIZE - 256)
        spans.append(s)
        assert idx.publish((key0 + i) % (1 << 48), s,
                           n_pages=1 + i, lease_sbs=1) is not None
    # per-record: identical target sets (the typed filter only adds type
    # names for precise recursion; it may not see more or fewer words)
    for rec in idx.records():
        typed = {t for t, _ in prefix_index_filter(r, rec.ptr, REC_BYTES)}
        cons = {t for t, _ in conservative_filter(r, rec.ptr, REC_BYTES)}
        assert typed == cons, (typed, cons)
    # whole-trace: same reachable set and same span reference counts
    refs_typed: dict = {}
    r._root_filters[PREFIX_INDEX_ROOT] = "prefix_index"
    typed_set = set(recovery.trace(r, refs_typed))
    refs_cons: dict = {}
    r._root_filters[PREFIX_INDEX_ROOT] = None
    cons_set = set(recovery.trace(r, refs_cons))
    r._root_filters[PREFIX_INDEX_ROOT] = "prefix_index"
    assert typed_set == cons_set
    assert refs_typed == refs_cons
    assert all(refs_typed[r.heap.sb_of(s)] == 1 for s in spans)


# ---------------------------------------------------------- crash recovery
def test_records_survive_crash_and_retrim_leases():
    """End-to-end host tentpole: a crash forgets every transient lease;
    recovery rebuilds the cache's lease FROM the record and re-trims it
    to the recorded superblock count, freeing the decode-ahead tail
    immediately — while a rooted holder keeps its conservative
    full-extent lease."""
    r = Ralloc(None, 8 * MB, sim_nvm=True, seed=3, expand_sbs=1)
    idx = PrefixIndex(r)
    s = r.malloc(4 * SB_SIZE - 256)
    sb = r.heap.sb_of(s)
    r.write_word(s, 0xFEED)
    r.flush_range(s, 1)
    r.fence()
    r.set_root(0, s)                             # the owner's durable root
    key = hash_tokens([9, 9])
    idx.publish(key, s, n_pages=3, lease_sbs=2)
    assert r.span_lease_counts(s) == [2, 2, 1, 1]
    r.mem.drain()
    img = r.mem.nvm.copy()                       # crash with owner live

    r2 = Ralloc(None, 8 * MB, sim_nvm=True, seed=4, backing=img,
                expand_sbs=1)
    idx2 = PrefixIndex(r2)
    r2.get_root(0)
    stats = r2.recover()
    assert stats["index_records"] == 1 and stats["index_retrims"] == 1
    # owner root: full extent; record: re-trimmed to 2 sbs
    assert r2.span_lease_counts(s) == [2, 2, 1, 1]
    rec = idx2.lookup(key)
    assert rec.span == s and rec.n_pages == 3 and rec.lease_sbs == 2
    assert r2.read_word(s) == 0xFEED

    # owner exits (unroot BEFORE releasing) → only the re-trimmed record
    # lease remains: the decode-ahead tail frees NOW, not when some lane
    # re-finishes
    r2.set_root(0, None)
    r2.free(s)
    assert r2.span_lease_counts(s) == [1, 1]
    assert recovery.free_superblock_runs(r2) == [(sb + 2, 2)]
    # crash AGAIN with the record as the span's only reference
    r2.mem.drain()
    img2 = r2.mem.nvm.copy()
    r3 = Ralloc(None, 8 * MB, sim_nvm=True, seed=5, backing=img2,
                expand_sbs=1)
    idx3 = PrefixIndex(r3)
    stats = r3.recover()
    assert stats["index_records"] == 1
    assert r3.span_lease_counts(s) == [1, 1]     # extent stayed trimmed
    assert idx3.remove(key)                      # unpublish frees the prefix
    assert (sb, 2) in recovery.free_superblock_runs(r3) or \
        any(a <= sb < a + ln for a, ln in recovery.free_superblock_runs(r3))


def test_crash_before_root_swing_leaves_no_dangling_record():
    """The publish_durable window: a crash after the record words are
    durable but before the root swings leaves the record unreachable —
    GC frees its block, the lease count falls back to the durable roots,
    and nothing dangles."""
    r = Ralloc(None, 8 * MB, sim_nvm=True, seed=7, expand_sbs=1)
    idx = PrefixIndex(r)
    s = r.malloc(3 * SB_SIZE - 256)
    r.set_root(0, s)
    r.mem.drain(); r.fence()
    # replay publish's steps by hand, stopping before the root swing
    r.span_acquire(s, 1)
    r.fence()
    rec = r.malloc(REC_BYTES)
    r.write_word(rec, pp.PPTR_NULL)
    r.write_word(rec + 1, pp.encode(rec + 1, s))
    r.write_word(rec + 2, 0xABCD)
    r.write_word(rec + 3, 1)
    r.write_word(rec + 4, 1)
    r.flush_range(rec, 5)
    r.fence()                                    # record durable …
    r.mem.drain()
    img = r.mem.nvm.copy()                       # … crash BEFORE the swing

    r2 = Ralloc(None, 8 * MB, sim_nvm=True, seed=8, backing=img,
                expand_sbs=1)
    idx2 = PrefixIndex(r2)
    r2.get_root(0)
    stats = r2.recover()
    assert stats["index_records"] == 0           # unreachable → no record
    assert idx2.records() == []
    assert r2.span_lease_counts(s) == [1, 1, 1]  # the durable root only
    # the record block was swept: it is allocatable again
    assert r2.malloc(REC_BYTES) is not None
    r2.free(s)                                   # one free tears it down
    with pytest.raises(ValueError):
        r2.free(s)


def test_iter_records_survives_cycles():
    """Defensive: a corrupt image whose chain loops must not hang."""
    r, idx = fresh()
    s = r.malloc(2 * SB_SIZE - 256)
    a = idx.publish(1, s, n_pages=1, lease_sbs=1)
    b = idx.publish(2, s, n_pages=1, lease_sbs=1)
    r.write_word(a, pp.encode(a, b))             # a → b → a cycle
    recs = list(iter_records(r))
    assert [rec.ptr for rec in recs] == [b, a]
