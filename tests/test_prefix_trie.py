"""Durable token-radix prefix trie unit + property tests
(core.prefix_trie).

The trie's contract: each node owns a page range of a published prompt
plus a prefix lease of exactly the superblocks that range's prefix
occupies; longest-prefix match at page granularity (splitting edges as
boundaries materialize); recovery prunes torn/unservable nodes durably
*before* the mark pass, re-publishes every survivor with zero
re-prefill, and re-trims each reconstructed full-extent lease to the
recorded length.
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # container without dev deps
    from _hypothesis_fallback import given, settings, strategies as st

import random

from repro.core import pptr as pp
from repro.core.filters import prefix_trie_filter
from repro.core.layout import SB_SIZE
from repro.core.prefix_index import hash_tokens
from repro.core.prefix_trie import (PREFIX_TRIE_ROOT, REC_WORDS, PrefixTrie,
                                    fingerprint, iter_nodes, page_hashes)
from repro.core.ralloc import Ralloc

MB = 1 << 20
PAGE = 4                                 # tokens per page in these tests


def fresh(size_mb: int = 8, **kw):
    r = Ralloc(None, size_mb * MB, expand_sbs=1, **kw)
    return r, PrefixTrie(r, page=PAGE, sb_pages=1)


def span_for(r, n_pages: int) -> int:
    """A span whose extent covers an ``n_pages``-page prefix
    (``sb_pages=1`` ⇒ one superblock per page)."""
    return r.malloc(n_pages * SB_SIZE - 256)


def toks(rng, n_pages: int, prefix=()):
    out = list(prefix)
    while len(out) < n_pages * PAGE:
        out.append(rng.randrange(1, 1 << 20))
    return out[:n_pages * PAGE]


# ----------------------------------------------------------------- hashing
def test_page_hashes_match_cumulative_prefix_hash():
    rng = random.Random(0)
    t = toks(rng, 5)
    hs = page_hashes(t, PAGE)
    assert len(hs) == 5
    for j, h in enumerate(hs):
        assert h == hash_tokens(t[:(j + 1) * PAGE])


def test_fingerprint_round_trips_untagged():
    for first, last in [(0, 0), (2**40 - 1, 2**33), (-1, -1), (7, 9)]:
        fp = fingerprint(first, last)
        assert 0 <= fp < (1 << 48)
        assert not pp.looks_like_pptr(fp)
        assert fp & 0xFFFFFFFF == first & 0xFFFFFFFF
        assert (fp >> 32) & 0xFFFF == last & 0xFFFF


# ------------------------------------------------------- insert/match CRUD
def test_insert_match_and_split():
    r, trie = fresh()
    rng = random.Random(1)
    a = toks(rng, 6)
    span_a = span_for(r, 6)
    na = trie.insert(a, span_a)
    assert na is not None and (na.start_page, na.end_page) == (0, 6)
    assert na.lease_sbs == 6
    node, k = trie.match(a)
    assert node is na and k == 6
    # prompt sharing 4 pages: mid-edge match reported by lookup
    b = toks(rng, 7, prefix=a[:4 * PAGE])
    node, k = trie.lookup(b)
    assert node is na and k == 4
    # inserting B splits A at page 4: M [0,4) + X' [4,6), B child [4,7)
    span_b = span_for(r, 7)
    nb = trie.insert(b, span_b)
    assert nb is not None and (nb.start_page, nb.end_page) == (4, 7)
    shapes = sorted((n.start_page, n.end_page) for n in trie.nodes())
    assert shapes == [(0, 4), (4, 6), (4, 7)]
    # every node's lease length is exactly its end_page's sb count, and
    # the lease vectors reflect prefix leases: span_a carries the owner
    # + M [0,4) + X' [0,6); B is self-contained on span_b (owner + its
    # own [0,7) lease)
    for n in trie.nodes():
        assert n.lease_sbs == -(-n.end_page // 1)
    assert r.span_lease_counts(span_a) == [3, 3, 3, 3, 2, 2]
    assert r.span_lease_counts(span_b) == [2] * 7
    # exact re-insert is a no-op returning the covering node
    assert trie.insert(a, span_a).end_page == 6
    assert len(trie.nodes()) == 3


def test_remove_leaf_only_and_clear():
    r, trie = fresh()
    rng = random.Random(2)
    a = toks(rng, 4)
    b = toks(rng, 6, prefix=a)
    sa, sb = span_for(r, 4), span_for(r, 6)
    trie.insert(a, sa)
    nb = trie.insert(b, sb)
    na = nb.parent
    with pytest.raises(ValueError):
        trie.remove(na)                       # interior: refuses
    assert trie.remove(nb)
    assert r.span_lease_counts(sb) == [1] * 6     # only the owner remains
    assert trie.clear() == 1
    assert list(iter_nodes(r)) == []
    assert r.span_lease_counts(sa) == [1] * 4


def test_insert_batch_single_commit_fences():
    r, trie = fresh()
    rng = random.Random(3)
    items = []
    for i in range(3):
        t = toks(rng, 3)
        items.append((t, span_for(r, 3)))
    from repro.core.prefix_trie import REC_BYTES
    r.free(r.malloc(REC_BYTES))     # warm the record class
    before = r.mem.n_fence
    nodes = trie.insert_batch(items)
    batch_fences = r.mem.n_fence - before
    assert all(n is not None for n in nodes)
    # content + fields + seals + root swing — not 4 per item
    assert batch_fences <= 4


# ----------------------------------------------------- recovery + re-trim
def test_crash_recovery_republishes_and_retrims():
    r, trie = fresh()
    rng = random.Random(4)
    a = toks(rng, 6)
    b = toks(rng, 7, prefix=a[:4 * PAGE])
    span_a, span_b = span_for(r, 6), span_for(r, 7)
    trie.insert(a, span_a)
    trie.insert(b, span_b)
    # owners exit: only the records' prefix leases keep the spans alive
    r.free(span_a)
    r.free(span_b)
    pre_a = r.span_lease_counts(span_a)
    pre_b = r.span_lease_counts(span_b)
    shapes = sorted((n.key, n.start_page, n.end_page, n.span, n.lease_sbs)
                    for n in trie.nodes())

    stats = r.recover()
    assert stats["trie_records"] == 3
    assert stats["trie_pruned"] == 0
    # X' [4,6) leases [0,6) of span_a but its reconstructed lease was
    # full-extent — exactly one retrim needed (M's lease == its extent
    # prefix already; span_b's node covers its whole extent)
    assert stats["trie_retrims"] >= 1
    # acceptance: post-recovery lease vector EQUALS the pre-crash one
    assert r.span_lease_counts(span_a) == pre_a
    assert r.span_lease_counts(span_b) == pre_b

    # zero re-prefill: a fresh attach re-publishes every surviving node
    t2 = PrefixTrie(r, page=PAGE, sb_pages=1)
    shapes2 = sorted((n.key, n.start_page, n.end_page, n.span, n.lease_sbs)
                     for n in t2.nodes())
    assert shapes2 == shapes
    # recovered nodes are token-less: full-boundary hits only
    node, k = t2.match(a)
    assert k == 6
    node, k = t2.match(b)
    assert k == 7
    # a partial prompt sharing 5 pages clamps to the recovered node
    # boundary at 4 (no page keys to match mid-edge)
    c = toks(rng, 8, prefix=a[:5 * PAGE])
    node, k = t2.match(c)
    assert k == 4 and node.end_page == 4


def test_torn_seal_and_coverage_prune():
    """Tear ONE sealed word of the mid node: pass 1 drops it, pass 2's
    coverage criterion drops the child whose ancestry it covered, and a
    child with an alternative cover is durably re-parented instead."""
    r, trie = fresh()
    rng = random.Random(5)
    a = toks(rng, 6)
    b = toks(rng, 7, prefix=a[:4 * PAGE])
    span_a, span_b = span_for(r, 6), span_for(r, 7)
    trie.insert(a, span_a)      # splits into M [0,4) + X' [4,6) on insert
    trie.insert(b, span_b)      # ... of B [4,7) on span_b
    by_shape = {(n.start_page, n.end_page): n for n in trie.nodes()}
    xp = by_shape[(4, 6)]
    # tear one sealed word (lease count) of X' without resealing
    r.write_word(xp.ptr + 6, xp.lease_sbs + 7)
    r.flush_range(xp.ptr + 6, 1)
    r.fence()
    stats = r.recover()
    # X' torn (pass 1); M [0,4) and B [4,7) survive — B's durable parent
    # dangles but M still covers boundary 4, so B re-parents, not drops
    assert stats["trie_pruned"] == 1
    assert stats["trie_records"] == 2
    t2 = PrefixTrie(r, page=PAGE, sb_pages=1)
    shapes = sorted((n.start_page, n.end_page) for n in t2.nodes())
    assert shapes == [(0, 4), (4, 7)]
    node, k = t2.match(b)
    assert k == 7                         # B serves through the new parent
    assert node.parent.end_page == 4
    # X''s lease died with it and the span was never rooted: only M's
    # [0,4) lease survives, and its retrim freed the tail superblocks
    assert r.span_lease_counts(span_a) == [1, 1, 1, 1]


def test_uncovered_children_drop_transitively():
    """Tear the ROOT-range node: nothing covers [0,4) any more, so the
    whole surviving subtree is unservable and durably dropped."""
    r, trie = fresh()
    rng = random.Random(6)
    a = toks(rng, 4)
    b = toks(rng, 6, prefix=a)
    sa, sb = span_for(r, 4), span_for(r, 6)
    na = trie.insert(a, sa)
    trie.insert(b, sb)
    r.write_word(na.ptr + 4, 99)          # tear end_page of [0,4)
    r.flush_range(na.ptr + 4, 1)
    r.fence()
    stats = r.recover()
    assert stats["trie_pruned"] == 2      # torn root + uncovered child
    assert stats["trie_records"] == 0
    assert list(iter_nodes(r)) == []
    # nothing references the spans any more (their owners were never
    # rooted): the sweep reclaims them entirely
    assert r.span_lease_counts(sa) == []
    assert r.span_lease_counts(sb) == []


# ---------------------------------------------------------------- filters
def test_trie_filter_is_precise():
    r, trie = fresh()
    rng = random.Random(7)
    a = toks(rng, 4)
    b = toks(rng, 6, prefix=a)
    sa, sb = span_for(r, 4), span_for(r, 6)
    trie.insert(a, sa)
    nb = trie.insert(b, sb)
    na = nb.parent
    # the chain head is B's record; its filter yields (next, parent,
    # span) — next and parent both happen to be A's record here, typed;
    # the span recurses conservative — and nothing else
    refs = list(prefix_trie_filter(r, nb.ptr, REC_WORDS * 8))
    tgt = {t for t, _ in refs}
    assert tgt == {na.ptr, sb}
    assert ("prefix_trie" in {ty for t, ty in refs if t == na.ptr})
    # a torn record's span pptr never reaches the tracer (next/parent do)
    r.write_word(nb.ptr + 6, 12345)
    refs = list(prefix_trie_filter(r, nb.ptr, REC_WORDS * 8))
    assert {t for t, _ in refs} == {na.ptr}


# ----------------------------------------------- hash-collision regression
def test_forged_key_collision_rejected_by_fingerprint():
    """Craft a second prompt with the SAME 48-bit cumulative hash but a
    different final token.  An in-process node rejects it by exact
    tokens; a *recovered* (token-less) node — the PR-5 residual — now
    rejects it by the durable fingerprint."""
    rng = random.Random(8)
    a = toks(rng, 3)
    M48 = (1 << 48) - 1
    M64 = (1 << 64) - 1

    def fnv_state(ts):
        h = 0xCBF29CE484222325
        for t in ts:
            h ^= int(t) & M64
            h = (h * 0x100000001B3) & M64
        return h

    # b: same as a except the last two tokens; pick the final token so
    # the low-48 multiplicand matches a's (multiplication mod 2^48
    # depends only on the low 48 bits) -> same 48-bit key
    for delta in range(1, 64):
        b = list(a)
        b[-2] = a[-2] ^ delta
        hp = fnv_state(b[:-1])            # b's state before last token
        h = fnv_state(a[:-1])
        b[-1] = (hp ^ h ^ a[-1]) & M48
        if (b[-1] ^ a[-1]) & 0xFFFF:      # need the low16 to differ
            break
    assert b != a
    assert hash_tokens(b) == hash_tokens(a)

    r, trie = fresh()
    span = span_for(r, 3)
    trie.insert(a, span)
    node, k = trie.match(b)
    assert k == 0                          # in-process: exact tokens
    r.recover()
    t2 = PrefixTrie(r, page=PAGE, sb_pages=1)
    assert t2.match(a)[1] == 3             # the real prompt still serves
    node, k = t2.match(b)
    assert k == 0, "recovered node served a forged collision"


# --------------------------------------------------------------- property
@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10**9))
def test_property_trie_invariants(seed):
    """(a) every node's lease length == its page range's superblock
    count; (b) longest-prefix match agrees with a naive list-scan model;
    (c) the durable image recovers to an equivalent trie."""
    rng = random.Random(seed)
    r = Ralloc(None, 8 * MB, expand_sbs=1)
    trie = PrefixTrie(r, page=PAGE, sb_pages=1)
    published = []
    for _ in range(rng.randrange(2, 5)):
        if published and rng.random() < 0.7:
            base = rng.choice(published)
            cut = rng.randrange(0, len(base) + 1)
            t = toks(rng, rng.randrange(1, 5), prefix=base[:cut])
        else:
            t = toks(rng, rng.randrange(1, 5))
        span = r.malloc((len(t) // PAGE) * SB_SIZE - 256)
        if trie.insert(t, span) is None:
            r.free(span)
            continue
        published.append(t)

    def naive_lpm(q):
        best = 0
        for p in published:
            i = 0
            while (i < min(len(q), len(p)) // PAGE
                   and q[i * PAGE:(i + 1) * PAGE]
                   == p[i * PAGE:(i + 1) * PAGE]):
                i += 1
            best = max(best, i)
        return best

    # (a)
    for n in trie.nodes():
        assert n.lease_sbs == -(-n.end_page // 1)
    # (b): published prompts, shared-prefix probes, and foreign probes
    probes = list(published)
    for p in published:
        cut = rng.randrange(0, len(p) + 1)
        probes.append(toks(rng, 4, prefix=p[:cut]))
    probes.append(toks(rng, 3))
    for q in probes:
        assert trie.match(q)[1] == naive_lpm(q), (seed, q)
    # (c)
    shape = sorted((n.key, n.start_page, n.end_page, n.span, n.lease_sbs)
                   for n in trie.nodes())
    r.recover()
    t2 = PrefixTrie(r, page=PAGE, sb_pages=1)
    shape2 = sorted((n.key, n.start_page, n.end_page, n.span, n.lease_sbs)
                    for n in t2.nodes())
    assert shape2 == shape
    for p in published:                   # full boundaries still serve
        assert t2.match(p)[1] == len(p) // PAGE
