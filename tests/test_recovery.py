"""Recoverability (paper Thm 5.4): crash injection + GC recovery."""

import os
import tempfile

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # container without dev deps
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import layout, pptr as pp
from repro.core.ralloc import Ralloc

MB = 1 << 20


def _durable_stack(r, n, cls_name="stack_node", base=1000):
    head = None
    for k in range(n):
        node = r.malloc(16)
        r.write_word(node, pp.PPTR_NULL if head is None else
                     pp.encode(node, head))
        r.write_word(node + 1, base + k)
        r.flush_range(node, 2)
        r.fence()
        head = node
    return head


def _walk_stack(r, head):
    vals = []
    w = head
    while w is not None:
        vals.append(r.read_word(w + 1))
        w = pp.decode(w, r.read_word(w))
    return vals


def test_crash_recover_stack_with_filter():
    path = tempfile.mktemp()
    r = Ralloc(path, 8 * MB, sim_nvm=True, seed=11)
    head = _durable_stack(r, 80)
    r.set_root(0, head, "stack_node")
    for _ in range(300):
        r.malloc(64)                   # leaked: allocated, never attached
    r.heap.crash()
    del r

    r2 = Ralloc(path, 8 * MB, sim_nvm=True, seed=12)
    assert r2.dirty_restart
    root = r2.get_root(0, "stack_node")
    stats = r2.recover()
    assert stats["reachable_blocks"] == 80
    assert _walk_stack(r2, root) == [1079 - k for k in range(80)]
    r2.close()
    os.unlink(path)


def test_crash_recover_conservative():
    """No filter function ⇒ Boehm-style scan still finds the structure."""
    path = tempfile.mktemp()
    r = Ralloc(path, 8 * MB, sim_nvm=True, seed=21)
    head = _durable_stack(r, 40)
    r.set_root(0, head)                # no type registered
    r.heap.crash()
    del r
    r2 = Ralloc(path, 8 * MB, sim_nvm=True, seed=22)
    r2.get_root(0)                     # conservative
    stats = r2.recover()
    assert stats["reachable_blocks"] >= 40     # false positives allowed
    assert _walk_stack(r2, r2.get_root(0))[:3] == [1039, 1038, 1037]
    r2.close()
    os.unlink(path)


def test_recovered_blocks_never_rehanded():
    path = tempfile.mktemp()
    r = Ralloc(path, 8 * MB, sim_nvm=True, seed=31)
    head = _durable_stack(r, 60)
    r.set_root(0, head, "stack_node")
    r.heap.crash()
    del r
    r2 = Ralloc(path, 8 * MB, sim_nvm=True, seed=32)
    root = r2.get_root(0, "stack_node")
    r2.recover()
    live = set()
    w = root
    while w is not None:
        live.add(w)
        w = pp.decode(w, r2.read_word(w))
    fresh = {r2.malloc(16) for _ in range(4000)}
    assert None not in fresh
    assert not (fresh & live)
    r2.close()
    os.unlink(path)


def test_tree_recovery_binary_filter():
    path = tempfile.mktemp()
    r = Ralloc(path, 8 * MB, sim_nvm=True, seed=41)

    def insert(root, key):
        node = r.malloc(32)
        r.write_word(node, key)
        r.write_word(node + 1, key * 10)
        r.write_word(node + 2, pp.PPTR_NULL)
        r.write_word(node + 3, pp.PPTR_NULL)
        r.flush_range(node, 4)
        r.fence()
        if root is None:
            return node
        cur = root
        while True:
            slot = 2 if key < r.read_word(cur) else 3
            child = pp.decode(cur + slot, r.read_word(cur + slot))
            if child is None:
                r.write_word(cur + slot, pp.encode(cur + slot, node))
                r.flush_range(cur + slot, 1)
                r.fence()
                return root
            cur = child

    rng = np.random.default_rng(0)
    keys = rng.permutation(200)
    root = None
    for k in keys:
        root = insert(root, int(k))
    r.set_root(0, root, "tree_node")
    r.heap.crash()
    del r

    r2 = Ralloc(path, 8 * MB, sim_nvm=True, seed=42)
    rt = r2.get_root(0, "tree_node")
    stats = r2.recover()
    assert stats["reachable_blocks"] == 200

    def count(n):
        if n is None:
            return 0
        l = pp.decode(n + 2, r2.read_word(n + 2))
        rr = pp.decode(n + 3, r2.read_word(n + 3))
        return 1 + count(l) + count(rr)

    assert count(rt) == 200
    r2.close()
    os.unlink(path)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 60), st.integers(0, 200))
def test_property_crash_anywhere_recovers(seed, n_nodes, n_leaks):
    """Random durable structure + random leaks + crash ⇒ after recovery all
    and only reachable blocks are allocated; traversal intact."""
    path = tempfile.mktemp()
    r = Ralloc(path, 8 * MB, sim_nvm=True, seed=seed)
    head = _durable_stack(r, n_nodes)
    r.set_root(0, head, "stack_node")
    rng = np.random.default_rng(seed)
    for _ in range(n_leaks):
        r.malloc(int(rng.choice([16, 64, 400])))
    r.heap.crash()
    del r
    r2 = Ralloc(path, 8 * MB, sim_nvm=True, seed=seed + 1)
    assert r2.dirty_restart
    root = r2.get_root(0, "stack_node")
    stats = r2.recover()
    assert stats["reachable_blocks"] == n_nodes
    assert len(_walk_stack(r2, root)) == n_nodes
    r2.close()
    os.unlink(path)


def test_crash_after_free_large_no_orphan_continuations():
    """A crash right after ``free`` of a multi-superblock object must not
    leave recovery staring at orphaned LARGE_CONT markers: the persistent
    span records are cleared before the superblocks hit the free list."""
    path = tempfile.mktemp()
    r = Ralloc(path, 16 * MB, sim_nvm=True, seed=61)
    head = _durable_stack(r, 10)
    r.set_root(0, head, "stack_node")
    big = r.malloc(300_000)
    r.free(big)
    r.heap.crash()
    del r

    r2 = Ralloc(path, 16 * MB, sim_nvm=True, seed=62)
    r2.get_root(0, "stack_node")
    stats = r2.recover()
    assert stats["large_blocks"] == 0
    used = int(r2.mem.read(layout.M_USED_SBS))
    for sb in range(used):
        assert r2.mem.read(r2.desc(sb, layout.D_SIZE_CLASS)) != \
            layout.LARGE_CONT, f"orphaned continuation marker on sb {sb}"
    assert len(_walk_stack(r2, r2.get_root(0))) == 10
    r2.close()
    os.unlink(path)


def test_large_block_survives_crash_recovery():
    """A *live* (rooted) large object round-trips through host recovery."""
    path = tempfile.mktemp()
    r = Ralloc(path, 16 * MB, sim_nvm=True, seed=71)
    big = r.malloc(200_000)
    for k in range(16):
        r.write_word(big + k, 4242 + k)
    r.flush_range(big, 16)
    r.fence()
    r.set_root(0, big, None)
    r.heap.crash()
    del r

    r2 = Ralloc(path, 16 * MB, sim_nvm=True, seed=72)
    big2 = r2.get_root(0)
    stats = r2.recover()
    assert stats["large_blocks"] == 1
    assert [r2.read_word(big2 + k) for k in range(16)] == \
        [4242 + k for k in range(16)]
    # fresh allocations never land inside the live span
    sb = r2.heap.sb_of(big2)
    span = range(r2.heap.sb_word(sb), r2.heap.sb_word(sb) + 4 * layout.SB_WORDS)
    fresh = [r2.malloc(14336) for _ in range(64)]
    assert all(p is None or p not in span for p in fresh)
    r2.close()
    os.unlink(path)


def test_clean_restart_no_gc():
    path = tempfile.mktemp()
    r = Ralloc(path, 8 * MB, sim_nvm=True, seed=51)
    head = _durable_stack(r, 10)
    r.set_root(0, head, "stack_node")
    r.close()
    r2 = Ralloc(path, 8 * MB, sim_nvm=True, seed=52)
    assert not r2.dirty_restart        # clean shutdown detected
    assert len(_walk_stack(r2, r2.get_root(0))) == 10
    p = r2.malloc(64)
    assert p is not None
    r2.close()
    os.unlink(path)
