"""Crash-during-recovery idempotence fuzz.

Recovery itself writes to the durable image (prune, sweep, lease
re-trim, final drain).  A machine that crashes *mid-recovery* reboots
into a second recovery over the partially-rewritten image — so recovery
must be idempotent: recovering the crash-interrupted image must land on
exactly the same semantic heap as recovering the pristine image once.

Mechanism: a ``CrashAfter(k)`` tracer raises ``SimulatedCrash`` on the
k+1-th memory event inside ``recover()``; in sim-NVM mode the backing
array then holds precisely the durable bytes (the write-back cache is
lost).  We re-open that image and recover fully, then compare semantic
state — per-superblock class records, the free *set* and its runs (list
order is rebuild-order, not part of the contract), the range-lease
snapshot, the index records, and the raw root table — against a
reference recovery of the pristine image.
"""

import random

import pytest

from repro.analysis.persist_lint import check_allocator
from repro.analysis.trace import CrashAfter, SimulatedCrash, attach_tracer
from repro.core import layout, recovery
from repro.core.layout import D_BLOCK_SIZE, D_SIZE_CLASS, SB_SIZE
from repro.core.prefix_index import PrefixIndex, hash_tokens
from repro.core.ralloc import Ralloc

HEAP_BYTES = 4 * (1 << 20)
SEED = 77


def _build_image(torn: bool = False):
    """A heap whose recovery exercises every write-phase: a published
    span whose owner vanished un-released (forces a *real* re-trim), a
    plain rooted span, a freed span (free run), and small record blocks.
    Returns the pristine durable image."""
    r = Ralloc(None, HEAP_BYTES, sim_nvm=True, seed=SEED, expand_sbs=1)
    idx = PrefixIndex(r)
    a = r.malloc(3 * SB_SIZE - 256)
    r.write_word(a, 0xAAAA)
    r.set_root(0, a)
    rec = idx.publish(hash_tokens([1, 2, 3]), a, n_pages=1, lease_sbs=1)
    assert rec is not None
    b = r.malloc(2 * SB_SIZE - 256)
    r.write_word(b, 0xBBBB)
    r.set_root(1, b)
    c = r.malloc(SB_SIZE)
    r.set_root(2, c)
    r.set_root(2, None)
    r.free(c)
    # the owner of `a` exits without releasing: after the crash the index
    # record is the span's only durable reference, so recovery must
    # re-trim its 3-sb extent down to the record's 1-sb lease.
    r.set_root(0, None)
    r.mem.drain()
    r.fence()
    img = r.mem.nvm.copy()
    if torn:
        # tear a sealed word of the (single) record: prune must unlink it
        img[rec + 4] ^= 0x4000
    return img


def _semantic_state(r, idx):
    m = r.mem
    used = int(m.read(layout.M_USED_SBS))
    descs = {sb: (int(m.read(r.desc(sb, D_SIZE_CLASS))),
                  int(m.read(r.desc(sb, D_BLOCK_SIZE))))
             for sb in range(used)}
    return {
        "used": used,
        "descs": descs,
        "free": sorted(recovery.free_superblock_list(r)),
        "runs": sorted(recovery.free_superblock_runs(r)),
        "leases": {sb: segs for sb, segs in r.leases.snapshot().items()
                   if segs},
        "records": sorted((c.ptr, c.key, c.span, c.n_pages, c.lease_sbs)
                          for c in idx.records()),
        "roots": tuple(int(m.read(layout.M_ROOTS + i))
                       for i in range(layout.MAX_ROOTS)),
    }


def _recover_fully(img, *, seed_shift=0):
    r = Ralloc(None, HEAP_BYTES, sim_nvm=True, seed=SEED + 1 + seed_shift,
               backing=img.copy(), expand_sbs=1)
    idx = PrefixIndex(r)
    tr = attach_tracer(r)
    stats = r.recover()
    rep = check_allocator(r, tr)
    assert rep.ok, f"persist-order violation during recovery:\n{rep}"
    return r, idx, stats, len(tr.events)


def _crash_then_recover(img, budget):
    """Crash recovery after `budget` events; return the re-recovered
    heap's semantic state, or None if the budget outlived recovery."""
    work = img.copy()
    r = Ralloc(None, HEAP_BYTES, sim_nvm=True, seed=SEED + 2,
               backing=work, expand_sbs=1)
    PrefixIndex(r)
    attach_tracer(r, CrashAfter(budget))
    try:
        r.recover()
        return None                       # recovery finished under budget
    except SimulatedCrash:
        pass
    # `work` now holds exactly what was durable at the crash point
    r2, idx2, _, _ = _recover_fully(work, seed_shift=2)
    return _semantic_state(r2, idx2)


def _budget_sweep(n_events, extra_random=6):
    ks = {1, 2, 3, n_events - 2, n_events - 1}
    ks.update(n_events * i // 12 for i in range(1, 12))
    rng = random.Random(SEED)
    ks.update(rng.randrange(1, n_events) for _ in range(extra_random))
    return sorted(k for k in ks if 1 <= k < n_events)


def test_recovery_scenario_is_potent():
    """Guard the fixture: the reference recovery must actually re-trim a
    span and rebuild leases/free runs, else the sweep proves nothing."""
    img = _build_image()
    r, idx, stats, n_events = _recover_fully(img)
    assert stats["index_retrims"] == 1, stats
    assert stats["index_pruned"] == 0, stats
    ref = _semantic_state(r, idx)
    assert ref["records"] and ref["free"] and ref["leases"]
    assert n_events > 50
    # recovery is a fixed point: running it again changes nothing
    r.recover()
    assert _semantic_state(r, idx) == ref


@pytest.mark.parametrize("torn", [False, True],
                         ids=["clean-image", "torn-record-image"])
def test_crash_mid_recovery_is_idempotent(torn):
    img = _build_image(torn=torn)
    r_ref, idx_ref, stats, n_events = _recover_fully(img)
    assert stats["index_pruned"] == (1 if torn else 0), stats
    ref = _semantic_state(r_ref, idx_ref)

    budgets = _budget_sweep(n_events) if not torn \
        else _budget_sweep(n_events, extra_random=3)[::2]
    assert len(budgets) >= 8
    interrupted = 0
    for k in budgets:
        state = _crash_then_recover(img, k)
        if state is None:
            continue
        interrupted += 1
        assert state == ref, f"divergence after crash at event {k}"
    # the sweep must have produced real mid-recovery crashes, including
    # deep ones (after the mark pass, inside sweep/retrim writes)
    assert interrupted >= len(budgets) - 2, (interrupted, len(budgets))


def test_crash_during_recovery_of_crash_image():
    """Double fault: crash mid-operation, crash again mid-recovery, then
    recover — still identical to recovering the first crash image."""
    r = Ralloc(None, HEAP_BYTES, sim_nvm=True, seed=SEED, expand_sbs=1)
    idx = PrefixIndex(r)
    a = r.malloc(2 * SB_SIZE - 256)
    r.set_root(0, a)
    idx.publish(hash_tokens([9]), a, n_pages=1, lease_sbs=1)
    b = r.malloc(SB_SIZE)
    r.set_root(1, b)
    r.mem.crash()                          # power loss mid-epoch
    img = r.mem.nvm.copy()

    r_ref, idx_ref, _, n_events = _recover_fully(img)
    ref = _semantic_state(r_ref, idx_ref)
    for k in (3, n_events // 3, 2 * n_events // 3, n_events - 1):
        state = _crash_then_recover(img, k)
        if state is not None:
            assert state == ref, f"divergence after nested crash at {k}"
