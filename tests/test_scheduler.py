"""Continuous-batching scheduler, group-commit publish, and the engine
admission/publish regression fixes that ride along with it.

Engine-level regressions (satellites):
  * admission past capacity raises the typed ``EngineBusy`` (was a bare
    ``IndexError`` out of ``free_lanes.pop()``);
  * a failed span reservation backs the admission out completely — the
    lane returns to the pool neutralized, not with the failed request's
    decode state still written into it;
  * mid-page publishes: the page path rejects them (the old guard was
    dead code), the span path clamps the boundary token to the
    *published* prefix instead of the publisher's current token;
  * record blocks allocate at dedicated ranks past the lane range, so
    they can never collide with lane 0's slot in the rank-indexed cache;
  * two records naming the same span at different prefix lengths
    recover to exactly the pre-crash lease vector.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro import obs
from repro.configs import get_smoke_config
from repro.core import jax_alloc as ja
from repro.core.prefix_index import hash_tokens
from repro.models import transformer as T
from repro.runtime import make_host_mesh
from repro.serving.engine import EngineBusy, ServingEngine
from repro.serving.scheduler import Scheduler


@pytest.fixture(scope="module")
def mesh():
    return make_host_mesh()


def _engine(mesh, **kw):
    cfg = dataclasses.replace(get_smoke_config("qwen2_5_32b"),
                              page_size=kw.pop("page_size", 8))
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return ServingEngine(cfg, mesh, params, **kw)


def _prompt(seed, n, vocab=512):
    rng = np.random.default_rng(seed)
    return [int(t) for t in rng.integers(1, vocab, size=n)]


# ---------------------------------------------------------------------------
# admission (satellite: EngineBusy + failed-reservation backout)
# ---------------------------------------------------------------------------
def test_add_request_raises_engine_busy(mesh):
    eng = _engine(mesh, lanes=2, max_seq=64)
    eng.add_request([1, 2, 3])
    eng.add_request([4, 5])
    with pytest.raises(EngineBusy):
        eng.add_request([6, 7, 8])          # was: bare IndexError
    # the failed admission left nothing behind
    assert len(eng.sessions) == 2 and not eng.free_lanes


def test_failed_span_reservation_neutralizes_lane(mesh):
    import jax.numpy as jnp
    eng = _engine(mesh, lanes=2, max_seq=64, pages_per_sb=4)
    # hog most of the arena so the decode-ahead reservation cannot fit
    eng.astate, hog = eng._alloc_large(state=eng.astate, nwords=jnp.int32(24))
    assert int(hog) >= 0
    prompt = _prompt(0, 40)                 # 5 pages > 4 per sb → span path
    with pytest.raises(MemoryError):
        eng.add_request(prompt)
    # the lane is back in the pool EXACTLY once, with no session and
    # neutral decode state — indistinguishable from never-admitted (the
    # old path returned it with this request's pos/block-table/cur-token
    # still written into it)
    assert sorted(eng.free_lanes) == [0, 1]
    assert eng.sessions == {} and eng.large_spans == {}
    for lane in range(2):
        assert int(np.asarray(eng.dstate["pos"][lane])) == 0
        assert np.asarray(eng.dstate["block_table"][lane]).max() < 0
        assert int(eng.cur_tokens[lane]) == 0
    # once the arena frees, the same request admits cleanly
    eng.astate = eng._free_large(state=eng.astate, off=jnp.int32(int(hog)),
                                 n_sbs=jnp.int32(-1))
    lane = eng.add_request(prompt)
    assert lane in eng.large_spans and lane in eng.sessions
    eng.finish(lane)


def test_scheduler_wait_queue_is_bounded(mesh):
    eng = _engine(mesh, lanes=2, max_seq=64)
    sched = Scheduler(eng, max_waiting=1)
    sched.submit([1, 2, 3])                 # lane
    sched.submit([4, 5, 6])                 # lane
    sched.submit([7, 8, 9])                 # wait queue
    assert len(sched.waiting) == 1
    with pytest.raises(EngineBusy):
        sched.submit([1, 1, 1])             # queue full → shed load


# ---------------------------------------------------------------------------
# continuous batching
# ---------------------------------------------------------------------------
def test_scheduler_interleaves_arrivals_and_finishes(mesh):
    eng = _engine(mesh, lanes=2, max_seq=64)
    sched = Scheduler(eng, max_waiting=8)
    prompts = [_prompt(s, 3 + s % 2, vocab=64) for s in range(5)]
    rids = [sched.submit(p, max_new_tokens=3) for p in prompts]
    assert len(sched.active) == 2 and len(sched.waiting) == 3
    results = sched.drain()
    # every request ran to its token budget on a recycled lane — the
    # waiting ones were admitted as earlier requests finished, without
    # draining the whole batch in between
    assert sorted(results) == sorted(rids)
    for rid, p in zip(rids, prompts):
        assert results[rid][:len(p)] == p
        assert len(results[rid]) == len(p) + 3
    assert not sched.active and not sched.waiting
    assert eng.sessions == {} and sorted(eng.free_lanes) == [0, 1]
    assert ja.live_blocks(eng.astate, eng.acfg)[0] == 0


def test_scheduler_group_commit_publish_flow(mesh):
    """Scheduler-driven serving with publish-on-finish: the first
    publisher's record dedups later identical publishes, queued arrivals
    hit the shared prefix, and the publish queue flushes on the
    scheduler's cadence."""
    eng = _engine(mesh, lanes=3, max_seq=64, pages_per_sb=2)
    sched = Scheduler(eng, max_waiting=8, publish_every=4)
    prompt = _prompt(3, 24)                 # 3 pages > 2 per sb → span path
    rids = [sched.submit(prompt, share_prefix=True, max_new_tokens=8,
                         publish=True) for _ in range(4)]
    results = sched.drain()
    assert sorted(results) == sorted(rids)
    for rid in rids:
        assert results[rid][:24] == prompt
        assert len(results[rid]) == 32
    # one durable record: identical re-publishes dedup on the cache key
    assert len(eng.prefix_store.walk()) == 1
    assert eng.pending_publishes == 0
    # the published prefix survives a crash — scheduler traffic produced
    # a durable, recoverable index
    stats = eng.crash_and_recover()
    assert stats["index_records"] == 1


# ---------------------------------------------------------------------------
# group-commit publish: queue → one batched append → one root swing
# ---------------------------------------------------------------------------
def test_queue_publish_batches_behind_one_flush(mesh):
    eng = _engine(mesh, lanes=3, max_seq=64, pages_per_sb=2)
    # publish-queue observability rides the same scenario: counters
    # reset by name (typos raise), then asserted against the flow below
    obs.reset("engine.publish_queued", "engine.publish_flushes",
              "engine.publish_batch_size", "engine.publish_queue_depth")
    p1, p2 = _prompt(4, 24), _prompt(5, 24)
    a = eng.add_request(p1, share_prefix=True)
    c = eng.add_request(p2, share_prefix=True)
    for _ in range(24):
        eng.step()
    assert eng.queue_publish(a) and eng.queue_publish(c)
    # nothing durable yet: both appends are parked in the queue …
    assert eng.pending_publishes == 2
    assert eng.prefix_store.walk() == []
    # … and the metrics see exactly that: two queued, depth 2, no flush
    snap = obs.snapshot()
    assert snap["counters"]["engine.publish_queued"] == 2
    assert snap["counters"]["engine.publish_flushes"] == 0
    assert snap["gauges"]["engine.publish_queue_depth"] == 2
    # … but the transient half is live — a sharer hits BEFORE the flush
    b = eng.add_request(p1, share_prefix=True)
    assert b in eng.shared_spans
    assert int(np.asarray(eng.dstate["pos"][b])) == 24
    # one flush lands both records as one chain segment
    assert eng.flush_publishes() == 2
    assert eng.pending_publishes == 0
    snap = obs.snapshot()
    assert snap["counters"]["engine.publish_flushes"] == 1
    assert snap["gauges"]["engine.publish_queue_depth"] == 0
    batch = snap["histograms"]["engine.publish_batch_size"]
    assert batch["count"] == 1 and batch["max"] == 2
    recs = eng.prefix_store.walk()
    assert {r.key for r in recs} == {hash_tokens(p1), hash_tokens(p2)}
    assert len({r.off for r in recs}) == 2
    first_bucket = next(b for b, h in enumerate(eng.prefix_store.heads)
                        if h >= 0)
    assert eng.prefix_store.heads[first_bucket] == recs[0].off
    stats = eng.crash_and_recover()
    assert stats["index_records"] == 2


def test_unflushed_publishes_die_with_a_crash(mesh):
    eng = _engine(mesh, lanes=3, max_seq=64, pages_per_sb=2)
    prompt = _prompt(6, 24)
    a = eng.add_request(prompt, share_prefix=True)
    off, n_span = eng.large_spans[a]
    head_sb = off // eng.acfg.sb_words
    ext = ja.span_sbs(eng.acfg, n_span)
    for _ in range(24):
        eng.step()
    assert eng.queue_publish(a)
    stats = eng.crash_and_recover()         # crash BEFORE any flush
    # the un-flushed group commit never became durable: no record, no
    # queue, and the cache's transient lease vanished — only the owner's
    # reconstructed full-extent lease remains
    assert stats["index_records"] == 0
    assert eng.pending_publishes == 0 and eng.prefix_store.walk() == []
    refs = np.asarray(eng.astate.span_refs)
    assert refs[head_sb:head_sb + ext].tolist() == [1] * ext
    # the prompt is a cache miss again — the sharer re-reserves
    b = eng.add_request(prompt, share_prefix=True)
    assert b in eng.large_spans and b not in eng.shared_spans


# ---------------------------------------------------------------------------
# satellite: record blocks never collide with lane pages
# ---------------------------------------------------------------------------
def test_record_blocks_disjoint_from_lane_zero_pages(mesh):
    """Record allocation uses dedicated ranks past the lane range — the
    old path requested rank 0 (lane 0's slot in the rank-indexed block
    cache).  Interleave lane-0 lazy decode allocation with batched
    publishes and check no offset is ever handed out twice."""
    eng = _engine(mesh, lanes=3, max_seq=64, pages_per_sb=2)
    p1, p2 = _prompt(7, 24), _prompt(8, 24)
    b = eng.add_request(p1, share_prefix=True)      # lane 2 (span)
    c = eng.add_request(p2, share_prefix=True)      # lane 1 (span)
    a = eng.add_request([5, 9, 3])                  # lane 0: lazy pages
    assert a == 0
    for _ in range(24):
        eng.step()
    assert eng.queue_publish(b) and eng.queue_publish(c)
    assert eng.flush_publishes() == 2
    for _ in range(8):
        eng.step()                          # lane 0 keeps allocating pages
    assert eng.queue_publish(b)             # longer prefix → new key
    assert eng.queue_publish(c)
    assert eng.flush_publishes() == 2
    rec_offs = [r.off for r in eng.prefix_store.walk()]
    assert len(rec_offs) == 4
    lane0 = np.asarray(eng.dstate["block_table"][a])
    lane0 = lane0[lane0 >= 0].tolist()
    assert lane0                            # lane 0 really allocated pages
    span_pages = [off + i for off, n in eng.large_spans.values()
                  for i in range(n)]
    everything = rec_offs + lane0 + span_pages
    assert len(everything) == len(set(everything)), \
        "an arena offset was handed out twice"


# ---------------------------------------------------------------------------
# satellite: mid-page publish semantics
# ---------------------------------------------------------------------------
def test_page_path_rejects_mid_page_publish(mesh):
    """The old alignment guard was dead (``pos < full*page`` can't hold)
    and a mid-page publish shipped the publisher's *current* token as
    the boundary token — sharers would decode garbage.  The page path
    now shares only page-aligned positions."""
    eng = _engine(mesh, lanes=4, max_seq=64, page_size=4)
    prompt = _prompt(9, 10, vocab=64)       # 2 full pages + 2 stragglers
    a = eng.add_request(prompt)
    for _ in range(len(prompt)):
        eng.step()
    assert int(np.asarray(eng.dstate["pos"][a])) == 10   # mid-page
    assert eng.queue_publish(a) is False
    assert eng._prefix_cache == {} and eng.page_refs == {}
    b = eng.add_request(prompt, share_prefix=True)
    assert int(np.asarray(eng.dstate["pos"][b])) == 0    # miss — no entry
    for lane in (a, b):
        eng.finish(lane)


def test_span_path_mid_page_publish_uses_boundary_token(mesh):
    """The span path already clamps a mid-page publish to whole pages —
    but it stored the lane's *current* token as the continuation, not
    the token at the published boundary."""
    eng = _engine(mesh, lanes=3, max_seq=64, pages_per_sb=2)
    prompt = _prompt(10, 24)
    a = eng.add_request(prompt, share_prefix=True)
    for _ in range(20):
        eng.step()
    assert int(np.asarray(eng.dstate["pos"][a])) == 20   # mid page 3
    assert eng.queue_publish(a)
    key = hash_tokens(prompt[:16])          # clamped to 2 whole pages
    entry = eng._prefix_cache[key]
    assert entry[3] == 2 and entry[4] == 16
    assert entry[6] == prompt[16]           # boundary token, NOT tokens[20]
    assert eng._prefix_tokens[key] == tuple(prompt[:16])
    # a sharer of the 16-token prefix resumes exactly at the boundary
    b = eng.add_request(prompt[:16], share_prefix=True)
    assert int(np.asarray(eng.dstate["pos"][b])) == 16
    assert eng.sessions[b].tokens == prompt[:16] + [prompt[16]]
    eng.flush_publishes()


# ---------------------------------------------------------------------------
# satellite: two records naming one span, crash-exact lease recovery
# ---------------------------------------------------------------------------
def test_double_record_same_span_lease_vector_survives_crash(mesh):
    eng = _engine(mesh, lanes=3, max_seq=64, pages_per_sb=2)
    prompt = _prompt(11, 32)                # 4 pages > 2 per sb → span
    a = eng.add_request(prompt, share_prefix=True)
    off, n_span = eng.large_spans[a]
    head_sb = off // eng.acfg.sb_words
    ext = ja.span_sbs(eng.acfg, n_span)
    for _ in range(16):
        eng.step()
    assert eng.queue_publish(a)             # record 1: 16 tokens, 1 sb lease
    for _ in range(16):
        eng.step()
    assert eng.queue_publish(a)             # record 2: 32 tokens, 2 sb lease
    assert eng.flush_publishes() == 2
    recs = eng.prefix_store.walk()
    assert [r.span for r in recs] == [off, off]          # same span, twice
    assert sorted(r.lease_sbs for r in recs) == [1, 2]   # different extents
    # a sharer leases the SHORT prefix — three different lease lengths
    # now cover one span (owner full-extent, record leases, sharer)
    b = eng.add_request(prompt[:16], share_prefix=True)
    assert eng.shared_spans[b] == (off, 2, 1)
    refs_before = np.asarray(eng.astate.span_refs).copy()

    stats = eng.crash_and_recover()
    assert stats["index_records"] == 2
    # acceptance: every reconstructed full-extent lease re-trims to its
    # recorded length — the vector equals the pre-crash one exactly
    assert np.asarray(eng.astate.span_refs).tolist() == \
        refs_before.tolist(), "post-recovery lease vector drifted"
    # both prefixes stay hittable without re-prefill
    c = eng.add_request(prompt[:16], share_prefix=True)
    assert c in eng.shared_spans
    assert int(np.asarray(eng.dstate["pos"][c])) == 16

    for lane in (a, b, c):
        eng.finish(lane)
    eng.drop_prefix_cache()                 # unlinks BOTH records
    assert eng.prefix_store.walk() == []
    assert ja.live_blocks(eng.astate, eng.acfg)["large"] == 0
    assert int(np.asarray(eng.astate.span_refs).sum()) == 0
    assert refs_before[head_sb] >= 4 and ext == 4        # scenario sanity
