"""Decode-vs-oracle parity and the paged serving engine."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import transformer as T
from repro.runtime import make_host_mesh
from repro.serving import decode as dec
from repro.serving.engine import ServingEngine


@pytest.fixture(scope="module")
def mesh():
    return make_host_mesh()


def _parity(cfg, mesh, S=24, tol=2e-2):
    key = jax.random.PRNGKey(1)
    params = T.init_params(cfg, key)
    B = 2
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    logits_full, _ = T.forward(cfg, params, {"tokens": toks})
    pshape = jax.eval_shape(lambda: params)
    step, _, _ = dec.make_decode_step(cfg, mesh, pshape, return_logits=True)
    ds = dec.make_dstate(cfg, batch=B, max_seq=64, dp_shards=1)
    Pn = ds["block_table"].shape[1]
    ds["block_table"] = jnp.asarray(
        np.arange(B * Pn, dtype=np.int32).reshape(B, Pn))
    errs = []
    for t in range(S):
        ds, tok, lg = step(params, ds, toks[:, t])
        errs.append(float(jnp.abs(lg - logits_full[:, t]).max()))
    rel = max(errs) / (float(jnp.abs(logits_full).max()) + 1e-9)
    assert rel < tol, rel


@pytest.mark.parametrize("arch,fp32", [
    ("qwen2_5_32b", False),            # GQA + bias + rope
    ("granite_20b", False),            # MQA kv=1
    ("mamba2_370m", False),            # recurrent state decode
    ("recurrentgemma_9b", True),       # hybrid (bf16 assoc-scan noise)
    ("granite_moe_3b_a800m", True),    # MoE (top-k routing is discrete)
    ("moonshot_v1_16b_a3b", True),
])
def test_decode_matches_oracle(arch, fp32, mesh):
    cfg = get_smoke_config(arch)
    if fp32:
        cfg = dataclasses.replace(cfg, dtype=jnp.float32,
                                  capacity_factor=100.0)
    # bf16 tolerance: recurrent-state archs accumulate rounding over the
    # whole sequence and the exact noise floor shifts between XLA releases
    # (observed 2.3e-2 for mamba2 on jax 0.4.37)
    _parity(cfg, mesh, tol=1e-3 if fp32 else 3e-2)


def test_engine_generate_evict_recover(mesh):
    cfg = dataclasses.replace(get_smoke_config("qwen2_5_32b"), page_size=8)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, mesh, params, lanes=4, max_seq=64)
    l0 = eng.add_request([5, 9, 3])
    l1 = eng.add_request([7, 7])
    for _ in range(16):
        eng.step()
    assert len(eng.sessions[l0].tokens) > 10
    # crash: all transient allocator metadata lost; GC rebuilds it
    stats = eng.crash_and_recover()
    assert stats["live_before"] == stats["live_after"] == stats["marked"]
    before = list(eng.sessions[l0].tokens)
    for _ in range(5):
        eng.step()
    assert eng.sessions[l0].tokens[:len(before)] == before
    assert len(eng.sessions[l0].tokens) == len(before) + 5
    # eviction frees pages; lane is reusable
    eng.finish(l0)
    l2 = eng.add_request([1, 2, 3])
    for _ in range(6):
        eng.step()
    assert len(eng.sessions[l2].tokens) > 3


def test_engine_page_accounting(mesh):
    from repro.core import jax_alloc as ja
    cfg = dataclasses.replace(get_smoke_config("starcoder2_3b"), page_size=8)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, mesh, params, lanes=2, max_seq=48)
    l0 = eng.add_request([3, 1, 4])
    for _ in range(20):
        eng.step()
    live = ja.live_blocks(eng.astate, eng.acfg)[0]
    pos = int(np.asarray(eng.dstate["pos"][l0]))
    expected = -(-pos // cfg.page_size)
    assert live == expected, (live, expected)
    eng.finish(l0)
    assert ja.live_blocks(eng.astate, eng.acfg)[0] == 0


def test_engine_oversized_prompt_span(mesh):
    """A prompt whose page table exceeds one superblock reserves one
    contiguous large-object span, survives crash recovery mid-prompt,
    and returns every superblock on eviction."""
    from repro.core import jax_alloc as ja
    cfg = dataclasses.replace(get_smoke_config("qwen2_5_32b"), page_size=8)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, mesh, params, lanes=2, max_seq=256,
                        pages_per_sb=16)
    rng = np.random.default_rng(0)
    prompt = [int(t) for t in rng.integers(1, cfg.vocab_size, size=200)]
    lane = eng.add_request(prompt)         # 25 pages > 16 per superblock
    assert lane in eng.large_spans
    off, n_span = eng.large_spans[lane]
    assert n_span == 32                    # decode-ahead: max_seq pages
    lb = ja.live_blocks(eng.astate, eng.acfg)
    assert lb["large"] == 1 and lb[0] == 0
    bt = np.asarray(eng.dstate["block_table"][lane])
    assert bt[:32].tolist() == list(range(off, off + 32))

    # a short request coexists: its lazily-allocated pages never overlap
    other = eng.add_request([5, 9, 3])
    for _ in range(20):
        eng.step()
    pages_other = np.asarray(eng.dstate["block_table"][other])
    pages_other = pages_other[pages_other >= 0]
    assert not set(pages_other.tolist()) & set(range(off, off + 32))

    # crash mid-prompt: the span survives the vectorized mark–sweep
    before = list(eng.sessions[lane].tokens)
    eng.crash_and_recover()
    assert ja.live_blocks(eng.astate, eng.acfg)["large"] == 1
    for _ in range(5):
        eng.step()
    assert eng.sessions[lane].tokens[:len(before)] == before

    # eviction frees the whole span; the superblocks are reusable
    eng.finish(lane)
    eng.finish(other)
    lb = ja.live_blocks(eng.astate, eng.acfg)
    assert lb["large"] == 0 and lb[0] == 0
    assert lane not in eng.large_spans


def test_engine_decode_ahead_no_mid_decode_alloc(mesh):
    """Decode-ahead reservation: a span-reserved sequence is sized to
    max_seq up front, so decoding past the prompt never allocates a page
    mid-decode (no lazy page, no span migration)."""
    from repro.core import jax_alloc as ja
    cfg = dataclasses.replace(get_smoke_config("qwen2_5_32b"), page_size=8)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, mesh, params, lanes=1, max_seq=64,
                        pages_per_sb=4)
    rng = np.random.default_rng(1)
    prompt = [int(t) for t in rng.integers(1, cfg.vocab_size, size=40)]
    lane = eng.add_request(prompt)         # 5 prompt pages > 4 per sb
    off, n_span = eng.large_spans[lane]
    assert n_span == 64 // 8               # max_seq pages, not the prompt's 5
    bt = np.asarray(eng.dstate["block_table"][lane])
    assert bt[:n_span].tolist() == list(range(off, off + n_span))
    for _ in range(45):                    # cross the prompt→decode boundary
        eng.step()
    assert int(np.asarray(eng.dstate["pos"][lane])) > len(prompt)
    # every page the decode touched was pre-backed by the span: the
    # per-page allocator never ran
    assert ja.live_blocks(eng.astate, eng.acfg)[0] == 0
    eng.finish(lane)
    lb = ja.live_blocks(eng.astate, eng.acfg)
    assert lb["large"] == 0 and lb[0] == 0


def test_engine_all_lanes_fit_decode_ahead_spans(mesh):
    """Arena sizing regression: every lane can hold a decode-ahead span
    at once — the superblock rounding of spans must be provisioned per
    lane, not absorbed by per-page slack."""
    from repro.core import jax_alloc as ja
    cfg = dataclasses.replace(get_smoke_config("qwen2_5_32b"), page_size=8)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, mesh, params, lanes=3, max_seq=128,
                        pages_per_sb=4)
    rng = np.random.default_rng(2)
    lanes = []
    for _ in range(3):                     # 5 prompt pages > 4 per sb each
        prompt = [int(t) for t in rng.integers(1, cfg.vocab_size, size=40)]
        lanes.append(eng.add_request(prompt))
    assert all(l in eng.large_spans for l in lanes)
    assert ja.live_blocks(eng.astate, eng.acfg)["large"] == 3
    spans = sorted(eng.large_spans[l] for l in lanes)
    for (a, na), (b, _) in zip(spans, spans[1:]):
        assert a + na <= b                 # reserved spans are disjoint
    for l in lanes:
        eng.finish(l)
    assert ja.live_blocks(eng.astate, eng.acfg)["large"] == 0


def test_engine_span_prefix_sharing(mesh):
    """Cross-lane prefix span sharing: a published oversized-prompt span
    is *acquired* by later matching requests (one refcount each — no page
    copy, no fresh reservation), survives crash recovery with its
    refcount GC-reconstructed from the lanes' roots, and frees only when
    the last holder exits."""
    from repro.core import jax_alloc as ja
    cfg = dataclasses.replace(get_smoke_config("qwen2_5_32b"), page_size=8)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, mesh, params, lanes=3, max_seq=64,
                        pages_per_sb=4)
    rng = np.random.default_rng(3)
    prompt = [int(t) for t in rng.integers(1, cfg.vocab_size, size=40)]

    a = eng.add_request(prompt, share_prefix=True)   # miss → reserves a span
    assert a in eng.large_spans
    off, n_span = eng.large_spans[a]
    head_sb = off // eng.acfg.sb_words
    for _ in range(len(prompt)):
        eng.step()
    eng.publish_prefix(a)
    # owner reference + the prefix cache's reference
    assert int(eng.astate.span_refs[head_sb]) == 2
    # re-publishing the same prefix must not stack cache references:
    # the entry holds exactly one
    eng.publish_prefix(a)
    assert int(eng.astate.span_refs[head_sb]) == 2

    b = eng.add_request(prompt, share_prefix=True)   # hit → acquire, no copy
    assert b in eng.shared_spans and b not in eng.large_spans
    assert int(eng.astate.span_refs[head_sb]) == 3
    assert ja.live_blocks(eng.astate, eng.acfg)["large"] == 1  # ONE span
    assert int(np.asarray(eng.dstate["pos"][b])) == len(prompt)
    full = len(prompt) // cfg.page_size
    bt_b = np.asarray(eng.dstate["block_table"][b])
    assert bt_b[:full].tolist() == list(range(off, off + full))

    # both lanes decode past the prefix; the sharer's fresh pages come
    # from the per-page allocator, never from inside the span
    for _ in range(10):
        eng.step()
    own_b = np.asarray(eng.dstate["block_table"][b])
    own_b = own_b[own_b >= 0][full:]
    assert own_b.size and not (set(own_b.tolist())
                               & set(range(off, off + n_span)))

    # crash: transient refcounts are lost; GC reconstructs them from the
    # two lanes' roots PLUS the durable index record — the cache's lease
    # now survives the crash (tentpole: crash-surviving cache keys)
    stats = eng.crash_and_recover()
    assert stats["index_records"] == 1
    assert int(eng.astate.span_refs[head_sb]) == 3
    assert ja.live_blocks(eng.astate, eng.acfg)["large"] == 1
    # recounted per-page refs never cover span-backed pages — a stale
    # entry would pin the offset after the span frees and is reallocated
    assert not (set(eng.page_refs)
                & set(range(off, off + n_span)))
    tokens_b = list(eng.sessions[b].tokens)
    for _ in range(3):
        eng.step()
    assert eng.sessions[b].tokens[:len(tokens_b)] == tokens_b

    # the record already re-published the entry: publishing again is a
    # no-op (the cache holds exactly one reference per entry)
    eng.publish_prefix(b)
    assert int(eng.astate.span_refs[head_sb]) == 3
    eng.drop_prefix_cache()              # cache lease + index record out
    assert int(eng.astate.span_refs[head_sb]) == 2
    # a *sharer* can publish anew after the drop: the entry takes one
    # span reference via the span path (never the per-page path — that
    # would refcount span-interior pages)
    eng.publish_prefix(b)
    assert int(eng.astate.span_refs[head_sb]) == 3
    assert not (set(eng.page_refs) & set(range(off, off + n_span)))
    eng.drop_prefix_cache()                          # cache ref released
    assert int(eng.astate.span_refs[head_sb]) == 2

    eng.finish(a)                                    # sharer keeps the span
    assert int(eng.astate.span_refs[head_sb]) == 1
    assert ja.live_blocks(eng.astate, eng.acfg)["large"] == 1
    bt_b = np.asarray(eng.dstate["block_table"][b])
    assert bt_b[:full].tolist() == list(range(off, off + full))
    eng.finish(b)                                    # last holder → freed
    assert ja.live_blocks(eng.astate, eng.acfg)["large"] == 0
    assert int(eng.astate.span_refs[head_sb]) == 0
    lb = ja.live_blocks(eng.astate, eng.acfg)
    assert lb[0] == 0                                # lazy pages freed too


def test_engine_owner_exit_frees_decode_ahead_tail(mesh):
    """Tentpole at the engine level: publish/acquire hold only *prefix*
    leases, so when the reserving lane finishes short, the decode-ahead
    tail of its span frees immediately — reusable by the next
    reservation — while the shared prefix stays placed for the sharer."""
    from repro.core import jax_alloc as ja
    cfg = dataclasses.replace(get_smoke_config("qwen2_5_32b"), page_size=8)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, mesh, params, lanes=3, max_seq=64,
                        pages_per_sb=2)
    rng = np.random.default_rng(5)
    prompt = [int(t) for t in rng.integers(1, cfg.vocab_size, size=24)]

    a = eng.add_request(prompt, share_prefix=True)
    off, n_span = eng.large_spans[a]
    head_sb = off // eng.acfg.sb_words
    ext = ja.span_sbs(eng.acfg, n_span)
    for _ in range(len(prompt)):
        eng.step()
    eng.publish_prefix(a)
    full = len(prompt) // cfg.page_size
    lease_sbs = -(-full // eng.acfg.sb_words)
    assert lease_sbs < ext                 # there IS a decode-ahead tail
    # prefix leases: head range carries owner+cache, the tail only the owner
    refs = np.asarray(eng.astate.span_refs)
    assert refs[head_sb] == 2
    assert refs[head_sb + ext - 1] == 1

    b = eng.add_request(prompt, share_prefix=True)   # prefix lease, no copy
    assert eng.shared_spans[b] == (off, full, lease_sbs)
    free_before = int(np.asarray(
        eng.astate.sb_class == ja.FREE_CLS)[:int(eng.astate.used_sbs)].sum())

    eng.finish(a)                          # owner exits: tail must free NOW
    cls = np.asarray(eng.astate.sb_class)
    tail = list(range(head_sb + lease_sbs, head_sb + ext))
    assert all(cls[s] == ja.FREE_CLS for s in tail), \
        "decode-ahead tail still pinned after the owner's release"
    assert cls[head_sb] == ja.LARGE_CLS    # shared prefix stays placed
    assert int(ja.span_sbs(eng.acfg, int(
        eng.astate.sb_block_words[head_sb]))) == lease_sbs
    free_after = int(np.asarray(
        eng.astate.sb_class == ja.FREE_CLS)[:int(eng.astate.used_sbs)].sum())
    assert free_after - free_before >= ext - lease_sbs
    # the sharer still decodes correctly off the shared prefix
    for _ in range(5):
        eng.step()
    bt_b = np.asarray(eng.dstate["block_table"][b])
    assert bt_b[:full].tolist() == list(range(off, off + full))
    # last holders out: cache, then the sharer — everything frees
    eng.drop_prefix_cache()
    eng.finish(b)
    assert ja.live_blocks(eng.astate, eng.acfg)["large"] == 0
    assert int(np.asarray(eng.astate.span_refs).sum()) == 0


def test_engine_prefix_index_survives_crash(mesh):
    """Tentpole acceptance: a published prefix survives
    ``crash_and_recover`` through the durable index — cache-hittable
    without re-prefill — and the recovered lease vector equals the
    pre-crash *trimmed* one: the record's and each live sharer's leases
    re-trim to their page-derived superblock counts instead of the
    conservative full extent."""
    from repro.core import jax_alloc as ja
    cfg = dataclasses.replace(get_smoke_config("qwen2_5_32b"), page_size=8)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, mesh, params, lanes=4, max_seq=64,
                        pages_per_sb=2)
    rng = np.random.default_rng(11)
    prompt = [int(t) for t in rng.integers(1, cfg.vocab_size, size=24)]

    a = eng.add_request(prompt, share_prefix=True)   # miss → reserves a span
    off, n_span = eng.large_spans[a]
    head_sb = off // eng.acfg.sb_words
    ext = ja.span_sbs(eng.acfg, n_span)
    for _ in range(len(prompt)):
        eng.step()
    eng.publish_prefix(a)                            # cache lease + record
    full = len(prompt) // cfg.page_size
    lease_sbs = -(-full // eng.acfg.sb_words)
    assert lease_sbs < ext                 # there IS a decode-ahead tail
    b = eng.add_request(prompt, share_prefix=True)   # sharer: prefix lease
    c = eng.add_request(prompt)                      # control (own span)
    for _ in range(len(prompt) + 4):       # control decodes past its prompt
        eng.step()
    refs_before = np.asarray(eng.astate.span_refs).copy()
    assert refs_before[head_sb] == 3       # owner + cache + sharer
    assert refs_before[head_sb + ext - 1] == 1       # tail: owner only

    stats = eng.crash_and_recover()
    assert stats["index_records"] == 1
    # acceptance: lease vector == pre-crash trimmed extents, NOT the
    # conservative full-extent reconstruction (which would be 3s across)
    assert np.asarray(eng.astate.span_refs).tolist() == \
        refs_before.tolist(), "post-recovery lease vector drifted"

    # acceptance: the published prefix is cache-hittable without
    # re-prefill — no fresh reservation, the request starts at the
    # prompt boundary on the recovered span
    spans_live = ja.live_blocks(eng.astate, eng.acfg)["large"]
    d = eng.add_request(prompt, share_prefix=True)
    assert d in eng.shared_spans and d not in eng.large_spans
    assert int(np.asarray(eng.dstate["pos"][d])) == len(prompt)
    assert ja.live_blocks(eng.astate, eng.acfg)["large"] == spans_live
    bt_d = np.asarray(eng.dstate["block_table"][d])
    assert bt_d[:full].tolist() == list(range(off, off + full))
    # …and decodes correctly off the recovered prefix (parity vs the
    # control lane, which prefilled the same prompt itself)
    for _ in range(4):
        eng.step()
    assert eng.sessions[d].tokens[len(prompt):] == \
        eng.sessions[c].tokens[len(prompt):len(eng.sessions[d].tokens)]

    # owner exit durably trims the tail; a second crash recovers the
    # trimmed extent as-is (record re-trim is a no-op at equal extents)
    eng.finish(a)
    refs_trimmed = np.asarray(eng.astate.span_refs).copy()
    assert refs_trimmed[head_sb] == 3      # cache + b + d
    eng.crash_and_recover()
    assert np.asarray(eng.astate.span_refs).tolist() == \
        refs_trimmed.tolist()
    assert int(ja.span_sbs(eng.acfg, int(
        eng.astate.sb_block_words[head_sb]))) == lease_sbs

    for lane in (b, c, d):
        eng.finish(lane)
    eng.drop_prefix_cache()                # last lease + record out
    assert ja.live_blocks(eng.astate, eng.acfg)["large"] == 0
    assert int(np.asarray(eng.astate.span_refs).sum()) == 0
    assert ja.live_blocks(eng.astate, eng.acfg)[0] == 0
    assert eng.prefix_store.walk() == []


def test_engine_finished_lane_offset_poisoned(mesh):
    """Satellite regression (stale-offset hazard): once a lane finishes,
    its span records are poisoned — a span reallocated at the same
    offset can never be released through the dead lane, and a double
    ``finish`` raises instead of silently freeing someone else's span."""
    from repro.core import jax_alloc as ja
    cfg = dataclasses.replace(get_smoke_config("qwen2_5_32b"), page_size=8)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, mesh, params, lanes=2, max_seq=64,
                        pages_per_sb=4)
    rng = np.random.default_rng(7)
    prompt = [int(t) for t in rng.integers(1, cfg.vocab_size, size=40)]

    a = eng.add_request(prompt, share_prefix=True)
    off_a, n_a = eng.large_spans[a]
    for _ in range(len(prompt)):
        eng.step()
    eng.publish_prefix(a)                            # cache: prefix lease
    eng.finish(a)                                    # owner's lease drops
    # the dead lane's span records are gone the moment it finishes …
    assert a not in eng.large_spans and a not in eng.shared_spans
    refs_before = np.asarray(eng.astate.span_refs).copy()
    with pytest.raises(KeyError):
        eng.finish(a)                                # … and a second finish
    # raises without releasing anything through the dead lane
    assert np.array_equal(np.asarray(eng.astate.span_refs), refs_before)

    eng.drop_prefix_cache()                          # last lease → span dies
    assert ja.live_blocks(eng.astate, eng.acfg)["large"] == 0
    b = eng.add_request(prompt)                      # best-fit: same offset
    off_b, n_b = eng.large_spans[b]
    assert off_b == off_a                            # the hazard setup
    head_sb = off_b // eng.acfg.sb_words
    # no transient record of the dead lane pins or can free the offset:
    # per-page refs never cover span pages, and the fresh span is owned
    # solely by b's new lease
    assert not (set(eng.page_refs) & set(range(off_b, off_b + n_b)))
    ext = ja.span_sbs(eng.acfg, n_b)
    assert np.asarray(eng.astate.span_refs)[
        head_sb:head_sb + ext].tolist() == [1] * ext
    # recovery recounts from live roots only — still nothing stale
    eng.crash_and_recover()
    assert not (set(eng.page_refs) & set(range(off_b, off_b + n_b)))
    assert int(eng.astate.sb_class[head_sb]) == ja.LARGE_CLS
    eng.finish(b)
    assert ja.live_blocks(eng.astate, eng.acfg)["large"] == 0


def test_prefix_hit_requires_exact_tokens(mesh):
    """Hash-keyed cache regression: a 48-bit key collision must never
    serve another prompt's KV — hits on entries published this process
    verify exact token equality (recovered entries, whose tokens died
    with the crash, match by hash alone — the documented residual)."""
    import dataclasses as dc
    from repro.core.prefix_index import hash_tokens
    cfg = dc.replace(get_smoke_config("qwen2_5_32b"), page_size=4)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, mesh, params, lanes=3, max_seq=64)
    prompt = [5, 9, 3, 7, 2, 8, 1, 4]
    a = eng.add_request(prompt)
    for _ in range(len(prompt)):
        eng.step()
    eng.publish_prefix(a)
    # forge a collision: alias the published entry under another
    # prompt's hash, exactly what equal 48-bit FNV digests would do
    other = [6, 6, 6, 6, 6, 6, 6, 6]
    eng._prefix_cache[hash_tokens(other)] = \
        eng._prefix_cache[hash_tokens(prompt)]
    eng._prefix_tokens[hash_tokens(other)] = tuple(prompt)
    b = eng.add_request(other, share_prefix=True)
    assert int(np.asarray(eng.dstate["pos"][b])) == 0   # miss, no KV reuse
    # the genuine prompt still hits
    c = eng.add_request(prompt, share_prefix=True)
    assert int(np.asarray(eng.dstate["pos"][c])) == len(prompt)
    for lane in (a, b, c):
        eng.finish(lane)
    del eng._prefix_cache[hash_tokens(other)]           # drop the forgery
    del eng._prefix_tokens[hash_tokens(other)]
    eng.drop_prefix_cache()


def test_prefix_sharing_refcounts(mesh):
    """RadixAttention-style prompt sharing over the paged allocator:
    shared pages are referenced by several block tables and return to the
    free pool only when the last reference drops — the paper's block-
    disjointness discipline extended with refcounts."""
    import dataclasses as dc
    from repro.core import jax_alloc as ja
    cfg = dc.replace(get_smoke_config("qwen2_5_32b"), page_size=4)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, mesh, params, lanes=4, max_seq=64)
    prompt = [5, 9, 3, 7, 2, 8, 1, 4]              # exactly 2 pages

    a = eng.add_request(prompt)
    for _ in range(len(prompt)):
        eng.step()
    eng.publish_prefix(a)
    pages_a = np.asarray(eng.dstate["block_table"][a])
    shared = set(pages_a[:2].tolist())

    # control: same prompt, no sharing
    c = eng.add_request(prompt)
    for _ in range(len(prompt)):
        eng.step()

    # shared-prefix request starts at pos = len(prompt) re-using pages
    b = eng.add_request(prompt, share_prefix=True)
    assert int(np.asarray(eng.dstate["pos"][b])) == len(prompt)
    pages_b = np.asarray(eng.dstate["block_table"][b])
    assert set(pages_b[:2].tolist()) == shared
    # both continue generating; teacher-forced outputs agree with control
    for _ in range(6):
        eng.step()
    assert eng.sessions[b].tokens[len(prompt):] == \
        eng.sessions[c].tokens[len(prompt):len(eng.sessions[b].tokens)]

    live0 = ja.live_blocks(eng.astate, eng.acfg)[0]
    eng.finish(a)                                   # shared pages survive
    assert set(np.asarray(eng.dstate["block_table"][b])[:2].tolist()) \
        == shared
    eng.finish(b)                                   # cache still holds them
    eng.finish(c)
    live1 = ja.live_blocks(eng.astate, eng.acfg)[0]
    assert live1 == 2                               # only the cached prefix
    eng.drop_prefix_cache()
    assert ja.live_blocks(eng.astate, eng.acfg)[0] == 0
