"""Span registry + free-run index unit tests (core.spans).

The registry's contract: refcounts live only in transient memory, free
of a shared span decrements, the last release frees, and recovery
rebuilds every count by counting root-reachable references to the span
head during the existing GC trace — nothing new is persisted.  The
index's contract: an exact mirror of free-stack membership whose
best-fit answer (smallest run >= request, leftmost on ties) matches the
drain-and-sort search it replaced.
"""

import random

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # container without dev deps
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import layout, pptr as pp, recovery
from repro.core.layout import SB_SIZE, contiguous_runs
from repro.core.ralloc import Ralloc
from repro.core.spans import FreeRunIndex, SpanRegistry

MB = 1 << 20


# ------------------------------------------------------------- SpanRegistry
def test_acquire_release_free_semantics():
    r = Ralloc(None, 8 * MB)
    ptr = r.malloc(2 * SB_SIZE - 256)
    sb = r.heap.sb_of(ptr)
    assert r.span_refcount(ptr) == 1
    assert r.span_acquire(ptr) == 2
    wm = int(r.mem.read(layout.M_USED_SBS))
    r.free(ptr)                                   # shared → decrement only
    assert r.span_refcount(ptr) == 1
    assert int(r.mem.read(layout.M_USED_SBS)) == wm
    assert recovery.free_superblock_runs(r) == []   # span still placed
    r.span_release(ptr)                           # last holder → real free
    assert recovery.free_superblock_runs(r) == [(sb, 2)]
    with pytest.raises(ValueError):
        r.free(ptr)                               # double free still raises


def test_acquire_rejects_dead_and_interior_pointers():
    r = Ralloc(None, 8 * MB)
    ptr = r.malloc(2 * SB_SIZE - 256)
    with pytest.raises(ValueError):
        r.span_acquire(ptr + layout.SB_WORDS)     # continuation, not head
    small = r.malloc(64)
    with pytest.raises(ValueError):
        r.span_acquire(small)                     # not a span at all
    r.free(ptr)
    with pytest.raises(ValueError):
        r.span_acquire(ptr)                       # dead span


def test_shared_span_superblocks_never_rehanded():
    """While any holder remains, placement must treat the span's
    superblocks as occupied — a fresh span may never land inside it."""
    r = Ralloc(None, 8 * MB)
    ptr = r.malloc(3 * SB_SIZE - 256)
    sb = r.heap.sb_of(ptr)
    r.span_acquire(ptr)
    r.free(ptr)                                   # refs 2 → 1
    for _ in range(4):
        q = r.malloc(2 * SB_SIZE - 256)
        qsb = r.heap.sb_of(q)
        assert not (sb <= qsb < sb + 3) and not (sb <= qsb + 1 < sb + 3)
        r.free(q)


def test_recovery_counts_block_references_and_roots():
    """Reconstruction counts *references*, wherever the trace finds them:
    a pptr stored inside a reachable block counts exactly like a root."""
    r = Ralloc(None, 8 * MB, sim_nvm=True)
    span = r.malloc(2 * SB_SIZE - 256)
    holder = r.malloc(64)                         # small block holding a pptr
    r.write_word(holder, pp.encode(holder, span))
    r.flush_range(holder, 1)
    r.fence()
    r.set_root(0, holder)                         # conservative-traced holder
    r.set_root(1, span)                           # plus one direct root
    r.mem.drain(); r.fence()
    img = r.mem.nvm.copy()

    r2 = Ralloc(None, 8 * MB, sim_nvm=True, seed=9, backing=img)
    stats = r2.recover()
    sb = r2.heap.sb_of(span)
    assert r2.spans.count(sb) == 2                # root + in-block reference
    assert stats["shared_spans"] == 1
    def span_free(rr):
        return any(s <= sb < s + ln
                   for s, ln in recovery.free_superblock_runs(rr))

    r2.free(span)                                 # one holder down…
    assert not span_free(r2)                      # …span still placed
    r2.free(span)                                 # …last holder frees
    assert span_free(r2)


def test_registry_defaults_preserve_unregistered_spans():
    reg = SpanRegistry()
    assert reg.count(7) == 1                      # unknown span = one owner
    assert reg.release(7) == 0                    # a single free frees it
    reg.reconstruct({3: 2, 5: 0})
    assert reg.count(3) == 2
    assert reg.count(5) == 1                      # floor: live ⇒ >= 1 ref


# ------------------------------------------------------------- FreeRunIndex
def _reference_best_fit(members, nsb):
    fits = [(ln, s) for s, ln in contiguous_runs(sorted(members))
            if ln >= nsb]
    return min(fits)[1] if fits else None


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 2), st.integers(0, 63)),
                min_size=1, max_size=120))
def test_index_mirrors_membership_and_best_fit(ops):
    """Random add/discard/claim against a naive membership model: runs
    and best-fit answers must match the drain-and-sort reference."""
    idx, members = FreeRunIndex(), set()
    for kind, sb in ops:
        if kind == 0:
            idx.add(sb)
            members.add(sb)
        elif kind == 1:
            idx.discard(sb)
            members.discard(sb)
        else:                                     # claim a best-fit run
            nsb = sb % 4 + 1
            want = _reference_best_fit(members, nsb)
            got = idx.best_fit(nsb)
            assert got == want
            if got is not None:
                idx.claim(got, nsb)
                members -= set(range(got, got + nsb))
        assert idx.runs() == contiguous_runs(sorted(members))
        assert len(idx) == len(members)
        assert all((sb in idx) == (sb in members) for sb in range(64))


def test_host_index_stays_in_sync_with_free_list():
    """White-box: after arbitrary span + small churn the index equals the
    Treiber free-list membership exactly (the lock-step precondition)."""
    r = Ralloc(None, 16 * MB)
    rng = random.Random(4)
    held = []
    for i in range(120):
        if held and rng.random() < 0.45:
            r.free(held.pop(rng.randrange(len(held))))
        else:
            k = rng.randint(1, 3)
            p = r.malloc(k * SB_SIZE - 256)
            assert p is not None
            held.append(p)
        if rng.random() < 0.3:
            s = r.malloc(4096)
            r.free(s)
        assert r._run_index.runs() == recovery.free_superblock_runs(r)
