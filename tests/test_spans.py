"""Range-lease table + free-run index unit tests (core.spans).

The lease table's contract: per-superblock-range lease counts live only
in transient memory; a release decrements a range; an unleased tail
suffix returns to the free set while the shared prefix stays placed; the
head range's last release frees whatever remains; and recovery rebuilds
every count by counting root-reachable references to the span head
during the existing GC trace (each one a full-extent lease) — nothing
new is persisted.  The index's contract: an exact mirror of free-stack
membership whose best-fit answer (smallest run >= request, leftmost on
ties) matches the drain-and-sort search it replaced.
"""

import random

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # container without dev deps
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import layout, pptr as pp, recovery
from repro.core.layout import SB_SIZE, contiguous_runs
from repro.core.ralloc import Ralloc
from repro.core.spans import FreeRunIndex, LeaseUnderflow, RangeLeaseTable

MB = 1 << 20


# ---------------------------------------------------------- RangeLeaseTable
def test_acquire_release_free_semantics():
    r = Ralloc(None, 8 * MB)
    ptr = r.malloc(2 * SB_SIZE - 256)
    sb = r.heap.sb_of(ptr)
    assert r.span_refcount(ptr) == 1
    assert r.span_acquire(ptr) == 2
    wm = int(r.mem.read(layout.M_USED_SBS))
    r.free(ptr)                                   # shared → decrement only
    assert r.span_refcount(ptr) == 1
    assert int(r.mem.read(layout.M_USED_SBS)) == wm
    assert recovery.free_superblock_runs(r) == []   # span still placed
    r.span_release(ptr)                           # last holder → real free
    assert recovery.free_superblock_runs(r) == [(sb, 2)]
    with pytest.raises(ValueError):
        r.free(ptr)                               # double free still raises


def test_prefix_lease_frees_unleased_tail():
    """Tentpole behavior: a follower leasing only the prefix leaves the
    owner's decode-ahead tail unleased — the owner's release returns
    exactly the tail to the free set while the prefix stays placed, and
    the follower's release frees the rest."""
    r = Ralloc(None, 8 * MB)
    ptr = r.malloc(4 * SB_SIZE - 256)
    sb = r.heap.sb_of(ptr)
    assert r.span_acquire(ptr, n_sbs=2) == 2      # prefix lease
    assert r.span_lease_counts(ptr) == [2, 2, 1, 1]
    r.free(ptr)                                   # owner's full release
    # the tail [sb+2, sb+4) was only the owner's — it freed; the prefix
    # (and its durable size record) survives
    assert recovery.free_superblock_runs(r) == [(sb + 2, 2)]
    assert r.span_lease_counts(ptr) == [1, 1]
    bs = int(r.mem.read(r.desc(sb, layout.D_BLOCK_SIZE)))
    assert -(-bs // SB_SIZE) == 2                 # extent durably shrunk
    # the freed tail is genuinely reusable
    q = r.malloc(2 * SB_SIZE - 256)
    assert r.heap.sb_of(q) == sb + 2
    r.free(q)
    r.span_release(ptr, n_sbs=2)                  # follower leaves → frees
    assert recovery.free_superblock_runs(r) == [(sb, 4)]


def test_span_trim_returns_tail_to_free_set():
    """``span_trim`` shrinks the owner's lease in place: the tail frees
    (and is reused) while the kept prefix stays live and strict."""
    r = Ralloc(None, 8 * MB)
    ptr = r.malloc(4 * SB_SIZE - 256)
    sb = r.heap.sb_of(ptr)
    assert r.span_trim(ptr, 3) == 3
    assert recovery.free_superblock_runs(r) == [(sb + 3, 1)]
    assert r.span_trim(ptr, 1) == 1               # trim again, further
    assert recovery.free_superblock_runs(r) == [(sb + 1, 3)]
    assert r.span_trim(ptr, 5) == 1               # >= extent: no-op
    with pytest.raises(ValueError):
        r.span_trim(ptr, 0)                       # head is free's job
    r.free(ptr)
    assert recovery.free_superblock_runs(r) == [(sb, 4)]
    with pytest.raises(ValueError):
        r.span_trim(ptr, 1)                       # dead span raises


def test_trim_respects_other_holders_leases():
    """A trim can only free what nobody else leases: with a 3-sb prefix
    lease outstanding, trimming the owner to 1 sb keeps 3 sbs placed."""
    r = Ralloc(None, 8 * MB)
    ptr = r.malloc(4 * SB_SIZE - 256)
    sb = r.heap.sb_of(ptr)
    r.span_acquire(ptr, n_sbs=3)
    assert r.span_trim(ptr, 1) == 3               # follower pins 3 sbs
    assert recovery.free_superblock_runs(r) == [(sb + 3, 1)]
    assert r.span_lease_counts(ptr) == [2, 1, 1]
    r.span_release(ptr, n_sbs=3)                  # follower leaves
    assert recovery.free_superblock_runs(r) == [(sb + 1, 3)]
    r.free(ptr)
    assert recovery.free_superblock_runs(r) == [(sb, 4)]


def test_repeat_trim_passes_held_length():
    """Regression: a second trim while another holder pins the extent
    must pass the caller's current held length — it releases only the
    caller's own [n_keep, n_held) range, never the other holder's tail
    lease (which previously got silently consumed and freed)."""
    r = Ralloc(None, 8 * MB)
    ptr = r.malloc(4 * SB_SIZE - 256)
    sb = r.heap.sb_of(ptr)
    r.span_acquire(ptr)                           # follower: full extent
    assert r.span_trim(ptr, 3) == 4               # owner 4 → 3; span pinned
    assert r.span_lease_counts(ptr) == [2, 2, 2, 1]
    assert r.span_trim(ptr, 1, n_held=3) == 4     # owner 3 → 1
    assert r.span_lease_counts(ptr) == [2, 1, 1, 1]
    assert recovery.free_superblock_runs(r) == []  # follower pins it all
    assert r.span_trim(ptr, 1, n_held=1) == 4     # no-op: nothing held past 1
    r.free(ptr)                                   # follower's full release
    assert r.span_lease_counts(ptr) == [1]        # owner's 1-sb lease left
    assert recovery.free_superblock_runs(r) == [(sb + 1, 3)]
    r.span_release(ptr, n_sbs=1)
    assert recovery.free_superblock_runs(r) == [(sb, 4)]


def test_concurrent_shared_releases_no_double_free():
    """Regression (release race): concurrent releases of one shared span
    must serialize the extent-read → decrement → free decision — a stale
    extent would double-push tail superblocks onto the free list."""
    import threading
    r = Ralloc(None, 16 * MB)
    for trial in range(8):
        ptr = r.malloc(4 * SB_SIZE - 256)
        sb = r.heap.sb_of(ptr)
        leases = [4 if i % 2 == 0 else 1 + (i % 4) for i in range(8)]
        for n in leases:
            r.span_acquire(ptr, n_sbs=n)
        errs = []

        def rel(n):
            try:
                r.span_release(ptr, n_sbs=n)
            except Exception as e:          # pragma: no cover
                errs.append(repr(e))

        ts = [threading.Thread(target=rel, args=(n,))
              for n in leases + [4]]       # holders + the owner
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        assert not errs, errs
        free = recovery.free_superblock_list(r)
        assert len(free) == len(set(free)), "double-pushed superblock"
        assert any(s <= sb < s + ln
                   for s, ln in recovery.free_superblock_runs(r))
        assert r._run_index.runs() == recovery.free_superblock_runs(r)


def test_release_of_unleased_range_raises():
    """Host strictness: releasing a range nobody leases raises (the
    device mirrors this as a masked no-op)."""
    r = Ralloc(None, 8 * MB)
    ptr = r.malloc(3 * SB_SIZE - 256)
    r.span_acquire(ptr, n_sbs=1)
    r.free(ptr)                                   # owner out; tail freed
    assert r.span_lease_counts(ptr) == [1]
    with pytest.raises(ValueError):
        r.span_release(ptr, n_sbs=0)              # empty range
    r.span_release(ptr, n_sbs=3)                  # clamped to extent → frees
    with pytest.raises(ValueError):
        r.span_release(ptr, n_sbs=1)              # dead span raises


def test_acquire_rejects_dead_and_interior_pointers():
    r = Ralloc(None, 8 * MB)
    ptr = r.malloc(2 * SB_SIZE - 256)
    with pytest.raises(ValueError):
        r.span_acquire(ptr + layout.SB_WORDS)     # continuation, not head
    with pytest.raises(ValueError):
        r.span_acquire(ptr, n_sbs=0)              # empty lease
    small = r.malloc(64)
    with pytest.raises(ValueError):
        r.span_acquire(small)                     # not a span at all
    r.free(ptr)
    with pytest.raises(ValueError):
        r.span_acquire(ptr)                       # dead span


def test_shared_span_superblocks_never_rehanded():
    """While any holder remains, placement must treat the leased prefix
    as occupied — a fresh span may never land inside it."""
    r = Ralloc(None, 8 * MB)
    ptr = r.malloc(3 * SB_SIZE - 256)
    sb = r.heap.sb_of(ptr)
    r.span_acquire(ptr)
    r.free(ptr)                                   # full lease remains
    for _ in range(4):
        q = r.malloc(2 * SB_SIZE - 256)
        qsb = r.heap.sb_of(q)
        assert not (sb <= qsb < sb + 3) and not (sb <= qsb + 1 < sb + 3)
        r.free(q)


def test_recovery_counts_block_references_and_roots():
    """Reconstruction counts *references*, wherever the trace finds them:
    a pptr stored inside a reachable block counts exactly like a root,
    and each becomes a full-extent lease."""
    r = Ralloc(None, 8 * MB, sim_nvm=True)
    span = r.malloc(2 * SB_SIZE - 256)
    holder = r.malloc(64)                         # small block holding a pptr
    r.write_word(holder, pp.encode(holder, span))
    r.flush_range(holder, 1)
    r.fence()
    r.set_root(0, holder)                         # conservative-traced holder
    r.set_root(1, span)                           # plus one direct root
    r.mem.drain(); r.fence()
    img = r.mem.nvm.copy()

    r2 = Ralloc(None, 8 * MB, sim_nvm=True, seed=9, backing=img)
    stats = r2.recover()
    sb = r2.heap.sb_of(span)
    assert r2.leases.counts(sb) == [2, 2]         # root + in-block reference
    assert stats["shared_spans"] == 1
    def span_free(rr):
        return any(s <= sb < s + ln
                   for s, ln in recovery.free_superblock_runs(rr))

    r2.free(span)                                 # one holder down…
    assert not span_free(r2)                      # …span still placed
    r2.free(span)                                 # …last holder frees
    assert span_free(r2)


def test_table_defaults_preserve_unregistered_spans():
    tab = RangeLeaseTable()
    assert tab.count(7) == 1                      # unknown span = one owner
    tab.ensure(7, 2)                              # as Ralloc.free would
    assert tab.release(7, 7, 9) == (0, 0)         # a single free frees it
    tab.reconstruct({3: (2, 2), 5: (1, 0)})
    assert tab.counts(3) == [2, 2]
    assert tab.count(5) == 1                      # floor: live ⇒ >= 1 lease


def test_table_interval_merge_split():
    """White-box: prefix leases split intervals, equal-count neighbours
    re-merge, zero suffixes truncate, head zero drops the span."""
    tab = RangeLeaseTable()
    tab.register(10, 4)
    assert tab.intervals(10) == [(10, 14, 1)]
    tab.acquire(10, 2)
    assert tab.intervals(10) == [(10, 12, 2), (12, 14, 1)]
    tab.acquire(10, 4)                            # full: counts equalize…
    tab.release(10, 12, 14)                       # …then the tail releases
    assert tab.intervals(10) == [(10, 12, 3), (12, 14, 1)]
    # a full-range release zeroes the count-1 tail → suffix truncates
    assert tab.release(10, 10, 14) == (2, 2)
    assert tab.intervals(10) == [(10, 12, 2)]
    with pytest.raises(LeaseUnderflow):
        tab.release(10, 12, 14)                   # nothing there any more
    assert tab.release(10, 10, 12) == (1, 2)
    assert tab.release(10, 10, 12) == (0, 0)      # head zero → span gone
    assert tab.extent(10) is None


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 6),
       st.lists(st.tuples(st.integers(0, 2), st.integers(1, 6)),
                min_size=1, max_size=40))
def test_table_matches_naive_count_model(ext, ops):
    """Property: the interval table behaves exactly like a naive per-sb
    count vector under random prefix acquires / range releases."""
    tab = RangeLeaseTable()
    tab.register(0, ext)
    model = [1] * ext
    for kind, k in ops:
        if not model:
            break
        cur = len(model)
        if kind == 0:                             # prefix acquire
            n = min(k, cur)
            for i in range(n):
                model[i] += 1
            tab.acquire(0, n)
        else:                                     # range release [a, b)
            a = (k - 1) % cur
            b = min(a + kind, cur)
            if a >= b or any(model[i] < 1 for i in range(a, b)):
                with pytest.raises(LeaseUnderflow):
                    tab.release(0, a, b)
                continue
            for i in range(a, b):
                model[i] -= 1
            if model[0] == 0:
                model = []                        # head zero → span freed
            else:
                while model and model[-1] == 0:
                    model.pop()                   # zero suffix truncates
            head, new_ext = tab.release(0, a, b)
            assert new_ext == len(model)
            assert head == (model[0] if model else 0)
        assert tab.counts(0) == model
        # intervals are coalesced: no adjacent equal counts
        iv = tab.intervals(0)
        assert all(x[2] != y[2] for x, y in zip(iv, iv[1:]))


# ------------------------------------------------------------- FreeRunIndex
def _reference_best_fit(members, nsb):
    fits = [(ln, s) for s, ln in contiguous_runs(sorted(members))
            if ln >= nsb]
    return min(fits)[1] if fits else None


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 2), st.integers(0, 63)),
                min_size=1, max_size=120))
def test_index_mirrors_membership_and_best_fit(ops):
    """Random add/discard/claim against a naive membership model: runs
    and best-fit answers must match the drain-and-sort reference."""
    idx, members = FreeRunIndex(), set()
    for kind, sb in ops:
        if kind == 0:
            idx.add(sb)
            members.add(sb)
        elif kind == 1:
            idx.discard(sb)
            members.discard(sb)
        else:                                     # claim a best-fit run
            nsb = sb % 4 + 1
            want = _reference_best_fit(members, nsb)
            got = idx.best_fit(nsb)
            assert got == want
            if got is not None:
                idx.claim(got, nsb)
                members -= set(range(got, got + nsb))
        assert idx.runs() == contiguous_runs(sorted(members))
        assert len(idx) == len(members)
        assert all((sb in idx) == (sb in members) for sb in range(64))


def test_host_index_stays_in_sync_with_free_list():
    """White-box: after arbitrary span + small churn the index equals the
    Treiber free-list membership exactly (the lock-step precondition)."""
    r = Ralloc(None, 16 * MB)
    rng = random.Random(4)
    held = []
    for i in range(120):
        if held and rng.random() < 0.45:
            r.free(held.pop(rng.randrange(len(held))))
        else:
            k = rng.randint(1, 3)
            p = r.malloc(k * SB_SIZE - 256)
            assert p is not None
            held.append(p)
        if rng.random() < 0.3:
            s = r.malloc(4096)
            r.free(s)
        assert r._run_index.runs() == recovery.free_superblock_runs(r)
