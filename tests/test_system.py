"""End-to-end behaviour: the paper's system working as one piece.

Train a tiny model with recoverable checkpointing, kill it mid-run,
restart, serve it with the paged engine, crash the engine's allocator
state, recover, and keep generating — the full Ralloc lifecycle.
"""

import dataclasses
import os
import tempfile

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_smoke_config
from repro.core.ralloc import Ralloc
from repro.data.pipeline import TokenStream
from repro.runtime import make_host_mesh
from repro.serving.engine import ServingEngine
from repro.train.loop import Trainer
from repro.train.optimizer import AdamWConfig


def test_train_crash_restart_then_serve():
    cfg = dataclasses.replace(get_smoke_config("starcoder2_3b"),
                              num_layers=2, vocab_size=64, page_size=8)
    path = tempfile.mktemp()
    heap = Ralloc(path, 256 << 20, sim_nvm=True, seed=7)
    cm = CheckpointManager(heap)
    stream = TokenStream(cfg.vocab_size, 2, 32, seed=3)

    tr = Trainer(cfg, AdamWConfig(warmup_steps=2), ckpt=cm, ckpt_every=4)
    tr.run(stream, steps=6, log_every=1000)
    heap.heap.crash()                      # full-system crash, no close()
    del tr, cm, heap

    heap2 = Ralloc(path, 256 << 20, sim_nvm=True, seed=8)
    assert heap2.dirty_restart
    cm2 = CheckpointManager(heap2)
    heap2.get_root(0, "ckpt_manifest")
    heap2.get_root(1, "ckpt_manifest")
    stats = heap2.recover()
    assert stats["reachable_blocks"] > 0
    tr2 = Trainer(cfg, AdamWConfig(warmup_steps=2), ckpt=cm2, ckpt_every=4)
    assert tr2.start_step == 4             # resumed from the committed root
    tr2.run(stream, steps=8, log_every=1000)

    mesh = make_host_mesh()
    eng = ServingEngine(cfg, mesh, tr2.params, lanes=2, max_seq=48)
    lane = eng.add_request([1, 2, 3])
    for _ in range(12):
        eng.step()
    assert len(eng.sessions[lane].tokens) > 6
    rec = eng.crash_and_recover()
    assert rec["live_before"] == rec["live_after"]
    before = list(eng.sessions[lane].tokens)
    for _ in range(4):
        eng.step()
    assert eng.sessions[lane].tokens[:len(before)] == before
    heap2.close()
    os.unlink(path)
