"""Engine-level prefix-trie serving: longest-prefix partial hits, durable
splits, device record seals, and crash recovery with zero re-prefill.

The acceptance bar (ISSUE PR 8): a request matching k pages of a longer
published prompt leases only those k pages' superblocks; a crash over a
populated trie re-publishes every surviving node and the post-recovery
lease vector equals the pre-crash trimmed one; a record with ONE torn
sidecar word is pruned (with its unservable descendants) instead of
re-leasing its span.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import jax_alloc as ja
from repro.core import jax_recovery as jr
from repro.models import transformer as T
from repro.runtime import make_host_mesh
from repro.serving.engine import ServingEngine
from repro.serving.prefix_store import F_KEY, F_SEAL, _SEALED


@pytest.fixture(scope="module")
def mesh():
    return make_host_mesh()


def _engine(mesh, lanes=3, pages_per_sb=2, max_seq=64):
    cfg = dataclasses.replace(get_smoke_config("qwen2_5_32b"), page_size=8)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, ServingEngine(cfg, mesh, params, lanes=lanes,
                              max_seq=max_seq, pages_per_sb=pages_per_sb)


def _publish_owner(cfg, eng, prompt):
    lane = eng.add_request(prompt, share_prefix=True)
    for _ in range(len(prompt)):
        eng.step()
    eng.publish_prefix(lane)
    return lane


def test_partial_hit_leases_only_matched_superblocks(mesh):
    cfg, eng = _engine(mesh)
    rng = np.random.default_rng(3)
    prompt = [int(t) for t in rng.integers(1, cfg.vocab_size, size=40)]
    a = _publish_owner(cfg, eng, prompt)
    off, n_span = eng.large_spans[a]
    full = len(prompt) // cfg.page_size                  # 5 pages
    assert len(eng.prefix_cache.nodes) == 1

    # B shares 2 of 5 pages: mid-edge match → durable split → B leases
    # ONLY ceil(2 pages / sb) superblocks, not the prefix's 3
    p2 = prompt[:16] + [int(t)
                        for t in rng.integers(1, cfg.vocab_size, size=20)]
    b = eng.add_request(p2, share_prefix=True)
    m_lease = -(-2 // eng.acfg.sb_words)
    assert eng.shared_spans[b] == (off, 2, m_lease)
    assert eng.lane_states.partial_hits[b] == 2
    assert b not in eng.large_spans                      # no reservation
    # the split is durable: M [0,2) + X' [2,5), both with records
    shapes = sorted((n.start_page, n.end_page, n.lease_sbs)
                    for n in eng.prefix_cache.nodes.values())
    full_lease = -(-full // eng.acfg.sb_words)
    assert shapes == [(0, 2, m_lease), (2, 5, full_lease)]
    assert all(n.rec_off >= 0 for n in eng.prefix_cache.nodes.values())
    assert len(eng.prefix_store.walk()) == 2
    # the matched pages serve from the span; pos starts past them
    bt_b = np.asarray(eng.dstate["block_table"][b])
    assert bt_b[:2].tolist() == [off, off + 1]
    assert int(np.asarray(eng.dstate["pos"][b])) == 2 * cfg.page_size

    # suffix replays teacher-forced on B's OWN lazily-allocated pages,
    # never inside the still-leased prefix superblocks
    for _ in range(len(p2) - 2 * cfg.page_size + 4):
        eng.step()
    assert eng.sessions[b].tokens[:len(p2)] == p2
    bt_b = np.asarray(eng.dstate["block_table"][b])
    own = bt_b[bt_b >= 0][2:]
    leased = full_lease * eng.acfg.sb_words
    assert own.size
    assert not set(own.tolist()) & set(range(off, off + leased))
    # per-request footprint: O(matched prefix) sbs leased, not O(prompt)
    assert m_lease < full_lease


def test_trie_publish_attaches_children_and_survives_crash(mesh):
    cfg, eng = _engine(mesh)
    rng = np.random.default_rng(5)
    prompt = [int(t) for t in rng.integers(1, cfg.vocab_size, size=40)]
    a = _publish_owner(cfg, eng, prompt)
    off, _ = eng.large_spans[a]
    full = len(prompt) // cfg.page_size

    # partial sharer forces the durable split M [0,2) + X' [2,5)
    p2 = prompt[:16] + [int(t)
                        for t in rng.integers(1, cfg.vocab_size, size=20)]
    b = eng.add_request(p2, share_prefix=True)
    # a NEW span owner extending A attaches as a child of X' at page 5
    pe = prompt + [int(t) for t in rng.integers(1, cfg.vocab_size, size=16)]
    e = eng.add_request(pe, share_prefix=False)
    off2, _ = eng.large_spans[e]
    for _ in range(len(pe)):
        eng.step()
    eng.publish_prefix(e)
    child = [n for n in eng.prefix_cache.nodes.values() if n.start_page == 5]
    assert len(child) == 1 and child[0].span == off2
    parent = eng.prefix_cache.nodes[child[0].parent]
    assert (parent.start_page, parent.end_page) == (2, 5)
    eng.finish(e)

    # ---- crash over the populated trie --------------------------------
    pre = np.asarray(eng.astate.span_refs).copy()
    stats = eng.crash_and_recover()
    assert stats["index_records"] == 3          # M, X', E-child
    assert stats["trie_pruned"] == 0
    # acceptance: post-recovery lease vector EQUALS the pre-crash one
    assert (np.asarray(eng.astate.span_refs) == pre).all()
    # the trie shape rebuilt token-less, parents linked
    shapes = sorted((n.start_page, n.end_page)
                    for n in eng.prefix_cache.nodes.values())
    assert shapes == [(0, 2), (2, 5), (5, 7)]

    # zero re-prefill: exact hit on the recovered deep node
    c = eng.add_request(prompt, share_prefix=True)
    assert c in eng.shared_spans and c not in eng.large_spans
    assert int(np.asarray(eng.dstate["pos"][c])) == full * cfg.page_size
    eng.finish(c)
    # partial hits clamp to recovered node boundaries (all-or-nothing:
    # token-less nodes have no page keys to split by)
    p3 = prompt[:16] + [int(t)
                        for t in rng.integers(1, cfg.vocab_size, size=24)]
    d = eng.add_request(p3, share_prefix=True)
    assert eng.shared_spans[d][1] == 2
    eng.finish(d)


def test_torn_sidecar_word_prunes_record_and_descendants(mesh):
    """Satellite: tear ONE sealed word of a mid node's device record —
    the seal mismatch must prune it (live_record_mask drops it) AND the
    coverage pass must drop its now-unservable descendants, while an
    independent root-range node survives untouched."""
    # max_seq 96 keeps owner lanes alive through both publish loops
    cfg, eng = _engine(mesh, max_seq=96)
    rng = np.random.default_rng(7)
    prompt = [int(t) for t in rng.integers(1, cfg.vocab_size, size=40)]
    a = _publish_owner(cfg, eng, prompt)
    off, _ = eng.large_spans[a]
    p2 = prompt[:16] + [int(t)
                        for t in rng.integers(1, cfg.vocab_size, size=20)]
    b = eng.add_request(p2, share_prefix=True)   # split: M [0,2) + X' [2,5)
    other = [int(t) for t in rng.integers(1, cfg.vocab_size, size=24)]
    o = _publish_owner(cfg, eng, other)          # independent [0,3) node

    xp = next(n for n in eng.prefix_cache.nodes.values()
              if (n.start_page, n.end_page) == (2, 5))
    eng.prefix_store.words[xp.rec_off][F_KEY] ^= 1       # tear one word
    assert not eng.prefix_store.seal_matches(xp.rec_off)

    stats = eng.crash_and_recover()
    # X' torn; nothing else covers boundary 2... M [0,2) still serves,
    # but no descendant of X' existed — pruned exactly 1
    assert stats["trie_pruned"] == 1
    assert stats["index_records"] == 2           # M + the independent node
    shapes = sorted((n.start_page, n.end_page)
                    for n in eng.prefix_cache.nodes.values())
    assert shapes == [(0, 2), (0, 3)]
    # the torn record's span survives only through its OTHER leases
    # (owner lane a + M's record + sharer b) — X''s phantom lease is
    # gone: the vector holds exactly what the remaining holders justify
    head_sb = off // eng.acfg.sb_words
    assert int(eng.astate.span_refs[head_sb]) == 3


def test_live_record_mask_seal_gate():
    """Unit: seal_ok gates live_record_mask independently of marks."""
    cfg = ja.ArenaConfig(num_sbs=4, sb_words=4, class_words=(1,),
                         cache_cap=8)
    marked = np.zeros(jr.num_slots(cfg), bool)
    marked[[1, 2]] = True
    offs = np.asarray([1, 2, -1], np.int32)
    live = np.asarray(jr.live_record_mask(cfg, marked, offs))
    assert live.tolist() == [True, True, False]
    live = np.asarray(jr.live_record_mask(
        cfg, marked, offs, seal_ok=np.asarray([True, False, True])))
    assert live.tolist() == [True, False, False]


def test_sealed_fields_cover_the_record_content():
    from repro.serving import prefix_store as ps
    # every content field is sealed; chain/shape fields are not
    assert set(_SEALED) == {ps.F_SPAN, ps.F_KEY, ps.F_PAGES,
                            ps.F_SPAN_PAGES, ps.F_TOK, ps.F_LEASE,
                            ps.F_START, ps.F_FPRINT}
    assert ps.F_NEXT not in _SEALED and ps.F_PARENT not in _SEALED
    assert F_SEAL not in _SEALED
