#!/usr/bin/env python
"""Render per-round metrics snapshots from a smoke artifact.

    python tools/dump_metrics.py out/smoke.json
    python tools/dump_metrics.py out/smoke.json --round sharedprompt_recover
    python tools/dump_metrics.py out/smoke.json --trace out/trace.json

Accepts either the smoke results file (rows carrying a ``metrics``
snapshot, what ``benchmarks.run --profile smoke --json`` writes) or its
``<stem>-metrics.json`` sibling (per-round snapshots + Chrome-trace span
events).  ``--trace`` merges every round's span events into ONE
Chrome-``traceEvents`` JSON loadable in chrome://tracing / Perfetto —
the sibling file is required for that (the results file has no events).
"""

from __future__ import annotations

import argparse
import json
import os
import sys


def _load_rounds(path: str) -> tuple[list[dict], bool]:
    """Normalize either artifact shape to ``[{workload, kind, snapshot,
    traceEvents?}]``; second element says whether events are present."""
    with open(path) as f:
        data = json.load(f)
    if "rounds" in data:                         # the -metrics sibling
        return data["rounds"], True
    rounds = [{"workload": r["workload"], "kind": r["kind"],
               "snapshot": r["metrics"]}
              for r in data.get("results", []) if r.get("metrics")]
    # the results file has no span events; offer the sibling if it exists
    stem, ext = os.path.splitext(path)
    sib = f"{stem}-metrics{ext or '.json'}"
    if os.path.exists(sib):
        return _load_rounds(sib)
    return rounds, False


def _fmt_val(v) -> str:
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)


def render_round(rnd: dict, *, nonzero_only: bool = True) -> str:
    snap = rnd["snapshot"]
    lines = [f"== {rnd['workload']} [{rnd['kind']}] =="]
    counters = {n: v for n, v in sorted(snap.get("counters", {}).items())
                if v or not nonzero_only}
    if counters:
        lines.append("  counters:")
        lines += [f"    {n:<36} {v}" for n, v in counters.items()]
    gauges = {n: v for n, v in sorted(snap.get("gauges", {}).items())
              if v or not nonzero_only}
    if gauges:
        lines.append("  gauges:")
        lines += [f"    {n:<36} {_fmt_val(v)}" for n, v in gauges.items()]
    hists = snap.get("histograms", {})
    if hists:
        lines.append("  histograms:")
        for n, h in sorted(hists.items()):
            lines.append(
                f"    {n:<36} n={h['count']} mean={_fmt_val(h['mean'])} "
                f"p50={_fmt_val(h['p50'])} p90={_fmt_val(h['p90'])} "
                f"p99={_fmt_val(h['p99'])} max={_fmt_val(h['max'])}")
    phases = snap.get("phases", {})
    if phases:
        lines.append("  phases:")
        for n, p in sorted(phases.items()):
            lines.append(
                f"    {n:<36} {p['seconds'] * 1e3:8.3f} ms  "
                f"items={p['items']} calls={p['calls']}")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="dump_metrics", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("path", help="smoke JSON (or its -metrics sibling)")
    ap.add_argument("--round", default=None, metavar="NAME",
                    help="only rounds whose workload contains NAME")
    ap.add_argument("--all", action="store_true",
                    help="include zero-valued counters/gauges")
    ap.add_argument("--trace", default=None, metavar="OUT",
                    help="write merged Chrome traceEvents JSON to OUT")
    args = ap.parse_args(argv)
    rounds, have_events = _load_rounds(args.path)
    if args.round:
        rounds = [r for r in rounds if args.round in r["workload"]]
    if not rounds:
        print("no rounds with metrics snapshots found", file=sys.stderr)
        return 1
    for rnd in rounds:
        print(render_round(rnd, nonzero_only=not args.all))
        print()
    if args.trace:
        if not have_events:
            print("no span events in this artifact (need the "
                  "<stem>-metrics.json sibling)", file=sys.stderr)
            return 1
        events = []
        for i, rnd in enumerate(rounds):
            for ev in rnd.get("traceEvents", []):
                # one pid per round so rounds stack as separate
                # process tracks in the viewer
                ev = dict(ev, pid=i)
                events.append(ev)
        with open(args.trace, "w") as f:
            json.dump({"traceEvents": events, "displayTimeUnit": "ms",
                       "otherData": {"rounds": [
                           f"{r['workload']}[{r['kind']}]"
                           for r in rounds]}}, f)
        print(f"# chrome trace ({len(events)} events) written to "
              f"{args.trace}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
