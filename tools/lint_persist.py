#!/usr/bin/env python
"""Repo-invariant static lint CLI (tier-1 CI gate).

    python tools/lint_persist.py [path ...]     # default: src/repro

Checks (see ``repro.analysis.static_checks``):
  NVM001  no direct .nvm[...] stores outside core/atomics.py
  SHD001  no jax.sharding.AxisType / shard_map outside src/repro/runtime/
  PER001  persistent-field writes flushed in-function or annotated
          `# persist: deferred`
  TRN001  transient free-run index arrays (run_len/run_start/
          run_bucket_min) never named in a flush-like call

Exits 0 iff no findings.
"""

from __future__ import annotations

import pathlib
import sys

_REPO = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(_REPO / "src"))

from repro.analysis.static_checks import check_tree  # noqa: E402


def main(argv=None) -> int:
    targets = (argv if argv is not None else sys.argv[1:]) or \
        [str(_REPO / "src" / "repro")]
    findings = []
    for t in targets:
        findings.extend(check_tree(t))
    for f in findings:
        print(f)
    if findings:
        print(f"lint-persist: {len(findings)} finding(s)", file=sys.stderr)
        return 1
    print("lint-persist: clean")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
